"""CI guard for the BENCH_particles.json trajectory.

Fails (exit 1) when a particles benchmark run did not actually append to the
trajectory, or when an appended entry's schema drifted from the pinned
contract. Shared engine: :mod:`benchmarks.trajcheck`. Usage (see
.github/workflows/ci.yml):

    N=$(python -m benchmarks.check_particles --count)
    python -m benchmarks.run --only particles --quick
    python -m benchmarks.check_particles --prev-count "$N" --min-new 2
"""

from __future__ import annotations

from pathlib import Path

from .trajcheck import run_check

TRAJ = Path(__file__).resolve().parents[1] / "BENCH_particles.json"

SCHEMA: dict[str, type | tuple[type, ...]] = {
    "scenario": str,
    "quick": bool,
    "mode": str,
    "nranks": int,
    "coarse_steps": int,
    "num_particles": int,
    "particles_per_s": (int, float),
    "redist_p2p_bytes_per_step": int,
    "moved_per_step": (int, float),
}
MODES = ("arena", "sharded")


def _check_extra(i: int, entry: dict) -> list[str]:
    errs = []
    if entry.get("mode") not in MODES:
        errs.append(f"entry {i}: mode {entry.get('mode')!r} not in {MODES}")
    if isinstance(entry.get("num_particles"), int) and entry["num_particles"] <= 0:
        errs.append(f"entry {i}: num_particles must be positive")
    return errs


def main() -> None:
    run_check(
        prog="check_particles", traj_path=TRAJ, schema=SCHEMA,
        check_extra=_check_extra,
    )


if __name__ == "__main__":
    main()
