"""Shared engine for the committed benchmark-trajectory CI guards.

Each guarded trajectory (``BENCH_stepping.json``, ``BENCH_particles.json``,
``BENCH_serving.json``) gets a thin CLI wrapper (``check_stepping.py`` /
``check_particles.py`` / ``check_serving.py``)
that supplies its path, pinned entry schema, and any extra per-entry rules;
the load/count/append/schema semantics live here exactly once, so the
guards cannot drift apart. Only entries appended after ``--prev-count`` are
validated, so trajectories may gain schema keys over time (e.g. stepping's
``stage_seconds_per_step`` per-stage breakdown, added with the telemetry
layer) without invalidating legacy entries. Protocol (see
.github/workflows/ci.yml):

    N=$(python -m benchmarks.check_<name> --count)
    python -m benchmarks.run --only <name> ...
    python -m benchmarks.check_<name> --prev-count "$N" --min-new K
"""

from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path
from typing import Callable


def _load(prog: str, traj_path: Path, *, missing_ok: bool = False) -> list:
    if missing_ok and not traj_path.exists():
        return []  # a deleted trajectory is a legitimate reset; count is 0
    try:
        traj = json.loads(traj_path.read_text())
    except (OSError, ValueError) as e:
        sys.exit(f"{prog}: cannot read {traj_path.name}: {e}")
    if not isinstance(traj, list):
        sys.exit(f"{prog}: {traj_path.name} is not a list")
    return traj


def check_schema(i: int, entry: dict, schema: dict) -> list[str]:
    errs = []
    for key, want in schema.items():
        if key not in entry:
            errs.append(f"entry {i}: missing key {key!r}")
        elif not isinstance(entry[key], want):
            errs.append(
                f"entry {i}: {key!r} has type {type(entry[key]).__name__}, "
                f"expected {want}"
            )
    return errs


def run_check(
    *,
    prog: str,
    traj_path: Path,
    schema: dict,
    check_extra: Callable[[int, dict], list[str]] | None = None,
) -> None:
    """Parse the shared CLI and enforce the append + schema contract.

    Only entries appended after ``--prev-count`` are validated — legacy
    entries may predate schema keys."""
    ap = argparse.ArgumentParser(prog=prog)
    ap.add_argument("--count", action="store_true",
                    help="print the current entry count and exit")
    ap.add_argument("--prev-count", type=int, default=None,
                    help="entry count before the benchmark ran")
    ap.add_argument("--min-new", type=int, default=1,
                    help="minimum entries the run must have appended")
    args = ap.parse_args()
    if args.count:
        print(len(_load(prog, traj_path, missing_ok=True)))
        return
    traj = _load(prog, traj_path)
    if args.prev_count is None:
        sys.exit(f"{prog}: --prev-count is required (or use --count)")
    new = traj[args.prev_count:]
    if len(new) < args.min_new:
        sys.exit(
            f"{prog}: benchmark appended {len(new)} entries "
            f"(< {args.min_new}): the run did not record results"
        )
    errs = [
        e
        for i, entry in enumerate(new, start=args.prev_count)
        for e in check_schema(i, entry, schema)
        + (check_extra(i, entry) if check_extra else [])
    ]
    if errs:
        sys.exit(f"{prog}: schema drift:\n  " + "\n  ".join(errs))
    print(f"{prog}: OK ({len(new)} new entries, schema intact)")
