"""CI guard for the BENCH_serving.json trajectory.

Fails (exit 1) when a serving benchmark run did not actually append to the
trajectory, or when an appended entry's schema drifted from the pinned
contract. Shared engine: :mod:`benchmarks.trajcheck`. Usage (see
.github/workflows/ci.yml):

    N=$(python -m benchmarks.check_serving --count)
    python -m benchmarks.run --only serving --quick
    python -m benchmarks.check_serving --prev-count "$N" --min-new 1
"""

from __future__ import annotations

from pathlib import Path

from .trajcheck import run_check

TRAJ = Path(__file__).resolve().parents[1] / "BENCH_serving.json"

SCHEMA: dict[str, type | tuple[type, ...]] = {
    "scenario": str,
    "quick": bool,
    "njobs": int,
    "coarse_steps": int,
    "amr_interval": int,
    "sequential_jobs_per_s": (int, float),
    "batched_jobs_per_s": (int, float),
    "batched_speedup": (int, float),
    "compile_hits": int,
    "compile_misses": int,
    "compile_cache_hit_rate": (int, float),
    "divergence_splits": int,
}


def _check_extra(i: int, entry: dict) -> list[str]:
    errs = []
    rate = entry.get("compile_cache_hit_rate")
    if isinstance(rate, (int, float)) and not (0.0 <= rate <= 1.0):
        errs.append(f"entry {i}: compile_cache_hit_rate {rate} outside [0, 1]")
    for key in ("sequential_jobs_per_s", "batched_jobs_per_s"):
        v = entry.get(key)
        if isinstance(v, (int, float)) and v <= 0:
            errs.append(f"entry {i}: {key} must be positive, got {v}")
    return errs


def main() -> None:
    run_check(
        prog="check_serving", traj_path=TRAJ, schema=SCHEMA,
        check_extra=_check_extra,
    )


if __name__ == "__main__":
    main()
