"""CI guard for the BENCH_stepping.json trajectory.

Fails (exit 1) when a benchmark run did not actually append to the
trajectory, or when an appended entry's schema drifted from the pinned
contract — silent schema drift would make the committed trajectory
incomparable across PRs. Shared engine: :mod:`benchmarks.trajcheck`. Usage
(see .github/workflows/ci.yml):

    N=$(python -m benchmarks.check_stepping --count)
    python -m benchmarks.run --only stepping --quick ...
    python -m benchmarks.check_stepping --prev-count "$N" --min-new 1
"""

from __future__ import annotations

from pathlib import Path

from .trajcheck import run_check

TRAJ = Path(__file__).resolve().parents[1] / "BENCH_stepping.json"

# entry contract: key -> type(s); "blocks_per_s" and "compile_s" additionally
# must contain every stepping mode the benchmark exercises
SCHEMA: dict[str, type | tuple[type, ...]] = {
    "scenario": str,
    "cells_per_block": list,
    "quick": bool,
    "coarse_steps": int,
    "best_of": int,
    "nranks": int,
    "blocks_per_s": dict,
    "compile_s": dict,
    # mode -> {halo/step/fused: seconds per coarse step of the timed region},
    # derived from the telemetry-backed data_stats (see README Observability)
    "stage_seconds_per_step": dict,
    "arena_speedup": (int, float),
    "fused_speedup": (int, float),
    "sharded_speedup": (int, float),
    "fused_sharded_speedup": (int, float),
    "sharded_halo_p2p_bytes_per_step": int,
    "fused_sharded_halo_p2p_bytes_per_step": int,
}
MODES = ("restack", "arena", "fused", "sharded", "fused_sharded")
# modes the benchmark only exercises when the environment supports them
# (device_sharded needs >= nranks XLA devices): required to be well-formed
# when present, never required to exist — legacy entries and single-device
# runs stay valid
OPTIONAL_MODES = ("device_sharded",)


def _check_mode(i: int, entry: dict, mode: str, *, required: bool) -> list[str]:
    errs = []
    bps = entry.get("blocks_per_s")
    present = isinstance(bps, dict) and mode in bps
    if not required and not present:
        return []
    if isinstance(bps, dict) and not isinstance(bps.get(mode), (int, float)):
        errs.append(f"entry {i}: blocks_per_s[{mode!r}] missing or non-numeric")
    cs = entry.get("compile_s")
    if isinstance(cs, dict) and not isinstance(cs.get(mode), (int, float)):
        errs.append(f"entry {i}: compile_s[{mode!r}] missing or non-numeric")
    ss = entry.get("stage_seconds_per_step")
    if isinstance(ss, dict):
        per_mode = ss.get(mode)
        if not isinstance(per_mode, dict) or not all(
            isinstance(v, (int, float)) and v >= 0 for v in per_mode.values()
        ):
            errs.append(
                f"entry {i}: stage_seconds_per_step[{mode!r}] missing or "
                "not a stage->seconds dict"
            )
    return errs


def _check_extra(i: int, entry: dict) -> list[str]:
    errs = []
    for mode in MODES:
        errs.extend(_check_mode(i, entry, mode, required=True))
    for mode in OPTIONAL_MODES:
        errs.extend(_check_mode(i, entry, mode, required=False))
        if isinstance(entry.get("blocks_per_s"), dict) and mode in entry["blocks_per_s"]:
            for key in (f"{mode}_speedup", f"{mode}_halo_p2p_bytes_per_step"):
                if not isinstance(entry.get(key), (int, float)):
                    errs.append(f"entry {i}: {key!r} missing or non-numeric")
    return errs


def main() -> None:
    run_check(
        prog="check_stepping", traj_path=TRAJ, schema=SCHEMA,
        check_extra=_check_extra,
    )


if __name__ == "__main__":
    main()
