"""CI guard for the BENCH_stepping.json trajectory.

Fails (exit 1) when a benchmark run did not actually append to the
trajectory, or when an appended entry's schema drifted from the pinned
contract — silent schema drift would make the committed trajectory
incomparable across PRs. Usage (see .github/workflows/ci.yml):

    N=$(python -m benchmarks.check_stepping --count)
    python -m benchmarks.run --only stepping --quick ...
    python -m benchmarks.check_stepping --prev-count "$N" --min-new 1
"""

from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

TRAJ = Path(__file__).resolve().parents[1] / "BENCH_stepping.json"

# entry contract: key -> type(s); "blocks_per_s" additionally must contain
# every stepping mode the benchmark exercises
SCHEMA: dict[str, type | tuple[type, ...]] = {
    "scenario": str,
    "cells_per_block": list,
    "quick": bool,
    "coarse_steps": int,
    "best_of": int,
    "nranks": int,
    "blocks_per_s": dict,
    "arena_speedup": (int, float),
    "fused_speedup": (int, float),
    "sharded_speedup": (int, float),
    "sharded_halo_p2p_bytes_per_step": int,
}
MODES = ("restack", "arena", "fused", "sharded")


def _load(*, missing_ok: bool = False) -> list:
    if missing_ok and not TRAJ.exists():
        return []  # a deleted trajectory is a legitimate reset; count is 0
    try:
        traj = json.loads(TRAJ.read_text())
    except (OSError, ValueError) as e:
        sys.exit(f"check_stepping: cannot read {TRAJ.name}: {e}")
    if not isinstance(traj, list):
        sys.exit(f"check_stepping: {TRAJ.name} is not a list")
    return traj


def _check_entry(i: int, entry: dict) -> list[str]:
    errs = []
    for key, want in SCHEMA.items():
        if key not in entry:
            errs.append(f"entry {i}: missing key {key!r}")
        elif not isinstance(entry[key], want):
            errs.append(
                f"entry {i}: {key!r} has type {type(entry[key]).__name__}, "
                f"expected {want}"
            )
    for mode in MODES:
        bps = entry.get("blocks_per_s")
        if isinstance(bps, dict) and not isinstance(bps.get(mode), (int, float)):
            errs.append(f"entry {i}: blocks_per_s[{mode!r}] missing or non-numeric")
    return errs


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--count", action="store_true",
                    help="print the current entry count and exit")
    ap.add_argument("--prev-count", type=int, default=None,
                    help="entry count before the benchmark ran")
    ap.add_argument("--min-new", type=int, default=1,
                    help="minimum entries the run must have appended")
    args = ap.parse_args()
    if args.count:
        print(len(_load(missing_ok=True)))
        return
    traj = _load()
    if args.prev_count is None:
        sys.exit("check_stepping: --prev-count is required (or use --count)")
    new = traj[args.prev_count:]
    if len(new) < args.min_new:
        sys.exit(
            f"check_stepping: benchmark appended {len(new)} entries "
            f"(< {args.min_new}): the stepping run did not record results"
        )
    # legacy entries predate some keys; only *new* entries must match the
    # full contract
    errs = [e for i, entry in enumerate(new, start=args.prev_count)
            for e in _check_entry(i, entry)]
    if errs:
        sys.exit("check_stepping: schema drift:\n  " + "\n  ".join(errs))
    print(f"check_stepping: OK ({len(new)} new entries, schema intact)")


if __name__ == "__main__":
    main()
