"""Benchmark harness: one function per paper table/figure.

Prints ``name,metric,value`` CSV rows. Run with:
    PYTHONPATH=src python -m benchmarks.run [--quick]

Mapping to the paper:
  amr_cycle          Tables 4-7 / Figs 8-15: AMR cycle cost per balancer vs N
                     (wall seconds at small N; per-rank bytes/rounds vs N)
  balance_quality    Table 3: avg/max blocks per rank before/after balancing
  diffusion_iters    Figs 10/12: main iterations to perfect balance vs N
  metadata_sync      Table 1: bytes globally replicated per rank (SFC) vs
                     diffusion, weak scaling
  migration_volume   Figs 8/9/11/13 data-migration stage: bytes moved per rank
  lbm_mlups          kernel throughput (MLUPS, interpret-mode lower bound +
                     pure-jnp reference path)
  stepping           per-substep restacking vs persistent arena vs the fused
                     device superstep vs the rank-sharded data plane (host
                     p2p + device-resident fused_sharded): blocks/s of the
                     full substepping loop, best-of-k timed, swept over
                     --ranks, appended to the BENCH_stepping.json trajectory
  particles          Lagrangian tracer layer: particles/s advected (RK2 +
                     redistribution) per stepping mode + redistribution p2p
                     bytes per step, appended to BENCH_particles.json
  serving            serving layer: batched ensemble vs sequential execution
                     of identical jobs — jobs/s, speedup, and compile-cache
                     hit rate, appended to BENCH_serving.json
  roofline           §Roofline: renders the dry-run artifact table
"""

from __future__ import annotations

import argparse
import math
import time

import numpy as np


def _csv(name: str, metric: str, value) -> None:
    print(f"{name},{metric},{value}")


def _timed(fn, *args) -> float:
    t0 = time.perf_counter()
    fn(*args)
    return time.perf_counter() - t0


# -----------------------------------------------------------------------------


def amr_cycle(quick: bool = False) -> None:
    """One full AMR stress cycle per balancer; wall time + comm volume."""
    from repro.core import AMRPipeline, BlockDataRegistry, Comm, DiffusionBalancer, SFCBalancer
    from .scenario import build_scenario, stress_marks

    ranks = (8, 32) if quick else (8, 32, 128)
    balancers = {
        "sfc-morton": lambda: SFCBalancer(order="morton"),
        "sfc-hilbert": lambda: SFCBalancer(order="hilbert"),
        "diff-push": lambda: DiffusionBalancer(mode="push", flow_iterations=15, max_main_iterations=30),
        "diff-pushpull": lambda: DiffusionBalancer(mode="pushpull", flow_iterations=5, max_main_iterations=30),
    }
    for nranks in ranks:
        for name, make in balancers.items():
            forest, geom = build_scenario(nranks)
            for b in forest.all_blocks():
                b.data["payload"] = np.zeros(512, np.float32)  # 2 KiB stand-in
            comm = Comm(nranks)
            pipe = AMRPipeline(balancer=make(), registry=BlockDataRegistry.trivial("payload"))
            t0 = time.perf_counter()
            forest, rep = pipe.run_cycle(forest, comm, stress_marks(geom))
            dt = time.perf_counter() - t0
            _csv(f"amr_cycle/{name}", f"n{nranks}_wall_s", round(dt, 4))
            _csv(f"amr_cycle/{name}", f"n{nranks}_coll_bytes_per_rank", comm.stats.collective_bytes_per_rank)
            _csv(f"amr_cycle/{name}", f"n{nranks}_p2p_bytes_per_rank_max", comm.stats.max_sent_bytes_per_rank)
            _csv(f"amr_cycle/{name}", f"n{nranks}_balance_iters", rep.main_iterations)


def balance_quality(quick: bool = False) -> None:
    """Table 3: avg/max blocks per rank, before and after load balancing."""
    from repro.core import Comm, DiffusionBalancer
    from repro.core.proxy import build_proxy, migrate_proxy_blocks
    from repro.core.refine import mark_and_balance_targets
    from .scenario import build_scenario, stress_marks

    nranks = 32
    forest, geom = build_scenario(nranks)
    comm = Comm(nranks)
    changed, ghost = mark_and_balance_targets(forest, comm, stress_marks(geom))
    proxy = build_proxy(forest, comm, ghost)
    levels = proxy.levels_in_use()
    for lvl in levels:
        counts = proxy.blocks_per_rank(lvl)
        _csv("balance_quality", f"L{lvl}_before_avg", round(sum(counts) / nranks, 3))
        _csv("balance_quality", f"L{lvl}_before_max", max(counts))
    balancer = DiffusionBalancer(mode="pushpull", flow_iterations=5, max_main_iterations=30)
    it = 0
    while True:
        assignments, again = balancer(proxy, comm, it)
        migrate_proxy_blocks(proxy, forest, comm, assignments)
        it += 1
        if not again:
            break
    for lvl in levels:
        counts = proxy.blocks_per_rank(lvl)
        ceil = math.ceil(sum(counts) / nranks)
        _csv("balance_quality", f"L{lvl}_after_avg", round(sum(counts) / nranks, 3))
        _csv("balance_quality", f"L{lvl}_after_max", max(counts))
        _csv("balance_quality", f"L{lvl}_perfect_max", ceil)


def diffusion_iters(quick: bool = False) -> None:
    """Figs 10/12: main iterations to perfect balance vs rank count."""
    from repro.core import AMRPipeline, BlockDataRegistry, Comm, DiffusionBalancer
    from .scenario import build_scenario, stress_marks

    ranks = (8, 32) if quick else (8, 16, 32, 64, 128)
    for mode, flows in (("push", 15), ("pushpull", 5)):
        for nranks in ranks:
            forest, geom = build_scenario(nranks)
            comm = Comm(nranks)
            bal = DiffusionBalancer(mode=mode, flow_iterations=flows, max_main_iterations=40)
            pipe = AMRPipeline(balancer=bal, registry=BlockDataRegistry.trivial())
            forest, rep = pipe.run_cycle(forest, comm, stress_marks(geom))
            _csv(f"diffusion_iters/{mode}", f"n{nranks}", rep.main_iterations)


def metadata_sync(quick: bool = False) -> None:
    """Table 1: per-rank bytes held after the balancing synchronization."""
    from repro.core import AMRPipeline, BlockDataRegistry, Comm, DiffusionBalancer, SFCBalancer
    from .scenario import build_scenario, stress_marks

    ranks = (8, 32) if quick else (8, 32, 128)
    cases = {
        "sfc_per_level_ids": lambda: SFCBalancer(per_level=True, weighted=False),
        "sfc_per_level_weighted": lambda: SFCBalancer(per_level=True, weighted=True),
        "sfc_flat_counts": lambda: SFCBalancer(per_level=False, weighted=False),
        "diffusion": lambda: DiffusionBalancer(mode="pushpull", flow_iterations=5, max_main_iterations=20),
    }
    for nranks in ranks:
        for name, make in cases.items():
            forest, geom = build_scenario(nranks)
            comm = Comm(nranks)
            pipe = AMRPipeline(balancer=make(), registry=BlockDataRegistry.trivial())
            pipe.run_cycle(forest, comm, stress_marks(geom))
            _csv(f"metadata_sync/{name}", f"n{nranks}_bytes_per_rank", comm.stats.collective_bytes_per_rank)


def migration_volume(quick: bool = False) -> None:
    """Data-migration stage volume per balancer (Figs 8/9/11/13 breakdown)."""
    from repro.core import AMRPipeline, BlockDataRegistry, Comm, DiffusionBalancer, SFCBalancer
    from .scenario import build_scenario, stress_marks

    nranks = 32
    for name, make in (
        ("sfc-morton", lambda: SFCBalancer(order="morton")),
        ("diff-pushpull", lambda: DiffusionBalancer(mode="pushpull", flow_iterations=5, max_main_iterations=30)),
    ):
        forest, geom = build_scenario(nranks)
        for b in forest.all_blocks():
            b.data["payload"] = np.zeros(16384, np.float32)  # 64 KiB per block
        comm = Comm(nranks)
        pipe = AMRPipeline(balancer=make(), registry=BlockDataRegistry.trivial("payload"))
        forest, rep = pipe.run_cycle(forest, comm, stress_marks(geom))
        mig = rep.stages.get("migrate")
        bal = rep.stages.get("balance")
        _csv(f"migration_volume/{name}", "migrate_bytes_total", mig.p2p_bytes)
        _csv(f"migration_volume/{name}", "balance_bytes_total", bal.p2p_bytes)
        _csv(f"migration_volume/{name}", "proxy_blocks_moved", rep.proxy_blocks_moved)


def lbm_mlups(quick: bool = False) -> None:
    """Fused stream-collide throughput (CPU; TPU numbers come from the
    roofline model — interpret-mode wall time is NOT the TPU projection)."""
    import jax
    import jax.numpy as jnp

    from repro.kernels.lbm_collide.ops import make_stream_collide
    from repro.lbm.lattice import D3Q19

    B, n = (2, 16) if quick else (4, 32)
    rng = np.random.default_rng(0)
    f = jnp.asarray(
        np.asarray(D3Q19.w, np.float32)[None, :, None, None, None]
        * (1 + 0.01 * rng.standard_normal((B, 19, n, n, n)).astype(np.float32))
    )
    mask = jnp.zeros((B, n, n, n), jnp.int32)
    for backend in ("ref", "pallas"):
        step = make_stream_collide(omega=1.6, backend=backend, interpret=True)
        out = step(f, mask)
        out.block_until_ready()
        t0 = time.perf_counter()
        reps = 3 if backend == "pallas" else 10
        for _ in range(reps):
            out = step(out, mask)
        out.block_until_ready()
        dt = (time.perf_counter() - t0) / reps
        mlups = B * n**3 / dt / 1e6
        _csv(f"lbm_mlups/{backend}", f"cells{B * n**3}", round(mlups, 3))


def stepping(
    quick: bool = False,
    *,
    best_of: int | None = None,
    ranks: tuple[int, ...] = (4,),
    steps: int | None = None,
    trace: str | None = None,
) -> None:
    """Per-substep restacking (seed) vs persistent arena vs the device-
    resident fused superstep vs the rank-sharded data plane (host p2p and
    device-resident fused_sharded) on the lid-driven-cavity config: blocks/s
    throughput of the full substepping loop (halo exchange + fused kernel),
    swept over simulated rank counts, appended to the BENCH_stepping.json
    trajectory (entry schema + append protocol: see README "Benchmark
    trajectories", guarded by benchmarks/check_stepping.py in CI).

    Single runs on a shared host are noise-bound (observed ~1.6x swings), so
    every timing is best-of-``best_of`` (default 2 quick / 3 full).

    With ``trace`` (a directory), telemetry is enabled for the timed region
    and one Chrome-trace artifact per (mode, nranks) is written there —
    render with ``tools/trace_report.py``. Tracing adds span overhead to the
    timed loops, so traced timings are not comparable with untraced entries.
    """
    from pathlib import Path

    from repro import telemetry
    from repro.lbm import AMRLBM

    from .scenario import cavity_config

    if trace:
        telemetry.configure(enabled=True)
    coarse = steps if steps is not None else (2 if quick else 4)
    k = best_of if best_of is not None else (2 if quick else 3)
    k = max(1, k)
    cells = (8, 8, 8) if quick else (16, 16, 16)
    # per-coarse-step stage attribution of the timed region (halo / step /
    # fused seconds from data_stats — exactly the spans, see telemetry docs)
    data_stages = ("halo", "step", "fused")
    traj_entries = []
    # restack/arena/fused never consult Block.owner, so their timings are
    # rank-independent: measure them once and reuse across the sweep
    baseline: dict[str, tuple[float, float, int, float, dict]] = {}
    rank_dependent = ("sharded", "fused_sharded", "device_sharded")
    for nranks in ranks:
        results: dict[str, float] = {}
        halo_bytes: dict[str, int] = {}
        wall: dict[str, float] = {}
        compile_s: dict[str, float] = {}
        stage_s: dict[str, dict[str, float]] = {}
        for mode in (
            "restack", "arena", "fused", "sharded", "fused_sharded",
            "device_sharded",
        ):
            if mode == "device_sharded":
                import jax

                if jax.device_count() < nranks:
                    print(
                        f"stepping: skipping device_sharded at n{nranks} "
                        f"(only {jax.device_count()} XLA device(s); set "
                        "XLA_FLAGS=--xla_force_host_platform_device_count"
                        f"={nranks})"
                    )
                    continue
            if mode not in rank_dependent and mode in baseline:
                (
                    results[mode], wall[mode], halo_bytes[mode],
                    compile_s[mode], stage_s[mode],
                ) = baseline[mode]
            else:
                cfg = cavity_config(
                    nranks=nranks, stepping_mode=mode, cells_per_block=cells
                )
                sim = AMRLBM(cfg)
                sim.advance(1)  # warm up the L0 stepper jit
                sim.adapt()  # develop the two-level structure
                # first post-adapt advance pays the program rebuild + jit for
                # the two-level topology: report it as compile_s, never fold
                # it into the throughput timing below
                compile_s[mode] = _timed(sim.advance, 1)
                sim.advance(1)  # explicit untimed steady-state warmup
                # block-steps per coarse step: level-l blocks substep 2^l times
                work = sum(
                    (2**l) * sum(1 for b in sim.forest.all_blocks() if b.level == l)
                    for l in sim.forest.levels_in_use()
                )
                # fused_sharded/device_sharded route their in-program device
                # messages through Comm but attribute them to the "fused"
                # stage (halo and step are indistinguishable inside the
                # per-rank / shard_map programs)
                stage = (
                    "fused"
                    if mode in ("fused_sharded", "device_sharded")
                    else "halo"
                )
                h0 = sim.data_stats[stage].p2p_bytes
                sec0 = {st: sim.data_stats[st].seconds for st in data_stages}
                if trace:
                    telemetry.get_tracer().reset()  # one artifact per mode
                dt = min(_timed(sim.advance, coarse) for _ in range(k))
                if trace:
                    telemetry.export.write_chrome_trace(
                        Path(trace) / f"stepping_{mode}_n{nranks}.trace.json"
                    )
                results[mode] = coarse * work / dt
                wall[mode] = dt
                # normalized to one coarse step of the timed region, so
                # entries are comparable across --best-of / --steps choices
                halo_bytes[mode] = (
                    sim.data_stats[stage].p2p_bytes - h0
                ) // (k * coarse)
                stage_s[mode] = {
                    st: round(
                        (sim.data_stats[st].seconds - sec0[st]) / (k * coarse), 6
                    )
                    for st in data_stages
                    if sim.data_stats[st].seconds > sec0[st]
                }
                if mode not in rank_dependent:
                    baseline[mode] = (
                        results[mode], wall[mode], halo_bytes[mode],
                        compile_s[mode], stage_s[mode],
                    )
            _csv(f"stepping/{mode}", f"n{nranks}_blocks_per_s", round(results[mode], 1))
            _csv(f"stepping/{mode}", f"n{nranks}_wall_s", round(wall[mode], 4))
            _csv(f"stepping/{mode}", f"n{nranks}_compile_s", round(compile_s[mode], 4))
        speedup = results["arena"] / results["restack"]
        fused_rel = results["fused"] / results["restack"]
        sharded_rel = results["sharded"] / results["restack"]
        fsh_rel = results["fused_sharded"] / results["restack"]
        _csv("stepping", f"n{nranks}_arena_speedup", round(speedup, 3))
        _csv("stepping", f"n{nranks}_fused_speedup", round(fused_rel, 3))
        _csv("stepping", f"n{nranks}_sharded_speedup", round(sharded_rel, 3))
        _csv("stepping", f"n{nranks}_fused_sharded_speedup", round(fsh_rel, 3))
        _csv("stepping", f"n{nranks}_sharded_halo_bytes_per_step", halo_bytes["sharded"])
        # device_sharded is present only when the process has >= nranks XLA
        # devices (see the skip above), so its keys are optional in the
        # trajectory schema (validated when present by check_stepping.py)
        dev_extra: dict[str, float | int] = {}
        if "device_sharded" in results:
            dev_rel = results["device_sharded"] / results["restack"]
            _csv("stepping", f"n{nranks}_device_sharded_speedup", round(dev_rel, 3))
            dev_extra = {
                "device_sharded_speedup": round(dev_rel, 3),
                "device_sharded_halo_p2p_bytes_per_step": halo_bytes[
                    "device_sharded"
                ],
            }
        traj_entries.append(
            {
                "scenario": "lid-driven-cavity",
                "cells_per_block": list(cells),  # quick/full differ ~8x in blocks/s
                "quick": quick,
                "coarse_steps": coarse,
                "best_of": k,
                "nranks": nranks,
                "blocks_per_s": {m: round(v, 1) for m, v in results.items()},
                "compile_s": {m: round(v, 4) for m, v in compile_s.items()},
                # mode -> {halo/step/fused: seconds per coarse step of the
                # timed region}; sums to ~wall/(best_of*coarse) per mode
                "stage_seconds_per_step": dict(stage_s),
                "arena_speedup": round(speedup, 3),
                "fused_speedup": round(fused_rel, 3),
                "sharded_speedup": round(sharded_rel, 3),
                "fused_sharded_speedup": round(fsh_rel, 3),
                "sharded_halo_p2p_bytes_per_step": halo_bytes["sharded"],
                "fused_sharded_halo_p2p_bytes_per_step": halo_bytes["fused_sharded"],
                **dev_extra,
            }
        )
    _append_trajectory("stepping", "BENCH_stepping.json", traj_entries)


def _append_trajectory(bench: str, filename: str, entries: list[dict]) -> None:
    """Append entries to a committed JSON trajectory (atomic, corruption-safe
    — same protocol as the stepping trajectory). Warnings are reported under
    ``bench`` in the name column, like every other row the bench emits."""
    import json
    from pathlib import Path

    traj_path = Path(__file__).resolve().parents[1] / filename
    try:
        traj = json.loads(traj_path.read_text())
        if not isinstance(traj, list):
            raise ValueError("trajectory is not a list")
    except OSError:  # no trajectory yet
        traj = []
    except ValueError:  # corrupt/partial/wrong shape: preserve aside, don't wipe
        bad = traj_path.with_suffix(".json.corrupt")
        traj_path.replace(bad)
        _csv(bench, "trajectory_warning", f"unreadable, moved to {bad.name}")
        traj = []
    traj.extend(entries)
    tmp = traj_path.with_suffix(".json.tmp")
    tmp.write_text(json.dumps(traj, indent=2) + "\n")
    tmp.replace(traj_path)  # atomic: a killed run can't truncate the trajectory


def particles(quick: bool = False) -> None:
    """Lagrangian tracer throughput: particles advected per second (trilinear
    RK2 + redistribution, the whole data_stats["particles"] stage) per
    stepping mode, plus redistribution p2p bytes and block moves per coarse
    step. Tracers are clustered under the lid so the run exercises the
    heterogeneous cells + alpha*N load model and real redistribution."""
    from repro.lbm import AMRLBM
    from repro.particles import ParticlesConfig

    from .scenario import cavity_config

    per_block = 64 if quick else 256
    coarse = 2 if quick else 4
    nranks = 4
    traj_entries = []
    for mode in ("arena", "sharded"):
        cfg = cavity_config(
            nranks=nranks,
            stepping_mode=mode,
            particles=ParticlesConfig(
                per_block=per_block,
                seed=1,
                alpha=0.05,
                region=((0.0, 0.0, 1.5), (2.0, 2.0, 2.0)),
            ),
        )
        sim = AMRLBM(cfg)
        sim.advance(1)  # warm up steppers + the advection kernel jit
        sim.adapt()  # develop the two-level structure
        sim.advance(1)
        n = sim.total_particles()
        st = sim.data_stats["particles"]
        t0, b0, m0 = st.seconds, st.p2p_bytes, sim.particles_moved
        sim.advance(coarse)
        dt = st.seconds - t0
        pps = n * coarse / max(dt, 1e-9)
        redist_bytes = (st.p2p_bytes - b0) // coarse
        moved = (sim.particles_moved - m0) / coarse
        _csv(f"particles/{mode}", "num_particles", n)
        _csv(f"particles/{mode}", "particles_per_s", round(pps, 1))
        _csv(f"particles/{mode}", "redist_p2p_bytes_per_step", redist_bytes)
        _csv(f"particles/{mode}", "moved_per_step", round(moved, 2))
        traj_entries.append(
            {
                "scenario": "lid-driven-cavity-tracers",
                "quick": quick,
                "mode": mode,
                "nranks": nranks,
                "coarse_steps": coarse,
                "num_particles": n,
                "particles_per_s": round(pps, 1),
                "redist_p2p_bytes_per_step": int(redist_bytes),
                "moved_per_step": round(moved, 2),
            }
        )
    _append_trajectory("particles", "BENCH_particles.json", traj_entries)


def serving(quick: bool = False) -> None:
    """Serving-layer amortization: the same 4 identical jobs executed as one
    batched ensemble (shared compiled superstep, per-member coefficients as
    batched operands) vs sequentially as independent fused runs (each paying
    its own program compiles). Emits jobs/s for both paths, the speedup, and
    the batched path's compile-cache hit rate; appends to the
    BENCH_serving.json trajectory (guarded by benchmarks/check_serving.py)."""
    from repro.serving import JobSpec, SimulationService

    from .scenario import cavity_config

    njobs = 4
    steps = 8 if quick else 12
    interval = 4

    def run_jobs(batching: bool) -> tuple[float, SimulationService]:
        svc = SimulationService(batching=batching)
        # sequential baseline = today's best solo path (device-resident fused)
        mode = "arena" if batching else "fused"
        for _ in range(njobs):
            svc.submit(
                JobSpec(
                    config=cavity_config(stepping_mode=mode),
                    coarse_steps=steps,
                    amr_interval=interval,
                    collect_diagnostics=False,
                )
            )
        t0 = time.perf_counter()
        svc.run()
        return time.perf_counter() - t0, svc

    seq_dt, _seq = run_jobs(batching=False)
    bat_dt, bat = run_jobs(batching=True)
    seq_jps = njobs / seq_dt
    bat_jps = njobs / bat_dt
    speedup = bat_jps / seq_jps
    s = bat.summary()
    _csv("serving/sequential", "jobs_per_s", round(seq_jps, 3))
    _csv("serving/batched", "jobs_per_s", round(bat_jps, 3))
    _csv("serving", "batched_speedup", round(speedup, 3))
    _csv("serving", "compile_cache_hit_rate", round(s["compile_cache_hit_rate"], 3))
    _csv("serving", "compile_misses", s["compile_misses"])
    _csv("serving", "divergence_splits", s["divergence_splits"])
    _append_trajectory(
        "serving",
        "BENCH_serving.json",
        [
            {
                "scenario": "lid-driven-cavity",
                "quick": quick,
                "njobs": njobs,
                "coarse_steps": steps,
                "amr_interval": interval,
                "sequential_jobs_per_s": round(seq_jps, 3),
                "batched_jobs_per_s": round(bat_jps, 3),
                "batched_speedup": round(speedup, 3),
                "compile_hits": s["compile_hits"],
                "compile_misses": s["compile_misses"],
                "compile_cache_hit_rate": round(s["compile_cache_hit_rate"], 3),
                "divergence_splits": s["divergence_splits"],
            }
        ],
    )


def roofline(quick: bool = False) -> None:
    """Render the §Roofline table from the dry-run artifacts."""
    import json
    from pathlib import Path

    art_dir = Path(__file__).resolve().parents[1] / "experiments" / "dryrun"
    rows = sorted(art_dir.glob("*.json"))
    if not rows:
        _csv("roofline", "status", "no dry-run artifacts (run repro.launch.dryrun)")
        return
    for path in rows:
        d = json.loads(path.read_text())
        r = d["roofline"]
        name = f"{d['arch']}/{d['shape']}/{d['mesh']}"
        _csv(f"roofline/{name}", "dominant", r["dominant"])
        _csv(f"roofline/{name}", "compute_s", f"{r['compute_s']:.4g}")
        _csv(f"roofline/{name}", "memory_s", f"{r['memory_s']:.4g}")
        _csv(f"roofline/{name}", "collective_s", f"{r['collective_s']:.4g}")
        _csv(f"roofline/{name}", "roofline_fraction", f"{r.get('roofline_fraction', 0):.3f}")
        _csv(f"roofline/{name}", "useful_ratio", d["flops"]["useful_ratio"])


ALL = {
    "amr_cycle": amr_cycle,
    "balance_quality": balance_quality,
    "diffusion_iters": diffusion_iters,
    "metadata_sync": metadata_sync,
    "migration_volume": migration_volume,
    "lbm_mlups": lbm_mlups,
    "stepping": stepping,
    "particles": particles,
    "serving": serving,
    "roofline": roofline,
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--only", action="append", choices=sorted(ALL), default=None)
    ap.add_argument(
        "--best-of", type=int, default=None,
        help="stepping: timings are best-of-K (default 2 quick / 3 full)",
    )
    ap.add_argument(
        "--ranks", type=str, default="4",
        help="stepping: comma-separated simulated rank counts to sweep",
    )
    ap.add_argument(
        "--steps", type=int, default=None,
        help="stepping: coarse steps per timed run (default 2 quick / 4 full)",
    )
    ap.add_argument(
        "--trace", type=str, default=None,
        help="stepping: enable telemetry and write one Chrome-trace artifact "
             "per (mode, nranks) into this directory",
    )
    args = ap.parse_args()
    names = args.only or list(ALL)
    ranks = tuple(int(r) for r in args.ranks.split(",") if r)
    print("name,metric,value")
    for name in names:
        t0 = time.perf_counter()
        if name == "stepping":
            stepping(quick=args.quick, best_of=args.best_of, ranks=ranks,
                     steps=args.steps, trace=args.trace)
        else:
            ALL[name](quick=args.quick)
        _csv(name, "bench_wall_s", round(time.perf_counter() - t0, 2))


if __name__ == "__main__":
    main()
