"""The paper's synthetic benchmark scenario (§5.1.1), scaled down.

Lid-driven-cavity-style block structure: a 3-level-refined region near the
"lid edges", then an artificial AMR trigger that coarsens the finest level
and refines an equal number of coarser neighbors, so that the finest region
moves inward and ~70% of cells change size — the stress pattern of Fig. 7.

Weak scaling: the root grid grows with the rank count so the per-rank block
counts match Table 3 regardless of N.
"""

from __future__ import annotations

from repro.core import Comm, ForestGeometry, make_uniform_forest
from repro.core.forest import BlockForest

__all__ = ["build_scenario", "cavity_config", "stress_marks"]


def cavity_config(
    *,
    nranks: int = 1,
    stepping_mode: str = "arena",
    cells_per_block: tuple[int, int, int] = (8, 8, 8),
    omega: float = 1.5,
    u_lid: tuple[float, float, float] = (0.08, 0.0, 0.0),
    kernel_backend: str = "ref",
    particles=None,
):
    """The canonical benchmark lid-driven-cavity scenario, declared once.

    Every driver-level bench (stepping, particles, serving) runs this config:
    a 2x2x2 root grid with one refinement level developing under the lid,
    matching the conformance-test setup so benchmark numbers and correctness
    tests exercise the same scenario. Keyword overrides cover the axes the
    benches sweep (rank count, stepping mode, block size, physics, tracers).
    """
    from repro.lbm import LidDrivenCavityConfig

    return LidDrivenCavityConfig(
        root_grid=(2, 2, 2),
        cells_per_block=cells_per_block,
        nranks=nranks,
        omega=omega,
        u_lid=u_lid,
        max_level=1,
        refine_upper=0.03,
        refine_lower=0.004,
        stepping_mode=stepping_mode,
        kernel_backend=kernel_backend,  # interpret-mode pallas would mask the data-path cost
        particles=particles,
    )


def build_scenario(nranks: int, *, blocks_per_rank: int = 8) -> tuple[BlockForest, ForestGeometry]:
    """Forest with ~blocks_per_rank blocks/rank across 3 levels, weak-scaled."""
    # choose a root grid with ~nranks*blocks_per_rank/12 root blocks
    import math

    target_roots = max(1, nranks * blocks_per_rank // 16)
    rx = max(1, int(round(target_roots ** (1 / 3))))
    ry = max(1, int(round((target_roots / rx) ** 0.5)))
    rz = max(1, target_roots // (rx * ry))
    geom = ForestGeometry(root_grid=(rx, ry, rz), max_level=10)
    forest = make_uniform_forest(geom, nranks, level=0)
    comm = Comm(nranks)
    from repro.core import AMRPipeline, BlockDataRegistry, SFCBalancer

    pipe = AMRPipeline(balancer=SFCBalancer(), registry=BlockDataRegistry.trivial())

    # refine a corner region twice -> 3 levels (like the lid-edge refinement)
    def refine_corner(rank, blocks):
        out = {}
        for bid, blk in blocks.items():
            x0, y0, z0, _, _, z1 = geom.aabb(bid)
            full = 1 << geom.max_level
            if z1 >= rz * full and x0 < (rx * full) // 2 and blk.level < 2:
                out[bid] = blk.level + 1
        return out

    forest, _ = pipe.run_cycle(forest, comm, refine_corner)
    forest, _ = pipe.run_cycle(forest, comm, refine_corner)
    return forest, geom


def stress_marks(geom: ForestGeometry):
    """§5.1.1 trigger: coarsen the finest level, refine its coarser shell."""

    def mark(rank, blocks):
        finest = max((b.level for b in blocks.values()), default=0)
        out = {}
        for bid, blk in blocks.items():
            if blk.level == finest and finest > 0:
                out[bid] = blk.level - 1
            elif blk.level == finest - 1:
                out[bid] = blk.level + 1
        return out

    return mark
