"""Passive tracers in the lid-driven cavity vortex.

Seeds Lagrangian tracers under the moving lid (where the flow is fastest),
advects them through the AMR-coupled LBM velocity field, and prints how the
tracer cloud spreads, how many hop blocks/ranks, and how the particle-aware
load model shifts weighted load across ranks.

    PYTHONPATH=src python examples/particles_in_cavity.py --steps 12
"""

from __future__ import annotations

import argparse

import numpy as np

from repro.lbm import AMRLBM, LidDrivenCavityConfig
from repro.particles import ParticlesConfig, all_particles


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=12)
    ap.add_argument("--mode", default="arena",
                    choices=["restack", "arena", "fused", "sharded",
                             "fused_sharded"])
    ap.add_argument("--nranks", type=int, default=4)
    ap.add_argument("--per-block", type=int, default=32)
    args = ap.parse_args()

    cfg = LidDrivenCavityConfig(
        root_grid=(2, 2, 2),
        cells_per_block=(8, 8, 8),
        nranks=args.nranks,
        omega=1.5,
        u_lid=(0.08, 0.0, 0.0),
        max_level=1,
        refine_upper=0.03,
        refine_lower=0.004,
        stepping_mode=args.mode,
        kernel_backend="ref",
        # seed the tracers into the developing lid vortex
        particles=ParticlesConfig(
            per_block=args.per_block,
            seed=1,
            alpha=0.05,
            region=((0.0, 0.0, 1.6), (2.0, 2.0, 2.0)),
        ),
    )
    sim = AMRLBM(cfg)
    n0 = sim.total_particles()
    print(f"seeded {n0} tracers under the lid "
          f"({args.mode} stepping, {args.nranks} simulated ranks)")
    for i in range(args.steps):
        sim.advance(1)
        if (i + 1) % 4 == 0:
            sim.adapt()
        p = all_particles(sim.forest)
        com = p["pos"].mean(axis=0)
        spread = p["pos"].std(axis=0)
        vmax = float(np.abs(p["vel"]).max()) if len(p["id"]) else 0.0
        print(
            f"step {i + 1:3d}: com=({com[0]:.3f},{com[1]:.3f},{com[2]:.3f}) "
            f"spread=({spread[0]:.3f},{spread[1]:.3f},{spread[2]:.3f}) "
            f"max|v|={vmax:.4f} moved={sim.particles_moved} "
            f"blocks={sim.forest.num_blocks()}"
        )
    assert sim.total_particles() == n0, "tracer population must be conserved"
    loads = sim.forest.weights_per_rank()
    print("weighted load per rank:", [round(w, 1) for w in loads])
    st = sim.data_stats["particles"]
    print(
        f"particle stage: {st.seconds:.2f}s, advected {sim.particles_advected}, "
        f"cross-rank redistribution {st.p2p_bytes} bytes in {st.p2p_messages} messages"
    )


if __name__ == "__main__":
    main()
