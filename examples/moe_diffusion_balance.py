"""The paper's technique applied to MoE serving: diffusion-balanced experts.

Experts are blocks, router token-counts are weights, expert-parallel device
groups are ranks (DESIGN.md §4). We simulate a skewed router (Zipf-like
expert popularity drifting over time) on the granite-moe-1b config (32
experts, top-8) across 16 EP groups, and rebalance the placement with the
same DiffusionBalancer that rebalances the AMR mesh — comparing against the
static (contiguous) placement a vanilla EP sharding uses.

    PYTHONPATH=src python examples/moe_diffusion_balance.py
"""

import numpy as np

from repro.configs import get_config
from repro.train.moe_balance import ExpertPlacement


def router_loads(rng, n_experts: int, t: float) -> np.ndarray:
    """Zipf-ish expert popularity whose ranking drifts over time."""
    ranks = (np.arange(n_experts) + 7 * t) % n_experts
    base = 1.0 / (1.0 + ranks) ** 1.2
    noise = rng.lognormal(0.0, 0.25, n_experts)
    load = base * noise
    return load / load.sum() * 100_000  # tokens routed per window


def main() -> None:
    cfg = get_config("granite-moe-1b-a400m")
    E, groups = cfg.n_experts, 16
    rng = np.random.default_rng(0)
    static = ExpertPlacement(n_experts=E, n_groups=groups)
    dynamic = ExpertPlacement(n_experts=E, n_groups=groups)

    print(f"{cfg.arch_id}: {E} experts on {groups} EP groups "
          f"(static vs diffusion-rebalanced placement)\n")
    print(f"{'window':>6s} {'static max':>12s} {'dynamic max':>12s} "
          f"{'avg':>9s} {'moved':>6s} {'iters':>6s}")
    worst_static, worst_dyn = 0.0, 0.0
    for t in range(8):
        loads = router_loads(rng, E, t)
        s_max = static.group_loads(loads).max()
        moved, iters = dynamic.rebalance(loads)
        d_max = dynamic.group_loads(loads).max()
        avg = loads.sum() / groups
        worst_static = max(worst_static, s_max / avg)
        worst_dyn = max(worst_dyn, d_max / avg)
        print(f"{t:6d} {s_max:12.0f} {d_max:12.0f} {avg:9.0f} "
              f"{len(moved):6d} {iters:6d}")
    print(f"\npeak overload (max/avg): static {worst_static:.2f}x vs "
          f"diffusion {worst_dyn:.2f}x")
    print("expert->group permutation for the sharded weights:",
          dynamic.permutation()[:12], "...")


if __name__ == "__main__":
    main()
