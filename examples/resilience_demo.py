"""Fault tolerance demo (paper §4.2): buddy snapshots + shrink-restart.

A running AMR/LBM-style simulation takes periodic in-memory snapshots
(every rank backs up rank (X+N/2) mod N). We then kill 3 of 8 ranks and
show the simulation resuming on 5 ranks after one forced AMR cycle, with
all block payloads intact.

    PYTHONPATH=src python examples/resilience_demo.py
"""

import numpy as np

from repro.core import (
    AMRPipeline,
    Comm,
    DiffusionBalancer,
    FieldRegistry,
    FieldSpec,
    ForestGeometry,
    make_uniform_forest,
)
from repro.core.checkpoint import load_checkpoint, save_checkpoint
from repro.core.resilience import ResilienceManager


def main() -> None:
    geom = ForestGeometry(root_grid=(2, 2, 2), max_level=8)
    nranks = 8
    forest = make_uniform_forest(geom, nranks, level=1)

    # one typed declaration drives snapshot/restore AND disk checkpointing
    # (FieldRegistry derives the §2.5 callbacks; BlockDataRegistry.trivial()
    #  remains available for truly opaque payloads)
    reg = FieldRegistry(
        cells=(4, 4, 4),
        fields=(FieldSpec("payload", dtype=np.float32, refine="interpolate", coarsen="restrict"),),
    )
    rng = np.random.default_rng(0)
    for b in forest.all_blocks():
        arr = reg.alloc("payload")
        arr[...] = rng.standard_normal(arr.shape)
        b.data["payload"] = arr
    checksum = sum(float(b.data["payload"].sum()) for b in forest.all_blocks())

    pipe = AMRPipeline(
        balancer=DiffusionBalancer(mode="pushpull", flow_iterations=5, max_main_iterations=20),
        registry=reg,
    )
    comm = Comm(nranks)

    # --- in-memory buddy snapshot (no disk I/O) ------------------------------
    mgr = ResilienceManager(reg)
    mgr.snapshot(forest, comm)
    snap_bytes = sum(s.nbytes() for s in mgr.snapshots)
    print(f"snapshot taken: {forest.num_blocks()} blocks, "
          f"{snap_bytes / 1024:.0f} KiB redundant state, "
          f"p2p bytes {comm.stats.p2p_bytes}")

    # --- kill 3 ranks, restore + rebalance on 5 -------------------------------
    failed = {1, 2, 7}
    print(f"simulating failure of ranks {sorted(failed)} ...")
    restored, comm2 = mgr.fail_and_restore(forest, failed, pipe)
    restored.check_all()
    checksum2 = sum(float(b.data["payload"].sum()) for b in restored.all_blocks())
    print(f"restored on {restored.nranks} ranks: {restored.num_blocks()} blocks, "
          f"per-rank {restored.blocks_per_rank()}")
    print(f"payload checksum: {checksum:.4f} -> {checksum2:.4f} "
          f"({'OK' if abs(checksum - checksum2) < 1e-3 else 'MISMATCH'})")

    # --- disk checkpoint/restart on a different rank count (§4.1) -------------
    save_checkpoint(restored, reg, "/tmp/repro_ckpt")
    again = load_checkpoint("/tmp/repro_ckpt", reg, nranks=12)
    again.check_all()
    print(f"disk checkpoint reloaded onto 12 ranks: per-rank "
          f"{again.blocks_per_rank()}")


if __name__ == "__main__":
    main()
