"""Quickstart: the four-step AMR pipeline on a toy forest in ~40 lines.

    PYTHONPATH=src python examples/quickstart.py
"""

from repro.core import (
    AMRPipeline,
    BlockDataRegistry,
    Comm,
    DiffusionBalancer,
    ForestGeometry,
    make_uniform_forest,
)

# a 2x2x2 root grid of octrees, distributed to 8 (simulated) ranks
geom = ForestGeometry(root_grid=(2, 2, 2), max_level=10)
forest = make_uniform_forest(geom, nranks=8, level=1)
for blk in forest.all_blocks():
    blk.data["payload"] = f"data-of-{blk.bid:#x}"  # blocks store arbitrary data

comm = Comm(nranks=8)
pipeline = AMRPipeline(
    balancer=DiffusionBalancer(mode="pushpull", flow_iterations=5, max_main_iterations=20),
    registry=BlockDataRegistry.trivial("payload"),
)


# mark callback: refine blocks touching the domain center, coarsen far corners
def mark(rank, blocks):
    out = {}
    center = (1 << geom.max_level), (1 << geom.max_level), (1 << geom.max_level)
    for bid, blk in blocks.items():
        x0, y0, z0, x1, y1, z1 = geom.aabb(bid)
        touches_center = x0 <= center[0] <= x1 and y0 <= center[1] <= y1 and z0 <= center[2] <= z1
        if touches_center and blk.level < 3:
            out[bid] = blk.level + 1
        elif not touches_center:
            out[bid] = blk.level - 1
    return out


print(f"before: {forest.num_blocks()} blocks, per-rank {forest.blocks_per_rank()}")
forest, report = pipeline.run_cycle(forest, comm, mark)
forest.check_all()  # leaf cover + adjacency + 2:1 balance
print(f"after:  {forest.num_blocks()} blocks, per-rank {forest.blocks_per_rank()}")
print(f"balance iterations: {report.main_iterations}, "
      f"proxy blocks moved: {report.proxy_blocks_moved}")
for stage, st in report.stages.items():
    print(f"  {stage:8s}: {st.seconds*1e3:7.1f} ms, {st.p2p_bytes:9d} p2p bytes, "
          f"{st.rounds} rounds")
print("comm totals:", comm.stats.summary())
