"""End-to-end driver: 3D lid-driven cavity with dynamic AMR (paper §5.1.1).

Runs the LBM (D3Q19, TRT) with the velocity-gradient refinement criterion,
diffusion load balancing, and per-level time stepping on persistent
LevelArena buffers (use ``--mode fused`` for the device-resident fused
superstep — one jitted program per coarse step — ``--mode restack`` for the
legacy per-substep restacking path, ``--mode sharded`` for the rank-sharded
data plane with cross-rank halo messaging, ``--mode fused_sharded`` for
the per-rank device-resident composition of the two, and ``--mode
device_sharded`` for one rank per XLA device with in-program ``ppermute``
halo routing — needs ``--nranks`` devices, e.g.
``XLA_FLAGS=--xla_force_host_platform_device_count=4 ... --mode
device_sharded --nranks 4``; see the README's "Choosing a stepping mode").
Prints per-epoch diagnostics including the AMR pipeline stage costs and,
per mode, data-plane halo traffic or host<->device transfer counts.

    PYTHONPATH=src python examples/lbm_cavity_amr.py [--steps 12] [--mode arena]
"""

import argparse

from repro.lbm import AMRLBM, LidDrivenCavityConfig


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=12)
    ap.add_argument("--amr-interval", type=int, default=3)
    ap.add_argument(
        "--mode",
        choices=(
            "arena", "fused", "sharded", "fused_sharded", "device_sharded",
            "restack",
        ),
        default="arena",
    )
    ap.add_argument("--nranks", type=int, default=8)
    args = ap.parse_args()

    cfg = LidDrivenCavityConfig(
        root_grid=(2, 2, 2),
        cells_per_block=(8, 8, 8),
        nranks=args.nranks,
        omega=1.6,
        u_lid=(0.08, 0.0, 0.0),
        collision="trt",
        max_level=2,
        refine_upper=0.04,
        refine_lower=0.006,
        balancer="diffusion-pushpull",
        stepping_mode=args.mode,
    )
    sim = AMRLBM(cfg)
    print(f"initial: {sim.forest.num_blocks()} blocks "
          f"({sim.num_fluid_cells()} fluid cells), mass {sim.total_mass():.2f}, "
          f"stepping={args.mode}")
    for epoch in range(args.steps // args.amr_interval):
        sim.advance(args.amr_interval)
        report = sim.adapt()
        sim.forest.check_all()
        levels = {l: sim.forest.blocks_per_rank(l) for l in sim.forest.levels_in_use()}
        print(
            f"step {sim.coarse_step:3d}: blocks={sim.forest.num_blocks():4d} "
            f"levels={sorted(levels)} vmax={sim.max_velocity():.4f} "
            f"mass={sim.total_mass():.2f} amr={'ran' if report.executed else 'skipped'}"
        )
        for lvl, counts in levels.items():
            print(f"    L{lvl}: max/rank={max(counts)} total={sum(counts)}")
    halo = sim.data_stats["halo"]
    if halo.p2p_bytes:
        print(f"halo traffic: {halo.p2p_bytes} bytes in {halo.p2p_messages} "
              f"p2p messages over {halo.exchange_rounds} rounds")
    if args.mode == "fused":
        res = sim.arena.device()
        fused = sim.data_stats["fused"]
        print(f"fused: {fused.exchange_rounds} in-program exchanges, "
              f"{res.h2d_transfers} h2d / {res.d2h_transfers} d2h transfers "
              f"({res.h2d_bytes + res.d2h_bytes} bytes total)")
    if args.mode == "fused_sharded":
        fused = sim.data_stats["fused"]
        residencies = [a.device() for a in sim.arenas.per_rank if a.levels()]
        h2d = sum(r.h2d_transfers for r in residencies)
        d2h = sum(r.d2h_transfers for r in residencies)
        print(f"fused_sharded: {fused.p2p_bytes} device-message bytes in "
              f"{fused.p2p_messages} p2p messages over {fused.exchange_rounds} "
              f"rounds; {h2d} h2d / {d2h} d2h transfers across "
              f"{len(residencies)} ranks")
    if args.mode == "device_sharded":
        fused = sim.data_stats["fused"]
        print(f"device_sharded: {fused.p2p_bytes} ppermute bytes in "
              f"{fused.p2p_messages} p2p messages over {fused.exchange_rounds} "
              f"in-program exchanges; {sim.comm.ppermute_rounds} ppermute "
              f"rounds, {sim.comm.ppermute_pad_bytes} pad bytes, "
              f"{sim.engine.device_held_bytes_per_rank()} held bytes/device")
    print(f"done: {sim.amr_cycles} AMR cycles executed")


if __name__ == "__main__":
    main()
