"""End-to-end training driver: train a reduced LM for a few hundred steps.

Uses the full production stack — model zoo, AdamW with fp32 masters,
microbatch gradient accumulation, the diffusion-balanced synthetic data
pipeline — at laptop scale (a reduced olmo-1b). On a real pod the same
driver runs with the full config plus the mesh/shardings from
``repro.launch.dryrun`` (see README).

    PYTHONPATH=src python examples/train_lm.py [--steps 200] [--arch olmo-1b]
"""

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.models.zoo import DistContext, build_model
from repro.train import (
    AdamWConfig,
    SyntheticTokenPipeline,
    adamw_init,
    make_train_step,
)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="olmo-1b")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--microbatches", type=int, default=2)
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced()
    model = build_model(cfg, DistContext(remat=False))
    params = model.init(jax.random.PRNGKey(0))
    opt = adamw_init(params)
    n_params = sum(int(x.size) for x in jax.tree.leaves(params))
    print(f"arch={args.arch} (reduced) params={n_params:,}")

    step = jax.jit(
        make_train_step(model, AdamWConfig(lr=3e-3, warmup_steps=20),
                        microbatches=args.microbatches)
    )
    pipe = SyntheticTokenPipeline(
        vocab=cfg.vocab, seq_len=args.seq, global_batch=args.batch, nranks=4
    )
    print(f"data buckets balanced onto 4 ranks in {pipe.balance_iters} diffusion "
          f"iterations; per-rank token loads {pipe.rank_load()}")

    t0 = time.perf_counter()
    tokens_seen = 0
    for i, batch in enumerate(pipe.structured_batches(args.steps)):
        b = {k: jnp.asarray(v) for k, v in batch.items()}
        params, opt, m = step(params, opt, b)
        tokens_seen += args.batch * args.seq
        if i % 20 == 0 or i == args.steps - 1:
            dt = time.perf_counter() - t0
            print(
                f"step {i:4d} loss={float(m['loss']):7.4f} "
                f"gnorm={float(m['grad_norm']):6.2f} "
                f"tok/s={tokens_seen / dt:9.0f}"
            )
    print("final loss:", float(m["loss"]))


if __name__ == "__main__":
    main()
