"""Generate the committed example telemetry trace: a 4-rank ``fused_sharded``
run whose timeline shows the per-substep emit/interior/route/absorb phases
(the PR 7 overlap structure), the AMR pipeline stages around an AMR event,
halo plan compiles, h2d/d2h residency traffic, and per-pair p2p byte
counters — everything ``tools/trace_report.py`` renders.

The 6x6x6 root grid matters: with 4 ranks, every rank then owns blocks with
no cross-rank face, so the interior/boundary split of the fused_sharded
substep actually engages (on a 4x4x4 grid every block of every rank is a
boundary block and no ``interior`` span ever appears). ``overlap_split=True``
forces the split on CPU too — a legitimate config override; the default
resolves to False on CPU only to keep the *bitwise* conformance contract,
which a trace run does not assert.

    PYTHONPATH=src python examples/trace_fused_sharded.py \
        [--out examples/traces/fused_sharded_4rank.trace.json]
    python tools/trace_report.py examples/traces/fused_sharded_4rank.trace.json
"""

import argparse

from repro import telemetry
from repro.lbm import AMRLBM, LidDrivenCavityConfig


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument(
        "--out", default="examples/traces/fused_sharded_4rank.trace.json"
    )
    ap.add_argument("--steps", type=int, default=4)
    args = ap.parse_args()

    telemetry.configure(enabled=True, capacity=8192)
    cfg = LidDrivenCavityConfig(
        root_grid=(6, 6, 6),
        cells_per_block=(4, 4, 4),
        nranks=4,
        max_level=1,
        stepping_mode="fused_sharded",
        overlap_split=True,  # see module docstring
    )
    sim = AMRLBM(cfg)
    sim.advance(args.steps // 2)
    sim.adapt(force_rebalance=True)  # the AMR event the timeline spans
    sim.advance(args.steps - args.steps // 2)

    path = telemetry.export.write_chrome_trace(args.out)
    tr = telemetry.get_tracer()
    phases = sorted({r.name for r in tr.records() if r.cat == "substep"})
    print(f"wrote {path} ({len(tr.records())} records)")
    print(f"substep phases: {phases}")
    print(f"per-rank buffers: {tr.buffer_stats()}")


if __name__ == "__main__":
    main()
