"""Sharding spec construction for every assigned architecture."""

import jax
import pytest
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs import SHAPES, all_arch_ids, cells_for, get_config
from repro.models.zoo import DistContext, build_model, init_cache
from repro.sharding.specs import (
    batch_pspecs,
    cache_pspecs,
    opt_state_pspecs,
    param_pspecs,
)
from repro.train.optimizer import adamw_init

AXES = ("data", "model")
SIZES = {"data": 16, "model": 16}


def _check_divisible(spec_tree, shape_tree):
    def check(spec, leaf):
        for dim, entry in zip(leaf.shape, tuple(spec)):
            if entry is None:
                continue
            names = entry if isinstance(entry, tuple) else (entry,)
            prod = 1
            for n in names:
                prod *= SIZES.get(n, 1)
            assert dim % prod == 0, (spec, leaf.shape)

    jax.tree.map(check, spec_tree, shape_tree, is_leaf=lambda x: isinstance(x, P))


@pytest.mark.slow
def test_param_and_opt_specs_all_archs():
    for arch in all_arch_ids():
        cfg = get_config(arch)
        model = build_model(cfg, DistContext())
        p_sds = jax.eval_shape(lambda m=model: m.init(jax.random.PRNGKey(0), jnp.bfloat16))
        specs = param_pspecs(cfg, p_sds, AXES, SIZES)
        assert jax.tree.structure(specs) == jax.tree.structure(p_sds)
        _check_divisible(specs, p_sds)
        # big matrices must actually be sharded on the model axis
        flat = jax.tree_util.tree_flatten_with_path(specs)[0]
        sharded = [s for _p, s in flat if "model" in str(s)]
        assert len(sharded) > 0, arch
        opt_sds = jax.eval_shape(adamw_init, p_sds)
        ospecs = opt_state_pspecs(cfg, opt_sds, AXES, SIZES)
        _check_divisible(
            jax.tree.map(lambda x: x, ospecs, is_leaf=lambda x: isinstance(x, P)),
            opt_sds,
        )


def test_cache_specs_all_cells():
    for arch in all_arch_ids():
        cfg = get_config(arch)
        for shape in cells_for(cfg):
            if shape.kind != "decode":
                continue
            c_sds = jax.eval_shape(
                lambda: init_cache(cfg, shape.global_batch, shape.seq_len, jnp.bfloat16)
            )
            specs = cache_pspecs(cfg, shape, c_sds, AXES, SIZES)
            assert jax.tree.structure(specs) == jax.tree.structure(c_sds)
            _check_divisible(specs, c_sds)
            if shape.global_batch == 1:
                # long-context: the KV sequence dim must be sharded on data
                flat = jax.tree_util.tree_flatten_with_path(specs)[0]
                kv = [s for p, s in flat if p and getattr(p[-1], "key", "") == "k"]
                if kv:
                    assert "data" in str(kv[0]), (arch, kv[0])


def test_batch_specs():
    for arch in all_arch_ids():
        cfg = get_config(arch)
        for shape in cells_for(cfg):
            specs = batch_pspecs(cfg, shape, AXES)
            assert "tokens" in specs


def test_wsc_is_identity_without_axes():
    dist = DistContext()
    x = jnp.ones((4, 4))
    assert dist.wsc(x, "b.") is x
