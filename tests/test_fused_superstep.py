"""Fused-superstep building blocks + stepping data-plane bugfix regressions.

* the compiled ghost plan (flat gather/scatter index arrays executed as jnp
  ops) reproduces the host exchange bit for bit, including the fine->coarse
  coalescence and coarse->fine explosion paths across a level transition;
* ghost-width-0 fields: interior diagnostics must not silently evaluate over
  empty ``arr[0:-0]`` slices;
* even-but-non-power-of-two cells per block are valid (the real halo
  alignment invariant), end to end through an AMR event;
* a caller-owned ``plan_cache`` can never replay a plan built for an older
  forest topology or storage binding.
"""

import numpy as np
import pytest

from repro.core import (
    AMRPipeline,
    Comm,
    ForestGeometry,
    LevelArena,
    SFCBalancer,
    make_uniform_forest,
)
from repro.kernels.lbm_collide.ops import apply_compiled_ghost_plan
from repro.lbm import AMRLBM, LidDrivenCavityConfig
from repro.lbm.grid import LBMBlockSpec, make_lbm_fields
from repro.lbm.halo import build_ghost_plan, compile_ghost_plan, fill_ghost_layers


def _seed_fields(forest, reg, rng=None):
    for b in forest.all_blocks():
        if rng is None:
            b.data["pdf"] = np.full(
                reg.block_shape("pdf"), float(b.bid % 97), np.float32
            )
        else:
            b.data["pdf"] = rng.standard_normal(reg.block_shape("pdf")).astype(
                np.float32
            )
        b.data["mask"] = np.zeros(reg.block_shape("mask"), np.int32)


def _two_level_arena(cells=(4, 4, 4)):
    """A 2-level forest (one root refined) with arena-backed random pdfs."""
    spec = LBMBlockSpec(cells=cells)
    reg = make_lbm_fields(spec)
    geom = ForestGeometry(root_grid=(2, 1, 1), max_level=6)
    forest = make_uniform_forest(geom, 1, level=0)
    _seed_fields(forest, reg)  # migration serializes fields during the cycle
    pipe = AMRPipeline(balancer=SFCBalancer(), registry=reg)
    root0 = min(b.bid for b in forest.all_blocks())
    forest, _ = pipe.run_cycle(
        forest, Comm(1), lambda r, blocks: {root0: 1}
    )
    assert forest.levels_in_use() == [0, 1]
    _seed_fields(forest, reg, rng=np.random.default_rng(7))
    arena = LevelArena(reg)
    arena.adopt(forest)
    return forest, reg, arena


def test_compiled_plan_matches_host_exchange_bitwise():
    forest, reg, arena = _two_level_arena()
    plan = compile_ghost_plan(
        forest,
        reg,
        {l: arena.slots(l) for l in arena.levels()},
        fields=("pdf",),
    )
    # the forest has a level transition, so all three resampling kinds and
    # both level directions must be present in the lowered ops
    assert {op.kind for op in plan.ops} == {"same", "fine", "coarse"}
    assert plan.num_cells > 0
    bufs = {l: np.array(arena.buffer(l, "pdf")) for l in arena.levels()}
    out = apply_compiled_ghost_plan(plan, {l: b for l, b in bufs.items()})

    fill_ghost_layers(forest, reg, fields=("pdf",))  # host reference, in place
    for l in arena.levels():
        np.testing.assert_array_equal(
            np.asarray(out[l]), arena.buffer(l, "pdf"), err_msg=f"level {l}"
        )


def test_compiled_plan_handles_integer_fields():
    """Regression: the fine-coalescence path multiplied by ``dtype(0.125)``,
    which is 0 for integer dtypes — int ghost cells came back zeroed (and
    with FLUID == 0 that silently turns walls into fluid)."""
    forest, reg, arena = _two_level_arena()
    rng = np.random.default_rng(11)
    for b in forest.all_blocks():  # in place: blocks hold arena views
        b.data["mask"][...] = rng.integers(0, 3, b.data["mask"].shape)
    plan = compile_ghost_plan(
        forest, reg, {l: arena.slots(l) for l in arena.levels()}, fields=("mask",)
    )
    bufs = {l: np.array(arena.buffer(l, "mask")) for l in arena.levels()}
    out = apply_compiled_ghost_plan(plan, bufs)
    fill_ghost_layers(forest, reg, fields=("mask",))
    for l in arena.levels():
        assert np.asarray(out[l]).any(), "int ghost fill must not be all-zero"
        np.testing.assert_array_equal(
            np.asarray(out[l]), arena.buffer(l, "mask"), err_msg=f"level {l}"
        )


def test_compiled_plan_levels_filter_restricts_targets_not_sources():
    forest, reg, arena = _two_level_arena()
    plan = compile_ghost_plan(
        forest,
        reg,
        {l: arena.slots(l) for l in arena.levels()},
        fields=("pdf",),
        levels={1},
    )
    assert all(op.dst_level == 1 for op in plan.ops)
    assert {op.src_level for op in plan.ops} == {0, 1}


# -- satellite: ghost-width-0 slicing ------------------------------------------


def test_zero_ghost_diagnostics_see_full_interior():
    """Regression: ``arr[g:-g]`` with ``g == 0`` is ``arr[0:0]`` — diagnostics
    silently summed empty arrays for zero-ghost fields."""
    cfg = LidDrivenCavityConfig(
        root_grid=(1, 1, 1),
        cells_per_block=(4, 4, 4),
        ghost=0,
        nranks=1,
        max_level=0,
        kernel_backend="ref",
        stepping_mode="restack",
    )
    sim = AMRLBM(cfg)
    ncells = 4**3 * sim.forest.num_blocks()
    assert sim.num_fluid_cells() == ncells
    # equilibrium at rho=1: total mass == fluid cell count (level 0 volume)
    assert abs(sim.total_mass() - ncells) < 1e-3
    assert sim.max_velocity() == 0.0


def test_zero_ghost_spec_interior_is_identity():
    spec = LBMBlockSpec(cells=(4, 4, 4), ghost=0)
    a = np.arange(4**3, dtype=np.float32).reshape(4, 4, 4)
    assert spec.interior(a).shape == (4, 4, 4)
    g1 = LBMBlockSpec(cells=(4, 4, 4), ghost=1)
    assert g1.interior(np.zeros((6, 6, 6))).shape == (4, 4, 4)


# -- satellite: even-but-non-pow2 cells per block ------------------------------


def test_even_non_pow2_cells_run_end_to_end():
    """The real invariant is *even* cells per block (octant split + halo
    alignment), not powers of two: a 6^3-cell config must survive stepping
    and an AMR event with mass conserved."""
    cfg = LidDrivenCavityConfig(
        root_grid=(2, 2, 2),
        cells_per_block=(6, 6, 6),
        nranks=2,
        omega=1.5,
        u_lid=(0.08, 0.0, 0.0),
        max_level=1,
        refine_upper=0.03,
        refine_lower=0.004,
        kernel_backend="ref",
        stepping_mode="arena",
    )
    sim = AMRLBM(cfg)
    m0 = sim.total_mass()
    sim.run(4, amr_interval=2)
    sim.forest.check_all()
    assert len(sim.forest.levels_in_use()) > 1  # exercised level transitions
    assert abs(sim.total_mass() - m0) / m0 < 1e-3
    assert np.isfinite(sim.max_velocity())


def test_odd_cells_rejected_with_aligned_message():
    with pytest.raises(AssertionError, match="even"):
        AMRLBM(LidDrivenCavityConfig(cells_per_block=(5, 5, 5)))


# -- satellite: stale plan_cache guard -----------------------------------------


def _uniform_arena(level=0):
    spec = LBMBlockSpec(cells=(4, 4, 4))
    reg = make_lbm_fields(spec)
    geom = ForestGeometry(root_grid=(2, 1, 1), max_level=6)
    forest = make_uniform_forest(geom, 1, level=level)
    _seed_fields(forest, reg)
    return forest, reg


def test_plan_cache_rebuilds_on_storage_rebind():
    """A cached plan holds views into the old arrays; replaying it after a
    storage rebind would fill the *old* arrays and leave the new ones
    untouched. The binding token must force a rebuild."""
    forest, reg = _uniform_arena()
    cache: dict = {}
    fill_ghost_layers(forest, reg, fields=("pdf",), plan_cache=cache)
    blocks = sorted(forest.all_blocks(), key=lambda b: b.bid)
    # rebind every block's storage (what LevelArena.adopt does on repack)
    for b in blocks:
        b.data["pdf"] = np.array(b.data["pdf"]) * 0 + float(b.bid % 97)
    fill_ghost_layers(forest, reg, fields=("pdf",), plan_cache=cache)
    a, b = blocks
    # a's low-x ghost plane must now hold b's value and vice versa
    assert np.all(a.data["pdf"][:, -1, 1:-1, 1:-1] == float(b.bid % 97))
    assert np.all(b.data["pdf"][:, 0, 1:-1, 1:-1] == float(a.bid % 97))


def test_plan_cache_version_token_guards_in_o1():
    """Callers that version their storage pass ``cache_token``: same token
    replays the cached plan (no O(blocks) scan), a bumped token rebuilds."""
    forest, reg = _uniform_arena()
    cache: dict = {}
    fill_ghost_layers(forest, reg, fields=("pdf",), plan_cache=cache, cache_token=1)
    (plan0, tok0) = next(iter(cache.values()))
    assert tok0 == ("version", 1)
    fill_ghost_layers(forest, reg, fields=("pdf",), plan_cache=cache, cache_token=1)
    assert next(iter(cache.values()))[0] is plan0  # replayed
    blocks = sorted(forest.all_blocks(), key=lambda b: b.bid)
    for b in blocks:  # storage rebind + version bump, as an arena adopt does
        b.data["pdf"] = np.array(b.data["pdf"]) * 0 + float(b.bid % 97)
    fill_ghost_layers(forest, reg, fields=("pdf",), plan_cache=cache, cache_token=2)
    assert next(iter(cache.values()))[0] is not plan0  # rebuilt
    a, b = blocks
    assert np.all(a.data["pdf"][:, -1, 1:-1, 1:-1] == float(b.bid % 97))


def test_plan_cache_rebuilds_on_topology_change():
    forest, reg = _uniform_arena()
    cache: dict = {}
    fill_ghost_layers(forest, reg, fields=("pdf",), plan_cache=cache)
    assert len(cache) == 1
    (plan0, _tok0) = next(iter(cache.values()))

    # refine one root: new leaves, new arrays — the old plan is meaningless
    pipe = AMRPipeline(balancer=SFCBalancer(), registry=reg)
    root0 = min(b.bid for b in forest.all_blocks())
    forest, _ = pipe.run_cycle(forest, Comm(1), lambda r, blocks: {root0: 1})
    fill_ghost_layers(forest, reg, fields=("pdf",), plan_cache=cache)
    (plan1, _tok1) = next(iter(cache.values()))
    assert plan1 is not plan0, "stale plan replayed for a mutated forest"
    # and the rebuilt plan actually produced cross-level ghost fills
    ref = {b.bid: np.array(b.data["pdf"]) for b in forest.all_blocks()}
    fill_ghost_layers(forest, reg, fields=("pdf",))  # cacheless reference
    for b in forest.all_blocks():
        np.testing.assert_array_equal(b.data["pdf"], ref[b.bid])
