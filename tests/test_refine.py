"""Distributed marking + 2:1 balance (paper §2.2)."""

from repro.core import Comm, make_uniform_forest
from repro.core.blockid import children_ids
from repro.core.refine import mark_and_balance_targets


def test_no_marks_early_exit(geom):
    forest = make_uniform_forest(geom, 4, level=1)
    comm = Comm(4)
    changed, _ = mark_and_balance_targets(forest, comm, None)
    assert not changed
    assert all(b.target_level == b.level for b in forest.all_blocks())
    # early exit costs exactly one reduction (plus the ghost exchange)
    assert comm.stats.allreduce_calls == 1


def test_refine_marks_are_always_accepted(geom):
    forest = make_uniform_forest(geom, 4, level=1)
    comm = Comm(4)
    victim = min(b.bid for b in forest.all_blocks())

    changed, _ = mark_and_balance_targets(
        forest, comm, lambda r, blocks: {victim: geom.level_of(victim) + 1} if victim in blocks else {}
    )
    assert changed
    by_id = {b.bid: b for b in forest.all_blocks()}
    assert by_id[victim].target_level == by_id[victim].level + 1


def test_forced_splits_maintain_two_one(geom):
    """Refining one block twice (two cycles) must force neighbors to split."""
    forest = make_uniform_forest(geom, 2, level=0)
    comm = Comm(2)
    # refine one root block; neighbors stay -> levels 0/1 everywhere: fine
    target = min(b.bid for b in forest.all_blocks())
    from repro.core import AMRPipeline, BlockDataRegistry, SFCBalancer

    pipe = AMRPipeline(balancer=SFCBalancer(), registry=BlockDataRegistry.trivial())
    forest, _ = pipe.run_cycle(
        forest, comm, lambda r, blocks: {target: 1} if target in blocks else {}
    )
    forest.check_all()
    # now refine one of the new level-1 blocks -> its level-0 neighbors
    # violate 2:1 and must be forced to split
    lvl1 = [b.bid for b in forest.all_blocks() if b.level == 1]
    inner = min(lvl1)
    forest, _ = pipe.run_cycle(
        forest, comm, lambda r, blocks: {inner: 2} if inner in blocks else {}
    )
    forest.check_all()  # includes 2:1 check
    assert max(b.level for b in forest.all_blocks()) == 2


def test_coarsening_requires_all_siblings(geom):
    forest = make_uniform_forest(geom, 2, level=1)
    comm = Comm(2)
    # mark only 7 of 8 siblings of one parent for coarsening -> no merge
    root = geom.root_id(0)
    sibs = children_ids(root)
    marks = {bid: 0 for bid in sibs[:7]}
    changed, _ = mark_and_balance_targets(
        forest, comm, lambda r, blocks: {b: t for b, t in marks.items() if b in blocks}
    )
    assert not changed  # nothing was accepted
    by_id = {b.bid: b for b in forest.all_blocks()}
    for bid in sibs:
        assert by_id[bid].target_level == 1


def test_coarsening_accepted_when_group_complete(geom):
    forest = make_uniform_forest(geom, 2, level=1)
    comm = Comm(2)
    root = geom.root_id(0)
    sibs = children_ids(root)
    marks = {bid: 0 for bid in sibs}
    changed, _ = mark_and_balance_targets(
        forest, comm, lambda r, blocks: {b: t for b, t in marks.items() if b in blocks}
    )
    assert changed
    by_id = {b.bid: b for b in forest.all_blocks()}
    for bid in sibs:
        assert by_id[bid].target_level == 0


def test_rounds_bounded_by_levels(geom):
    """§2.2: the iteration count depends on the depth, not the rank count."""
    rounds = {}
    for nranks in (2, 8):
        forest = make_uniform_forest(geom, nranks, level=1)
        comm = Comm(nranks)
        victim = min(b.bid for b in forest.all_blocks())
        mark_and_balance_targets(
            forest, comm, lambda r, blocks: {victim: 2} if victim in blocks else {}
        )
        rounds[nranks] = comm.stats.exchange_rounds  # p2p supersteps only
    # neighbor-exchange rounds must not grow with rank count
    assert rounds[8] <= rounds[2] + 2
