"""Proxy data structure (§2.3): topology, bilateral links, migration (§2.4)."""

import random

from repro.core import Comm, make_uniform_forest
from repro.core.blockid import children_ids, parent_id
from repro.core.forest import build_adjacency
from repro.core.proxy import build_proxy, migrate_proxy_blocks
from repro.core.refine import mark_and_balance_targets

from conftest import make_random_marks


def _build(geom, nranks, seed):
    forest = make_uniform_forest(geom, nranks, level=1)
    comm = Comm(nranks)
    changed, ghost = mark_and_balance_targets(
        forest, comm, make_random_marks(seed)
    )
    proxy = build_proxy(forest, comm, ghost)
    return forest, proxy, comm


def test_proxy_topology_matches_adjacency_oracle(geom):
    for seed in (0, 1, 2):
        forest, proxy, _ = _build(geom, 4, seed)
        # the proxy must be a valid forest: cover + 2:1 + exact adjacency
        proxy.check_all()


def test_bilateral_links(geom):
    forest, proxy, _ = _build(geom, 4, 3)
    proxy_by_id = {b.bid: b for b in proxy.all_blocks()}
    for blk in forest.all_blocks():
        t = blk.target_level
        if t == blk.level + 1:
            assert len(blk.target_ranks) == 8
            for o, ch in enumerate(children_ids(blk.bid)):
                pb = proxy_by_id[ch]
                assert pb.owner == blk.target_ranks[o]
                assert pb.source_ranks == [blk.owner]
        elif t == blk.level:
            pb = proxy_by_id[blk.bid]
            assert pb.owner == blk.target_ranks[0]
            assert pb.source_ranks == [blk.owner]
        else:
            pb = proxy_by_id[parent_id(blk.bid)]
            assert pb.owner == blk.target_ranks[0]
            assert len(pb.source_ranks) == 8


def test_proxy_migration_preserves_links_and_adjacency(geom):
    forest, proxy, comm = _build(geom, 4, 4)
    rng = random.Random(0)
    # random assignment of every proxy block
    assignments = []
    for r in range(4):
        assignments.append({bid: rng.randrange(4) for bid in proxy.local_blocks(r)})
    n_before = proxy.num_blocks()
    moved = migrate_proxy_blocks(proxy, forest, comm, assignments)
    assert proxy.num_blocks() == n_before  # conservation
    proxy.check_all()  # owners in neighbor maps must be fresh
    # bilateral links: actual target_ranks point at the proxy owners
    proxy_by_id = {b.bid: b for b in proxy.all_blocks()}
    for blk in forest.all_blocks():
        if blk.target_level == blk.level + 1:
            for o, ch in enumerate(children_ids(blk.bid)):
                assert blk.target_ranks[o] == proxy_by_id[ch].owner
        elif blk.target_level == blk.level:
            assert blk.target_ranks[0] == proxy_by_id[blk.bid].owner
        else:
            assert blk.target_ranks[0] == proxy_by_id[parent_id(blk.bid)].owner
    # a second migration round still works (stale-owner forwarding)
    assignments2 = []
    for r in range(4):
        assignments2.append({bid: rng.randrange(4) for bid in proxy.local_blocks(r)})
    migrate_proxy_blocks(proxy, forest, comm, assignments2)
    proxy.check_all()


def test_proxy_creation_is_neighbor_local(geom):
    """§2.3: proxy creation must not use collectives at all."""
    forest = make_uniform_forest(geom, 4, level=1)
    comm = Comm(4)
    changed, ghost = mark_and_balance_targets(forest, comm, make_random_marks(7))
    before = comm.stats.allreduce_calls + comm.stats.allgather_calls
    build_proxy(forest, comm, ghost)
    after = comm.stats.allreduce_calls + comm.stats.allgather_calls
    assert before == after
