"""Pallas kernel sweeps: shapes x dtypes x lattices x collision models vs the
pure-jnp oracle (interpret mode on CPU)."""

import numpy as np
import jax.numpy as jnp
import pytest

from repro.kernels.lbm_collide.ops import fused_stream_collide
from repro.kernels.lbm_collide.ref import CT_FLUID, CT_LID, CT_WALL
from repro.lbm.lattice import D3Q19, D3Q27


def _random_state(rng, B, lattice, shape, dtype):
    w = np.asarray(lattice.w, dtype=dtype)
    f = w[None, :, None, None, None] * (
        1.0 + 0.05 * rng.standard_normal((B, lattice.Q, *shape))
    ).astype(dtype)
    mask = np.zeros((B, *shape), np.int32)
    mask[:, 0] = CT_WALL
    mask[:, -1] = CT_LID
    mask[:, :, 0] = CT_WALL
    return jnp.asarray(f), jnp.asarray(mask)


@pytest.mark.parametrize("lattice", [D3Q19, D3Q27], ids=["d3q19", "d3q27"])
@pytest.mark.parametrize("collision", ["bgk", "trt"])
@pytest.mark.parametrize(
    "shape", [(4, 4, 4), (8, 6, 10), (5, 7, 3)], ids=["cube", "rect", "odd"]
)
def test_pallas_matches_ref(lattice, collision, shape):
    rng = np.random.default_rng(42)
    f, mask = _random_state(rng, 2, lattice, shape, np.float32)
    kw = dict(
        omega=1.55,
        lattice=lattice,
        collision=collision,
        u_wall=(0.04, 0.01, 0.0),
    )
    out_p = fused_stream_collide(f, mask, backend="pallas", **kw)
    out_r = fused_stream_collide(f, mask, backend="ref", **kw)
    np.testing.assert_allclose(np.asarray(out_p), np.asarray(out_r), rtol=3e-5, atol=3e-6)


@pytest.mark.parametrize("dtype", [np.float32, np.float64], ids=["f32", "f64"])
def test_pallas_dtype_sweep(dtype):
    import jax

    with jax.experimental.enable_x64(True) if dtype == np.float64 else _null():
        rng = np.random.default_rng(7)
        f, mask = _random_state(rng, 1, D3Q19, (6, 6, 6), dtype)
        kw = dict(omega=1.2, lattice=D3Q19, collision="bgk")
        out_p = fused_stream_collide(f, mask, backend="pallas", **kw)
        out_r = fused_stream_collide(f, mask, backend="ref", **kw)
        tol = 1e-12 if dtype == np.float64 else 3e-6
        np.testing.assert_allclose(np.asarray(out_p), np.asarray(out_r), rtol=tol * 10, atol=tol)


class _null:
    def __enter__(self):
        return self

    def __exit__(self, *a):
        return False


@pytest.mark.parametrize("omega", [0.6, 1.0, 1.9])
def test_pallas_omega_sweep(omega):
    rng = np.random.default_rng(0)
    f, mask = _random_state(rng, 3, D3Q19, (6, 6, 6), np.float32)
    out_p = fused_stream_collide(f, mask, backend="pallas", omega=omega)
    out_r = fused_stream_collide(f, mask, backend="ref", omega=omega)
    np.testing.assert_allclose(np.asarray(out_p), np.asarray(out_r), rtol=3e-5, atol=3e-6)


def test_wall_cells_frozen_and_lid_injects_momentum():
    rng = np.random.default_rng(1)
    f, mask = _random_state(rng, 1, D3Q19, (8, 8, 8), np.float32)
    out = fused_stream_collide(
        f, mask, backend="pallas", omega=1.5, u_wall=(0.1, 0.0, 0.0)
    )
    m = np.asarray(mask[0])
    fo, fi = np.asarray(out[0]), np.asarray(f[0])
    # wall/lid cells keep their PDFs
    np.testing.assert_allclose(fo[:, m != CT_FLUID], fi[:, m != CT_FLUID])
    # fluid next to the moving lid gains x-momentum
    c = np.asarray(D3Q19.c, np.float32)
    mom_x = np.einsum("qxyz,q->xyz", fo, c[:, 0])
    assert mom_x[-2][m[-2] == CT_FLUID].mean() > 1e-5


@pytest.mark.parametrize(
    "backend,want_interpret,want_donate",
    [("cpu", True, False), ("gpu", False, True), ("tpu", False, True)],
)
def test_build_time_flag_resolution_per_backend(
    monkeypatch, backend, want_interpret, want_donate
):
    """Pin the build-time resolution of the kernel-dispatch flags.

    ``interpret=None`` must resolve to "interpret iff CPU" (the old hardwired
    ``interpret=True`` silently ran the Pallas interpreter on accelerators),
    and ``donate=None`` to "donate iff not CPU" (XLA:CPU codegen under
    aliasing drifts by one ulp, breaking bitwise conformance). Explicit bools
    always win over the backend probe.
    """
    import jax

    from repro.kernels.lbm_collide.lbm_collide import (
        resolve_donate,
        resolve_interpret,
    )

    monkeypatch.setattr(jax, "default_backend", lambda: backend)
    assert resolve_interpret(None) is want_interpret
    assert resolve_donate(None) is want_donate
    # explicit overrides ignore the backend entirely
    for flag in (True, False):
        assert resolve_interpret(flag) is flag
        assert resolve_donate(flag) is flag


def test_flag_resolution_happens_at_build_time(monkeypatch):
    """The backend probe runs when the program is built, not when it runs.

    Build a fused superstep under a monkeypatched backend, then restore it:
    the program must keep the resolution it was built with (here: the probe
    is consulted during ``make_fused_superstep``, so patching afterwards has
    no effect on the built program's kernels).
    """
    from repro.kernels.lbm_collide import ops

    calls = []
    real = ops.resolve_interpret
    monkeypatch.setattr(
        ops, "resolve_interpret", lambda v=None: calls.append(v) or real(v)
    )
    ops.make_stream_collide(omega=1.6, backend="pallas")
    assert calls, "make_stream_collide must resolve interpret at build time"
