"""Conformance suite for the sharded and fused (device-resident) data planes.

Pins the invariants that make ``stepping_mode="sharded"`` a faithful
distributed execution of the single-rank reference, ``stepping_mode="fused"``
a faithful *device-resident* one, and ``stepping_mode="fused_sharded"`` both
at once — same fields to 1e-10 across an AMR event, mass conserved, zero
host<->device transfers per substep in steady state (asserted on the
residency layers' counters), and cross-rank traffic that stays p2p-only with
byte-exact parity between the host patches and the device-built messages:

* **conformance** — the full AMR+LBM cycle at 1/4/13 simulated ranks
  reproduces the single-rank restack reference macroscopic fields
  (density/velocity) within 1e-10 after 8 coarse steps spanning at least one
  AMR event (in practice the match is bitwise: identical kernels, identical
  exchange arithmetic, only ownership differs);
* **communication shape** — ghost exchange puts only point-to-point traffic
  on the fabric, every communicating rank pair is a process-graph neighbor
  pair, and stepping triggers no collectives at all;
* **storage shape** — each rank's arenas hold exactly its own blocks
  (O(local blocks) bytes), re-established after every AMR event.
"""

import numpy as np
import pytest

from repro.lbm import AMRLBM, LidDrivenCavityConfig
from repro.lbm.criteria import macroscopic
from repro.lbm.halo import RankHaloPlan

COARSE_STEPS = 8
AMR_INTERVAL = 4  # -> AMR cycles after steps 4 and 8: the run spans >= 1 event

BASE = dict(
    root_grid=(2, 2, 2),
    cells_per_block=(8, 8, 8),
    omega=1.5,
    u_lid=(0.08, 0.0, 0.0),
    max_level=1,
    refine_upper=0.03,
    refine_lower=0.004,
    kernel_backend="ref",  # interpret-mode pallas is identical but far slower
)


def _run(mode: str, nranks: int) -> AMRLBM:
    sim = AMRLBM(LidDrivenCavityConfig(nranks=nranks, stepping_mode=mode, **BASE))
    sim.run(COARSE_STEPS, amr_interval=AMR_INTERVAL)
    return sim


@pytest.fixture(scope="module")
def reference() -> AMRLBM:
    """Single-rank restack run: the seed data path, one global arena."""
    return _run("restack", 1)


@pytest.mark.parametrize(
    "nranks", [1, 4, pytest.param(13, marks=pytest.mark.slow)]
)
def test_sharded_matches_single_rank_reference(reference, nranks):
    sim = _run("sharded", nranks)
    assert sim.amr_cycles >= 1, "the run must span at least one AMR event"
    assert len(sim.forest.levels_in_use()) > 1
    # ownership-independent topology + fields: same leaves, same physics
    _assert_macroscopic_match(sim, reference)
    assert abs(sim.total_mass() - reference.total_mass()) < 1e-6


def test_sharded_stepping_uses_only_p2p_next_neighbor_traffic():
    sim = AMRLBM(LidDrivenCavityConfig(nranks=4, stepping_mode="sharded", **BASE))
    sim.advance(2)
    sim.adapt()  # develop two levels so coarse/fine exchange paths run too
    assert len(sim.forest.levels_in_use()) > 1

    before = sim.comm.stats.summary()
    sim.advance(2)
    after = sim.comm.stats.summary()
    # stepping is pure data plane: messages + delivery rounds, no collectives
    assert after["allreduce_calls"] == before["allreduce_calls"]
    assert after["allgather_calls"] == before["allgather_calls"]
    assert after["collective_bytes_per_rank"] == before["collective_bytes_per_rank"]
    assert after["p2p_bytes"] > before["p2p_bytes"]
    assert after["exchange_rounds"] > before["exchange_rounds"]
    # the driver attributes the same traffic to the "halo" data-plane stage
    halo = sim.data_stats["halo"]
    assert halo.p2p_bytes > 0 and halo.exchange_rounds > 0
    assert halo.collective_bytes_per_rank == 0

    # every communicating pair is a process-graph neighbor pair (paper §2:
    # next-neighbor communication only); cache entries are (plan, token)
    plans = [p for p, _tok in sim._halo_plans.values() if isinstance(p, RankHaloPlan)]
    assert plans, "sharded stepping must go through rank halo plans"
    for plan in plans:
        for src, dst in plan.rank_pairs():
            assert src != dst
            assert dst in sim.forest.neighbor_ranks(src), (src, dst)


def _assert_macroscopic_match(sim: AMRLBM, reference: AMRLBM) -> None:
    ref_blocks = {b.bid: b for b in reference.forest.all_blocks()}
    got_blocks = {b.bid: b for b in sim.forest.all_blocks()}
    assert set(ref_blocks) == set(got_blocks)
    for bid, rb in ref_blocks.items():
        gb = got_blocks[bid]
        rho_r, u_r = macroscopic(rb.data["pdf"], sim.spec.lattice)
        rho_g, u_g = macroscopic(gb.data["pdf"], sim.spec.lattice)
        g = sim.spec.ghost
        sl = (slice(g, -g),) * 3
        np.testing.assert_allclose(
            np.asarray(rho_g)[sl], np.asarray(rho_r)[sl], rtol=0, atol=1e-10
        )
        np.testing.assert_allclose(
            np.asarray(u_g)[(Ellipsis, *sl)],
            np.asarray(u_r)[(Ellipsis, *sl)],
            rtol=0,
            atol=1e-10,
        )


def test_fused_matches_restack_reference_across_amr(reference):
    """The device-resident fused superstep is a faithful execution of the
    substep cycle: identical macroscopic fields (1e-10; in practice bitwise
    — the compiled exchange mirrors the host resampling arithmetic exactly)
    after 8 coarse steps spanning an AMR event, and mass is conserved."""
    sim = _run("fused", 1)
    assert sim.amr_cycles >= 1, "the run must span at least one AMR event"
    assert len(sim.forest.levels_in_use()) > 1
    _assert_macroscopic_match(sim, reference)
    assert abs(sim.total_mass() - reference.total_mass()) < 1e-6
    # mass conservation against the initial condition (equilibrium at rho=1:
    # one unit per fluid root-cell volume)
    fresh = AMRLBM(LidDrivenCavityConfig(nranks=1, stepping_mode="fused", **BASE))
    assert abs(sim.total_mass() - fresh.total_mass()) / fresh.total_mass() < 1e-3


def test_fused_steady_state_performs_zero_host_transfers():
    """Between AMR events the fused loop is fully device-resident: after the
    one-time upload, further coarse steps perform no host<->device transfer
    in either direction (asserted via the residency layer's counters)."""
    sim = AMRLBM(LidDrivenCavityConfig(nranks=1, stepping_mode="fused", **BASE))
    sim.advance(1)  # builds the program + uploads pdf/mask
    res = sim.arena.device()
    before = (res.h2d_transfers, res.d2h_transfers)
    assert res.h2d_transfers > 0  # the initial upload happened and was counted
    sim.advance(3)  # 3 coarse steps = 3 * 2^lmax substeps, all on device
    assert (res.h2d_transfers, res.d2h_transfers) == before
    # in-program exchanges are attributed to the "fused" data-plane stage
    fused = sim.data_stats["fused"]
    lmax = max(sim.forest.levels_in_use())
    assert fused.exchange_rounds == 4 * 2**lmax
    assert fused.seconds > 0.0
    # diagnostics rematerialize host views: exactly the flush transfers
    sim.total_mass()
    assert res.d2h_transfers > before[1]
    d2h = res.d2h_transfers
    sim.total_mass()  # already synced: no second download
    assert res.d2h_transfers == d2h


def test_fused_checkpoint_after_materialize_matches_reference(tmp_path):
    """External host-data consumers (checkpointing) see the current state
    after materialize_host(); an arena adopt with un-flushed device results
    fails loudly instead of silently losing steps."""
    from repro.core.checkpoint import load_checkpoint, save_checkpoint

    sim = AMRLBM(LidDrivenCavityConfig(nranks=1, stepping_mode="fused", **BASE))
    sim.run(COARSE_STEPS, amr_interval=AMR_INTERVAL)
    sim.advance(1)  # end on a plain advance: device is newer than host now
    sim.materialize_host()
    save_checkpoint(sim.forest, sim.registry, tmp_path / "ckpt")
    restored = load_checkpoint(tmp_path / "ckpt", sim.registry)
    ref2 = _run("restack", 1)
    ref2.advance(1)
    ref_blocks = {b.bid: b for b in ref2.forest.all_blocks()}
    got_blocks = {b.bid: b for b in restored.all_blocks()}
    assert set(ref_blocks) == set(got_blocks)
    g = sim.spec.ghost
    sl = (Ellipsis,) + (slice(g, -g),) * 3
    for bid, rb in ref_blocks.items():
        np.testing.assert_allclose(
            got_blocks[bid].data["pdf"][sl], rb.data["pdf"][sl], rtol=0, atol=1e-10
        )


def test_fused_adopt_without_flush_fails_loudly():
    sim = AMRLBM(LidDrivenCavityConfig(nranks=1, stepping_mode="fused", **BASE))
    sim.advance(1)  # device-newer pdf state pending
    with pytest.raises(AssertionError, match="flush"):
        sim.arena.adopt(sim.forest)
    sim.materialize_host()
    sim.arena.adopt(sim.forest)  # flushed: fine


def test_fused_transfers_only_on_amr_events():
    sim = AMRLBM(LidDrivenCavityConfig(nranks=1, stepping_mode="fused", **BASE))
    sim.advance(2)
    sim.adapt()
    assert len(sim.forest.levels_in_use()) > 1
    sim.advance(1)  # re-upload for the new topology
    res = sim.arena.device()
    before = (res.h2d_transfers, res.d2h_transfers)
    sim.advance(2)
    assert (res.h2d_transfers, res.d2h_transfers) == before


@pytest.mark.parametrize(
    "nranks", [1, 4, pytest.param(13, marks=pytest.mark.slow)]
)
def test_fused_sharded_matches_single_rank_reference(reference, nranks):
    """The per-rank device-resident data plane is a faithful distributed
    execution: fused_sharded at 1/4/13 ranks reproduces the single-rank
    restack reference (1e-10; in practice bitwise — identical kernels,
    identical exchange arithmetic on device, only ownership differs) after
    8 coarse steps spanning an AMR event, and mass is conserved."""
    sim = _run("fused_sharded", nranks)
    assert sim.amr_cycles >= 1, "the run must span at least one AMR event"
    assert len(sim.forest.levels_in_use()) > 1
    _assert_macroscopic_match(sim, reference)
    assert abs(sim.total_mass() - reference.total_mass()) < 1e-6


def test_fused_sharded_steady_state_performs_zero_host_transfers():
    """Between AMR events every rank's substep loop is fully device-resident:
    after the one-time upload, further coarse steps perform no host<->device
    transfer in either direction on ANY rank (asserted via each rank's
    residency counters) — the only per-substep host involvement is routing
    device-built message buffers through the Comm fabric."""
    sim = AMRLBM(
        LidDrivenCavityConfig(nranks=4, stepping_mode="fused_sharded", **BASE)
    )
    sim.advance(2)
    sim.adapt()
    assert len(sim.forest.levels_in_use()) > 1
    sim.advance(1)  # re-upload for the new topology
    res = [a.device() for a in sim.arenas.per_rank if a.levels()]
    before = [(r.h2d_transfers, r.d2h_transfers) for r in res]
    assert any(r.h2d_transfers > 0 for r in res)  # uploads happened, counted
    sim.advance(2)
    assert [(r.h2d_transfers, r.d2h_transfers) for r in res] == before
    # the coarse-step loop is attributed to the "fused" data-plane stage,
    # including the cross-rank device-message traffic it put on the fabric
    fused = sim.data_stats["fused"]
    assert fused.seconds > 0.0
    assert fused.p2p_bytes > 0 and fused.collective_bytes_per_rank == 0
    # diagnostics rematerialize host views: flush transfers only
    d2h0 = sum(r.d2h_transfers for r in res)
    sim.total_mass()
    assert sum(r.d2h_transfers for r in res) > d2h0
    d2h1 = sum(r.d2h_transfers for r in res)
    sim.total_mass()  # already synced: no second download
    assert sum(r.d2h_transfers for r in res) == d2h1


def test_fused_sharded_stepping_uses_only_p2p_next_neighbor_traffic():
    """The compiled rank-halo plan preserves the communication shape of the
    host-sharded exchange: p2p only, no collectives, every communicating
    pair a process-graph neighbor pair, and byte-for-byte the same traffic
    (sender-side resampling produces identically-sized messages)."""
    from repro.lbm.halo import compile_rank_halo_plan

    sim = AMRLBM(
        LidDrivenCavityConfig(nranks=4, stepping_mode="fused_sharded", **BASE)
    )
    sim.advance(2)
    sim.adapt()
    assert len(sim.forest.levels_in_use()) > 1
    before = sim.comm.stats.summary()
    sim.advance(2)
    after = sim.comm.stats.summary()
    assert after["allreduce_calls"] == before["allreduce_calls"]
    assert after["allgather_calls"] == before["allgather_calls"]
    assert after["collective_bytes_per_rank"] == before["collective_bytes_per_rank"]
    assert after["p2p_bytes"] > before["p2p_bytes"]
    assert after["exchange_rounds"] > before["exchange_rounds"]

    # every communicating pair is a process-graph neighbor pair, and the
    # per-pair message bytes equal the host plan's patch bytes exactly
    arenas = sim.arenas
    rank_slots = {
        r: {l: arenas.per_rank[r].slots(l) for l in arenas.per_rank[r].levels()}
        for r in range(4)
    }
    from repro.lbm.halo import build_rank_halo_plan

    plan = compile_rank_halo_plan(sim.forest, sim.fields, rank_slots)
    host_plan = build_rank_halo_plan(sim.forest, sim.fields)
    assert plan.rank_pairs() == host_plan.rank_pairs()
    assert plan.cross_rank_bytes() == host_plan.cross_rank_bytes()
    for m in plan.messages:
        assert m.src_rank != m.dst_rank
        assert m.dst_rank in sim.forest.neighbor_ranks(m.src_rank)
        assert m.nbytes == host_plan.nbytes[(m.src_rank, m.dst_rank)]

    # the static halo-protocol verifier proves the full contract on the same
    # plan: pairwise-matched messages, byte symmetry, in-bounds indices,
    # interior-only gathers, exact ghost-ring coverage
    from repro.analysis import verify_compiled_rank_plan

    assert verify_compiled_rank_plan(sim.forest, sim.fields, plan, rank_slots) == []


def test_rank_arenas_partition_data_by_owner_across_amr():
    sim = AMRLBM(LidDrivenCavityConfig(nranks=4, stepping_mode="sharded", **BASE))
    sim.arenas.check_consistent(sim.forest)
    sim.advance(2)
    sim.adapt()
    sim.advance(1)
    # after migration/refine/coarsen the per-rank arenas were rebuilt: every
    # block's storage lives in (and only in) its owner's arena
    sim.arenas.check_consistent(sim.forest)
    for r in range(4):
        arena = sim.arenas.per_rank[r]
        owned = {b.bid for b in sim.forest.local_blocks(r).values()}
        indexed = {bid for lvl in arena.levels() for bid in arena.slots(lvl)}
        assert indexed == owned
    held = sim.arenas.held_bytes_per_rank()
    per_block = sum(
        int(np.prod(spec.block_shape(sim.fields.cells))) * np.dtype(spec.dtype).itemsize
        for spec in sim.fields.fields.values()
    )
    for r in range(4):
        assert held[r] == len(sim.forest.local_blocks(r)) * per_block


# -- pallas-backend legs -------------------------------------------------------
# The pallas kernel computes moments with unrolled per-direction arithmetic
# (the ref kernel uses einsum contractions), so pallas runs are NOT bitwise
# against ref runs — the cross-backend tolerance lives in
# tests/test_kernels_lbm.py. Within the backend the conformance contract is
# the same as for ref: every fused mode matches a pallas *restack* reference
# at 1e-10 (in practice bitwise) across an AMR event. Shorter schedule than
# the ref legs — interpret mode is slow.

PALLAS_STEPS = 4
PALLAS_INTERVAL = 2  # AMR cycles after steps 2 and 4: spans >= 1 event


def _run_pallas(mode: str, nranks: int, **over) -> AMRLBM:
    cfg = {**BASE, "kernel_backend": "pallas", **over}
    sim = AMRLBM(LidDrivenCavityConfig(nranks=nranks, stepping_mode=mode, **cfg))
    sim.run(PALLAS_STEPS, amr_interval=PALLAS_INTERVAL)
    return sim


@pytest.fixture(scope="module")
def pallas_reference() -> AMRLBM:
    """Single-rank restack run on the pallas (interpret-on-CPU) kernel."""
    return _run_pallas("restack", 1)


@pytest.mark.parametrize(
    "mode,nranks",
    [("fused", 1), ("fused_sharded", 1), ("fused_sharded", 4)],
)
def test_pallas_fused_modes_match_pallas_restack_reference(
    pallas_reference, mode, nranks
):
    """The halo-in-tile Pallas superstep (ghost ring scattered into the VMEM
    tile before the stencil reads) is a faithful execution of the substep
    cycle on its own backend, solo and sharded, across an AMR event."""
    sim = _run_pallas(mode, nranks)
    assert sim.amr_cycles >= 1, "the run must span at least one AMR event"
    assert len(sim.forest.levels_in_use()) > 1
    _assert_macroscopic_match(sim, pallas_reference)
    assert abs(sim.total_mass() - pallas_reference.total_mass()) < 1e-6


def test_pallas_fused_steady_state_performs_zero_host_transfers():
    """Halo-in-tile stepping keeps the zero-host-transfer contract: the
    ghost values are gathered and consumed inside the compiled superstep,
    never materialized through the host."""
    cfg = {**BASE, "kernel_backend": "pallas"}
    sim = AMRLBM(LidDrivenCavityConfig(nranks=1, stepping_mode="fused", **cfg))
    sim.advance(1)
    res = sim.arena.device()
    before = (res.h2d_transfers, res.d2h_transfers)
    sim.advance(3)
    assert (res.h2d_transfers, res.d2h_transfers) == before


def test_pallas_donated_superstep_consumes_buffers_and_survives_amr():
    """Explicit ``donate_pdfs=True``: the superstep ping-pongs the pdf
    buffers in place (inputs are deleted after each call), AMR events rebuild
    the programs without ever touching a stale donated buffer, and the
    physics stays within float32 round-off of the undonated twin (donation
    perturbs XLA:CPU codegen by ~1 ulp per step, which is why it is not the
    CPU default)."""
    cfg = {**BASE, "kernel_backend": "pallas"}
    don = AMRLBM(
        LidDrivenCavityConfig(
            nranks=1, stepping_mode="fused", donate_pdfs=True, **cfg
        )
    )
    ref = AMRLBM(
        LidDrivenCavityConfig(
            nranks=1, stepping_mode="fused", donate_pdfs=False, **cfg
        )
    )

    don.advance(1)
    lvl = min(don.forest.levels_in_use())
    held = don.arena.device().fetch(lvl, "pdf")
    don.advance(1)
    assert held.is_deleted(), "donated superstep must consume its inputs"
    ref.advance(2)

    # cross an AMR event: programs rebuild, residency re-uploads — a stale
    # donated buffer anywhere in the engine would raise on next use
    don.adapt()
    ref.adapt()
    assert len(don.forest.levels_in_use()) > 1
    don.advance(PALLAS_INTERVAL)
    ref.advance(PALLAS_INTERVAL)
    don.adapt()
    ref.adapt()

    # undonated twin: same program minus aliasing; only codegen round-off
    assert don.amr_cycles >= 1
    ref_blocks = {b.bid: b for b in ref.forest.all_blocks()}
    got_blocks = {b.bid: b for b in don.forest.all_blocks()}
    assert set(ref_blocks) == set(got_blocks)
    don.materialize_host()
    ref.materialize_host()
    g = don.spec.ghost
    sl = (Ellipsis,) + (slice(g, -g),) * 3
    for bid, rb in ref_blocks.items():
        np.testing.assert_allclose(
            got_blocks[bid].data["pdf"][sl],
            rb.data["pdf"][sl],
            rtol=0,
            atol=1e-6,
        )
    assert abs(don.total_mass() - ref.total_mass()) < 1e-6


# -- device-matrix legs --------------------------------------------------------
# device_sharded places each rank's padded block stack on its own XLA device
# (shard_map over a 1-D mesh, in-program ppermute for halo messages). Host
# devices come from XLA_FLAGS=--xla_force_host_platform_device_count=N, which
# must be set before the first jax import — the CI device-matrix job does
# exactly that; under the default single-device environment the wider legs
# skip and only the 1-device leg runs.

import jax  # noqa: E402


def _require_devices(n: int) -> None:
    if jax.device_count() < n:
        pytest.skip(
            f"needs {n} XLA devices (run under "
            f"XLA_FLAGS=--xla_force_host_platform_device_count={n})"
        )


@pytest.mark.parametrize("nranks", [1, 2, 4])
def test_device_sharded_matches_single_rank_reference(reference, nranks):
    """The real multi-device data plane is a faithful distributed execution:
    device_sharded at 1/2/4 devices reproduces the single-rank restack
    reference (1e-10; in practice bitwise — the per-rank switch branches run
    the identical exchange arithmetic, only placement differs) after 8 coarse
    steps spanning an AMR event, and mass is conserved."""
    _require_devices(nranks)
    sim = _run("device_sharded", nranks)
    assert sim.amr_cycles >= 1, "the run must span at least one AMR event"
    assert len(sim.forest.levels_in_use()) > 1
    _assert_macroscopic_match(sim, reference)
    assert abs(sim.total_mass() - reference.total_mass()) < 1e-6


def test_device_sharded_traffic_is_p2p_with_host_plan_byte_parity():
    """ppermute traffic is p2p-only and byte-identical to the host fabric:
    the in-program permutes account exactly the CompiledRankMessage nbytes
    the fused_sharded mode puts on the simulated Comm for the same
    trajectory, every communicating pair is a process-graph neighbor pair,
    and the round schedule is a partial-permutation cover of the messages
    (zero-padding counted separately as wire overhead, never as traffic)."""
    _require_devices(4)
    from repro.lbm.halo import (
        build_rank_halo_plan,
        compile_rank_halo_plan,
        schedule_ppermute_rounds,
    )

    def traj(mode):
        sim = AMRLBM(
            LidDrivenCavityConfig(nranks=4, stepping_mode=mode, **BASE)
        )
        sim.advance(2)
        sim.adapt()
        assert len(sim.forest.levels_in_use()) > 1
        before = sim.comm.stats.summary()
        sim.advance(2)
        after = sim.comm.stats.summary()
        keys = (
            "p2p_bytes",
            "p2p_messages",
            "allreduce_calls",
            "allgather_calls",
            "collective_bytes_per_rank",
        )
        return sim, {k: after[k] - before[k] for k in keys}

    dev, ddelta = traj("device_sharded")
    _host, hdelta = traj("fused_sharded")
    assert ddelta["allreduce_calls"] == 0
    assert ddelta["allgather_calls"] == 0
    assert ddelta["collective_bytes_per_rank"] == 0
    assert ddelta["p2p_bytes"] > 0
    # byte parity message-for-message with the simulated fabric's accounting
    assert ddelta["p2p_bytes"] == hdelta["p2p_bytes"]
    assert ddelta["p2p_messages"] == hdelta["p2p_messages"]

    # the logical bytes are the host-sharded plan's patch bytes exactly
    arenas = dev.arenas
    rank_slots = {
        r: {l: arenas.per_rank[r].slots(l) for l in arenas.per_rank[r].levels()}
        for r in range(4)
    }
    plan = compile_rank_halo_plan(dev.forest, dev.fields, rank_slots)
    host_plan = build_rank_halo_plan(dev.forest, dev.fields)
    assert plan.cross_rank_bytes() == host_plan.cross_rank_bytes()
    for m in plan.messages:
        assert m.src_rank != m.dst_rank
        assert m.dst_rank in dev.forest.neighbor_ranks(m.src_rank)
        assert m.nbytes == host_plan.nbytes[(m.src_rank, m.dst_rank)]

    # the schedule covers every message once, each round a partial permutation
    rounds = schedule_ppermute_rounds(plan.messages)
    covered = [m for rnd in rounds for m in rnd.messages]
    assert sorted(m.key for m in covered) == sorted(m.key for m in plan.messages)
    for rnd in rounds:
        srcs = [s for s, _ in rnd.perm]
        dsts = [d for _, d in rnd.perm]
        assert len(set(srcs)) == len(srcs), rnd.perm
        assert len(set(dsts)) == len(dsts), rnd.perm
        assert rnd.num_cells == max(m.num_cells for m in rnd.messages)
        assert rnd.pad_cells() == sum(
            rnd.num_cells - m.num_cells for m in rnd.messages
        )
    assert dev.comm.ppermute_rounds > 0
    assert dev.comm.ppermute_pad_bytes >= 0


def test_device_sharded_resizes_across_device_counts():
    """Elastic resize works across device counts: a device_sharded run
    resized 2 -> 4 devices keeps its DeviceComm fabric and continues with
    physics matching the restack reference."""
    _require_devices(4)
    from repro.serving.elastic import resize_ranks

    sim = AMRLBM(
        LidDrivenCavityConfig(nranks=2, stepping_mode="device_sharded", **BASE)
    )
    for i in range(AMR_INTERVAL):
        sim.advance(1)
    sim.adapt()
    report = resize_ranks(sim, 4)
    assert report.new_nranks == 4
    assert hasattr(sim.comm, "ppermute"), "resize must preserve the fabric type"
    for i in range(AMR_INTERVAL):
        sim.advance(1)
    sim.adapt()
    sim.materialize_host()

    ref = _run("restack", 1)
    _assert_macroscopic_match(sim, ref)
