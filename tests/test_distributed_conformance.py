"""Distributed-conformance suite for the rank-sharded data plane.

Pins the invariants that make ``stepping_mode="sharded"`` a faithful
distributed execution of the single-rank reference (ISSUE 2 acceptance):

* **conformance** — the full AMR+LBM cycle at 1/4/13 simulated ranks
  reproduces the single-rank restack reference macroscopic fields
  (density/velocity) within 1e-10 after 8 coarse steps spanning at least one
  AMR event (in practice the match is bitwise: identical kernels, identical
  exchange arithmetic, only ownership differs);
* **communication shape** — ghost exchange puts only point-to-point traffic
  on the fabric, every communicating rank pair is a process-graph neighbor
  pair, and stepping triggers no collectives at all;
* **storage shape** — each rank's arenas hold exactly its own blocks
  (O(local blocks) bytes), re-established after every AMR event.
"""

import numpy as np
import pytest

from repro.lbm import AMRLBM, LidDrivenCavityConfig
from repro.lbm.criteria import macroscopic
from repro.lbm.halo import RankHaloPlan

COARSE_STEPS = 8
AMR_INTERVAL = 4  # -> AMR cycles after steps 4 and 8: the run spans >= 1 event

BASE = dict(
    root_grid=(2, 2, 2),
    cells_per_block=(8, 8, 8),
    omega=1.5,
    u_lid=(0.08, 0.0, 0.0),
    max_level=1,
    refine_upper=0.03,
    refine_lower=0.004,
    kernel_backend="ref",  # interpret-mode pallas is identical but far slower
)


def _run(mode: str, nranks: int) -> AMRLBM:
    sim = AMRLBM(LidDrivenCavityConfig(nranks=nranks, stepping_mode=mode, **BASE))
    sim.run(COARSE_STEPS, amr_interval=AMR_INTERVAL)
    return sim


@pytest.fixture(scope="module")
def reference() -> AMRLBM:
    """Single-rank restack run: the seed data path, one global arena."""
    return _run("restack", 1)


@pytest.mark.parametrize(
    "nranks", [1, 4, pytest.param(13, marks=pytest.mark.slow)]
)
def test_sharded_matches_single_rank_reference(reference, nranks):
    sim = _run("sharded", nranks)
    assert sim.amr_cycles >= 1, "the run must span at least one AMR event"
    assert len(sim.forest.levels_in_use()) > 1

    ref_blocks = {b.bid: b for b in reference.forest.all_blocks()}
    got_blocks = {b.bid: b for b in sim.forest.all_blocks()}
    # ownership-independent topology: the same leaves exist on both runs
    assert set(ref_blocks) == set(got_blocks)

    for bid, rb in ref_blocks.items():
        gb = got_blocks[bid]
        rho_r, u_r = macroscopic(rb.data["pdf"], sim.spec.lattice)
        rho_g, u_g = macroscopic(gb.data["pdf"], sim.spec.lattice)
        g = sim.spec.ghost
        sl = (slice(g, -g),) * 3
        np.testing.assert_allclose(
            np.asarray(rho_g)[sl], np.asarray(rho_r)[sl], rtol=0, atol=1e-10
        )
        np.testing.assert_allclose(
            np.asarray(u_g)[(Ellipsis, *sl)],
            np.asarray(u_r)[(Ellipsis, *sl)],
            rtol=0,
            atol=1e-10,
        )
    assert abs(sim.total_mass() - reference.total_mass()) < 1e-6


def test_sharded_stepping_uses_only_p2p_next_neighbor_traffic():
    sim = AMRLBM(LidDrivenCavityConfig(nranks=4, stepping_mode="sharded", **BASE))
    sim.advance(2)
    sim.adapt()  # develop two levels so coarse/fine exchange paths run too
    assert len(sim.forest.levels_in_use()) > 1

    before = sim.comm.stats.summary()
    sim.advance(2)
    after = sim.comm.stats.summary()
    # stepping is pure data plane: messages + delivery rounds, no collectives
    assert after["allreduce_calls"] == before["allreduce_calls"]
    assert after["allgather_calls"] == before["allgather_calls"]
    assert after["collective_bytes_per_rank"] == before["collective_bytes_per_rank"]
    assert after["p2p_bytes"] > before["p2p_bytes"]
    assert after["exchange_rounds"] > before["exchange_rounds"]
    # the driver attributes the same traffic to the "halo" data-plane stage
    halo = sim.data_stats["halo"]
    assert halo.p2p_bytes > 0 and halo.exchange_rounds > 0
    assert halo.collective_bytes_per_rank == 0

    # every communicating pair is a process-graph neighbor pair (paper §2:
    # next-neighbor communication only)
    plans = [p for p in sim._halo_plans.values() if isinstance(p, RankHaloPlan)]
    assert plans, "sharded stepping must go through rank halo plans"
    for plan in plans:
        for src, dst in plan.rank_pairs():
            assert src != dst
            assert dst in sim.forest.neighbor_ranks(src), (src, dst)


def test_rank_arenas_partition_data_by_owner_across_amr():
    sim = AMRLBM(LidDrivenCavityConfig(nranks=4, stepping_mode="sharded", **BASE))
    sim.arenas.check_consistent(sim.forest)
    sim.advance(2)
    sim.adapt()
    sim.advance(1)
    # after migration/refine/coarsen the per-rank arenas were rebuilt: every
    # block's storage lives in (and only in) its owner's arena
    sim.arenas.check_consistent(sim.forest)
    for r in range(4):
        arena = sim.arenas.per_rank[r]
        owned = {b.bid for b in sim.forest.local_blocks(r).values()}
        indexed = {bid for lvl in arena.levels() for bid in arena.slots(lvl)}
        assert indexed == owned
    held = sim.arenas.held_bytes_per_rank()
    per_block = sum(
        int(np.prod(spec.block_shape(sim.fields.cells))) * np.dtype(spec.dtype).itemsize
        for spec in sim.fields.fields.values()
    )
    for r in range(4):
        assert held[r] == len(sim.forest.local_blocks(r)) * per_block
