"""Tier-1 wrapper around the markdown link-and-path checker.

The CI fast tier runs ``python tools/check_docs.py`` directly; this test
runs the same engine so a module rename that orphans a README /
ARCHITECTURE / CHANGES reference fails an ordinary ``pytest`` run too.
"""

import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parents[1]
sys.path.insert(0, str(ROOT / "tools"))

from check_docs import DOCS, _module_exists, collect_errors  # noqa: E402


def test_committed_docs_have_no_dead_references():
    errors = collect_errors(ROOT)
    assert not errors, "\n".join(errors)


def test_architecture_doc_exists_and_is_checked():
    assert (ROOT / "ARCHITECTURE.md").exists()
    assert "ARCHITECTURE.md" in DOCS


def test_checker_detects_dead_references(tmp_path):
    """The checker must actually catch rot, not just pass vacuously."""
    (tmp_path / "src" / "repro" / "core").mkdir(parents=True)
    (tmp_path / "src" / "repro" / "__init__.py").touch()
    (tmp_path / "src" / "repro" / "core" / "__init__.py").touch()
    (tmp_path / "src" / "repro" / "core" / "fields.py").touch()
    (tmp_path / "README.md").write_text(
        "see [the guide](docs/missing.md) and `src/repro/core/gone.py`;\n"
        "`repro.core.fields.LevelArena` is fine, `repro.core.arenas` is not,\n"
        "and `src/repro/core/fields.py` is fine too.\n"
    )
    errors = collect_errors(tmp_path)
    dead = {e.split("dead ")[1] for e in errors}
    assert "md-link reference: 'docs/missing.md'" in dead
    assert "path reference: 'src/repro/core/gone.py'" in dead
    assert "module reference: 'repro.core.arenas'" in dead
    assert len(errors) == 3, errors


def test_module_resolver_accepts_attribute_tails(tmp_path):
    (tmp_path / "src" / "repro").mkdir(parents=True)
    (tmp_path / "src" / "repro" / "__init__.py").touch()
    (tmp_path / "src" / "repro" / "halo.py").touch()
    assert _module_exists(tmp_path, "repro.halo")
    assert _module_exists(tmp_path, "repro.halo.compile_ghost_plan")
    assert not _module_exists(tmp_path, "repro.missing")
    # a bare-package prefix must not vouch for a missing submodule
    assert not _module_exists(tmp_path, "repro.missing.deep.attr")
