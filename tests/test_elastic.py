"""LM-plane checkpointing + elasticity control logic (now in serving)."""

import warnings

import jax
import numpy as np

from repro.configs import get_config
from repro.models.zoo import DistContext, build_model
from repro.serving.elastic import StragglerMonitor, plan_shrink
from repro.train.checkpoint import load_train_state, save_train_state
from repro.train.optimizer import adamw_init


def test_train_state_roundtrip(tmp_path):
    cfg = get_config("qwen2-0.5b").reduced()
    model = build_model(cfg, DistContext(remat=False))
    params = model.init(jax.random.PRNGKey(0))
    opt = adamw_init(params)
    save_train_state(tmp_path, params=params, opt_state=opt, step=42, meta={"arch": cfg.arch_id})
    p2, o2, meta = load_train_state(tmp_path, params, opt)
    assert meta["step"] == 42 and meta["arch"] == cfg.arch_id
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(p2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert int(o2["step"]) == int(opt["step"])


def test_straggler_monitor_shifts_load_away_from_slow_host():
    mon = StragglerMonitor(n_hosts=4)
    # host 2 is 3x slower
    for _ in range(5):
        mon.observe(np.array([1.0, 1.0, 3.0, 1.0]))
    caps = mon.capacities()
    assert caps[2] < 0.5 and caps[0] > 0.9
    rng = np.random.default_rng(0)
    buckets = list(rng.pareto(1.5, 32) + 0.5)
    assign, _ = mon.rebalance_buckets(buckets)
    loads = np.zeros(4)
    for w, h in zip(buckets, assign):
        loads[h] += w
    # the slow host gets materially less than a fair share
    assert loads[2] < sum(buckets) / 4


def test_plan_shrink_keeps_model_axis():
    rng = np.random.default_rng(1)
    buckets = list(rng.pareto(1.5, 24) + 0.5)
    plan = plan_shrink(
        alive_hosts=[0, 1, 3, 4, 6, 7],  # lost hosts 2 and 5
        chips_per_host=8,
        model_parallel=16,
        last_checkpoint_step=1000,
        bucket_tokens=buckets,
    )
    assert plan.mesh_shape == (3, 16)  # 48 chips / 16-way TP
    assert plan.resume_step == 1000
    assert len(plan.bucket_assignment) == 24
    assert set(plan.bucket_assignment) <= set(range(6))


def test_train_elastic_shim_warns_and_reexports():
    import importlib
    import sys

    sys.modules.pop("repro.train.elastic", None)
    with warnings.catch_warnings(record=True) as caught:
        warnings.simplefilter("always")
        mod = importlib.import_module("repro.train.elastic")
    assert any(issubclass(w.category, DeprecationWarning) for w in caught)
    assert mod.StragglerMonitor is StragglerMonitor
    assert mod.plan_shrink is plan_shrink
