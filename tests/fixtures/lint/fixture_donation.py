"""Seeded violations for the donation-safety checker (never executed)."""

from repro.kernels.lbm_collide.ops import make_fused_superstep


def use_after_donate(pdfs, cfg):
    fn = make_fused_superstep(**cfg)
    fn(pdfs)
    return pdfs[0].sum()  # TP-DONATED: pdfs was consumed by the donating program


def alias_after_donate(pdfs, cfg):
    fn = make_fused_superstep(**cfg)
    stash = pdfs
    fn(pdfs)
    return stash  # TP-ALIAS: stash aliases the donated buffer


def attribute_stash(holder, pdfs, cfg):
    fn = make_fused_superstep(**cfg)
    holder.saved = pdfs
    fn(pdfs)
    return holder.saved  # TP-ATTR: attribute alias of the donated buffer


def safe_rebind(pdfs, cfg):
    fn = make_fused_superstep(**cfg)
    pdfs = fn(pdfs)  # NEG-REBIND: the sanctioned ping-pong idiom
    return pdfs


def sanctioned_read(pdfs, cfg):
    fn = make_fused_superstep(**cfg)
    fn(pdfs)
    # repro: donation-ok(fixture: cpu backend resolves donate off, buffer survives)
    return pdfs  # NEG-ANNOTATED: allowlisted


def with_block_rebind(pdfs, cfg, span):
    fn = make_fused_superstep(**cfg)
    with span:
        pdfs = fn(pdfs)  # NEG-WITH-REBIND: revive must work inside a with suite
        total = pdfs[0]
    return total


def with_block_use_after_donate(pdfs, cfg, span):
    fn = make_fused_superstep(**cfg)
    with span:
        fn(pdfs)
        return pdfs[0]  # TP-WITH: read after donate inside the with suite
