"""Mini stepping module for the collective-free checker fixture."""

from .support import helper_exchange


def step(comm, values):
    total = comm.allreduce(values, sum)  # TP-COLLECTIVE: collective on stepping path
    return helper_exchange(comm, total)


def sanctioned(comm, values):
    # repro: collective-ok(fixture: documented startup-only reduction)
    return comm.allgather(values, 8)  # NEG-ANNOTATED: allowlisted
