"""Control-plane module: excluded by config, its collectives are sanctioned."""


def balance(comm, weights):
    return comm.allgather(weights, 4)  # NEG-EXCLUDED: module is config-excluded
