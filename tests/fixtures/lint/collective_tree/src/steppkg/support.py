"""Imported by the stepping root: reachability must extend here."""


def helper_exchange(comm, values):
    return comm.all_gather(values)  # TP-REACHABLE: collective one import hop away
