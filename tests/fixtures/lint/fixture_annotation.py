"""Annotation-hygiene fixture: an allowlist entry with no reason is itself a
finding (never executed)."""

import jax
import numpy as np


def undocumented_sanction(dev):
    _ = jax
    # repro: host-ok()
    return np.asarray(dev)  # the empty reason above is flagged, the sync is not suppressed
