"""Mini device-fabric module for the ppermute checker fixture.

ppermute is sanctioned p2p, but only with a reason on record: an
unannotated call outside the fabric provider is a finding."""

import jax


def leak_halo(perm, payload):
    return jax.lax.ppermute(payload, "ranks", perm)  # TP-PPERMUTE: unannotated


def leak_permute(perm, payload):
    return jax.lax.collective_permute(payload, perm)  # TP-PERMUTE: alias name


def route_halo(perm, payload):
    # repro: collective-ok(fixture: partial-permutation halo routing)
    return jax.lax.ppermute(payload, "ranks", perm)  # NEG-ANNOTATED


def ppermute(payload, pairs):
    """Fabric provider: the def's own name exempts its body."""
    return jax.lax.collective_permute(payload, pairs)  # NEG-PROVIDER
