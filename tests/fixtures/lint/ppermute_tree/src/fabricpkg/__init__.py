from . import stepping  # noqa: F401
