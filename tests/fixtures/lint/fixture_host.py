"""Seeded violations for the host-transfer checker (never executed)."""

import jax
import jax.numpy as jnp
import numpy as np


def bad_sync(dev):
    return dev.mean().item()  # TP-ITEM: implicit d2h sync


def bad_copy(dev):
    return np.asarray(dev)  # TP-ASARRAY: implicit d2h transfer


def bad_fence(dev):
    jax.block_until_ready(dev)  # TP-FENCE: pipeline stall
    return dev


def sanctioned_sync(dev):
    # repro: host-ok(fixture: documented copy-out contract)
    return np.asarray(dev)  # NEG-ANNOTATED: allowlisted


def host_only():
    return np.asarray([1, 2, 3])  # NEG-HOSTVALUE: literal arg, no device source


def traced_cast(x, scale):
    return x * float(scale)  # TP-CAST: concretizes a traced param


def traced_loop(x):
    acc = 0.0
    for v in x:  # TP-ITER: host iteration over a traced param
        acc = acc + v
    return acc


def host_cast_ok(x):
    q = 19
    return x * float(q)  # NEG-CLOSURE: cast of a host local, not a param


step = jax.jit(traced_cast)
loop_step = jax.jit(traced_loop)
ok_step = jax.jit(host_cast_ok)
_ = jnp
