"""Seeded violations for the retrace static checker (never executed)."""

import jax


def jit_in_loop(fns, xs):
    out = []
    for f in fns:
        prog = jax.jit(f)  # TP-LOOP: fresh cache entry per iteration
        out.append(prog(xs))
    return out


def jit_lambda(x):
    return jax.jit(lambda v: v * 2)(x)  # TP-LAMBDA: new function object per call


def mutable_closure_factory(levels):
    table = {}
    for l in levels:
        table[l] = l * 2

    def stepper(x):  # TP-CLOSURE: traced body snapshots a mutated dict
        return x + table[0]

    return jax.jit(stepper)


def float_static(x, omega=1.5):
    return x * omega


bad_static = jax.jit(float_static, static_argnums=1)  # TP-STATIC: float static arg


def hoisted(fns, xs):
    progs = []
    for f in fns:
        # repro: retrace-ok(fixture: bounded one-time build per factory call)
        progs.append(jax.jit(f))  # NEG-ANNOTATED: allowlisted
    return [p(xs) for p in progs]
