"""Training substrate: optimizer math, grad accumulation, data pipeline."""

import jax
import pytest
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models.zoo import DistContext, build_model
from repro.train import (
    AdamWConfig,
    SyntheticTokenPipeline,
    adamw_init,
    diffusion_assign_buckets,
    make_train_step,
)
from repro.train.moe_balance import ExpertPlacement


def _setup(arch="olmo-1b"):
    cfg = get_config(arch).reduced()
    model = build_model(cfg, DistContext(remat=False))
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def test_loss_decreases_on_structured_data():
    cfg, model, params = _setup()
    opt = adamw_init(params)
    step = jax.jit(make_train_step(model, AdamWConfig(lr=3e-3, warmup_steps=10)))
    pipe = SyntheticTokenPipeline(vocab=cfg.vocab, seq_len=32, global_batch=8)
    losses = []
    for batch in pipe.structured_batches(25):
        b = {k: jnp.asarray(v) for k, v in batch.items()}
        params, opt, m = step(params, opt, b)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] - 0.5, (losses[0], losses[-1])


@pytest.mark.slow
def test_microbatch_accumulation_matches_full_batch():
    cfg, model, params = _setup()
    opt = adamw_init(params)
    batch = next(
        SyntheticTokenPipeline(vocab=cfg.vocab, seq_len=16, global_batch=4).batches(1)
    )
    b = {k: jnp.asarray(v) for k, v in batch.items()}
    step1 = jax.jit(make_train_step(model, AdamWConfig(lr=1e-3), microbatches=1))
    step2 = jax.jit(make_train_step(model, AdamWConfig(lr=1e-3), microbatches=2))
    p1, _, m1 = step1(params, opt, b)
    p2, _, m2 = step2(params, adamw_init(params), b)
    # losses agree; params agree to accumulation tolerance
    assert abs(float(m1["loss"]) - float(m2["loss"])) < 2e-3
    for a, c in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(c), rtol=2e-2, atol=2e-4)


def test_adamw_applies_weight_decay_and_clip():
    params = {"w": jnp.ones((4, 4))}
    opt = adamw_init(params)
    grads = {"w": jnp.full((4, 4), 100.0)}  # exceeds clip
    cfg = AdamWConfig(lr=1e-2, grad_clip=1.0, weight_decay=0.1, warmup_steps=1)
    from repro.train.optimizer import adamw_update

    new_params, new_opt, stats = adamw_update(grads, opt, params, cfg)
    assert float(stats["grad_norm"]) > 1.0
    assert float(jnp.abs(new_params["w"]).max()) < 1.0  # moved down
    assert int(new_opt["step"]) == 1


def test_diffusion_bucket_assignment_balances():
    rng = np.random.default_rng(0)
    weights = list(rng.pareto(1.5, 48) + 0.5)
    assign, iters = diffusion_assign_buckets(weights, 6)
    assert len(assign) == 48 and all(0 <= a < 6 for a in assign)
    loads = np.zeros(6)
    for w, a in zip(weights, assign):
        loads[a] += w
    avg = sum(weights) / 6
    # bounded by avg + the single largest bucket (granularity limit)
    assert loads.max() <= avg + max(weights) + 1e-9


def test_expert_placement_reduces_peak_load():
    pl = ExpertPlacement(n_experts=16, n_groups=4)
    loads = np.asarray([10.0, 1, 1, 1, 1, 1, 1, 1, 1, 1, 1, 1, 1, 1, 1, 1])
    before = pl.group_loads(loads).max()
    pl.rebalance(loads)
    after = pl.group_loads(loads).max()
    assert after <= before
    assert after <= loads.sum() / 4 + loads.max()
    perm = pl.permutation()
    assert sorted(perm.tolist()) == list(range(16))
