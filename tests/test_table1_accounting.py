"""Table-1 accounting: the paper's per-rank scalability argument, measured.

The paper's Table 1 distinguishes algorithms whose per-rank memory/traffic
is O(local state) (diffusion balancing, next-neighbor ghost exchange, O(1)
allreduce results) from those that replicate Θ(N) bytes on every rank
(allgather-style SFC balancing). With the rank-sharded data plane the whole
AMR+LBM cycle runs over the accounted ``Comm`` fabric, so these properties
are now assertable end to end:

* balancing + ghost exchange with the diffusion balancer record **zero**
  allgather-style collectives;
* bytes a rank must hold per collective stay O(1) as the rank count grows
  (4 -> 16 ranks), and per-rank held data-plane bytes / peak inbox bytes do
  not grow with N (fixed global problem => they shrink);
* the SFC balancer is the positive control: its allgather makes per-rank
  collective bytes grow ~linearly in N, proving the counters can tell the
  difference.
"""

import pytest

from repro.lbm import AMRLBM, LidDrivenCavityConfig
from repro.particles import ParticlesConfig

# particle traffic enabled: tracer advection/redistribution and the
# cells + alpha*N weight model must not change the collective shape of the
# cycle — zero allgathers, O(1) bytes per collective
BASE = dict(
    root_grid=(2, 2, 2),
    cells_per_block=(8, 8, 8),
    omega=1.5,
    u_lid=(0.08, 0.0, 0.0),
    max_level=1,
    refine_upper=0.03,
    refine_lower=0.004,
    stepping_mode="sharded",
    kernel_backend="ref",
    particles=ParticlesConfig(
        per_block=8,
        seed=1,
        alpha=0.05,
        region=((0.0, 0.0, 1.5), (2.0, 2.0, 2.0)),
    ),
)


def _run(nranks: int, balancer: str) -> AMRLBM:
    """Full cycle: stepping, one AMR event (balancing + migration), stepping."""
    sim = AMRLBM(LidDrivenCavityConfig(nranks=nranks, balancer=balancer, **BASE))
    sim.advance(2)
    sim.adapt()
    assert sim.amr_cycles >= 1
    sim.advance(2)
    return sim


@pytest.fixture(scope="module")
def diffusion_runs():
    return {n: _run(n, "diffusion-pushpull") for n in (4, 16)}


def test_diffusion_cycle_records_no_allgather(diffusion_runs):
    for sim in diffusion_runs.values():
        assert sim.comm.stats.allgather_calls == 0
        # ghost exchange itself is collective-free (halo stage attribution)
        assert sim.data_stats["halo"].collective_bytes_per_rank == 0
        assert sim.data_stats["halo"].p2p_bytes > 0
        # particle traffic is live and just as collective-free
        assert sim.total_particles() > 0
        assert sim.particles_advected > 0
        assert sim.data_stats["particles"].collective_bytes_per_rank == 0


def test_per_rank_held_bytes_bounded_as_ranks_grow(diffusion_runs):
    s4, s16 = diffusion_runs[4], diffusion_runs[16]

    def per_collective(sim):
        st = sim.comm.stats
        return st.collective_bytes_per_rank / max(1, st.allreduce_calls)

    # O(1) result bytes per collective, independent of the rank count
    # (an allgather would scale this by 4x going from 4 to 16 ranks)
    assert per_collective(s16) <= per_collective(s4) * 1.25
    # fixed global problem: per-rank data-plane bytes and the peak bytes any
    # rank receives in one round must not grow with the rank count
    assert max(s16.arenas.held_bytes_per_rank()) <= max(
        s4.arenas.held_bytes_per_rank()
    )
    assert (
        s16.comm.stats.max_inbox_bytes_per_round
        <= s4.comm.stats.max_inbox_bytes_per_round
    )


def test_fused_sharded_cycle_keeps_the_table1_shape():
    """The device-resident sharded mode must not change the collective shape
    of the cycle: the compiled rank-halo exchange routes device-built
    buffers as the same one-message-per-rank-pair p2p traffic, so a full
    stepping + AMR + stepping cycle (with live particle traffic) still
    records zero allgathers and collective-free halo/particle stages."""
    cfg = dict(BASE, stepping_mode="fused_sharded")
    sim = AMRLBM(LidDrivenCavityConfig(nranks=4, balancer="diffusion-pushpull", **cfg))
    sim.advance(2)
    sim.adapt()
    assert sim.amr_cycles >= 1
    sim.advance(2)
    assert sim.comm.stats.allgather_calls == 0
    # the device-message exchange is attributed under "fused": p2p only
    assert sim.data_stats["fused"].p2p_bytes > 0
    assert sim.data_stats["fused"].collective_bytes_per_rank == 0
    assert sim.data_stats["halo"].collective_bytes_per_rank == 0
    assert sim.total_particles() > 0 and sim.particles_advected > 0
    assert sim.data_stats["particles"].collective_bytes_per_rank == 0


def test_sfc_allgather_is_the_positive_control():
    s4 = _run(4, "morton")
    s16 = _run(16, "morton")
    assert s4.comm.stats.allgather_calls > 0
    # Θ(N) bytes held per rank: 4x the ranks => strictly more bytes per rank
    assert (
        s16.comm.stats.collective_bytes_per_rank
        > s4.comm.stats.collective_bytes_per_rank
    )


def _require_devices(n: int) -> None:
    import jax

    if jax.device_count() < n:
        pytest.skip(
            f"needs {n} XLA devices (run under "
            f"XLA_FLAGS=--xla_force_host_platform_device_count={n})"
        )


def test_device_sharded_cycle_keeps_the_table1_shape():
    """The real device fabric must not change the collective shape of the
    cycle either: halo payloads move as in-program ppermute (a partial
    permutation — pure p2p), so a full stepping + AMR + stepping cycle with
    live particle traffic records zero allgather/allreduce-class collectives
    during stepping and p2p-only stage attribution."""
    _require_devices(4)
    cfg = dict(BASE, stepping_mode="device_sharded")
    sim = AMRLBM(LidDrivenCavityConfig(nranks=4, balancer="diffusion-pushpull", **cfg))
    sim.advance(2)
    before = sim.comm.stats.summary()
    sim.advance(2)
    after = sim.comm.stats.summary()
    # stepping is collective-free: ppermute bytes land in the p2p counters
    assert after["allgather_calls"] == before["allgather_calls"] == 0
    assert after["allreduce_calls"] == before["allreduce_calls"]
    assert after["collective_bytes_per_rank"] == before["collective_bytes_per_rank"]
    assert after["p2p_bytes"] > before["p2p_bytes"]
    sim.adapt()
    assert sim.amr_cycles >= 1
    sim.advance(2)
    assert sim.comm.stats.allgather_calls == 0
    assert sim.data_stats["fused"].p2p_bytes > 0
    assert sim.data_stats["fused"].collective_bytes_per_rank == 0
    assert sim.data_stats["halo"].collective_bytes_per_rank == 0
    assert sim.total_particles() > 0 and sim.particles_advected > 0
    assert sim.data_stats["particles"].collective_bytes_per_rank == 0


def test_device_sharded_held_bytes_do_not_grow_with_devices():
    """Table-1 boundedness on the real fabric: per-device held bytes of the
    padded stepping state do not grow when the same global problem spreads
    over more devices (2 -> 4) — equal-blocks-per-rank padding is bounded by
    the max per-rank share, which shrinks with the device count."""
    _require_devices(4)
    cfg = dict(BASE, stepping_mode="device_sharded")

    def held(nranks: int) -> int:
        sim = AMRLBM(
            LidDrivenCavityConfig(nranks=nranks, balancer="diffusion-pushpull", **cfg)
        )
        sim.advance(2)
        sim.adapt()  # AMR event: padding re-derived for the refined forest
        sim.advance(2)
        sim.materialize_host()
        return sim.engine.device_held_bytes_per_rank()

    h2, h4 = held(2), held(4)
    assert h2 > 0 and h4 > 0
    assert h4 <= h2, (h2, h4)
