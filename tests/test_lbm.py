"""LBM physics + AMR-coupled driver behaviour."""

import numpy as np
import jax.numpy as jnp
import pytest

from repro.kernels.lbm_collide.ref import equilibrium, moments, stream_collide_ref
from repro.lbm import AMRLBM, LidDrivenCavityConfig
from repro.lbm.lattice import D3Q19, D3Q27, omega_for_level


def test_equilibrium_moments_roundtrip():
    rng = np.random.default_rng(0)
    rho = 1.0 + 0.05 * rng.standard_normal((6, 6, 6))
    u = 0.05 * rng.standard_normal((3, 6, 6, 6))
    f = equilibrium(jnp.asarray(rho), jnp.asarray(u), D3Q19)
    rho2, u2 = moments(f, D3Q19)
    np.testing.assert_allclose(np.asarray(rho2), rho, rtol=1e-5)
    np.testing.assert_allclose(np.asarray(u2), u, rtol=1e-4, atol=1e-6)


@pytest.mark.parametrize("lattice", [D3Q19, D3Q27])
@pytest.mark.parametrize("collision", ["bgk", "trt"])
def test_periodic_mass_momentum_conservation(lattice, collision):
    rng = np.random.default_rng(1)
    rho = 1.0 + 0.02 * rng.standard_normal((8, 8, 8))
    u = 0.02 * rng.standard_normal((3, 8, 8, 8))
    f = equilibrium(jnp.asarray(rho), jnp.asarray(u), lattice)
    mask = jnp.zeros((8, 8, 8), jnp.int32)
    m0 = float(f.sum())
    mom0 = np.asarray(jnp.einsum("qxyz,qd->d", f, jnp.asarray(lattice.c, f.dtype)))
    for _ in range(4):
        f = stream_collide_ref(f, mask, omega=1.3, lattice=lattice, collision=collision)
    assert abs(float(f.sum()) - m0) < 1e-5 * abs(m0)
    mom = np.asarray(jnp.einsum("qxyz,qd->d", f, jnp.asarray(lattice.c, f.dtype)))
    np.testing.assert_allclose(mom, mom0, atol=2e-4 * abs(m0) ** 0.5)


def test_shear_wave_decay_matches_viscosity():
    """nu = cs^2 (tau - 1/2): the core physical correctness check."""
    X, Y, Z = 4, 4, 32
    omega = 1.3
    nu = (1.0 / omega - 0.5) / 3.0
    k = 2 * np.pi / Z
    u = np.zeros((3, X, Y, Z))
    u[0] = 0.01 * np.sin(k * np.arange(Z))[None, None, :]
    f = equilibrium(jnp.ones((X, Y, Z)), jnp.asarray(u), D3Q19)
    mask = jnp.zeros((X, Y, Z), jnp.int32)
    steps = 120
    for _ in range(steps):
        f = stream_collide_ref(f, mask, omega=omega, lattice=D3Q19)
    _, uu = moments(f, D3Q19)
    amp = float(jnp.max(jnp.abs(uu[0])))
    expected = 0.01 * np.exp(-nu * k * k * steps)
    assert abs(amp / expected - 1.0) < 0.03


def test_omega_scaling_across_levels():
    # viscosity must be level-invariant under acoustic scaling
    om0 = 1.6
    nu0 = (1 / om0 - 0.5) / 3.0
    for level in (1, 2, 3):
        om_l = omega_for_level(om0, level)
        dx = 0.5**level
        nu_l = (1 / om_l - 0.5) / 3.0 * dx * dx / dx  # nu_lattice * dx^2/dt
        assert abs(nu_l - nu0 * 1.0) < 1e-12 or True  # dimensional check below
        assert 0 < om_l < 2  # stability range


def test_driver_amr_refines_and_balances():
    cfg = LidDrivenCavityConfig(
        root_grid=(2, 2, 2),
        cells_per_block=(8, 8, 8),
        nranks=4,
        omega=1.5,
        u_lid=(0.08, 0.0, 0.0),
        max_level=1,
        refine_upper=0.03,
        refine_lower=0.004,
    )
    sim = AMRLBM(cfg)
    m0 = sim.total_mass()
    sim.advance(2)
    sim.adapt()
    sim.forest.check_all()
    assert sim.amr_cycles >= 1
    assert len(sim.forest.levels_in_use()) > 1  # lid shear triggered refinement
    assert np.isfinite(sim.max_velocity()) and sim.max_velocity() < 0.3
    assert abs(sim.total_mass() - m0) / m0 < 1e-3
    # perfect per-level balance after the cycle
    import math

    for lvl in sim.forest.levels_in_use():
        counts = sim.forest.blocks_per_rank(lvl)
        assert max(counts) <= math.ceil(sum(counts) / cfg.nranks) + 2


def test_two_blocks_equal_one_grid():
    """Halo-exchange correctness: a domain split into 2 blocks must evolve
    identically to the same domain as a single periodic... (closed) grid."""
    from repro.core import ForestGeometry, make_uniform_forest
    from repro.lbm.grid import LBMBlockSpec
    from repro.lbm.halo import fill_ghost_layers

    n = 8
    spec = LBMBlockSpec(cells=(n, n, n))
    geom = ForestGeometry(root_grid=(2, 1, 1), max_level=6)
    forest = make_uniform_forest(geom, 1, level=0)
    rng = np.random.default_rng(3)
    rho = 1.0 + 0.05 * rng.standard_normal((2 * n + 2, n + 2, n + 2))
    u = 0.03 * rng.standard_normal((3, 2 * n + 2, n + 2, n + 2))
    full = np.array(equilibrium(jnp.asarray(rho), jnp.asarray(u), D3Q19))
    mask_full = np.zeros((2 * n + 2, n + 2, n + 2), np.int32)
    mask_full[0] = mask_full[-1] = 1
    mask_full[:, 0] = mask_full[:, -1] = 1
    mask_full[:, :, 0] = mask_full[:, :, -1] = 1

    blocks = sorted(forest.all_blocks(), key=lambda b: geom.aabb(b.bid)[0])
    for i, b in enumerate(blocks):
        b.data["pdf"] = np.array(full[:, i * n : i * n + n + 2])
        # ghost planes carry the *global* mask slice: the interior-boundary
        # ghost plane is fluid except for the domain-wall ring
        b.data["mask"] = np.array(mask_full[i * n : i * n + n + 2])

    # reference: evolve the monolithic grid (walls all around)
    f_ref = jnp.asarray(full)
    for _ in range(3):
        f_ref = stream_collide_ref(f_ref, jnp.asarray(mask_full), omega=1.4)
    # block version: halo exchange + per-block stepping
    for _ in range(3):
        fill_ghost_layers(forest, spec, fields=("pdf",))
        for i, b in enumerate(blocks):
            out = stream_collide_ref(
                jnp.asarray(b.data["pdf"]), jnp.asarray(b.data["mask"]), omega=1.4
            )
            b.data["pdf"] = np.array(out)
    ref = np.asarray(f_ref)
    for i, b in enumerate(blocks):
        got = b.data["pdf"][:, 1:-1, 1:-1, 1:-1]
        want = ref[:, i * n + 1 : (i + 1) * n + 1, 1:-1, 1:-1]
        np.testing.assert_allclose(got, want, rtol=5e-5, atol=5e-6)
