"""Telemetry layer: bounded rings, disabled-path cost, span/StageStats
consistency, and Chrome-trace export validity.

The contracts pinned here are the ones the observability layer advertises:

* **Bounded buffers** — per-rank telemetry memory is a construction-time
  bound (capacity x nominal record size), independent of rank count and run
  length; evictions are counted, never silent.
* **Near-zero disabled path** — ``span()`` returns the shared ``NULL_SPAN``
  (no allocation, no clock reads) and ``instant()`` is a no-op, so leaving
  the instrumentation in hot loops costs ~nothing when telemetry is off.
* **Spans are the stats** — the instrumentation feeds the same ``seconds``
  into ``StageStats`` that it records as a span, and
  :func:`~repro.telemetry.export.stage_seconds` accumulates in recording
  order, so the span sums equal the ``data_stats`` / ``CycleReport``
  surfaces *exactly* (float-for-float), and the two can never disagree.
* **Valid traces** — a traced 4-rank ``fused_sharded`` run spanning an AMR
  event exports Chrome-trace JSON that ``tools/trace_report.py`` accepts,
  including the per-substep emit/interior/route/absorb phases that make the
  PR 7 overlap visible; the committed example artifact stays valid too.
"""

import json
import sys
import time
from pathlib import Path

import pytest

from repro import telemetry
from repro.lbm.driver import AMRLBM, LidDrivenCavityConfig
from repro.telemetry import (
    NULL_SPAN,
    Counter,
    Histogram,
    MetricsRegistry,
    SECONDS_BUCKETS,
    Tracer,
)
from repro.telemetry.tracer import RECORD_NOMINAL_BYTES

ROOT = Path(__file__).resolve().parents[1]
sys.path.insert(0, str(ROOT / "tools"))

from trace_report import PHASES, check_trace  # noqa: E402

BASE = dict(
    root_grid=(2, 2, 2),
    cells_per_block=(8, 8, 8),
    omega=1.5,
    u_lid=(0.08, 0.0, 0.0),
    max_level=1,
    refine_upper=0.03,
    refine_lower=0.004,
    kernel_backend="ref",
)


def _cfg(**over) -> LidDrivenCavityConfig:
    return LidDrivenCavityConfig(**{**BASE, **over})


@pytest.fixture(autouse=True)
def _restore_global_tracer():
    """Tests mutate the process-wide tracer; restore the defaults so the
    rest of the suite keeps its zero-overhead disabled path."""
    yield
    telemetry.configure(enabled=False, clock=time.perf_counter)
    telemetry.get_tracer().reset()


# ---------------------------------------------------------------------------
# bounded-buffer contract
# ---------------------------------------------------------------------------


def _fake_clock(step: float = 1.0):
    t = [0.0]

    def clock() -> float:
        t[0] += step
        return t[0]

    return clock


def test_ring_evicts_at_capacity_and_counts():
    tr = Tracer(enabled=True, capacity=8, clock=_fake_clock())
    for i in range(20):
        tr.instant(f"ev{i}", rank=0)
    recs = tr.records(rank=0)
    assert len(recs) == 8  # bounded: oldest 12 gone
    assert [r.name for r in recs] == [f"ev{i}" for i in range(12, 20)]
    stats = tr.buffer_stats()[0]
    assert stats == {"entries": 8, "capacity": 8, "evicted": 12, "total": 20}
    # chronological merge survives wrap-around
    t0s = [r.t0 for r in recs]
    assert t0s == sorted(t0s)


@pytest.mark.parametrize("nranks", [4, 13])
def test_per_rank_memory_bounded_independent_of_rank_count(nranks):
    """The Table-1 discipline for observability: each rank's telemetry
    memory hits the same construction-time bound whether the run has 4
    ranks or 13 — there is no global log anywhere."""
    cap = 16
    tr = Tracer(enabled=True, capacity=cap, clock=_fake_clock())
    for i in range(50 * nranks):  # far past capacity on every rank
        tr.instant("ev", rank=i % nranks)
    held = tr.held_bytes_per_rank()
    assert set(held) == set(range(nranks))
    bound = cap * RECORD_NOMINAL_BYTES
    assert all(b == bound for b in held.values())
    for stats in tr.buffer_stats().values():
        assert stats["entries"] == cap
        assert stats["evicted"] == stats["total"] - cap


def test_metrics_are_bounded():
    # label-set cap: later combinations fold into one overflow series
    c = Counter("c", max_series=2)
    for src in range(5):
        c.inc(10, src=src)
    assert c.total() == 50  # nothing lost, just folded
    assert len(c.series()) == 3  # 2 real + overflow
    assert c.overflowed == 3
    # histogram: fixed layout, correct bucket placement
    h = Histogram("h", buckets=SECONDS_BUCKETS)
    h.observe(5e-7)  # below first bound (1e-6)
    h.observe(0.5)  # -> 1e0 bucket
    h.observe(1e9)  # -> +inf bucket
    (series,) = h.series().values()
    assert series["n"] == 3 and sum(series["counts"]) == 3
    assert series["counts"][0] == 1 and series["counts"][-1] == 1
    # registry cap: past max_metrics, observations drop (counted), never grow
    reg = MetricsRegistry(max_metrics=2)
    reg.counter("a").inc()
    reg.counter("b").inc()
    reg.counter("c").inc()  # dropped
    assert len(reg) == 2 and reg.dropped_metrics == 1
    reg.counter("a").inc()  # existing metrics still reachable when full
    assert reg.counter("a").total() == 2


# ---------------------------------------------------------------------------
# disabled path
# ---------------------------------------------------------------------------


def test_disabled_path_is_null_and_records_nothing():
    tr = Tracer(enabled=False)
    assert tr.span("x") is NULL_SPAN  # shared instance, no allocation
    assert tr.span("y", cat="substep", rank=3) is NULL_SPAN
    with tr.span("x") as sp:
        sp.set(bytes=123)
    tr.instant("ev", rank=2)
    assert tr.records() == [] and tr.buffer_stats() == {}
    # stage() must still time (its .seconds feeds StageStats) but not record
    with tr.stage("halo") as sp:
        pass
    assert sp.seconds >= 0.0 and tr.records() == []


def test_disabled_span_overhead_is_negligible():
    """Pin the cost of leaving instrumentation in hot loops: 100k disabled
    span() round-trips must be far below anything a stepping loop notices
    (generous wall bound to stay robust on loaded CI hosts)."""
    tr = Tracer(enabled=False)
    t0 = time.perf_counter()
    for _ in range(100_000):
        with tr.span("hot", cat="substep", rank=0):
            pass
    assert time.perf_counter() - t0 < 1.0


# ---------------------------------------------------------------------------
# spans == stats
# ---------------------------------------------------------------------------


def test_stage_spans_equal_data_stats_exactly():
    """data_stats["halo"/"step"] and the recorded stage spans come from the
    same Span.seconds values accumulated in the same order — equality is
    exact, not approximate."""
    telemetry.configure(enabled=True, capacity=8192)
    tr = telemetry.get_tracer()
    tr.reset()
    sim = AMRLBM(_cfg(stepping_mode="arena", nranks=2))
    sim.run(4, amr_interval=2)
    sums = telemetry.export.stage_seconds(tr, cat="stage")
    assert sums["halo"] == sim.data_stats["halo"].seconds
    assert sums["step"] == sim.data_stats["step"].seconds


def test_amr_cycle_report_matches_spans_exactly():
    telemetry.configure(enabled=True, capacity=8192)
    tr = telemetry.get_tracer()
    sim = AMRLBM(_cfg(stepping_mode="arena", nranks=2))
    sim.advance(2)
    tr.reset()  # isolate exactly one AMR cycle
    report = sim.adapt(force_rebalance=True)
    assert report.executed
    sums = telemetry.export.stage_seconds(tr, cat="amr")
    for stage in ("refine", "proxy", "balance", "migrate"):
        assert sums[stage] == report.stages[stage].seconds


def test_injectable_clock_threads_through_serving():
    """With a deterministic clock injected, every serving latency is an
    exact whole-tick difference — proof that no instrumentation site fell
    back to time.perf_counter()."""
    from repro.serving import JobSpec, SimulationService

    telemetry.configure(enabled=True, clock=_fake_clock())
    svc = SimulationService()
    jid = svc.submit(
        JobSpec(config=_cfg(stepping_mode="arena"), coarse_steps=2,
                amr_interval=4)
    )
    svc.run()
    job = svc.jobs[jid]
    assert job.status == "done"
    latency = svc.data_stats["serving"]["jobs"][jid]["latency_s"]
    assert latency == job.finished_at - job.submitted_at
    assert latency == int(latency) and latency > 0  # whole fake-clock ticks


# ---------------------------------------------------------------------------
# Chrome-trace export
# ---------------------------------------------------------------------------


def test_fused_sharded_trace_is_valid_and_shows_all_phases(tmp_path):
    """A traced 4-rank fused_sharded run across an AMR event exports a valid
    Chrome trace whose substeps carry distinct emit/interior/route/absorb
    spans (the 6x6x6 grid gives every rank interior blocks at 4 ranks, so
    the overlap split actually engages; see examples/trace_fused_sharded.py).
    """
    telemetry.configure(enabled=True, capacity=8192)
    tr = telemetry.get_tracer()
    tr.reset()
    sim = AMRLBM(
        _cfg(
            root_grid=(6, 6, 6),
            cells_per_block=(4, 4, 4),
            nranks=4,
            stepping_mode="fused_sharded",
            overlap_split=True,
        )
    )
    sim.advance(1)
    report = sim.adapt(force_rebalance=True)
    assert report.executed, "the trace must span an AMR event"
    sim.advance(1)

    path = telemetry.export.write_chrome_trace(tmp_path / "t.json")
    trace = json.loads(path.read_text())
    assert check_trace(trace, require_substep_phases=True) == []
    names = {
        ev["name"] for ev in trace["traceEvents"]
        if ev.get("cat") == "substep"
    }
    assert set(PHASES) <= names
    assert any(
        ev["name"] == "amr.event" and ev["ph"] == "i"
        for ev in trace["traceEvents"]
    )
    # counter tracks synthesized from route bytes + compile events
    kinds = {ev["name"] for ev in trace["traceEvents"] if ev["ph"] == "C"}
    assert "substep.bytes" in kinds and "compiles" in kinds
    # per-pair p2p byte counters made it into the embedded metrics
    p2p = trace["metadata"]["metrics"]["comm.p2p_bytes"]["series"]
    assert p2p and all(v > 0 for v in p2p.values())
    # and the artifact itself proves the buffers stayed bounded
    for stats in trace["metadata"]["buffers"].values():
        assert stats["entries"] <= stats["capacity"] == 8192


def test_committed_example_trace_is_valid():
    path = ROOT / "examples" / "traces" / "fused_sharded_4rank.trace.json"
    trace = json.loads(path.read_text())
    assert check_trace(trace, require_substep_phases=True) == []
