"""HLO parser: loop-corrected collective bytes and dot FLOPs."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.launch.hlo_analysis import analyze_hlo, roofline_terms


def test_scan_dot_flops_are_trip_count_corrected():
    """cost_analysis counts while bodies once; analyze_hlo must multiply by
    the recovered trip count."""

    def f(x, w):
        y, _ = jax.lax.scan(lambda c, wi: (c @ wi, None), x, w)
        return y

    x = jnp.ones((64, 64))
    flops = {}
    for L in (3, 6):
        comp = jax.jit(f).lower(x, jnp.ones((L, 64, 64))).compile()
        stats = analyze_hlo(comp.as_text())
        flops[L] = stats.dot_flops_total
    per_iter = 2 * 64 * 64 * 64
    assert abs(flops[3] - 3 * per_iter) / (3 * per_iter) < 0.05, flops
    assert abs(flops[6] - 6 * per_iter) / (6 * per_iter) < 0.05, flops


def test_nested_scan_multipliers():
    def f(x, w):
        def outer(c, wi):
            def inner(ci, _):
                return ci @ wi, None

            c2, _ = jax.lax.scan(inner, c, None, length=4)
            return c2, None

        y, _ = jax.lax.scan(outer, x, w)
        return y

    comp = jax.jit(f).lower(jnp.ones((32, 32)), jnp.ones((5, 32, 32))).compile()
    stats = analyze_hlo(comp.as_text())
    per_iter = 2 * 32 * 32 * 32
    expect = 5 * 4 * per_iter
    assert abs(stats.dot_flops_total - expect) / expect < 0.05


def test_roofline_terms_dominance():
    t = roofline_terms(
        flops_per_device=197e12,  # 1 second of compute
        hbm_bytes_per_device=819e9 * 0.5,
        collective_bytes_per_device=0.0,
    )
    assert t["dominant"] == "compute_s"
    assert abs(t["compute_s"] - 1.0) < 1e-9
    assert 0.99 < t["roofline_fraction"] <= 1.0
    t2 = roofline_terms(
        flops_per_device=197e12 * 0.1,
        hbm_bytes_per_device=0.0,
        collective_bytes_per_device=200e9 * 4,  # 4 seconds on links
    )
    assert t2["dominant"] == "collective_s"
    assert t2["roofline_fraction"] < 0.05
