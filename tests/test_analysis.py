"""Tests for the invariant analyzer (``src/repro/analysis``).

Three layers, mirroring how the analyzer is meant to be trusted:

* fixture tests — each checker catches its seeded true-positive constructs in
  ``tests/fixtures/lint/`` and stays silent on the allowlisted/benign
  negatives sitting right next to them;
* machinery tests — baseline roundtrip + loud staleness, annotation hygiene,
  the retrace sentinel's trace counting, and the HLO transfer-op counter;
* real-tree tests (tier-1 contract) — the full checker suite runs clean on
  the repo against the committed baseline, and the halo-protocol verifier
  proves the 1/4/13-rank sweep topologies without executing a step.
"""

import dataclasses
from pathlib import Path

import numpy as np
import pytest

from repro.analysis import (
    Finding,
    LintConfig,
    RetraceSentinel,
    apply_baseline,
    budget_findings,
    build_sweep_topology,
    line_hash,
    load_baseline,
    load_config,
    rank_slot_map,
    run,
    sweep_topologies,
    verify_compiled_rank_plan,
    write_baseline,
)
from repro.analysis.astutil import ModuleCache
from repro.analysis.checkers import (
    annotation_findings,
    check_collective,
    check_donation,
    check_host_transfer,
    check_retrace,
)
from repro.launch.hlo_analysis import count_transfer_ops

REPO_ROOT = Path(__file__).resolve().parent.parent
FIXTURES = REPO_ROOT / "tests" / "fixtures" / "lint"


def _lines(findings, path):
    return sorted(f.line for f in findings if f.path == path)


# -- fixture tests: one true-positive and one negative per checker -----------------


def test_host_checker_catches_seeded_violations():
    cfg = LintConfig(
        repo_root=FIXTURES,
        raw={"host_transfer": {"paths": ["fixture_host.py"]}},
    )
    findings = check_host_transfer(cfg, ModuleCache(FIXTURES))
    # TP-ITEM 9, TP-ASARRAY 13, TP-FENCE 17, TP-CAST 31, TP-ITER 36
    assert _lines(findings, "fixture_host.py") == [9, 13, 17, 31, 36]
    # the annotated sync (23), the literal arg (27) and the host-local cast
    # (44) must NOT be flagged — they are the sanctioned shapes
    assert all(f.checker == "host" for f in findings)


def test_donation_checker_catches_use_after_donate():
    cfg = LintConfig(
        repo_root=FIXTURES,
        raw={
            "donation": {
                "paths": ["fixture_donation.py"],
                "factories": ["make_fused_superstep"],
            }
        },
    )
    findings = check_donation(cfg, ModuleCache(FIXTURES))
    # TP-DONATED 9 (direct read), TP-ALIAS 16 (alias read), TP-ATTR 23
    # (attribute stash), TP-WITH 51 (read after donate inside a with suite);
    # the rebinds (28, 42) and annotated read (36) stay clean — a with block
    # is straight-line code, so a rebind inside it revives like any other
    assert _lines(findings, "fixture_donation.py") == [9, 16, 23, 51]
    assert "use-after-donate" in findings[0].message


def test_retrace_checker_catches_unstable_patterns():
    cfg = LintConfig(
        repo_root=FIXTURES, raw={"retrace": {"paths": ["fixture_retrace.py"]}}
    )
    findings = check_retrace(cfg, ModuleCache(FIXTURES))
    # TP-LOOP 9, TP-LAMBDA 15, TP-CLOSURE 23, TP-STATIC 33; the annotated
    # loop build (40) is allowlisted
    assert _lines(findings, "fixture_retrace.py") == [9, 15, 23, 33]


def test_collective_checker_uses_import_reachability():
    root = FIXTURES / "collective_tree"
    cfg = LintConfig(
        repo_root=root,
        raw={
            "collective": {
                "stepping_modules": ["steppkg.stepping"],
                "exclude": ["steppkg.control"],
            }
        },
    )
    findings = check_collective(cfg, ModuleCache(root))
    by_path = {f.path: f for f in findings}
    # TP-COLLECTIVE in the root module, TP-REACHABLE one import hop away
    assert _lines(findings, "src/steppkg/stepping.py") == [7]
    assert _lines(findings, "src/steppkg/support.py") == [5]
    # the finding names the import chain back to the stepping root
    assert "steppkg.support <- steppkg.stepping" in by_path["src/steppkg/support.py"].message
    # annotated call (stepping.py:13) and config-excluded control.py stay clean
    assert len(findings) == 2


def test_collective_checker_flags_unannotated_ppermute():
    """ppermute/collective_permute are in the default collective set: the
    sanctioned p2p fabric must carry a reason at every call site. Unannotated
    calls are findings; the annotated route and the fabric provider def (its
    own name is in the set) stay clean."""
    root = FIXTURES / "ppermute_tree"
    cfg = LintConfig(
        repo_root=root,
        raw={"collective": {"stepping_modules": ["fabricpkg.stepping"],
                            "exclude": []}},
    )
    findings = check_collective(cfg, ModuleCache(root))
    # TP-PPERMUTE 10, TP-PERMUTE 14; NEG-ANNOTATED (19) and NEG-PROVIDER
    # (24, enclosing def named 'ppermute') stay clean
    assert _lines(findings, "src/fabricpkg/stepping.py") == [10, 14]
    assert len(findings) == 2
    assert "ppermute" in findings[0].message
    assert "collective_permute" in findings[1].message


def test_annotation_checker_rejects_empty_reasons():
    cfg = LintConfig(
        repo_root=FIXTURES,
        raw={
            "host_transfer": {"paths": ["fixture_annotation.py"]},
            "donation": {"paths": []},
            "retrace": {"paths": []},
        },
    )
    cache = ModuleCache(FIXTURES)
    ann = annotation_findings(cfg, cache)
    assert _lines(ann, "fixture_annotation.py") == [10]
    assert ann[0].checker == "annotation"
    # an empty-reason allowlist entry does NOT suppress the finding it covers
    host = check_host_transfer(cfg, cache)
    assert _lines(host, "fixture_annotation.py") == [11]


# -- baseline machinery ------------------------------------------------------------


def _finding_for(path: Path, rel: str, lineno: int) -> Finding:
    text = path.read_text().splitlines()[lineno - 1]
    return Finding(
        checker="host",
        severity="error",
        path=rel,
        line=lineno,
        message="seeded",
        fix_hint="",
        line_hash=line_hash(text),
    )


def test_baseline_suppresses_then_fails_loudly_on_edit(tmp_path):
    src = tmp_path / "mod.py"
    src.write_text("x = 1\ny = dev.item()\n")
    f = _finding_for(src, "mod.py", 2)
    bl_path = tmp_path / "baseline.json"
    write_baseline(bl_path, [f])
    baseline = load_baseline(bl_path)
    assert len(baseline) == 1

    # matching finding is suppressed, nothing new, nothing stale
    new, suppressed, stale = apply_baseline([f], baseline, tmp_path)
    assert new == [] and len(suppressed) == 1 and stale == []

    # line-shift with identical content still matches (hash is content-based)
    src.write_text("x = 1\nz = 0\ny = dev.item()\n")
    shifted = _finding_for(src, "mod.py", 3)
    new, suppressed, stale = apply_baseline([shifted], baseline, tmp_path)
    assert new == [] and stale == []

    # editing the flagged line invalidates the entry LOUDLY
    src.write_text("x = 1\ny = dev.mean().item()\n")
    edited = _finding_for(src, "mod.py", 2)
    new, suppressed, stale = apply_baseline([edited], baseline, tmp_path)
    assert len(new) == 1  # the edited line is a fresh finding
    assert len(stale) == 1 and "STALE" in stale[0]

    # fixed finding (line intact, checker silent) is the other stale flavor
    src.write_text("x = 1\ny = dev.item()\n")
    new, suppressed, stale = apply_baseline([], baseline, tmp_path)
    assert new == [] and len(stale) == 1 and "no longer fires" in stale[0]


# -- retrace sentinel --------------------------------------------------------------


def test_retrace_sentinel_counts_traces_and_restores_jit():
    import jax
    import jax.numpy as jnp

    orig_jit = jax.jit

    def double(x):
        return x * 2

    with RetraceSentinel() as s:
        prog = jax.jit(double)
        prog(jnp.ones((4,)))
        prog(jnp.ones((4,)))  # cache hit: no retrace
        prog(jnp.ones((8,)))  # new shape: one retrace
    assert jax.jit is orig_jit  # patch removed on exit
    assert s.total() == 2

    assert budget_findings("unit", s.counts, 2) == []
    over = budget_findings("unit", s.counts, 1)
    assert len(over) == 1
    assert "traced 2 times, budget is 1" in over[0].message


def test_fused_engine_stays_within_compile_budget():
    from repro.lbm import AMRLBM, LidDrivenCavityConfig

    budget = load_config(REPO_ROOT).section("retrace")["budgets"]["fused"]
    cfg = LidDrivenCavityConfig(
        root_grid=(2, 2, 2),
        cells_per_block=(8, 8, 8),
        nranks=1,
        omega=1.5,
        u_lid=(0.08, 0.0, 0.0),
        max_level=1,
        refine_upper=0.03,
        refine_lower=0.004,
        stepping_mode="fused",
    )
    with RetraceSentinel() as s:
        sim = AMRLBM(cfg)
        sim.advance(2)  # same arena version: ONE program build
        sim.adapt()  # refinement bumps the version
        sim.advance(2)  # exactly one rebuild for the new forest
    assert budget_findings("fused", s.counts, budget) == []
    # traces scale with arena versions (2 here), never with steps
    assert s.total() <= 2 * len(s.counts) + 2


# -- HLO transfer-op counter -------------------------------------------------------


def test_count_transfer_ops_flags_each_kind():
    hlo = "\n".join(
        [
            "HloModule tampered",
            "  %t = (f32[8], token[]) infeed(token[] %tok)",
            "  %o = token[] outfeed(f32[8] %x, token[] %tok)",
            '  %s = send(f32[8] %x, token[] %tok), is_host_transfer=true',
            '  %r = recv(token[] %tok), is_host_transfer=true',
            '  %c = custom-call(%x), custom_call_target="xla_ffi_python_cpu_callback"',
            "  %p = f32[8]{0:S(5)} parameter(0)",
        ]
    )
    counts = count_transfer_ops(hlo)
    assert counts["infeed_outfeed"] == 2
    assert counts["host_send_recv"] == 2
    assert counts["host_callback"] == 1
    assert counts["host_memory_space"] == 1
    assert counts["total"] == 6


def test_count_transfer_ops_clean_module():
    hlo = "\n".join(
        [
            "HloModule clean",
            "  %a = f32[8]{0} add(f32[8] %x, f32[8] %y)",
            "  ROOT %t = (f32[8]) tuple(%a)",
        ]
    )
    assert count_transfer_ops(hlo)["total"] == 0


# -- halo-protocol verifier --------------------------------------------------------


@pytest.fixture(scope="module")
def four_rank_plan():
    from repro.lbm.grid import LBMBlockSpec, make_lbm_fields
    from repro.lbm.halo import compile_rank_halo_plan

    forest = build_sweep_topology(4)
    spec = LBMBlockSpec(cells=(8, 8, 8), ghost=1)
    registry = make_lbm_fields(spec)
    rank_slots = rank_slot_map(forest)
    plan = compile_rank_halo_plan(forest, registry, rank_slots, fields=("pdf", "mask"))
    return forest, registry, plan, rank_slots


def test_protocol_verifier_passes_intact_plan(four_rank_plan):
    forest, registry, plan, rank_slots = four_rank_plan
    assert plan.messages, "4-rank sweep topology must exchange halos"
    assert verify_compiled_rank_plan(forest, registry, plan, rank_slots) == []


def test_protocol_verifier_catches_dropped_message(four_rank_plan):
    forest, registry, plan, rank_slots = four_rank_plan
    tampered = dataclasses.replace(plan, messages=plan.messages[1:])
    findings = verify_compiled_rank_plan(forest, registry, tampered, rank_slots)
    assert any("orphan send" in f.message for f in findings)
    assert any("coverage" in f.message or "ghost" in f.message for f in findings)


def test_protocol_verifier_catches_byte_asymmetry(four_rank_plan):
    forest, registry, plan, rank_slots = four_rank_plan
    msgs = list(plan.messages)
    msgs[0] = dataclasses.replace(msgs[0], nbytes=msgs[0].nbytes + 8)
    tampered = dataclasses.replace(plan, messages=tuple(msgs))
    findings = verify_compiled_rank_plan(forest, registry, tampered, rank_slots)
    assert any("byte asymmetry" in f.message for f in findings)


def test_protocol_verifier_catches_out_of_bounds_scatter(four_rank_plan):
    forest, registry, plan, rank_slots = four_rank_plan
    msgs = list(plan.messages)
    m = msgs[0]
    lvl, slot, cell, n = m.scatter[0]
    bad = (lvl, slot, np.full_like(cell, 10**7), n)
    msgs[0] = dataclasses.replace(m, scatter=(bad,) + m.scatter[1:])
    tampered = dataclasses.replace(plan, messages=tuple(msgs))
    findings = verify_compiled_rank_plan(forest, registry, tampered, rank_slots)
    assert any("cell ids outside" in f.message for f in findings)


def test_protocol_sweep_proves_1_4_13_rank_topologies():
    # the acceptance sweep: every topology verified statically, including the
    # compiled-vs-host per-pair byte cross-check (Table-1 mode independence),
    # without executing a single step
    assert sweep_topologies((1, 4, 13)) == []


# -- real tree (tier-1 contract) ---------------------------------------------------


def test_real_tree_is_clean_against_committed_baseline():
    cfg = load_config(REPO_ROOT)
    findings = run(cfg)
    baseline = load_baseline(cfg.baseline_path)
    new, suppressed, stale = apply_baseline(findings, baseline, REPO_ROOT)
    assert new == [], "new lint findings:\n" + "\n".join(
        f"  {f.path}:{f.line} [{f.checker}] {f.message}" for f in new
    )
    assert stale == [], "stale baseline entries:\n" + "\n".join(stale)


def test_fixtures_are_never_scanned_by_the_real_tree_run():
    cfg = load_config(REPO_ROOT)
    cache = ModuleCache(REPO_ROOT)
    for section in ("host_transfer", "donation", "retrace"):
        paths = cache.files(cfg.section(section)["paths"])
        assert not any("fixtures" in p.parts for p in paths), section
