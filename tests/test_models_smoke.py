"""Per-architecture smoke tests: REDUCED same-family configs, one forward +
one train step on CPU, asserting output shapes and no NaNs (assignment
requirement). The FULL configs are exercised only via the dry-run."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import all_arch_ids, get_config

pytestmark = pytest.mark.slow  # model-zoo smoke: minutes, not data-plane coverage
from repro.models.zoo import DistContext, build_model
from repro.train import AdamWConfig, adamw_init, make_train_step

ARCHS = all_arch_ids()


def _batch(cfg, B=2, S=16):
    batch = {
        "tokens": jnp.zeros((B, S), jnp.int32) + 5,
        "labels": jnp.ones((B, S), jnp.int32),
    }
    if cfg.is_encoder_decoder:
        batch["enc_embeds"] = jnp.full((B, cfg.encoder_len, cfg.d_model), 0.01, jnp.float32)
    if cfg.m_rope:
        p1 = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
        batch["positions"] = jnp.stack([p1, p1, p1], axis=1)
        batch["frontend_embeds"] = jnp.full((B, S, cfg.d_model), 0.01, jnp.float32)
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_reduced_forward_and_train_step(arch):
    cfg = get_config(arch).reduced()
    assert cfg.family == get_config(arch).family  # same family as assigned
    model = build_model(cfg, DistContext(remat=False))
    params = model.init(jax.random.PRNGKey(0))
    batch = _batch(cfg)
    logits = jax.jit(model.logits)(params, batch)
    assert logits.shape == (2, 16, cfg.vocab)
    assert bool(jnp.isfinite(logits).all()), f"{arch}: non-finite logits"

    step = jax.jit(make_train_step(model, AdamWConfig(lr=1e-3), microbatches=1))
    opt = adamw_init(params)
    params2, opt2, metrics = step(params, opt, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert np.isfinite(float(metrics["grad_norm"]))
    # parameters actually changed
    delta = sum(
        float(jnp.abs(a - b).sum())
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(params2))
    )
    assert delta > 0


@pytest.mark.parametrize("arch", ARCHS)
def test_reduced_decode_step(arch):
    cfg = get_config(arch).reduced()
    model = build_model(cfg, DistContext(remat=False))
    params = model.init(jax.random.PRNGKey(1))
    B = 2
    cache = model.init_cache(B, 32)
    tok = jnp.zeros((B, 1), jnp.int32) + 7
    extras = None
    if cfg.m_rope:
        extras = {"frontend_embeds": jnp.zeros((B, 1, cfg.d_model), jnp.float32)}
    logits, cache2 = jax.jit(lambda p, t, c: model.decode(p, t, c, extras))(
        params, tok, cache
    )
    assert logits.shape == (B, 1, cfg.vocab)
    assert bool(jnp.isfinite(logits).all())
    # cache structure preserved
    assert jax.tree.structure(cache) == jax.tree.structure(cache2)


def test_prefill_then_decode_consistency_dense():
    """Greedy next-token from full forward == decode on the same history
    (validates the cache path against the parallel path for a dense arch)."""
    cfg = get_config("olmo-1b").reduced()
    model = build_model(cfg, DistContext(remat=False))
    params = model.init(jax.random.PRNGKey(2))
    B, S = 1, 8
    rng = np.random.default_rng(0)
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (B, S)), jnp.int32)
    full_logits = model.logits(params, {"tokens": toks})

    # build the cache by feeding tokens one at a time through decode
    cache = model.init_cache(B, S)
    # zero the pos so rope positions match 0..S-1
    cache["pos"] = jnp.zeros((), jnp.int32)
    outs = []
    for t in range(S):
        logits, cache = model.decode(params, toks[:, t : t + 1], cache)
        outs.append(np.asarray(logits[0, 0]))
    # the final decode step sees the full history: compare with teacher-forced
    np.testing.assert_allclose(
        np.asarray(full_logits[0, -1]), outs[-1], rtol=2e-3, atol=2e-3
    )
