"""Equal-blocks-per-rank padding: the device fabric's SPMD layout, proven.

The ``device_sharded`` mode pads every rank's per-level block stack to the
max per-rank block count so all devices run one program. This is only sound
if the padding is *invisible*: no compiled halo plan may ever read or write
a padded slot, padded slots must be exactly inert under the kernel (masked-
slot writes provably dead), and the physics mass of the real slots must be
untouched. A hand-rolled hypothesis twin in the ``test_balancing.py`` style
pins these properties over seeded-random forest partitions (refine/coarsen/
balance driven by ``make_random_marks``), not just the cavity trajectory the
conformance suite walks:

* **layout** — padded counts are the per-level max over ranks, and every
  rank's dense slot ids stay valid in the padded layout unchanged;
* **no padded reads** — ``verify_padded_plan`` returns no findings for any
  activity pattern's compiled plan on any partition;
* **schedule** — the ppermute rounds are partial permutations covering
  every message exactly once, for any partition;
* **dead writes** — stepping a padded stack leaves real slots bitwise equal
  to stepping the unpadded stack and padded slots (all-WALL mask, weight
  PDFs) bitwise unchanged: the pad value is an exact fixed point of the
  stream+collide kernel, so total mass over real slots is preserved exactly.
"""

import numpy as np
import pytest

from conftest import make_random_marks
from repro.core import (
    AMRPipeline,
    BlockDataRegistry,
    Comm,
    DiffusionBalancer,
    ForestGeometry,
    make_uniform_forest,
)
from repro.kernels.lbm_collide.ops import make_stream_collide
from repro.lbm.grid import CellType, LBMBlockSpec
from repro.lbm.halo import (
    compile_rank_halo_plan,
    padded_block_counts,
    schedule_ppermute_rounds,
    verify_padded_plan,
)
from repro.lbm.lattice import D3Q19

NRANKS = 4
SEEDS = range(6)
SPEC = LBMBlockSpec(cells=(8, 8, 8), ghost=1, lattice=D3Q19)


def _random_partition(seed: int):
    """A seeded-random forest: refine/coarsen marks + diffusion balancing."""
    geom = ForestGeometry(root_grid=(2, 2, 2), max_level=3)
    forest = make_uniform_forest(geom, NRANKS, level=1)
    pipe = AMRPipeline(
        balancer=DiffusionBalancer(mode="pushpull", flow_iterations=5),
        registry=BlockDataRegistry.trivial(),
    )
    forest, _report = pipe.run_cycle(
        forest, Comm(NRANKS), make_random_marks(seed)
    )
    forest.check_all()
    return forest


def _rank_slots(forest):
    """Dense per-rank slot maps, exactly as ``RankArenas.adopt`` assigns."""
    slots: dict[int, dict[int, dict[int, int]]] = {}
    for r in range(NRANKS):
        per_level: dict[int, dict[int, int]] = {}
        for b in forest.local_blocks(r).values():
            per_level.setdefault(b.level, {})[b.bid] = len(
                per_level.get(b.level, {})
            )
        slots[r] = per_level
    return slots


@pytest.mark.parametrize("seed", SEEDS)
def test_padded_layout_and_plans_never_touch_a_padded_slot(seed):
    forest = _random_partition(seed)
    rank_slots = _rank_slots(forest)
    counts = padded_block_counts(rank_slots, NRANKS)

    # layout: per-level max over ranks; dense rank-local ids stay valid
    for lvl in forest.levels_in_use():
        per_rank = [len(rank_slots[r].get(lvl, {})) for r in range(NRANKS)]
        assert counts[lvl] == max(per_rank)
        for r in range(NRANKS):
            ids = sorted(rank_slots[r].get(lvl, {}).values())
            assert ids == list(range(len(ids)))  # dense from zero
            assert all(i < counts[lvl] for i in ids)

    # no activity pattern's compiled plan reads or writes a padded slot, and
    # every pattern's ppermute schedule is a partial-permutation exact cover
    levels = sorted(forest.levels_in_use())
    lmax = levels[-1]
    for p in range(lmax + 1):
        active = {l for l in levels if l >= lmax - p}
        plan = compile_rank_halo_plan(
            forest, SPEC, rank_slots, fields=("pdf",), levels=active
        )
        assert verify_padded_plan(plan, rank_slots) == []
        rounds = schedule_ppermute_rounds(plan.messages)
        covered = sorted(m.key for rnd in rounds for m in rnd.messages)
        assert covered == sorted(m.key for m in plan.messages)
        for rnd in rounds:
            srcs = [s for s, _ in rnd.perm]
            dsts = [d for _, d in rnd.perm]
            assert len(set(srcs)) == len(srcs), rnd.perm
            assert len(set(dsts)) == len(dsts), rnd.perm


def test_verify_padded_plan_detects_an_out_of_range_slot():
    """Sanity: the verifier is not vacuous — a slot map clipped below a used
    dst slot is reported as a violation."""
    forest = _random_partition(0)
    rank_slots = _rank_slots(forest)
    plan = compile_rank_halo_plan(forest, SPEC, rank_slots, fields=("pdf",))
    if not plan.messages:  # pragma: no cover - partition-dependent guard
        pytest.skip("partition produced no cross-rank messages")
    # shrink the receiver's claimed block count below a used dst slot
    m = plan.messages[0]
    dst_level = m.scatter[0][0]
    clipped = {
        r: {l: dict(s) for l, s in levels.items()}
        for r, levels in rank_slots.items()
    }
    used = int(max(int(s[1].max()) for s in m.scatter if s[0] == dst_level))
    kept = {
        bid: slot
        for bid, slot in clipped[m.dst_rank][dst_level].items()
        if slot <= used - 1
    } if used > 0 else {}
    clipped[m.dst_rank][dst_level] = kept
    assert verify_padded_plan(plan, clipped) != []


@pytest.mark.parametrize("seed", SEEDS)
def test_padding_is_inert_under_the_kernel_and_preserves_mass(seed):
    """Stepping the padded stack == stepping the real stack, bitwise, and the
    padded slots are an exact fixed point (weight PDFs under all-WALL masks):
    masked-slot writes are provably dead and total mass over real slots is
    exactly the unpadded mass."""
    rng = np.random.default_rng(seed)
    Q = SPEC.lattice.Q
    shape = tuple(c + 2 * SPEC.ghost for c in SPEC.cells)
    B, Bmax = 3, 5  # a rank owning 3 of a 5-slot padded stack

    pdf = (0.1 + 0.9 * rng.random((B, Q) + shape)).astype(np.float32)
    # random masks with a WALL shell and a sprinkle of LID cells: the kernel
    # must be inert on pads regardless of what real blocks look like
    mask = np.full((B,) + shape, CellType.WALL, np.int32)
    inner = (slice(None), slice(1, -1), slice(1, -1), slice(1, -1))
    mask[inner] = rng.choice(
        [CellType.FLUID, CellType.WALL, CellType.LID],
        size=mask[inner].shape,
        p=[0.8, 0.15, 0.05],
    ).astype(np.int32)

    w = np.asarray(SPEC.lattice.w, dtype=np.float32)
    pad_pdf = np.broadcast_to(
        w.reshape((Q, 1, 1, 1)), (Bmax - B, Q) + shape
    ).copy()
    padded_pdf = np.concatenate([pdf, pad_pdf])
    padded_mask = np.concatenate(
        [mask, np.full((Bmax - B,) + shape, CellType.WALL, np.int32)]
    )

    # padding preserves total mass: exactly the real mass plus the known
    # inert pad contribution (weights sum to 1 per cell)
    assert np.asarray(padded_pdf[:B]).tobytes() == pdf.tobytes()

    step = make_stream_collide(
        omega=1.5, lattice=SPEC.lattice, u_wall=(0.08, 0.0, 0.0), backend="ref"
    )
    out_real = np.asarray(step(pdf, mask))
    out_padded = np.asarray(step(padded_pdf, padded_mask))

    # real slots: bitwise identical to the unpadded step (vmapped kernel is
    # per-block, so padding cannot perturb real physics)
    assert out_padded[:B].tobytes() == out_real.tobytes()
    # padded slots: bitwise unchanged — the write is provably dead
    assert out_padded[B:].tobytes() == pad_pdf.tobytes()
    # and therefore mass over real slots is exactly preserved by padding
    assert np.float64(out_padded[:B].sum(dtype=np.float64)) == np.float64(
        out_real.sum(dtype=np.float64)
    )
