"""SFC (§2.4.1) and diffusion (§2.4.2) load balancing."""

import math

import pytest

from repro.core import (
    AMRPipeline,
    BlockDataRegistry,
    Comm,
    DiffusionBalancer,
    SFCBalancer,
    make_uniform_forest,
)

from conftest import make_random_marks


def _run(geom, nranks, balancer, seed=0, level=1):
    forest = make_uniform_forest(geom, nranks, level=level)
    comm = Comm(nranks)
    pipe = AMRPipeline(balancer=balancer, registry=BlockDataRegistry.trivial())
    forest, report = pipe.run_cycle(forest, comm, make_random_marks(seed))
    forest.check_all()
    return forest, comm, report


def _perfect_per_level(forest, nranks, slack=0):
    """slack=0: exact ceiling (SFC). slack=1: the diffusion scheme's
    granularity band (paper: 'may not always achieve a perfect global
    balance ... quickly eliminate processes with high load')."""
    for lvl in forest.levels_in_use():
        counts = forest.blocks_per_rank(lvl)
        assert max(counts) <= math.ceil(sum(counts) / nranks) + slack, (lvl, counts)


@pytest.mark.parametrize("order", ["morton", "hilbert"])
def test_sfc_balancer_perfect_per_level(geom3d, order):
    forest, comm, _ = _run(geom3d, 8, SFCBalancer(order=order, per_level=True))
    _perfect_per_level(forest, 8)


def test_sfc_allgather_cost_scales_with_ranks():
    """Table 1 / §2.4.1: per-rank held bytes grow Θ(N) for SFC balancing
    under WEAK scaling (blocks per rank constant, like the paper's §5.1.1)."""
    from repro.core import ForestGeometry

    held = {}
    for nranks, roots in ((4, (2, 2, 1)), (16, (4, 4, 1))):
        geom = ForestGeometry(root_grid=roots, max_level=8)
        _f, comm, _ = _run(geom, nranks, SFCBalancer(per_level=True), seed=1)
        held[nranks] = comm.stats.collective_bytes_per_rank
    assert held[16] > held[4] * 2.5


@pytest.mark.parametrize("mode,flows,slack", [("push", 15, 2), ("pushpull", 5, 1)])
def test_diffusion_balancer_converges(geom3d, mode, flows, slack):
    # paper §2.4.2: push-only with too few flow iterations "does not always
    # result in perfect balance"; the strict-descent handshake additionally
    # freezes unit-slope plateaus, so push-only gets a 2-block band while
    # alternating push/pull reaches within one block of the ceiling.
    bal = DiffusionBalancer(mode=mode, flow_iterations=flows, max_main_iterations=30)
    forest, comm, report = _run(geom3d, 8, bal)
    _perfect_per_level(forest, 8, slack=slack)
    assert report.main_iterations < 30  # early termination fired


def test_diffusion_is_allgather_free(geom3d):
    bal = DiffusionBalancer(mode="pushpull", flow_iterations=5, max_main_iterations=20)
    _f, comm, _ = _run(geom3d, 8, bal)
    assert comm.stats.allgather_calls == 0


def test_diffusion_weighted_blocks(geom):
    """Blocks with non-uniform weights (e.g. fluid-cell counts, §3.2)."""
    import random as _r

    forest = make_uniform_forest(geom, 4, level=1)
    rng = _r.Random(0)
    for b in forest.all_blocks():
        b.weight = rng.choice([1.0, 2.0, 3.0])
    comm = Comm(4)
    pipe = AMRPipeline(
        balancer=DiffusionBalancer(mode="pushpull", flow_iterations=5, max_main_iterations=30),
        registry=BlockDataRegistry.trivial(),
        weight_fn=lambda old, kind, nb: old.weight,
    )
    forest, _ = pipe.run_cycle(forest, comm, None, force_rebalance=True)
    forest.check_all()
    loads = forest.weights_per_rank()
    avg = sum(loads) / len(loads)
    assert max(loads) <= avg + 3.0 + 1e-9  # within one max-block granularity


def test_balance_conserves_blocks_and_weights(geom3d):
    forest = make_uniform_forest(geom3d, 8, level=1)
    total_before = forest.num_blocks()
    comm = Comm(8)
    pipe = AMRPipeline(
        balancer=DiffusionBalancer(mode="push", flow_iterations=15, max_main_iterations=20),
        registry=BlockDataRegistry.trivial(),
    )
    forest, _ = pipe.run_cycle(forest, comm, None, force_rebalance=True)
    assert forest.num_blocks() == total_before


def test_diffusion_flow_conservation_deterministic(geom3d):
    """Non-hypothesis twin of the property tests in test_property.py: raw
    Cybenko flows are antisymmetric, and no rank pushes more weight than its
    positive adjusted outflow per level."""
    import random

    nranks = 6
    forest = make_uniform_forest(geom3d, nranks, level=1)
    rng = random.Random(7)
    for b in forest.all_blocks():
        b.weight = rng.choice([1.0, 2.0, 3.0])
    comm = Comm(nranks)
    bal = DiffusionBalancer(mode="push", flow_iterations=10, max_main_iterations=5)
    assignments, _ = bal(forest, comm, 0)
    total = 0.0
    for r in range(nranks):
        for j, flow in bal.last_flows_raw[r].items():
            back = bal.last_flows_raw[j][r]
            for li, f in enumerate(flow):
                assert abs(f + back[li]) < 1e-9, (r, j, li)
                total += f
    assert abs(total) < 1e-9
    for r in range(nranks):
        pushed: dict[int, float] = {}
        for bid in assignments[r]:
            blk = forest.local_blocks(r)[bid]
            pushed[blk.level] = pushed.get(blk.level, 0.0) + blk.weight
        for li, w in pushed.items():
            budget = sum(f[li] for f in bal.last_flows[r].values() if f[li] > 0)
            assert w <= budget + 1e-9, (r, li, w, budget)


# -- data-dependent weights (recompute_weights + particle load model) ---------------


def test_refined_octet_rederives_weights_from_callback(geom3d):
    """Regression: blocks created by refine/coarsen/migrate used to keep the
    construction default ``weight=1.0``. With ``block_weight_fn`` set, an
    octet refined from a weighted parent re-derives its weights from the
    callback (post-migration reevaluation), not from any default."""
    from repro.core import recompute_weights

    forest = make_uniform_forest(geom3d, 2, level=1)
    for b in forest.all_blocks():
        b.data["load"] = 5.0  # data the weight model derives from
    weight_fn = lambda blk: float(blk.data.get("load", 0.0)) or 1.0
    assert recompute_weights(forest, weight_fn) == forest.num_blocks()

    target = min(b.bid for b in forest.all_blocks())
    reg = BlockDataRegistry.trivial("load")
    pipe = AMRPipeline(
        balancer=SFCBalancer(order="morton"),
        registry=reg,
        block_weight_fn=weight_fn,
    )
    comm = Comm(2)
    forest, _ = pipe.run_cycle(
        forest, comm, lambda r, blocks: {target: 2} if target in blocks else {}
    )
    children = [b for b in forest.all_blocks() if b.level == 2]
    assert len(children) == 8
    for b in children:
        # trivial registry's split passes the payload through to every child
        assert b.weight == weight_fn(b) == 5.0, hex(b.bid)


def test_default_proxy_weight_propagates_instead_of_resetting(geom3d):
    """Regression for the latent 1.0-reset: without any weight callback, a
    plain rebalance cycle must leave custom block weights intact."""
    forest = make_uniform_forest(geom3d, 4, level=1)
    for b in forest.all_blocks():
        b.weight = 2.5
    comm = Comm(4)
    pipe = AMRPipeline(
        balancer=DiffusionBalancer(mode="pushpull", flow_iterations=5),
        registry=BlockDataRegistry.trivial(),
    )
    forest, _ = pipe.run_cycle(forest, comm, None, force_rebalance=True)
    assert all(b.weight == 2.5 for b in forest.all_blocks())


def _clustered_particle_forest(geom, nranks, *, seed=5):
    """Uniform level-1 forest with tracers clustered in one domain corner —
    the heterogeneous mesh+particle load regime (Nanda et al. 2025)."""
    from repro.particles import register_particles, seed_particles

    forest = make_uniform_forest(geom, nranks, level=1)
    reg = BlockDataRegistry()
    register_particles(reg, geom)
    seed_particles(
        forest, geom, per_block=40, seed=seed,
        region=((0.0, 0.0, 0.0), (1.0, 1.0, 1.0)),
    )
    return forest, reg


def test_diffusion_reduces_weighted_imbalance_on_particle_cluster(geom3d):
    """Deterministic twin of the hypothesis property: with the
    cells + alpha*N load model on a particle-clustered forest, diffusion
    balancing strictly reduces the max/mean *weighted* load."""
    from repro.particles import particle_block_weight, particle_proxy_weight

    nranks = 8
    cells, alpha = (4, 4, 4), 2.0
    forest, reg = _clustered_particle_forest(geom3d, nranks)
    bw = particle_block_weight(cells, alpha)
    from repro.core import recompute_weights

    recompute_weights(forest, bw)

    def imbalance(f):
        loads = f.weights_per_rank()
        return max(loads) / (sum(loads) / len(loads))

    before = imbalance(forest)
    assert before > 1.3, "the cluster must create a genuine imbalance"
    comm = Comm(nranks)
    pipe = AMRPipeline(
        balancer=DiffusionBalancer(mode="pushpull", flow_iterations=5,
                                   max_main_iterations=30),
        registry=reg,
        weight_fn=particle_proxy_weight(geom3d, cells, alpha),
        block_weight_fn=bw,
    )
    forest, report = pipe.run_cycle(forest, comm, None, force_rebalance=True)
    forest.check_all()
    after = imbalance(forest)
    assert after < before, (before, after)
    assert after < 1.0 + 0.6 * (before - 1.0), (before, after)


def test_particle_conservation_through_advect_redistribute_amr(geom3d):
    """Deterministic twin of the hypothesis property in test_property.py:
    displace (stand-in advection) -> redistribute -> refine/coarsen/migrate
    conserves the particle population exactly, and every particle ends up
    inside its block."""
    import numpy as np

    from repro.particles import all_particles, block_box, redistribute_particles

    nranks = 5
    forest, reg = _clustered_particle_forest(geom3d, nranks)
    before = all_particles(forest)
    rng_np = np.random.default_rng(11)
    for b in forest.all_blocks():
        p = b.data["particles"]
        p["pos"][...] += rng_np.normal(scale=0.05, size=p["pos"].shape)
    comm = Comm(nranks)
    moved, _ = redistribute_particles(forest, geom3d, comm, boundary="reflect")
    assert moved > 0
    pipe = AMRPipeline(
        balancer=DiffusionBalancer(mode="pushpull", flow_iterations=5),
        registry=reg,
    )
    forest, _ = pipe.run_cycle(forest, comm, make_random_marks(4))
    forest.check_all()
    after = all_particles(forest)
    np.testing.assert_array_equal(before["id"], after["id"])
    for b in forest.all_blocks():
        lo, hi = block_box(geom3d, b.bid)
        p = b.data["particles"]
        assert np.all((p["pos"] >= lo) & (p["pos"] < hi)), hex(b.bid)
