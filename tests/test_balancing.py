"""SFC (§2.4.1) and diffusion (§2.4.2) load balancing."""

import math

import pytest

from repro.core import (
    AMRPipeline,
    BlockDataRegistry,
    Comm,
    DiffusionBalancer,
    SFCBalancer,
    make_uniform_forest,
)

from conftest import make_random_marks


def _run(geom, nranks, balancer, seed=0, level=1):
    forest = make_uniform_forest(geom, nranks, level=level)
    comm = Comm(nranks)
    pipe = AMRPipeline(balancer=balancer, registry=BlockDataRegistry.trivial())
    forest, report = pipe.run_cycle(forest, comm, make_random_marks(seed))
    forest.check_all()
    return forest, comm, report


def _perfect_per_level(forest, nranks, slack=0):
    """slack=0: exact ceiling (SFC). slack=1: the diffusion scheme's
    granularity band (paper: 'may not always achieve a perfect global
    balance ... quickly eliminate processes with high load')."""
    for lvl in forest.levels_in_use():
        counts = forest.blocks_per_rank(lvl)
        assert max(counts) <= math.ceil(sum(counts) / nranks) + slack, (lvl, counts)


@pytest.mark.parametrize("order", ["morton", "hilbert"])
def test_sfc_balancer_perfect_per_level(geom3d, order):
    forest, comm, _ = _run(geom3d, 8, SFCBalancer(order=order, per_level=True))
    _perfect_per_level(forest, 8)


def test_sfc_allgather_cost_scales_with_ranks():
    """Table 1 / §2.4.1: per-rank held bytes grow Θ(N) for SFC balancing
    under WEAK scaling (blocks per rank constant, like the paper's §5.1.1)."""
    from repro.core import ForestGeometry

    held = {}
    for nranks, roots in ((4, (2, 2, 1)), (16, (4, 4, 1))):
        geom = ForestGeometry(root_grid=roots, max_level=8)
        _f, comm, _ = _run(geom, nranks, SFCBalancer(per_level=True), seed=1)
        held[nranks] = comm.stats.collective_bytes_per_rank
    assert held[16] > held[4] * 2.5


@pytest.mark.parametrize("mode,flows,slack", [("push", 15, 2), ("pushpull", 5, 1)])
def test_diffusion_balancer_converges(geom3d, mode, flows, slack):
    # paper §2.4.2: push-only with too few flow iterations "does not always
    # result in perfect balance"; the strict-descent handshake additionally
    # freezes unit-slope plateaus, so push-only gets a 2-block band while
    # alternating push/pull reaches within one block of the ceiling.
    bal = DiffusionBalancer(mode=mode, flow_iterations=flows, max_main_iterations=30)
    forest, comm, report = _run(geom3d, 8, bal)
    _perfect_per_level(forest, 8, slack=slack)
    assert report.main_iterations < 30  # early termination fired


def test_diffusion_is_allgather_free(geom3d):
    bal = DiffusionBalancer(mode="pushpull", flow_iterations=5, max_main_iterations=20)
    _f, comm, _ = _run(geom3d, 8, bal)
    assert comm.stats.allgather_calls == 0


def test_diffusion_weighted_blocks(geom):
    """Blocks with non-uniform weights (e.g. fluid-cell counts, §3.2)."""
    import random as _r

    forest = make_uniform_forest(geom, 4, level=1)
    rng = _r.Random(0)
    for b in forest.all_blocks():
        b.weight = rng.choice([1.0, 2.0, 3.0])
    comm = Comm(4)
    pipe = AMRPipeline(
        balancer=DiffusionBalancer(mode="pushpull", flow_iterations=5, max_main_iterations=30),
        registry=BlockDataRegistry.trivial(),
        weight_fn=lambda old, kind, nb: old.weight,
    )
    forest, _ = pipe.run_cycle(forest, comm, None, force_rebalance=True)
    forest.check_all()
    loads = forest.weights_per_rank()
    avg = sum(loads) / len(loads)
    assert max(loads) <= avg + 3.0 + 1e-9  # within one max-block granularity


def test_balance_conserves_blocks_and_weights(geom3d):
    forest = make_uniform_forest(geom3d, 8, level=1)
    total_before = forest.num_blocks()
    comm = Comm(8)
    pipe = AMRPipeline(
        balancer=DiffusionBalancer(mode="push", flow_iterations=15, max_main_iterations=20),
        registry=BlockDataRegistry.trivial(),
    )
    forest, _ = pipe.run_cycle(forest, comm, None, force_rebalance=True)
    assert forest.num_blocks() == total_before


def test_diffusion_flow_conservation_deterministic(geom3d):
    """Non-hypothesis twin of the property tests in test_property.py: raw
    Cybenko flows are antisymmetric, and no rank pushes more weight than its
    positive adjusted outflow per level."""
    import random

    nranks = 6
    forest = make_uniform_forest(geom3d, nranks, level=1)
    rng = random.Random(7)
    for b in forest.all_blocks():
        b.weight = rng.choice([1.0, 2.0, 3.0])
    comm = Comm(nranks)
    bal = DiffusionBalancer(mode="push", flow_iterations=10, max_main_iterations=5)
    assignments, _ = bal(forest, comm, 0)
    total = 0.0
    for r in range(nranks):
        for j, flow in bal.last_flows_raw[r].items():
            back = bal.last_flows_raw[j][r]
            for li, f in enumerate(flow):
                assert abs(f + back[li]) < 1e-9, (r, j, li)
                total += f
    assert abs(total) < 1e-9
    for r in range(nranks):
        pushed: dict[int, float] = {}
        for bid in assignments[r]:
            blk = forest.local_blocks(r)[bid]
            pushed[blk.level] = pushed.get(blk.level, 0.0) + blk.weight
        for li, w in pushed.items():
            budget = sum(f[li] for f in bal.last_flows[r].values() if f[li] > 0)
            assert w <= budget + 1e-9, (r, li, w, budget)
