"""Typed field API (FieldSpec/FieldRegistry) and LevelArena data plane."""

import math

import numpy as np
import pytest

from repro.core import (
    AMRPipeline,
    Comm,
    DiffusionBalancer,
    FieldRegistry,
    FieldSpec,
    ForestGeometry,
    LevelArena,
    SFCBalancer,
    make_uniform_forest,
)
from repro.core.checkpoint import load_checkpoint, save_checkpoint
from repro.core.resilience import ResilienceManager
from repro.lbm import AMRLBM, LidDrivenCavityConfig
from repro.lbm.grid import LBMBlockSpec, make_lbm_fields


CELLS = (4, 4, 4)


def _density_registry() -> FieldRegistry:
    return FieldRegistry(
        cells=CELLS,
        fields=(FieldSpec("rho", dtype=np.float64, refine="interpolate", coarsen="restrict"),),
    )


def _total_mass(forest, reg: FieldRegistry) -> float:
    """Cell-volume-weighted integral: level-l cells have volume 8^-l."""
    return sum(
        float(reg.interior("rho", b.data["rho"]).sum()) * (8.0 ** -b.level)
        for b in forest.all_blocks()
    )


def test_field_registry_derives_seed_equivalent_callbacks():
    """Split->merge through the derived callbacks is the identity on cell
    averages (the seed's volumetric-copy invariant)."""
    spec = LBMBlockSpec(cells=(8, 8, 8))
    reg = make_lbm_fields(spec)
    item = reg.items["pdf"]
    rng = np.random.default_rng(0)
    pdf = rng.standard_normal(spec.pdf_shape).astype(np.float32)
    parts = {o: item.serialize_split(pdf, None, o) for o in range(8)}
    children = {o: item.deserialize_split(p, None) for o, p in parts.items()}
    coarse = {o: item.serialize_merge(children[o], None) for o in range(8)}
    merged = item.deserialize_merge(coarse, None)
    g = spec.ghost
    np.testing.assert_allclose(
        merged[:, g:-g, g:-g, g:-g], pdf[:, g:-g, g:-g, g:-g], rtol=1e-6
    )
    # mask: inject/max must round-trip categorical data exactly
    mi = reg.items["mask"]
    mask = rng.integers(0, 3, spec.mask_shape).astype(np.int32)
    child = mi.deserialize_split(mi.serialize_split(mask, None, 3), None)
    assert child.dtype == np.int32
    back = mi.deserialize_merge(
        {o: mi.serialize_merge(mi.deserialize_split(mi.serialize_split(mask, None, o), None), None)
         for o in range(8)},
        None,
    )
    np.testing.assert_array_equal(back[1:-1, 1:-1, 1:-1], mask[1:-1, 1:-1, 1:-1])


@pytest.mark.parametrize(
    "balancer",
    [SFCBalancer(order="hilbert"), DiffusionBalancer(mode="pushpull", flow_iterations=5)],
)
def test_migrate_data_conserves_mass_for_interpolate_restrict_pair(balancer):
    """Split->merge roundtrip through migrate_data conserves total mass."""
    geom = ForestGeometry(root_grid=(2, 2, 2), max_level=8)
    reg = _density_registry()
    nranks = 4
    forest = make_uniform_forest(geom, nranks, level=1)
    rng = np.random.default_rng(7)
    for b in forest.all_blocks():
        arr = reg.alloc("rho")
        arr[...] = rng.random(arr.shape)
        b.data["rho"] = arr
    mass0 = _total_mass(forest, reg)
    comm = Comm(nranks)
    pipe = AMRPipeline(balancer=balancer, registry=reg)
    # refine everything (split), then coarsen everything (merge): the full
    # interpolate -> restrict roundtrip across the migration machinery
    forest, _ = pipe.run_cycle(
        forest, comm, lambda r, blocks: {bid: blk.level + 1 for bid, blk in blocks.items()}
    )
    forest.check_all()
    assert abs(_total_mass(forest, reg) - mass0) < 1e-9 * abs(mass0)
    forest, _ = pipe.run_cycle(
        forest, comm, lambda r, blocks: {bid: blk.level - 1 for bid, blk in blocks.items()}
    )
    forest.check_all()
    assert abs(_total_mass(forest, reg) - mass0) < 1e-9 * abs(mass0)


def test_arena_views_and_slots_follow_topology():
    geom = ForestGeometry(root_grid=(2, 2, 1), max_level=8)
    reg = _density_registry()
    forest = make_uniform_forest(geom, 3, level=1)
    for b in forest.all_blocks():
        b.data["rho"] = np.full(reg.block_shape("rho"), float(b.bid % 97))
    arena = LevelArena(reg)
    arena.adopt(forest)
    arena.check_consistent(forest)
    # views alias the SoA buffer: writing through a block mutates the buffer
    blk = next(forest.all_blocks())
    slot = arena.slot_of(blk.level, blk.bid)
    blk.data["rho"][...] = -5.0
    assert float(arena.buffer(blk.level, "rho")[slot].max()) == -5.0
    # per-block values survived the packing
    for b in forest.all_blocks():
        if b is not blk:
            assert float(b.data["rho"][0, 0, 0]) == float(b.bid % 97)
    # re-adopt with unchanged topology reuses the same buffers
    buf_before = arena.buffer(blk.level, "rho")
    arena.adopt(forest)
    assert arena.buffer(blk.level, "rho") is buf_before
    arena.check_consistent(forest)


def test_arena_slots_consistent_after_amr_cycle():
    """check_all + per-field slot audit after a full AMR/LBM cycle."""
    cfg = LidDrivenCavityConfig(
        root_grid=(2, 2, 2),
        cells_per_block=(8, 8, 8),
        nranks=4,
        omega=1.5,
        u_lid=(0.08, 0.0, 0.0),
        max_level=1,
        refine_upper=0.03,
        refine_lower=0.004,
    )
    sim = AMRLBM(cfg)
    sim.arena.check_consistent(sim.forest)
    sim.advance(2)
    report = sim.adapt()
    assert report.executed
    sim.forest.check_all()
    sim.arena.check_consistent(sim.forest)
    assert set(sim.arena.levels()) == set(sim.forest.levels_in_use())


def test_arena_stepping_matches_restack_baseline():
    """Both stepping modes must produce identical physics."""
    sims = {}
    for mode in ("arena", "restack"):
        cfg = LidDrivenCavityConfig(
            root_grid=(2, 1, 1),
            cells_per_block=(8, 8, 8),
            nranks=2,
            omega=1.5,
            u_lid=(0.06, 0.0, 0.0),
            max_level=1,
            stepping_mode=mode,
            kernel_backend="ref",
        )
        sim = AMRLBM(cfg)
        sim.advance(2)
        sim.adapt()
        sim.advance(1)
        sims[mode] = {b.bid: np.array(b.data["pdf"]) for b in sim.forest.all_blocks()}
    assert sims["arena"].keys() == sims["restack"].keys()
    for bid, pdf in sims["arena"].items():
        np.testing.assert_allclose(pdf, sims["restack"][bid], rtol=1e-6, atol=1e-7)


def test_checkpoint_and_resilience_through_field_registry(tmp_path):
    """Typed registry drives checkpoint encode/decode and buddy restore."""
    geom = ForestGeometry(root_grid=(2, 2, 2), max_level=8)
    reg = _density_registry()
    forest = make_uniform_forest(geom, 8, level=1)
    for b in forest.all_blocks():
        arr = reg.alloc("rho")
        arr[...] = float(b.bid % 1000)
        b.data["rho"] = arr
    # disk checkpoint onto a different rank count
    save_checkpoint(forest, reg, tmp_path)
    restored = load_checkpoint(tmp_path, reg, nranks=3)
    restored.check_all()
    for b in restored.all_blocks():
        assert b.data["rho"].dtype == np.float64
        assert float(b.data["rho"][1, 1, 1]) == float(b.bid % 1000)
    # buddy resilience restore
    pipe = AMRPipeline(
        balancer=DiffusionBalancer(mode="pushpull", flow_iterations=5, max_main_iterations=20),
        registry=reg,
    )
    mgr = ResilienceManager(reg)
    mgr.snapshot(forest, Comm(8))
    restored2, _comm = mgr.fail_and_restore(forest, failed={1, 6}, pipeline=pipe)
    restored2.check_all()
    assert restored2.num_blocks() == forest.num_blocks()
    for b in restored2.all_blocks():
        assert float(b.data["rho"][1, 1, 1]) == float(b.bid % 1000)


def test_buddy_snapshot_survives_in_place_arena_stepping():
    """Snapshots must not alias arena buffers: in-place stepping after a
    snapshot must not change what fail_and_restore brings back."""
    cfg = LidDrivenCavityConfig(
        root_grid=(2, 1, 1),
        cells_per_block=(8, 8, 8),
        nranks=2,
        omega=1.5,
        u_lid=(0.06, 0.0, 0.0),
        max_level=1,
        kernel_backend="ref",
    )
    sim = AMRLBM(cfg)
    sim.advance(1)
    mgr = ResilienceManager(sim.registry)
    mgr.snapshot(sim.forest, sim.comm)
    at_snapshot = {b.bid: np.array(b.data["pdf"]) for b in sim.forest.all_blocks()}
    sim.advance(2)  # mutates the arena buffers in place
    drifted = {b.bid: np.array(b.data["pdf"]) for b in sim.forest.all_blocks()}
    assert any(not np.array_equal(at_snapshot[bid], drifted[bid]) for bid in at_snapshot)
    restored, _comm = mgr.fail_and_restore(sim.forest, failed={1}, pipeline=sim.pipeline)
    got = {b.bid: b.data["pdf"] for b in restored.all_blocks()}
    assert got.keys() == at_snapshot.keys()
    for bid, pdf in got.items():
        np.testing.assert_array_equal(pdf, at_snapshot[bid])
        # restored state owns its memory: stepping it must not touch the
        # snapshot (so a second restore from the same snapshot stays valid)
        for snap in mgr.snapshots:
            for _meta, payload in list(snap.own.values()) + list(snap.buddy.values()):
                assert not np.shares_memory(pdf, payload["pdf"])


def test_copy_policy_passes_payload_opaque():
    reg = FieldRegistry(
        cells=CELLS,
        fields=(FieldSpec("meta", dtype=np.float32, shape=(2,), refine="copy", coarsen="copy"),),
    )
    item = reg.items["meta"]
    d = np.arange(2 * 6 * 6 * 6, dtype=np.float32).reshape(reg.block_shape("meta"))
    child = item.deserialize_split(item.serialize_split(d, None, 5), None)
    np.testing.assert_array_equal(child, d)
    assert child is not d  # children must not alias the parent
    merged = item.deserialize_merge({o: item.serialize_merge(d, None) for o in range(8)}, None)
    np.testing.assert_array_equal(merged, d)


def test_ghost_zero_field_splits_and_merges():
    """A field without halo (ghost=0) must go through the derived callbacks."""
    reg = FieldRegistry(
        cells=CELLS,
        fields=(FieldSpec("t", dtype=np.float64, ghost=0, refine="interpolate", coarsen="restrict"),),
    )
    item = reg.items["t"]
    rng = np.random.default_rng(2)
    d = rng.random(reg.block_shape("t"))
    assert d.shape == CELLS  # no ghost padding
    np.testing.assert_array_equal(reg.interior("t", d), d)
    children = {
        o: item.deserialize_split(item.serialize_split(d, None, o), None) for o in range(8)
    }
    merged = item.deserialize_merge(
        {o: item.serialize_merge(c, None) for o, c in children.items()}, None
    )
    np.testing.assert_allclose(merged, d, rtol=1e-12)


def test_field_registry_rejects_duplicate_and_validates_decode():
    reg = _density_registry()
    with pytest.raises(AssertionError):
        reg.add(FieldSpec("rho"))
    bad = {"rho": np.zeros((2, 2, 2))}
    with pytest.raises(ValueError, match="payload shape"):
        reg.decode_block(bad, None)
