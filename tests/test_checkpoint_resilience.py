"""Checkpoint/restart (§4.1) and buddy-snapshot resilience (§4.2)."""

import numpy as np
import pytest

from repro.core import (
    AMRPipeline,
    BlockDataRegistry,
    Comm,
    DiffusionBalancer,
    make_uniform_forest,
)
from repro.core.checkpoint import load_checkpoint, save_checkpoint
from repro.core.resilience import ResilienceManager


def _forest_with_payload(geom, nranks):
    forest = make_uniform_forest(geom, nranks, level=1)
    for b in forest.all_blocks():
        b.data["payload"] = np.full((3,), float(b.bid % 1000))
    return forest


def test_checkpoint_roundtrip_same_ranks(geom, tmp_path):
    reg = BlockDataRegistry.trivial()
    forest = _forest_with_payload(geom, 4)
    save_checkpoint(forest, reg, tmp_path)
    restored = load_checkpoint(tmp_path, reg, nranks=4)
    restored.check_all()
    assert restored.num_blocks() == forest.num_blocks()
    for b in restored.all_blocks():
        assert float(b.data["payload"][0]) == float(b.bid % 1000)


@pytest.mark.parametrize("new_ranks", [2, 7])
def test_checkpoint_restart_on_different_rank_count(geom, tmp_path, new_ranks):
    reg = BlockDataRegistry.trivial()
    forest = _forest_with_payload(geom, 4)
    save_checkpoint(forest, reg, tmp_path)
    restored = load_checkpoint(tmp_path, reg, nranks=new_ranks)
    restored.check_all()
    assert restored.num_blocks() == forest.num_blocks()
    counts = restored.blocks_per_rank()
    assert max(counts) - min(counts) <= max(2, forest.num_blocks() // new_ranks)


def test_resilience_restores_after_failures(geom):
    reg = BlockDataRegistry.trivial()
    forest = _forest_with_payload(geom, 8)
    n_blocks = forest.num_blocks()
    pipe = AMRPipeline(
        balancer=DiffusionBalancer(mode="pushpull", flow_iterations=5, max_main_iterations=20),
        registry=reg,
    )
    comm = Comm(8)
    mgr = ResilienceManager(reg)
    mgr.snapshot(forest, comm)
    restored, comm2 = mgr.fail_and_restore(forest, failed={1, 2, 7}, pipeline=pipe)
    restored.check_all()
    assert restored.nranks == 5
    assert restored.num_blocks() == n_blocks
    for b in restored.all_blocks():
        assert float(b.data["payload"][0]) == float(b.bid % 1000)


def test_resilience_rejects_buddy_pair_failure(geom):
    reg = BlockDataRegistry.trivial()
    forest = _forest_with_payload(geom, 8)
    pipe = AMRPipeline(balancer=DiffusionBalancer(), registry=reg)
    mgr = ResilienceManager(reg)
    mgr.snapshot(forest, Comm(8))
    with pytest.raises(AssertionError, match="buddy pair"):
        mgr.fail_and_restore(forest, failed={2, 6}, pipeline=pipe)  # 6 = buddy of 2
