"""Conformance suite for the Lagrangian particle subsystem.

Pins the invariants that make :mod:`repro.particles` a faithful meshless
layer on the block forest:

* **storage** — refinement routes every particle to the child octant owning
  its position, coarsening concatenates the octet; particle count and id set
  are conserved through any AMR cycle;
* **distributed conformance** — sharded advection at 1/4/13 simulated ranks
  reproduces the single-rank restack reference positions + ids within 1e-10
  (bitwise in practice: fixed-order interpolation arithmetic) across an AMR
  event *and* a forced load-balancing cycle, with the population exactly
  conserved;
* **persistence** — checkpoint/restart (including onto a different rank
  count) and buddy resilience round-trip particle state bitwise, via the
  registry codec the §2.5 callbacks derive;
* **accounting** — cross-rank particle traffic is pure batched p2p with
  exact (ragged-honest) byte counts.
"""

import numpy as np
import pytest

from repro.core import BlockDataRegistry, Comm, ForestGeometry, make_uniform_forest
from repro.lbm import AMRLBM, LidDrivenCavityConfig
from repro.particles import (
    ParticlesConfig,
    all_particles,
    apply_domain_boundary,
    block_box,
    empty_particles,
    find_leaf,
    num_particles,
    particles_nbytes,
    register_particles,
    seed_particles,
    total_particles,
)
from repro.core.migration import payload_nbytes

COARSE_STEPS = 8
AMR_INTERVAL = 4

# tracers clustered under the lid, where the flow is fastest — exercises both
# the heterogeneous load model and genuine cross-block redistribution
BASE = dict(
    root_grid=(2, 2, 2),
    cells_per_block=(8, 8, 8),
    omega=1.5,
    u_lid=(0.08, 0.0, 0.0),
    max_level=1,
    refine_upper=0.03,
    refine_lower=0.004,
    kernel_backend="ref",
    particles=ParticlesConfig(
        per_block=24,
        seed=1,
        alpha=0.05,
        region=((0.0, 0.0, 1.7), (2.0, 2.0, 2.0)),
    ),
)


def _run(mode: str, nranks: int) -> AMRLBM:
    """AMR events at steps 4/8, then a forced load-balancing cycle and one
    more coarse step — the acceptance scenario."""
    sim = AMRLBM(LidDrivenCavityConfig(nranks=nranks, stepping_mode=mode, **BASE))
    n0 = sim.total_particles()
    assert n0 > 0
    sim.run(COARSE_STEPS, amr_interval=AMR_INTERVAL)
    sim.adapt(force_rebalance=True)
    sim.advance(1)
    assert sim.total_particles() == n0, "particle count must be exactly conserved"
    return sim


@pytest.fixture(scope="module")
def reference() -> AMRLBM:
    return _run("restack", 1)


# -- distributed conformance -------------------------------------------------------


@pytest.mark.parametrize(
    "nranks", [1, 4, pytest.param(13, marks=pytest.mark.slow)]
)
def test_sharded_particles_match_single_rank_reference(reference, nranks):
    sim = _run("sharded", nranks)
    assert sim.amr_cycles >= 1, "the run must span at least one AMR event"
    ref = all_particles(reference.forest)
    got = all_particles(sim.forest)
    np.testing.assert_array_equal(got["id"], ref["id"])
    np.testing.assert_allclose(got["pos"], ref["pos"], rtol=0, atol=1e-10)
    np.testing.assert_allclose(got["vel"], ref["vel"], rtol=0, atol=1e-10)


@pytest.mark.parametrize(
    "mode, nranks",
    [("arena", 1), ("fused", 1), ("fused_sharded", 1), ("fused_sharded", 4)],
)
def test_host_and_device_modes_match_reference(reference, mode, nranks):
    sim = _run(mode, nranks)
    ref = all_particles(reference.forest)
    got = all_particles(sim.forest)
    np.testing.assert_array_equal(got["id"], ref["id"])
    np.testing.assert_allclose(got["pos"], ref["pos"], rtol=0, atol=1e-10)


def test_redistribution_is_exercised_and_batched_p2p(reference):
    """The reference run actually moves tracers across blocks; at 13 ranks
    some of those moves cross rank boundaries as batched p2p messages with
    collective-free accounting."""
    assert reference.particles_moved > 0
    sim = _run("sharded", 13)
    assert sim.particles_moved == reference.particles_moved
    st = sim.data_stats["particles"]
    assert st.p2p_bytes > 0 and st.p2p_messages > 0
    assert st.collective_bytes_per_rank == 0
    # every particle sits inside its block after redistribution
    for b in sim.forest.all_blocks():
        lo, hi = block_box(sim.geom, b.bid)
        p = b.data["particles"]
        assert np.all((p["pos"] >= lo) & (p["pos"] < hi)), hex(b.bid)


# -- storage: split/merge routing ---------------------------------------------------


def _make_particle_forest(geom, nranks, per_block=6, seed=3):
    forest = make_uniform_forest(geom, nranks, level=1)
    reg = BlockDataRegistry()
    register_particles(reg, geom)
    seed_particles(forest, geom, per_block=per_block, seed=seed)
    return forest, reg


def test_refine_routes_particles_to_owning_child_octant(geom3d):
    from repro.core import AMRPipeline, SFCBalancer

    forest, reg = _make_particle_forest(geom3d, 2)
    before = all_particles(forest)
    pipe = AMRPipeline(balancer=SFCBalancer(order="morton"), registry=reg)
    comm = Comm(2)
    forest, _ = pipe.run_cycle(
        forest, comm, lambda r, blocks: {bid: b.level + 1 for bid, b in blocks.items()}
    )
    forest.check_all()
    after = all_particles(forest)
    np.testing.assert_array_equal(before["id"], after["id"])
    np.testing.assert_array_equal(before["pos"], after["pos"])
    # routing is exact: every particle's position is inside its (finer) block
    for b in forest.all_blocks():
        lo, hi = block_box(geom3d, b.bid)
        p = b.data["particles"]
        assert np.all((p["pos"] >= lo) & (p["pos"] < hi)), hex(b.bid)


def test_coarsen_concatenates_octet_sorted_by_id(geom3d):
    from repro.core import AMRPipeline, SFCBalancer

    forest, reg = _make_particle_forest(geom3d, 3)
    before = all_particles(forest)
    pipe = AMRPipeline(balancer=SFCBalancer(order="morton"), registry=reg)
    comm = Comm(3)
    forest, _ = pipe.run_cycle(
        forest, comm, lambda r, blocks: {bid: b.level - 1 for bid, b in blocks.items()}
    )
    forest.check_all()
    assert forest.levels_in_use() == [0]
    after = all_particles(forest)
    np.testing.assert_array_equal(before["id"], after["id"])
    np.testing.assert_array_equal(before["pos"], after["pos"])
    for b in forest.all_blocks():
        p = b.data["particles"]
        assert np.all(np.diff(p["id"]) > 0), "per-block sets must be id-sorted"


def test_seeding_is_rank_count_independent(geom3d):
    a = _make_particle_forest(geom3d, 1)[0]
    b = _make_particle_forest(geom3d, 7)[0]
    pa, pb = all_particles(a), all_particles(b)
    np.testing.assert_array_equal(pa["id"], pb["id"])
    np.testing.assert_array_equal(pa["pos"], pb["pos"])


# -- domain boundaries --------------------------------------------------------------


def test_reflecting_boundary_mirrors_and_flips_velocity():
    hi = np.array([2.0, 2.0, 2.0])
    pos = np.array([[-0.1, 1.0, 2.3], [0.5, 0.5, 0.5]])
    vel = np.array([[-1.0, 0.0, 2.0], [1.0, 1.0, 1.0]])
    p, v = apply_domain_boundary(pos, vel, hi, "reflect")
    np.testing.assert_allclose(p[0], [0.1, 1.0, 1.7])
    np.testing.assert_allclose(v[0], [1.0, 0.0, -2.0])
    np.testing.assert_allclose(p[1], pos[1])
    assert np.all(p >= 0.0) and np.all(p < hi)


def test_periodic_boundary_wraps_and_routes_across_the_domain(geom3d):
    forest, _reg = _make_particle_forest(geom3d, 4, per_block=2)
    # push one block's particles just past the domain's upper x face
    blk = max(forest.all_blocks(), key=lambda b: block_box(geom3d, b.bid)[1][0])
    p = blk.data["particles"]
    p["pos"][:, 0] = 2.0 + 1e-3  # outside; wraps to ~0.001
    from repro.particles import redistribute_particles

    comm = Comm(4)
    n0 = total_particles(forest)
    moved, _ = redistribute_particles(forest, geom3d, comm, boundary="periodic")
    assert moved >= 1
    assert total_particles(forest) == n0
    for b in forest.all_blocks():
        lo, hi = block_box(geom3d, b.bid)
        q = b.data["particles"]
        assert np.all((q["pos"] >= lo) & (q["pos"] < hi))


def test_find_leaf_is_the_containment_oracle(geom3d):
    forest = make_uniform_forest(geom3d, 2, level=1)
    leaves = {b.bid: b.owner for b in forest.all_blocks()}
    rng = np.random.default_rng(0)
    for pos in rng.random((32, 3)) * np.array(geom3d.root_grid):
        bid = find_leaf(geom3d, leaves, pos)
        lo, hi = block_box(geom3d, bid)
        assert np.all((pos >= lo) & (pos < hi))
    assert find_leaf(geom3d, leaves, (-0.1, 0.5, 0.5)) is None


# -- persistence --------------------------------------------------------------------


def test_checkpoint_roundtrips_particle_state_bitwise(tmp_path):
    from repro.core.checkpoint import load_checkpoint, save_checkpoint

    sim = AMRLBM(LidDrivenCavityConfig(nranks=4, stepping_mode="arena", **BASE))
    sim.run(4, amr_interval=2)
    sim.materialize_host()
    save_checkpoint(sim.forest, sim.registry, tmp_path / "ckpt")
    for nranks in (None, 3):  # same and different rank counts
        restored = load_checkpoint(tmp_path / "ckpt", sim.registry, nranks=nranks)
        ref = {b.bid: b.data["particles"] for b in sim.forest.all_blocks()}
        got = {b.bid: b.data["particles"] for b in restored.all_blocks()}
        assert set(ref) == set(got)
        for bid in ref:
            for k in ("pos", "vel", "id"):
                np.testing.assert_array_equal(got[bid][k], ref[bid][k]), (bid, k)


def test_resilience_snapshot_restores_particles():
    from repro.core.resilience import ResilienceManager

    sim = AMRLBM(LidDrivenCavityConfig(nranks=4, stepping_mode="arena", **BASE))
    sim.advance(2)
    before = all_particles(sim.forest)
    mgr = ResilienceManager(sim.registry)
    mgr.snapshot(sim.forest, sim.comm)
    restored, _comm = mgr.fail_and_restore(sim.forest, {1}, sim.pipeline)
    after = all_particles(restored)
    np.testing.assert_array_equal(before["id"], after["id"])
    np.testing.assert_array_equal(before["pos"], after["pos"])


# -- accounting ---------------------------------------------------------------------


def test_particle_payload_bytes_are_exact():
    """Ragged SoA payloads size to the exact sum of their array bytes plus
    wire keys — the Table-1 honesty requirement for particle migration."""
    p = {
        "pos": np.zeros((7, 3), np.float64),
        "vel": np.zeros((7, 3), np.float64),
        "id": np.zeros(7, np.int64),
    }
    keys = sum(len(k) for k in p)
    assert particles_nbytes(p) == 7 * (24 + 24 + 8)
    assert payload_nbytes(p) == particles_nbytes(p) + keys
    assert payload_nbytes(empty_particles()) == keys


def test_weight_hook_tracks_particle_counts():
    sim = AMRLBM(LidDrivenCavityConfig(nranks=4, stepping_mode="sharded", **BASE))
    ncells = 8 * 8 * 8
    alpha = BASE["particles"].alpha
    for b in sim.forest.all_blocks():
        assert b.weight == ncells + alpha * num_particles(b.data["particles"])
    sim.advance(2)
    sim.adapt(force_rebalance=True)
    # weights re-derived from actual post-cycle data, never the 1.0 default
    for b in sim.forest.all_blocks():
        assert b.weight == ncells + alpha * num_particles(b.data["particles"])
