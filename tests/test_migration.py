"""Data migration with the six serialization callbacks (§2.5)."""

import numpy as np
import pytest

from repro.core import (
    AMRPipeline,
    BlockDataItem,
    BlockDataRegistry,
    Comm,
    DiffusionBalancer,
    SFCBalancer,
    make_uniform_forest,
)
from repro.lbm.grid import LBMBlockSpec, make_lbm_registry

from conftest import make_random_marks


def _counting_registry():
    """Registry that tracks which callbacks ran, for a scalar 'mass' field
    whose total must be conserved by split (divide by 8) and merge (sum)."""
    calls = {"move": 0, "split": 0, "merge": 0}

    reg = BlockDataRegistry()
    reg.register(
        "mass",
        BlockDataItem(
            serialize_move=lambda d, b: (calls.__setitem__("move", calls["move"] + 1), d)[1],
            deserialize_move=lambda p, b: p,
            serialize_split=lambda d, b, o: (calls.__setitem__("split", calls["split"] + 1), d / 8.0)[1],
            deserialize_split=lambda p, b: p,
            serialize_merge=lambda d, b: (calls.__setitem__("merge", calls["merge"] + 1), d)[1],
            deserialize_merge=lambda parts, b: sum(parts.values()),
        ),
    )
    return reg, calls


@pytest.mark.parametrize("balancer", [SFCBalancer(), DiffusionBalancer(mode="pushpull", flow_iterations=5)])
def test_mass_conservation_through_cycles(geom3d, balancer):
    reg, calls = _counting_registry()
    forest = make_uniform_forest(geom3d, 4, level=1)
    for b in forest.all_blocks():
        b.data["mass"] = 1.0
    total0 = sum(b.data["mass"] for b in forest.all_blocks())
    comm = Comm(4)
    pipe = AMRPipeline(balancer=balancer, registry=reg)
    # random refines, then coarsen-everything (guarantees complete sibling
    # groups so the merge path is actually exercised), then random again
    marks = [
        make_random_marks(0, p_refine=0.4, p_coarsen=0.0),
        lambda r, blocks: {bid: blk.level - 1 for bid, blk in blocks.items()},
        make_random_marks(1),
    ]
    for mark in marks:
        forest, _ = pipe.run_cycle(forest, comm, mark)
        forest.check_all()
        total = sum(b.data["mass"] for b in forest.all_blocks())
        assert abs(total - total0) < 1e-9
    assert calls["split"] > 0 and calls["merge"] > 0


def test_lbm_registry_split_merge_roundtrip():
    """Volumetric split followed by merge must reproduce the coarse PDFs."""
    spec = LBMBlockSpec(cells=(8, 8, 8))
    reg = make_lbm_registry(spec)
    item = reg.items["pdf"]
    rng = np.random.default_rng(0)
    pdf = rng.standard_normal(spec.pdf_shape).astype(np.float32)

    parts = {o: item.serialize_split(pdf, None, o) for o in range(8)}
    children = {o: item.deserialize_split(p, None) for o, p in parts.items()}
    # now coarsen children back and reassemble
    coarse_parts = {o: item.serialize_merge(children[o], None) for o in range(8)}
    merged = item.deserialize_merge(coarse_parts, None)
    g = spec.ghost
    np.testing.assert_allclose(
        merged[:, g:-g, g:-g, g:-g], pdf[:, g:-g, g:-g, g:-g], rtol=1e-6
    )


def test_lbm_registry_mass_conserving_split():
    spec = LBMBlockSpec(cells=(8, 8, 8))
    reg = make_lbm_registry(spec)
    item = reg.items["pdf"]
    pdf = np.random.default_rng(1).random(spec.pdf_shape).astype(np.float32)
    g = spec.ghost
    coarse_mass = pdf[:, g:-g, g:-g, g:-g].sum()
    fine_mass = 0.0
    for o in range(8):
        child = item.deserialize_split(item.serialize_split(pdf, None, o), None)
        # each fine cell has 1/8 the volume of a coarse cell
        fine_mass += child[:, g:-g, g:-g, g:-g].sum() / 8.0
    np.testing.assert_allclose(fine_mass, coarse_mass, rtol=1e-5)


def test_migration_moves_data_to_new_owner(geom):
    reg = BlockDataRegistry.trivial()
    forest = make_uniform_forest(geom, 2, level=1)
    for b in forest.all_blocks():
        b.data["payload"] = b.bid
    comm = Comm(2)
    pipe = AMRPipeline(
        balancer=DiffusionBalancer(mode="push", flow_iterations=15), registry=reg
    )
    forest, _ = pipe.run_cycle(forest, comm, None, force_rebalance=True)
    for b in forest.all_blocks():
        assert b.data["payload"] == b.bid  # payloads follow their blocks


# -- payload byte accounting --------------------------------------------------------


def test_payload_nbytes_sizes_ragged_dicts_exactly():
    """Regression: dict-of-ndarray (particle-style SoA) payloads must size to
    the exact sum of array bytes plus wire keys — previously dict keys were
    dropped and unknown leaf types fell through to a flat pickled guess."""
    from repro.core.migration import payload_nbytes

    pos = np.zeros((5, 3), np.float64)
    ids = np.arange(5, dtype=np.int64)
    ragged = {"pos": pos, "id": ids}
    assert payload_nbytes(ragged) == pos.nbytes + ids.nbytes + len("pos") + len("id")
    # nested ragged containers recurse exactly
    nested = [ragged, {"pos": np.zeros((2, 3), np.float32)}]
    assert payload_nbytes(nested) == payload_nbytes(ragged) + 2 * 3 * 4 + 3
    assert payload_nbytes({}) == 0 and payload_nbytes(None) == 0


def test_payload_nbytes_scalar_conventions():
    from repro.core.migration import payload_nbytes

    assert payload_nbytes(np.float32(1.0)) == 4  # numpy scalar: itemsize
    assert payload_nbytes(np.int64(1)) == 8
    assert payload_nbytes(True) == 1
    assert payload_nbytes(3) == 8 and payload_nbytes(3.5) == 8
    assert payload_nbytes("abcd") == 4
    assert payload_nbytes(b"xyz") == 3
    assert payload_nbytes((np.zeros(4, np.int32), "ab")) == 16 + 2
