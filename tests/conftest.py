import random

import pytest

from repro.core import Comm, ForestGeometry, make_uniform_forest


@pytest.fixture
def geom():
    return ForestGeometry(root_grid=(2, 2, 1), max_level=8)


@pytest.fixture
def geom3d():
    return ForestGeometry(root_grid=(2, 2, 2), max_level=8)


def make_random_marks(seed: int, p_refine: float = 0.3, p_coarsen: float = 0.3):
    rng = random.Random(seed)

    def mark(rank, blocks):
        out = {}
        for bid, blk in blocks.items():
            x = rng.random()
            if x < p_refine:
                out[bid] = blk.level + 1
            elif x < p_refine + p_coarsen:
                out[bid] = blk.level - 1
        return out

    return mark
