"""Regression: data-plane stage attribution is consistent across all modes.

``AMRLBM.data_stats`` attributes data-plane cost to four stages — ``halo``
(ghost exchange), ``step`` (kernel calls), ``fused`` (device-resident
coarse-step programs, where halo and step are indistinguishable), and
``particles`` (tracer advection + redistribution). This suite pins the
attribution *contract* for the same 8-coarse-step run (particles enabled,
4 simulated ranks, spanning AMR events) in every stepping mode:

* each mode fills exactly its designated stages and leaves the others empty
  (a host mode must never report "fused" work, a device mode must never
  report per-substep "step" work);
* physics-side counters (mass, tracer advection/move counts, particle
  redistribution bytes) are identical across modes — attribution must not
  change what is measured, only where it is filed;
* the p2p bytes the two sharded modes put on the fabric agree exactly:
  host-sharded files everything under "halo", fused_sharded splits the same
  traffic between "fused" (in-program device messages) and "halo" (host-side
  refreshes around AMR events and particle advection).
"""

import pytest

from repro.lbm import AMRLBM, LidDrivenCavityConfig
from repro.particles import ParticlesConfig

MODES = ("restack", "arena", "fused", "sharded", "fused_sharded")
HOST_MODES = ("restack", "arena", "sharded")
DEVICE_MODES = ("fused", "fused_sharded")
COARSE_STEPS = 8

BASE = dict(
    root_grid=(2, 2, 2),
    cells_per_block=(8, 8, 8),
    nranks=4,
    omega=1.5,
    u_lid=(0.08, 0.0, 0.0),
    max_level=1,
    refine_upper=0.03,
    refine_lower=0.004,
    kernel_backend="ref",
    particles=ParticlesConfig(
        per_block=8,
        seed=1,
        alpha=0.05,
        region=((0.0, 0.0, 1.5), (2.0, 2.0, 2.0)),
    ),
)


@pytest.fixture(scope="module")
def runs() -> dict[str, AMRLBM]:
    out = {}
    for mode in MODES:
        sim = AMRLBM(LidDrivenCavityConfig(stepping_mode=mode, **BASE))
        sim.run(COARSE_STEPS, amr_interval=4)
        out[mode] = sim
    return out


def test_all_modes_ran_the_same_simulation(runs):
    ref = runs["restack"]
    assert ref.amr_cycles >= 1 and len(ref.forest.levels_in_use()) > 1
    for mode, sim in runs.items():
        assert sim.coarse_step == COARSE_STEPS, mode
        assert sim.amr_cycles == ref.amr_cycles, mode
        assert abs(sim.total_mass() - ref.total_mass()) < 1e-6, mode
        # attribution must not perturb the physics-side counters
        assert sim.particles_advected == ref.particles_advected, mode
        assert sim.particles_moved == ref.particles_moved, mode
        assert sim.total_particles() == ref.total_particles(), mode


def test_host_modes_fill_halo_and_step_and_never_fused(runs):
    for mode in HOST_MODES:
        st = runs[mode].data_stats
        assert st["halo"].seconds > 0.0, mode
        assert st["step"].seconds > 0.0, mode
        fused = st["fused"]
        assert fused.seconds == 0.0 and fused.p2p_bytes == 0, mode
        assert fused.p2p_messages == 0 and fused.exchange_rounds == 0, mode


def test_device_modes_fill_fused_and_never_step(runs):
    for mode in DEVICE_MODES:
        st = runs[mode].data_stats
        assert st["fused"].seconds > 0.0, mode
        assert st["fused"].exchange_rounds > 0, mode
        step = st["step"]
        assert step.seconds == 0.0 and step.p2p_bytes == 0, mode
        # halo still carries the host-side refreshes around AMR events and
        # the pre-advection ghost refresh — but no per-substep exchange
        assert st["halo"].seconds > 0.0, mode


def test_particles_stage_is_mode_invariant(runs):
    ref = runs["restack"].data_stats["particles"]
    assert ref.seconds > 0.0
    for mode, sim in runs.items():
        st = sim.data_stats["particles"]
        assert st.seconds > 0.0, mode
        # identical redistribution traffic in every mode (same physics, same
        # rank count, same Comm fabric)
        assert st.p2p_bytes == ref.p2p_bytes, mode
        assert st.p2p_messages == ref.p2p_messages, mode
        assert st.collective_bytes_per_rank == 0, mode


def test_only_comm_routed_stages_report_fabric_traffic(runs):
    # non-sharded data planes never touch the Comm fabric for halo traffic
    for mode in ("restack", "arena", "fused"):
        assert runs[mode].data_stats["halo"].p2p_bytes == 0, mode
    assert runs["sharded"].data_stats["halo"].p2p_bytes > 0
    assert runs["fused_sharded"].data_stats["fused"].p2p_bytes > 0


def test_sharded_modes_account_identical_halo_traffic(runs):
    """Host-sharded files all halo traffic under "halo"; fused_sharded files
    the in-program device messages under "fused" and only the host-side
    refreshes under "halo". The totals must agree byte for byte — the
    compiled message buffers are exactly the host patches."""
    sh = runs["sharded"].data_stats
    fs = runs["fused_sharded"].data_stats
    assert sh["halo"].p2p_bytes == fs["fused"].p2p_bytes + fs["halo"].p2p_bytes
    assert (
        sh["halo"].p2p_messages
        == fs["fused"].p2p_messages + fs["halo"].p2p_messages
    )
