"""Hypothesis property tests on the system's invariants."""

import math

import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import HealthCheck, given, settings, strategies as st  # noqa: E402

from repro.core import (
    AMRPipeline,
    BlockDataRegistry,
    Comm,
    DiffusionBalancer,
    ForestGeometry,
    SFCBalancer,
    make_uniform_forest,
)
from repro.core.blockid import hilbert_index_3d

GEOM = ForestGeometry(root_grid=(2, 2, 1), max_level=8)

_slow = settings(
    max_examples=12,
    deadline=None,
    suppress_health_check=[HealthCheck.too_slow],
)


@given(seed=st.integers(0, 10_000), nranks=st.sampled_from([2, 4, 7]))
@_slow
def test_pipeline_preserves_all_invariants(seed, nranks):
    """After any random mark pattern + diffusion rebalance: leaf cover, exact
    symmetric adjacency, 2:1 balance, payload conservation."""
    import random

    forest = make_uniform_forest(GEOM, nranks, level=1)
    n_payload = forest.num_blocks()
    for b in forest.all_blocks():
        b.data["payload"] = 1.0
    comm = Comm(nranks)
    rng = random.Random(seed)

    def mark(rank, blocks):
        out = {}
        for bid, blk in blocks.items():
            x = rng.random()
            if x < 0.4:
                out[bid] = blk.level + 1
            elif x < 0.7:
                out[bid] = blk.level - 1
        return out

    pipe = AMRPipeline(
        balancer=DiffusionBalancer(mode="pushpull", flow_iterations=5, max_main_iterations=20),
        registry=BlockDataRegistry.trivial("payload"),
    )
    forest, _ = pipe.run_cycle(forest, comm, mark)
    forest.check_all()
    for b in forest.all_blocks():
        assert "payload" in b.data


@given(seed=st.integers(0, 10_000))
@_slow
def test_sfc_balancing_is_deterministic_and_perfect(seed):
    import random

    nranks = 4
    forest = make_uniform_forest(GEOM, nranks, level=1)
    comm = Comm(nranks)
    rng = random.Random(seed)

    def mark(rank, blocks):
        return {
            bid: blk.level + 1 for bid, blk in blocks.items() if rng.random() < 0.3
        }

    pipe = AMRPipeline(balancer=SFCBalancer(order="hilbert"), registry=BlockDataRegistry.trivial())
    forest, _ = pipe.run_cycle(forest, comm, mark)
    for lvl in forest.levels_in_use():
        counts = forest.blocks_per_rank(lvl)
        assert max(counts) <= math.ceil(sum(counts) / nranks)


@given(
    nbits=st.integers(1, 4),
    xyz=st.tuples(st.integers(0, 15), st.integers(0, 15), st.integers(0, 15)),
)
@settings(max_examples=60, deadline=None)
def test_hilbert_index_bijective_in_range(nbits, xyz):
    n = 1 << nbits
    x, y, z = (c % n for c in xyz)
    h = hilbert_index_3d(nbits, x, y, z)
    assert 0 <= h < n**3


@given(
    seed=st.integers(0, 10_000),
    nranks=st.sampled_from([3, 5, 8]),
    mode=st.sampled_from(["push", "pull"]),
)
@_slow
def test_diffusion_flow_conservation(seed, nranks, mode):
    """Cybenko flow conservation: the raw per-edge flows are exactly
    antisymmetric (f_ij = -f_ji), so every edge — and hence the whole
    process graph — carries zero net flow."""
    import random

    forest = make_uniform_forest(GEOM, nranks, level=1)
    rng = random.Random(seed)
    for b in forest.all_blocks():
        b.weight = rng.choice([1.0, 2.0, 3.0])
    comm = Comm(nranks)
    bal = DiffusionBalancer(mode=mode, flow_iterations=10, max_main_iterations=5)
    bal(forest, comm, 0)
    raw = bal.last_flows_raw
    assert len(raw) == nranks
    total = 0.0
    for r in range(nranks):
        for j, flow in raw[r].items():
            back = raw[j][r]  # the process graph is symmetric
            for li, f in enumerate(flow):
                assert abs(f + back[li]) < 1e-9, (r, j, li)
                total += f
    assert abs(total) < 1e-9


@given(seed=st.integers(0, 10_000), nranks=st.sampled_from([3, 5, 8]))
@_slow
def test_diffusion_push_never_exceeds_flow(seed, nranks):
    """Pushed block weight is bounded by the computed flow: per main
    iteration, no rank ships more weight (per level) than its positive
    adjusted outflow."""
    import random

    forest = make_uniform_forest(GEOM, nranks, level=1)
    rng = random.Random(seed)
    for b in forest.all_blocks():
        b.weight = rng.choice([1.0, 2.0])
    comm = Comm(nranks)
    bal = DiffusionBalancer(mode="push", flow_iterations=10, max_main_iterations=5)
    assignments, _ = bal(forest, comm, 0)
    adj = bal.last_flows
    for r in range(nranks):
        pushed: dict[int, float] = {}
        for bid in assignments[r]:
            blk = forest.local_blocks(r)[bid]
            pushed[blk.level] = pushed.get(blk.level, 0.0) + blk.weight
        for li, w in pushed.items():
            budget = sum(
                flow[li] for flow in adj[r].values() if flow[li] > 0
            )
            assert w <= budget + 1e-9, (r, li, w, budget)


@given(seed=st.integers(0, 10_000), nranks=st.sampled_from([3, 5, 8]))
@_slow
def test_diffusion_never_loses_blocks(seed, nranks):
    import random

    forest = make_uniform_forest(GEOM, nranks, level=1)
    rng = random.Random(seed)
    for b in forest.all_blocks():
        b.weight = rng.choice([1.0, 2.0])
    total_blocks = forest.num_blocks()
    total_weight = sum(b.weight for b in forest.all_blocks())
    comm = Comm(nranks)
    pipe = AMRPipeline(
        balancer=DiffusionBalancer(mode="push", flow_iterations=15, max_main_iterations=15),
        registry=BlockDataRegistry.trivial(),
        weight_fn=lambda old, kind, nb: old.weight,
    )
    forest, _ = pipe.run_cycle(forest, comm, None, force_rebalance=True)
    assert forest.num_blocks() == total_blocks
    assert abs(sum(b.weight for b in forest.all_blocks()) - total_weight) < 1e-9


@given(
    seed=st.integers(0, 10_000),
    nranks=st.sampled_from([2, 4, 7]),
    boundary=st.sampled_from(["reflect", "periodic"]),
)
@_slow
def test_particle_conservation_through_advect_redistribute_amr(seed, nranks, boundary):
    """Particle-count conservation across displace (stand-in advection) ->
    redistribute -> refine -> coarsen -> migrate: the id set is conserved
    exactly and every particle ends up inside its owning block (deterministic
    twin: test_balancing.py)."""
    import random

    import numpy as np

    from repro.particles import (
        all_particles,
        block_box,
        redistribute_particles,
        register_particles,
        seed_particles,
    )

    forest = make_uniform_forest(GEOM, nranks, level=1)
    reg = BlockDataRegistry()
    register_particles(reg, GEOM)
    seed_particles(forest, GEOM, per_block=5, seed=seed)
    before = all_particles(forest)
    rng_np = np.random.default_rng(seed)
    for b in forest.all_blocks():
        p = b.data["particles"]
        p["pos"][...] += rng_np.normal(scale=0.06, size=p["pos"].shape)
    comm = Comm(nranks)
    redistribute_particles(forest, GEOM, comm, boundary=boundary)
    rng = random.Random(seed)

    def mark(rank, blocks):
        out = {}
        for bid, blk in blocks.items():
            x = rng.random()
            if x < 0.4:
                out[bid] = blk.level + 1
            elif x < 0.7:
                out[bid] = blk.level - 1
        return out

    pipe = AMRPipeline(
        balancer=DiffusionBalancer(mode="pushpull", flow_iterations=5,
                                   max_main_iterations=20),
        registry=reg,
    )
    forest, _ = pipe.run_cycle(forest, comm, mark)
    forest.check_all()
    after = all_particles(forest)
    np.testing.assert_array_equal(before["id"], after["id"])
    for b in forest.all_blocks():
        lo, hi = block_box(GEOM, b.bid)
        p = b.data["particles"]
        assert np.all((p["pos"] >= lo) & (p["pos"] < hi))
