"""Hypothesis property tests on the system's invariants."""

import math

import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import HealthCheck, given, settings, strategies as st  # noqa: E402

from repro.core import (
    AMRPipeline,
    BlockDataRegistry,
    Comm,
    DiffusionBalancer,
    ForestGeometry,
    SFCBalancer,
    make_uniform_forest,
)
from repro.core.blockid import hilbert_index_3d

GEOM = ForestGeometry(root_grid=(2, 2, 1), max_level=8)

_slow = settings(
    max_examples=12,
    deadline=None,
    suppress_health_check=[HealthCheck.too_slow],
)


@given(seed=st.integers(0, 10_000), nranks=st.sampled_from([2, 4, 7]))
@_slow
def test_pipeline_preserves_all_invariants(seed, nranks):
    """After any random mark pattern + diffusion rebalance: leaf cover, exact
    symmetric adjacency, 2:1 balance, payload conservation."""
    import random

    forest = make_uniform_forest(GEOM, nranks, level=1)
    n_payload = forest.num_blocks()
    for b in forest.all_blocks():
        b.data["payload"] = 1.0
    comm = Comm(nranks)
    rng = random.Random(seed)

    def mark(rank, blocks):
        out = {}
        for bid, blk in blocks.items():
            x = rng.random()
            if x < 0.4:
                out[bid] = blk.level + 1
            elif x < 0.7:
                out[bid] = blk.level - 1
        return out

    pipe = AMRPipeline(
        balancer=DiffusionBalancer(mode="pushpull", flow_iterations=5, max_main_iterations=20),
        registry=BlockDataRegistry.trivial("payload"),
    )
    forest, _ = pipe.run_cycle(forest, comm, mark)
    forest.check_all()
    for b in forest.all_blocks():
        assert "payload" in b.data


@given(seed=st.integers(0, 10_000))
@_slow
def test_sfc_balancing_is_deterministic_and_perfect(seed):
    import random

    nranks = 4
    forest = make_uniform_forest(GEOM, nranks, level=1)
    comm = Comm(nranks)
    rng = random.Random(seed)

    def mark(rank, blocks):
        return {
            bid: blk.level + 1 for bid, blk in blocks.items() if rng.random() < 0.3
        }

    pipe = AMRPipeline(balancer=SFCBalancer(order="hilbert"), registry=BlockDataRegistry.trivial())
    forest, _ = pipe.run_cycle(forest, comm, mark)
    for lvl in forest.levels_in_use():
        counts = forest.blocks_per_rank(lvl)
        assert max(counts) <= math.ceil(sum(counts) / nranks)


@given(
    nbits=st.integers(1, 4),
    xyz=st.tuples(st.integers(0, 15), st.integers(0, 15), st.integers(0, 15)),
)
@settings(max_examples=60, deadline=None)
def test_hilbert_index_bijective_in_range(nbits, xyz):
    n = 1 << nbits
    x, y, z = (c % n for c in xyz)
    h = hilbert_index_3d(nbits, x, y, z)
    assert 0 <= h < n**3


@given(seed=st.integers(0, 10_000), nranks=st.sampled_from([3, 5, 8]))
@_slow
def test_diffusion_never_loses_blocks(seed, nranks):
    import random

    forest = make_uniform_forest(GEOM, nranks, level=1)
    rng = random.Random(seed)
    for b in forest.all_blocks():
        b.weight = rng.choice([1.0, 2.0])
    total_blocks = forest.num_blocks()
    total_weight = sum(b.weight for b in forest.all_blocks())
    comm = Comm(nranks)
    pipe = AMRPipeline(
        balancer=DiffusionBalancer(mode="push", flow_iterations=15, max_main_iterations=15),
        registry=BlockDataRegistry.trivial(),
        weight_fn=lambda old, kind, nb: old.weight,
    )
    forest, _ = pipe.run_cycle(forest, comm, None, force_rebalance=True)
    assert forest.num_blocks() == total_blocks
    assert abs(sum(b.weight for b in forest.all_blocks()) - total_weight) < 1e-9
