"""Serving subsystem: ensemble conformance, divergence splits, elastic
resize, and the submit/poll/stream job driver.

Conformance discipline matches tests/test_distributed_conformance.py: the
batched ensemble path must reproduce independent single-run references
(fused device superstep) across an AMR event, and an elastic resize mid-run
must continue bitwise-identically to a fixed-rank reference.
"""

import numpy as np
import pytest

from repro.core.checkpoint import load_checkpoint
from repro.lbm.driver import AMRLBM, LidDrivenCavityConfig
from repro.serving import JobSpec, SimulationService, is_batchable, resize_ranks

BASE = dict(
    root_grid=(2, 2, 2),
    cells_per_block=(8, 8, 8),
    omega=1.5,
    u_lid=(0.08, 0.0, 0.0),
    max_level=1,
    refine_upper=0.03,
    refine_lower=0.004,
    kernel_backend="ref",
)
COARSE_STEPS = 8
AMR_INTERVAL = 4

# four members with different physics; the last (omega=1.9, slow lid) never
# refines, so the batch hits a real divergence split at the AMR event
MEMBERS = [
    dict(omega=1.5, u_lid=(0.08, 0.0, 0.0)),
    dict(omega=1.7, u_lid=(0.06, 0.0, 0.0)),
    dict(omega=1.6, u_lid=(0.08, 0.02, 0.0)),
    dict(omega=1.9, u_lid=(0.05, 0.0, 0.0)),
]


def _cfg(**over) -> LidDrivenCavityConfig:
    return LidDrivenCavityConfig(**{**BASE, **over})


def _assert_same_fields(sim: AMRLBM, ref: AMRLBM, *, atol: float) -> None:
    sim.materialize_host()
    ref.materialize_host()
    key = lambda f: sorted((b.bid, b.level) for b in f.all_blocks())
    assert key(sim.forest) == key(ref.forest), "topologies diverged"
    ref_blocks = {b.bid: b for b in ref.forest.all_blocks()}
    for b in sim.forest.all_blocks():
        rb = ref_blocks[b.bid]
        np.testing.assert_array_equal(b.data["mask"], rb.data["mask"])
        if atol == 0.0:
            np.testing.assert_array_equal(b.data["pdf"], rb.data["pdf"])
        else:
            np.testing.assert_allclose(
                b.data["pdf"], rb.data["pdf"], rtol=0.0, atol=atol
            )


def test_ensemble_matches_independent_references_across_amr():
    """>=4 batched members with different tau / lid velocities match solo
    fused references at 1e-10 across an AMR event, with at most one compile
    per (topology, activity-pattern) key for the whole batch."""
    refs = []
    for over in MEMBERS:
        ref = AMRLBM(_cfg(stepping_mode="fused", **over))
        ref.run(COARSE_STEPS, amr_interval=AMR_INTERVAL)
        refs.append(ref)

    svc = SimulationService()
    ids = [
        svc.submit(
            JobSpec(
                config=_cfg(stepping_mode="arena", **over),
                coarse_steps=COARSE_STEPS,
                amr_interval=AMR_INTERVAL,
            )
        )
        for over in MEMBERS
    ]
    svc.run()

    amr_happened = False
    for jid, ref in zip(ids, refs):
        job = svc.jobs[jid]
        assert job.status == "done" and job.step == COARSE_STEPS
        _assert_same_fields(job.sim, ref, atol=1e-10)
        amr_happened = amr_happened or job.sim.amr_cycles > 0
    assert amr_happened, "the run must cross an AMR event"

    s = svc.summary()
    assert s["jobs_completed"] == len(MEMBERS)
    assert s["ensembles_formed"] == 1
    # omega=1.9 never refines -> one real divergence split at the AMR event
    assert s["divergence_splits"] >= 1
    # compile-amortization contract: one program build per distinct
    # (topology, activity-pattern-set) key for the whole batch — here the
    # uniform level-0 forest plus the refined post-AMR forest — and the
    # post-split groups re-hit the cache instead of recompiling per member
    assert s["compile_misses"] <= 2
    assert s["compile_hits"] >= 1
    # per-job latency/throughput counters are exposed in data_stats["serving"]
    stats = svc.data_stats["serving"]
    for jid in ids:
        rec = stats["jobs"][jid]
        assert rec["status"] == "done"
        assert rec["steps_per_s"] > 0 and rec["latency_s"] > 0
    assert stats["stage"].seconds > 0
    assert stats["compile"]["misses"] == s["compile_misses"]


@pytest.mark.parametrize("nranks", [(4, 2), (2, 6)])
def test_elastic_resize_preserves_physics_bitwise(nranks):
    """Resize mid-run (shrink 4->2 and grow 2->6) continues bitwise-
    identically to the fixed-rank reference."""
    n0, n1 = nranks
    ref = AMRLBM(_cfg(nranks=n0, stepping_mode="sharded"))
    ref.run(COARSE_STEPS, amr_interval=AMR_INTERVAL)

    sim = AMRLBM(_cfg(nranks=n0, stepping_mode="sharded"))
    sim.run(AMR_INTERVAL, amr_interval=AMR_INTERVAL)
    report = resize_ranks(sim, n1)
    assert report.old_nranks == n0 and report.new_nranks == n1
    assert sim.cfg.nranks == n1 and sim.comm.nranks == n1
    owners = {b.owner for b in sim.forest.all_blocks()}
    assert owners <= set(range(n1))
    sim.run(COARSE_STEPS - AMR_INTERVAL, amr_interval=AMR_INTERVAL)

    _assert_same_fields(sim, ref, atol=0.0)  # bitwise


def test_elastic_resize_via_disk_checkpoint(tmp_path):
    """The durable variant routes the same protocol through the on-disk
    checkpoint files and stays bitwise too."""
    ref = AMRLBM(_cfg(nranks=2, stepping_mode="arena"))
    ref.run(6, amr_interval=AMR_INTERVAL)

    sim = AMRLBM(_cfg(nranks=2, stepping_mode="arena"))
    sim.run(4, amr_interval=AMR_INTERVAL)
    report = resize_ranks(sim, 3, checkpoint_dir=tmp_path / "ckpt")
    assert report.via_disk
    sim.run(2, amr_interval=AMR_INTERVAL)
    _assert_same_fields(sim, ref, atol=0.0)


def test_service_stream_poll_and_checkpoints(tmp_path):
    """The job driver streams diagnostics + registry-codec checkpoints in
    order and reports completion through poll()."""
    svc = SimulationService(checkpoint_root=tmp_path)
    jid = svc.submit(
        JobSpec(
            config=_cfg(stepping_mode="arena"),
            coarse_steps=COARSE_STEPS,
            amr_interval=AMR_INTERVAL,
            checkpoint_every=4,
        )
    )
    events = list(svc.stream(jid))
    kinds = [e["type"] for e in events]
    assert kinds[-1] == "done"
    assert "diagnostics" in kinds and "checkpoint" in kinds
    diag_steps = [e["step"] for e in events if e["type"] == "diagnostics"]
    assert diag_steps == sorted(diag_steps)
    # mass is conserved along the stream (closed box + moving lid)
    masses = [e["mass"] for e in events if e["type"] == "diagnostics"]
    np.testing.assert_allclose(masses, masses[0], rtol=1e-5)

    job = svc.jobs[jid]
    assert job.checkpoints, "checkpoint_every=4 must have streamed checkpoints"
    restored = load_checkpoint(job.checkpoints[-1], job.sim.registry, 2)
    assert len(list(restored.all_blocks())) == len(
        list(job.sim.forest.all_blocks())
    )

    polled = svc.poll(jid)
    assert polled["status"] == "done"
    assert polled["step"] == COARSE_STEPS
    assert polled["checkpoints"] == len(job.checkpoints)


def test_service_runs_unbatchable_jobs_solo_and_resizes():
    """Non-batchable configs (sharded data plane) run solo through their own
    engine; the service can elastically resize them mid-run."""
    cfg = _cfg(nranks=4, stepping_mode="sharded")
    assert not is_batchable(cfg)
    svc = SimulationService()
    jid = svc.submit(JobSpec(config=cfg, coarse_steps=6, amr_interval=AMR_INTERVAL))
    svc.run_round()  # advances the solo job by one amr_interval chunk
    assert svc.jobs[jid].step == AMR_INTERVAL
    report = svc.resize(jid, 2)
    assert report.new_nranks == 2
    svc.run()
    assert svc.jobs[jid].status == "done"
    assert svc.counters["solo_steps"] == 6
    assert any(e["type"] == "resize" for e in svc.jobs[jid].events)
