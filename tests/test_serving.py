"""Serving subsystem: ensemble conformance, divergence splits, elastic
resize, and the submit/poll/stream job driver.

Conformance discipline matches tests/test_distributed_conformance.py: the
batched ensemble path must reproduce independent single-run references
(fused device superstep) across an AMR event, and an elastic resize mid-run
must continue bitwise-identically to a fixed-rank reference.
"""

import numpy as np
import pytest

from repro.core.checkpoint import load_checkpoint
from repro.lbm.driver import AMRLBM, LidDrivenCavityConfig
from repro.serving import JobSpec, SimulationService, is_batchable, resize_ranks

BASE = dict(
    root_grid=(2, 2, 2),
    cells_per_block=(8, 8, 8),
    omega=1.5,
    u_lid=(0.08, 0.0, 0.0),
    max_level=1,
    refine_upper=0.03,
    refine_lower=0.004,
    kernel_backend="ref",
)
COARSE_STEPS = 8
AMR_INTERVAL = 4

# four members with different physics; the last (omega=1.9, slow lid) never
# refines, so the batch hits a real divergence split at the AMR event
MEMBERS = [
    dict(omega=1.5, u_lid=(0.08, 0.0, 0.0)),
    dict(omega=1.7, u_lid=(0.06, 0.0, 0.0)),
    dict(omega=1.6, u_lid=(0.08, 0.02, 0.0)),
    dict(omega=1.9, u_lid=(0.05, 0.0, 0.0)),
]


def _cfg(**over) -> LidDrivenCavityConfig:
    return LidDrivenCavityConfig(**{**BASE, **over})


def _assert_same_fields(sim: AMRLBM, ref: AMRLBM, *, atol: float) -> None:
    # pdf comparison covers the interior (physical) cells, matching the
    # distributed-conformance discipline: the ghost ring is scratch state,
    # overwritten by the next substep's fill before anything reads it, and
    # XLA:CPU rounds dead ghost-cell stencil outputs context-dependently
    # across differently-batched (vmap-ed) builds of the same program
    sim.materialize_host()
    ref.materialize_host()
    key = lambda f: sorted((b.bid, b.level) for b in f.all_blocks())
    assert key(sim.forest) == key(ref.forest), "topologies diverged"
    ref_blocks = {b.bid: b for b in ref.forest.all_blocks()}
    core = (slice(None), slice(1, -1), slice(1, -1), slice(1, -1))
    for b in sim.forest.all_blocks():
        rb = ref_blocks[b.bid]
        np.testing.assert_array_equal(b.data["mask"], rb.data["mask"])
        p, q = b.data["pdf"][core], rb.data["pdf"][core]
        if atol == 0.0:
            np.testing.assert_array_equal(p, q)
        else:
            np.testing.assert_allclose(p, q, rtol=0.0, atol=atol)


def test_ensemble_matches_independent_references_across_amr():
    """>=4 batched members with different tau / lid velocities match solo
    fused references at 1e-10 across an AMR event, with at most one compile
    per (topology, activity-pattern) key for the whole batch."""
    refs = []
    for over in MEMBERS:
        ref = AMRLBM(_cfg(stepping_mode="fused", **over))
        ref.run(COARSE_STEPS, amr_interval=AMR_INTERVAL)
        refs.append(ref)

    svc = SimulationService()
    ids = [
        svc.submit(
            JobSpec(
                config=_cfg(stepping_mode="arena", **over),
                coarse_steps=COARSE_STEPS,
                amr_interval=AMR_INTERVAL,
            )
        )
        for over in MEMBERS
    ]
    svc.run()

    amr_happened = False
    for jid, ref in zip(ids, refs):
        job = svc.jobs[jid]
        assert job.status == "done" and job.step == COARSE_STEPS
        _assert_same_fields(job.sim, ref, atol=1e-10)
        amr_happened = amr_happened or job.sim.amr_cycles > 0
    assert amr_happened, "the run must cross an AMR event"

    s = svc.summary()
    assert s["jobs_completed"] == len(MEMBERS)
    assert s["ensembles_formed"] == 1
    # omega=1.9 never refines -> one real divergence split at the AMR event
    assert s["divergence_splits"] >= 1
    # compile-amortization contract: one program build per distinct
    # (topology, activity-pattern-set) key for the whole batch — here the
    # uniform level-0 forest plus the refined post-AMR forest — and the
    # post-split groups re-hit the cache instead of recompiling per member
    assert s["compile_misses"] <= 2
    assert s["compile_hits"] >= 1
    # per-job latency/throughput counters are exposed in data_stats["serving"]
    stats = svc.data_stats["serving"]
    for jid in ids:
        rec = stats["jobs"][jid]
        assert rec["status"] == "done"
        assert rec["steps_per_s"] > 0 and rec["latency_s"] > 0
    assert stats["stage"].seconds > 0
    assert stats["compile"]["misses"] == s["compile_misses"]


@pytest.mark.parametrize("nranks", [(4, 2), (2, 6)])
def test_elastic_resize_preserves_physics_bitwise(nranks):
    """Resize mid-run (shrink 4->2 and grow 2->6) continues bitwise-
    identically to the fixed-rank reference."""
    n0, n1 = nranks
    ref = AMRLBM(_cfg(nranks=n0, stepping_mode="sharded"))
    ref.run(COARSE_STEPS, amr_interval=AMR_INTERVAL)

    sim = AMRLBM(_cfg(nranks=n0, stepping_mode="sharded"))
    sim.run(AMR_INTERVAL, amr_interval=AMR_INTERVAL)
    report = resize_ranks(sim, n1)
    assert report.old_nranks == n0 and report.new_nranks == n1
    assert sim.cfg.nranks == n1 and sim.comm.nranks == n1
    owners = {b.owner for b in sim.forest.all_blocks()}
    assert owners <= set(range(n1))
    sim.run(COARSE_STEPS - AMR_INTERVAL, amr_interval=AMR_INTERVAL)

    _assert_same_fields(sim, ref, atol=0.0)  # bitwise


def test_elastic_resize_via_disk_checkpoint(tmp_path):
    """The durable variant routes the same protocol through the on-disk
    checkpoint files and stays bitwise too."""
    ref = AMRLBM(_cfg(nranks=2, stepping_mode="arena"))
    ref.run(6, amr_interval=AMR_INTERVAL)

    sim = AMRLBM(_cfg(nranks=2, stepping_mode="arena"))
    sim.run(4, amr_interval=AMR_INTERVAL)
    report = resize_ranks(sim, 3, checkpoint_dir=tmp_path / "ckpt")
    assert report.via_disk
    sim.run(2, amr_interval=AMR_INTERVAL)
    _assert_same_fields(sim, ref, atol=0.0)


def test_service_stream_poll_and_checkpoints(tmp_path):
    """The job driver streams diagnostics + registry-codec checkpoints in
    order and reports completion through poll()."""
    svc = SimulationService(checkpoint_root=tmp_path)
    jid = svc.submit(
        JobSpec(
            config=_cfg(stepping_mode="arena"),
            coarse_steps=COARSE_STEPS,
            amr_interval=AMR_INTERVAL,
            checkpoint_every=4,
        )
    )
    events = list(svc.stream(jid))
    kinds = [e["type"] for e in events]
    assert kinds[-1] == "done"
    assert "diagnostics" in kinds and "checkpoint" in kinds
    diag_steps = [e["step"] for e in events if e["type"] == "diagnostics"]
    assert diag_steps == sorted(diag_steps)
    # mass is conserved along the stream (closed box + moving lid)
    masses = [e["mass"] for e in events if e["type"] == "diagnostics"]
    np.testing.assert_allclose(masses, masses[0], rtol=1e-5)

    job = svc.jobs[jid]
    assert job.checkpoints, "checkpoint_every=4 must have streamed checkpoints"
    restored = load_checkpoint(job.checkpoints[-1], job.sim.registry, 2)
    assert len(list(restored.all_blocks())) == len(
        list(job.sim.forest.all_blocks())
    )

    polled = svc.poll(jid)
    assert polled["status"] == "done"
    assert polled["step"] == COARSE_STEPS
    assert polled["checkpoints"] == len(job.checkpoints)


def test_service_runs_unbatchable_jobs_solo_and_resizes():
    """Non-batchable configs (sharded data plane) run solo through their own
    engine; the service can elastically resize them mid-run."""
    cfg = _cfg(nranks=4, stepping_mode="sharded")
    assert not is_batchable(cfg)
    svc = SimulationService()
    jid = svc.submit(JobSpec(config=cfg, coarse_steps=6, amr_interval=AMR_INTERVAL))
    svc.run_round()  # advances the solo job by one amr_interval chunk
    assert svc.jobs[jid].step == AMR_INTERVAL
    report = svc.resize(jid, 2)
    assert report.new_nranks == 2
    svc.run()
    assert svc.jobs[jid].status == "done"
    assert svc.counters["solo_steps"] == 6
    assert any(e["type"] == "resize" for e in svc.jobs[jid].events)


def test_pallas_solo_job_matches_fused_reference_bitwise():
    """A ``kernel_backend="pallas"`` job is unbatchable (the ensemble program
    is built from the ref coefficient kernel) and must run solo through its
    own fused engine — submit/poll/stream all work, and the final state is
    bitwise-identical to an independent fused run of the same config."""
    over = dict(stepping_mode="fused", kernel_backend="pallas")
    cfg = _cfg(**over)
    assert not is_batchable(cfg)

    steps, interval = 4, 2  # crosses one AMR event; interpret mode is slow
    ref = AMRLBM(_cfg(**over))
    ref.run(steps, amr_interval=interval)

    svc = SimulationService()
    jid = svc.submit(JobSpec(config=cfg, coarse_steps=steps, amr_interval=interval))
    assert svc.poll(jid)["status"] == "pending"

    events = list(svc.stream(jid))  # drives rounds from the consumer loop
    kinds = [e["type"] for e in events]
    assert kinds[-1] == "done" and "diagnostics" in kinds

    job = svc.jobs[jid]
    assert job.status == "done" and job.step == steps
    assert job.sim.amr_cycles >= 1, "the run must cross an AMR event"
    _assert_same_fields(job.sim, ref, atol=0.0)  # bitwise

    s = svc.summary()
    assert s["solo_steps"] == steps and s["ensembles_formed"] == 0
    assert s["compile_misses"] == 0, "solo jobs must not touch the batch cache"
    polled = svc.poll(jid)
    assert polled["status"] == "done" and polled["step"] == steps


def test_explicitly_donated_jobs_run_solo_on_cpu():
    """``donate_pdfs=True`` on XLA:CPU perturbs the solo fused math by one
    ulp (codegen under aliasing), so such jobs must not join a batch whose
    program never donates — the per-member bitwise contract would lie."""
    import jax

    if jax.default_backend() != "cpu":
        pytest.skip("CPU-only donation-drift gate")
    assert is_batchable(_cfg(stepping_mode="arena"))
    assert not is_batchable(_cfg(stepping_mode="arena", donate_pdfs=True))
