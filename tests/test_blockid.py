"""Block ID scheme, Morton/Hilbert keys, adjacency geometry."""

import itertools

import pytest

from repro.core.blockid import (
    ForestGeometry,
    children_ids,
    hilbert_index_3d,
    octant_of,
    parent_id,
    sibling_ids,
)


def test_id_roundtrip():
    geom = ForestGeometry(root_grid=(3, 2, 2), max_level=10)
    for root in range(geom.num_roots):
        bid = geom.root_id(root)
        assert geom.level_of(bid) == 0
        assert geom.root_of(bid) == root
        for o in range(8):
            ch = children_ids(bid)[o]
            assert octant_of(ch) == o
            assert parent_id(ch) == bid
            assert geom.level_of(ch) == 1
            assert geom.root_of(ch) == root


def test_coords_roundtrip():
    geom = ForestGeometry(root_grid=(2, 1, 1), max_level=8)
    for level in (1, 2, 3):
        n = 1 << level
        for x, y, z in [(0, 0, 0), (n - 1, n - 1, n - 1), (1, 0, n - 1)]:
            bid = geom.id_from_coords(level, x, y, z, root_idx=1)
            assert geom.block_coords(bid) == (level, x, y, z)
            assert geom.root_of(bid) == 1


def test_aabb_and_adjacency():
    geom = ForestGeometry(root_grid=(2, 1, 1), max_level=4)
    r0, r1 = geom.root_id(0), geom.root_id(1)
    assert geom.adjacent(r0, r1)
    assert geom.adjacency_kind(r0, r1) == "face"
    # children across the root boundary touch by face/edge/corner
    c0 = geom.id_from_coords(1, 1, 0, 0, 0)  # right half of root 0
    c1 = geom.id_from_coords(1, 0, 0, 0, 1)  # left half of root 1
    assert geom.adjacency_kind(c0, c1) == "face"
    c2 = geom.id_from_coords(1, 0, 1, 1, 1)
    assert geom.adjacency_kind(c0, c2) in ("edge", "corner")
    # non-neighbors
    far = geom.id_from_coords(1, 1, 1, 1, 1)
    near = geom.id_from_coords(1, 0, 0, 0, 0)
    assert not geom.adjacent(near, far)


def test_neighbor_region_ids_cross_root():
    geom = ForestGeometry(root_grid=(2, 2, 1), max_level=6)
    bid = geom.id_from_coords(1, 1, 1, 0, 0)  # corner block of root 0
    nb = geom.neighbor_region_ids(bid, 1, 0, 0)
    assert nb is not None and geom.root_of(nb) == 1
    assert geom.adjacency_kind(bid, nb) == "face"
    out = geom.neighbor_region_ids(bid, 0, 0, -1)  # below the domain
    assert out is None


def test_morton_key_orders_levels_depth_first():
    geom = ForestGeometry(root_grid=(1, 1, 1), max_level=6)
    root = geom.root_id(0)
    # leaves: children of child0 + children 1..7
    leaves = list(children_ids(children_ids(root)[0])) + list(children_ids(root))[1:]
    order = sorted(leaves, key=geom.morton_key)
    # the 8 grandchildren (inside octant 0) must come before octant 1..7
    assert all(geom.level_of(b) == 2 for b in order[:8])
    assert all(geom.level_of(b) == 1 for b in order[8:])


@pytest.mark.parametrize("nbits", [1, 2, 3])
def test_hilbert_curve_is_a_hamiltonian_face_path(nbits):
    """The defining property the paper exploits (§2.4.1): consecutive cells
    along the Hilbert curve are always connected via faces."""
    n = 1 << nbits
    cells = {}
    for x, y, z in itertools.product(range(n), repeat=3):
        h = hilbert_index_3d(nbits, x, y, z)
        assert h not in cells, "hilbert index collision"
        cells[h] = (x, y, z)
    assert len(cells) == n**3
    for i in range(1, n**3):
        a, b = cells[i - 1], cells[i]
        dist = sum(abs(p - q) for p, q in zip(a, b))
        assert dist == 1, f"hilbert jump {a}->{b}"


def test_sibling_ids():
    geom = ForestGeometry(root_grid=(1, 1, 1), max_level=4)
    ch = children_ids(geom.root_id(0))
    for c in ch:
        assert set(sibling_ids(c)) == set(ch)
