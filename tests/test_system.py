"""End-to-end behaviour tests for the paper's system.

These reproduce the *shape* of the paper's headline behaviours at laptop
scale: the synthetic benchmark scenario (§5.1.1), the scaling argument
(diffusion O(1) vs SFC Θ(N) per-rank bytes), and the full AMR + LBM loop.
"""

import math

import numpy as np
import pytest

from repro.core import (
    AMRPipeline,
    BlockDataRegistry,
    Comm,
    DiffusionBalancer,
    ForestGeometry,
    SFCBalancer,
    make_uniform_forest,
)
from repro.lbm import AMRLBM, LidDrivenCavityConfig


def _paper_benchmark_marks(geom, forest):
    """§5.1.1-style stress: coarsen all finest blocks, refine an equal
    amount of coarser neighbors -> most cells change size."""
    levels = forest.levels_in_use()
    finest = max(levels)

    def mark(rank, blocks):
        out = {}
        for bid, blk in blocks.items():
            if blk.level == finest:
                out[bid] = blk.level - 1
            elif blk.level == finest - 1:
                out[bid] = blk.level + 1
        return out

    return mark


@pytest.mark.parametrize(
    "balancer_name,balancer",
    [
        ("morton", SFCBalancer(order="morton")),
        ("hilbert", SFCBalancer(order="hilbert")),
        ("diffusion", DiffusionBalancer(mode="pushpull", flow_iterations=5, max_main_iterations=30)),
    ],
)
def test_full_amr_stress_cycle(balancer_name, balancer):
    """72%-of-cells-change-size style repartitioning stress (§5.1.1)."""
    geom = ForestGeometry(root_grid=(2, 2, 2), max_level=8)
    nranks = 8
    forest = make_uniform_forest(geom, nranks, level=1)
    comm = Comm(nranks)
    pipe = AMRPipeline(balancer=balancer, registry=BlockDataRegistry.trivial())
    # create a two-level structure first
    some = sorted(b.bid for b in forest.all_blocks())[:16]
    forest, _ = pipe.run_cycle(
        forest, comm, lambda r, blocks: {b: geom.level_of(b) + 1 for b in some if b in blocks}
    )
    forest.check_all()
    n_before = forest.num_blocks()
    # now the paper's stress marks
    forest, report = pipe.run_cycle(forest, comm, _paper_benchmark_marks(geom, forest))
    forest.check_all()
    assert report.executed
    for lvl in forest.levels_in_use():
        counts = forest.blocks_per_rank(lvl)
        assert max(counts) <= math.ceil(sum(counts) / nranks) + (
            0 if balancer_name != "diffusion" else 2
        )


def test_scaling_argument_diffusion_vs_sfc():
    """The paper's central claim: diffusion per-rank collective bytes stay
    O(1) while SFC per-rank bytes grow Θ(N)."""
    # WEAK scaling: blocks per rank constant, domain grows with ranks
    sfc_bytes, diff_bytes = {}, {}
    for nranks, roots in ((8, (2, 2, 2)), (32, (4, 4, 2))):
        geom = ForestGeometry(root_grid=roots, max_level=8)
        for name, bal, store in (
            ("sfc", SFCBalancer(per_level=True), sfc_bytes),
            ("diff", DiffusionBalancer(mode="pushpull", flow_iterations=5, max_main_iterations=10), diff_bytes),
        ):
            forest = make_uniform_forest(geom, nranks, level=1)
            comm = Comm(nranks)
            pipe = AMRPipeline(balancer=bal, registry=BlockDataRegistry.trivial())
            forest, _ = pipe.run_cycle(forest, comm, None, force_rebalance=True)
            store[nranks] = comm.stats.collective_bytes_per_rank
    assert sfc_bytes[32] > sfc_bytes[8] * 2.5  # Θ(N) growth
    assert diff_bytes[32] <= diff_bytes[8] * 2.0  # bounded (iterations only)


@pytest.mark.slow
def test_lbm_amr_end_to_end():
    cfg = LidDrivenCavityConfig(
        root_grid=(2, 2, 2),
        cells_per_block=(8, 8, 8),
        nranks=4,
        omega=1.5,
        u_lid=(0.08, 0.0, 0.0),
        max_level=1,
        refine_upper=0.03,
        refine_lower=0.004,
        balancer="diffusion-pushpull",
    )
    sim = AMRLBM(cfg)
    m0 = sim.total_mass()
    sim.run(coarse_steps=4, amr_interval=2)
    sim.forest.check_all()
    assert sim.amr_cycles >= 1
    assert np.isfinite(sim.max_velocity())
    assert abs(sim.total_mass() - m0) / m0 < 5e-3
    # flow actually developed
    assert sim.max_velocity() > 1e-4
