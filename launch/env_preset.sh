#!/usr/bin/env bash
# Launch preset for the stepping hot loop: allocator + XLA runtime flags.
#
# Usage:
#   launch/env_preset.sh python benchmarks/run.py stepping --size 16 ...
#   launch/env_preset.sh python -m pytest tests/test_lbm.py -q
#
# Wraps any command with the environment the benchmarks are meant to run
# under. Everything degrades gracefully: tcmalloc is only preloaded when the
# library exists, XLA flags are appended to (not clobbering) any caller
# XLA_FLAGS, and PYTHONPATH gains src/ so the repo runs uninstalled.
#
# None of the flags below change numerics — fast-math style options are
# deliberately absent (the conformance suites pin the fused data planes to
# the host reference at 1e-10, in practice bitwise; see
# tests/test_distributed_conformance.py).
set -euo pipefail

repo_root="$(cd "$(dirname "${BASH_SOURCE[0]}")/.." && pwd)"

# -- allocator: tcmalloc if present --------------------------------------------
# The superstep allocates multi-MB pdf buffers per substep unless donation is
# on; glibc malloc round-trips those through mmap/munmap (page faults every
# step). tcmalloc keeps them cached. Probe the usual install names and skip
# silently when absent (this container ships none).
for so in \
    /usr/lib/x86_64-linux-gnu/libtcmalloc.so.4 \
    /usr/lib/x86_64-linux-gnu/libtcmalloc_minimal.so.4 \
    /usr/lib/libtcmalloc.so.4; do
  if [[ -e "$so" ]]; then
    export LD_PRELOAD="${LD_PRELOAD:+$LD_PRELOAD:}$so"
    # silence the "large alloc" report for the block arenas (tens of GB at
    # paper scale); harmless when tcmalloc is not loaded
    export TCMALLOC_LARGE_ALLOC_REPORT_THRESHOLD=60000000000
    break
  fi
done

# -- logging -------------------------------------------------------------------
# keep benchmark stdout clean of TF/XLA runtime chatter
export TF_CPP_MIN_LOG_LEVEL=${TF_CPP_MIN_LOG_LEVEL:-4}

# -- XLA flags -----------------------------------------------------------------
# Latency-hiding scheduler: overlaps the async-dispatched device work (emit /
# interior programs) with host-side message routing — the compiled analogue
# of the paper's communication hiding. The flag lives in the gpu_ namespace
# of XLA's DebugOptions but is parsed (and ignored) by every backend, so it
# is safe to set unconditionally. Appended so callers can still add their
# own flags.
xla_extra="--xla_gpu_enable_latency_hiding_scheduler=true"
# Simulated multi-host runs: N XLA host devices from one process. Opt-in via
# REPRO_HOST_DEVICES because it changes jax.device_count() for everything.
if [[ -n "${REPRO_HOST_DEVICES:-}" ]]; then
  xla_extra="$xla_extra --xla_force_host_platform_device_count=${REPRO_HOST_DEVICES}"
fi
export XLA_FLAGS="${XLA_FLAGS:+$XLA_FLAGS }$xla_extra"

# -- repo on the path ----------------------------------------------------------
export PYTHONPATH="${repo_root}/src${PYTHONPATH:+:$PYTHONPATH}"

exec "$@"
