#!/usr/bin/env python3
"""Render (or validate) a telemetry Chrome-trace artifact.

Default mode renders the paper-style per-stage breakdown from a trace file
produced by ``repro.telemetry.export.write_chrome_trace``:

* the per-stage wall-time table (refine / proxy / balance / migrate from the
  AMR pipeline, halo / step / fused / particles from the stepping data
  plane, the serving stages) — the repro of the paper's Figures 8-13
  per-stage timing breakdowns, read off one artifact;
* the per-substep phase table (emit / interior / route / absorb) for the
  ``fused_sharded`` engine, plus the **interior-overlap efficiency**: the
  fraction of host-side routing time that ran while interior stepping was
  already dispatched to the device (route spans are marked ``overlapped``
  when interior programs were dispatched that substep). 0.0 means no
  overlap (the CPU-default unsplit absorb); ~1.0 means every routed byte
  hid behind interior compute;
* top per-rank-pair p2p bytes from the embedded bounded-metrics snapshot,
  and the per-rank ring-buffer accounting (the bounded-metadata proof).

``--check`` validates the artifact instead: structural Chrome-trace schema
(traceEvents, phases, pid/tid/ts/dur types, process metadata) and — with
``--require-substep-phases`` — that at least one substep carries all four
distinct emit/interior/route/absorb phase spans (the PR's acceptance shape
for a traced 4-rank fused_sharded run). Exit code 1 on any violation, so CI
can gate on it.

Usage:
    python tools/trace_report.py TRACE.json
    python tools/trace_report.py TRACE.json --check [--require-substep-phases]
"""

from __future__ import annotations

import argparse
import json
import sys
from collections import defaultdict
from pathlib import Path

PHASES = ("emit", "interior", "route", "absorb")
# stage-table ordering: AMR pipeline first, then data plane, then serving
STAGE_ORDER = (
    "refine", "proxy", "balance", "migrate",
    "halo", "step", "fused", "particles",
    "serving.round", "ensemble.advance", "resize",
)


def load_trace(path: str | Path) -> dict:
    return json.loads(Path(path).read_text())


# -----------------------------------------------------------------------------
# validation
# -----------------------------------------------------------------------------


def check_trace(trace: dict, *, require_substep_phases: bool = False) -> list[str]:
    """Structural validation; returns a list of violations (empty = valid)."""
    errs: list[str] = []
    if not isinstance(trace, dict) or "traceEvents" not in trace:
        return ["not a Chrome-trace object (missing 'traceEvents')"]
    events = trace["traceEvents"]
    if not isinstance(events, list) or not events:
        return ["'traceEvents' is empty or not a list"]
    named_pids: set[int] = set()
    used_pids: set[int] = set()
    for i, ev in enumerate(events):
        if not isinstance(ev, dict):
            errs.append(f"event {i}: not an object")
            continue
        ph = ev.get("ph")
        if ph not in ("X", "i", "M", "C"):
            errs.append(f"event {i}: unknown phase {ph!r}")
            continue
        if not isinstance(ev.get("pid"), int) or not isinstance(ev.get("tid"), int):
            errs.append(f"event {i}: pid/tid must be ints")
        if not isinstance(ev.get("name"), str) or not ev["name"]:
            errs.append(f"event {i}: missing name")
        if ph == "M":
            if ev.get("name") == "process_name":
                named_pids.add(ev.get("pid"))
            continue
        used_pids.add(ev.get("pid"))
        ts = ev.get("ts")
        if not isinstance(ts, (int, float)) or ts < 0:
            errs.append(f"event {i}: bad ts {ts!r}")
        if ph == "X":
            dur = ev.get("dur")
            if not isinstance(dur, (int, float)) or dur < 0:
                errs.append(f"event {i}: bad dur {dur!r}")
    for pid in sorted(used_pids - named_pids):
        errs.append(f"pid {pid} has events but no process_name metadata")
    if require_substep_phases:
        by_substep: dict = defaultdict(set)
        for ev in events:
            if ev.get("ph") == "X" and ev.get("cat") == "substep":
                args = ev.get("args") or {}
                if "substep" in args and ev["name"] in PHASES:
                    by_substep[args["substep"]].add(ev["name"])
        complete = [s for s, names in by_substep.items() if set(PHASES) <= names]
        if not by_substep:
            errs.append("no substep-phase spans found (cat='substep')")
        elif not complete:
            errs.append(
                "no substep carries all four phases "
                f"{PHASES}; saw {dict((k, sorted(v)) for k, v in by_substep.items())}"
            )
    return errs


# -----------------------------------------------------------------------------
# report
# -----------------------------------------------------------------------------


def _x_events(trace: dict) -> list[dict]:
    return [ev for ev in trace["traceEvents"] if ev.get("ph") == "X"]


def stage_table(trace: dict) -> list[tuple[str, str, int, float, float]]:
    """(cat, name, count, total_s, mean_ms) rows for every span name."""
    agg: dict[tuple[str, str], list] = defaultdict(lambda: [0, 0.0])
    for ev in _x_events(trace):
        a = agg[(ev.get("cat", "?"), ev["name"])]
        a[0] += 1
        a[1] += ev.get("dur", 0.0) / 1e6
    rows = []
    for (cat, name), (count, total) in agg.items():
        rows.append((cat, name, count, total, total / count * 1e3))
    order = {n: i for i, n in enumerate(STAGE_ORDER)}
    rows.sort(key=lambda r: (order.get(r[1], len(order)), r[0], r[1]))
    return rows


def overlap_efficiency(trace: dict) -> tuple[float, float, float]:
    """(efficiency, overlapped_route_s, total_route_s) from route spans."""
    total = overlapped = 0.0
    for ev in _x_events(trace):
        if ev.get("cat") == "substep" and ev["name"] == "route":
            dur = ev.get("dur", 0.0) / 1e6
            total += dur
            if (ev.get("args") or {}).get("overlapped"):
                overlapped += dur
    return (overlapped / total if total > 0 else 0.0), overlapped, total


def render_report(trace: dict) -> str:
    out: list[str] = []
    events = _x_events(trace)
    if not events:
        return "(no span events)"
    t0 = min(ev["ts"] for ev in events)
    t1 = max(ev["ts"] + ev.get("dur", 0.0) for ev in events)
    wall = (t1 - t0) / 1e6
    out.append(f"trace wall time: {wall * 1e3:.2f} ms "
               f"({len(events)} spans, {len({ev['pid'] for ev in events})} ranks)")
    out.append("")
    out.append("Per-stage breakdown (paper Figs 8-13 style):")
    out.append(f"  {'stage':<28} {'cat':<12} {'count':>6} {'total_ms':>10} "
               f"{'mean_ms':>9} {'share':>7}")
    for cat, name, count, total, mean_ms in stage_table(trace):
        share = total / wall if wall > 0 else 0.0
        out.append(f"  {name:<28} {cat:<12} {count:>6} {total * 1e3:>10.3f} "
                   f"{mean_ms:>9.4f} {share:>6.1%}")
    eff, ov, tot = overlap_efficiency(trace)
    out.append("")
    if tot > 0:
        out.append(
            f"interior-overlap efficiency: {eff:.3f} "
            f"({ov * 1e3:.3f} ms of {tot * 1e3:.3f} ms routing overlapped "
            "with dispatched interior stepping)"
        )
    else:
        out.append("interior-overlap efficiency: n/a (no route spans)")
    meta = trace.get("metadata") or {}
    metrics = meta.get("metrics") or {}
    p2p = metrics.get("comm.p2p_bytes")
    if p2p:
        out.append("")
        out.append("Top per-rank-pair p2p bytes:")
        series = sorted(p2p["series"].items(), key=lambda kv: -kv[1])[:8]
        for label, val in series:
            out.append(f"  {label:<24} {int(val):>14,} B")
    buffers = meta.get("buffers")
    if buffers:
        out.append("")
        out.append("Per-rank ring buffers (bounded-metadata proof):")
        for rank, st in sorted(buffers.items(), key=lambda kv: int(kv[0])):
            out.append(
                f"  rank {rank}: {st['entries']}/{st['capacity']} entries, "
                f"{st['evicted']} evicted of {st['total']} total"
            )
    return "\n".join(out)


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        prog="trace_report", description=__doc__,
        formatter_class=argparse.RawDescriptionHelpFormatter,
    )
    ap.add_argument("trace", help="trace JSON produced by repro.telemetry")
    ap.add_argument("--check", action="store_true",
                    help="validate the trace schema instead of rendering")
    ap.add_argument("--require-substep-phases", action="store_true",
                    help="with --check: require a substep with all four "
                         "emit/interior/route/absorb phase spans")
    args = ap.parse_args(argv)
    try:
        trace = load_trace(args.trace)
    except (OSError, ValueError) as e:
        print(f"trace_report: cannot read {args.trace}: {e}", file=sys.stderr)
        return 1
    if args.check:
        errs = check_trace(
            trace, require_substep_phases=args.require_substep_phases
        )
        if errs:
            print(f"trace_report: {args.trace} INVALID:", file=sys.stderr)
            for e in errs:
                print(f"  - {e}", file=sys.stderr)
            return 1
        nev = len(trace["traceEvents"])
        print(f"trace_report: OK ({nev} events, schema valid)")
        return 0
    try:
        print(render_report(trace))
    except BrokenPipeError:  # report piped into head/less and truncated
        sys.stderr.close()  # suppress the interpreter's shutdown warning
    return 0


if __name__ == "__main__":
    sys.exit(main())
