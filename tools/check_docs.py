"""Markdown link-and-path checker for the committed docs.

Docs rot silently: a module rename leaves README/ARCHITECTURE sections
pointing at files that no longer exist, and nothing fails. This checker
makes that rot loud. Over the repo-root markdown docs (README.md,
ARCHITECTURE.md, CHANGES.md, ROADMAP.md) it verifies:

* **relative markdown links** — ``[text](path)`` targets (anchors stripped)
  must exist on disk; external ``http(s)``/``mailto`` links and pure
  ``#anchor`` links are skipped;
* **tree paths** — any reference to ``src/...``, ``tests/...``,
  ``benchmarks/...``, ``examples/...`` or a ``BENCH_*.json`` trajectory must
  name an existing file or directory;
* **dotted module names** — ``repro.x.y...`` / ``benchmarks.x`` references
  must have a resolvable module prefix under ``src/`` (or the repo root):
  ``repro.core.fields.LevelArena`` is fine because ``repro.core.fields``
  resolves; ``repro.core.arenas`` fails because no prefix beyond the bare
  package does.

Run it directly (CI fast tier does)::

    python tools/check_docs.py            # exit 1 + report on any dead ref
    python tools/check_docs.py --verbose  # also list every checked ref

``tests/test_docs.py`` runs the same engine as part of tier-1, so a rename
that breaks a doc reference fails the ordinary test run too.
"""

from __future__ import annotations

import argparse
import re
import sys
from pathlib import Path

DOCS = ("README.md", "ARCHITECTURE.md", "CHANGES.md", "ROADMAP.md")

_MD_LINK = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
_TREE_PATH = re.compile(r"\b((?:src|tests|benchmarks|examples)/[A-Za-z0-9_/.\-]+)")
_BENCH_FILE = re.compile(r"\b(BENCH_[A-Za-z0-9_]+\.json)\b")
_DOTTED = re.compile(r"\b((?:repro|benchmarks)(?:\.[A-Za-z_][A-Za-z0-9_]*)+)")
_SKIP_SCHEMES = ("http://", "https://", "mailto:", "#")


def _strip_punct(path: str) -> str:
    return path.rstrip(".,;:)`'\"")


def _path_exists(root: Path, ref: str) -> bool:
    return (root / ref).exists()


def _module_exists(root: Path, dotted: str) -> bool:
    """True iff the dotted name resolves to a module path. Trailing segments
    may be classes/functions, but only after a module *file*:
    ``repro.core.fields.LevelArena`` resolves via ``fields.py``, while a
    bare package prefix (``repro.core`` for ``repro.core.arenas``) does not
    vouch for a missing submodule — the full name must then match a package
    itself. (The checker validates module paths, not API surfaces.)"""
    parts = dotted.split(".")
    for k in range(len(parts), 1, -1):
        for base in (root / "src", root):
            p = base.joinpath(*parts[:k])
            if p.with_suffix(".py").exists():
                return True  # module file: trailing segments are attributes
            if (p / "__init__.py").exists():
                # package: a longer prefix already failed to resolve, so only
                # an exact full-name match counts
                return k == len(parts)
    return False


def check_file(root: Path, doc: Path) -> list[tuple[int, str, str]]:
    """Return (line number, kind, reference) for every dead reference."""
    errors: list[tuple[int, str, str]] = []
    for lineno, line in enumerate(doc.read_text().splitlines(), start=1):
        for m in _MD_LINK.finditer(line):
            target = m.group(1)
            if target.startswith(_SKIP_SCHEMES):
                continue
            target = _strip_punct(target.split("#", 1)[0])
            if target and not _path_exists(root, target):
                errors.append((lineno, "md-link", target))
        for m in _TREE_PATH.finditer(line):
            ref = _strip_punct(m.group(1))
            if not _path_exists(root, ref):
                errors.append((lineno, "path", ref))
        for m in _BENCH_FILE.finditer(line):
            if not _path_exists(root, m.group(1)):
                errors.append((lineno, "path", m.group(1)))
        for m in _DOTTED.finditer(line):
            if not _module_exists(root, m.group(1)):
                errors.append((lineno, "module", m.group(1)))
    return errors


def collect_errors(root: Path | None = None) -> list[str]:
    """All dead references across the checked docs, as printable strings."""
    root = root or Path(__file__).resolve().parents[1]
    out: list[str] = []
    for name in DOCS:
        doc = root / name
        if not doc.exists():
            continue  # ARCHITECTURE.md may not exist in forks/subsets
        for lineno, kind, ref in check_file(root, doc):
            out.append(f"{name}:{lineno}: dead {kind} reference: {ref!r}")
    return out


def main() -> None:
    ap = argparse.ArgumentParser(prog="check_docs")
    ap.add_argument("--verbose", action="store_true",
                    help="list the checked docs even when clean")
    args = ap.parse_args()
    root = Path(__file__).resolve().parents[1]
    errors = collect_errors(root)
    if args.verbose or errors:
        checked = [n for n in DOCS if (root / n).exists()]
        print(f"check_docs: checked {', '.join(checked)}")
    if errors:
        print("\n".join(errors))
        sys.exit(f"check_docs: {len(errors)} dead reference(s)")
    print("check_docs: OK")


if __name__ == "__main__":
    main()
