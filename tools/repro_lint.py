#!/usr/bin/env python
"""Invariant lint CLI: run the static checkers against the tree.

Usage (from the repo root; src/ must be importable, e.g. PYTHONPATH=src):

    python tools/repro_lint.py --all                 # all checkers + protocol sweep
    python tools/repro_lint.py --checker host        # one source checker
    python tools/repro_lint.py --protocol            # halo-protocol topology sweep
    python tools/repro_lint.py --all --update-baseline

Exit status is 0 iff there are no non-baselined findings and no stale
baseline entries. Baseline entries are matched by (checker, path, content
hash of the flagged line): editing a baselined line invalidates the entry
and the lint fails loudly until it is re-audited (see
``src/repro/analysis/findings.py``).
"""

from __future__ import annotations

import argparse
import sys
from pathlib import Path

REPO_ROOT = Path(__file__).resolve().parent.parent
sys.path.insert(0, str(REPO_ROOT / "src"))

from repro.analysis import (  # noqa: E402
    CHECKERS,
    apply_baseline,
    load_baseline,
    load_config,
    render,
    run,
    sweep_topologies,
    write_baseline,
)


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--all", action="store_true", help="run every checker plus the protocol sweep")
    ap.add_argument(
        "--checker", action="append", choices=sorted(CHECKERS), default=[],
        help="run one source checker (repeatable)",
    )
    ap.add_argument("--protocol", action="store_true", help="run the halo-protocol topology sweep")
    ap.add_argument(
        "--ranks", default=None,
        help="comma-separated rank counts for the protocol sweep (default from pyproject)",
    )
    ap.add_argument(
        "--update-baseline", action="store_true",
        help="rewrite the baseline to the current findings (audit the diff!)",
    )
    ap.add_argument("--no-baseline", action="store_true", help="report raw findings, ignore the baseline")
    ap.add_argument("--root", default=str(REPO_ROOT), help="repo root to lint")
    args = ap.parse_args(argv)

    root = Path(args.root).resolve()
    cfg = load_config(root)

    names = list(CHECKERS) if args.all else args.checker
    if not names and not args.protocol and not args.all:
        ap.error("pick --all, --checker NAME, or --protocol")

    findings = run(cfg, names) if names else []
    if args.all or args.protocol:
        ranks = args.ranks or ",".join(str(r) for r in cfg.section("protocol")["ranks"])
        findings += sweep_topologies(tuple(int(r) for r in ranks.split(",")))

    if args.update_baseline:
        write_baseline(cfg.baseline_path, findings)
        print(f"baseline written: {cfg.baseline_path} ({len(findings)} entries)")
        return 0

    baseline = [] if args.no_baseline else load_baseline(cfg.baseline_path)
    new, suppressed, stale = apply_baseline(findings, baseline, root)

    for f in new:
        print(render(f))
    for msg in stale:
        print(f"baseline: {msg}")
    checker_names = sorted(set(names) | ({"protocol"} if (args.all or args.protocol) else set()))
    print(
        f"repro_lint: {len(new)} finding(s), {len(suppressed)} baselined, "
        f"{len(stale)} stale baseline entr{'y' if len(stale) == 1 else 'ies'} "
        f"[checkers: {', '.join(checker_names)}]"
    )
    return 1 if new or stale else 0


if __name__ == "__main__":
    sys.exit(main())
