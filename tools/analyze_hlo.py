#!/usr/bin/env python
"""Lower the fused stepping program and analyze its compiled HLO.

The IR-level complement of the source-level host-transfer lint: the source
checker proves no *code path* syncs; this tool proves the compiled stepping
program contains no transfer *ops* at all — no infeed/outfeed, no
host-transfer send/recv, no host-callback custom-calls, no host-memory-space
placements.

Usage (from the repo root):

    python tools/analyze_hlo.py                       # print HLO summary
    python tools/analyze_hlo.py --assert-no-transfers # exit 1 on any transfer op
    python tools/analyze_hlo.py --after-amr           # lower the post-AMR program too

Builds the canonical lid-driven-cavity scenario (the same config the
conformance tests and benchmarks run), grabs the fused engine's jitted
superstep, lowers it with ``jax.jit``'s AOT API — no stepping required for
the default program — and runs :func:`repro.launch.hlo_analysis.analyze_hlo`
plus :func:`~repro.launch.hlo_analysis.count_transfer_ops` over the text.
"""

from __future__ import annotations

import argparse
import sys
from pathlib import Path

REPO_ROOT = Path(__file__).resolve().parent.parent
sys.path.insert(0, str(REPO_ROOT / "src"))
sys.path.insert(0, str(REPO_ROOT))


def lowered_fused_hlo(*, after_amr: bool = False) -> str:
    """Compiled HLO text of the fused superstep for the canonical scenario."""
    from benchmarks.scenario import cavity_config
    from repro.lbm import AMRLBM

    sim = AMRLBM(cavity_config(nranks=1, stepping_mode="fused"))
    if after_amr:
        # develop refinement so the lowered program includes the level
        # transitions (coalescence/explosion gathers) of the 2-level forest
        sim.advance(1)
        sim.adapt()
    eng = sim.engine
    fn, levels = eng._fused_program()
    res = eng.arena.device()
    pdfs = tuple(res.fetch(l, "pdf") for l in levels)
    return fn.lower(pdfs).compile().as_text()


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument(
        "--assert-no-transfers", action="store_true",
        help="fail (exit 1) if the lowered stepping program contains any "
        "host<->device transfer op",
    )
    ap.add_argument(
        "--after-amr", action="store_true",
        help="also lower the refined-forest program (slower: steps once and "
        "runs an AMR cycle first)",
    )
    args = ap.parse_args(argv)

    from repro.launch.hlo_analysis import analyze_hlo, count_transfer_ops

    status = 0
    variants = [("uniform", False)] + ([("after-amr", True)] if args.after_amr else [])
    for label, after in variants:
        text = lowered_fused_hlo(after_amr=after)
        stats = analyze_hlo(text)
        transfers = count_transfer_ops(text)
        print(f"[{label}] computations={len(stats.computations)} "
              f"collective_bytes={stats.collective_bytes_total:.0f} "
              f"dot_flops={stats.dot_flops_total:.0f}")
        print(f"[{label}] transfer ops: " + ", ".join(
            f"{k}={v}" for k, v in transfers.items()))
        if transfers["total"]:
            status = 1
            print(
                f"[{label}] FAIL: fused stepping program contains "
                f"{transfers['total']} host<->device transfer op(s) — the "
                "zero-transfer-per-substep contract is broken",
            )
    if args.assert_no_transfers:
        if status == 0:
            print("OK: zero host<->device transfer ops in the fused stepping program")
        return status
    return 0


if __name__ == "__main__":
    sys.exit(main())
