"""Launcher: production mesh, multi-pod dry-run, HLO roofline analysis,
training/serving drivers."""
