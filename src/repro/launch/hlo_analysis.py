"""Roofline-term extraction from compiled XLA artifacts.

``compiled.cost_analysis()`` counts ``while``-loop bodies **once** (verified
empirically — scan length does not change reported FLOPs), so raw numbers
badly undercount scan-over-layers models. This module therefore parses the
post-partitioning HLO text itself:

1. split the module into named computations;
2. record every collective op (all-gather / all-reduce / reduce-scatter /
   all-to-all / collective-permute) with the *operand* byte size (resolved
   through a per-computation symbol table), and every ``dot`` with its FLOPs
   (2 x result elements x contraction size);
3. recover each while loop's trip count from the integer ``constant(N)`` in
   its condition computation and propagate multipliers down the (possibly
   nested) body computations;
4. report loop-corrected totals.

Roofline terms (per step, whole mesh):

    compute    = FLOPs / (chips * 197e12)          [bf16 MXU peak, v5e]
    memory     = bytes / (chips * 819e9)           [HBM]
    collective = collective_bytes / (chips * 4 * 45e9)  [ICI links/chip]

plus, for multi-pod meshes, a separate DCN term for pod-crossing collectives
(identified by replica groups that span pods).
"""

from __future__ import annotations

import re
from dataclasses import dataclass, field

__all__ = [
    "HW",
    "analyze_hlo",
    "roofline_terms",
    "HloStats",
    "count_transfer_ops",
]


class HW:
    """TPU v5e-class hardware constants (per chip)."""

    PEAK_FLOPS_BF16 = 197e12
    HBM_BW = 819e9
    ICI_LINK_BW = 50e9  # ~50 GB/s per link
    ICI_LINKS = 4  # 2D torus: 4 links/chip
    DCN_BW = 25e9  # inter-pod, per host aggregate (approx)


_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COLL_RE = re.compile(
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)(?:-start)?\("
)
_OP_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w\.\-]+)\s*=\s*(.+)$")
_COMP_HDR_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*\(.*\)\s*->\s*.+\{")
_WHILE_RE = re.compile(r"while\(.*?\),\s*condition=%?([\w\.\-]+),\s*body=%?([\w\.\-]+)")
_CONST_RE = re.compile(r"s32\[\]\s+constant\((\d+)\)")
_DOT_RE = re.compile(r"\bdot\(")
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
# modern HLO prints operand types inline: dot(f32[64,64]{1,0} %lhs, ...)
_DOT_LHS_INLINE_RE = re.compile(r"\bdot\(\s*(\w+)\[([\d,]*)\]")
_TRIP_COUNT_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')


def _shape_bytes(type_str: str) -> int:
    """Total bytes of all shapes in a type string (handles tuples)."""
    total = 0
    for dtype, dims in _SHAPE_RE.findall(type_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def _shape_dims(type_str: str) -> tuple[list[int], str] | None:
    m = _SHAPE_RE.search(type_str)
    if not m:
        return None
    dtype, dims = m.groups()
    return ([int(d) for d in dims.split(",")] if dims else [], dtype)


@dataclass
class CompStats:
    collective_bytes: dict[str, float] = field(default_factory=dict)
    collective_count: int = 0
    dot_flops: float = 0.0
    whiles: list[tuple[str, str]] = field(default_factory=list)  # (cond, body)


@dataclass
class HloStats:
    computations: dict[str, CompStats]
    trip_counts: dict[str, int]  # body computation -> trip count
    entry: str

    # loop-corrected totals
    collective_bytes: dict[str, float] = field(default_factory=dict)
    collective_bytes_total: float = 0.0
    dot_flops_total: float = 0.0


def _split_computations(text: str) -> dict[str, list[str]]:
    comps: dict[str, list[str]] = {}
    cur: str | None = None
    entry: str | None = None
    for line in text.splitlines():
        if not line.startswith(" ") and "{" in line and "->" in line:
            m = _COMP_HDR_RE.match(line.strip())
            if m:
                cur = m.group(1)
                comps[cur] = []
                if line.startswith("ENTRY"):
                    comps["__entry__"] = [cur]  # marker
                continue
        if cur is not None:
            if line.strip() == "}":
                cur = None
            else:
                comps.setdefault(cur, []).append(line)
    return comps


def analyze_hlo(text: str) -> HloStats:
    comps_lines = _split_computations(text)
    entry = comps_lines.pop("__entry__", [None])[0]
    comps: dict[str, CompStats] = {}
    cond_trip: dict[str, int] = {}

    for name, lines in comps_lines.items():
        st = CompStats()
        symbols: dict[str, str] = {}
        for line in lines:
            m = _OP_DEF_RE.match(line)
            if not m:
                continue
            op_name, rhs = m.groups()
            symbols[op_name] = rhs
        for line in lines:
            m = _OP_DEF_RE.match(line)
            if not m:
                continue
            op_name, rhs = m.groups()
            cm = _COLL_RE.search(rhs)
            if cm and "-done(" not in rhs:
                kind = cm.group(1)
                # operand bytes via symbol lookup; fall back to result bytes
                args = re.findall(r"%([\w\.\-]+)", rhs.split("(", 1)[1])
                nbytes = 0
                for a in args:
                    if a in symbols:
                        nbytes += _shape_bytes(symbols[a].split(" ", 1)[0] + " " + symbols[a])
                        break  # first operand only (rest are attrs/reducers)
                if nbytes == 0:
                    nbytes = _shape_bytes(rhs.split("=", 1)[0] if "=" in rhs else rhs)
                if nbytes == 0:
                    nbytes = _shape_bytes(rhs)
                st.collective_bytes[kind] = st.collective_bytes.get(kind, 0.0) + nbytes
                st.collective_count += 1
            if _DOT_RE.search(rhs):
                out = _shape_dims(rhs)
                contract = _CONTRACT_RE.search(rhs)
                if out and contract:
                    out_elems = 1
                    for d in out[0]:
                        out_elems *= d
                    # lhs shape: prefer the inline operand type (modern HLO
                    # prints it right in the operand list); fall back to the
                    # symbol table for %name-only operand syntax
                    lhs_shape: tuple[list[int], str] | None = None
                    im = _DOT_LHS_INLINE_RE.search(rhs)
                    if im:
                        dtype, dims = im.groups()
                        lhs_shape = (
                            [int(d) for d in dims.split(",")] if dims else [],
                            dtype,
                        )
                    else:
                        lhs_ref = re.search(r"dot\(%?([\w\.\-]+)", rhs)
                        lhs_rhs = symbols.get(lhs_ref.group(1)) if lhs_ref else None
                        if lhs_rhs:
                            lhs_shape = _shape_dims(lhs_rhs)
                    k = 1
                    if lhs_shape and contract.group(1):
                        for ci in contract.group(1).split(","):
                            idx = int(ci)
                            if idx < len(lhs_shape[0]):
                                k *= lhs_shape[0][idx]
                    st.dot_flops += 2.0 * out_elems * k
            wm = _WHILE_RE.search(rhs)
            if wm:
                st.whiles.append((wm.group(1), wm.group(2)))
                # XLA often records the trip count right on the while op;
                # prefer that over the constant recovered from the condition
                tm = _TRIP_COUNT_RE.search(rhs)
                if tm:
                    cond_trip[wm.group(1)] = int(tm.group(1))
        comps[name] = st
        consts = [int(c) for c in _CONST_RE.findall("\n".join(lines))]
        if consts:
            # a known_trip_count recorded on the while op itself wins over
            # the constant recovered from the condition computation
            cond_trip.setdefault(name, max(consts))

    # propagate multipliers down the while-nesting tree
    mult: dict[str, float] = {name: 0.0 for name in comps}
    if entry and entry in mult:
        mult[entry] = 1.0
    else:  # fall back: computation with no parent while
        bodies = {b for c in comps.values() for _, b in c.whiles}
        for name in comps:
            if name not in bodies:
                mult[name] = max(mult.get(name, 0.0), 1.0)
    trip_counts: dict[str, int] = {}
    changed = True
    iters = 0
    while changed and iters < 50:
        changed = False
        iters += 1
        for name, st in comps.items():
            if mult.get(name, 0.0) <= 0:
                continue
            for cond, body in st.whiles:
                trips = cond_trip.get(cond, 1)
                new_m = mult[name] * trips
                if new_m > mult.get(body, 0.0):
                    mult[body] = new_m
                    trip_counts[body] = trips
                    changed = True

    stats = HloStats(computations=comps, trip_counts=trip_counts, entry=entry or "")
    for name, st in comps.items():
        m = mult.get(name, 0.0)
        if m <= 0:
            continue
        for kind, b in st.collective_bytes.items():
            stats.collective_bytes[kind] = stats.collective_bytes.get(kind, 0.0) + m * b
        stats.dot_flops_total += m * st.dot_flops
    stats.collective_bytes_total = sum(stats.collective_bytes.values())
    return stats


# host<->device transfer evidence in compiled HLO: infeed/outfeed ops,
# send/recv marked as host transfers, custom-calls into Python/host callbacks,
# and operands/results placed in host memory space (S(5) layout annotations)
# the result type between '=' and the opcode may be bare ("token[]") or a
# parenthesized tuple ("(f32[8], token[])")
_TRANSFER_OP_RE = re.compile(r"=\s*[^=]*?\b(infeed|outfeed)\(")
_HOST_SENDRECV_RE = re.compile(r"\b(send|recv|send-done|recv-done)\(.*is_host_transfer=true")
_HOST_CALLBACK_RE = re.compile(
    r'custom_call_target="([^"]*(?:callback|[Hh]ost[Tt]ransfer|[Hh]ost[Cc]ompute)[^"]*)"'
)
_HOST_SPACE_RE = re.compile(r"\{[^}]*:S\(5\)\}")


def count_transfer_ops(text: str) -> dict[str, int]:
    """Count host<->device transfer ops in compiled HLO text.

    The IR-level twin of the source-level host-transfer lint
    (``tools/repro_lint.py``): a stepping program that is transfer-free at
    the source level must also lower to a module with zero infeeds/outfeeds,
    zero host-transfer send/recv pairs, zero host-callback custom-calls and
    no host-memory-space (``S(5)``) placements. Returns per-kind counts plus
    a ``"total"`` entry; ``tools/analyze_hlo.py --assert-no-transfers``
    fails on a nonzero total.
    """
    counts = {
        "infeed_outfeed": 0,
        "host_send_recv": 0,
        "host_callback": 0,
        "host_memory_space": 0,
    }
    for line in text.splitlines():
        if _TRANSFER_OP_RE.search(line):
            counts["infeed_outfeed"] += 1
        if _HOST_SENDRECV_RE.search(line):
            counts["host_send_recv"] += 1
        if _HOST_CALLBACK_RE.search(line):
            counts["host_callback"] += 1
        counts["host_memory_space"] += len(_HOST_SPACE_RE.findall(line))
    counts["total"] = sum(counts.values())
    return counts


def roofline_terms(
    *,
    flops_per_device: float,
    hbm_bytes_per_device: float,
    collective_bytes_per_device: float,
    dcn_bytes_per_device: float = 0.0,
    n_pods: int = 1,
) -> dict:
    """All inputs are PER-DEVICE quantities — the partitioned HLO that
    ``compiled.as_text()`` shows *is* the per-device program, and SPMD means
    per-device time == step time."""
    compute_s = flops_per_device / HW.PEAK_FLOPS_BF16
    memory_s = hbm_bytes_per_device / HW.HBM_BW
    coll_s = collective_bytes_per_device / (HW.ICI_LINKS * HW.ICI_LINK_BW)
    terms = {
        "compute_s": compute_s,
        "memory_s": memory_s,
        "collective_s": coll_s,
    }
    if n_pods > 1 and dcn_bytes_per_device:
        terms["dcn_s"] = dcn_bytes_per_device / HW.DCN_BW
    dominant = max(terms, key=lambda k: terms[k])
    terms["dominant"] = dominant
    terms["bound_s"] = terms[dominant]
    # roofline fraction: useful compute time over the bound
    terms["roofline_fraction"] = compute_s / max(terms["bound_s"], 1e-30)
    return terms
