"""Production mesh construction.

Defined as a FUNCTION so importing this module never touches jax device
state; the dry-run entry point forces 512 host devices *before* calling it.

Topology: 16x16 = 256 chips per pod (TPU v5e pod slice); the multi-pod mesh
prepends a "pod" axis (2 pods = 512 chips). The ("data","model") axes map to
the ICI torus within a pod; the "pod" axis crosses DCN — the sharding specs
therefore keep per-layer collectives intra-pod and only allow whole-gradient
all-reduces on the pod axis (see repro.sharding.specs).
"""

from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "mesh_axes", "mesh_devices"]


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def mesh_axes(mesh) -> tuple[str, ...]:
    return tuple(mesh.axis_names)


def mesh_devices(mesh) -> int:
    return int(mesh.devices.size)
