"""Analytic FLOP / HBM-byte models per (architecture x shape).

These are the MODEL_FLOPS = 6·N·D-style quantities of the roofline mandate
(exact formulas, independent of compilation), used (a) as the numerator of
the useful-compute ratio against the loop-corrected HLO dot FLOPs and (b)
as the HBM-traffic estimate, since ``cost_analysis`` bytes are undercounted
inside while loops just like FLOPs.

Conventions (per optimizer/serve step, whole cluster):
  train:  3 x forward FLOPs (fwd + 2x bwd) on 6·N_active·tokens accounting
          plus attention 12·B·S²·H·hd·L/2 (causal) — remat recompute is NOT
          counted here (it is *waste*, visible as useful_ratio < 1).
  decode: 2·N_active per token + attention 4·B·T·H·hd per layer.

HBM bytes (steady state, per step):
  train:  params bf16 read (fwd+bwd+remat fwd) + grad fp32 + AdamW state
          read/write (3 fp32 tensors r+w) + activation stash r/w.
  decode: params read once + KV/state cache read + cache write.
"""

from __future__ import annotations

from ..configs.base import ArchConfig
from ..configs.shapes import ShapeConfig

__all__ = ["model_flops", "hbm_bytes_estimate"]


def _attn_flops_per_layer(cfg: ArchConfig, B: int, S: int, causal: bool) -> float:
    # qk^T + pv : 2 * 2 * B * S * S_kv * H * hd (halved if causal)
    S_kv = min(S, cfg.sliding_window) if cfg.sliding_window else S
    f = 4.0 * B * S * S_kv * cfg.n_heads * cfg.hd
    return f / 2 if causal and not cfg.sliding_window else f


def _n_attn_layers(cfg: ArchConfig) -> int:
    if cfg.family == "hybrid":
        return cfg.n_layers // max(1, cfg.hybrid_attn_every)  # shared-attn sites
    if cfg.family == "ssm":
        return 0
    return cfg.n_layers


def model_flops(cfg: ArchConfig, shape: ShapeConfig) -> float:
    B = shape.global_batch
    N_active = cfg.active_params_count()
    if shape.kind in ("train", "prefill"):
        S = shape.seq_len
        tokens = B * S
        f = 2.0 * N_active * tokens
        f += _n_attn_layers(cfg) * _attn_flops_per_layer(cfg, B, S, causal=True)
        if cfg.family in ("ssm", "hybrid"):
            # recurrent-state math: ~ T * H * hd * (hd or N) per layer
            if cfg.family == "ssm":
                H, hd = cfg.d_model // cfg.rwkv_head_dim, cfg.rwkv_head_dim
                f += 4.0 * tokens * H * hd * hd * cfg.n_layers
            else:
                d_inner = cfg.ssm_expand * cfg.d_model
                f += 6.0 * tokens * d_inner * cfg.ssm_state * cfg.n_layers
        if cfg.is_encoder_decoder:
            enc_tokens = B * cfg.encoder_len
            per_layer = 12 * cfg.d_model**2 if cfg.activation != "swiglu" else 16 * cfg.d_model**2
            f += 2.0 * enc_tokens * cfg.encoder_layers * per_layer
            f += B * S * cfg.encoder_len * cfg.n_heads * cfg.hd * 4 * cfg.n_layers  # cross
        return f * (3.0 if shape.kind == "train" else 1.0)

    # decode: one token per sequence against a cache of seq_len
    T = shape.seq_len
    f = 2.0 * N_active * B
    if cfg.sliding_window:
        T = min(T, cfg.sliding_window)
    f += _n_attn_layers(cfg) * 4.0 * B * T * cfg.n_heads * cfg.hd
    if cfg.family == "ssm":
        H, hd = cfg.d_model // cfg.rwkv_head_dim, cfg.rwkv_head_dim
        f += 4.0 * B * H * hd * hd * cfg.n_layers
    if cfg.family == "hybrid":
        d_inner = cfg.ssm_expand * cfg.d_model
        f += 6.0 * B * d_inner * cfg.ssm_state * cfg.n_layers
    return f


def _cache_bytes(cfg: ArchConfig, shape: ShapeConfig, dtype_bytes: int = 2) -> float:
    B, T = shape.global_batch, shape.seq_len
    if cfg.sliding_window:
        T = min(T, cfg.sliding_window)
    kv = 2.0 * _n_attn_layers(cfg) * B * T * cfg.n_kv * cfg.hd * dtype_bytes
    state = 0.0
    if cfg.family == "ssm":
        H, hd = cfg.d_model // cfg.rwkv_head_dim, cfg.rwkv_head_dim
        state = 4.0 * B * H * hd * hd * cfg.n_layers  # fp32 wkv
    if cfg.family == "hybrid":
        d_inner = cfg.ssm_expand * cfg.d_model
        H = d_inner // cfg.ssm_head_dim
        state = 4.0 * B * H * cfg.ssm_state * cfg.ssm_head_dim * cfg.n_layers
    if cfg.is_encoder_decoder:
        kv += 2.0 * cfg.n_layers * B * cfg.encoder_len * cfg.n_kv * cfg.hd * dtype_bytes
    return kv + state


def hbm_bytes_estimate(cfg: ArchConfig, shape: ShapeConfig) -> float:
    P = cfg.params_count()
    if shape.kind == "train":
        B, S = shape.global_batch, shape.seq_len
        act = 2.0 * B * S * cfg.d_model * (2 * cfg.n_layers)  # bf16 stash r+w
        # bf16 params read 3x (fwd, bwd, remat-fwd), fp32 grads w+r,
        # AdamW master/m/v read+write in fp32
        return 3 * 2 * P + 2 * 4 * P + 6 * 4 * P + 2 * act
    if shape.kind == "prefill":
        B, S = shape.global_batch, shape.seq_len
        act = 2.0 * B * S * cfg.d_model * (2 * cfg.n_layers)
        return 2 * P + act
    # decode: read active params once, read whole cache, write one slot
    P_act = cfg.active_params_count()
    cache = _cache_bytes(cfg, shape)
    return 2 * P_act * 1.0 + cache + cache / max(1, shape.seq_len)
