"""ShapeDtypeStruct stand-ins for every model input (dry-run, no allocation)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from ..configs.shapes import ShapeConfig
from ..models.zoo import init_cache

__all__ = ["input_specs", "cache_specs", "param_shapes"]


def input_specs(cfg: ArchConfig, shape: ShapeConfig, *, act_dtype=jnp.bfloat16) -> dict:
    """Batch stand-ins for train/prefill (token sequences) or decode (1 token)."""
    B = shape.global_batch
    S = 1 if shape.kind == "decode" else shape.seq_len
    sds = jax.ShapeDtypeStruct
    batch: dict = {"tokens": sds((B, S), jnp.int32)}
    if shape.kind == "train":
        batch["labels"] = sds((B, S), jnp.int32)
    if cfg.m_rope:
        batch["positions"] = sds((B, 3, S), jnp.int32)
        batch["frontend_embeds"] = sds((B, S, cfg.d_model), act_dtype)
    if cfg.is_encoder_decoder:
        batch["enc_embeds"] = sds((B, cfg.encoder_len, cfg.d_model), act_dtype)
    return batch


def cache_specs(cfg: ArchConfig, shape: ShapeConfig, *, dtype=jnp.bfloat16):
    """Abstract KV-cache/recurrent-state tree for decode shapes."""
    return jax.eval_shape(
        lambda: init_cache(cfg, shape.global_batch, shape.seq_len, dtype)
    )


def param_shapes(cfg: ArchConfig, model, *, dtype=jnp.bfloat16):
    return jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0), dtype))
