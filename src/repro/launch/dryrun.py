import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture x shape x mesh) cell.

The two lines above MUST run before any jax import: jax locks the device
count at first init, and the production meshes need 512 host placeholder
devices (16x16 single-pod, 2x16x16 multi-pod). Do not set this flag
globally — smoke tests and benchmarks must see one device.

Per cell this script:
  * builds the train_step (train shapes) or serve/prefill step,
  * jits with full in/out shardings from repro.sharding.specs,
  * ``.lower(**ShapeDtypeStructs).compile()`` — no real allocation,
  * prints ``compiled.memory_analysis()`` (proves the per-device program
    fits HBM) and ``compiled.cost_analysis()`` (FLOPs/bytes),
  * parses the partitioned HLO for loop-corrected collective bytes and dot
    FLOPs (repro.launch.hlo_analysis),
  * writes a JSON artifact under experiments/dryrun/ for §Dry-run/§Roofline.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch yi-9b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both
"""

import argparse
import json
import time
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from ..configs import SHAPES, all_arch_ids, cells_for, get_config
from ..configs.base import ArchConfig
from ..configs.shapes import ShapeConfig
from ..models.zoo import DistContext, build_model
from ..sharding.specs import batch_pspecs, cache_pspecs, opt_state_pspecs, param_pspecs
from ..train.optimizer import AdamWConfig, adamw_init
from ..train.train_step import make_train_step
from .hlo_analysis import analyze_hlo, roofline_terms
from .inputs import cache_specs, input_specs, param_shapes
from .mesh import make_production_mesh
from .perf_model import hbm_bytes_estimate, model_flops

OUT_DIR = Path(__file__).resolve().parents[3] / "experiments" / "dryrun"


def _shard_tree(mesh, spec_tree):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s),
        spec_tree,
        is_leaf=lambda x: isinstance(x, P),
    )


def _microbatches(cfg: ArchConfig, shape: ShapeConfig, n_batch_shards: int) -> int:
    if shape.kind != "train":
        return 1
    per_shard = shape.global_batch // max(1, n_batch_shards)
    want = 8 if cfg.d_model >= 4096 else 2
    mb = min(want, per_shard) or 1
    while shape.global_batch % (mb * n_batch_shards) and mb > 1:
        mb -= 1
    return max(1, mb)


def run_cell(arch: str, shape_id: str, multi_pod: bool, *, verbose: bool = True, layout: str = "tp-fsdp", microbatches: int | None = None) -> dict:
    cfg = get_config(arch)
    shape = SHAPES[shape_id]
    mesh = make_production_mesh(multi_pod=multi_pod)
    axes = tuple(mesh.axis_names)
    n_chips = int(mesh.devices.size)
    n_batch_shards = n_chips // 16 if layout != "fsdp" else n_chips
    mesh_name = ("multi" if multi_pod else "single") + ("" if layout == "tp-fsdp" else f"-{layout}")

    batch_axes = ("pod", "data") if multi_pod else ("data",)
    if layout == "fsdp":
        batch_axes = batch_axes + ("model",)
    dist = DistContext(
        n_token_groups=n_batch_shards,
        remat=True,
        batch_axes=batch_axes,
        model_axis="model" if layout != "fsdp" else None,
        model_size=16 if layout != "fsdp" else 1,
        # decode caches with kv-heads not divisible by the model axis are
        # sequence-sharded; pin attention to contract T locally (it.4)
        decode_seq_shard=(shape.kind == "decode" and cfg.n_kv % 16 != 0),
    )
    model = build_model(cfg, dist)
    p_sds = param_shapes(cfg, model, dtype=jnp.bfloat16)
    # NOTE (§Perf pair 2, it.3 — REFUTED): a replicated-over-data serving
    # layout was tried for decode; the per-layer param gathers turned out to
    # be only ~0.5 GB/step while replication costs 9 GB/device. FSDP stays.
    p_spec = param_pspecs(cfg, p_sds, axes, layout=layout)
    p_shard = _shard_tree(mesh, p_spec)

    t0 = time.perf_counter()
    if shape.kind == "train":
        opt_sds = jax.eval_shape(adamw_init, p_sds)
        opt_spec = opt_state_pspecs(cfg, opt_sds, axes, layout=layout)
        opt_shard = _shard_tree(mesh, opt_spec)
        batch_sds = input_specs(cfg, shape)
        b_spec = batch_pspecs(cfg, shape, axes, layout=layout)
        b_shard = {k: NamedSharding(mesh, b_spec[k]) for k in batch_sds}
        mb = microbatches or _microbatches(cfg, shape, n_batch_shards)
        step = make_train_step(model, AdamWConfig(), microbatches=mb)
        with mesh:
            lowered = jax.jit(
                step,
                in_shardings=(p_shard, opt_shard, b_shard),
                out_shardings=(p_shard, opt_shard, None),
                donate_argnums=(0, 1),
            ).lower(p_sds, opt_sds, batch_sds)
            compiled = lowered.compile()
    elif shape.kind == "prefill":
        batch_sds = input_specs(cfg, shape)
        b_spec = batch_pspecs(cfg, shape, axes)
        b_shard = {k: NamedSharding(mesh, b_spec[k]) for k in batch_sds}

        def prefill(params, batch):
            h, _aux = model.hidden(params, batch)
            # last-position logits (the served token distribution)
            from ..models.zoo import logits_from_hidden

            return logits_from_hidden(cfg, params, h[:, -1:])

        with mesh:
            lowered = jax.jit(
                prefill, in_shardings=(p_shard, b_shard)
            ).lower(p_sds, batch_sds)
            compiled = lowered.compile()
        mb = 1
    else:  # decode
        batch_sds = input_specs(cfg, shape)
        c_sds = cache_specs(cfg, shape)
        c_spec = cache_pspecs(cfg, shape, c_sds, axes)
        c_shard = _shard_tree(mesh, c_spec)
        b_spec = batch_pspecs(cfg, shape, axes)
        tok_shard = NamedSharding(mesh, b_spec["tokens"])
        extra_names = [k for k in batch_sds if k != "tokens"]
        extras_sds = {k: batch_sds[k] for k in extra_names} or None
        extras_shard = (
            {k: NamedSharding(mesh, b_spec[k]) for k in extra_names} if extra_names else None
        )

        def serve(params, token, cache, extras):
            return model.decode(params, token, cache, extras)

        with mesh:
            lowered = jax.jit(
                serve,
                in_shardings=(p_shard, tok_shard, c_shard, extras_shard),
                out_shardings=(None, c_shard),
                donate_argnums=(2,),
            ).lower(p_sds, batch_sds["tokens"], c_sds, extras_sds)
            compiled = lowered.compile()
        mb = 1
    compile_s = time.perf_counter() - t0

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis() or {}
    text = compiled.as_text()
    hlo = analyze_hlo(text)

    # NOTE: HLO-derived numbers are PER-DEVICE (the partitioned program);
    # the analytic model numbers are whole-cluster -> divide by chips.
    flops_model = model_flops(cfg, shape)
    flops_hlo_raw = float(cost.get("flops", 0.0))
    flops_hlo_corrected = hlo.dot_flops_total  # per device
    hbm = hbm_bytes_estimate(cfg, shape)

    terms = roofline_terms(
        flops_per_device=max(flops_hlo_corrected, flops_model / n_chips),
        hbm_bytes_per_device=hbm / n_chips,
        collective_bytes_per_device=hlo.collective_bytes_total,
        n_pods=2 if multi_pod else 1,
    )

    result = {
        "arch": arch,
        "shape": shape_id,
        "mesh": mesh_name,
        "n_chips": n_chips,
        "microbatches": mb,
        "compile_s": round(compile_s, 1),
        "memory": {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "alias_bytes": mem.alias_size_in_bytes,
            "peak_per_device_gib": round(
                (mem.argument_size_in_bytes + mem.temp_size_in_bytes
                 + mem.output_size_in_bytes - mem.alias_size_in_bytes)
                / 2**30, 3),
        },
        "flops": {
            "model_cluster": flops_model,
            "hlo_raw_per_device": flops_hlo_raw,
            "hlo_loop_corrected_dots_per_device": flops_hlo_corrected,
            # MODEL_FLOPS / compiled FLOPs: <1 means remat/padding waste
            "useful_ratio": round(
                flops_model / max(flops_hlo_corrected * n_chips, 1.0), 4
            ),
        },
        "hbm_bytes_estimate": hbm,
        "collectives": {
            "bytes_by_kind": {k: float(v) for k, v in hlo.collective_bytes.items()},
            "bytes_total": float(hlo.collective_bytes_total),
            "trip_counts": hlo.trip_counts,
        },
        "roofline": terms,
    }
    if verbose:
        print(f"--- {arch} x {shape_id} x {mesh_name} ({n_chips} chips) ---")
        print("memory_analysis:", mem)
        print("cost_analysis flops (raw):", flops_hlo_raw)
        print(json.dumps({k: result[k] for k in ("flops", "collectives", "roofline")}, indent=1, default=str)[:1200])
    return result


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", action="append", default=None)
    ap.add_argument("--shape", action="append", default=None)
    ap.add_argument("--mesh", choices=["single", "multi", "both"], default="single")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--layout", choices=["tp-fsdp", "fsdp"], default="tp-fsdp")
    ap.add_argument("--microbatches", type=int, default=None)
    ap.add_argument("--out", default=str(OUT_DIR))
    args = ap.parse_args()

    out_dir = Path(args.out)
    out_dir.mkdir(parents=True, exist_ok=True)
    archs = args.arch or (all_arch_ids() if args.all else ["qwen2-0.5b"])
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]

    summary = []
    for arch in archs:
        cfg = get_config(arch)
        shapes = args.shape or [s.shape_id for s in cells_for(cfg)]
        for shape_id in shapes:
            for multi in meshes:
                mesh_name = "multi" if multi else "single"
                suffix = "" if args.layout == "tp-fsdp" else f"--{args.layout}"
                if args.microbatches:
                    suffix += f"--mb{args.microbatches}"
                path = out_dir / f"{arch}__{shape_id}__{mesh_name}{suffix}.json"
                if args.skip_existing and path.exists():
                    print(f"skip {path.name}")
                    continue
                try:
                    res = run_cell(arch, shape_id, multi, layout=args.layout, microbatches=args.microbatches)
                    path.write_text(json.dumps(res, indent=1, default=str))
                    summary.append(
                        (arch, shape_id, mesh_name, "OK",
                         res["roofline"]["dominant"], res["compile_s"])
                    )
                except Exception as e:  # noqa: BLE001 — report, keep going
                    traceback.print_exc()
                    summary.append((arch, shape_id, mesh_name, f"FAIL:{type(e).__name__}", "-", 0))
                    path.with_suffix(".err").write_text(traceback.format_exc())
    print("\n=== dry-run summary ===")
    for row in summary:
        print(f"{row[0]:24s} {row[1]:12s} {row[2]:7s} {row[3]:18s} dominant={row[4]:12s} compile={row[5]}s")


if __name__ == "__main__":
    main()
