"""Assigned input shapes and the realized (arch x shape) cell set.

LM transformer shapes are seq_len x global_batch. ``decode_*`` / ``long_*``
lower ``serve_step`` (one new token against a KV cache/recurrent state of
seq_len), NOT ``train_step``. ``long_500k`` requires sub-quadratic attention:
it runs for SSM/hybrid archs and for windowed-attention archs (mixtral SWA
keeps a rolling window cache); it is skipped for pure full-attention archs
(olmo, qwen2, yi, granite, granite-moe, qwen2-vl, whisper) — see DESIGN.md
§Arch-applicability.
"""

from __future__ import annotations

from dataclasses import dataclass

from .base import ArchConfig

__all__ = ["ShapeConfig", "SHAPES", "cells_for"]


@dataclass(frozen=True)
class ShapeConfig:
    shape_id: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}


def cells_for(cfg: ArchConfig) -> list[ShapeConfig]:
    """The shape cells realized for an architecture (skips annotated)."""
    out = [SHAPES["train_4k"], SHAPES["prefill_32k"], SHAPES["decode_32k"]]
    if cfg.sub_quadratic:
        out.append(SHAPES["long_500k"])
    return out
