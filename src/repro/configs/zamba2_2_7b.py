"""zamba2-2.7b [hybrid]: 54L d_model=2560 32H (kv=32) d_ff=10240 vocab=32000,
ssm_state=64. Mamba2 backbone + shared attention blocks. [arXiv:2411.15242; hf]"""

from .base import ArchConfig, register

CONFIG = register(
    ArchConfig(
        arch_id="zamba2-2.7b",
        family="hybrid",
        n_layers=54,
        d_model=2560,
        n_heads=32,
        n_kv=32,
        d_ff=10240,
        vocab=32000,
        head_dim=80,
        ssm_state=64,
        ssm_expand=2,
        ssm_head_dim=64,
        hybrid_attn_every=6,  # one shared attn block application per 6 mamba layers
        source="arXiv:2411.15242",
    )
)
