"""rwkv6-3b [ssm]: 32L d_model=2560 (attention-free) d_ff=8960 vocab=65536.
RWKV-6 "Finch": data-dependent decay. [arXiv:2404.05892; hf]"""

from .base import ArchConfig, register

CONFIG = register(
    ArchConfig(
        arch_id="rwkv6-3b",
        family="ssm",
        n_layers=32,
        d_model=2560,
        n_heads=40,  # 2560 / 64 WKV heads
        n_kv=40,
        d_ff=8960,
        vocab=65536,
        rwkv_head_dim=64,
        activation="relu2",  # rwkv channel-mix uses squared ReLU
        source="arXiv:2404.05892",
    )
)
