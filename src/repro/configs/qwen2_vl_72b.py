"""qwen2-vl-72b [vlm]: 80L d_model=8192 64H (GQA kv=8) d_ff=29568
vocab=152064. M-RoPE, dynamic resolution; transformer BACKBONE only — the
vision frontend is a stub providing precomputed patch embeddings.
[arXiv:2409.12191; hf]"""

from .base import ArchConfig, register

CONFIG = register(
    ArchConfig(
        arch_id="qwen2-vl-72b",
        family="vlm",
        n_layers=80,
        d_model=8192,
        n_heads=64,
        n_kv=8,
        d_ff=29568,
        vocab=152064,
        qkv_bias=True,
        m_rope=True,
        m_rope_sections=(16, 24, 24),
        rope_theta=1_000_000.0,
        frontend="vision-stub",
        source="arXiv:2409.12191",
    )
)
