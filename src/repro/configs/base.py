"""Architecture configuration dataclass + registry for the assigned archs."""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field, replace

__all__ = ["ArchConfig", "register", "get_config", "all_arch_ids"]


@dataclass(frozen=True)
class ArchConfig:
    arch_id: str
    family: str  # dense | moe | hybrid | ssm | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv: int
    d_ff: int
    vocab: int
    head_dim: int | None = None  # default d_model // n_heads
    # options
    qkv_bias: bool = False
    nonparametric_ln: bool = False  # olmo: LN without scale/bias
    norm: str = "rmsnorm"  # rmsnorm | layernorm
    activation: str = "swiglu"  # swiglu | gelu
    rope_theta: float = 10_000.0
    tie_embeddings: bool = False
    # attention windowing (mixtral SWA)
    sliding_window: int | None = None
    # moe
    n_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    # ssm / hybrid (mamba2)
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_conv: int = 4
    hybrid_attn_every: int = 0  # zamba2: shared attn block every k mamba layers
    # rwkv
    rwkv_head_dim: int = 64
    # vlm / audio frontend stubs
    m_rope: bool = False
    m_rope_sections: tuple[int, int, int] = (16, 24, 24)
    frontend: str | None = None  # "vision-stub" | "audio-stub"
    encoder_layers: int = 0  # whisper encoder depth
    encoder_len: int = 1500  # precomputed frame embeddings (stub)
    source: str = ""

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def sub_quadratic(self) -> bool:
        """Eligible for long_500k (SSM / hybrid / windowed attention)."""
        return self.family in ("ssm", "hybrid") or self.sliding_window is not None

    @property
    def is_encoder_decoder(self) -> bool:
        return self.family == "audio"

    def reduced(self) -> "ArchConfig":
        """Tiny same-family config for CPU smoke tests."""
        return replace(
            self,
            n_layers=max(2, min(4, self.n_layers)),
            d_model=64,
            n_heads=4,
            n_kv=min(4, max(1, self.n_kv if self.n_kv < self.n_heads else 4)),
            d_ff=128,
            vocab=256,
            head_dim=16,
            n_experts=min(self.n_experts, 4) if self.n_experts else 0,
            top_k=min(self.top_k, 2) if self.top_k else 0,
            ssm_state=min(self.ssm_state, 16) if self.ssm_state else 0,
            ssm_head_dim=16 if self.ssm_state or self.family == "ssm" else self.ssm_head_dim,
            rwkv_head_dim=16,
            sliding_window=64 if self.sliding_window else None,
            hybrid_attn_every=2 if self.hybrid_attn_every else 0,
            encoder_layers=2 if self.encoder_layers else 0,
            encoder_len=16 if self.encoder_layers else self.encoder_len,
            m_rope_sections=(2, 3, 3) if self.m_rope else self.m_rope_sections,
        )

    def params_count(self) -> int:
        """Approximate total parameter count (used for 6ND roofline math)."""
        D, F, V, L = self.d_model, self.d_ff, self.vocab, self.n_layers
        hd = self.hd
        emb = V * D * (1 if self.tie_embeddings else 2)
        if self.family == "ssm":  # rwkv6
            att = 5 * D * D + 2 * D * 64  # r,k,v,g,o + decay lora
            mlp = 3 * D * F // 2 if self.activation == "swiglu" else 2 * D * F
            return emb + L * (att + mlp)
        d_inner = self.ssm_expand * D
        mamba = (
            D * (2 * d_inner + 2 * self.ssm_state + d_inner // self.ssm_head_dim)
            + d_inner * D
        )
        attn = D * (self.n_heads * hd) + 2 * D * (self.n_kv * hd) + (self.n_heads * hd) * D
        if self.activation == "swiglu":
            mlp = 3 * D * F
        else:
            mlp = 2 * D * F
        if self.n_experts:
            moe = D * self.n_experts + self.n_experts * mlp
            layer = attn + moe
        elif self.family in ("hybrid",):
            # mamba layers + shared attn applications approximated
            layer = mamba + (attn + mlp) / max(1, self.n_layers / max(1, self.n_layers // max(1, self.hybrid_attn_every)))
        else:
            layer = attn + mlp
        enc = self.encoder_layers * (attn + mlp + (attn if self.is_encoder_decoder else 0))
        return int(emb + L * layer + enc)

    def active_params_count(self) -> int:
        """Active params per token (MoE: only top-k experts count)."""
        if not self.n_experts:
            return self.params_count()
        D, F, L = self.d_model, self.d_ff, self.n_layers
        hd = self.hd
        emb = self.vocab * D * 2
        attn = D * (self.n_heads * hd) + 2 * D * (self.n_kv * hd) + (self.n_heads * hd) * D
        mlp_one = 3 * D * F
        layer = attn + D * self.n_experts + self.top_k * mlp_one
        return int(emb + L * layer)


_REGISTRY: dict[str, ArchConfig] = {}


def register(cfg: ArchConfig) -> ArchConfig:
    _REGISTRY[cfg.arch_id] = cfg
    return cfg


def get_config(arch_id: str) -> ArchConfig:
    if not _REGISTRY:
        from . import _load_all  # lazy import of all config modules

        _load_all()
    return _REGISTRY[arch_id]


def all_arch_ids() -> list[str]:
    if not _REGISTRY:
        from . import _load_all

        _load_all()
    return sorted(_REGISTRY)
