"""olmo-1b [dense]: 16L d_model=2048 16H (GQA kv=16) d_ff=8192 vocab=50304.
Non-parametric LayerNorm (no scale/bias). [arXiv:2402.00838; hf]"""

from .base import ArchConfig, register

CONFIG = register(
    ArchConfig(
        arch_id="olmo-1b",
        family="dense",
        n_layers=16,
        d_model=2048,
        n_heads=16,
        n_kv=16,
        d_ff=8192,
        vocab=50304,
        nonparametric_ln=True,
        norm="layernorm",
        activation="swiglu",
        tie_embeddings=True,
        source="arXiv:2402.00838",
    )
)
