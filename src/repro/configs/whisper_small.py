"""whisper-small [audio]: 12L d_model=768 12H d_ff=3072 vocab=51865.
Encoder-decoder; conv frontend is a STUB (precomputed frame embeddings).
[arXiv:2212.04356; unverified]"""

from .base import ArchConfig, register

CONFIG = register(
    ArchConfig(
        arch_id="whisper-small",
        family="audio",
        n_layers=12,  # decoder layers
        d_model=768,
        n_heads=12,
        n_kv=12,
        d_ff=3072,
        vocab=51865,
        norm="layernorm",
        activation="gelu",
        encoder_layers=12,
        encoder_len=1500,
        frontend="audio-stub",
        source="arXiv:2212.04356",
    )
)
