"""Architecture configs: one module per assigned architecture (+ the paper's
own AMR/LBM benchmark config in :mod:`repro.configs.amr_lbm`)."""

from .base import ArchConfig, all_arch_ids, get_config
from .shapes import SHAPES, ShapeConfig, cells_for

_ARCH_MODULES = [
    "olmo_1b",
    "qwen2_0_5b",
    "yi_9b",
    "granite_20b",
    "zamba2_2_7b",
    "granite_moe_1b_a400m",
    "mixtral_8x7b",
    "rwkv6_3b",
    "qwen2_vl_72b",
    "whisper_small",
]


def _load_all() -> None:
    import importlib

    for m in _ARCH_MODULES:
        importlib.import_module(f"{__name__}.{m}")


_load_all()

__all__ = [
    "ArchConfig",
    "ShapeConfig",
    "SHAPES",
    "cells_for",
    "get_config",
    "all_arch_ids",
]
