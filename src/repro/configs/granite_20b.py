"""granite-20b [dense]: 52L d_model=6144 48H (MQA kv=1) d_ff=24576 vocab=49152.
Llama-architecture, code model. [arXiv:2405.04324; hf]"""

from .base import ArchConfig, register

CONFIG = register(
    ArchConfig(
        arch_id="granite-20b",
        family="dense",
        n_layers=52,
        d_model=6144,
        n_heads=48,
        n_kv=1,
        d_ff=24576,
        vocab=49152,
        activation="gelu",
        norm="layernorm",
        source="arXiv:2405.04324",
    )
)
