from .ops import fused_stream_collide
from .ref import stream_collide_ref

__all__ = ["fused_stream_collide", "stream_collide_ref"]
