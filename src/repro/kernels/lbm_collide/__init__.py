from .lbm_collide import resolve_donate, resolve_interpret
from .ops import fused_stream_collide
from .ref import stream_collide_ref

__all__ = [
    "fused_stream_collide",
    "resolve_donate",
    "resolve_interpret",
    "stream_collide_ref",
]
