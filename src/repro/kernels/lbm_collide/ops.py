"""Jitted public wrapper around the fused LBM stream+collide kernel.

Dispatches between the Pallas kernel (TPU target; interpret mode on CPU) and
the pure-jnp reference (oracle / fallback). All simulation-constant
parameters (lattice, omega, wall velocity, collision model) are closed over
so the jitted step takes only the block stack and the mask. Whether the
Pallas path interprets is resolved once at program-build time from the
active JAX backend (:func:`~.lbm_collide.resolve_interpret`).

The compiled superstep paths here implement the halo-in-tile data plane:
ghost exchange is merged into one fill per destination level
(:func:`~repro.lbm.halo.lower_halo_fill`) and fused into the same program
as the stencil (:func:`make_halo_stream_collide`), the double-buffered pdf
tuples are donated (``donate_argnums``) so each substep ping-pongs in
place, and the rank-sharded absorb can split into interior/boundary
programs so cross-rank payload routing overlaps interior stepping.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from ...lbm.halo import lower_halo_fill
from ...lbm.lattice import D3Q19, Lattice
from .lbm_collide import (
    lbm_stream_collide_halo_pallas,
    lbm_stream_collide_pallas,
    resolve_donate,
    resolve_interpret,
)
from .ref import (
    collision_coeffs,
    precompute_stream_masks,
    stream_collide_coeffs,
    stream_collide_ref,
)

__all__ = [
    "fused_stream_collide",
    "make_stream_collide",
    "make_arena_stream_collide",
    "make_halo_stream_collide",
    "apply_compiled_ghost_plan",
    "make_fused_superstep",
    "make_ensemble_superstep",
    "make_rank_emit",
    "make_rank_absorb",
    "make_rank_absorb_split",
    "make_device_superstep",
    "boundary_slot_sets",
    "resolve_interpret",
    "resolve_donate",
]


def make_stream_collide(
    *,
    omega: float,
    lattice: Lattice = D3Q19,
    u_wall: tuple[float, float, float] = (0.0, 0.0, 0.0),
    collision: str = "bgk",
    backend: str = "pallas",  # "pallas" | "ref"
    interpret: bool | None = None,
):
    """Build a jitted ``step(f_blocks, mask_blocks) -> f_blocks`` function.

    ``interpret=None`` (the default) resolves to "interpret iff the active
    backend is CPU", once, here at build time — the flag is then baked into
    the program, so a process that starts on TPU lowers the kernel natively
    without every call site having to thread the decision through."""

    if backend == "pallas":
        interpret = resolve_interpret(interpret)

        @jax.jit
        def step(f: jax.Array, mask: jax.Array) -> jax.Array:
            return lbm_stream_collide_pallas(
                f,
                mask,
                omega=omega,
                lattice=lattice,
                u_wall=u_wall,
                collision=collision,
                interpret=interpret,
            )

    elif backend == "ref":
        ref = functools.partial(
            stream_collide_ref,
            omega=omega,
            lattice=lattice,
            u_wall=u_wall,
            collision=collision,
        )

        @jax.jit
        def step(f: jax.Array, mask: jax.Array) -> jax.Array:
            return jax.vmap(ref)(f, mask)

    else:
        raise ValueError(f"unknown backend {backend!r}")

    return step


def make_arena_stream_collide(
    *,
    omega: float,
    lattice: Lattice = D3Q19,
    u_wall: tuple[float, float, float] = (0.0, 0.0, 0.0),
    collision: str = "bgk",
    backend: str = "pallas",
    interpret: bool | None = None,
):
    """Arena entry point: an in-place ``step(f_buf, mask) -> None`` over a
    persistent :class:`~repro.core.fields.LevelArena` buffer.

    ``f_buf`` is the level's contiguous ``(B, Q, X, Y, Z)`` SoA buffer; it is
    handed to the fused kernel whole (one host->device transfer, no
    per-block restacking) and the result is written back into the same
    buffer, so all per-block views bound by the arena stay valid. ``mask``
    may be a precomputed device array — masks only change on AMR events, so
    callers can cache the transfer across substeps.
    """
    step = make_stream_collide(
        omega=omega,
        lattice=lattice,
        u_wall=u_wall,
        collision=collision,
        backend=backend,
        interpret=interpret,
    )

    def step_arena(f_buf: np.ndarray, mask: jax.Array | np.ndarray) -> None:
        out = step(jnp.asarray(f_buf), jnp.asarray(mask))
        # repro: host-ok(arena-mode copy-out contract: results land in the host arena each step)
        np.copyto(f_buf, np.asarray(out))

    return step_arena


# -- halo-in-tile stepping -------------------------------------------------------


def _pad_fill_layout(
    dst_slot: np.ndarray, dst_cell: np.ndarray, nblocks: int, dims: tuple[int, int, int]
) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Repack a flat merged fill into the per-block padded slab layout the
    halo-aware Pallas kernel consumes.

    Returns ``(entry, cell, valid)``, each ``(nblocks, P)`` with ``P`` the
    max fills per block: ``entry[b, j]`` indexes the fill's concatenated
    value rows, ``cell[b, j]`` the flat ghosted-box cell to write. Padding
    rows point at the box's center cell — an interior cell that is never a
    halo target (all targets lie in the ghost ring) — with ``valid`` False,
    so the kernel writes that cell's current value back: a deterministic
    no-op even under duplicate-index scatter."""
    n = int(dst_cell.size)
    pad_cell = (dims[0] // 2 * dims[1] + dims[1] // 2) * dims[2] + dims[2] // 2
    assert not np.any(dst_cell == pad_cell), "halo fill targeted the pad cell"
    counts = np.bincount(dst_slot, minlength=nblocks)
    assert counts.size == nblocks, (counts.size, nblocks)
    P = int(counts.max()) if n else 0
    entry = np.zeros((nblocks, P), dtype=np.int32)
    cell = np.full((nblocks, P), pad_cell, dtype=np.int32)
    valid = np.zeros((nblocks, P), dtype=bool)
    order = np.argsort(dst_slot, kind="stable")
    pos = 0
    for b in range(nblocks):
        k = int(counts[b])
        idx = order[pos : pos + k]
        pos += k
        entry[b, :k] = idx
        cell[b, :k] = dst_cell[idx]
        valid[b, :k] = True
    return entry, cell, valid


def make_halo_stream_collide(
    dst_slot: np.ndarray,
    dst_cell: np.ndarray,
    *,
    mask: np.ndarray,
    omega: float,
    lattice: Lattice = D3Q19,
    u_wall: tuple[float, float, float] = (0.0, 0.0, 0.0),
    collision: str = "bgk",
    magic: float = 3.0 / 16.0,
    backend: str = "pallas",
    interpret: bool | None = None,
):
    """Build a halo-aware ``step(f, vals) -> f`` for one level's block stack:
    the ghost fill targeting (``dst_slot``, ``dst_cell``) and the
    stream+collide stencil run as one fused unit instead of materializing an
    exchanged buffer between them.

    ``vals`` is the ``(N, Q)`` concatenated fill values (gathered by the
    enclosing superstep from pre-step buffers, in the merged fill's segment
    order). On the ``pallas`` backend the fill happens *inside* the kernel:
    each grid step scatters its block's padded value slab into the
    VMEM-resident tile before the stencil reads. On the ``ref`` backend the
    fill is a single merged jnp scatter feeding the stencil in the same
    program — and the mask being a build-time constant here lets the
    streaming selectors be precomputed on the host
    (:func:`~.ref.precompute_stream_masks`), dropping the per-substep mask
    rolls entirely. Both paths are bitwise equal to scatter-then-step.

    ``mask`` is the level's host ``(B, X, Y, Z)`` cell-type stack, closed
    over as a constant (programs are rebuilt on mask refresh / AMR events).
    """
    # repro: host-ok(build-time mask normalization, outside the stepping loop)
    mask = np.asarray(mask)
    nblocks = mask.shape[0]
    dims = mask.shape[1:]
    assert dst_cell.size > 0, "use make_stream_collide when there is no fill"
    db = jnp.asarray(dst_slot)
    dc = jnp.asarray(dst_cell)

    if backend == "pallas":
        interpret = resolve_interpret(interpret)
        entry, cell, valid = _pad_fill_layout(dst_slot, dst_cell, nblocks, dims)
        entry_j = jnp.asarray(entry)
        cell_j = jnp.asarray(cell)
        valid_j = jnp.asarray(valid)
        mask_j = jnp.asarray(mask)

        def step(f: jax.Array, vals: jax.Array) -> jax.Array:
            hv = vals[entry_j]  # (B, P, Q) padded per-block slabs
            return lbm_stream_collide_halo_pallas(
                f,
                mask_j,
                hv,
                cell_j,
                valid_j,
                omega=omega,
                lattice=lattice,
                u_wall=u_wall,
                collision=collision,
                magic=magic,
                interpret=interpret,
            )

    elif backend == "ref":
        pm = precompute_stream_masks(mask, lattice)
        fs = jnp.asarray(pm["fluid_src"])  # (Q, B, X, Y, Z)
        ls = jnp.asarray(pm["lid_src"])
        fl = jnp.asarray(pm["fluid"])  # (B, X, Y, Z)

        def step(f: jax.Array, vals: jax.Array) -> jax.Array:
            f = _flat3(f).at[db, :, dc].set(vals).reshape(f.shape)
            coeffs = collision_coeffs(
                omega,
                lattice=lattice,
                u_wall=u_wall,
                collision=collision,
                magic=magic,
                dtype=f.dtype.type,
            )

            def blk(fb, fsb, lsb, flb):
                return stream_collide_coeffs(
                    fb,
                    None,
                    coeffs,
                    lattice=lattice,
                    collision=collision,
                    premask={"fluid_src": fsb, "lid_src": lsb, "fluid": flb},
                )

            return jax.vmap(blk, in_axes=(0, 1, 1, 0))(f, fs, ls, fl)

    else:
        raise ValueError(f"unknown backend {backend!r}")

    return step


def _device_plan_ops(plan, level_index: dict[int, int]) -> list[tuple]:
    """Lower a :class:`~repro.lbm.halo.CompiledGhostPlan` for one field into
    device-ready (dst idx, src idx, kind, index arrays) tuples, mapping levels
    to positions in the superstep's buffer tuple."""
    ops = []
    for op in plan.ops:
        ops.append(
            (
                level_index[op.dst_level],
                level_index[op.src_level],
                op.kind,
                jnp.asarray(op.dst_slot),
                jnp.asarray(op.dst_cell),
                jnp.asarray(op.src_slot),
                jnp.asarray(op.src_cell),
            )
        )
    return ops


def _flat3(a: jax.Array) -> jax.Array:
    """(B, *lead, X, Y, Z) -> (B, C, cells) with C the flattened lead axes."""
    return a.reshape(a.shape[0], -1, a.shape[-3] * a.shape[-2] * a.shape[-1])


def _gather_vals(s: jax.Array, kind: str, sb, sc) -> jax.Array:
    """Gather (and sender-side resample) one exchange segment: (N, C) values."""
    flat = _flat3(s)
    if kind == "fine":
        v = flat[sb, :, sc]  # (N, 8, C): octet gather in canonical order
        acc = v[:, 0]
        for k in range(1, 8):  # fixed-sequence sum == host _extract
            acc = acc + v[:, k]
        if jnp.issubdtype(s.dtype, jnp.floating):
            return acc * s.dtype.type(0.125)
        return (acc / 8).astype(s.dtype)  # int fields: truncating divide
    return flat[sb, :, sc]  # same / coarse: plain (possibly replicating) gather


def _run_plan_ops(ops: list[tuple], bufs: list[jax.Array]) -> list[jax.Array]:
    """Execute lowered exchange ops functionally on (B, *lead, X, Y, Z)
    per-level buffers (pure gathers/scatters — safe inside jit)."""
    for dst, src, kind, db, dc, sb, sc in ops:
        vals = _gather_vals(bufs[src], kind, sb, sc)
        d = bufs[dst]
        bufs[dst] = _flat3(d).at[db, :, dc].set(vals).reshape(d.shape)
    return bufs


def _lower_fill_gathers(fill, level_index: dict[int, int]) -> tuple:
    """Device-ready gather specs for a merged fill's value segments."""
    return tuple(
        (
            level_index[seg.src_level],
            seg.kind,
            jnp.asarray(seg.src_slot),
            jnp.asarray(seg.src_cell),
        )
        for seg in fill.segments
    )


def _concat_vals(bufs, gathers, extra=()) -> jax.Array:
    """Concatenate gathered segment values (plus any pre-built extra value
    arrays, e.g. inbound message slices) in merged-fill order."""
    parts = [_gather_vals(bufs[si], kind, sb, sc) for si, kind, sb, sc in gathers]
    parts += list(extra)
    return parts[0] if len(parts) == 1 else jnp.concatenate(parts, axis=0)


def apply_compiled_ghost_plan(plan, bufs: dict[int, jax.Array]) -> dict[int, jax.Array]:
    """Run one compiled single-field ghost exchange on per-level buffers.

    ``bufs`` maps level -> (B, *lead, X, Y, Z) array; a new dict with updated
    arrays is returned (pure — usable standalone or under jit). This is the
    building block :func:`make_fused_superstep` composes; exposed separately
    so tests can pin compiled-vs-host exchange equivalence directly.
    """
    assert len({op.field for op in plan.ops}) <= 1, (
        "apply_compiled_ghost_plan executes one field's buffers; compile "
        "multi-field exchanges as one plan per field"
    )
    levels = sorted(bufs)
    index = {l: i for i, l in enumerate(levels)}
    out = _run_plan_ops(
        _device_plan_ops(plan, index), [jnp.asarray(bufs[l]) for l in levels]
    )
    return dict(zip(levels, out))


def make_fused_superstep(
    *,
    levels,
    plans,
    steppers,
    masks,
    unroll_limit: int = 32,
    donate: bool | None = None,
    halo_stepper_factory=None,
):
    """Compile one full coarse step — the whole ``2^lmax`` substep cycle with
    interleaved ghost exchange — into a single jitted device program.

    Per substep ``s`` the active level set is ``{l : s % 2^(lmax-l) == 0}``,
    which depends only on the number of trailing zeros of ``s``; there are
    therefore just ``lmax+1`` distinct *activity patterns*. Each pattern
    becomes one branch. With ``halo_stepper_factory`` set the branch runs the
    halo-in-tile schedule: every active level's ghost fill is merged into one
    scatter (:func:`~repro.lbm.halo.lower_halo_fill`), all fill values are
    gathered up front from the pre-step buffers (sources are interior cells,
    disjoint from every fill target, so this is bitwise equal to the
    sequential per-op schedule), and each level then steps through its fused
    fill+stencil program — no intermediate exchanged buffer is materialized.
    Without the factory the legacy per-op gather/scatter schedule runs.
    Short cycles (``nsub <= unroll_limit``, i.e. essentially always) are
    unrolled straight-line — on CPU the ``fori_loop`` carry and ``switch``
    result copies cost more than the whole substep — while deeper
    hierarchies run the loop as ``lax.fori_loop`` dispatching through
    ``lax.switch`` on the pattern of ``s`` to bound program size. Nothing
    touches the host either way: the only transfers are the caller's initial
    upload and whatever diagnostics later flush back.

    ``donate`` resolves through :func:`~.lbm_collide.resolve_donate`
    (default: donate exactly when the backend is not CPU — XLA:CPU codegen
    under aliasing perturbs the stencil by one ulp, which would break the
    bitwise conformance contract). When donation is on, XLA aliases the
    inputs into the outputs and the superstep ping-pongs the double-buffered
    populations in place — callers must treat the passed-in arrays as
    consumed (the engines re-``store`` the returned arrays into their
    residency immediately).

    Args:
        levels: refinement levels in use (the buffer tuple's order is the
            ascending sort of this).
        plans: pattern index ``p`` (0..lmax) -> compiled ghost plan for the
            active set ``{l : l >= lmax - p}``.
        steppers: level -> ``step(f, mask) -> f`` (from
            :func:`make_stream_collide`; closed over, traced inline). Used
            for active levels with no fill, and for every level in the
            legacy schedule.
        masks: level -> device mask stack for that level's buffer.
        halo_stepper_factory: optional ``(level, dst_slot, dst_cell) ->
            step(f, vals)`` builder (see :func:`make_halo_stream_collide`).

    Returns:
        A jitted ``superstep(pdfs: tuple) -> tuple`` advancing one coarse
        step; ``pdfs`` holds one (B, Q, X, Y, Z) buffer per level, ascending.
    """
    levels = tuple(sorted(levels))
    index = {l: i for i, l in enumerate(levels)}
    lmax = levels[-1]
    nsub = 1 << lmax
    masks_t = tuple(jnp.asarray(masks[l]) for l in levels)

    def make_branch(p: int):
        active = tuple(sorted((l for l in levels if l >= lmax - p), reverse=True))
        if halo_stepper_factory is None:
            ops = _device_plan_ops(plans[p], index)

            def branch(pdfs):
                bufs = _run_plan_ops(ops, list(pdfs))
                for l in active:  # finest first, as the host driver orders
                    i = index[l]  # its per-level kernel calls
                    bufs[i] = steppers[l](bufs[i], masks_t[i])
                return tuple(bufs)

            return branch

        fills = lower_halo_fill(plans[p])
        assert set(fills) <= set(active), (sorted(fills), active)
        gathers = {l: _lower_fill_gathers(f, index) for l, f in fills.items()}
        hsteps = {
            l: halo_stepper_factory(l, f.dst_slot, f.dst_cell)
            for l, f in fills.items()
        }

        def branch(pdfs):
            bufs = list(pdfs)
            # all fill values gather from the pre-step buffers (every source
            # is an interior cell; every target a ghost cell — disjoint)
            vals = {l: _concat_vals(bufs, gathers[l]) for l in fills}
            for l in active:  # finest first
                i = index[l]
                if l in fills:
                    bufs[i] = hsteps[l](bufs[i], vals[l])
                else:
                    bufs[i] = steppers[l](bufs[i], masks_t[i])
            return tuple(bufs)

        return branch

    branches = [make_branch(p) for p in range(lmax + 1)]
    # pattern of substep s = trailing zeros of s (s=0 activates everything)
    pattern = [
        lmax if s == 0 else min((s & -s).bit_length() - 1, lmax) for s in range(nsub)
    ]

    def superstep(pdfs):
        pdfs = tuple(pdfs)
        if nsub <= unroll_limit:
            for s in range(nsub):
                pdfs = branches[pattern[s]](pdfs)
            return pdfs
        pattern_dev = jnp.asarray(pattern, dtype=jnp.int32)

        def body(s, carry):
            return jax.lax.switch(pattern_dev[s], branches, carry)

        return jax.lax.fori_loop(0, nsub, body, pdfs)

    if resolve_donate(donate):
        return jax.jit(superstep, donate_argnums=0)
    return jax.jit(superstep)


def make_ensemble_superstep(
    *,
    levels,
    plans,
    masks,
    lattice: Lattice = D3Q19,
    collision: str = "bgk",
    unroll_limit: int = 32,
):
    """Compile one coarse step for a whole *ensemble* of independent members
    sharing one forest topology: :func:`make_fused_superstep` with a leading
    member axis ``vmap``-ped over per-member physics coefficients.

    Per-member relaxation rates and wall velocities enter as *batched
    operands* (not closed-over constants), so one compiled program serves
    every member of the batch — the inference-serving amortization: compile
    once per (topology, activity-pattern set), dispatch once per coarse step
    for all members. Because the coefficients are pre-rounded to the field
    dtype on the host (:func:`~repro.kernels.lbm_collide.ref.collision_coeffs`)
    and only ever combine as ``coefficient * array``, each member's slice of
    the batched program is bitwise-identical to a solo fused run with the
    same parameters on every interior cell (dead post-step ghost values may
    round differently under the member ``vmap`` on XLA:CPU).

    Args:
        levels: refinement levels in use (ascending buffer-tuple order).
        plans: pattern index ``p`` (0..lmax) -> compiled ghost plan for the
            active set ``{l : l >= lmax - p}`` (per-*member* slot layout —
            all members share it, since they share the topology).
        masks: level -> (B, X, Y, Z) mask stack shared by every member.
        lattice / collision: the (topology-compatible) kernel configuration
            shared by the whole ensemble.

    Returns:
        A jitted ``superstep(pdfs: tuple, coeffs: dict) -> tuple`` advancing
        one coarse step: ``pdfs`` holds one ``(M, B, Q, X, Y, Z)`` buffer per
        level (``M`` = ensemble members, leading axis), ``coeffs`` maps level
        -> per-member coefficient arrays (leading ``M`` axis, from
        ``collision_coeffs`` stacked across members).
    """
    levels = tuple(sorted(levels))
    index = {l: i for i, l in enumerate(levels)}
    lmax = levels[-1]
    nsub = 1 << lmax
    masks_t = tuple(jnp.asarray(masks[l]) for l in levels)
    # host-precomputed streaming selectors, mirroring the solo fused path's
    # merged-fill steppers (make_halo_stream_collide, backend="ref"): the
    # batched program must trace the *same op structure* as a solo fused run
    # or XLA:CPU's context-dependent rounding breaks the per-member bitwise
    # contract (a structurally different batch drifts by one ulp)
    premasks = {
        # repro: host-ok(build-time d2h of the mask stack for selector precompute, once per program build)
        l: precompute_stream_masks(np.asarray(masks[l]), lattice) for l in levels
    }
    pm_t = {
        l: (
            jnp.asarray(pm["fluid_src"]),  # (Q, B, X, Y, Z)
            jnp.asarray(pm["lid_src"]),
            jnp.asarray(pm["fluid"]),  # (B, X, Y, Z)
        )
        for l, pm in premasks.items()
    }

    def step_level(fb: jax.Array, mb: jax.Array, coeffs: dict) -> jax.Array:
        return jax.vmap(
            lambda f, m: stream_collide_coeffs(
                f, m, coeffs, lattice=lattice, collision=collision
            )
        )(fb, mb)

    def step_level_filled(
        fb: jax.Array, l: int, db, dc, vals: jax.Array, coeffs: dict
    ) -> jax.Array:
        # merged fill scatter + premask stencil, same shape as the solo
        # halo stepper (vmap over blocks, selectors batched along axis 1)
        fb = _flat3(fb).at[db, :, dc].set(vals).reshape(fb.shape)
        fs, ls, fl = pm_t[l]

        def blk(f, fsb, lsb, flb):
            return stream_collide_coeffs(
                f,
                None,
                coeffs,
                lattice=lattice,
                collision=collision,
                premask={"fluid_src": fsb, "lid_src": lsb, "fluid": flb},
            )

        return jax.vmap(blk, in_axes=(0, 1, 1, 0))(fb, fs, ls, fl)

    def make_branch(p: int):
        active = tuple(sorted((l for l in levels if l >= lmax - p), reverse=True))
        fills = lower_halo_fill(plans[p])
        assert set(fills) <= set(active), (sorted(fills), active)
        gathers = {l: _lower_fill_gathers(f, index) for l, f in fills.items()}
        scatters = {
            l: (jnp.asarray(f.dst_slot), jnp.asarray(f.dst_cell))
            for l, f in fills.items()
        }

        def branch(carry):
            pdfs, coeffs = carry
            bufs = list(pdfs)
            # all fill values gather from the pre-step buffers, exactly as
            # the solo fused superstep's halo-in-tile branch does
            vals = {l: _concat_vals(bufs, gathers[l]) for l in fills}
            for l in active:  # finest first, matching the solo kernel order
                i = index[l]
                if l in fills:
                    db, dc = scatters[l]
                    bufs[i] = step_level_filled(
                        bufs[i], l, db, dc, vals[l], coeffs[l]
                    )
                else:
                    bufs[i] = step_level(bufs[i], masks_t[i], coeffs[l])
            return tuple(bufs), coeffs

        return branch

    branches = [make_branch(p) for p in range(lmax + 1)]
    pattern = [
        lmax if s == 0 else min((s & -s).bit_length() - 1, lmax) for s in range(nsub)
    ]

    def member_superstep(pdfs, coeffs):
        carry = (tuple(pdfs), coeffs)
        if nsub <= unroll_limit:
            for s in range(nsub):
                carry = branches[pattern[s]](carry)
            return carry[0]
        pattern_dev = jnp.asarray(pattern, dtype=jnp.int32)

        def body(s, carry):
            return jax.lax.switch(pattern_dev[s], branches, carry)

        return jax.lax.fori_loop(0, nsub, body, carry)[0]

    return jax.jit(jax.vmap(member_superstep, in_axes=(0, 0)))


def make_rank_emit(messages, level_index: dict[int, int]):
    """Compile one rank's message-building side of a sharded exchange.

    ``messages`` are the :class:`~repro.lbm.halo.CompiledRankMessage` specs
    whose ``src_rank`` is this rank; ``level_index`` maps the rank's levels
    to positions in its buffer tuple. Returns a jitted
    ``emit(pdfs: tuple) -> tuple`` producing one device-resident ``(N, C)``
    payload per message (sender-side resampled, segments concatenated in the
    spec's canonical order) — the arrays handed to the ``Comm`` fabric, so
    nothing touches the host. Returns ``None`` when the rank sends nothing.

    ``emit`` deliberately never donates its inputs: it only *reads* the pdf
    buffers, and they must stay live for the interior/absorb programs
    dispatched after it in the same substep. The donation happens there —
    the runtime sequences the donated write after emit's pending reads.
    """
    if not messages:
        return None
    specs = tuple(
        tuple(
            (level_index[src_level], kind, jnp.asarray(sb), jnp.asarray(sc))
            for src_level, kind, sb, sc in m.gather
        )
        for m in messages
    )

    @jax.jit
    def emit(pdfs):
        out = []
        for segs in specs:
            parts = [_gather_vals(pdfs[li], kind, sb, sc) for li, kind, sb, sc in segs]
            out.append(parts[0] if len(parts) == 1 else jnp.concatenate(parts, axis=0))
        return tuple(out)

    return emit


def boundary_slot_sets(messages, masks) -> dict[int, frozenset[int]]:
    """Per-level sets of block slots whose ghost layer depends on inbound
    cross-rank messages (the *boundary* blocks of a rank). ``masks`` maps
    the rank's levels to their (B, ...) stacks (only shapes are read)."""
    bnd: dict[int, set[int]] = {l: set() for l in masks}
    for m in messages:
        for dl, db, _dc, _n in m.scatter:
            bnd.setdefault(dl, set()).update(int(s) for s in np.unique(db))
    return {l: frozenset(s) for l, s in bnd.items()}


def make_rank_absorb(
    messages,
    local_plan,
    level_index: dict[int, int],
    steppers,
    masks,
    active_levels,
    *,
    donate: bool | None = None,
    halo_stepper_factory=None,
):
    """Compile one rank's receive+exchange+step side of a sharded substep.

    ``messages`` are the inbound :class:`~repro.lbm.halo.CompiledRankMessage`
    specs (``dst_rank`` == this rank) in plan order — the caller passes the
    received payloads in the same order; ``local_plan`` is the rank's
    intra-rank :class:`~repro.lbm.halo.CompiledGhostPlan` (or None);
    ``steppers``/``masks`` map the rank's levels to ``step(f, mask) -> f``
    kernels and device mask stacks; ``active_levels`` is this substep
    pattern's active set intersected with the rank's levels.

    With ``halo_stepper_factory`` set, the local-plan fills and the inbound
    message scatters targeting each level are merged into *one* fill per
    level and fused into that level's stencil program (halo-in-tile) — local
    fill values gather from the pre-step buffers, message values are sliced
    straight from the payload operands. ``donate`` resolves through
    :func:`~.lbm_collide.resolve_donate`; when on, the pdf tuple is donated
    so the substep runs ping-pong in place (payload operands are never
    donated — the fabric may still hold them).

    Returns a jitted ``absorb(pdfs: tuple, msgs: tuple) -> tuple`` — one
    device program per (rank, activity pattern), no host contact.
    """
    order = tuple(sorted(active_levels, reverse=True))  # finest first, as the
    masks_t = {l: jnp.asarray(masks[l]) for l in order}  # host driver does

    if halo_stepper_factory is None:
        scatters = tuple(
            tuple(
                (level_index[dst_level], jnp.asarray(db), jnp.asarray(dc), n)
                for dst_level, db, dc, n in m.scatter
            )
            for m in messages
        )
        local_ops = _device_plan_ops(local_plan, level_index) if local_plan else []

        def absorb(pdfs, msgs):
            bufs = list(pdfs)
            for segs, msg in zip(scatters, msgs):
                off = 0
                for li, db, dc, n in segs:
                    d = bufs[li]
                    bufs[li] = (
                        _flat3(d).at[db, :, dc].set(msg[off : off + n]).reshape(d.shape)
                    )
                    off += n
            bufs = _run_plan_ops(local_ops, bufs)
            for l in order:
                i = level_index[l]
                bufs[i] = steppers[l](bufs[i], masks_t[l])
            return tuple(bufs)

    else:
        fills = (
            lower_halo_fill(local_plan)
            if local_plan is not None and local_plan.ops
            else {}
        )
        # level -> merged fill: local segments first, then message slices, in
        # (message, scatter-segment) order — dst rows and value parts aligned
        per: dict[int, dict] = {
            l: {
                "dst": [(f.dst_slot, f.dst_cell)],
                "gath": _lower_fill_gathers(f, level_index),
                "msg": [],
            }
            for l, f in fills.items()
        }
        for mi, m in enumerate(messages):
            off = 0
            for dl, db, dc, n in m.scatter:
                e = per.setdefault(dl, {"dst": [], "gath": (), "msg": []})
                e["dst"].append((db, dc))
                e["msg"].append((mi, off, n))
                off += n
        assert set(per) <= set(order), (sorted(per), order)
        hsteps = {
            l: halo_stepper_factory(
                l,
                np.concatenate([d[0] for d in e["dst"]]),
                np.concatenate([d[1] for d in e["dst"]]),
            )
            for l, e in per.items()
        }

        def absorb(pdfs, msgs):
            bufs = list(pdfs)
            vals = {
                l: _concat_vals(
                    bufs,
                    e["gath"],
                    extra=[msgs[mi][off : off + n] for mi, off, n in e["msg"]],
                )
                for l, e in per.items()
            }
            for l in order:
                i = level_index[l]
                if l in vals:
                    bufs[i] = hsteps[l](bufs[i], vals[l])
                else:
                    bufs[i] = steppers[l](bufs[i], masks_t[l])
            return tuple(bufs)

    if resolve_donate(donate):
        return jax.jit(absorb, donate_argnums=0)
    return jax.jit(absorb)


def make_rank_absorb_split(
    messages,
    local_plan,
    level_index: dict[int, int],
    steppers,
    masks,
    active_levels,
    *,
    donate: bool | None = None,
):
    """Split one rank's substep into an interior and a boundary program so
    cross-rank payload routing overlaps interior stepping.

    *Boundary* blocks are the slots whose ghost layer depends on inbound
    messages (:func:`boundary_slot_sets`); everything else is *interior* —
    by construction an interior block's ghosts are filled entirely by the
    rank-local plan. The interior program ``interior(pdfs) -> pdfs`` gathers
    **all** local fill values from the pre-step buffers, scatters them
    (including the boundary blocks' local-sourced ghosts — their gathers
    happened before any stepping, preserving exchange semantics), then
    steps only the interior slots of each active level. The boundary
    program ``boundary(pdfs, msgs) -> pdfs`` scatters the inbound payloads
    and steps the boundary slots. The advance loop dispatches every rank's
    interior program *before* routing messages on the host, so the fabric
    work hides behind interior compute; the two programs together are
    bitwise equal to the unsplit absorb (per-block stepping is independent,
    and every ghost fill lands before the slot that reads it steps).

    Both programs donate their pdf tuple when ``donate`` (resolved through
    :func:`~.lbm_collide.resolve_donate`) is on.
    """
    order = tuple(sorted(active_levels, reverse=True))
    # repro: host-ok(build-time d2h of mask stacks for program lowering, once per arena version)
    masks_np = {l: np.asarray(masks[l]) for l in order}
    bnd = boundary_slot_sets(messages, masks_np)
    idx_int = {
        l: np.asarray(
            [s for s in range(masks_np[l].shape[0]) if s not in bnd.get(l, ())],
            dtype=np.int32,
        )
        for l in order
    }
    idx_bnd = {
        l: np.asarray(sorted(bnd.get(l, ())), dtype=np.int32) for l in order
    }
    masks_t = {l: jnp.asarray(masks_np[l]) for l in order}
    sub_mask = {
        ("int", l): jnp.asarray(masks_np[l][idx_int[l]]) for l in order
    }
    sub_mask.update(
        (("bnd", l), jnp.asarray(masks_np[l][idx_bnd[l]])) for l in order
    )
    fills = (
        lower_halo_fill(local_plan) if local_plan is not None and local_plan.ops else {}
    )
    local_j = {
        l: (jnp.asarray(f.dst_slot), jnp.asarray(f.dst_cell), _lower_fill_gathers(f, level_index))
        for l, f in fills.items()
    }
    scatters = tuple(
        tuple(
            (level_index[dst_level], jnp.asarray(db), jnp.asarray(dc), n)
            for dst_level, db, dc, n in m.scatter
        )
        for m in messages
    )

    def _step_subset(bufs, l, idx, which):
        i = level_index[l]
        if idx.size == 0:
            return
        if idx.size == masks_np[l].shape[0]:
            bufs[i] = steppers[l](bufs[i], masks_t[l])
            return
        sel = jnp.asarray(idx)
        sub = steppers[l](bufs[i][sel], sub_mask[(which, l)])
        bufs[i] = bufs[i].at[sel].set(sub)

    def interior(pdfs):
        bufs = list(pdfs)
        # every local fill (interior *and* boundary targets) gathers and
        # lands here, from pre-step sources
        for l, (db, dc, gath) in local_j.items():
            vals = _concat_vals(bufs, gath)
            i = level_index[l]
            d = bufs[i]
            bufs[i] = _flat3(d).at[db, :, dc].set(vals).reshape(d.shape)
        for l in order:
            _step_subset(bufs, l, idx_int[l], "int")
        return tuple(bufs)

    def boundary(pdfs, msgs):
        bufs = list(pdfs)
        for segs, msg in zip(scatters, msgs):
            off = 0
            for li, db, dc, n in segs:
                d = bufs[li]
                bufs[li] = (
                    _flat3(d).at[db, :, dc].set(msg[off : off + n]).reshape(d.shape)
                )
                off += n
        for l in order:
            _step_subset(bufs, l, idx_bnd[l], "bnd")
        return tuple(bufs)

    if resolve_donate(donate):
        return (
            jax.jit(interior, donate_argnums=0),
            jax.jit(boundary, donate_argnums=0),
        )
    return jax.jit(interior), jax.jit(boundary)


def make_device_superstep(
    *,
    mesh,
    levels,
    plans,
    schedules,
    steppers,
    unroll_limit: int = 32,
    donate: bool | None = None,
):
    """Compile one coarse step as a single SPMD program over real XLA devices.

    The ``device_sharded`` analogue of the per-rank program set built by
    ``FusedShardedEngine``: one ``shard_map`` over a 1-D ``mesh`` (axis
    ``"ranks"``, one device per rank) runs the whole ``2^lmax`` substep cycle,
    and the simulated ``Comm`` fabric's per-pair messages become
    ``jax.lax.ppermute`` calls *inside* the program. Per-rank asymmetry — the
    gather/scatter index arrays of :func:`compile_rank_halo_plan` differ on
    every rank — is expressed as ``lax.switch`` on ``lax.axis_index``: each
    branch closes over exactly one rank's index constants, so the arithmetic
    (including the canonical fixed-order octet sum) is *identical* to the
    host-fabric engines and the bitwise conformance contract carries over.

    Buffers are the equal-blocks-per-rank padded stacks: each per-level
    operand is ``(nranks, Bmax_l, ...)`` sharded on the leading axis, so every
    shard sees ``(1, Bmax_l, ...)`` and rank-local slot ids address it
    directly. Payloads for one :class:`~repro.lbm.halo.PpermuteRound` are
    zero-padded to the round's ``num_cells`` so all participants ship one
    shape; receivers scatter only the logical rows.

    Args:
        mesh: 1-D ``jax.sharding.Mesh`` whose single axis enumerates ranks.
        levels: global refinement levels in use (buffer tuple order is the
            ascending sort, same for every rank).
        plans: pattern index ``p`` -> :class:`CompiledRankHaloPlan` for the
            active set ``{l : l >= lmax - p}``.
        schedules: pattern index ``p`` -> ppermute rounds from
            :func:`~repro.lbm.halo.schedule_ppermute_rounds` over
            ``plans[p].messages``.
        steppers: level -> ``step(f, mask) -> f`` (shared with every other
            engine — same kernel, same trace).

    Returns:
        A jitted ``superstep(pdfs: tuple, masks: tuple) -> tuple`` advancing
        one coarse step; each tuple holds one padded global per-level stack.
        Masks are operands (not closed-over constants) because they are
        sharded alongside the pdfs.
    """
    from jax.experimental.shard_map import shard_map  # noqa: PLC0415 — jax<0.5 has no jax.shard_map

    levels = tuple(sorted(levels))
    index = {l: i for i, l in enumerate(levels)}
    lmax = levels[-1]
    nsub = 1 << lmax
    axis = mesh.axis_names[0]
    nranks = mesh.shape[axis]

    def make_emit_branch(rank: int, rounds):
        # per round: this rank's outbound gather (or a zero payload)
        specs = []
        for rnd in rounds:
            mine = [m for m in rnd.messages if m.src_rank == rank]
            assert len(mine) <= 1, (rank, rnd.perm)
            if mine:
                m = mine[0]
                segs = tuple(
                    (index[sl], kind, jnp.asarray(sb), jnp.asarray(sc))
                    for sl, kind, sb, sc in m.gather
                )
                specs.append((segs, m.num_cells, rnd.num_cells))
            else:
                specs.append((None, 0, rnd.num_cells))

        def emit(bufs):
            C = _flat3(bufs[0]).shape[1]
            dt = bufs[0].dtype
            out = []
            for segs, n, cap in specs:
                if segs is None:
                    out.append(jnp.zeros((cap, C), dt))
                    continue
                parts = [
                    _gather_vals(bufs[li], kind, sb, sc) for li, kind, sb, sc in segs
                ]
                v = parts[0] if len(parts) == 1 else jnp.concatenate(parts, axis=0)
                if n < cap:
                    v = jnp.concatenate([v, jnp.zeros((cap - n, C), dt)], axis=0)
                out.append(v)
            return tuple(out)

        return emit

    def make_exchange_branch(rank: int, rounds, plan):
        inbound = []  # (round idx, lowered scatter segments), in round order
        for k, rnd in enumerate(rounds):
            for m in rnd.messages:
                if m.dst_rank == rank:
                    segs = tuple(
                        (index[dl], jnp.asarray(db), jnp.asarray(dc), n)
                        for dl, db, dc, n in m.scatter
                    )
                    inbound.append((k, segs))
        local = plan.local.get(rank)
        local_ops = _device_plan_ops(local, index) if local is not None else []

        def exchange(bufs, recvs=()):
            bufs = list(bufs)
            # inbound scatters write ghost cells, local gathers read interior
            # cells — disjoint, so the order is immaterial (same argument as
            # make_rank_absorb)
            for k, segs in inbound:
                msg = recvs[k]
                off = 0
                for li, db, dc, n in segs:
                    d = bufs[li]
                    bufs[li] = (
                        _flat3(d).at[db, :, dc].set(msg[off : off + n]).reshape(d.shape)
                    )
                    off += n
            bufs = _run_plan_ops(local_ops, bufs)
            return tuple(bufs)

        return exchange

    def make_pattern_branch(p: int):
        rounds = schedules[p]
        active = tuple(sorted((l for l in levels if l >= lmax - p), reverse=True))
        emits = [make_emit_branch(r, rounds) for r in range(nranks)]
        exchanges = [make_exchange_branch(r, rounds, plans[p]) for r in range(nranks)]
        perms = [list(rnd.perm) for rnd in rounds]

        def branch(bufs, masks):
            if nranks == 1:
                bufs = exchanges[0](bufs)
            else:
                ridx = jax.lax.axis_index(axis)
                if rounds:
                    payloads = jax.lax.switch(ridx, emits, tuple(bufs))
                    recvs = tuple(
                        # repro: collective-ok(ppermute is a partial permutation — pure p2p halo routing, bytes attributed via DeviceComm.ppermute)
                        jax.lax.ppermute(pl, axis, perm)
                        for pl, perm in zip(payloads, perms)
                    )
                    bufs = jax.lax.switch(ridx, exchanges, tuple(bufs), recvs)
                else:
                    bufs = jax.lax.switch(
                        ridx,
                        [lambda b, e=e: e(b) for e in exchanges],
                        tuple(bufs),
                    )
            bufs = list(bufs)
            for l in active:  # finest first, as the host driver orders
                i = index[l]
                bufs[i] = steppers[l](bufs[i], masks[i])
            return tuple(bufs)

        return branch

    branches = [make_pattern_branch(p) for p in range(lmax + 1)]
    pattern = [
        lmax if s == 0 else min((s & -s).bit_length() - 1, lmax) for s in range(nsub)
    ]

    def mapped(pdfs, masks):
        bufs = tuple(b[0] for b in pdfs)  # shard_map hands (1, Bmax, ...)
        m = tuple(mm[0] for mm in masks)
        if nsub <= unroll_limit:
            for s in range(nsub):
                bufs = branches[pattern[s]](bufs, m)
        else:
            pattern_dev = jnp.asarray(pattern, dtype=jnp.int32)

            def body(s, carry):
                return jax.lax.switch(
                    pattern_dev[s],
                    [lambda c, br=br: br(c, m) for br in branches],
                    carry,
                )

            bufs = jax.lax.fori_loop(0, nsub, body, bufs)
        return tuple(b[None] for b in bufs)

    spec = jax.sharding.PartitionSpec(axis)
    sm = shard_map(
        mapped,
        mesh=mesh,
        in_specs=(spec, spec),
        out_specs=spec,
        check_rep=False,  # lax.switch on axis_index is deliberately per-device
    )
    if resolve_donate(donate):
        return jax.jit(sm, donate_argnums=0)
    return jax.jit(sm)


def fused_stream_collide(
    f: jax.Array,
    mask: jax.Array,
    *,
    omega: float,
    lattice: Lattice = D3Q19,
    u_wall: tuple[float, float, float] = (0.0, 0.0, 0.0),
    collision: str = "bgk",
    backend: str = "pallas",
    interpret: bool | None = None,
) -> jax.Array:
    """One fused stream+collide step over (B, Q, X, Y, Z) block stacks."""
    return make_stream_collide(
        omega=omega,
        lattice=lattice,
        u_wall=u_wall,
        collision=collision,
        backend=backend,
        interpret=interpret,
    )(f, mask)
