"""Jitted public wrapper around the fused LBM stream+collide kernel.

Dispatches between the Pallas kernel (TPU target; interpret mode on CPU) and
the pure-jnp reference (oracle / fallback). All simulation-constant
parameters (lattice, omega, wall velocity, collision model) are closed over
so the jitted step takes only the block stack and the mask.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from ...lbm.lattice import D3Q19, Lattice
from .lbm_collide import lbm_stream_collide_pallas
from .ref import stream_collide_ref

__all__ = [
    "fused_stream_collide",
    "make_stream_collide",
    "make_arena_stream_collide",
    "apply_compiled_ghost_plan",
    "make_fused_superstep",
]


def make_stream_collide(
    *,
    omega: float,
    lattice: Lattice = D3Q19,
    u_wall: tuple[float, float, float] = (0.0, 0.0, 0.0),
    collision: str = "bgk",
    backend: str = "pallas",  # "pallas" | "ref"
    interpret: bool = True,
):
    """Build a jitted ``step(f_blocks, mask_blocks) -> f_blocks`` function."""

    if backend == "pallas":

        @jax.jit
        def step(f: jax.Array, mask: jax.Array) -> jax.Array:
            return lbm_stream_collide_pallas(
                f,
                mask,
                omega=omega,
                lattice=lattice,
                u_wall=u_wall,
                collision=collision,
                interpret=interpret,
            )

    elif backend == "ref":
        ref = functools.partial(
            stream_collide_ref,
            omega=omega,
            lattice=lattice,
            u_wall=u_wall,
            collision=collision,
        )

        @jax.jit
        def step(f: jax.Array, mask: jax.Array) -> jax.Array:
            return jax.vmap(ref)(f, mask)

    else:
        raise ValueError(f"unknown backend {backend!r}")

    return step


def make_arena_stream_collide(
    *,
    omega: float,
    lattice: Lattice = D3Q19,
    u_wall: tuple[float, float, float] = (0.0, 0.0, 0.0),
    collision: str = "bgk",
    backend: str = "pallas",
    interpret: bool = True,
):
    """Arena entry point: an in-place ``step(f_buf, mask) -> None`` over a
    persistent :class:`~repro.core.fields.LevelArena` buffer.

    ``f_buf`` is the level's contiguous ``(B, Q, X, Y, Z)`` SoA buffer; it is
    handed to the fused kernel whole (one host->device transfer, no
    per-block restacking) and the result is written back into the same
    buffer, so all per-block views bound by the arena stay valid. ``mask``
    may be a precomputed device array — masks only change on AMR events, so
    callers can cache the transfer across substeps.
    """
    step = make_stream_collide(
        omega=omega,
        lattice=lattice,
        u_wall=u_wall,
        collision=collision,
        backend=backend,
        interpret=interpret,
    )

    def step_arena(f_buf: np.ndarray, mask: jax.Array | np.ndarray) -> None:
        out = step(jnp.asarray(f_buf), jnp.asarray(mask))
        np.copyto(f_buf, np.asarray(out))

    return step_arena


def _device_plan_ops(plan, level_index: dict[int, int]) -> list[tuple]:
    """Lower a :class:`~repro.lbm.halo.CompiledGhostPlan` for one field into
    device-ready (dst idx, src idx, kind, index arrays) tuples, mapping levels
    to positions in the superstep's buffer tuple."""
    ops = []
    for op in plan.ops:
        ops.append(
            (
                level_index[op.dst_level],
                level_index[op.src_level],
                op.kind,
                jnp.asarray(op.dst_slot),
                jnp.asarray(op.dst_cell),
                jnp.asarray(op.src_slot),
                jnp.asarray(op.src_cell),
            )
        )
    return ops


def _run_plan_ops(ops: list[tuple], bufs: list[jax.Array]) -> list[jax.Array]:
    """Execute lowered exchange ops functionally on (B, *lead, X, Y, Z)
    per-level buffers (pure gathers/scatters — safe inside jit)."""
    for dst, src, kind, db, dc, sb, sc in ops:
        s = bufs[src]
        flat = s.reshape(s.shape[0], -1, s.shape[-3] * s.shape[-2] * s.shape[-1])
        if kind == "fine":
            v = flat[sb, :, sc]  # (N, 8, C): octet gather in canonical order
            acc = v[:, 0]
            for k in range(1, 8):  # fixed-sequence sum == host _extract
                acc = acc + v[:, k]
            if jnp.issubdtype(s.dtype, jnp.floating):
                vals = acc * s.dtype.type(0.125)
            else:  # integer fields: truncating divide, like the host path
                vals = (acc / 8).astype(s.dtype)
        else:  # same / coarse: plain (possibly replicating) gather
            vals = flat[sb, :, sc]  # (N, C)
        d = bufs[dst]
        dflat = d.reshape(d.shape[0], -1, d.shape[-3] * d.shape[-2] * d.shape[-1])
        bufs[dst] = dflat.at[db, :, dc].set(vals).reshape(d.shape)
    return bufs


def apply_compiled_ghost_plan(plan, bufs: dict[int, jax.Array]) -> dict[int, jax.Array]:
    """Run one compiled single-field ghost exchange on per-level buffers.

    ``bufs`` maps level -> (B, *lead, X, Y, Z) array; a new dict with updated
    arrays is returned (pure — usable standalone or under jit). This is the
    building block :func:`make_fused_superstep` composes; exposed separately
    so tests can pin compiled-vs-host exchange equivalence directly.
    """
    assert len({op.field for op in plan.ops}) <= 1, (
        "apply_compiled_ghost_plan executes one field's buffers; compile "
        "multi-field exchanges as one plan per field"
    )
    levels = sorted(bufs)
    index = {l: i for i, l in enumerate(levels)}
    out = _run_plan_ops(
        _device_plan_ops(plan, index), [jnp.asarray(bufs[l]) for l in levels]
    )
    return dict(zip(levels, out))


def make_fused_superstep(
    *,
    levels,
    plans,
    steppers,
    masks,
    unroll_limit: int = 32,
):
    """Compile one full coarse step — the whole ``2^lmax`` substep cycle with
    interleaved ghost exchange — into a single jitted device program.

    Per substep ``s`` the active level set is ``{l : s % 2^(lmax-l) == 0}``,
    which depends only on the number of trailing zeros of ``s``; there are
    therefore just ``lmax+1`` distinct *activity patterns*. Each pattern
    becomes one branch (ghost exchange for the active set lowered from its
    :class:`~repro.lbm.halo.CompiledGhostPlan`, then stream+collide on the
    active levels, finest first). Short cycles (``nsub <= unroll_limit``,
    i.e. essentially always) are unrolled straight-line — on CPU the
    ``fori_loop`` carry and ``switch`` result copies cost more than the whole
    substep — while deeper hierarchies run the loop as ``lax.fori_loop``
    dispatching through ``lax.switch`` on the pattern of ``s`` to bound
    program size. Nothing touches the host either way: the only transfers
    are the caller's initial upload and whatever diagnostics later flush
    back.

    Args:
        levels: refinement levels in use (the buffer tuple's order is the
            ascending sort of this).
        plans: pattern index ``p`` (0..lmax) -> compiled ghost plan for the
            active set ``{l : l >= lmax - p}``.
        steppers: level -> ``step(f, mask) -> f`` (from
            :func:`make_stream_collide`; closed over, traced inline).
        masks: level -> device mask stack for that level's buffer.

    Returns:
        A jitted ``superstep(pdfs: tuple) -> tuple`` advancing one coarse
        step; ``pdfs`` holds one (B, Q, X, Y, Z) buffer per level, ascending.
    """
    levels = tuple(sorted(levels))
    index = {l: i for i, l in enumerate(levels)}
    lmax = levels[-1]
    nsub = 1 << lmax
    masks_t = tuple(jnp.asarray(masks[l]) for l in levels)

    def make_branch(p: int):
        active = tuple(l for l in levels if l >= lmax - p)
        ops = _device_plan_ops(plans[p], index)

        def branch(pdfs):
            bufs = _run_plan_ops(ops, list(pdfs))
            for l in sorted(active, reverse=True):  # finest first, as the
                i = index[l]  # host driver orders its per-level kernel calls
                bufs[i] = steppers[l](bufs[i], masks_t[i])
            return tuple(bufs)

        return branch

    branches = [make_branch(p) for p in range(lmax + 1)]
    # pattern of substep s = trailing zeros of s (s=0 activates everything)
    pattern = [
        lmax if s == 0 else min((s & -s).bit_length() - 1, lmax) for s in range(nsub)
    ]

    @jax.jit
    def superstep(pdfs):
        pdfs = tuple(pdfs)
        if nsub <= unroll_limit:
            for s in range(nsub):
                pdfs = branches[pattern[s]](pdfs)
            return pdfs
        pattern_dev = jnp.asarray(pattern, dtype=jnp.int32)

        def body(s, carry):
            return jax.lax.switch(pattern_dev[s], branches, carry)

        return jax.lax.fori_loop(0, nsub, body, pdfs)

    return superstep


def fused_stream_collide(
    f: jax.Array,
    mask: jax.Array,
    *,
    omega: float,
    lattice: Lattice = D3Q19,
    u_wall: tuple[float, float, float] = (0.0, 0.0, 0.0),
    collision: str = "bgk",
    backend: str = "pallas",
    interpret: bool = True,
) -> jax.Array:
    """One fused stream+collide step over (B, Q, X, Y, Z) block stacks."""
    return make_stream_collide(
        omega=omega,
        lattice=lattice,
        u_wall=u_wall,
        collision=collision,
        backend=backend,
        interpret=interpret,
    )(f, mask)
