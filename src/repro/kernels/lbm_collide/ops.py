"""Jitted public wrapper around the fused LBM stream+collide kernel.

Dispatches between the Pallas kernel (TPU target; interpret mode on CPU) and
the pure-jnp reference (oracle / fallback). All simulation-constant
parameters (lattice, omega, wall velocity, collision model) are closed over
so the jitted step takes only the block stack and the mask.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from ...lbm.lattice import D3Q19, Lattice
from .lbm_collide import lbm_stream_collide_pallas
from .ref import stream_collide_coeffs, stream_collide_ref

__all__ = [
    "fused_stream_collide",
    "make_stream_collide",
    "make_arena_stream_collide",
    "apply_compiled_ghost_plan",
    "make_fused_superstep",
    "make_ensemble_superstep",
    "make_rank_emit",
    "make_rank_absorb",
]


def make_stream_collide(
    *,
    omega: float,
    lattice: Lattice = D3Q19,
    u_wall: tuple[float, float, float] = (0.0, 0.0, 0.0),
    collision: str = "bgk",
    backend: str = "pallas",  # "pallas" | "ref"
    interpret: bool = True,
):
    """Build a jitted ``step(f_blocks, mask_blocks) -> f_blocks`` function."""

    if backend == "pallas":

        @jax.jit
        def step(f: jax.Array, mask: jax.Array) -> jax.Array:
            return lbm_stream_collide_pallas(
                f,
                mask,
                omega=omega,
                lattice=lattice,
                u_wall=u_wall,
                collision=collision,
                interpret=interpret,
            )

    elif backend == "ref":
        ref = functools.partial(
            stream_collide_ref,
            omega=omega,
            lattice=lattice,
            u_wall=u_wall,
            collision=collision,
        )

        @jax.jit
        def step(f: jax.Array, mask: jax.Array) -> jax.Array:
            return jax.vmap(ref)(f, mask)

    else:
        raise ValueError(f"unknown backend {backend!r}")

    return step


def make_arena_stream_collide(
    *,
    omega: float,
    lattice: Lattice = D3Q19,
    u_wall: tuple[float, float, float] = (0.0, 0.0, 0.0),
    collision: str = "bgk",
    backend: str = "pallas",
    interpret: bool = True,
):
    """Arena entry point: an in-place ``step(f_buf, mask) -> None`` over a
    persistent :class:`~repro.core.fields.LevelArena` buffer.

    ``f_buf`` is the level's contiguous ``(B, Q, X, Y, Z)`` SoA buffer; it is
    handed to the fused kernel whole (one host->device transfer, no
    per-block restacking) and the result is written back into the same
    buffer, so all per-block views bound by the arena stay valid. ``mask``
    may be a precomputed device array — masks only change on AMR events, so
    callers can cache the transfer across substeps.
    """
    step = make_stream_collide(
        omega=omega,
        lattice=lattice,
        u_wall=u_wall,
        collision=collision,
        backend=backend,
        interpret=interpret,
    )

    def step_arena(f_buf: np.ndarray, mask: jax.Array | np.ndarray) -> None:
        out = step(jnp.asarray(f_buf), jnp.asarray(mask))
        np.copyto(f_buf, np.asarray(out))

    return step_arena


def _device_plan_ops(plan, level_index: dict[int, int]) -> list[tuple]:
    """Lower a :class:`~repro.lbm.halo.CompiledGhostPlan` for one field into
    device-ready (dst idx, src idx, kind, index arrays) tuples, mapping levels
    to positions in the superstep's buffer tuple."""
    ops = []
    for op in plan.ops:
        ops.append(
            (
                level_index[op.dst_level],
                level_index[op.src_level],
                op.kind,
                jnp.asarray(op.dst_slot),
                jnp.asarray(op.dst_cell),
                jnp.asarray(op.src_slot),
                jnp.asarray(op.src_cell),
            )
        )
    return ops


def _flat3(a: jax.Array) -> jax.Array:
    """(B, *lead, X, Y, Z) -> (B, C, cells) with C the flattened lead axes."""
    return a.reshape(a.shape[0], -1, a.shape[-3] * a.shape[-2] * a.shape[-1])


def _gather_vals(s: jax.Array, kind: str, sb, sc) -> jax.Array:
    """Gather (and sender-side resample) one exchange segment: (N, C) values."""
    flat = _flat3(s)
    if kind == "fine":
        v = flat[sb, :, sc]  # (N, 8, C): octet gather in canonical order
        acc = v[:, 0]
        for k in range(1, 8):  # fixed-sequence sum == host _extract
            acc = acc + v[:, k]
        if jnp.issubdtype(s.dtype, jnp.floating):
            return acc * s.dtype.type(0.125)
        return (acc / 8).astype(s.dtype)  # int fields: truncating divide
    return flat[sb, :, sc]  # same / coarse: plain (possibly replicating) gather


def _run_plan_ops(ops: list[tuple], bufs: list[jax.Array]) -> list[jax.Array]:
    """Execute lowered exchange ops functionally on (B, *lead, X, Y, Z)
    per-level buffers (pure gathers/scatters — safe inside jit)."""
    for dst, src, kind, db, dc, sb, sc in ops:
        vals = _gather_vals(bufs[src], kind, sb, sc)
        d = bufs[dst]
        bufs[dst] = _flat3(d).at[db, :, dc].set(vals).reshape(d.shape)
    return bufs


def apply_compiled_ghost_plan(plan, bufs: dict[int, jax.Array]) -> dict[int, jax.Array]:
    """Run one compiled single-field ghost exchange on per-level buffers.

    ``bufs`` maps level -> (B, *lead, X, Y, Z) array; a new dict with updated
    arrays is returned (pure — usable standalone or under jit). This is the
    building block :func:`make_fused_superstep` composes; exposed separately
    so tests can pin compiled-vs-host exchange equivalence directly.
    """
    assert len({op.field for op in plan.ops}) <= 1, (
        "apply_compiled_ghost_plan executes one field's buffers; compile "
        "multi-field exchanges as one plan per field"
    )
    levels = sorted(bufs)
    index = {l: i for i, l in enumerate(levels)}
    out = _run_plan_ops(
        _device_plan_ops(plan, index), [jnp.asarray(bufs[l]) for l in levels]
    )
    return dict(zip(levels, out))


def make_fused_superstep(
    *,
    levels,
    plans,
    steppers,
    masks,
    unroll_limit: int = 32,
):
    """Compile one full coarse step — the whole ``2^lmax`` substep cycle with
    interleaved ghost exchange — into a single jitted device program.

    Per substep ``s`` the active level set is ``{l : s % 2^(lmax-l) == 0}``,
    which depends only on the number of trailing zeros of ``s``; there are
    therefore just ``lmax+1`` distinct *activity patterns*. Each pattern
    becomes one branch (ghost exchange for the active set lowered from its
    :class:`~repro.lbm.halo.CompiledGhostPlan`, then stream+collide on the
    active levels, finest first). Short cycles (``nsub <= unroll_limit``,
    i.e. essentially always) are unrolled straight-line — on CPU the
    ``fori_loop`` carry and ``switch`` result copies cost more than the whole
    substep — while deeper hierarchies run the loop as ``lax.fori_loop``
    dispatching through ``lax.switch`` on the pattern of ``s`` to bound
    program size. Nothing touches the host either way: the only transfers
    are the caller's initial upload and whatever diagnostics later flush
    back.

    Args:
        levels: refinement levels in use (the buffer tuple's order is the
            ascending sort of this).
        plans: pattern index ``p`` (0..lmax) -> compiled ghost plan for the
            active set ``{l : l >= lmax - p}``.
        steppers: level -> ``step(f, mask) -> f`` (from
            :func:`make_stream_collide`; closed over, traced inline).
        masks: level -> device mask stack for that level's buffer.

    Returns:
        A jitted ``superstep(pdfs: tuple) -> tuple`` advancing one coarse
        step; ``pdfs`` holds one (B, Q, X, Y, Z) buffer per level, ascending.
    """
    levels = tuple(sorted(levels))
    index = {l: i for i, l in enumerate(levels)}
    lmax = levels[-1]
    nsub = 1 << lmax
    masks_t = tuple(jnp.asarray(masks[l]) for l in levels)

    def make_branch(p: int):
        active = tuple(l for l in levels if l >= lmax - p)
        ops = _device_plan_ops(plans[p], index)

        def branch(pdfs):
            bufs = _run_plan_ops(ops, list(pdfs))
            for l in sorted(active, reverse=True):  # finest first, as the
                i = index[l]  # host driver orders its per-level kernel calls
                bufs[i] = steppers[l](bufs[i], masks_t[i])
            return tuple(bufs)

        return branch

    branches = [make_branch(p) for p in range(lmax + 1)]
    # pattern of substep s = trailing zeros of s (s=0 activates everything)
    pattern = [
        lmax if s == 0 else min((s & -s).bit_length() - 1, lmax) for s in range(nsub)
    ]

    @jax.jit
    def superstep(pdfs):
        pdfs = tuple(pdfs)
        if nsub <= unroll_limit:
            for s in range(nsub):
                pdfs = branches[pattern[s]](pdfs)
            return pdfs
        pattern_dev = jnp.asarray(pattern, dtype=jnp.int32)

        def body(s, carry):
            return jax.lax.switch(pattern_dev[s], branches, carry)

        return jax.lax.fori_loop(0, nsub, body, pdfs)

    return superstep


def make_ensemble_superstep(
    *,
    levels,
    plans,
    masks,
    lattice: Lattice = D3Q19,
    collision: str = "bgk",
    unroll_limit: int = 32,
):
    """Compile one coarse step for a whole *ensemble* of independent members
    sharing one forest topology: :func:`make_fused_superstep` with a leading
    member axis ``vmap``-ped over per-member physics coefficients.

    Per-member relaxation rates and wall velocities enter as *batched
    operands* (not closed-over constants), so one compiled program serves
    every member of the batch — the inference-serving amortization: compile
    once per (topology, activity-pattern set), dispatch once per coarse step
    for all members. Because the coefficients are pre-rounded to the field
    dtype on the host (:func:`~repro.kernels.lbm_collide.ref.collision_coeffs`)
    and only ever combine as ``coefficient * array``, each member's slice of
    the batched program is bitwise-identical to a solo fused run with the
    same parameters.

    Args:
        levels: refinement levels in use (ascending buffer-tuple order).
        plans: pattern index ``p`` (0..lmax) -> compiled ghost plan for the
            active set ``{l : l >= lmax - p}`` (per-*member* slot layout —
            all members share it, since they share the topology).
        masks: level -> (B, X, Y, Z) mask stack shared by every member.
        lattice / collision: the (topology-compatible) kernel configuration
            shared by the whole ensemble.

    Returns:
        A jitted ``superstep(pdfs: tuple, coeffs: dict) -> tuple`` advancing
        one coarse step: ``pdfs`` holds one ``(M, B, Q, X, Y, Z)`` buffer per
        level (``M`` = ensemble members, leading axis), ``coeffs`` maps level
        -> per-member coefficient arrays (leading ``M`` axis, from
        ``collision_coeffs`` stacked across members).
    """
    levels = tuple(sorted(levels))
    index = {l: i for i, l in enumerate(levels)}
    lmax = levels[-1]
    nsub = 1 << lmax
    masks_t = tuple(jnp.asarray(masks[l]) for l in levels)

    def step_level(fb: jax.Array, mb: jax.Array, coeffs: dict) -> jax.Array:
        return jax.vmap(
            lambda f, m: stream_collide_coeffs(
                f, m, coeffs, lattice=lattice, collision=collision
            )
        )(fb, mb)

    def make_branch(p: int):
        active = tuple(l for l in levels if l >= lmax - p)
        ops = _device_plan_ops(plans[p], index)

        def branch(carry):
            pdfs, coeffs = carry
            bufs = _run_plan_ops(ops, list(pdfs))
            for l in sorted(active, reverse=True):  # finest first, matching
                i = index[l]  # the solo fused superstep's kernel order
                bufs[i] = step_level(bufs[i], masks_t[i], coeffs[l])
            return tuple(bufs), coeffs

        return branch

    branches = [make_branch(p) for p in range(lmax + 1)]
    pattern = [
        lmax if s == 0 else min((s & -s).bit_length() - 1, lmax) for s in range(nsub)
    ]

    def member_superstep(pdfs, coeffs):
        carry = (tuple(pdfs), coeffs)
        if nsub <= unroll_limit:
            for s in range(nsub):
                carry = branches[pattern[s]](carry)
            return carry[0]
        pattern_dev = jnp.asarray(pattern, dtype=jnp.int32)

        def body(s, carry):
            return jax.lax.switch(pattern_dev[s], branches, carry)

        return jax.lax.fori_loop(0, nsub, body, carry)[0]

    return jax.jit(jax.vmap(member_superstep, in_axes=(0, 0)))


def make_rank_emit(messages, level_index: dict[int, int]):
    """Compile one rank's message-building side of a sharded exchange.

    ``messages`` are the :class:`~repro.lbm.halo.CompiledRankMessage` specs
    whose ``src_rank`` is this rank; ``level_index`` maps the rank's levels
    to positions in its buffer tuple. Returns a jitted
    ``emit(pdfs: tuple) -> tuple`` producing one device-resident ``(N, C)``
    payload per message (sender-side resampled, segments concatenated in the
    spec's canonical order) — the arrays handed to the ``Comm`` fabric, so
    nothing touches the host. Returns ``None`` when the rank sends nothing.
    """
    if not messages:
        return None
    specs = tuple(
        tuple(
            (level_index[src_level], kind, jnp.asarray(sb), jnp.asarray(sc))
            for src_level, kind, sb, sc in m.gather
        )
        for m in messages
    )

    @jax.jit
    def emit(pdfs):
        out = []
        for segs in specs:
            parts = [_gather_vals(pdfs[li], kind, sb, sc) for li, kind, sb, sc in segs]
            out.append(parts[0] if len(parts) == 1 else jnp.concatenate(parts, axis=0))
        return tuple(out)

    return emit


def make_rank_absorb(
    messages,
    local_plan,
    level_index: dict[int, int],
    steppers,
    masks,
    active_levels,
):
    """Compile one rank's receive+exchange+step side of a sharded substep.

    ``messages`` are the inbound :class:`~repro.lbm.halo.CompiledRankMessage`
    specs (``dst_rank`` == this rank) in plan order — the caller passes the
    received payloads in the same order; ``local_plan`` is the rank's
    intra-rank :class:`~repro.lbm.halo.CompiledGhostPlan` (or None);
    ``steppers``/``masks`` map the rank's levels to ``step(f, mask) -> f``
    kernels and device mask stacks; ``active_levels`` is this substep
    pattern's active set intersected with the rank's levels.

    Returns a jitted ``absorb(pdfs: tuple, msgs: tuple) -> tuple`` that
    scatters inbound payload segments into ghost cells, runs the intra-rank
    exchange, then stream+collides the active levels finest-first — one
    device program per (rank, activity pattern), no host contact.
    """
    scatters = tuple(
        tuple(
            (level_index[dst_level], jnp.asarray(db), jnp.asarray(dc), n)
            for dst_level, db, dc, n in m.scatter
        )
        for m in messages
    )
    local_ops = _device_plan_ops(local_plan, level_index) if local_plan else []
    order = tuple(sorted(active_levels, reverse=True))  # finest first, as the
    masks_t = {l: jnp.asarray(masks[l]) for l in order}  # host driver does

    @jax.jit
    def absorb(pdfs, msgs):
        bufs = list(pdfs)
        for segs, msg in zip(scatters, msgs):
            off = 0
            for li, db, dc, n in segs:
                d = bufs[li]
                bufs[li] = _flat3(d).at[db, :, dc].set(msg[off : off + n]).reshape(d.shape)
                off += n
        bufs = _run_plan_ops(local_ops, bufs)
        for l in order:
            i = level_index[l]
            bufs[i] = steppers[l](bufs[i], masks_t[l])
        return tuple(bufs)

    return absorb


def fused_stream_collide(
    f: jax.Array,
    mask: jax.Array,
    *,
    omega: float,
    lattice: Lattice = D3Q19,
    u_wall: tuple[float, float, float] = (0.0, 0.0, 0.0),
    collision: str = "bgk",
    backend: str = "pallas",
    interpret: bool = True,
) -> jax.Array:
    """One fused stream+collide step over (B, Q, X, Y, Z) block stacks."""
    return make_stream_collide(
        omega=omega,
        lattice=lattice,
        u_wall=u_wall,
        collision=collision,
        backend=backend,
        interpret=interpret,
    )(f, mask)
