"""Jitted public wrapper around the fused LBM stream+collide kernel.

Dispatches between the Pallas kernel (TPU target; interpret mode on CPU) and
the pure-jnp reference (oracle / fallback). All simulation-constant
parameters (lattice, omega, wall velocity, collision model) are closed over
so the jitted step takes only the block stack and the mask.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from ...lbm.lattice import D3Q19, Lattice
from .lbm_collide import lbm_stream_collide_pallas
from .ref import stream_collide_ref

__all__ = ["fused_stream_collide", "make_stream_collide", "make_arena_stream_collide"]


def make_stream_collide(
    *,
    omega: float,
    lattice: Lattice = D3Q19,
    u_wall: tuple[float, float, float] = (0.0, 0.0, 0.0),
    collision: str = "bgk",
    backend: str = "pallas",  # "pallas" | "ref"
    interpret: bool = True,
):
    """Build a jitted ``step(f_blocks, mask_blocks) -> f_blocks`` function."""

    if backend == "pallas":

        @jax.jit
        def step(f: jax.Array, mask: jax.Array) -> jax.Array:
            return lbm_stream_collide_pallas(
                f,
                mask,
                omega=omega,
                lattice=lattice,
                u_wall=u_wall,
                collision=collision,
                interpret=interpret,
            )

    elif backend == "ref":
        ref = functools.partial(
            stream_collide_ref,
            omega=omega,
            lattice=lattice,
            u_wall=u_wall,
            collision=collision,
        )

        @jax.jit
        def step(f: jax.Array, mask: jax.Array) -> jax.Array:
            return jax.vmap(ref)(f, mask)

    else:
        raise ValueError(f"unknown backend {backend!r}")

    return step


def make_arena_stream_collide(
    *,
    omega: float,
    lattice: Lattice = D3Q19,
    u_wall: tuple[float, float, float] = (0.0, 0.0, 0.0),
    collision: str = "bgk",
    backend: str = "pallas",
    interpret: bool = True,
):
    """Arena entry point: an in-place ``step(f_buf, mask) -> None`` over a
    persistent :class:`~repro.core.fields.LevelArena` buffer.

    ``f_buf`` is the level's contiguous ``(B, Q, X, Y, Z)`` SoA buffer; it is
    handed to the fused kernel whole (one host->device transfer, no
    per-block restacking) and the result is written back into the same
    buffer, so all per-block views bound by the arena stay valid. ``mask``
    may be a precomputed device array — masks only change on AMR events, so
    callers can cache the transfer across substeps.
    """
    step = make_stream_collide(
        omega=omega,
        lattice=lattice,
        u_wall=u_wall,
        collision=collision,
        backend=backend,
        interpret=interpret,
    )

    def step_arena(f_buf: np.ndarray, mask: jax.Array | np.ndarray) -> None:
        out = step(jnp.asarray(f_buf), jnp.asarray(mask))
        np.copyto(f_buf, np.asarray(out))

    return step_arena


def fused_stream_collide(
    f: jax.Array,
    mask: jax.Array,
    *,
    omega: float,
    lattice: Lattice = D3Q19,
    u_wall: tuple[float, float, float] = (0.0, 0.0, 0.0),
    collision: str = "bgk",
    backend: str = "pallas",
    interpret: bool = True,
) -> jax.Array:
    """One fused stream+collide step over (B, Q, X, Y, Z) block stacks."""
    return make_stream_collide(
        omega=omega,
        lattice=lattice,
        u_wall=u_wall,
        collision=collision,
        backend=backend,
        interpret=interpret,
    )(f, mask)
