"""Pure-jnp oracle for the fused LBM stream+collide step.

One fused update of a single block array ``f`` of shape ``(Q, X, Y, Z)``
holding *post-collision* PDFs:

1. **pull streaming** with halfway bounce-back: the population arriving at
   cell ``x`` along ``c_q`` is ``f_q(x - c_q)`` if the source cell is fluid;
   if the source is a wall, it is the reflected own population
   ``f_opp(q)(x)`` plus the moving-wall momentum term
   ``6 w_q (c_q . u_wall)`` (velocity bounce-back, paper §5.1.1's lid);
2. **collision**: BGK or TRT (magic parameter 3/16, paper §5.2).

Rolls wrap around array edges, so with an all-fluid mask the block behaves
as a fully periodic box (used by the physics tests); in the AMR driver the
outermost layer is a ghost layer refreshed by halo exchange before every
step, making the wrapped values irrelevant.

Cell types: 0 = fluid, 1 = no-slip obstacle, 2 = moving wall (``u_wall``).
Non-fluid cells keep their PDF values unchanged.
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from ...lbm.lattice import D3Q19, Lattice

__all__ = [
    "stream_collide_ref",
    "stream_collide_coeffs",
    "collision_coeffs",
    "precompute_stream_masks",
    "equilibrium",
    "moments",
    "CT_FLUID",
    "CT_WALL",
    "CT_LID",
]

CT_FLUID = 0
CT_WALL = 1
CT_LID = 2


def moments(f: jnp.ndarray, lattice: Lattice) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Density (X,Y,Z) and velocity (3,X,Y,Z) from PDFs (Q,X,Y,Z)."""
    c = jnp.asarray(lattice.c, dtype=f.dtype)  # (Q,3)
    rho = jnp.sum(f, axis=0)
    mom = jnp.einsum("qxyz,qd->dxyz", f, c)
    u = mom / rho[None]
    return rho, u


def equilibrium(rho: jnp.ndarray, u: jnp.ndarray, lattice: Lattice) -> jnp.ndarray:
    """Second-order Maxwell equilibrium, shape (Q, X, Y, Z)."""
    c = jnp.asarray(lattice.c, dtype=rho.dtype)  # (Q,3)
    w = jnp.asarray(lattice.w, dtype=rho.dtype)  # (Q,)
    cu = jnp.einsum("qd,dxyz->qxyz", c, u)  # (Q,X,Y,Z)
    usq = jnp.sum(u * u, axis=0)  # (X,Y,Z)
    return w[:, None, None, None] * rho[None] * (
        1.0 + 3.0 * cu + 4.5 * cu * cu - 1.5 * usq[None]
    )


def collision_coeffs(
    omega: float,
    *,
    lattice: Lattice = D3Q19,
    u_wall: tuple[float, float, float] = (0.0, 0.0, 0.0),
    collision: str = "bgk",
    magic: float = 3.0 / 16.0,
    dtype=np.float32,
) -> dict[str, np.ndarray]:
    """Host-derived per-step scalar coefficients for :func:`stream_collide_coeffs`.

    Every omega/u_wall-dependent quantity the kernel consumes is reduced here
    to a small set of dtype-precision scalars (and one ``(Q,)`` lid vector),
    computed in float64 exactly like the original closure path. The kernel
    then only ever combines them as ``coefficient * array``, so passing them
    as *traced* operands (the batched ensemble path, one value per member)
    produces bitwise-identical results to baking them in as constants (the
    single-run path): the rounding to ``dtype`` happens here, once, either way.
    """
    c = np.asarray(lattice.c)  # repro: host-ok(lattice constants are host numpy, folded into the program)
    w = np.asarray(lattice.w)  # repro: host-ok(lattice constants are host numpy, folded into the program)
    uw = np.asarray(u_wall, dtype=np.float64)  # repro: host-ok(lattice constants are host numpy, folded into the program)
    # velocity bounce-back momentum term per direction: 6 w_q (c_q . u_wall)
    lid = np.array(
        [6.0 * w[q] * float(c[q] @ uw) for q in range(lattice.Q)], dtype=dtype
    )
    if collision == "bgk":
        return {"lid": lid, "om": dtype(omega)}
    if collision == "trt":
        tau_plus = 1.0 / omega
        tau_minus = magic / (tau_plus - 0.5) + 0.5
        return {
            "lid": lid,
            "om_p": dtype(1.0 / tau_plus),
            "om_m": dtype(1.0 / tau_minus),
        }
    raise ValueError(f"unknown collision model {collision!r}")


def precompute_stream_masks(mask, lattice: Lattice = D3Q19) -> dict[str, np.ndarray]:
    """Hoist the mask-derived streaming selectors out of the kernel.

    The cell-type mask only changes at AMR events, yet the kernel re-rolls it
    (and re-compares against the cell-type codes) for every direction, every
    substep. For the compiled superstep paths — where the mask is a build-time
    constant — this precomputes, on the host, exactly the booleans the kernel
    derives: ``fluid_src[q]`` / ``lid_src[q]`` are the rolled-mask comparisons
    for direction ``q``, ``fluid`` is the local-cell selector. Feeding them
    through :func:`stream_collide_coeffs`'s ``premask`` argument produces
    bitwise-identical results (identical booleans drive identical selects).

    ``mask`` may be a single block ``(X, Y, Z)`` or a stack ``(B, X, Y, Z)``;
    rolls act on the trailing three axes and the ``q`` axis leads:
    ``fluid_src``/``lid_src`` are ``(Q, *mask.shape)`` bool.
    """
    # repro: host-ok(mask selector precompute is host-side by design, once per program build)
    m = np.asarray(mask)
    Q = lattice.Q
    c = np.asarray(lattice.c)  # repro: host-ok(lattice constants are host numpy, folded into the program)
    fluid_src = np.empty((Q,) + m.shape, dtype=bool)
    lid_src = np.empty((Q,) + m.shape, dtype=bool)
    for q in range(Q):
        rolled = np.roll(
            m, shift=(int(c[q, 0]), int(c[q, 1]), int(c[q, 2])), axis=(-3, -2, -1)
        )
        fluid_src[q] = rolled == CT_FLUID
        lid_src[q] = rolled == CT_LID
    return {"fluid_src": fluid_src, "lid_src": lid_src, "fluid": m == CT_FLUID}


def stream_collide_coeffs(
    f: jnp.ndarray,
    mask: jnp.ndarray | None,
    coeffs: dict,
    *,
    lattice: Lattice = D3Q19,
    collision: str = "bgk",
    premask: dict | None = None,
) -> jnp.ndarray:
    """One fused stream+collide step on a single block (Q, X, Y, Z).

    ``coeffs`` comes from :func:`collision_coeffs` and may hold either host
    scalars (closed over as constants — the classic path) or traced arrays
    (per-member physics parameters under ``vmap`` — the ensemble path); both
    execute the identical op sequence. When ``premask`` (from
    :func:`precompute_stream_masks`) is given, the mask rolls/compares are
    skipped in favor of the precomputed selectors and ``mask`` may be None.
    """
    dtype = f.dtype
    Q = lattice.Q
    c = np.asarray(lattice.c)  # repro: host-ok(lattice constants are host numpy, folded into the program)
    opp = np.asarray(lattice.opposite)  # repro: host-ok(lattice constants are host numpy, folded into the program)
    lid = coeffs["lid"]

    # -- pull streaming with bounce-back ------------------------------------
    f_in = []
    for q in range(Q):
        cq = c[q]
        pulled = jnp.roll(f[q], shift=(int(cq[0]), int(cq[1]), int(cq[2])), axis=(0, 1, 2))
        if premask is not None:
            is_fluid_src = premask["fluid_src"][q]
            is_lid_src = premask["lid_src"][q]
        else:
            src_mask = jnp.roll(
                mask, shift=(int(cq[0]), int(cq[1]), int(cq[2])), axis=(0, 1, 2)
            )
            is_fluid_src = src_mask == CT_FLUID
            is_lid_src = src_mask == CT_LID
        bounced = f[opp[q]] + lid[q] * is_lid_src.astype(dtype)
        f_in.append(jnp.where(is_fluid_src, pulled, bounced))
    f_in = jnp.stack(f_in)

    # -- collision -------------------------------------------------------------
    rho, u = moments(f_in, lattice)
    feq = equilibrium(rho, u, lattice)
    if collision == "bgk":
        f_out = f_in + coeffs["om"] * (feq - f_in)
    elif collision == "trt":
        om_p = coeffs["om_p"]
        om_m = coeffs["om_m"]
        f_opp_in = f_in[opp]
        feq_opp = feq[opp]
        f_plus = 0.5 * (f_in + f_opp_in)
        f_minus = 0.5 * (f_in - f_opp_in)
        feq_plus = 0.5 * (feq + feq_opp)
        feq_minus = 0.5 * (feq - feq_opp)
        f_out = f_in - om_p * (f_plus - feq_plus) - om_m * (f_minus - feq_minus)
    else:
        raise ValueError(f"unknown collision model {collision!r}")

    if premask is not None:
        fluid = jnp.asarray(premask["fluid"])[None].astype(dtype)
    else:
        fluid = (mask == CT_FLUID)[None].astype(dtype)
    return f_out * fluid + f * (1 - fluid)


def stream_collide_ref(
    f: jnp.ndarray,
    mask: jnp.ndarray,
    omega: float,
    lattice: Lattice = D3Q19,
    u_wall: tuple[float, float, float] = (0.0, 0.0, 0.0),
    collision: str = "bgk",
    magic: float = 3.0 / 16.0,
) -> jnp.ndarray:
    """One fused stream+collide step on a single block (Q, X, Y, Z)."""
    coeffs = collision_coeffs(
        omega,
        lattice=lattice,
        u_wall=u_wall,
        collision=collision,
        magic=magic,
        dtype=f.dtype.type,
    )
    return stream_collide_coeffs(f, mask, coeffs, lattice=lattice, collision=collision)
