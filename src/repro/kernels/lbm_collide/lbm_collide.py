"""Pallas TPU kernel: fused LBM stream+collide, one AMR block per grid step.

TPU adaptation of the paper's compute hot loop (§3/§5: the D3Q19/D3Q27
stream-collide accounts for nearly all FLOPs of the simulation):

* The AMR domain partitioning already tiles the mesh into fixed-size blocks
  (e.g. 34^3 cells incl. ghost layer, paper Fig. 16). One such block in f32
  D3Q19 is ~3 MB — it fits VMEM whole. We therefore map **one AMR block per
  Pallas grid step**: ``grid=(num_blocks,)`` with a full-block BlockSpec, so
  each step runs entirely out of VMEM with a single HBM round-trip per
  block, the optimum for this memory-bound kernel (AI ~ 1.5 flop/byte).
* Streaming is realized as static single-cell rolls of VMEM-resident planes
  (vector shifts on the VPU — no MXU work exists in LBM), fused with the
  collision so PDFs are read and written exactly once per time step.
* The ghost layer travels with the block. The plain entry point
  (:func:`lbm_stream_collide_pallas`) leaves halo exchange entirely to the
  halo/driver layer; the halo-aware entry point
  (:func:`lbm_stream_collide_halo_pallas`) additionally takes the block's
  exchanged ghost values as a compact per-block operand and scatters them
  into the VMEM-resident tile *before* streaming — the superstep no longer
  materializes a separately exchanged full buffer between kernel calls.

The kernels are validated against ``ref.stream_collide_ref`` in interpret
mode (this container is CPU-only); on TPU the same ``pallas_call`` lowers
with the block resident in VMEM. Whether to interpret is resolved once at
program-build time from the active JAX backend (see :func:`resolve_interpret`).
For best TPU layout the innermost (Z) extent should be padded to the
128-lane width by the caller; correctness does not depend on it.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from ...lbm.lattice import D3Q19, Lattice
from .ref import CT_FLUID, CT_LID

__all__ = [
    "lbm_stream_collide_pallas",
    "lbm_stream_collide_halo_pallas",
    "resolve_interpret",
    "resolve_donate",
]


def resolve_interpret(interpret: bool | None = None) -> bool:
    """Resolve the Pallas ``interpret`` flag once, at program-build time.

    ``None`` (the default everywhere) means "interpret exactly when the
    active JAX backend is CPU": on a real TPU/GPU the kernel lowers natively,
    on this CPU-only container it runs the interpreter. Passing an explicit
    bool overrides the backend probe (e.g. forcing interpret mode on an
    accelerator to debug a lowering issue). Callers resolve *before* closing
    the flag into a jitted program so the decision is taken exactly once per
    program build, not per trace."""
    if interpret is None:
        return jax.default_backend() == "cpu"
    return bool(interpret)


def resolve_donate(donate: bool | None = None) -> bool:
    """Resolve the superstep buffer-donation flag once, at program-build time.

    ``None`` (the default everywhere) means "donate exactly when the active
    JAX backend is *not* CPU". On an accelerator the compiled substep
    programs are memory-bound and donating the double-buffered pdf tuple
    (``donate_argnums``) lets XLA ping-pong them in place, halving the HBM
    footprint and eliminating the output allocation per substep. On XLA:CPU,
    however, input/output aliasing feeds into LLVM's codegen (vectorization
    and FMA contraction decisions change with the buffer assignment) and the
    compiled stencil can differ from the undonated one by one ulp — measured
    and deterministic, but enough to break the repo's bitwise conformance
    contract between the fused modes and the host ``restack`` reference
    (``--xla_cpu_enable_fast_math=false`` does not remove it). So the CPU
    default keeps the value-identical path; an explicit bool overrides the
    probe in either direction (the donation tests force ``True``)."""
    if donate is None:
        return jax.default_backend() != "cpu"
    return bool(donate)


def _stream_collide_body(
    f,
    mask,
    *,
    lattice: Lattice,
    omega: float,
    u_wall: tuple[float, float, float],
    collision: str,
    magic: float,
):
    """Shared stream+collide body on one VMEM-resident (Q, X, Y, Z) block."""
    dtype = f.dtype
    Q = lattice.Q
    c = np.asarray(lattice.c)  # repro: host-ok(lattice constants are host numpy, baked into the traced program)
    w = np.asarray(lattice.w)  # repro: host-ok(lattice constants are host numpy, baked into the traced program)
    opp = np.asarray(lattice.opposite)  # repro: host-ok(lattice constants are host numpy, baked into the traced program)
    uw = np.asarray(u_wall, dtype=np.float64)  # repro: host-ok(lattice constants are host numpy, baked into the traced program)

    is_fluid_src = []
    pulled = []
    for q in range(Q):
        sh = (int(c[q, 0]), int(c[q, 1]), int(c[q, 2]))
        pulled.append(jnp.roll(f[q], shift=sh, axis=(0, 1, 2)))
        is_fluid_src.append(jnp.roll(mask, shift=sh, axis=(0, 1, 2)))

    f_in = []
    for q in range(Q):
        lid_term = dtype.type(6.0 * w[q] * float(c[q] @ uw))
        bounced = f[opp[q]] + lid_term * (is_fluid_src[q] == CT_LID).astype(dtype)
        f_in.append(jnp.where(is_fluid_src[q] == CT_FLUID, pulled[q], bounced))

    # moments (unrolled over Q -> pure VPU element-wise work)
    rho = f_in[0]
    for q in range(1, Q):
        rho = rho + f_in[q]
    ux = uy = uz = jnp.zeros_like(rho)
    for q in range(Q):
        if c[q, 0]:
            ux = ux + dtype.type(float(c[q, 0])) * f_in[q]
        if c[q, 1]:
            uy = uy + dtype.type(float(c[q, 1])) * f_in[q]
        if c[q, 2]:
            uz = uz + dtype.type(float(c[q, 2])) * f_in[q]
    inv_rho = 1.0 / rho
    ux, uy, uz = ux * inv_rho, uy * inv_rho, uz * inv_rho
    usq = ux * ux + uy * uy + uz * uz

    feq = []
    for q in range(Q):
        cu = jnp.zeros_like(rho)
        if c[q, 0]:
            cu = cu + dtype.type(float(c[q, 0])) * ux
        if c[q, 1]:
            cu = cu + dtype.type(float(c[q, 1])) * uy
        if c[q, 2]:
            cu = cu + dtype.type(float(c[q, 2])) * uz
        feq.append(
            dtype.type(w[q])
            * rho
            * (1.0 + 3.0 * cu + 4.5 * cu * cu - 1.5 * usq)
        )

    if collision == "bgk":
        om = dtype.type(omega)
        f_out = [f_in[q] + om * (feq[q] - f_in[q]) for q in range(Q)]
    elif collision == "trt":
        tau_plus = 1.0 / omega
        tau_minus = magic / (tau_plus - 0.5) + 0.5
        om_p = dtype.type(1.0 / tau_plus)
        om_m = dtype.type(1.0 / tau_minus)
        f_out = []
        for q in range(Q):
            qo = int(opp[q])
            f_p = 0.5 * (f_in[q] + f_in[qo])
            f_m = 0.5 * (f_in[q] - f_in[qo])
            fe_p = 0.5 * (feq[q] + feq[qo])
            fe_m = 0.5 * (feq[q] - feq[qo])
            f_out.append(f_in[q] - om_p * (f_p - fe_p) - om_m * (f_m - fe_m))
    else:
        raise ValueError(f"unknown collision model {collision!r}")

    fluid = (mask == CT_FLUID).astype(dtype)
    return jnp.stack([f_out[q] * fluid + f[q] * (1 - fluid) for q in range(Q)])


def _kernel(f_ref, mask_ref, out_ref, **cfg):
    f = f_ref[0]  # (Q, X, Y, Z) resident in VMEM
    mask = mask_ref[0]  # (X, Y, Z)
    out_ref[0] = _stream_collide_body(f, mask, **cfg)


def _halo_kernel(f_ref, mask_ref, hv_ref, hc_ref, hm_ref, out_ref, **cfg):
    """Halo-aware variant: scatter the block's exchanged ghost values into
    the VMEM tile, then stream+collide — the ghost gather is fused into the
    stencil read instead of being materialized as a full exchanged buffer.

    ``hv`` is the per-block padded (P, Q) ghost-value slab, ``hc`` the (P,)
    flat cell ids in the ghosted box, ``hm`` the (P,) validity mask. Padding
    rows all point at one interior cell that is never a halo target and
    write back its current value, so the scatter has no conflicting
    duplicate targets and padded entries are exact no-ops — the fill is
    deterministic and bitwise equal to the unpadded jnp scatter."""
    f = f_ref[0]
    mask = mask_ref[0]
    hv = hv_ref[0]  # (P, Q)
    hc = hc_ref[0]  # (P,)
    hm = hm_ref[0]  # (P,)
    Q = f.shape[0]
    flat = f.reshape(Q, -1)
    cur = flat[:, hc]  # (Q, P)
    new = jnp.where(hm[None, :], hv.T, cur)
    f = flat.at[:, hc].set(new).reshape(f.shape)
    out_ref[0] = _stream_collide_body(f, mask, **cfg)


def _cfg(omega, lattice, u_wall, collision, magic):
    return dict(
        lattice=lattice,
        omega=float(omega),
        u_wall=tuple(float(v) for v in u_wall),
        collision=collision,
        magic=float(magic),
    )


def lbm_stream_collide_pallas(
    f: jax.Array,
    mask: jax.Array,
    *,
    omega: float,
    lattice: Lattice = D3Q19,
    u_wall: tuple[float, float, float] = (0.0, 0.0, 0.0),
    collision: str = "bgk",
    magic: float = 3.0 / 16.0,
    interpret: bool | None = None,
) -> jax.Array:
    """Fused stream+collide over a stack of blocks.

    Args:
      f:    (B, Q, X, Y, Z) post-collision PDFs (ghost layer included).
      mask: (B, X, Y, Z) int32 cell types (0 fluid / 1 wall / 2 lid).
      interpret: None (default) resolves per :func:`resolve_interpret`.
    Returns:
      (B, Q, X, Y, Z) updated PDFs.
    """
    B, Q, X, Y, Z = f.shape
    assert mask.shape == (B, X, Y, Z), (f.shape, mask.shape)
    kern = functools.partial(_kernel, **_cfg(omega, lattice, u_wall, collision, magic))
    return pl.pallas_call(
        kern,
        grid=(B,),
        in_specs=[
            pl.BlockSpec((1, Q, X, Y, Z), lambda b: (b, 0, 0, 0, 0)),
            pl.BlockSpec((1, X, Y, Z), lambda b: (b, 0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, Q, X, Y, Z), lambda b: (b, 0, 0, 0, 0)),
        out_shape=jax.ShapeDtypeStruct(f.shape, f.dtype),
        interpret=resolve_interpret(interpret),
    )(f, mask)


def lbm_stream_collide_halo_pallas(
    f: jax.Array,
    mask: jax.Array,
    halo_vals: jax.Array,
    halo_cell: jax.Array,
    halo_valid: jax.Array,
    *,
    omega: float,
    lattice: Lattice = D3Q19,
    u_wall: tuple[float, float, float] = (0.0, 0.0, 0.0),
    collision: str = "bgk",
    magic: float = 3.0 / 16.0,
    interpret: bool | None = None,
) -> jax.Array:
    """Halo-aware fused ghost-fill + stream+collide over a stack of blocks.

    The kernel's tile effectively grows to cover the ghost ring: each grid
    step receives, alongside its (Q, X, Y, Z) block, a compact padded slab of
    the exchanged ghost values for that block and writes them into the tile
    before the stencil reads — no intermediate exchanged buffer exists
    between the gather and the stencil.

    Args:
      f:          (B, Q, X, Y, Z) post-collision PDFs (ghost layer included).
      mask:       (B, X, Y, Z) int32 cell types.
      halo_vals:  (B, P, Q) padded per-block ghost values (P = max fills per
                  block; rows beyond a block's count are padding).
      halo_cell:  (B, P) int32 flat cell ids into the ghosted (X, Y, Z) box;
                  padding rows point at a never-targeted interior cell.
      halo_valid: (B, P) bool; False rows are written back unchanged.
      interpret:  None (default) resolves per :func:`resolve_interpret`.
    Returns:
      (B, Q, X, Y, Z) updated PDFs.
    """
    B, Q, X, Y, Z = f.shape
    P = halo_cell.shape[1]
    assert mask.shape == (B, X, Y, Z), (f.shape, mask.shape)
    assert halo_vals.shape == (B, P, Q), (halo_vals.shape, (B, P, Q))
    assert halo_valid.shape == (B, P), (halo_valid.shape, (B, P))
    kern = functools.partial(
        _halo_kernel, **_cfg(omega, lattice, u_wall, collision, magic)
    )
    return pl.pallas_call(
        kern,
        grid=(B,),
        in_specs=[
            pl.BlockSpec((1, Q, X, Y, Z), lambda b: (b, 0, 0, 0, 0)),
            pl.BlockSpec((1, X, Y, Z), lambda b: (b, 0, 0, 0)),
            pl.BlockSpec((1, P, Q), lambda b: (b, 0, 0)),
            pl.BlockSpec((1, P), lambda b: (b, 0)),
            pl.BlockSpec((1, P), lambda b: (b, 0)),
        ],
        out_specs=pl.BlockSpec((1, Q, X, Y, Z), lambda b: (b, 0, 0, 0, 0)),
        out_shape=jax.ShapeDtypeStruct(f.shape, f.dtype),
        interpret=resolve_interpret(interpret),
    )(f, mask, halo_vals, halo_cell, halo_valid)
