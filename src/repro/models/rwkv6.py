"""RWKV-6 "Finch" block: time-mix with data-dependent per-channel decay.

The WKV recurrence ``S_t = diag(w_t) S_{t-1} + k_t^T v_t`` with
``y_t = r_t (S_{t-1} + diag(u) k_t^T v_t)`` is evaluated with a two-level
scan (TPU adaptation):

* an *intra-chunk* scan over the chunk positions (depth = chunk length,
  vectorized over all chunks/heads — exact and numerically stable for
  arbitrary data-dependent decays, which rules out the factored
  exp-of-cumsum form: its one-sided exponents overflow f32);
* an *inter-chunk* scan over chunk-end states, where the carried state is
  decayed by the chunk's total decay (exponents <= 0, safe) — depth T/chunk.

Total sequential depth is chunk + T/chunk instead of T. Decode carries the
(heads, hd, hd) state and the previous token (for token-shift) — O(1)/token,
which is what makes the rwkv6 ``long_500k`` cell run without a KV cache.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["rwkv6_time_mix", "rwkv6_channel_mix", "rwkv6_decode_step", "rwkv6_init_cache"]


def _token_shift(x: jax.Array, prev: jax.Array | None = None) -> jax.Array:
    """x_{t-1} (zeros / `prev` for the first position)."""
    first = jnp.zeros_like(x[:, :1]) if prev is None else prev[:, None]
    return jnp.concatenate([first, x[:, :-1]], axis=1)


def _mix_inputs(x: jax.Array, xs: jax.Array, p: dict):
    out = {}
    for name in ("r", "k", "v", "g", "w"):
        mu = p[f"mu_{name}"].astype(x.dtype)
        out[name] = x + mu * (xs - x)
    return out


def _decay(xw: jax.Array, p: dict) -> jax.Array:
    """Data-dependent decay (the Finch contribution): per channel, per token.
    w = exp(-exp(w0 + tanh(x @ A) @ B)) in (0, 1)."""
    lora = jnp.tanh(xw @ p["w_lora_a"]) @ p["w_lora_b"]
    return -jnp.exp(p["w0"].astype(jnp.float32) + lora.astype(jnp.float32))  # log w <= 0


def rwkv6_time_mix(
    x: jax.Array,  # (B, S, D)
    p: dict,
    *,
    n_heads: int,
    head_dim: int,
    chunk: int = 64,
    shift_prev: jax.Array | None = None,
    wsc=None,
) -> jax.Array:
    B, S, D = x.shape
    H, hd = n_heads, head_dim
    wsc = wsc or (lambda a, dims: a)
    xs = _token_shift(x, shift_prev)
    m = _mix_inputs(x, xs, p)
    r = wsc((m["r"] @ p["w_r"]).reshape(B, S, H, hd), "b.m.").astype(jnp.float32)
    k = wsc((m["k"] @ p["w_k"]).reshape(B, S, H, hd), "b.m.").astype(jnp.float32)
    v = wsc((m["v"] @ p["w_v"]).reshape(B, S, H, hd), "b.m.").astype(jnp.float32)
    g = jax.nn.silu(m["g"] @ p["w_g"])
    logw = wsc(_decay(m["w"], p).reshape(B, S, H, hd), "b.m.")  # log-decay
    u = p["u"].astype(jnp.float32)  # (H, hd)

    L = min(chunk, S)
    pad = -S % L
    if pad:
        r, k, v = (jnp.pad(a, ((0, 0), (0, pad), (0, 0), (0, 0))) for a in (r, k, v))
        logw = jnp.pad(logw, ((0, 0), (0, pad), (0, 0), (0, 0)))
    nc = (S + pad) // L
    rc = r.reshape(B, nc, L, H, hd)
    kc = k.reshape(B, nc, L, H, hd)
    vc = v.reshape(B, nc, L, H, hd)
    wc = jnp.exp(logw.reshape(B, nc, L, H, hd))  # decays in (0,1]

    # -- intra-chunk scan over positions (vectorized over B, nc, H) ----------
    def intra_step(S_state, inp):
        r_t, k_t, v_t, w_t = inp  # (B,nc,H,hd)
        kv = k_t[..., :, None] * v_t[..., None, :]  # (B,nc,H,hd,hd)
        y_t = jnp.einsum(
            "bchd,bchde->bche", r_t, S_state + u[None, None, :, :, None] * kv
        )
        S_new = S_state * w_t[..., None] + kv
        return S_new, y_t

    S0 = jnp.zeros((B, nc, H, hd, hd), dtype=jnp.float32)
    S_end, y_intra = jax.lax.scan(
        intra_step,
        S0,
        (
            rc.transpose(2, 0, 1, 3, 4),
            kc.transpose(2, 0, 1, 3, 4),
            vc.transpose(2, 0, 1, 3, 4),
            wc.transpose(2, 0, 1, 3, 4),
        ),
    )
    y_intra = y_intra.transpose(1, 2, 0, 3, 4)  # (B,nc,L,H,hd)
    # NOTE: S_end here was accumulated *without* inter-chunk initial state —
    # linearity of the recurrence lets us add the carried part separately.

    # -- inter-chunk scan over chunk states -----------------------------------
    cum_w = jnp.cumsum(logw.reshape(B, nc, L, H, hd), axis=2)  # (B,nc,L,H,hd)
    total_decay = jnp.exp(cum_w[:, :, -1])  # (B,nc,H,hd)

    def inter_step(Hs, inp):
        s_end, dec = inp  # (B,H,hd,hd), (B,H,hd)
        H_new = Hs * dec[..., None] + s_end
        return H_new, Hs

    H0 = jnp.zeros((B, H, hd, hd), dtype=jnp.float32)
    _, H_prev = jax.lax.scan(
        inter_step,
        H0,
        (S_end.transpose(1, 0, 2, 3, 4), total_decay.transpose(1, 0, 2, 3)),
    )
    H_prev = H_prev.transpose(1, 0, 2, 3, 4)  # (B,nc,H,hd,hd)
    # carried contribution: r_t decayed from chunk start attends H_prev
    decay_from_start = jnp.exp(cum_w - logw.reshape(B, nc, L, H, hd))  # exp(cum_{t-1})
    r_dec = rc * decay_from_start
    y_inter = jnp.einsum("bclhd,bchde->bclhe", r_dec, H_prev)

    y = (y_intra + y_inter).reshape(B, S + pad, H, hd)[:, :S]
    # per-head group norm, then gate and output projection
    mean = y.mean(axis=-1, keepdims=True)
    var = y.var(axis=-1, keepdims=True)
    y = (y - mean) * jax.lax.rsqrt(var + 64e-5)
    y = y * p["ln_x_scale"].reshape(H, hd) + p["ln_x_bias"].reshape(H, hd)
    y = y.reshape(B, S, D).astype(x.dtype) * g.astype(x.dtype)
    return y @ p["w_o"]


def rwkv6_channel_mix(x: jax.Array, p: dict, shift_prev: jax.Array | None = None) -> jax.Array:
    xs = _token_shift(x, shift_prev)
    xk = x + p["mu_ck"].astype(x.dtype) * (xs - x)
    xr = x + p["mu_cr"].astype(x.dtype) * (xs - x)
    k = jnp.square(jax.nn.relu(xk @ p["w_ck"]))
    return jax.nn.sigmoid(xr @ p["w_cr"]) * (k @ p["w_cv"])


def rwkv6_init_cache(batch: int, d_model: int, n_heads: int, head_dim: int):
    """Per-layer recurrent state: token-shift slots for both mixes + WKV."""
    return {
        "shift_t": jnp.zeros((batch, d_model), dtype=jnp.float32),
        "shift_c": jnp.zeros((batch, d_model), dtype=jnp.float32),
        "wkv": jnp.zeros((batch, n_heads, head_dim, head_dim), dtype=jnp.float32),
    }


def rwkv6_time_mix_step(
    xt: jax.Array,  # (B, D) — normalized layer input at this position
    shift_prev: jax.Array,  # (B, D)
    wkv: jax.Array,  # (B, H, hd, hd)
    p: dict,
    *,
    n_heads: int,
    head_dim: int,
) -> tuple[jax.Array, jax.Array]:
    """One-token time mix; returns (y (B,D), new wkv state)."""
    B, D = xt.shape
    H, hd = n_heads, head_dim
    xs = shift_prev.astype(xt.dtype)
    m = _mix_inputs(xt, xs, p)
    r = (m["r"] @ p["w_r"]).reshape(B, H, hd).astype(jnp.float32)
    k = (m["k"] @ p["w_k"]).reshape(B, H, hd).astype(jnp.float32)
    v = (m["v"] @ p["w_v"]).reshape(B, H, hd).astype(jnp.float32)
    g = jax.nn.silu(m["g"] @ p["w_g"])
    w = jnp.exp(_decay(m["w"], p).reshape(B, H, hd))
    u = p["u"].astype(jnp.float32)

    kv = k[..., :, None] * v[..., None, :]
    y = jnp.einsum("bhd,bhde->bhe", r, wkv + u[None, :, :, None] * kv)
    wkv_new = wkv * w[..., None] + kv

    mean = y.mean(axis=-1, keepdims=True)
    var = y.var(axis=-1, keepdims=True)
    y = (y - mean) * jax.lax.rsqrt(var + 64e-5)
    y = y * p["ln_x_scale"].reshape(H, hd) + p["ln_x_bias"].reshape(H, hd)
    y = y.reshape(B, D).astype(xt.dtype) * g.astype(xt.dtype)
    return y @ p["w_o"], wkv_new


def rwkv6_channel_mix_step(
    xt: jax.Array, shift_prev: jax.Array, p: dict
) -> jax.Array:
    xs = shift_prev.astype(xt.dtype)
    xk = xt + p["mu_ck"].astype(xt.dtype) * (xs - xt)
    xr = xt + p["mu_cr"].astype(xt.dtype) * (xs - xt)
    k = jnp.square(jax.nn.relu(xk @ p["w_ck"]))
    return jax.nn.sigmoid(xr @ p["w_cr"]) * (k @ p["w_cv"])
