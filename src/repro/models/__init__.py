"""Model zoo for the assigned architectures (JAX, scan-based layer stacks)."""

from .zoo import build_model, Model

__all__ = ["build_model", "Model"]
