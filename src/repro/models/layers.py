"""Shared layer primitives: norms, RoPE / M-RoPE, MLPs, embeddings."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "rms_norm",
    "layer_norm",
    "norm",
    "rope_freqs",
    "apply_rope",
    "mrope_freqs",
    "mlp",
    "init_linear",
]


def rms_norm(x: jax.Array, scale: jax.Array | None, eps: float = 1e-5) -> jax.Array:
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    y = (x.astype(jnp.float32) * jax.lax.rsqrt(var + eps)).astype(x.dtype)
    return y * scale.astype(x.dtype) if scale is not None else y


def layer_norm(
    x: jax.Array,
    scale: jax.Array | None,
    bias: jax.Array | None,
    eps: float = 1e-5,
) -> jax.Array:
    xf = x.astype(jnp.float32)
    mean = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = ((xf - mean) * jax.lax.rsqrt(var + eps)).astype(x.dtype)
    if scale is not None:
        y = y * scale.astype(x.dtype)
    if bias is not None:
        y = y + bias.astype(x.dtype)
    return y


def norm(x: jax.Array, params: dict | None, kind: str) -> jax.Array:
    """Dispatch on norm kind; ``params`` may be None (non-parametric, olmo)."""
    if kind == "rmsnorm":
        return rms_norm(x, None if params is None else params.get("scale"))
    return layer_norm(
        x,
        None if params is None else params.get("scale"),
        None if params is None else params.get("bias"),
    )


# -- rotary embeddings --------------------------------------------------------


def rope_freqs(positions: jax.Array, head_dim: int, theta: float) -> tuple[jax.Array, jax.Array]:
    """cos/sin tables, shape positions.shape + (head_dim//2,)."""
    inv = 1.0 / (theta ** (np.arange(0, head_dim, 2) / head_dim))
    ang = positions[..., None].astype(jnp.float32) * inv[None]
    return jnp.cos(ang), jnp.sin(ang)


def mrope_freqs(
    positions: jax.Array,  # (B, 3, S): temporal / height / width position ids
    head_dim: int,
    theta: float,
    sections: tuple[int, int, int],
) -> tuple[jax.Array, jax.Array]:
    """M-RoPE (qwen2-vl): the head_dim/2 frequency slots are split into
    (t, h, w) sections, each driven by its own position stream."""
    assert sum(sections) == head_dim // 2, (sections, head_dim)
    inv = 1.0 / (theta ** (np.arange(0, head_dim, 2) / head_dim))
    ang_all = positions[..., None].astype(jnp.float32) * inv[None]  # (B,3,S,hd/2)
    parts = []
    start = 0
    for i, sec in enumerate(sections):
        parts.append(ang_all[:, i, :, start : start + sec])
        start += sec
    ang = jnp.concatenate(parts, axis=-1)  # (B, S, hd/2)
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jax.Array, cos: jax.Array, sin: jax.Array) -> jax.Array:
    """Rotate-half RoPE. x: (B, S, H, hd); cos/sin: (B, S, hd//2)."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    c = cos[..., None, :].astype(x.dtype)  # (B, S, 1, hd/2)
    s = sin[..., None, :].astype(x.dtype)
    return jnp.concatenate([x1 * c - x2 * s, x2 * c + x1 * s], axis=-1)


# -- MLP ------------------------------------------------------------------------


def mlp(x: jax.Array, p: dict, activation: str) -> jax.Array:
    if activation == "swiglu":
        gate = jax.nn.silu(x @ p["w_gate"])
        up = x @ p["w_up"]
        return (gate * up) @ p["w_down"]
    if activation == "gelu":
        h = jax.nn.gelu(x @ p["w_up"] + p.get("b_up", 0.0))
        return h @ p["w_down"] + p.get("b_down", 0.0)
    if activation == "relu2":
        h = jnp.square(jax.nn.relu(x @ p["w_up"]))
        return h @ p["w_down"]
    raise ValueError(activation)


def init_linear(key: jax.Array, shape: tuple[int, ...], dtype=jnp.float32) -> jax.Array:
    fan_in = shape[0] if len(shape) == 2 else shape[-2]
    return (jax.random.normal(key, shape) / np.sqrt(fan_in)).astype(dtype)
