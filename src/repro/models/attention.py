"""Attention: chunked (flash-style) training/prefill path, KV-cache decode,
sliding windows, GQA, and a distributed flash-decode for sequence-sharded
caches (long-context, batch=1).

The chunked path never materializes the full (S x S) score matrix: it scans
KV chunks with an online-softmax accumulator and maps over Q chunks, so peak
memory is O(S * chunk) — required for the 32k prefill cells to fit HBM.
"""

from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["chunked_attention", "decode_attention", "sharded_decode_attention"]

_NEG = -1e30


def _repeat_kv(k: jax.Array, groups: int) -> jax.Array:
    """(B, T, Hkv, d) -> (B, T, Hkv*groups, d) for GQA."""
    if groups == 1:
        return k
    b, t, h, d = k.shape
    return jnp.broadcast_to(k[:, :, :, None, :], (b, t, h, groups, d)).reshape(
        b, t, h * groups, d
    )


def chunked_attention(
    q: jax.Array,  # (B, S, H, d)
    k: jax.Array,  # (B, T, Hkv, d)
    v: jax.Array,  # (B, T, Hkv, d)
    *,
    causal: bool = True,
    window: int | None = None,
    q_offset: int = 0,
    q_chunk: int = 1024,
    kv_chunk: int = 1024,
) -> jax.Array:
    """Flash-style attention with online softmax over KV chunks."""
    B, S, H, d = q.shape
    _, T, Hkv, _ = k.shape
    groups = H // Hkv
    k = _repeat_kv(k, groups)
    v = _repeat_kv(v, groups)
    scale = 1.0 / np.sqrt(d)

    q_chunk = min(q_chunk, S)
    kv_chunk = min(kv_chunk, T)
    # pad to multiples
    S_pad = -S % q_chunk
    T_pad = -T % kv_chunk
    qp = jnp.pad(q, ((0, 0), (0, S_pad), (0, 0), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, T_pad), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, T_pad), (0, 0), (0, 0)))
    nq, nkv = (S + S_pad) // q_chunk, (T + T_pad) // kv_chunk

    q_pos_base = jnp.arange(q_chunk) + q_offset
    kv_pos_base = jnp.arange(kv_chunk)

    qp = qp.reshape(B, nq, q_chunk, H, d).transpose(1, 0, 3, 2, 4)  # (nq,B,H,qc,d)
    kp = kp.reshape(B, nkv, kv_chunk, H, d).transpose(1, 0, 3, 2, 4)
    vp = vp.reshape(B, nkv, kv_chunk, H, d).transpose(1, 0, 3, 2, 4)

    def one_q_chunk(qi: jax.Array, q_blk: jax.Array) -> jax.Array:
        q_pos = q_pos_base + qi * q_chunk

        def kv_step(carry, inputs):
            m, l, acc = carry
            kj, k_blk, v_blk = inputs
            kv_pos = kv_pos_base + kj * kv_chunk
            s = jnp.einsum("bhqd,bhkd->bhqk", q_blk, k_blk) * scale
            mask = kv_pos[None, :] < T  # drop padded kv
            if causal:
                mask = mask & (kv_pos[None, :] <= q_pos[:, None])
            if window is not None:
                mask = mask & (kv_pos[None, :] > q_pos[:, None] - window)
            s = jnp.where(mask[None, None], s, _NEG)
            m_new = jnp.maximum(m, s.max(axis=-1))
            p = jnp.exp(s - m_new[..., None])
            alpha = jnp.exp(m - m_new)
            l_new = l * alpha + p.sum(axis=-1)
            acc_new = acc * alpha[..., None] + jnp.einsum("bhqk,bhkd->bhqd", p, v_blk)
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((B, H, q_chunk), _NEG, dtype=jnp.float32)
        l0 = jnp.zeros((B, H, q_chunk), dtype=jnp.float32)
        a0 = jnp.zeros((B, H, q_chunk, d), dtype=jnp.float32)
        (m, l, acc), _ = jax.lax.scan(
            kv_step,
            (m0, l0, a0),
            (jnp.arange(nkv), kp.astype(jnp.float32), vp.astype(jnp.float32)),
        )
        return acc / jnp.maximum(l, 1e-30)[..., None]

    out = jax.lax.map(
        lambda args: one_q_chunk(*args), (jnp.arange(nq), qp.astype(jnp.float32))
    )  # (nq, B, H, qc, d)
    out = out.transpose(1, 0, 3, 2, 4).reshape(B, S + S_pad, H, d)[:, :S]
    return out.astype(q.dtype)


def decode_attention(
    q: jax.Array,  # (B, 1, H, d)
    k_cache: jax.Array,  # (B, T, Hkv, d)
    v_cache: jax.Array,
    *,
    window: int | None = None,
    fill: jax.Array | int | None = None,
    slot: jax.Array | int | None = None,
) -> jax.Array:
    """Single-token decode against a ring-buffer KV cache.

    ``slot`` is the index the newest entry was just written to; entry ages
    are ``(slot - idx) mod T``. A roll-by-one layout (newest = last) is the
    ``slot = T-1`` special case. The ring layout matters for distributed
    caches: writing one slot touches a single shard of a sequence-sharded
    cache, whereas rolling reshuffles every shard boundary (§Perf pair 2).
    ``fill`` masks warm-up slots (age >= fill); ``window`` masks beyond the
    sliding window."""
    B, _, H, d = q.shape
    _, T, Hkv, _ = k_cache.shape
    groups = H // Hkv
    scale = 1.0 / np.sqrt(d)
    # grouped-query contraction WITHOUT materializing the repeated (or f32)
    # cache: q is reshaped to (B, Hkv, G, d) and contracted against the
    # stored cache directly, accumulating in f32 (preferred_element_type) —
    # the cache is read once in its storage dtype.
    qg = q.reshape(B, Hkv, groups, d)
    s = jnp.einsum(
        "bhgd,bthd->bhgt", qg, k_cache, preferred_element_type=jnp.float32
    ) * scale  # (B, Hkv, G, T)
    idx = jnp.arange(T)
    age = (slot - idx) % T if slot is not None else T - 1 - idx
    if window is not None:
        s = jnp.where(age[None, None, None, :] < window, s, _NEG)
    if fill is not None:
        s = jnp.where(age[None, None, None, :] < fill, s, _NEG)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum(
        "bhgt,bthd->bhgd", p.astype(v_cache.dtype), v_cache,
        preferred_element_type=jnp.float32,
    )
    return out.reshape(B, 1, H, d).astype(q.dtype)


def sharded_decode_attention(
    q: jax.Array,  # (B, 1, H, d) — replicated over the shard axis
    k_cache: jax.Array,  # (B, T, Hkv, d) — T sharded over ``axis_name``
    v_cache: jax.Array,
    *,
    axis_name: str,
) -> jax.Array:
    """Distributed flash-decode: every shard attends to its local KV slice;
    the partial (max, sum, weighted-value) statistics are combined across the
    shard axis with small collectives. Used for ``long_500k`` (batch=1) where
    the 0.5M-entry KV cache is sharded over the 'data' axis.

    Must be called inside shard_map (or with `axis_name` bound)."""
    B, _, H, d = q.shape
    _, T_local, Hkv, _ = k_cache.shape
    groups = H // Hkv
    scale = 1.0 / np.sqrt(d)
    qg = q.reshape(B, H, d).astype(jnp.float32)
    kg = _repeat_kv(k_cache, groups).astype(jnp.float32)
    vg = _repeat_kv(v_cache, groups).astype(jnp.float32)
    s = jnp.einsum("bhd,bthd->bht", qg, kg) * scale  # (B, H, T_local)
    m_local = s.max(axis=-1)
    m_global = jax.lax.pmax(m_local, axis_name)
    p = jnp.exp(s - m_global[..., None])
    l_local = p.sum(axis=-1)
    o_local = jnp.einsum("bht,bthd->bhd", p, vg)
    l_global = jax.lax.psum(l_local, axis_name)
    o_global = jax.lax.psum(o_local, axis_name)
    out = o_global / jnp.maximum(l_global, 1e-30)[..., None]
    return out[:, None].astype(q.dtype)
