"""Mamba2 block in the chunked SSD (state-space duality) form.

TPU adaptation: instead of a sequential selective scan, the sequence is
processed in chunks of 128 with the block decomposition of the SSD paper —
intra-chunk work becomes (L x L)-masked matmuls on the MXU, inter-chunk work
is a short scan carrying the (H, N, P) state. The per-head scalar decay makes
all pairwise decay exponents <= 0, so the formulation is numerically safe.

Decode carries (conv cache (K-1 inputs), SSM state (H, N, P)) and costs O(1)
per token — the reason zamba2/rwkv long_500k cells are feasible at all.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["mamba2_forward", "mamba2_decode_step", "mamba2_init_cache"]


def _segsum(a: jax.Array) -> jax.Array:
    """Pairwise segment sums: out[..., i, j] = sum_{k in (j, i]} a[..., k]
    for j < i, -inf elsewhere (log-decay matrix of the SSD paper)."""
    L = a.shape[-1]
    cum = jnp.cumsum(a, axis=-1)
    diff = cum[..., :, None] - cum[..., None, :]
    mask = jnp.tril(jnp.ones((L, L), dtype=bool), k=0)
    return jnp.where(mask, diff, -jnp.inf)


def _split_proj(zxbcdt: jax.Array, d_inner: int, n_state: int, n_heads: int):
    z, x, B, C, dt = jnp.split(
        zxbcdt,
        [d_inner, 2 * d_inner, 2 * d_inner + n_state, 2 * d_inner + 2 * n_state],
        axis=-1,
    )
    return z, x, B, C, dt


def _causal_conv(xbc: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Depthwise causal conv over the sequence axis. xbc: (B,S,Cd), w: (K,Cd)."""
    K = w.shape[0]
    pad = jnp.pad(xbc, ((0, 0), (K - 1, 0), (0, 0)))
    out = jnp.zeros_like(xbc)
    for k in range(K):  # K=4: unrolled taps
        out = out + pad[:, k : k + xbc.shape[1]] * w[k]
    return out + b


def mamba2_forward(
    u: jax.Array,  # (B, S, D)
    p: dict,
    *,
    d_state: int,
    head_dim: int,
    chunk: int = 128,
    wsc=None,
) -> jax.Array:
    Bsz, S, D = u.shape
    d_inner = p["out_proj"].shape[0]
    H = d_inner // head_dim
    N = d_state
    wsc = wsc or (lambda a, dims: a)

    zxbcdt = u @ p["in_proj"]
    z, x, Bm, Cm, dt = _split_proj(zxbcdt, d_inner, N, H)
    xbc = jnp.concatenate([x, Bm, Cm], axis=-1)
    xbc = jax.nn.silu(_causal_conv(xbc, p["conv_w"], p["conv_b"]))
    x, Bm, Cm = jnp.split(xbc, [d_inner, d_inner + N], axis=-1)
    Bm, Cm = wsc(Bm, "b.."), wsc(Cm, "b..")  # n_state is small: replicate

    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])  # (B,S,H)
    dt = wsc(dt, "b.m")
    A = -jnp.exp(p["A_log"].astype(jnp.float32))  # (H,)
    xh = wsc(x.reshape(Bsz, S, H, head_dim), "b.m.")  # heads on model

    L = min(chunk, S)
    pad = -S % L
    if pad:
        xh = jnp.pad(xh, ((0, 0), (0, pad), (0, 0), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
    nc = (S + pad) // L
    xc = xh.reshape(Bsz, nc, L, H, head_dim).astype(jnp.float32)
    Bc = Bm.reshape(Bsz, nc, L, N).astype(jnp.float32)
    Cc = Cm.reshape(Bsz, nc, L, N).astype(jnp.float32)
    dtc = dt.reshape(Bsz, nc, L, H)
    dA = dtc * A  # (B,nc,L,H) log decays (<= 0)

    # intra-chunk (MXU): Y_intra = (C B^T ∘ decay ∘ causal) @ (dt x)
    Lmat = jnp.exp(_segsum(dA.transpose(0, 1, 3, 2)))  # (B,nc,H,L,L)
    G = jnp.einsum("bcln,bcmn->bclm", Cc, Bc)  # (B,nc,L,L)
    xdt = xc * dtc[..., None]
    y_intra = jnp.einsum("bclm,bchlm,bcmhp->bclhp", G, Lmat, xdt)

    # chunk state contributions and the inter-chunk scan
    a_cum = jnp.cumsum(dA, axis=2)  # (B,nc,L,H)
    a_end = a_cum[:, :, -1:]  # (B,nc,1,H)
    decay_to_end = jnp.exp(a_end - a_cum)  # <= 1
    S_chunk = jnp.einsum("bcln,bclh,bclhp->bchnp", Bc, decay_to_end, xdt)
    chunk_decay = jnp.exp(a_end[:, :, 0])  # (B,nc,H)

    def scan_fn(h, inp):
        s_c, dec = inp
        h_new = h * dec[..., None, None] + s_c
        return h_new, h  # emit the *previous* state for this chunk

    h0 = jnp.zeros((Bsz, H, N, head_dim), dtype=jnp.float32)
    _, h_prev = jax.lax.scan(
        scan_fn,
        h0,
        (S_chunk.transpose(1, 0, 2, 3, 4), chunk_decay.transpose(1, 0, 2)),
    )
    h_prev = h_prev.transpose(1, 0, 2, 3, 4)  # (B,nc,H,N,P)
    decay_from_start = jnp.exp(a_cum)  # (B,nc,L,H)
    y_inter = jnp.einsum("bcln,bchnp,bclh->bclhp", Cc, h_prev, decay_from_start)

    y = (y_intra + y_inter).reshape(Bsz, S + pad, H, head_dim)[:, :S]
    y = y + xh[:, :S] * p["D_skip"][None, None, :, None]
    y = y.reshape(Bsz, S, d_inner).astype(u.dtype)

    # gated RMSNorm then output projection (Mamba2)
    gated = y * jax.nn.silu(z)
    var = jnp.mean(jnp.square(gated.astype(jnp.float32)), axis=-1, keepdims=True)
    gated = (gated.astype(jnp.float32) * jax.lax.rsqrt(var + 1e-5)).astype(u.dtype)
    gated = gated * p["norm_scale"]
    return gated @ p["out_proj"]


def mamba2_init_cache(batch: int, p: dict, *, d_state: int, head_dim: int, conv_k: int):
    d_inner = p["out_proj"].shape[0]
    H = d_inner // head_dim
    conv_dim = d_inner + 2 * d_state
    return {
        "conv": jnp.zeros((batch, conv_k - 1, conv_dim), dtype=jnp.float32),
        "ssm": jnp.zeros((batch, H, d_state, head_dim), dtype=jnp.float32),
    }


def mamba2_decode_step(
    u: jax.Array,  # (B, 1, D)
    cache: dict,
    p: dict,
    *,
    d_state: int,
    head_dim: int,
) -> tuple[jax.Array, dict]:
    Bsz, _, D = u.shape
    d_inner = p["out_proj"].shape[0]
    H = d_inner // head_dim
    N = d_state

    zxbcdt = u[:, 0] @ p["in_proj"]
    z, x, Bm, Cm, dt = _split_proj(zxbcdt, d_inner, N, H)
    xbc = jnp.concatenate([x, Bm, Cm], axis=-1)  # (B, conv_dim)
    hist = jnp.concatenate([cache["conv"], xbc[:, None]], axis=1)  # (B,K,Cd)
    w = p["conv_w"]  # (K, Cd)
    xbc = jax.nn.silu(jnp.einsum("bkc,kc->bc", hist, w) + p["conv_b"])
    new_conv = hist[:, 1:]
    x, Bm, Cm = jnp.split(xbc, [d_inner, d_inner + N], axis=-1)

    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])  # (B,H)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    xh = x.reshape(Bsz, H, head_dim).astype(jnp.float32)
    dA = jnp.exp(dt * A)  # (B,H)
    dBx = jnp.einsum("bn,bh,bhp->bhnp", Bm.astype(jnp.float32), dt, xh)
    ssm = cache["ssm"] * dA[..., None, None] + dBx
    y = jnp.einsum("bn,bhnp->bhp", Cm.astype(jnp.float32), ssm)
    y = y + xh * p["D_skip"][None, :, None]
    y = y.reshape(Bsz, d_inner).astype(u.dtype)

    gated = y * jax.nn.silu(z)
    var = jnp.mean(jnp.square(gated.astype(jnp.float32)), axis=-1, keepdims=True)
    gated = (gated.astype(jnp.float32) * jax.lax.rsqrt(var + 1e-5)).astype(u.dtype)
    gated = gated * p["norm_scale"]
    out = (gated @ p["out_proj"])[:, None]
    return out, {"conv": new_conv, "ssm": ssm}
