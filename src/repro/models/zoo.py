"""Model zoo: parameter init + train/prefill/decode computations per family.

All layer stacks are ``lax.scan`` over parameters stacked on a leading layer
axis (bounded HLO size and compile time even for the 80-layer/72B dry-run),
with optional per-layer remat. The same layer bodies serve train, prefill,
and decode; decode carries KV caches / recurrent states through the scan.

Family dispatch:
  dense / vlm        GQA attention (+ M-RoPE for qwen2-vl) + (Sw)GLU MLP
  moe                GQA attention + capacity-routed expert MLP
  hybrid (zamba2)    Mamba2 backbone + one *shared* attention block applied
                     every ``hybrid_attn_every`` layers (own KV cache per
                     application site)
  ssm (rwkv6)        time-mix (WKV, data-dependent decay) + channel-mix
  audio (whisper)    encoder-decoder; conv frontend stubbed by precomputed
                     frame embeddings from input_specs
"""

from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ArchConfig, get_config
from .attention import chunked_attention, decode_attention
from .layers import apply_rope, mlp, mrope_freqs, norm, rope_freqs
from .mamba2 import mamba2_decode_step, mamba2_forward, mamba2_init_cache
from .moe import moe_layer
from .rwkv6 import (
    rwkv6_channel_mix,
    rwkv6_channel_mix_step,
    rwkv6_init_cache,
    rwkv6_time_mix,
    rwkv6_time_mix_step,
)

__all__ = ["Model", "build_model"]


# =============================================================================
# parameter initialization
# =============================================================================


def _lin(key, fan_in, shape, dtype):
    return (jax.random.normal(key, shape) * (fan_in**-0.5)).astype(dtype)


def _norm_params(cfg: ArchConfig, D: int) -> dict | None:
    if cfg.nonparametric_ln:
        return None
    p = {"scale": jnp.ones((D,))}
    if cfg.norm == "layernorm":
        p["bias"] = jnp.zeros((D,))
    return p


def _attn_params(cfg: ArchConfig, key, dtype) -> dict:
    D, H, Hkv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv, cfg.hd
    ks = jax.random.split(key, 4)
    p = {
        "wq": _lin(ks[0], D, (D, H * hd), dtype),
        "wk": _lin(ks[1], D, (D, Hkv * hd), dtype),
        "wv": _lin(ks[2], D, (D, Hkv * hd), dtype),
        "wo": _lin(ks[3], H * hd, (H * hd, D), dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((H * hd,), dtype)
        p["bk"] = jnp.zeros((Hkv * hd,), dtype)
        p["bv"] = jnp.zeros((Hkv * hd,), dtype)
    return p


def _mlp_params(cfg: ArchConfig, key, dtype, d_ff: int | None = None) -> dict:
    D, F = cfg.d_model, d_ff or cfg.d_ff
    ks = jax.random.split(key, 3)
    if cfg.activation == "swiglu":
        return {
            "w_gate": _lin(ks[0], D, (D, F), dtype),
            "w_up": _lin(ks[1], D, (D, F), dtype),
            "w_down": _lin(ks[2], F, (F, D), dtype),
        }
    return {
        "w_up": _lin(ks[0], D, (D, F), dtype),
        "w_down": _lin(ks[1], F, (F, D), dtype),
    }


def _moe_params(cfg: ArchConfig, key, dtype) -> dict:
    D, F, E = cfg.d_model, cfg.d_ff, cfg.n_experts
    ks = jax.random.split(key, 4)
    return {
        "router": _lin(ks[0], D, (D, E), jnp.float32),
        "w_gate": _lin(ks[1], D, (E, D, F), dtype),
        "w_up": _lin(ks[2], D, (E, D, F), dtype),
        "w_down": _lin(ks[3], F, (E, F, D), dtype),
    }


def _mamba_params(cfg: ArchConfig, key, dtype) -> dict:
    D = cfg.d_model
    d_inner = cfg.ssm_expand * D
    N, P, K = cfg.ssm_state, cfg.ssm_head_dim, cfg.ssm_conv
    H = d_inner // P
    conv_dim = d_inner + 2 * N
    ks = jax.random.split(key, 3)
    return {
        "in_proj": _lin(ks[0], D, (D, 2 * d_inner + 2 * N + H), dtype),
        "conv_w": _lin(ks[1], K, (K, conv_dim), dtype),
        "conv_b": jnp.zeros((conv_dim,), dtype),
        "dt_bias": jnp.zeros((H,), jnp.float32),
        "A_log": jnp.zeros((H,), jnp.float32),
        "D_skip": jnp.ones((H,), jnp.float32),
        "norm_scale": jnp.ones((d_inner,), dtype),
        "out_proj": _lin(ks[2], d_inner, (d_inner, D), dtype),
    }


def _rwkv_params(cfg: ArchConfig, key, dtype) -> dict:
    D, F = cfg.d_model, cfg.d_ff
    H, hd = D // cfg.rwkv_head_dim, cfg.rwkv_head_dim
    lora = 64
    ks = jax.random.split(key, 10)
    p = {
        "w_r": _lin(ks[0], D, (D, D), dtype),
        "w_k": _lin(ks[1], D, (D, D), dtype),
        "w_v": _lin(ks[2], D, (D, D), dtype),
        "w_g": _lin(ks[3], D, (D, D), dtype),
        "w_o": _lin(ks[4], D, (D, D), dtype),
        "w_lora_a": _lin(ks[5], D, (D, lora), dtype),
        "w_lora_b": _lin(ks[6], lora, (lora, D), dtype) * 0.1,
        "w0": jnp.full((D,), -0.6, jnp.float32),
        "u": jnp.zeros((H, hd), jnp.float32),
        "ln_x_scale": jnp.ones((D,), jnp.float32),
        "ln_x_bias": jnp.zeros((D,), jnp.float32),
        "w_ck": _lin(ks[7], D, (D, F), dtype),
        "w_cv": _lin(ks[8], F, (F, D), dtype),
        "w_cr": _lin(ks[9], D, (D, D), dtype),
    }
    for name in ("r", "k", "v", "g", "w"):
        p[f"mu_{name}"] = jnp.full((D,), 0.5, jnp.float32)
    p["mu_ck"] = jnp.full((D,), 0.5, jnp.float32)
    p["mu_cr"] = jnp.full((D,), 0.5, jnp.float32)
    return p


def _layer_params(cfg: ArchConfig, key, dtype) -> dict:
    D = cfg.d_model
    k1, k2 = jax.random.split(key)
    if cfg.family == "ssm":
        p = {"tm": _rwkv_params(cfg, k1, dtype)}
    elif cfg.family == "hybrid":
        p = {"mamba": _mamba_params(cfg, k1, dtype)}
    elif cfg.family == "moe":
        p = {"attn": _attn_params(cfg, k1, dtype), "moe": _moe_params(cfg, k2, dtype)}
    else:
        p = {"attn": _attn_params(cfg, k1, dtype), "mlp": _mlp_params(cfg, k2, dtype)}
    ln1 = _norm_params(cfg, D)
    ln2 = _norm_params(cfg, D)
    if ln1 is not None:
        p["ln1"], p["ln2"] = ln1, ln2
    return p


def init_params(cfg: ArchConfig, key: jax.Array, dtype=jnp.float32) -> dict:
    D, V, L = cfg.d_model, cfg.vocab, cfg.n_layers
    keys = jax.random.split(key, L + 8)
    stacked = jax.tree.map(
        lambda *xs: jnp.stack(xs),
        *[_layer_params(cfg, keys[i], dtype) for i in range(L)],
    )
    params: dict[str, Any] = {
        "embed": (jax.random.normal(keys[L], (V, D)) * 0.02).astype(dtype),
        "layers": stacked,
        "final_ln": _norm_params(cfg, D) or {},
    }
    if not cfg.tie_embeddings:
        params["head"] = _lin(keys[L + 1], D, (D, V), dtype)
    if cfg.family == "hybrid":
        k1, k2 = jax.random.split(keys[L + 2])
        params["shared_attn"] = {
            "ln1": _norm_params(cfg, D) or {"scale": jnp.ones((D,))},
            "attn": _attn_params(cfg, k1, dtype),
            "ln2": _norm_params(cfg, D) or {"scale": jnp.ones((D,))},
            "mlp": _mlp_params(cfg, k2, dtype),
        }
    if cfg.family == "ssm":
        params["ln0"] = {"scale": jnp.ones((D,)), "bias": jnp.zeros((D,))}
    if cfg.is_encoder_decoder:
        enc_keys = jax.random.split(keys[L + 3], cfg.encoder_layers)
        enc_layers = []
        for ek in enc_keys:
            e1, e2 = jax.random.split(ek)
            enc_layers.append(
                {
                    "ln1": _norm_params(cfg, D),
                    "attn": _attn_params(cfg, e1, dtype),
                    "ln2": _norm_params(cfg, D),
                    "mlp": _mlp_params(cfg, e2, dtype),
                }
            )
        params["encoder"] = jax.tree.map(lambda *xs: jnp.stack(xs), *enc_layers)
        params["enc_final_ln"] = _norm_params(cfg, D) or {}
        # decoder cross-attention (stacked with the self-attn layers)
        xkeys = jax.random.split(keys[L + 4], L)
        cross = [
            {"ln": _norm_params(cfg, D), "attn": _attn_params(cfg, xk, dtype)}
            for xk in xkeys
        ]
        params["cross"] = jax.tree.map(lambda *xs: jnp.stack(xs), *cross)
    return params


# =============================================================================
# layer bodies
# =============================================================================


def _attention_block(
    cfg: ArchConfig,
    x: jax.Array,
    p: dict,
    cos: jax.Array | None,
    sin: jax.Array | None,
    dist: "DistContext",
    *,
    causal: bool = True,
    kv_override: tuple[jax.Array, jax.Array] | None = None,
) -> jax.Array:
    B, S, D = x.shape
    H, Hkv, hd = cfg.n_heads, cfg.n_kv, cfg.hd
    # kv heads shard on the model axis only when they divide it; otherwise
    # they are replicated (Megatron GQA convention) — never let the
    # partitioner split head_dim (a contracted dim) instead.
    kv_dims = "b.m." if (dist.model_size > 1 and Hkv % dist.model_size == 0) else "b..."
    q = x @ p["wq"] + (p["bq"] if cfg.qkv_bias else 0.0)
    q = dist.wsc(q.reshape(B, S, H, hd), "b.m.")
    if kv_override is None:
        k = (x @ p["wk"] + (p["bk"] if cfg.qkv_bias else 0.0)).reshape(B, S, Hkv, hd)
        v = (x @ p["wv"] + (p["bv"] if cfg.qkv_bias else 0.0)).reshape(B, S, Hkv, hd)
        k = dist.wsc(k, kv_dims)
        v = dist.wsc(v, kv_dims)
        if cos is not None:
            q = apply_rope(q, cos, sin)
            k = apply_rope(k, cos, sin)
    else:
        k, v = kv_override
        k = dist.wsc(k, kv_dims)
        v = dist.wsc(v, kv_dims)
        if cos is not None:
            q = apply_rope(q, cos, sin)
    out = chunked_attention(
        q, k, v, causal=causal and kv_override is None, window=cfg.sliding_window
    )
    out = dist.wsc(out, "b.m.")
    return out.reshape(B, S, H * hd) @ p["wo"]


def _dense_layer(cfg: ArchConfig, x, p, cos, sin, dist):
    h = norm(x, p.get("ln1"), cfg.norm)
    x = x + _attention_block(cfg, h, p["attn"], cos, sin, dist)
    h = norm(x, p.get("ln2"), cfg.norm)
    x = x + mlp(h, p["mlp"], cfg.activation)
    return x


def _moe_dense_layer(cfg: ArchConfig, x, p, cos, sin, dist):
    h = norm(x, p.get("ln1"), cfg.norm)
    x = x + _attention_block(cfg, h, p["attn"], cos, sin, dist)
    h = norm(x, p.get("ln2"), cfg.norm)
    y, aux = moe_layer(
        h,
        p["moe"],
        n_experts=cfg.n_experts,
        top_k=cfg.top_k,
        capacity_factor=cfg.capacity_factor,
        n_token_groups=dist.n_token_groups,
        expert_parallel=dist.model_size > 1 and cfg.n_experts % dist.model_size == 0,
        wsc=dist.wsc,
    )
    return x + y, aux


def _rwkv_layer(cfg: ArchConfig, x, p, dist):
    h = norm(x, p.get("ln1"), "layernorm")
    x = x + rwkv6_time_mix(
        h, p["tm"], n_heads=cfg.d_model // cfg.rwkv_head_dim, head_dim=cfg.rwkv_head_dim,
        wsc=dist.wsc,
    )
    h = norm(x, p.get("ln2"), "layernorm")
    x = x + rwkv6_channel_mix(h, p["tm"])
    return x


def _mamba_layer(cfg: ArchConfig, x, p, dist):
    h = norm(x, p.get("ln1"), cfg.norm)
    return x + mamba2_forward(
        h, p["mamba"], d_state=cfg.ssm_state, head_dim=cfg.ssm_head_dim, wsc=dist.wsc
    )


def _shared_attn_block(cfg: ArchConfig, x, p, cos, sin, dist):
    h = norm(x, p["ln1"], cfg.norm)
    x = x + _attention_block(cfg, h, p["attn"], cos, sin, dist)
    h = norm(x, p["ln2"], cfg.norm)
    return x + mlp(h, p["mlp"], cfg.activation)


# =============================================================================
# full-sequence forward (train / prefill)
# =============================================================================


@dataclass(frozen=True)
class DistContext:
    """Static distribution facts the model math needs: token-group counts for
    MoE dispatch, and the mesh axis names for explicit sharding constraints.

    The constraints matter: without them the SPMD partitioner is free to
    shard a GQA head_dim (n_kv*hd reshaped to (n_kv, hd) when n_kv < axis)
    — a *contracted* dimension — which turns every attention score tensor
    into a full-size all-reduce inside the chunk loops (observed: 7.5 GB
    per chunk on qwen2-0.5b). ``wsc`` pins the intended layout; with no
    axes configured it is the identity (single-device smoke tests).
    """

    n_token_groups: int = 1
    remat: bool = True
    batch_axes: tuple[str, ...] = ()
    model_axis: str | None = None
    model_size: int = 1
    # decode KV caches sequence-sharded on the model axis (serving layout
    # for archs whose kv-head count does not divide the axis)
    decode_seq_shard: bool = False

    @property
    def active(self) -> bool:
        return bool(self.batch_axes) or self.model_axis is not None

    def wsc(self, x: jax.Array, dims: str) -> jax.Array:
        """Constrain: dims is a string of 'b' (batch axes), 'm' (model axis),
        '.' (unsharded) per array dimension, e.g. "b.m." for (B,S,H,d)."""
        if not self.active:
            return x
        from jax.sharding import PartitionSpec as P

        spec = []
        for d in dims:
            if d == "b":
                spec.append(self.batch_axes if len(self.batch_axes) != 1 else self.batch_axes[0])
            elif d == "m":
                spec.append(self.model_axis)
            else:
                spec.append(None)
        return jax.lax.with_sharding_constraint(x, P(*spec))


def _positions_and_rope(cfg: ArchConfig, batch: dict, S: int, B: int):
    if cfg.is_encoder_decoder:
        return None, None  # whisper: learned/sinusoidal positions are in stubs
    if cfg.m_rope:
        pos = batch.get("positions")
        if pos is None:
            p1 = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
            pos = jnp.stack([p1, p1, p1], axis=1)
        return mrope_freqs(pos, cfg.hd, cfg.rope_theta, cfg.m_rope_sections)
    pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    return rope_freqs(pos, cfg.hd, cfg.rope_theta)


def _embed(cfg: ArchConfig, params: dict, batch: dict) -> jax.Array:
    x = params["embed"][batch["tokens"]]
    if cfg.frontend == "vision-stub" and "frontend_embeds" in batch:
        x = x + batch["frontend_embeds"].astype(x.dtype)
    if cfg.family == "ssm":
        x = norm(x, params["ln0"], "layernorm")
    return x


def _encoder_forward(cfg: ArchConfig, params: dict, enc_embeds: jax.Array, dist) -> jax.Array:
    def body(x, p):
        h = norm(x, p.get("ln1"), cfg.norm)
        x = x + _attention_block(cfg, h, p["attn"], None, None, dist, causal=False)
        h = norm(x, p.get("ln2"), cfg.norm)
        x = x + mlp(h, p["mlp"], cfg.activation)
        return x, None

    f = jax.checkpoint(body) if dist.remat else body
    x, _ = jax.lax.scan(f, enc_embeds, params["encoder"])
    return norm(x, params.get("enc_final_ln") or None, cfg.norm)


def forward_hidden(
    cfg: ArchConfig, params: dict, batch: dict, dist: DistContext
) -> tuple[jax.Array, jax.Array]:
    """Returns (final hidden states (B,S,D), aux loss scalar)."""
    x = _embed(cfg, params, batch)
    B, S, D = x.shape
    cos, sin = _positions_and_rope(cfg, batch, S, B)
    aux0 = jnp.zeros((), jnp.float32)

    if cfg.family in ("dense", "vlm"):

        def body(carry, p):
            return _dense_layer(cfg, carry, p, cos, sin, dist), None

        f = jax.checkpoint(body) if dist.remat else body
        x, _ = jax.lax.scan(f, x, params["layers"])
        aux = aux0

    elif cfg.family == "moe":

        def body(carry, p):
            x, aux = carry
            x, a = _moe_dense_layer(cfg, x, p, cos, sin, dist)
            return (x, aux + a), None

        f = jax.checkpoint(body) if dist.remat else body
        (x, aux), _ = jax.lax.scan(f, (x, aux0), params["layers"])

    elif cfg.family == "ssm":

        def body(carry, p):
            return _rwkv_layer(cfg, carry, p, dist), None

        f = jax.checkpoint(body) if dist.remat else body
        x, _ = jax.lax.scan(f, x, params["layers"])
        aux = aux0

    elif cfg.family == "hybrid":
        every = cfg.hybrid_attn_every
        n_groups = cfg.n_layers // every
        grouped = jax.tree.map(
            lambda a: a.reshape(n_groups, every, *a.shape[1:]), params["layers"]
        )

        def body(carry, p):
            return _mamba_layer(cfg, carry, p, dist), None

        f = jax.checkpoint(body) if dist.remat else body
        shared = (
            jax.checkpoint(
                lambda x, sp: _shared_attn_block(cfg, x, sp, cos, sin, dist)
            )
            if dist.remat
            else (lambda x, sp: _shared_attn_block(cfg, x, sp, cos, sin, dist))
        )  # the 9 unrolled shared-attn sites must be remat'd too, else each
        #    stashes its full activations outside the scan (§Perf residuals)
        for gi in range(n_groups):
            p_g = jax.tree.map(lambda a: a[gi], grouped)
            x, _ = jax.lax.scan(f, x, p_g)
            x = shared(x, params["shared_attn"])
        aux = aux0

    elif cfg.family == "audio":
        enc = _encoder_forward(cfg, params, batch["enc_embeds"].astype(x.dtype), dist)
        Hkv, hd = cfg.n_kv, cfg.hd

        def body(carry, p):
            x = carry
            h = norm(x, p.get("ln1"), cfg.norm)
            x = x + _attention_block(cfg, h, p["attn"], None, None, dist, causal=True)
            hq = norm(x, p["cross"]["ln"], cfg.norm)
            ek = (enc @ p["cross"]["attn"]["wk"]).reshape(B, -1, Hkv, hd)
            ev = (enc @ p["cross"]["attn"]["wv"]).reshape(B, -1, Hkv, hd)
            x = x + _attention_block(
                cfg, hq, p["cross"]["attn"], None, None, dist, causal=False, kv_override=(ek, ev)
            )
            h2 = norm(x, p.get("ln2"), cfg.norm)
            x = x + mlp(h2, p["mlp"], cfg.activation)
            return x, None

        layers = dict(params["layers"])
        layers["cross"] = params["cross"]
        f = jax.checkpoint(body) if dist.remat else body
        x, _ = jax.lax.scan(f, x, layers)
        aux = aux0
    else:
        raise ValueError(cfg.family)

    x = norm(x, params.get("final_ln") or None, cfg.norm)
    return x, aux


def logits_from_hidden(cfg: ArchConfig, params: dict, h: jax.Array) -> jax.Array:
    if cfg.tie_embeddings:
        return h @ params["embed"].T
    return h @ params["head"]


def loss_fn(
    cfg: ArchConfig,
    params: dict,
    batch: dict,
    dist: DistContext,
    *,
    logit_chunk: int = 512,
) -> tuple[jax.Array, dict]:
    """Chunked softmax cross-entropy (never materializes (B,S,V) at once)."""
    h, aux = forward_hidden(cfg, params, batch, dist)
    B, S, D = h.shape
    labels = batch["labels"]
    C = min(logit_chunk, S)
    pad = -S % C
    if pad:
        h = jnp.pad(h, ((0, 0), (0, pad), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, pad)), constant_values=-1)
    nch = (S + pad) // C
    hc = h.reshape(B, nch, C, D).transpose(1, 0, 2, 3)
    lc = labels.reshape(B, nch, C).transpose(1, 0, 2)

    def chunk_loss(carry, inp):
        hch, lch = inp
        logits = logits_from_hidden(cfg, params, hch).astype(jnp.float32)
        lse = jax.nn.logsumexp(logits, axis=-1)
        tgt = jnp.take_along_axis(
            logits, jnp.maximum(lch, 0)[..., None], axis=-1
        )[..., 0]
        valid = (lch >= 0).astype(jnp.float32)
        nll = (lse - tgt) * valid
        return (carry[0] + nll.sum(), carry[1] + valid.sum()), None

    (total, count), _ = jax.lax.scan(chunk_loss, (0.0, 0.0), (hc, lc))
    ce = total / jnp.maximum(count, 1.0)
    loss = ce + 0.01 * aux
    return loss, {"ce": ce, "aux": aux, "tokens": count}


# =============================================================================
# decode (serve_step)
# =============================================================================


def init_cache(cfg: ArchConfig, batch: int, cache_len: int, dtype=jnp.float32) -> dict:
    """KV caches / recurrent state sized for ``cache_len`` history."""
    L, Hkv, hd = cfg.n_layers, cfg.n_kv, cfg.hd
    if cfg.sliding_window is not None:
        cache_len = min(cache_len, cfg.sliding_window)
    if cfg.family in ("dense", "vlm", "moe"):
        return {
            "k": jnp.zeros((L, batch, cache_len, Hkv, hd), dtype),
            "v": jnp.zeros((L, batch, cache_len, Hkv, hd), dtype),
            "pos": jnp.zeros((), jnp.int32) + cache_len,
        }
    if cfg.family == "ssm":
        caches = [
            rwkv6_init_cache(batch, cfg.d_model, cfg.d_model // cfg.rwkv_head_dim, cfg.rwkv_head_dim)
            for _ in range(L)
        ]
        return jax.tree.map(lambda *xs: jnp.stack(xs), *caches)
    if cfg.family == "hybrid":
        d_inner = cfg.ssm_expand * cfg.d_model
        H = d_inner // cfg.ssm_head_dim
        n_sites = cfg.n_layers // cfg.hybrid_attn_every
        mamba = {
            "conv": jnp.zeros((L, batch, cfg.ssm_conv - 1, d_inner + 2 * cfg.ssm_state), jnp.float32),
            "ssm": jnp.zeros((L, batch, H, cfg.ssm_state, cfg.ssm_head_dim), jnp.float32),
        }
        return {
            "mamba": mamba,
            "k": jnp.zeros((n_sites, batch, cache_len, Hkv, hd), dtype),
            "v": jnp.zeros((n_sites, batch, cache_len, Hkv, hd), dtype),
            "pos": jnp.zeros((), jnp.int32) + cache_len,
        }
    if cfg.family == "audio":
        Tenc = cfg.encoder_len
        return {
            "k": jnp.zeros((L, batch, cache_len, Hkv, hd), dtype),
            "v": jnp.zeros((L, batch, cache_len, Hkv, hd), dtype),
            "ek": jnp.zeros((L, batch, Tenc, Hkv, hd), dtype),
            "ev": jnp.zeros((L, batch, Tenc, Hkv, hd), dtype),
            "pos": jnp.zeros((), jnp.int32) + cache_len,
        }
    raise ValueError(cfg.family)


def _decode_attn(
    cfg: ArchConfig, x: jax.Array, p: dict, kc, vc, cos, sin, fill=None, slot=None,
    dist: "DistContext | None" = None,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """One-token attention against a ring-buffer cache: the new KV pair is
    written to slot ``pos mod T`` (a single-shard dynamic update even when
    the cache sequence dim is sharded — rolling instead reshuffles every
    shard boundary, §Perf pair 2), then the token attends the whole cache
    with age masking (warm-up via ``fill``, SWA via the window).
    Returns (out, new_k_cache, new_v_cache)."""
    B, _, D = x.shape
    H, Hkv, hd = cfg.n_heads, cfg.n_kv, cfg.hd
    T = kc.shape[1]
    q = (x @ p["wq"] + (p["bq"] if cfg.qkv_bias else 0.0)).reshape(B, 1, H, hd)
    k = (x @ p["wk"] + (p["bk"] if cfg.qkv_bias else 0.0)).reshape(B, 1, Hkv, hd)
    v = (x @ p["wv"] + (p["bv"] if cfg.qkv_bias else 0.0)).reshape(B, 1, Hkv, hd)
    if cos is not None:
        q = apply_rope(q, cos, sin)
        k = apply_rope(k, cos, sin)
    if dist is not None and dist.decode_seq_shard:
        # T-sharded cache: replicate q over the model axis so the attention
        # contraction stays T-local (XLA otherwise picks head-parallelism
        # and all-gathers the whole cache — §Perf pair 2, it.4)
        q = dist.wsc(q, "b...")
        kc = dist.wsc(kc, "bm..")
        vc = dist.wsc(vc, "bm..")
    if slot is None:  # legacy roll layout (replicated caches only)
        kc = jnp.concatenate([kc[:, 1:], k.astype(kc.dtype)], axis=1)
        vc = jnp.concatenate([vc[:, 1:], v.astype(vc.dtype)], axis=1)
        out = decode_attention(q, kc, vc, window=cfg.sliding_window, fill=fill)
    else:
        kc = jax.lax.dynamic_update_slice_in_dim(kc, k.astype(kc.dtype), slot, axis=1)
        vc = jax.lax.dynamic_update_slice_in_dim(vc, v.astype(vc.dtype), slot, axis=1)
        out = decode_attention(
            q, kc, vc, window=cfg.sliding_window, fill=fill, slot=slot
        )
    return out.reshape(B, 1, H * hd) @ p["wo"], kc, vc


def decode_step(
    cfg: ArchConfig,
    params: dict,
    token: jax.Array,  # (B, 1) int32
    cache: dict,
    dist: DistContext,
    batch_extras: dict | None = None,
) -> tuple[jax.Array, dict]:
    """serve_step: one new token against the cache; returns (logits, cache)."""
    batch = {"tokens": token, **(batch_extras or {})}
    x = _embed(cfg, params, batch)
    B = x.shape[0]
    pos = cache.get("pos")
    if cfg.is_encoder_decoder or cfg.family == "ssm":
        cos = sin = None
    elif cfg.m_rope:
        p3 = jnp.broadcast_to(pos[None, None, None], (B, 3, 1))
        cos, sin = mrope_freqs(p3, cfg.hd, cfg.rope_theta, cfg.m_rope_sections)
    else:
        p1 = jnp.broadcast_to(pos[None, None], (B, 1))
        cos, sin = rope_freqs(p1, cfg.hd, cfg.rope_theta)

    new_cache = dict(cache)
    fill = None if pos is None else jnp.minimum(pos + 1, jnp.int32(2**30))
    cache_len = cache["k"].shape[2] if "k" in cache else 0
    slot = None if (pos is None or not cache_len) else (pos % cache_len).astype(jnp.int32)
    if cfg.family in ("dense", "vlm", "moe"):

        def body(x, inp):
            p, kc, vc = inp
            h = norm(x, p.get("ln1"), cfg.norm)
            att, kc, vc = _decode_attn(cfg, h, p["attn"] if "attn" in p else p, kc, vc, cos, sin, fill, slot, dist)
            x = x + att
            h = norm(x, p.get("ln2"), cfg.norm)
            if cfg.family == "moe":
                y, _ = moe_layer(
                    h,
                    p["moe"],
                    n_experts=cfg.n_experts,
                    top_k=cfg.top_k,
                    capacity_factor=cfg.capacity_factor,
                    n_token_groups=1,
                )
                x = x + y
            else:
                x = x + mlp(h, p["mlp"], cfg.activation)
            return x, (kc, vc)

        x, (ks, vs) = jax.lax.scan(body, x, (params["layers"], cache["k"], cache["v"]))
        new_cache["k"], new_cache["v"] = ks, vs

    elif cfg.family == "ssm":
        H, hd = cfg.d_model // cfg.rwkv_head_dim, cfg.rwkv_head_dim

        def body(x, inp):
            p, c = inp
            h = norm(x, p.get("ln1"), "layernorm")[:, 0]
            y, wkv = rwkv6_time_mix_step(
                h, c["shift_t"], c["wkv"], p["tm"], n_heads=H, head_dim=hd
            )
            x = x + y[:, None]
            h2 = norm(x, p.get("ln2"), "layernorm")[:, 0]
            y2 = rwkv6_channel_mix_step(h2, c["shift_c"], p["tm"])
            x = x + y2[:, None]
            new_c = {"shift_t": h.astype(jnp.float32), "shift_c": h2.astype(jnp.float32), "wkv": wkv}
            return x, new_c

        x, new_states = jax.lax.scan(body, x, (params["layers"], cache))
        new_cache = new_states

    elif cfg.family == "hybrid":
        every = cfg.hybrid_attn_every
        n_groups = cfg.n_layers // every
        grouped_p = jax.tree.map(
            lambda a: a.reshape(n_groups, every, *a.shape[1:]), params["layers"]
        )
        grouped_c = jax.tree.map(
            lambda a: a.reshape(n_groups, every, *a.shape[1:]), cache["mamba"]
        )

        def body(x, inp):
            p, c = inp
            h = norm(x, p.get("ln1"), cfg.norm)
            y, new_c = mamba2_decode_step(
                h, c, p["mamba"], d_state=cfg.ssm_state, head_dim=cfg.ssm_head_dim
            )
            return x + y, new_c

        new_mamba_groups = []
        ks, vs = [], []
        for gi in range(n_groups):
            p_g = jax.tree.map(lambda a: a[gi], grouped_p)
            c_g = jax.tree.map(lambda a: a[gi], grouped_c)
            x, nc = jax.lax.scan(body, x, (p_g, c_g))
            new_mamba_groups.append(nc)
            sp = params["shared_attn"]
            h = norm(x, sp["ln1"], cfg.norm)
            att, kc, vc = _decode_attn(
                cfg, h, sp["attn"], cache["k"][gi], cache["v"][gi], cos, sin, fill, slot, dist
            )
            x = x + att
            h = norm(x, sp["ln2"], cfg.norm)
            x = x + mlp(h, sp["mlp"], cfg.activation)
            ks.append(kc)
            vs.append(vc)
        new_cache["mamba"] = jax.tree.map(
            lambda *xs: jnp.concatenate([a for a in xs], axis=0),
            *new_mamba_groups,
        )
        new_cache["k"] = jnp.stack(ks)
        new_cache["v"] = jnp.stack(vs)

    elif cfg.family == "audio":

        def body(x, inp):
            p, kc, vc, ek, ev = inp
            h = norm(x, p.get("ln1"), cfg.norm)
            att, kc, vc = _decode_attn(cfg, h, p["attn"], kc, vc, None, None, fill, slot, dist)
            x = x + att
            hq = norm(x, p["cross"]["ln"], cfg.norm)
            B = x.shape[0]
            H, hd = cfg.n_heads, cfg.hd
            q = (hq @ p["cross"]["attn"]["wq"]).reshape(B, 1, H, hd)
            xatt = decode_attention(q, ek, ev)
            x = x + xatt.reshape(B, 1, H * hd) @ p["cross"]["attn"]["wo"]
            h2 = norm(x, p.get("ln2"), cfg.norm)
            x = x + mlp(h2, p["mlp"], cfg.activation)
            return x, (kc, vc)

        layers = dict(params["layers"])
        layers["cross"] = params["cross"]
        x, (ks, vs) = jax.lax.scan(
            body, x, (layers, cache["k"], cache["v"], cache["ek"], cache["ev"])
        )
        new_cache["k"], new_cache["v"] = ks, vs
    else:
        raise ValueError(cfg.family)

    x = norm(x, params.get("final_ln") or None, cfg.norm)
    logits = logits_from_hidden(cfg, params, x)
    if pos is not None:
        new_cache["pos"] = pos + 1
    return logits, new_cache


# =============================================================================
# public bundle
# =============================================================================


@dataclass(frozen=True)
class Model:
    cfg: ArchConfig
    dist: DistContext

    def init(self, key: jax.Array, dtype=jnp.float32) -> dict:
        return init_params(self.cfg, key, dtype)

    def loss(self, params, batch):
        return loss_fn(self.cfg, params, batch, self.dist)

    def hidden(self, params, batch):
        return forward_hidden(self.cfg, params, batch, self.dist)

    def logits(self, params, batch):
        h, aux = forward_hidden(self.cfg, params, batch, self.dist)
        return logits_from_hidden(self.cfg, params, h)

    def init_cache(self, batch: int, cache_len: int, dtype=jnp.float32):
        return init_cache(self.cfg, batch, cache_len, dtype)

    def decode(self, params, token, cache, batch_extras=None):
        return decode_step(self.cfg, params, token, cache, self.dist, batch_extras)


def build_model(cfg: ArchConfig | str, dist: DistContext | None = None) -> Model:
    if isinstance(cfg, str):
        cfg = get_config(cfg)
    return Model(cfg=cfg, dist=dist or DistContext())
