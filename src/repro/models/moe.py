"""Mixture-of-Experts layer with capacity-based top-k routing.

Dispatch is performed *within token groups* that map 1:1 onto the data-mesh
shards (the group count is the data-parallel degree): the position-in-expert
cumsum then never crosses a shard boundary, so the partitioner keeps routing
local and only the expert einsums communicate. Expert weights are sharded on
the model axis — over the expert dimension when it divides the axis (true
expert parallelism, granite-moe 32e/16) and over d_ff otherwise (tensor
parallelism inside each expert, mixtral 8e/16).

This layer is also the integration point for the paper's technique on MoE
architectures: :class:`repro.train.moe_balance.ExpertDiffusionBalancer` treats
experts as blocks with router-load weights and rebalances the expert->device
placement with the diffusion scheme between steps (DESIGN.md §4).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["moe_layer", "moe_capacity"]


def moe_capacity(tokens_per_group: int, n_experts: int, top_k: int, capacity_factor: float) -> int:
    cap = int(tokens_per_group * top_k * capacity_factor / n_experts)
    return max(4, min(tokens_per_group, cap))


def moe_layer(
    x: jax.Array,  # (B, S, D)
    p: dict,
    *,
    n_experts: int,
    top_k: int,
    capacity_factor: float,
    n_token_groups: int = 1,
    expert_parallel: bool = False,
    wsc=None,
) -> tuple[jax.Array, jax.Array]:
    """Returns (output (B,S,D), aux load-balancing loss scalar)."""
    B, S, D = x.shape
    T = B * S
    G = n_token_groups if T % max(1, n_token_groups) == 0 else 1
    Tg = T // G
    E, K = n_experts, top_k
    C = moe_capacity(Tg, E, K, capacity_factor)
    wsc = wsc or (lambda a, dims: a)
    # NOTE (§Perf pair 1, it.2): constraining the *activation* expert dim to
    # the model axis ("true EP") forces a (G,E,C,D) reshard per einsum that
    # GSPMD implements as replicate+all-reduce (~1.9 GB/layer-exec). Keeping
    # activations group-local and letting the (small) expert weights be
    # gathered on demand is strictly cheaper for these expert sizes; the
    # weights remain EP/FSDP-sharded in storage.
    e_ax = "."

    xf = wsc(x.reshape(G, Tg, D), "b..")
    logits = jnp.einsum("gtd,de->gte", xf, p["router"]).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)  # (G, Tg, E)
    gate, expert_idx = jax.lax.top_k(probs, K)  # (G, Tg, K)
    gate = gate / jnp.maximum(gate.sum(axis=-1, keepdims=True), 1e-9)

    # position-in-expert via a cumsum over the (group-local) token axis
    flat_e = expert_idx.reshape(G, Tg * K)  # token-major, K minor
    onehot = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)  # (G, Tg*K, E)
    pos = jnp.cumsum(onehot, axis=1) - 1  # (G, Tg*K, E)
    pos_in_e = jnp.take_along_axis(pos, flat_e[..., None], axis=-1)[..., 0]  # (G, Tg*K)
    keep = (pos_in_e < C).astype(x.dtype)

    # scatter-dispatch tokens into (G, E, C, D). The group dim MUST be a
    # scatter *batch* dim (vmap) — with explicit iota indices GSPMD treats
    # it as a general scatter, replicates the (G,E,C,D) operand and
    # all-reduces the partial scatters: 5 TB/device/step on granite-moe
    # train_4k (§Perf pair 1, it.1).
    x_rep = jnp.repeat(xf, K, axis=1)  # (G, Tg*K, D)
    pos_clip = jnp.minimum(pos_in_e, C - 1)

    def scatter_group(e_g, p_g, x_g):
        return jnp.zeros((E, C, D), dtype=x.dtype).at[e_g, p_g].add(x_g)

    disp = jax.vmap(scatter_group)(flat_e, pos_clip, x_rep * keep[..., None])
    disp = wsc(disp, f"b{e_ax}..")  # token groups on data; experts on model (EP)

    # expert FFN (SwiGLU), expert dim leading for EP/TP sharding
    h = jax.nn.silu(jnp.einsum("gecd,edf->gecf", disp, p["w_gate"])) * jnp.einsum(
        "gecd,edf->gecf", disp, p["w_up"]
    )
    h = wsc(h, f"b{e_ax}.." if expert_parallel else "b..m")
    out_e = jnp.einsum("gecf,efd->gecd", h, p["w_down"])
    out_e = wsc(out_e, f"b{e_ax}..")

    # combine: gather back and weight by the (renormalized) gates (batched
    # gather over the group dim, same partitioning argument as the scatter)
    back = jax.vmap(lambda o_g, e_g, p_g: o_g[e_g, p_g])(out_e, flat_e, pos_clip)
    back = back * (keep * gate.reshape(G, Tg * K).astype(x.dtype))[..., None]
    y = back.reshape(G, Tg, K, D).sum(axis=2).reshape(B, S, D)

    # auxiliary load-balancing loss (Switch): E * sum_e f_e * p_e
    frac = jnp.mean(
        jax.nn.one_hot(expert_idx[..., 0], E, dtype=jnp.float32), axis=(0, 1)
    )
    mean_prob = jnp.mean(probs, axis=(0, 1))
    aux = E * jnp.sum(frac * mean_prob)
    return y, aux
