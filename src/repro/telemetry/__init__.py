"""Telemetry: per-rank span tracing, bounded metrics, Chrome-trace export.

One instrumentation layer shared by every subsystem (the AMReX-TinyProfiler
role for this repo): the AMR pipeline stages, the stepping engines' substep
phases, halo plan builds, host<->device residency traffic, compile events,
and the serving job lifecycle all record into one process-wide
:class:`~repro.telemetry.tracer.Tracer`.

Design rules (the paper's bounded-metadata discipline, applied to
observability):

* **Bounded everywhere.** Every rank records into its own fixed-capacity
  ring buffer — old records are evicted (and the eviction counted), never
  accumulated; metric label sets are capped per metric. Per-rank telemetry
  memory is therefore independent of rank count and run length, the Table-1
  property.
* **Near-zero cost when disabled.** ``span()`` returns a shared no-op
  context manager when tracing is off; ``stage()`` always times (it replaces
  the hand-rolled ``perf_counter``/``StageStats`` idiom) but records
  nothing. An overhead test pins the disabled path.
* **One clock.** All timestamps come from the tracer's injectable ``clock``
  (default ``time.perf_counter``), so latency tests can substitute a fake
  clock and every ``StageStats.seconds`` is derivable from the spans that
  produced it — the two surfaces cannot disagree.

Usage::

    from repro import telemetry
    telemetry.configure(enabled=True)
    sim.run(8)
    telemetry.export.write_chrome_trace("trace.json")
    # then: python tools/trace_report.py trace.json
"""

from . import export
from .metrics import (
    BYTES_BUCKETS,
    SECONDS_BUCKETS,
    Counter,
    Gauge,
    Histogram,
    MetricsRegistry,
)
from .tracer import NULL_SPAN, Span, SpanRecord, Tracer, configure, get_tracer

__all__ = [
    "BYTES_BUCKETS",
    "SECONDS_BUCKETS",
    "Counter",
    "Gauge",
    "Histogram",
    "MetricsRegistry",
    "NULL_SPAN",
    "Span",
    "SpanRecord",
    "Tracer",
    "configure",
    "export",
    "get_tracer",
]
