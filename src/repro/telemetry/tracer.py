"""Per-rank span tracer with bounded ring buffers and an injectable clock.

The tracer is a process-wide singleton (:func:`get_tracer` /
:func:`configure`) so instrumentation sites can cache the object at import
time — ``configure`` mutates it in place, never replaces it. The repo is
single-threaded by design (simulated ranks run cooperatively on one host
thread), so no locking is needed; span nesting depth is tracked on the
tracer itself.

Records are the paper's bounded-metadata discipline applied to
observability: each simulated rank owns a fixed-capacity ring
(:class:`_Ring`) — a rank's telemetry memory is bounded by ``capacity``
records regardless of rank count or run length, evictions are counted, and
there is no global append-only log anywhere.
"""

from __future__ import annotations

import time
from typing import Any, Callable

from .metrics import MetricsRegistry

__all__ = ["SpanRecord", "Span", "Tracer", "NULL_SPAN", "configure", "get_tracer"]

# nominal bytes per record for the held-bytes bound (name/cat interned refs +
# three floats + small args dict); a sizing convention, not a measurement
RECORD_NOMINAL_BYTES = 160


class SpanRecord:
    """One completed span or instant event (immutable once recorded)."""

    __slots__ = ("name", "cat", "rank", "ph", "t0", "dur", "depth", "args")

    def __init__(self, name, cat, rank, ph, t0, dur, depth, args):
        self.name = name
        self.cat = cat  # subsystem: becomes the trace thread (tid)
        self.rank = rank  # becomes the trace process (pid)
        self.ph = ph  # "X" complete span | "i" instant
        self.t0 = t0
        self.dur = dur
        self.depth = depth
        self.args = args  # dict | None

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return (
            f"SpanRecord({self.name!r}, cat={self.cat!r}, rank={self.rank}, "
            f"ph={self.ph!r}, t0={self.t0:.6f}, dur={self.dur:.6f})"
        )


class _Ring:
    """Fixed-capacity record ring: eviction counted, memory bounded."""

    __slots__ = ("capacity", "_buf", "_next", "evicted", "total")

    def __init__(self, capacity: int) -> None:
        assert capacity > 0, capacity
        self.capacity = capacity
        self._buf: list[SpanRecord] = []
        self._next = 0  # overwrite cursor once the buffer is full
        self.evicted = 0
        self.total = 0

    def __len__(self) -> int:
        return len(self._buf)

    def append(self, rec: SpanRecord) -> None:
        self.total += 1
        if len(self._buf) < self.capacity:
            self._buf.append(rec)
            return
        self._buf[self._next] = rec
        self._next = (self._next + 1) % self.capacity
        self.evicted += 1

    def snapshot(self) -> list[SpanRecord]:
        """Records in chronological (recording) order."""
        return self._buf[self._next :] + self._buf[: self._next]


class _NullSpan:
    """Shared no-op span: the disabled fast path allocates nothing."""

    __slots__ = ()
    seconds = 0.0
    t0 = 0.0

    def set(self, **_kw) -> None:
        pass

    def __enter__(self) -> "_NullSpan":
        return self

    def __exit__(self, *_exc) -> bool:
        return False


NULL_SPAN = _NullSpan()


class Span:
    """A timed region; records into the tracer's ring on exit (if enabled).

    ``seconds`` is valid after ``__exit__`` and is the value the
    instrumentation feeds into ``StageStats`` — by construction, summing the
    recorded spans reproduces the stats surface exactly.
    """

    __slots__ = ("_tracer", "_record", "name", "cat", "rank", "args", "depth",
                 "t0", "seconds")

    def __init__(self, tracer: "Tracer", name: str, cat: str, rank: int,
                 record: bool, args: dict | None) -> None:
        self._tracer = tracer
        self._record = record
        self.name = name
        self.cat = cat
        self.rank = rank
        self.args = args
        self.depth = 0
        self.t0 = 0.0
        self.seconds = 0.0

    def set(self, **kw: Any) -> None:
        """Attach args discovered mid-span (bytes moved, counts, ...)."""
        if self.args is None:
            self.args = kw
        else:
            self.args.update(kw)

    def __enter__(self) -> "Span":
        tr = self._tracer
        self.depth = tr._depth
        tr._depth += 1
        self.t0 = tr.clock()
        return self

    def __exit__(self, *_exc) -> bool:
        tr = self._tracer
        t1 = tr.clock()
        tr._depth -= 1
        self.seconds = t1 - self.t0
        if self._record:
            tr._ring(self.rank).append(
                SpanRecord(self.name, self.cat, self.rank, "X", self.t0,
                           self.seconds, self.depth, self.args)
            )
        return False


class Tracer:
    """Process-wide span tracer + metrics registry.

    Attributes:
        enabled: master switch; when False, :meth:`span` and :meth:`instant`
            are no-ops and :meth:`stage` only times.
        capacity: per-rank ring capacity (records); changing it via
            :meth:`configure` drops existing rings.
        clock: monotonic time source, injectable for deterministic tests.
        metrics: the bounded :class:`~repro.telemetry.metrics.MetricsRegistry`.
    """

    def __init__(self, *, enabled: bool = False, capacity: int = 4096,
                 clock: Callable[[], float] = time.perf_counter) -> None:
        self.enabled = enabled
        self.capacity = capacity
        self.clock = clock
        self.metrics = MetricsRegistry()
        self._rings: dict[int, _Ring] = {}
        self._depth = 0

    # -- configuration ---------------------------------------------------------
    def configure(self, *, enabled: bool | None = None,
                  capacity: int | None = None,
                  clock: Callable[[], float] | None = None) -> "Tracer":
        """Mutate the tracer in place (identity-stable: cached references at
        instrumentation sites keep working). A capacity change resets the
        rings — the bound is a construction property, not a trim."""
        if enabled is not None:
            self.enabled = enabled
        if clock is not None:
            self.clock = clock
        if capacity is not None and capacity != self.capacity:
            self.capacity = capacity
            self._rings = {}
        return self

    def reset(self) -> None:
        """Drop all recorded spans and metrics (keeps configuration)."""
        self._rings = {}
        self.metrics.reset()
        self._depth = 0

    # -- recording -------------------------------------------------------------
    def _ring(self, rank: int) -> _Ring:
        ring = self._rings.get(rank)
        if ring is None:
            ring = self._rings[rank] = _Ring(self.capacity)
        return ring

    def span(self, name: str, *, cat: str = "default", rank: int = 0,
             **args: Any):
        """A recorded span — the pure-observability idiom. Returns the shared
        :data:`NULL_SPAN` when disabled (no allocation, no clock reads)."""
        if not self.enabled:
            return NULL_SPAN
        return Span(self, name, cat, rank, True, args or None)

    def stage(self, name: str, *, cat: str = "stage", rank: int = 0,
              **args: Any) -> Span:
        """A span that *always* times (its ``.seconds`` feeds ``StageStats``)
        but records only when enabled — the drop-in replacement for the
        ``t0 = perf_counter(); ...; StageStats(seconds=...)`` boilerplate."""
        return Span(self, name, cat, rank, self.enabled, args or None)

    def instant(self, name: str, *, cat: str = "default", rank: int = 0,
                **args: Any) -> None:
        """Record a zero-duration event (h2d/d2h transfer, jit trace, job
        lifecycle edge). No-op when disabled."""
        if not self.enabled:
            return
        self._ring(rank).append(
            SpanRecord(name, cat, rank, "i", self.clock(), 0.0, self._depth,
                       args or None)
        )

    # -- introspection ---------------------------------------------------------
    def records(self, rank: int | None = None) -> list[SpanRecord]:
        """Recorded events, chronological; all ranks merged unless ``rank``
        is given."""
        if rank is not None:
            ring = self._rings.get(rank)
            return ring.snapshot() if ring is not None else []
        out: list[SpanRecord] = []
        for r in sorted(self._rings):
            out.extend(self._rings[r].snapshot())
        out.sort(key=lambda rec: rec.t0)
        return out

    def buffer_stats(self) -> dict[int, dict[str, int]]:
        """Per-rank ring accounting: entries, capacity, evicted, total."""
        return {
            r: {
                "entries": len(ring),
                "capacity": ring.capacity,
                "evicted": ring.evicted,
                "total": ring.total,
            }
            for r, ring in sorted(self._rings.items())
        }

    def held_bytes_per_rank(self) -> dict[int, int]:
        """Nominal telemetry bytes held per rank (the Table-1 quantity for
        the observability layer): entries x a fixed per-record size. Bounded
        by ``capacity * RECORD_NOMINAL_BYTES`` for every rank by
        construction."""
        return {
            r: len(ring) * RECORD_NOMINAL_BYTES
            for r, ring in sorted(self._rings.items())
        }


# the process-wide tracer: identity-stable, mutated by configure()
_GLOBAL = Tracer()


def get_tracer() -> Tracer:
    """The process-wide tracer (cacheable at import time)."""
    return _GLOBAL


def configure(**kw) -> Tracer:
    """Configure the process-wide tracer; see :meth:`Tracer.configure`."""
    return _GLOBAL.configure(**kw)
