"""Chrome-trace / Perfetto export and span -> stats aggregation.

Layout convention (load the JSON in ``chrome://tracing`` or
https://ui.perfetto.dev):

* **pid = rank.** Every simulated rank is one trace process; control-plane
  work that is not attributable to a single rank records under rank 0.
* **tid = subsystem.** Each span category ("amr", "stage", "substep",
  "halo.plan", "compile", "residency", "serving", ...) gets one thread per
  process, named accordingly.
* **Counter tracks.** Events carrying a ``bytes`` arg (residency h2d/d2h,
  route payloads) accumulate into per-(rank, category) byte counter tracks;
  ``compile``-category events accumulate into a compile-count track — the
  bytes/compiles timelines the paper-style breakdowns read.

The trace also embeds the bounded metrics snapshot and per-rank ring
accounting under ``"metadata"`` so ``tools/trace_report.py`` can render
per-pair p2p bytes and prove the buffers stayed bounded, from the artifact
alone.
"""

from __future__ import annotations

import json
from pathlib import Path

from .tracer import SpanRecord, Tracer, get_tracer

__all__ = [
    "to_chrome_trace",
    "write_chrome_trace",
    "stage_seconds",
    "stage_totals",
]

TRACE_VERSION = 1


def _tid_map(records: list[SpanRecord]) -> dict[str, int]:
    """Stable category -> tid assignment (sorted; tid 0 is metadata-only)."""
    return {cat: i + 1 for i, cat in enumerate(sorted({r.cat for r in records}))}


def to_chrome_trace(tracer: Tracer | None = None) -> dict:
    """Render the tracer's records as a Chrome-trace dict (JSON-ready)."""
    tr = tracer if tracer is not None else get_tracer()
    records = tr.records()
    tids = _tid_map(records)
    base = min((r.t0 for r in records), default=0.0)
    events: list[dict] = []
    ranks = sorted({r.rank for r in records})
    for rank in ranks:
        events.append(
            {
                "ph": "M", "pid": rank, "tid": 0, "name": "process_name",
                "args": {"name": f"rank {rank}"},
            }
        )
        events.append(
            {
                "ph": "M", "pid": rank, "tid": 0,
                "name": "process_sort_index", "args": {"sort_index": rank},
            }
        )
    seen_threads: set[tuple[int, int]] = set()
    counters: dict[tuple[int, str], float] = {}  # (rank, track) -> cumulative
    for rec in records:
        tid = tids[rec.cat]
        if (rec.rank, tid) not in seen_threads:
            seen_threads.add((rec.rank, tid))
            events.append(
                {
                    "ph": "M", "pid": rec.rank, "tid": tid,
                    "name": "thread_name", "args": {"name": rec.cat},
                }
            )
        ts = round((rec.t0 - base) * 1e6, 3)
        ev = {
            "ph": rec.ph, "pid": rec.rank, "tid": tid, "name": rec.name,
            "cat": rec.cat, "ts": ts,
        }
        if rec.ph == "X":
            ev["dur"] = round(rec.dur * 1e6, 3)
        else:
            ev["s"] = "t"
        if rec.args:
            ev["args"] = dict(rec.args)
        events.append(ev)
        # synthesized counter tracks
        nbytes = rec.args.get("bytes") if rec.args else None
        if isinstance(nbytes, (int, float)):
            key = (rec.rank, f"{rec.cat}.bytes")
            counters[key] = counters.get(key, 0) + nbytes
            events.append(
                {
                    "ph": "C", "pid": rec.rank, "tid": 0, "name": key[1],
                    "ts": ts, "args": {"bytes": counters[key]},
                }
            )
        if rec.cat == "compile":
            key = (rec.rank, "compiles")
            counters[key] = counters.get(key, 0) + 1
            events.append(
                {
                    "ph": "C", "pid": rec.rank, "tid": 0, "name": "compiles",
                    "ts": ts, "args": {"count": counters[key]},
                }
            )
    return {
        "traceEvents": events,
        "displayTimeUnit": "ms",
        "metadata": {
            "trace_version": TRACE_VERSION,
            "clock": "tracer",
            "ranks": ranks,
            "buffers": {str(k): v for k, v in tr.buffer_stats().items()},
            "metrics": tr.metrics.snapshot(),
        },
    }


def write_chrome_trace(path: str | Path, tracer: Tracer | None = None) -> Path:
    """Export the tracer to a Chrome-trace JSON file; returns the path."""
    path = Path(path)
    path.parent.mkdir(parents=True, exist_ok=True)
    path.write_text(json.dumps(to_chrome_trace(tracer)) + "\n")
    return path


def stage_seconds(tracer: Tracer | None = None, *, cat: str = "stage") -> dict[str, float]:
    """Sum recorded span durations per name for one category, accumulating
    in recording order — the identical left-to-right float additions the
    ``StageStats`` surfaces perform, so a stage's span sum equals its
    ``data_stats`` seconds *exactly* (pinned by tests/test_telemetry.py)."""
    tr = tracer if tracer is not None else get_tracer()
    out: dict[str, float] = {}
    for rec in tr.records():
        if rec.ph == "X" and rec.cat == cat:
            out[rec.name] = out.get(rec.name, 0.0) + rec.dur
    return out


def stage_totals(tracer: Tracer | None = None) -> dict[tuple[str, str], dict]:
    """(cat, name) -> {count, seconds} over every recorded span."""
    tr = tracer if tracer is not None else get_tracer()
    out: dict[tuple[str, str], dict] = {}
    for rec in tr.records():
        if rec.ph != "X":
            continue
        agg = out.setdefault((rec.cat, rec.name), {"count": 0, "seconds": 0.0})
        agg["count"] += 1
        agg["seconds"] += rec.dur
    return out
