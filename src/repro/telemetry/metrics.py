"""Bounded metrics: counters, gauges, histograms with fixed bucket layouts.

Same discipline as the span rings: nothing here can grow without bound. The
registry caps the number of metrics, every metric caps its label-set count
(new label combinations beyond the cap fold into one ``overflow`` series and
the fold is counted), and histograms use *fixed* bucket layouts declared at
construction — per-rank metric memory is O(metrics x series x buckets), all
three capped, independent of rank count and run length.
"""

from __future__ import annotations

from bisect import bisect_left
from typing import Any

__all__ = [
    "SECONDS_BUCKETS",
    "BYTES_BUCKETS",
    "Counter",
    "Gauge",
    "Histogram",
    "MetricsRegistry",
]

# fixed layouts (upper bounds; one implicit +inf bucket at the end):
# latencies from 1us to 10s, decades
SECONDS_BUCKETS: tuple[float, ...] = tuple(10.0 ** e for e in range(-6, 2))
# message/transfer sizes from 64B to 1GiB, x4 steps
BYTES_BUCKETS: tuple[float, ...] = tuple(float(4 ** e) for e in range(3, 16))

_OVERFLOW_KEY = (("overflow", "true"),)


def _label_key(labels: dict[str, Any]) -> tuple:
    return tuple(sorted((k, str(v)) for k, v in labels.items()))


def _series_name(key: tuple) -> str:
    return ",".join(f"{k}={v}" for k, v in key) if key else "_total"


class _Bounded:
    """Shared label-set bounding: at most ``max_series`` label combinations
    per metric; later combinations fold into the overflow series."""

    def __init__(self, name: str, max_series: int) -> None:
        self.name = name
        self.max_series = max_series
        self.overflowed = 0  # observations folded into the overflow series

    def _key(self, labels: dict, existing: dict) -> tuple:
        key = _label_key(labels)
        if key in existing or len(existing) < self.max_series:
            return key
        self.overflowed += 1
        return _OVERFLOW_KEY


class Counter(_Bounded):
    """Monotonic per-label-set totals (bytes, messages, compiles)."""

    def __init__(self, name: str, max_series: int = 64) -> None:
        super().__init__(name, max_series)
        self._values: dict[tuple, float] = {}

    def inc(self, value: float = 1, **labels: Any) -> None:
        key = self._key(labels, self._values)
        self._values[key] = self._values.get(key, 0) + value

    def total(self) -> float:
        return sum(self._values.values())

    def series(self) -> dict[str, float]:
        return {_series_name(k): v for k, v in sorted(self._values.items())}

    def snapshot(self) -> dict:
        return {
            "type": "counter",
            "total": self.total(),
            "series": self.series(),
            "overflowed": self.overflowed,
        }


class Gauge(_Bounded):
    """Last-written value per label set (queue depths, cache sizes)."""

    def __init__(self, name: str, max_series: int = 64) -> None:
        super().__init__(name, max_series)
        self._values: dict[tuple, float] = {}

    def set(self, value: float, **labels: Any) -> None:
        key = self._key(labels, self._values)
        self._values[key] = value

    def series(self) -> dict[str, float]:
        return {_series_name(k): v for k, v in sorted(self._values.items())}

    def snapshot(self) -> dict:
        return {
            "type": "gauge",
            "series": self.series(),
            "overflowed": self.overflowed,
        }


class Histogram(_Bounded):
    """Fixed-bucket distribution (latencies, message sizes).

    ``buckets`` are upper bounds; an observation lands in the first bucket
    whose bound is >= the value, or the implicit +inf bucket. The layout is
    fixed at construction — two histograms with the same layout are directly
    comparable across runs and ranks.
    """

    def __init__(self, name: str, buckets: tuple[float, ...] = SECONDS_BUCKETS,
                 max_series: int = 64) -> None:
        super().__init__(name, max_series)
        assert tuple(buckets) == tuple(sorted(buckets)), "buckets must ascend"
        self.buckets = tuple(float(b) for b in buckets)
        # label key -> [counts per bucket + inf, sum, n]
        self._series: dict[tuple, list] = {}

    def observe(self, value: float, **labels: Any) -> None:
        key = self._key(labels, self._series)
        s = self._series.get(key)
        if s is None:
            s = self._series[key] = [[0] * (len(self.buckets) + 1), 0.0, 0]
        counts = s[0]
        # first bucket whose bound is >= value; past-the-end = +inf bucket
        counts[bisect_left(self.buckets, value)] += 1
        s[1] += value
        s[2] += 1

    def series(self) -> dict[str, dict]:
        out = {}
        for key, (counts, total, n) in sorted(self._series.items()):
            out[_series_name(key)] = {
                "counts": list(counts),
                "sum": total,
                "n": n,
            }
        return out

    def snapshot(self) -> dict:
        return {
            "type": "histogram",
            "buckets": list(self.buckets),
            "series": self.series(),
            "overflowed": self.overflowed,
        }


class _NullMetric:
    """Returned once the registry is full: observations are dropped (and the
    drop counted by the registry), never unbounded."""

    def inc(self, value: float = 1, **labels: Any) -> None:
        pass

    def set(self, value: float, **labels: Any) -> None:
        pass

    def observe(self, value: float, **labels: Any) -> None:
        pass


_NULL_METRIC = _NullMetric()


class MetricsRegistry:
    """Bounded name -> metric map with get-or-create accessors."""

    def __init__(self, *, max_metrics: int = 256, max_series: int = 64) -> None:
        self.max_metrics = max_metrics
        self.max_series = max_series
        self._metrics: dict[str, Any] = {}
        self.dropped_metrics = 0

    def __len__(self) -> int:
        return len(self._metrics)

    def _get(self, name: str, cls, **kw):
        m = self._metrics.get(name)
        if m is not None:
            assert isinstance(m, cls), (name, type(m), cls)
            return m
        if len(self._metrics) >= self.max_metrics:
            self.dropped_metrics += 1
            return _NULL_METRIC
        m = self._metrics[name] = cls(name, max_series=self.max_series, **kw)
        return m

    def counter(self, name: str) -> Counter:
        return self._get(name, Counter)

    def gauge(self, name: str) -> Gauge:
        return self._get(name, Gauge)

    def histogram(self, name: str,
                  buckets: tuple[float, ...] = SECONDS_BUCKETS) -> Histogram:
        return self._get(name, Histogram, buckets=buckets)

    def snapshot(self) -> dict:
        return {
            name: m.snapshot() for name, m in sorted(self._metrics.items())
        }

    def reset(self) -> None:
        self._metrics = {}
        self.dropped_metrics = 0
