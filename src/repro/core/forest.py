"""Distributed block forest (paper §2): rank-local block storage + ghost info.

Every rank stores *only* its own blocks. For each local block it additionally
knows the IDs and owner ranks of all spatially adjacent blocks (face, edge,
or corner — the distributed adjacency graph of §2). There is no replicated
global meta data: the per-rank memory is O(local blocks), independent of the
total number of ranks — the paper's central scalability property, asserted by
:func:`metadata_bytes_per_rank` and measured in ``benchmarks/metadata_sync.py``.

Forest *initialization* constructs the initial partition globally (as does
waLBerla's setup phase); every later modification (refinement, balancing,
migration) is performed by the distributed algorithms in
:mod:`repro.core.refine` / :mod:`repro.core.balancing` /
:mod:`repro.core.migration` using only rank-local state and messages.
"""

from __future__ import annotations

import itertools
from dataclasses import dataclass, field
from typing import Any, Callable, Iterable, Iterator

from .blockid import ALL_DIRECTIONS, ForestGeometry, children_ids, parent_id
from .comm import BYTES_BLOCK_ID, BYTES_LEVEL, BYTES_RANK, BYTES_WEIGHT

__all__ = ["Block", "BlockForest", "make_uniform_forest", "make_forest_from_levels"]


@dataclass
class Block:
    """A rank-local block. ``data`` holds named simulation payloads (actual
    forest); proxy blocks leave it empty and use the link fields instead."""

    bid: int
    level: int
    owner: int
    neighbors: dict[int, int] = field(default_factory=dict)  # bid -> owner rank
    weight: float = 1.0
    # refinement marking state (§2.2): effective target level
    target_level: int | None = None
    # bilateral proxy<->actual links (§2.3):
    #   on actual blocks: target rank per new block (1 for keep/move-or-merge, 8 for split)
    #   on proxy blocks: source rank per constituent actual block (8 for merge)
    target_ranks: list[int] = field(default_factory=list)
    source_ranks: list[int] = field(default_factory=list)
    data: dict[str, Any] = field(default_factory=dict)

    def clone_shallow(self) -> "Block":
        return Block(
            bid=self.bid,
            level=self.level,
            owner=self.owner,
            neighbors=dict(self.neighbors),
            weight=self.weight,
        )

    def meta_nbytes(self) -> int:
        """Approximate serialized meta-data size (paper §2.4: 'a few bytes')."""
        return (
            BYTES_BLOCK_ID
            + BYTES_LEVEL
            + BYTES_RANK
            + BYTES_WEIGHT
            + len(self.neighbors) * (BYTES_BLOCK_ID + BYTES_RANK)
            + (len(self.source_ranks) + len(self.target_ranks)) * BYTES_RANK
        )


class BlockForest:
    """Rank-partitioned forest: ``ranks[r]`` maps bid -> Block for rank r."""

    def __init__(self, geom: ForestGeometry, nranks: int):
        self.geom = geom
        self.nranks = nranks
        self.ranks: list[dict[int, Block]] = [dict() for _ in range(nranks)]

    # -- rank-local access (what the distributed algorithms use) ----------------
    def local_blocks(self, rank: int) -> dict[int, Block]:
        return self.ranks[rank]

    def neighbor_ranks(self, rank: int) -> set[int]:
        """Process graph neighbors of ``rank`` (paper §2.4.2)."""
        out: set[int] = set()
        for blk in self.ranks[rank].values():
            out.update(r for r in blk.neighbors.values() if r != rank)
        return out

    def insert(self, blk: Block) -> None:
        self.ranks[blk.owner][blk.bid] = blk

    def remove(self, rank: int, bid: int) -> Block:
        return self.ranks[rank].pop(bid)

    # -- whole-forest iteration (verification / setup / data-plane export) ------
    def all_blocks(self) -> Iterator[Block]:
        for rank_blocks in self.ranks:
            yield from rank_blocks.values()

    def num_blocks(self) -> int:
        return sum(len(r) for r in self.ranks)

    def blocks_per_rank(self, level: int | None = None) -> list[int]:
        if level is None:
            return [len(r) for r in self.ranks]
        return [sum(1 for b in r.values() if b.level == level) for r in self.ranks]

    def weights_per_rank(self, level: int | None = None) -> list[float]:
        return [
            sum(b.weight for b in r.values() if level is None or b.level == level)
            for r in self.ranks
        ]

    def levels_in_use(self) -> list[int]:
        return sorted({b.level for b in self.all_blocks()})

    def metadata_bytes_per_rank(self) -> list[int]:
        return [sum(b.meta_nbytes() for b in r.values()) for r in self.ranks]

    # -- invariants (test/verification only: global scans) ----------------------
    def check_leaf_cover(self) -> None:
        """Leaves cover the domain exactly: total volume matches and no block
        is an ancestor of another (octree leaves can only overlap that way)."""
        geom = self.geom
        total = 0
        ids = sorted(b.bid for b in self.all_blocks())
        assert len(ids) == len(set(ids)), "duplicate block ids"
        for b in self.all_blocks():
            side = 1 << (geom.max_level - b.level)
            total += side**3
        full = (1 << geom.max_level) ** 3 * geom.num_roots
        assert total == full, f"leaf volume {total} != domain volume {full}"
        # ancestor check: for consecutive sorted ids a < b, b descends from a
        # iff shifting b right by 3*(level_b - level_a) gives a.
        by_id = {b.bid: b for b in self.all_blocks()}
        for bid in ids:
            cur = bid >> 3
            while cur >= (1 << geom.root_bits):
                assert cur not in by_id, f"{cur:#x} is an ancestor of {bid:#x}"
                cur >>= 3

    def check_adjacency(self) -> None:
        """Neighbor lists are complete, symmetric, owner-correct, geometric."""
        owner_of = {b.bid: b.owner for b in self.all_blocks()}
        by_id = {b.bid: b for b in self.all_blocks()}
        for b in self.all_blocks():
            for nb, owner in b.neighbors.items():
                assert nb in by_id, f"{b.bid:#x} lists non-leaf neighbor {nb:#x}"
                assert owner == owner_of[nb], f"stale owner for {nb:#x} at {b.bid:#x}"
                assert self.geom.adjacent(b.bid, nb), f"{b.bid:#x} !~ {nb:#x}"
                assert b.bid in by_id[nb].neighbors, f"asymmetric {b.bid:#x}/{nb:#x}"
            # completeness: every leaf geometrically adjacent must be listed
            expected = _geometric_neighbors(self.geom, b.bid, by_id)
            assert expected == set(b.neighbors), (
                f"block {b.bid:#x}: neighbors {sorted(b.neighbors)} != "
                f"expected {sorted(expected)}"
            )

    def check_two_one_balance(self) -> None:
        for b in self.all_blocks():
            by_level = {nb: self.geom.level_of(nb) for nb in b.neighbors}
            for nb, lvl in by_level.items():
                assert abs(lvl - b.level) <= 1, (
                    f"2:1 violated: {b.bid:#x} (L{b.level}) ~ {nb:#x} (L{lvl})"
                )

    def check_all(self) -> None:
        self.check_leaf_cover()
        self.check_adjacency()
        self.check_two_one_balance()


# -- construction -----------------------------------------------------------------


def _geometric_neighbors(geom: ForestGeometry, bid: int, leaves: dict[int, Any]) -> set[int]:
    """All leaves adjacent to ``bid`` given the full leaf map (init/verify only)."""
    out: set[int] = set()
    for dx, dy, dz in ALL_DIRECTIONS:
        same = geom.neighbor_region_ids(bid, dx, dy, dz)
        if same is None:
            continue
        # walk up: the region may be covered by a coarser leaf
        cur = same
        found = False
        while cur.bit_length() > geom.root_bits:
            if cur in leaves:
                out.add(cur)
                found = True
                break
            cur = parent_id(cur)
        if found:
            continue
        # walk down: covered by finer leaves; recurse into touching children
        stack = [same]
        while stack:
            cand = stack.pop()
            if cand in leaves:
                if geom.adjacent(bid, cand):
                    out.add(cand)
                continue
            if geom.level_of(cand) >= geom.max_level:
                continue
            for ch in children_ids(cand):
                if geom.adjacent(bid, ch) or _contains(geom, ch, bid):
                    stack.append(ch)
    out.discard(bid)
    return out


def _contains(geom: ForestGeometry, a: int, b: int) -> bool:
    ax0, ay0, az0, ax1, ay1, az1 = geom.aabb(a)
    bx0, by0, bz0, bx1, by1, bz1 = geom.aabb(b)
    return ax0 <= bx0 and ay0 <= by0 and az0 <= bz0 and ax1 >= bx1 and ay1 >= by1 and az1 >= bz1


def build_adjacency(geom: ForestGeometry, blocks: Iterable[Block]) -> None:
    """(Re)compute neighbor lists for a *complete* block set. Init-time only —
    post-init adjacency is maintained incrementally by the distributed
    algorithms; tests use this as the oracle."""
    by_id = {b.bid: b for b in blocks}
    for b in by_id.values():
        b.neighbors = {
            nb: by_id[nb].owner for nb in _geometric_neighbors(geom, b.bid, by_id)
        }


def make_forest_from_levels(
    geom: ForestGeometry,
    nranks: int,
    leaf_ids: Iterable[int],
    assign: Callable[[int, int], int] | None = None,
    order: str = "morton",
) -> BlockForest:
    """Build a forest from an explicit leaf-id set, distributing blocks along
    the SFC (default Morton) into ``nranks`` equal contiguous chunks — the
    standard static initial partition the paper starts from (Fig. 1)."""
    forest = BlockForest(geom, nranks)
    ids = sorted(leaf_ids, key=geom.morton_key if order == "morton" else geom.hilbert_key)
    n = len(ids)
    blocks = []
    for i, bid in enumerate(ids):
        owner = assign(i, n) if assign else min(nranks - 1, i * nranks // max(1, n))
        blocks.append(Block(bid=bid, level=geom.level_of(bid), owner=owner))
    build_adjacency(geom, blocks)
    for b in blocks:
        forest.insert(b)
    return forest


def make_uniform_forest(
    geom: ForestGeometry, nranks: int, level: int = 0, order: str = "morton"
) -> BlockForest:
    """Uniformly refined forest: every root refined ``level`` times."""
    leaf_ids: list[int] = []
    for root in range(geom.num_roots):
        frontier = [geom.root_id(root)]
        for _ in range(level):
            frontier = [c for b in frontier for c in children_ids(b)]
        leaf_ids.extend(frontier)
    return make_forest_from_levels(geom, nranks, leaf_ids, order=order)
