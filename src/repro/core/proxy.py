"""Lightweight proxy data structure (paper §2.3) and proxy migration (§2.4).

The proxy forest is a shallow, topology-only copy of the actual forest that
conforms to the *target* levels computed in §2.2. Proxy blocks carry no
simulation data — only identity, connectivity, a weight, and the bilateral
links to their actual counterparts:

* each **actual** block stores one target rank per corresponding proxy block
  (8 for a split, 1 otherwise) — ``Block.target_ranks``;
* each **proxy** block stores one source rank per corresponding actual block
  (8 for a merge, 1 otherwise) — ``Block.source_ranks``.

Construction is process-local except for one neighbor exchange of the new
block infos plus one forwarding round for merge groups, so its runtime is
independent of the total number of ranks (paper §2.3).

:func:`migrate_proxy_blocks` is the framework part of the load balancing
stage: it moves proxy blocks to their assigned target ranks — a transfer of
only a few bytes each — while maintaining the bilateral links and the
distributed adjacency (owner ranks) of all neighbors. Misaddressed neighbor
updates (both endpoints moved in the same round) are fixed by one forwarding
round through the previous owner.
"""

from __future__ import annotations

from collections import defaultdict
from typing import Callable

from .blockid import child_id, children_ids, octant_of, parent_id, sibling_ids
from .comm import BYTES_BLOCK_ID, BYTES_LEVEL, BYTES_RANK, BYTES_WEIGHT, Comm
from .forest import Block, BlockForest

__all__ = ["build_proxy", "migrate_proxy_blocks", "ProxyWeightFn"]

# weight callback: (old actual block, kind, new bid) -> proxy block weight.
ProxyWeightFn = Callable[[Block, str, int], float]


def _default_weight(old: Block, _kind: str, _new_bid: int) -> float:
    """Propagate the actual block's weight onto its proxy successor(s).

    Per-block cost model (paper §3.2: every block stores a grid of the same
    size, so cost is per *block*): split children inherit the parent's
    weight, a merged block the designated sibling's. The old default returned
    a hardcoded 1.0, which silently reset every custom weight — even on
    plain keeps — on each AMR cycle; callers with additive weight semantics
    (e.g. particle counts) should install an explicit weight callback (see
    ``AMRPipeline.block_weight_fn`` / ``repro.particles.balance``)."""
    return old.weight


def build_proxy(
    forest: BlockForest,
    comm: Comm,
    ghost_targets: list[dict[int, int]],
    weight_fn: ProxyWeightFn | None = None,
) -> BlockForest:
    """Create the proxy forest from ``target_level`` and establish links."""
    geom = forest.geom
    R = forest.nranks
    weight_fn = weight_fn or _default_weight
    proxy = BlockForest(geom, R)

    # -- step 1: process-local creation of proxy blocks + links ---------------
    # new_infos[r][old_bid] = [(new_bid, new_owner, kind)]
    new_infos: list[dict[int, list[tuple[int, int, str]]]] = [dict() for _ in range(R)]
    for r in range(R):
        for bid, blk in forest.local_blocks(r).items():
            t = blk.target_level
            assert t is not None, "run mark_and_balance_targets first"
            if t == blk.level:
                blk.target_ranks = [r]
                pb = Block(bid=bid, level=blk.level, owner=r,
                           weight=weight_fn(blk, "keep", bid), source_ranks=[r])
                pb.data["kind"] = "keep"
                proxy.insert(pb)
                new_infos[r][bid] = [(bid, r, "keep")]
            elif t == blk.level + 1:
                blk.target_ranks = [r] * 8
                infos = []
                for ch in children_ids(bid):
                    pb = Block(bid=ch, level=blk.level + 1, owner=r,
                               weight=weight_fn(blk, "split", ch), source_ranks=[r])
                    pb.data["kind"] = "split"
                    proxy.insert(pb)
                    infos.append((ch, r, "split"))
                new_infos[r][bid] = infos
            else:  # merge: all 8 siblings are leaves (guaranteed by §2.2)
                sibs = sibling_ids(bid)
                owners = {
                    s: (r if s == bid else blk.neighbors[s]) for s in sibs
                }
                designated = owners[min(sibs)]
                blk.target_ranks = [designated]
                pid = parent_id(bid)
                if bid == min(sibs):
                    pb = Block(bid=pid, level=blk.level - 1, owner=r,
                               weight=weight_fn(blk, "merge", pid),
                               source_ranks=[owners[child_id(pid, o)] for o in range(8)])
                    pb.data["kind"] = "merge"
                    proxy.insert(pb)
                new_infos[r][bid] = [(pid, designated, "merge")]

    # -- step 2: exchange new-block infos with old-neighbor owners ------------
    nbytes_info = BYTES_BLOCK_ID + BYTES_RANK + BYTES_LEVEL
    for r in range(R):
        per_dst: dict[int, list[tuple[int, list[tuple[int, int, str]]]]] = defaultdict(list)
        for bid, blk in forest.local_blocks(r).items():
            for owner in set(blk.neighbors.values()):
                if owner != r:
                    per_dst[owner].append((bid, new_infos[r][bid]))
        for dst, items in per_dst.items():
            n = sum(len(infos) for _, infos in items)
            comm.send(r, dst, "newinfo", items, nbytes=n * nbytes_info)
    inbox = comm.exchange()
    ghost_new: list[dict[int, list[tuple[int, int, str]]]] = [dict() for _ in range(R)]
    for dst, msgs in inbox.items():
        for _tag, items in msgs:
            for old_bid, infos in items:
                ghost_new[dst][old_bid] = infos

    # -- step 3: per-old-block candidate sets; forward merge candidates -------
    cands: list[dict[int, dict[int, int]]] = [dict() for _ in range(R)]  # old bid -> {new bid: owner}
    for r in range(R):
        local = forest.local_blocks(r)
        for bid, blk in local.items():
            c: dict[int, int] = {}
            for nbid, nowner in blk.neighbors.items():
                infos = (
                    new_infos[r].get(nbid)
                    if nowner == r
                    else ghost_new[r].get(nbid)
                )
                assert infos is not None, f"missing new-info for {nbid:#x}"
                for new_bid, new_owner, _kind in infos:
                    c[new_bid] = new_owner
            for new_bid, new_owner, _kind in new_infos[r][bid]:
                c[new_bid] = new_owner
            cands[r][bid] = c
    # forward merge-group candidates to the designated owner
    for r in range(R):
        for bid, blk in forest.local_blocks(r).items():
            if blk.target_level == blk.level - 1:
                designated = blk.target_ranks[0]
                pid = parent_id(bid)
                payload = (pid, list(cands[r][bid].items()))
                if designated == r:
                    # local: merge directly below
                    cands[r].setdefault(-pid, {}).update(cands[r][bid])
                else:
                    comm.send(r, designated, "mcand", payload,
                              nbytes=len(cands[r][bid]) * (BYTES_BLOCK_ID + BYTES_RANK))
    inbox = comm.exchange()
    for dst, msgs in inbox.items():
        for _tag, (pid, items) in msgs:
            cands[dst].setdefault(-pid, {}).update(dict(items))

    # -- step 4: adjacency of proxy blocks (geometric filter) -----------------
    for r in range(R):
        for pb in proxy.local_blocks(r).values():
            if pb.data["kind"] == "merge":
                c = cands[r].get(-pb.bid, {})
            elif pb.data["kind"] == "split":
                c = cands[r][parent_id(pb.bid)]
            else:
                c = cands[r][pb.bid]
            pb.neighbors = {
                nb: owner
                for nb, owner in c.items()
                if nb != pb.bid and geom.adjacent(pb.bid, nb)
            }
    return proxy


def migrate_proxy_blocks(
    proxy: BlockForest,
    actual: BlockForest,
    comm: Comm,
    assignments: list[dict[int, int]],
) -> int:
    """Framework part of the dynamic load balancing stage (§2.4).

    Moves proxy blocks to their assigned target ranks, updating (a) the
    bilateral links on the actual blocks, (b) the neighbor owner maps of all
    adjacent proxy blocks. Returns the number of migrated blocks.
    """
    R = proxy.nranks
    moved = 0
    move_table: list[dict[int, int]] = [dict() for _ in range(R)]
    local_updates: list[list[tuple[int, int, int]]] = [[] for _ in range(R)]

    for r in range(R):
        targets = assignments[r] if r < len(assignments) else {}
        for bid, tgt in list(targets.items()):
            blk = proxy.local_blocks(r).get(bid)
            if blk is None or tgt == r:
                continue
            moved += 1
            move_table[r][bid] = tgt
            proxy.remove(r, bid)
            blk.owner = tgt
            comm.send(r, tgt, "move", blk, nbytes=blk.meta_nbytes())
            # neighbor owner updates
            for nb, nowner in blk.neighbors.items():
                upd = (nb, bid, tgt)
                if nowner == r:
                    local_updates[r].append(upd)
                else:
                    comm.send(r, nowner, "nbupd", upd,
                              nbytes=2 * BYTES_BLOCK_ID + BYTES_RANK)
            # bilateral link updates on the actual blocks
            kind = blk.data.get("kind", "keep")
            if kind == "keep":
                links = [(bid, 0, blk.source_ranks[0])]
            elif kind == "split":
                links = [(parent_id(bid), octant_of(bid), blk.source_ranks[0])]
            else:  # merge
                links = [(child_id(bid, o), 0, blk.source_ranks[o]) for o in range(8)]
            for abid, idx, src in links:
                comm.send(r, src, "link", (abid, idx, tgt),
                          nbytes=BYTES_BLOCK_ID + BYTES_RANK + 1)

    inbox = comm.exchange()
    forwards: list[tuple[int, int, tuple[int, int, int]]] = []
    for dst, msgs in inbox.items():
        for tag, payload in msgs:
            if tag == "move":
                proxy.insert(payload)
            elif tag == "link":
                abid, idx, tgt = payload
                actual.local_blocks(dst)[abid].target_ranks[idx] = tgt
    # apply neighbor updates (after inserts so moved-in blocks are updatable)
    pending: list[tuple[int, tuple[int, int, int]]] = []
    for dst, msgs in inbox.items():
        for tag, payload in msgs:
            if tag == "nbupd":
                pending.append((dst, payload))
    for r in range(R):
        for upd in local_updates[r]:
            pending.append((r, upd))
    for dst, (nb, bid, tgt) in pending:
        blk = proxy.local_blocks(dst).get(nb)
        if blk is not None:
            blk.neighbors[bid] = tgt
        elif nb in move_table[dst]:  # neighbor moved away this round: forward
            forwards.append((dst, move_table[dst][nb], (nb, bid, tgt)))
        else:
            raise AssertionError(f"nbupd for unknown block {nb:#x} at rank {dst}")
    for src, dst, upd in forwards:
        comm.send(src, dst, "nbupd", upd, nbytes=2 * BYTES_BLOCK_ID + BYTES_RANK)
    inbox = comm.exchange()
    for dst, msgs in inbox.items():
        for _tag, (nb, bid, tgt) in msgs:
            proxy.local_blocks(dst)[nb].neighbors[bid] = tgt
    return moved
