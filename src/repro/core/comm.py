"""Message-passing fabric for simulated distributed ranks, with accounting.

Every algorithm in :mod:`repro.core` is written against this interface: ranks
may only read their *own* state plus messages delivered by the fabric. This
keeps the implementation faithful to the paper's fully distributed algorithms
while allowing thousands of simulated ranks in one process.

The fabric counts, per rank and in total:

* point-to-point messages and bytes,
* collective participations and the bytes each rank must *hold* as a result
  (the paper's Table 1 quantity: allgather makes every rank hold Θ(N) bytes,
  allreduce only O(1)),
* communication rounds (supersteps).

These counters are the measured quantities behind EXPERIMENTS.md's
reproduction of the paper's scalability argument (§2.4.1 vs §2.4.2).

On a real machine this layer maps 1:1 onto MPI (send/recv, MPI_Allreduce,
MPI_Allgatherv) or, on a TPU pod, onto `jax.lax` collectives — see
DESIGN.md §3 for the mapping.
"""

from __future__ import annotations

from collections import defaultdict
from dataclasses import dataclass, field
from typing import Any, Callable, Iterable

from ..telemetry import BYTES_BUCKETS, get_tracer

__all__ = ["CommStats", "Comm", "DeviceComm"]

_TR = get_tracer()

# Byte-size conventions for meta data (paper §2.4: "a few bytes of data").
BYTES_BLOCK_ID = 8          # block identifier (paper: 4-8 bytes per block)
BYTES_RANK = 4              # a process rank
BYTES_WEIGHT = 4            # a block weight (paper: 1-4 bytes)
BYTES_LEVEL = 1             # a block level / target-level
BYTES_FLOAT = 8
BYTES_COUNT = 4


@dataclass
class CommStats:
    nranks: int = 0
    rounds: int = 0
    exchange_rounds: int = 0  # p2p supersteps only (no collective latency)
    p2p_messages: int = 0
    p2p_bytes: int = 0
    allreduce_calls: int = 0
    allgather_calls: int = 0
    # bytes a single rank must hold/receive as a result of collectives:
    collective_bytes_per_rank: int = 0
    max_inbox_bytes_per_round: int = 0
    # per-rank p2p bytes sent (for peak/imbalance analysis)
    sent_bytes_by_rank: dict[int, int] = field(default_factory=lambda: defaultdict(int))

    def reset(self) -> None:
        self.rounds = 0
        self.exchange_rounds = 0
        self.p2p_messages = 0
        self.p2p_bytes = 0
        self.allreduce_calls = 0
        self.allgather_calls = 0
        self.collective_bytes_per_rank = 0
        self.max_inbox_bytes_per_round = 0
        self.sent_bytes_by_rank = defaultdict(int)

    @property
    def max_sent_bytes_per_rank(self) -> int:
        return max(self.sent_bytes_by_rank.values(), default=0)

    def summary(self) -> dict[str, float]:
        return {
            "nranks": self.nranks,
            "rounds": self.rounds,
            "exchange_rounds": self.exchange_rounds,
            "p2p_messages": self.p2p_messages,
            "p2p_bytes": self.p2p_bytes,
            "p2p_bytes_per_rank_avg": self.p2p_bytes / max(1, self.nranks),
            "p2p_bytes_per_rank_max": self.max_sent_bytes_per_rank,
            "allreduce_calls": self.allreduce_calls,
            "allgather_calls": self.allgather_calls,
            "collective_bytes_per_rank": self.collective_bytes_per_rank,
        }


class Comm:
    """Superstep message fabric for ``nranks`` simulated ranks."""

    def __init__(self, nranks: int):
        self.nranks = nranks
        self.stats = CommStats(nranks=nranks)
        self._outbox: dict[int, list[tuple[str, Any, int]]] = defaultdict(list)

    # -- point-to-point -------------------------------------------------------
    def send(self, src: int, dst: int, tag: str, payload: Any, nbytes: int) -> None:
        """Queue a message; delivered at the next :meth:`exchange` round."""
        assert 0 <= dst < self.nranks, (src, dst)
        self._outbox[dst].append((tag, payload, nbytes))
        self.stats.p2p_messages += 1
        self.stats.p2p_bytes += nbytes
        self.stats.sent_bytes_by_rank[src] += nbytes
        if _TR.enabled:
            _TR.metrics.counter("comm.p2p_bytes").inc(nbytes, src=src, dst=dst)
            _TR.metrics.counter("comm.p2p_messages").inc(src=src, dst=dst)
            _TR.metrics.histogram(
                "comm.p2p_message_bytes", buckets=BYTES_BUCKETS
            ).observe(nbytes)

    def exchange(self) -> dict[int, list[tuple[str, Any]]]:
        """Deliver all queued messages; one communication round (superstep)."""
        self.stats.rounds += 1
        self.stats.exchange_rounds += 1
        inbox: dict[int, list[tuple[str, Any]]] = defaultdict(list)
        max_inbox = 0
        for dst, msgs in self._outbox.items():
            inbox[dst] = [(tag, payload) for tag, payload, _ in msgs]
            max_inbox = max(max_inbox, sum(n for _, _, n in msgs))
        self.stats.max_inbox_bytes_per_round = max(
            self.stats.max_inbox_bytes_per_round, max_inbox
        )
        self._outbox = defaultdict(list)
        return inbox

    # -- collectives ------------------------------------------------------------
    def allreduce(self, per_rank_values: Iterable[Any], op: Callable[[Any, Any], Any], nbytes: int = 8) -> Any:
        """Global reduction; every rank receives the reduced value.

        Cost model: O(1) result bytes per rank, log(N) latency — the paper's
        two optional global reductions (§2.2, §2.4.2) use this.
        """
        self.stats.allreduce_calls += 1
        self.stats.rounds += max(1, (self.nranks - 1).bit_length())
        self.stats.collective_bytes_per_rank += nbytes
        if _TR.enabled:
            _TR.metrics.counter("comm.collectives").inc(kind="allreduce")
        it = iter(per_rank_values)
        acc = next(it)
        for v in it:
            acc = op(acc, v)
        return acc

    def allgather(self, per_rank_values: list[Any], nbytes_each: int) -> list[Any]:
        """Global gather; every rank receives every rank's contribution.

        Cost model: Θ(N)·nbytes_each held bytes per rank — this is the
        SFC balancer's scalability bottleneck measured in §5.1.2/Table 1.
        """
        self.stats.allgather_calls += 1
        self.stats.rounds += max(1, (self.nranks - 1).bit_length())
        self.stats.collective_bytes_per_rank += nbytes_each * self.nranks
        if _TR.enabled:
            _TR.metrics.counter("comm.collectives").inc(kind="allgather")
        return list(per_rank_values)

    def barrier(self) -> None:
        self.stats.rounds += 1


class DeviceComm(Comm):
    """Accounting fabric for the real device data plane (`device_sharded`).

    When ranks are XLA devices under ``shard_map``, halo payloads move as
    ``jax.lax.ppermute`` collectives *inside* the compiled program — the
    fabric never touches the bytes. This subclass keeps the control plane
    (AMR, balancing, migration) on the simulated :class:`Comm` superstep
    path unchanged, and adds :meth:`ppermute` so the stepping engine can
    attribute the in-program traffic into the same :class:`CommStats` and
    telemetry counters the Table-1 tests and trace reports read. ppermute is
    a *partial permutation* — pure point-to-point routing with no fan-in —
    so its bytes are accounted as p2p, never as collective held-bytes.

    ``pad_bytes`` tracks the wire overhead of equal-shape round payloads
    (shorter messages zero-padded to the round maximum); it is reported
    separately and deliberately kept out of ``p2p_bytes`` so the logical
    traffic stays byte-identical to the host-sharded plan.
    """

    def __init__(self, nranks: int):
        super().__init__(nranks)
        self.ppermute_rounds = 0
        self.ppermute_pad_bytes = 0

    def ppermute(
        self,
        messages: Iterable[Any],
        *,
        rounds: int = 1,
        pad_bytes: int = 0,
    ) -> None:
        """Account one substep's worth of in-program halo permutes.

        ``messages`` are :class:`~repro.lbm.halo.CompiledRankMessage`-likes
        (``src_rank``/``dst_rank``/``nbytes``); ``rounds`` is the number of
        ``ppermute`` calls the schedule needed (one per partial permutation).
        """
        inbox: dict[int, int] = defaultdict(int)
        for m in messages:
            self.stats.p2p_messages += 1
            self.stats.p2p_bytes += m.nbytes
            self.stats.sent_bytes_by_rank[m.src_rank] += m.nbytes
            inbox[m.dst_rank] += m.nbytes
            if _TR.enabled:
                _TR.metrics.counter("comm.p2p_bytes").inc(
                    m.nbytes, src=m.src_rank, dst=m.dst_rank
                )
                _TR.metrics.counter("comm.p2p_messages").inc(
                    src=m.src_rank, dst=m.dst_rank
                )
                _TR.metrics.histogram(
                    "comm.p2p_message_bytes", buckets=BYTES_BUCKETS
                ).observe(m.nbytes)
        self.stats.rounds += 1
        self.stats.exchange_rounds += 1
        self.stats.max_inbox_bytes_per_round = max(
            self.stats.max_inbox_bytes_per_round, max(inbox.values(), default=0)
        )
        self.ppermute_rounds += rounds
        self.ppermute_pad_bytes += pad_bytes
        if _TR.enabled:
            _TR.metrics.counter("comm.ppermute_rounds").inc(rounds)
            if pad_bytes:
                _TR.metrics.counter("comm.ppermute_pad_bytes").inc(pad_bytes)
