"""Checkpoint/restart built on the migration serializers (paper §4.1).

A checkpoint is (a) the *topology file* — the current distributed block
partitioning (IDs, levels, owners, weights, adjacency) — plus (b) one payload
file per rank containing the move-serialized block data. On a real machine
(b) is written with parallel MPI I/O / per-host files; here each simulated
rank writes its own file, which preserves the structure exactly.

Restart may use a *different* rank count: the topology is reloaded, blocks
are redistributed along the Morton curve (the standard initial partition),
and the payloads are deserialized on their new owners — "loading the
previously created snapshot" followed by the data structure initialization
of [57]. A subsequent AMR cycle rebalances if required.

The two halves of that protocol are exposed separately as
:func:`snapshot_payloads` (registry-codec encode of every block) and
:func:`rebuild_forest` (Morton redistribution + decode onto the new owners),
so in-memory consumers — the elastic rank-resize in
:mod:`repro.serving.elastic` — can run the identical snapshot/restore path
without touching disk.
"""

from __future__ import annotations

import json
import pickle
from pathlib import Path
from typing import Any

from .blockid import ForestGeometry
from .forest import Block, BlockForest, build_adjacency
from .migration import BlockDataRegistry

__all__ = [
    "save_checkpoint",
    "load_checkpoint",
    "snapshot_payloads",
    "rebuild_forest",
]


def snapshot_payloads(
    forest: BlockForest, registry: BlockDataRegistry, *, copy: bool = False
) -> dict[int, dict[str, Any]]:
    """Move-serialize every block's data through the registry codec.

    Returns bid -> payload for the whole forest — the in-memory equivalent of
    the per-rank checkpoint payload files. With ``copy=False`` payloads alias
    the live arrays (safe when immediately persisted or decoded, as both the
    on-disk checkpoint and the elastic resize do); pass ``copy=True`` to keep
    a snapshot that survives later in-place mutation.
    """
    return {
        bid: registry.encode_block(blk, copy=copy)
        for r in range(forest.nranks)
        for bid, blk in forest.local_blocks(r).items()
    }


def rebuild_forest(
    geom: ForestGeometry,
    entries: list[dict],
    payloads: dict[int, dict[str, Any]],
    registry: BlockDataRegistry,
    nranks: int,
) -> BlockForest:
    """Reassemble a forest from topology entries + codec payloads onto
    ``nranks`` ranks: blocks are redistributed in equal contiguous chunks
    along the Morton curve (the standard initial partition) and each payload
    is deserialized on its new owner. ``entries`` holds one
    ``{"bid", "level", "weight"}`` dict per block (the topology-file rows;
    any previous ``owner`` is irrelevant — ownership is recomputed)."""
    entries = sorted(entries, key=lambda e: geom.morton_key(e["bid"]))
    forest = BlockForest(geom, nranks)
    blocks = []
    n = len(entries)
    for i, e in enumerate(entries):
        owner = min(nranks - 1, i * nranks // max(1, n))
        blk = Block(bid=e["bid"], level=e["level"], owner=owner, weight=e["weight"])
        blk.data = registry.decode_block(payloads[e["bid"]], blk)
        blocks.append(blk)
    build_adjacency(geom, blocks)
    for b in blocks:
        forest.insert(b)
    return forest


def save_checkpoint(
    forest: BlockForest, registry: BlockDataRegistry, path: str | Path
) -> None:
    path = Path(path)
    path.mkdir(parents=True, exist_ok=True)
    topo = {
        "geom": {"root_grid": list(forest.geom.root_grid), "max_level": forest.geom.max_level},
        "nranks": forest.nranks,
        "blocks": [
            {"bid": b.bid, "level": b.level, "owner": b.owner, "weight": b.weight}
            for b in forest.all_blocks()
        ],
    }
    (path / "topology.json").write_text(json.dumps(topo))
    for r in range(forest.nranks):
        payload = {
            # no owned copies needed: pickle.dump snapshots the arrays itself
            bid: registry.encode_block(blk, copy=False)
            for bid, blk in forest.local_blocks(r).items()
        }
        with open(path / f"rank_{r:06d}.pkl", "wb") as f:
            pickle.dump(payload, f)


def load_checkpoint(
    path: str | Path,
    registry: BlockDataRegistry,
    nranks: int | None = None,
) -> BlockForest:
    """Restore a forest, optionally onto a different number of ranks."""
    path = Path(path)
    topo = json.loads((path / "topology.json").read_text())
    geom = ForestGeometry(
        root_grid=tuple(topo["geom"]["root_grid"]), max_level=topo["geom"]["max_level"]
    )
    old_nranks = topo["nranks"]
    nranks = nranks or old_nranks
    # gather payloads (indexed by bid — rank layout on disk is irrelevant)
    payloads: dict[int, dict] = {}
    for r in range(old_nranks):
        with open(path / f"rank_{r:06d}.pkl", "rb") as f:
            payloads.update(pickle.load(f))
    return rebuild_forest(geom, topo["blocks"], payloads, registry, nranks)
