"""Extreme-scale block-structured AMR (Schornbaum & Rüde 2017) — core library.

The paper's contribution as a composable module: distributed forest-of-octrees
domain partitioning, the four-step AMR pipeline with its lightweight proxy
data structure, SFC- and diffusion-based dynamic load balancing, data
migration with user-registered serialization callbacks, checkpoint/restart,
and buddy-based resilience.
"""

from .blockid import ForestGeometry, hilbert_index_3d
from .comm import Comm, CommStats, DeviceComm
from .forest import Block, BlockForest, make_forest_from_levels, make_uniform_forest
from .refine import mark_and_balance_targets
from .proxy import build_proxy, migrate_proxy_blocks
from .migration import BlockDataItem, BlockDataRegistry, migrate_data
from .fields import DeviceResidency, FieldRegistry, FieldSpec, LevelArena, RankArenas
from .pipeline import AMRPipeline, CycleReport, recompute_weights
from .balancing import DiffusionBalancer, SFCBalancer

__all__ = [
    "ForestGeometry",
    "hilbert_index_3d",
    "Comm",
    "CommStats",
    "DeviceComm",
    "Block",
    "BlockForest",
    "make_forest_from_levels",
    "make_uniform_forest",
    "mark_and_balance_targets",
    "build_proxy",
    "migrate_proxy_blocks",
    "BlockDataItem",
    "BlockDataRegistry",
    "FieldSpec",
    "FieldRegistry",
    "LevelArena",
    "RankArenas",
    "DeviceResidency",
    "migrate_data",
    "AMRPipeline",
    "CycleReport",
    "recompute_weights",
    "DiffusionBalancer",
    "SFCBalancer",
]
