"""Octree block identification scheme (paper §2, cf. p4est [12] / waLBerla).

A *forest of octrees* partitions the domain: a Cartesian root grid of
``(rx, ry, rz)`` root blocks, each root the root of an octree. Every block is
identified by a single integer ID built from a marker bit, the root index,
and 3 bits per level (the octant path):

    root id            = (1 << root_bits) | root_index
    child(id, octant)  = (id << 3) | octant          octant = x | y<<1 | z<<2
    parent(id)         = id >> 3
    level(id)          = (bit_length(id) - 1 - root_bits) // 3

The tree structure is therefore *implicit* in the IDs — it is never stored
explicitly (paper §2: "the resulting tree structure is not stored explicitly,
but it is implicitly defined by a unique identification scheme").

Sorting blocks by the :func:`morton_key` yields a depth-first Morton (z-curve)
ordering; :func:`hilbert_key` yields Hilbert order via Skilling's transpose
algorithm.  Both keys left-align the path bits at ``max_level`` so blocks of
different levels interleave correctly along the curve.
"""

from __future__ import annotations

import itertools
from dataclasses import dataclass, field
from functools import lru_cache
from typing import Iterator

__all__ = [
    "ForestGeometry",
    "octant_of",
    "child_id",
    "parent_id",
    "sibling_ids",
    "morton_key",
    "hilbert_key",
    "hilbert_index_3d",
]


def _bits_for(n: int) -> int:
    """Number of bits needed to represent indices 0..n-1 (at least 1)."""
    return max(1, (max(n - 1, 0)).bit_length())


@dataclass(frozen=True)
class ForestGeometry:
    """Static geometry of the forest: root grid plus octree depth budget.

    All coordinate math is done in *fine units*: the unit grid obtained by
    (conceptually) refining every root block ``max_level`` times. A block at
    level ``l`` covers a cube of side ``2**(max_level - l)`` fine units.
    """

    root_grid: tuple[int, int, int]
    max_level: int = 14  # depth budget; IDs stay < 2**64 for root_bits <= 20

    @property
    def root_bits(self) -> int:
        rx, ry, rz = self.root_grid
        return _bits_for(rx * ry * rz)

    @property
    def num_roots(self) -> int:
        rx, ry, rz = self.root_grid
        return rx * ry * rz

    # -- root index <-> root coordinates ------------------------------------
    def root_index(self, cx: int, cy: int, cz: int) -> int:
        rx, ry, _ = self.root_grid
        return cx + rx * (cy + ry * cz)

    def root_coords(self, root_idx: int) -> tuple[int, int, int]:
        rx, ry, _ = self.root_grid
        return root_idx % rx, (root_idx // rx) % ry, root_idx // (rx * ry)

    # -- id decomposition ----------------------------------------------------
    def root_id(self, root_idx: int) -> int:
        return (1 << self.root_bits) | root_idx

    def level_of(self, bid: int) -> int:
        n = bid.bit_length() - 1 - self.root_bits
        assert n >= 0 and n % 3 == 0, f"malformed block id {bid:#x}"
        return n // 3

    def root_of(self, bid: int) -> int:
        return (bid >> (3 * self.level_of(bid))) & ((1 << self.root_bits) - 1)

    def path_of(self, bid: int) -> tuple[int, ...]:
        """Octant path from root (level 1 first) to the block's own level."""
        level = self.level_of(bid)
        return tuple((bid >> (3 * (level - 1 - k))) & 7 for k in range(level))

    # -- geometry ------------------------------------------------------------
    def block_coords(self, bid: int) -> tuple[int, int, int, int]:
        """(level, x, y, z) with x,y,z the block coords *within its root*
        at the block's level (each in [0, 2**level))."""
        level = self.level_of(bid)
        x = y = z = 0
        for o in self.path_of(bid):
            x = (x << 1) | (o & 1)
            y = (y << 1) | ((o >> 1) & 1)
            z = (z << 1) | ((o >> 2) & 1)
        return level, x, y, z

    def id_from_coords(self, level: int, x: int, y: int, z: int, root_idx: int) -> int:
        bid = self.root_id(root_idx)
        for k in range(level - 1, -1, -1):
            o = ((x >> k) & 1) | (((y >> k) & 1) << 1) | (((z >> k) & 1) << 2)
            bid = (bid << 3) | o
        return bid

    def aabb(self, bid: int) -> tuple[int, int, int, int, int, int]:
        """(x0, y0, z0, x1, y1, z1) of the block in fine units (half-open)."""
        level, x, y, z = self.block_coords(bid)
        rx, ry, rz = self.root_coords(self.root_of(bid))
        side = 1 << (self.max_level - level)
        full = 1 << self.max_level
        x0 = rx * full + x * side
        y0 = ry * full + y * side
        z0 = rz * full + z * side
        return x0, y0, z0, x0 + side, y0 + side, z0 + side

    def adjacent(self, a: int, b: int) -> bool:
        """Face/edge/corner adjacency of two non-overlapping blocks."""
        ax0, ay0, az0, ax1, ay1, az1 = self.aabb(a)
        bx0, by0, bz0, bx1, by1, bz1 = self.aabb(b)
        # closed boxes must intersect in every dimension
        return (
            ax0 <= bx1 and bx0 <= ax1
            and ay0 <= by1 and by0 <= ay1
            and az0 <= bz1 and bz0 <= az1
            and a != b
        )

    def adjacency_kind(self, a: int, b: int) -> str:
        """'face' | 'edge' | 'corner' | 'overlap' | 'none' between two blocks."""
        ax0, ay0, az0, ax1, ay1, az1 = self.aabb(a)
        bx0, by0, bz0, bx1, by1, bz1 = self.aabb(b)
        overlaps = 0
        touches = 0
        for lo_a, hi_a, lo_b, hi_b in (
            (ax0, ax1, bx0, bx1),
            (ay0, ay1, by0, by1),
            (az0, az1, bz0, bz1),
        ):
            if lo_a < hi_b and lo_b < hi_a:
                overlaps += 1
            elif hi_a == lo_b or hi_b == lo_a:
                touches += 1
            else:
                return "none"
        if overlaps == 3:
            return "overlap"
        return {2: "face", 1: "edge", 0: "corner"}[overlaps]

    def in_domain(self, level: int, x: int, y: int, z: int, root_cx: int, root_cy: int, root_cz: int) -> bool:
        rx, ry, rz = self.root_grid
        return 0 <= root_cx < rx and 0 <= root_cy < ry and 0 <= root_cz < rz

    def neighbor_region_ids(self, bid: int, dx: int, dy: int, dz: int) -> int | None:
        """ID of the same-level neighbor block in direction (dx,dy,dz) (each in
        {-1,0,+1}), or None if outside the domain. Crosses root boundaries."""
        level, x, y, z = self.block_coords(bid)
        rcx, rcy, rcz = self.root_coords(self.root_of(bid))
        n = 1 << level
        nx, ny, nz = x + dx, y + dy, z + dz
        if nx < 0:
            rcx -= 1
            nx += n
        elif nx >= n:
            rcx += 1
            nx -= n
        if ny < 0:
            rcy -= 1
            ny += n
        elif ny >= n:
            rcy += 1
            ny -= n
        if nz < 0:
            rcz -= 1
            nz += n
        elif nz >= n:
            rcz += 1
            nz -= n
        rx, ry, rz = self.root_grid
        if not (0 <= rcx < rx and 0 <= rcy < ry and 0 <= rcz < rz):
            return None
        return self.id_from_coords(level, nx, ny, nz, self.root_index(rcx, rcy, rcz))

    # -- SFC keys --------------------------------------------------------------
    def morton_key(self, bid: int) -> tuple[int, int, int]:
        """Depth-first Morton key: (root, left-aligned path, level)."""
        level = self.level_of(bid)
        path = bid & ((1 << (3 * level)) - 1)
        return (self.root_of(bid), path << (3 * (self.max_level - level)), level)

    def hilbert_key(self, bid: int) -> tuple[int, int, int]:
        """Depth-first Hilbert key (per-root curve, roots in index order)."""
        level, x, y, z = self.block_coords(bid)
        h = hilbert_index_3d(max(level, 1), x, y, z) if level > 0 else 0
        return (self.root_of(bid), h << (3 * (self.max_level - level)), level)


# -- plain-int helpers (geometry-free) ------------------------------------------


def octant_of(bid: int) -> int:
    """Octant of a (non-root) block within its parent."""
    return bid & 7


def child_id(bid: int, octant: int) -> int:
    return (bid << 3) | octant


def parent_id(bid: int) -> int:
    return bid >> 3


def sibling_ids(bid: int) -> tuple[int, ...]:
    """All 8 ids sharing this block's parent (includes bid itself)."""
    base = (bid >> 3) << 3
    return tuple(base | o for o in range(8))


def children_ids(bid: int) -> tuple[int, ...]:
    return tuple((bid << 3) | o for o in range(8))


# -- Hilbert curve (Skilling's transpose algorithm, 3D) --------------------------


def hilbert_index_3d(nbits: int, x: int, y: int, z: int) -> int:
    """Hilbert index of cell (x, y, z) on a 2**nbits cube grid.

    Implements J. Skilling, "Programming the Hilbert curve" (AIP 2004):
    AxesToTranspose followed by bit interleaving. O(nbits), no lookup tables
    (cf. paper §2.4.1 [14] — tables exist; the arithmetic form is equivalent).
    """
    X = [x, y, z]
    n = 3
    m = 1 << (nbits - 1)
    # Inverse undo excess work
    q = m
    while q > 1:
        p = q - 1
        for i in range(n):
            if X[i] & q:
                X[0] ^= p
            else:
                t = (X[0] ^ X[i]) & p
                X[0] ^= t
                X[i] ^= t
        q >>= 1
    # Gray encode
    for i in range(1, n):
        X[i] ^= X[i - 1]
    t = 0
    q = m
    while q > 1:
        if X[n - 1] & q:
            t ^= q - 1
        q >>= 1
    for i in range(n):
        X[i] ^= t
    # Interleave: bit (nbits-1-b) of X[i] becomes bit (3*(nbits-1-b) + (2-i))
    h = 0
    for b in range(nbits - 1, -1, -1):
        for i in range(n):
            h = (h << 1) | ((X[i] >> b) & 1)
    return h


ALL_DIRECTIONS: tuple[tuple[int, int, int], ...] = tuple(
    d for d in itertools.product((-1, 0, 1), repeat=3) if d != (0, 0, 0)
)
