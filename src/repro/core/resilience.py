"""Non-invasive fault tolerance via redundant in-memory snapshots (paper §4.2).

During snapshot creation every rank X serializes its own blocks and sends a
copy to its *buddy* rank Y = (X + N/2) mod N — pairwise point-to-point
communication only, no disk I/O. The snapshot occupies half the memory
(paper: "leaving only 1/3 of the available memory to the actual simulation"
when counting both own-state and buddy-state copies).

On failure of a process set F, the survivors restore their own saved state;
for every failed rank its buddy additionally restores the failed rank's
blocks. Restoration is immediately followed by one AMR cycle (force-
rebalance) that re-balances the simulation on the surviving ranks. Up to
half of all ranks can fail simultaneously, as long as no buddy pair fails
together — exactly the paper's best-case bound.

The underlying MPI would be a ULFM-style fault-tolerant MPI [5]; the fabric
here simulates the failure notification by constructing the shrunken world.
"""

from __future__ import annotations

import pickle
from dataclasses import dataclass, field

from .comm import Comm
from .forest import Block, BlockForest
from .migration import BlockDataRegistry, payload_nbytes
from .pipeline import AMRPipeline

__all__ = ["ResilienceManager", "BuddySnapshot"]


@dataclass
class BuddySnapshot:
    """Per-rank snapshot storage: own state + buddy's state (both serialized)."""

    own: dict[int, tuple[dict, dict]] = field(default_factory=dict)
    buddy_rank: int = -1
    buddy: dict[int, tuple[dict, dict]] = field(default_factory=dict)

    def nbytes(self) -> int:
        return payload_nbytes(self.own) + payload_nbytes(self.buddy)


class ResilienceManager:
    def __init__(self, registry: BlockDataRegistry):
        self.registry = registry
        self.snapshots: list[BuddySnapshot] = []

    # -- snapshot creation ------------------------------------------------------
    def snapshot(self, forest: BlockForest, comm: Comm) -> None:
        N = forest.nranks
        self.snapshots = [BuddySnapshot() for _ in range(N)]
        for r in range(N):
            state: dict[int, tuple[dict, dict]] = {}
            for bid, blk in forest.local_blocks(r).items():
                meta = {
                    "bid": blk.bid,
                    "level": blk.level,
                    "weight": blk.weight,
                    "neighbors": dict(blk.neighbors),
                }
                state[bid] = (meta, self.registry.encode_block(blk))
            self.snapshots[r].own = state
            buddy = (r + N // 2) % N
            self.snapshots[r].buddy_rank = buddy
            # ship a copy to the buddy (pairwise point-to-point)
            comm.send(r, buddy, "snap", (r, state), nbytes=payload_nbytes(state))
        inbox = comm.exchange()
        for dst, msgs in inbox.items():
            for _tag, (src, state) in msgs:
                # buddy stores the *source's* state redundantly
                self.snapshots[dst].buddy = state
                self.snapshots[dst].buddy_of = src  # type: ignore[attr-defined]

    # -- failure + restore --------------------------------------------------------
    def fail_and_restore(
        self,
        forest: BlockForest,
        failed: set[int],
        pipeline: AMRPipeline,
    ) -> tuple[BlockForest, Comm]:
        """Simulate failure of ``failed`` ranks and restore on the survivors.

        Returns the restored, re-balanced forest on N-|F| ranks and the new
        (shrunken) communicator.
        """
        N = forest.nranks
        assert self.snapshots, "no snapshot taken"
        survivors = [r for r in range(N) if r not in failed]
        assert survivors, "all ranks failed"
        for f in failed:
            buddy = (f + N // 2) % N
            assert buddy not in failed, (
                f"buddy pair ({f},{buddy}) failed together — snapshot lost"
            )
        new_rank_of = {old: new for new, old in enumerate(survivors)}
        new_n = len(survivors)
        restored = BlockForest(forest.geom, new_n)

        def rebuild(state: dict, owner_new: int) -> None:
            for bid, (meta, payload) in state.items():
                blk = Block(
                    bid=meta["bid"],
                    level=meta["level"],
                    owner=owner_new,
                    weight=meta["weight"],
                )
                # copy: the snapshot must survive the restored run mutating
                # its blocks in place (a second restore must stay valid)
                blk.data = self.registry.decode_block(payload, blk, copy=True)
                restored.insert(blk)

        for old in survivors:
            rebuild(self.snapshots[old].own, new_rank_of[old])
        for f in failed:
            buddy = (f + N // 2) % N
            rebuild(self.snapshots[buddy].buddy, new_rank_of[buddy])

        # neighbor owner maps must be remapped to the shrunken world; owners
        # of restored failed-rank blocks changed to their buddy. Rebuild the
        # owner info from the restored forest's own records (each block knows
        # its neighbors' ids from the snapshot meta; owners are re-derived).
        owner_of = {b.bid: b.owner for b in restored.all_blocks()}
        for b in restored.all_blocks():
            meta_neighbors = None
            # find neighbor ids from whichever snapshot carried this block
            for snap in self.snapshots:
                if b.bid in snap.own:
                    meta_neighbors = snap.own[b.bid][0]["neighbors"]
                    break
            assert meta_neighbors is not None
            b.neighbors = {nb: owner_of[nb] for nb in meta_neighbors}

        # "immediately followed by the execution of one AMR cycle that ensures
        #  load balance of the simulation on fewer processes"
        comm = Comm(new_n)
        restored, _report = pipeline.run_cycle(
            restored, comm, mark_fn=None, force_rebalance=True
        )
        return restored, comm
