"""Distributed block-level refinement & coarsening with 2:1 balance (paper §2.2).

Two-step phase:

1. An application callback assigns a *wish* target level to every local block
   (perfectly distributed, no communication).
2. The framework enforces 2:1 balance with neighbor-only exchanges:
   - all refinement wishes are accepted;
   - additional blocks are iteratively *forced to split*;
   - coarsening wishes are accepted iff all 8 siblings wish to merge and the
     merged block would not violate 2:1 against the neighbors' target levels
     (iterative, so accepted merges can enable further merges — Fig. 2 (3,4)).

Sibling groups may span ranks: all 8 siblings are mutually corner-adjacent,
so the vote/decision traffic is next-neighbor only. The iteration count is
bounded by the number of levels in use (paper §2.2); two global reductions of
one boolean implement the early-exit optimization.

The function returns the per-rank ghost view of neighbor target levels, which
the proxy construction (§2.3) reuses.
"""

from __future__ import annotations

from collections import defaultdict
from typing import Callable, Mapping

from .blockid import octant_of, parent_id, sibling_ids
from .comm import BYTES_BLOCK_ID, BYTES_LEVEL, BYTES_RANK, Comm
from .forest import Block, BlockForest

__all__ = ["mark_and_balance_targets", "MarkCallback"]

# callback: (rank, local blocks) -> {bid: wished target level}
MarkCallback = Callable[[int, Mapping[int, Block]], Mapping[int, int] | None]


def _exchange_targets(forest: BlockForest, comm: Comm) -> list[dict[int, int]]:
    """One neighbor-exchange round of (bid, target_level) for boundary blocks.

    Returns per-rank ghost maps {neighbor bid -> its current target level}.
    """
    nbytes_item = BYTES_BLOCK_ID + BYTES_LEVEL
    for r in range(forest.nranks):
        per_dst: dict[int, list[tuple[int, int]]] = defaultdict(list)
        for bid, blk in forest.local_blocks(r).items():
            for owner in set(blk.neighbors.values()):
                if owner != r:
                    per_dst[owner].append((bid, blk.target_level))
        for dst, items in per_dst.items():
            comm.send(r, dst, "tgt", items, nbytes=len(items) * nbytes_item)
    inbox = comm.exchange()
    ghost: list[dict[int, int]] = [dict() for _ in range(forest.nranks)]
    for dst, msgs in inbox.items():
        for _tag, items in msgs:
            for bid, t in items:
                ghost[dst][bid] = t
    return ghost


def mark_and_balance_targets(
    forest: BlockForest,
    comm: Comm,
    mark_fn: MarkCallback | None,
) -> tuple[bool, list[dict[int, int]]]:
    """Run the full §2.2 phase. Sets ``blk.target_level`` on every block.

    Returns ``(levels_changed, ghost_targets)`` where ``ghost_targets[r]``
    maps every remote neighbor bid of rank ``r`` to its final target level.
    """
    R = forest.nranks

    # -- step 1: application-dependent callback (distributed, no comm) -------
    wish: list[dict[int, int]] = [dict() for _ in range(R)]
    for r in range(R):
        local = forest.local_blocks(r)
        answers = dict(mark_fn(r, local)) if mark_fn is not None else {}
        for bid, blk in local.items():
            w = int(answers.get(bid, blk.level))
            wish[r][bid] = max(blk.level - 1, min(blk.level + 1, w))
            # phase A initialization: accept splits, treat coarsen wishes as
            # "keep" until they are accepted by the merge protocol below.
            blk.target_level = blk.level + 1 if wish[r][bid] > blk.level else blk.level

    # -- early-exit reduction #1 (paper §2.2) ---------------------------------
    any_marked = comm.allreduce(
        (
            any(w != forest.local_blocks(r)[bid].level for bid, w in wish[r].items())
            for r in range(R)
        ),
        lambda a, b: a or b,
        nbytes=1,
    )
    if not any_marked:
        return False, _exchange_targets(forest, comm)

    # -- phase A: iterative forced splits to maintain 2:1 ---------------------
    ghost: list[dict[int, int]] = [dict() for _ in range(R)]
    while True:
        ghost = _exchange_targets(forest, comm)
        changed = False
        for r in range(R):
            g = ghost[r]
            local = forest.local_blocks(r)
            for bid, blk in local.items():
                nb_max = blk.target_level
                for nb in blk.neighbors:
                    t = g.get(nb)
                    if t is None:  # local neighbor
                        t = local[nb].target_level
                    if t > nb_max:
                        nb_max = t
                forced = nb_max - 1
                if forced > blk.target_level:
                    assert forced <= blk.level + 1, "2:1 precondition violated"
                    blk.target_level = forced
                    changed = True
        if not comm.allreduce([changed] * R, lambda a, b: a or b, nbytes=1):
            break

    # -- phase B: iterative coarsening acceptance ------------------------------
    # A block is a merge candidate while: it wishes to coarsen, was not forced
    # to split, and is not yet accepted (acceptance lowers target_level).
    while True:
        ghost = _exchange_targets(forest, comm)
        # round 1: votes to the designated sibling owner (min bid in group)
        for r in range(R):
            g = ghost[r]
            local = forest.local_blocks(r)
            for bid, blk in local.items():
                if wish[r][bid] >= blk.level or blk.target_level != blk.level:
                    continue
                sibs = sibling_ids(bid)
                if not all(s == bid or s in blk.neighbors for s in sibs):
                    continue  # some sibling area is refined -> group invalid
                external_ok = True
                for nb in blk.neighbors:
                    if nb in sibs:
                        continue
                    t = g.get(nb)
                    if t is None:
                        t = local[nb].target_level
                    if t > blk.level:  # merged block would be at level-1
                        external_ok = False
                        break
                designated = min(sibs)
                dst = r if designated == bid else blk.neighbors[designated]
                # the vote carries the voter's neighbor meta for §2.3 reuse
                comm.send(
                    r,
                    dst,
                    "vote",
                    (parent_id(bid), octant_of(bid), external_ok, r),
                    nbytes=BYTES_BLOCK_ID + 1 + 1 + BYTES_RANK,
                )
        inbox = comm.exchange()
        votes: dict[int, dict[int, list[tuple[int, bool, int]]]] = defaultdict(
            lambda: defaultdict(list)
        )
        for dst, msgs in inbox.items():
            for _tag, (pid, oct_, ok, src) in msgs:
                votes[dst][pid].append((oct_, ok, src))
        # round 2: decisions back to the sibling owners
        for dst, groups in votes.items():
            for pid, vs in groups.items():
                if len({o for o, _, _ in vs}) == 8 and all(ok for _, ok, _ in vs):
                    for oct_, _, src in vs:
                        comm.send(
                            dst, src, "accept", (pid, oct_), nbytes=BYTES_BLOCK_ID + 1
                        )
        inbox = comm.exchange()
        changed = False
        for dst, msgs in inbox.items():
            local = forest.local_blocks(dst)
            for _tag, (pid, oct_) in msgs:
                bid = (pid << 3) | oct_
                blk = local[bid]
                if blk.target_level == blk.level:
                    blk.target_level = blk.level - 1
                    changed = True
        if not comm.allreduce([changed] * R, lambda a, b: a or b, nbytes=1):
            break

    # -- early-exit reduction #2 (paper §2.2) ---------------------------------
    levels_changed = comm.allreduce(
        (
            any(b.target_level != b.level for b in forest.local_blocks(r).values())
            for r in range(R)
        ),
        lambda a, b: a or b,
        nbytes=1,
    )
    ghost = _exchange_targets(forest, comm)
    return bool(levels_changed), ghost
