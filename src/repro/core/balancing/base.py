from __future__ import annotations

from typing import Protocol

from ..comm import Comm
from ..forest import BlockForest

__all__ = ["Balancer", "max_level_in_use", "is_balanced_per_level"]


class Balancer(Protocol):
    def __call__(
        self, proxy: BlockForest, comm: Comm, iteration: int
    ) -> tuple[list[dict[int, int]], bool]:
        """Return (per-rank {bid: target rank}, run-another-iteration)."""
        ...


def max_level_in_use(proxy: BlockForest, comm: Comm) -> int:
    """Global max block level — one small allreduce."""
    per_rank = [
        max((b.level for b in proxy.local_blocks(r).values()), default=0)
        for r in range(proxy.nranks)
    ]
    return comm.allreduce(per_rank, max, nbytes=1)


def is_balanced_per_level(
    proxy: BlockForest, comm: Comm, levels: range, tolerance: float = 0.0
) -> bool:
    """Global check: every level's max per-rank weight is within the perfect-
    balance bound (ceil of the average for unit weights; (1+tol)·avg plus one
    block granularity otherwise). Costs one allreduce (paper §2.4.2: the
    second optional global reduction enabling early termination)."""
    R = proxy.nranks
    stats: list[list[tuple[float, float, float]]] = []
    for r in range(R):
        per_level = []
        for lvl in levels:
            ws = [b.weight for b in proxy.local_blocks(r).values() if b.level == lvl]
            per_level.append((sum(ws), max(ws, default=0.0), float(len(ws))))
        stats.append(per_level)

    def combine(a, b):
        return [
            (wa + wb, max(ma, mb), ca + cb)
            for (wa, ma, ca), (wb, mb, cb) in zip(a, b)
        ]

    totals = comm.allreduce(stats, combine, nbytes=8 * 3 * len(levels))
    for (total_w, max_blk_w, count), li in zip(totals, levels):
        if count == 0:
            continue
        avg = total_w / R
        # perfect balance bound: no rank above the unavoidable granularity
        bound = avg * (1.0 + tolerance) + max_blk_w * (1.0 - 1.0 / max(R, 1)) + 1e-9
        max_w = max(
            sum(b.weight for b in proxy.local_blocks(r).values() if b.level == li)
            for r in range(R)
        )
        if max_w > bound:
            return False
    return True
