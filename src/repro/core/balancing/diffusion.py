"""Fully distributed diffusion-based dynamic load balancing (paper §2.4.2).

Two nested iteration levels (paper Alg. 2):

* **flow iterations** — Cybenko's first-order diffusion scheme [18] on the
  distributed process graph with Boillat's edge weights [6]
  ``alpha_ij = 1 / (max(d_i, d_j) + 1)``, computable with next-neighbor
  communication only. They produce the desired load flow ``f_ij`` over every
  process-graph edge (no blocks move yet).
* **main iterations** — after the flow is known, the **push** (Alg. 3) or
  **pull** (Alg. 4) scheme matches whole blocks against the per-edge flows,
  the framework migrates the chosen proxy blocks, and the procedure repeats.
  Alternating push/pull is supported (the paper's "push/pull" configuration).

Per-level balancing (required by the LBM, §3.2) computes loads and flows per
level over the *same* process graph; the candidate blocks for migration are
restricted to the level being balanced.

Every step uses next-neighbor communication only; with a fixed number of
iterations, runtime and memory per rank are independent of the total number
of ranks. Two optional global reductions (total load; balanced-yet flag)
enable early termination — exactly the paper's two reductions.

Block-selection details follow the paper: only blocks *adjacent to the
receiving rank* are candidates ("can be moved to process j"), and among
multiple candidates the block with the weakest connection to its own rank
and the strongest connection to the receiver is preferred, where connection
strength weighs face > edge > corner contacts.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Iterable

from ..comm import BYTES_BLOCK_ID, BYTES_FLOAT, BYTES_RANK, BYTES_WEIGHT, Comm
from ..forest import Block, BlockForest
from .base import is_balanced_per_level, max_level_in_use

__all__ = ["DiffusionBalancer"]

_STRENGTH = {"face": 4.0, "edge": 2.0, "corner": 1.0}
_EPS = 1e-9


def _connection_strengths(
    geom, blk: Block, local_bids: set[int], marked: set[int]
) -> dict[int, float]:
    """Strength of blk's connection to each rank owning one of its neighbors
    (own rank keyed by -1; marked-for-migration blocks excluded from own)."""
    out: dict[int, float] = {}
    for nb, owner in blk.neighbors.items():
        s = _STRENGTH[geom.adjacency_kind(blk.bid, nb)]
        if nb in local_bids and nb not in marked:
            out[-1] = out.get(-1, 0.0) + s
        else:
            out[owner] = out.get(owner, 0.0) + s
    return out


@dataclass
class DiffusionBalancer:
    """Iterative local balancer: push / pull / alternating push-pull."""

    mode: str = "push"  # "push" | "pull" | "pushpull"
    flow_iterations: int = 15  # paper: 15 (push-only), 5 (alternating)
    max_main_iterations: int = 20
    per_level: bool = True
    use_global_reductions: bool = True  # the two optional reductions
    tolerance: float = 0.0
    # filled in by __call__ for introspection/benchmarks:
    last_balanced: bool = field(default=False, init=False)
    _last_progress: bool = field(default=True, init=False)
    # per-main-iteration flow snapshots (property tests pin their invariants):
    # raw Cybenko flows are exactly antisymmetric (f_ij = -f_ji, so every
    # edge's flow sums to zero globally); the adjusted flows bound how much
    # block weight the push/pull selection may move per edge direction.
    last_flows_raw: list[dict[int, list[float]]] = field(default_factory=list, init=False)
    last_flows: list[dict[int, list[float]]] = field(default_factory=list, init=False)

    # -- helpers -----------------------------------------------------------------
    def _neighbor_ranks(self, proxy: BlockForest, r: int) -> list[int]:
        return sorted(proxy.neighbor_ranks(r))

    def _loads(self, proxy: BlockForest, r: int, levels: range) -> list[float]:
        w = [0.0] * len(levels)
        for b in proxy.local_blocks(r).values():
            w[b.level] += b.weight
        return w

    # -- main entry ---------------------------------------------------------------
    def __call__(
        self, proxy: BlockForest, comm: Comm, iteration: int
    ) -> tuple[list[dict[int, int]], bool]:
        R = proxy.nranks
        geom = proxy.geom
        max_level = max_level_in_use(proxy, comm)
        levels = range(max_level + 1) if self.per_level else range(1)

        # -- process graph + degrees (next-neighbor exchange of d_i) ---------
        nbrs = [self._neighbor_ranks(proxy, r) for r in range(R)]
        deg = [len(n) for n in nbrs]
        for r in range(R):
            for j in nbrs[r]:
                comm.send(r, j, "deg", (r, deg[r]), nbytes=BYTES_RANK + BYTES_RANK)
        inbox = comm.exchange()
        deg_of: list[dict[int, int]] = [dict() for _ in range(R)]
        for dst, msgs in inbox.items():
            for _tag, (src, d) in msgs:
                deg_of[dst][src] = d

        # -- per-level process loads ------------------------------------------
        if self.per_level:
            w = [self._loads(proxy, r, levels) for r in range(R)]
        else:
            w = [[sum(b.weight for b in proxy.local_blocks(r).values())] for r in range(R)]
        w_cur = [list(x) for x in w]

        # -- flow iterations (Alg. 2 lines 9-17) -------------------------------
        flows: list[dict[int, list[float]]] = [
            {j: [0.0] * len(levels) for j in nbrs[r]} for r in range(R)
        ]
        alpha = [
            {j: 1.0 / (max(deg[r], deg_of[r][j]) + 1.0) for j in nbrs[r]}
            for r in range(R)
        ]
        w_nb0: list[dict[int, list[float]]] = [dict() for _ in range(R)]
        for it in range(self.flow_iterations):
            for r in range(R):
                for j in nbrs[r]:
                    # copy: a real message is a snapshot of the sender's state
                    # at send time — passing the live list would let later
                    # ranks observe mid-superstep updates (and break the
                    # f_ij = -f_ji antisymmetry of Cybenko's scheme)
                    comm.send(r, j, "w", (r, list(w_cur[r])),
                              nbytes=BYTES_RANK + BYTES_FLOAT * len(levels))
            inbox = comm.exchange()
            w_nb: list[dict[int, list[float]]] = [dict() for _ in range(R)]
            for dst, msgs in inbox.items():
                for _tag, (src, wv) in msgs:
                    w_nb[dst][src] = wv
            if it == 0:
                w_nb0 = w_nb  # original neighbor loads (for the avg adjustment)
            for r in range(R):
                delta = [0.0] * len(levels)
                for j in nbrs[r]:
                    for li in range(len(levels)):
                        fp = alpha[r][j] * (w_cur[r][li] - w_nb[r][j][li])
                        flows[r][j][li] += fp
                        delta[li] += fp
                for li in range(len(levels)):
                    w_cur[r][li] -= delta[li]

        self.last_flows_raw = [
            {j: list(v) for j, v in flows[r].items()} for r in range(R)
        ]

        # -- optional global reduction #1: exact global average (paper) --------
        # "This information can be used to adapt the process local
        #  inflow/outflow values with respect to the exact globally average
        #  process load."  Crucially this CAPS each rank's accumulated
        # outflow (inflow) at its exact excess (deficit) over the average:
        # the sum of all excesses equals the total imbalance, so uncoordinated
        # senders can never swamp a common underloaded neighbor (observed
        # oscillation otherwise), and a stalled rank whose per-edge flows are
        # all smaller than one block weight still pushes its excess along the
        # steepest edges. The per-edge flows remain pure Cybenko clues.
        avg = None
        if self.use_global_reductions:
            totals = comm.allreduce(
                (list(x) for x in w),
                lambda a, b: [x + y for x, y in zip(a, b)],
                nbytes=BYTES_FLOAT * len(levels),
            )
            avg = [t / R for t in totals]
            # Adjust the per-edge flows w.r.t. the exact global average
            # (paper §2.4.2). Two rules keep the iteration stable AND free of
            # granularity stalls:
            #   (a) no edge may carry more than HALF the pairwise load gap —
            #       sending more would invert the pair and oscillate;
            #   (b) each rank's total outflow is budgeted by its exact excess
            #       over the average; any part of that budget the converged
            #       Cybenko flows do not cover is granted to the remaining
            #       downhill-edge capacity, steepest edge first (this is what
            #       melts load plateaus at block granularity).
            # The sum of all excesses equals the global imbalance, so the
            # total traffic per main iteration stays bounded.
            for r in range(R):
                if not nbrs[r]:
                    continue
                for li in range(len(levels)):
                    gaps = {
                        j: max(0.0, (w[r][li] - w_nb0[r].get(j, w[r])[li]) / 2.0)
                        for j in nbrs[r]
                    }
                    excess = w[r][li] - avg[li]
                    if excess > _EPS:
                        f_sel = {
                            j: min(max(flows[r][j][li], 0.0), gaps[j]) for j in nbrs[r]
                        }
                        rem = excess - sum(f_sel.values())
                        if rem > _EPS:
                            for j in sorted(gaps, key=lambda x: -gaps[x]):
                                room = gaps[j] - f_sel[j]
                                if room <= _EPS:
                                    continue
                                grant = min(room, rem)
                                f_sel[j] += grant
                                rem -= grant
                                if rem <= _EPS:
                                    break
                        for j in nbrs[r]:
                            if flows[r][j][li] > 0 or f_sel[j] > 0:
                                flows[r][j][li] = f_sel[j]
                    elif excess < -_EPS:
                        deficit = -excess
                        ugaps = {
                            j: max(0.0, (w_nb0[r].get(j, w[r])[li] - w[r][li]) / 2.0)
                            for j in nbrs[r]
                        }
                        f_sel = {
                            j: min(max(-flows[r][j][li], 0.0), ugaps[j])
                            for j in nbrs[r]
                        }
                        rem = deficit - sum(f_sel.values())
                        if rem > _EPS:
                            for j in sorted(ugaps, key=lambda x: -ugaps[x]):
                                room = ugaps[j] - f_sel[j]
                                if room <= _EPS:
                                    continue
                                grant = min(room, rem)
                                f_sel[j] += grant
                                rem -= grant
                                if rem <= _EPS:
                                    break
                        for j in nbrs[r]:
                            if flows[r][j][li] < 0 or f_sel[j] > 0:
                                flows[r][j][li] = -f_sel[j]

        self.last_flows = [{j: list(v) for j, v in flows[r].items()} for r in range(R)]

        # -- block selection: push (Alg. 3) or pull (Alg. 4) -------------------
        use_pull = self.mode == "pull" or (self.mode == "pushpull" and iteration % 2 == 1)
        assignments: list[dict[int, int]] = [dict() for _ in range(R)]
        if not use_pull:
            for r in range(R):
                self._push(proxy, geom, r, flows[r], levels, assignments[r],
                           w[r], avg, w_nb0[r])
        else:
            self._pull(proxy, comm, geom, flows, nbrs, levels, assignments,
                       w, avg)

        # inform neighbor processes about the blocks about to be sent
        # (Alg. 2 line 19), extended into an accept/deny handshake for the
        # push scheme: a receiver accepts offers only up to its own deficit
        # below the global average plus one block of granularity. Without
        # this, many senders whose steepest downhill edge points at the same
        # underloaded rank swamp it and the iteration oscillates (receivers
        # in the pull scheme already control their inflow by construction).
        if not use_pull and avg is not None:
            for r in range(R):
                by_recv: dict[int, list] = {}
                for bid, j in assignments[r].items():
                    blk = proxy.local_blocks(r)[bid]
                    by_recv.setdefault(j, []).append(
                        (bid, blk.weight, blk.level if self.per_level else 0)
                    )
                for j, items in by_recv.items():
                    comm.send(r, j, "offer", (r, items, list(w[r])),
                              nbytes=len(items) * (BYTES_BLOCK_ID + BYTES_WEIGHT)
                              + BYTES_FLOAT * len(levels))
            inbox = comm.exchange()
            denies: list[list[tuple[int, int]]] = [[] for _ in range(R)]
            for dst, msgs in inbox.items():
                w_dst = list(w[dst])
                for _tag, (src, items, w_src) in msgs:
                    w_rem = list(w_src)
                    for bid, wgt, li in items:
                        # accept only if the pairwise imbalance strictly
                        # improves (sum-of-squares potential descends) —
                        # guarantees quiescence, no churn, no swamping
                        if w_dst[li] + wgt <= w_rem[li] - wgt + _EPS:
                            w_dst[li] += wgt
                            w_rem[li] -= wgt
                        else:
                            denies[dst].append((src, bid))
            for dst in range(R):
                for src, bid in denies[dst]:
                    comm.send(dst, src, "deny", bid, nbytes=BYTES_BLOCK_ID)
            inbox = comm.exchange()
            for dst, msgs in inbox.items():
                for _tag, bid in msgs:
                    assignments[dst].pop(bid, None)
        else:
            for r in range(R):
                for j in nbrs[r]:
                    comm.send(r, j, "notice", bool(assignments[r]), nbytes=1)
            comm.exchange()

        # -- optional global reduction #2: early termination --------------------
        if self.use_global_reductions:
            # NOTE: checked on the *pre-migration* state; the pipeline applies
            # the assignments afterwards, so "balanced" means no moves needed.
            balanced = is_balanced_per_level(proxy, comm, levels, self.tolerance)
            progress = any(assignments[r] for r in range(R))
            if iteration == 0:
                self._last_progress = True
            # stop only after TWO fruitless rounds: in alternating push/pull a
            # fruitless pull can precede a productive push (and vice versa).
            stalled = not progress and not self._last_progress
            self.last_balanced = balanced and not progress
            again = (
                not self.last_balanced
                and not stalled
                and (iteration + 1) < self.max_main_iterations
            )
            self._last_progress = progress
        else:
            again = (iteration + 1) < self.max_main_iterations
        return assignments, again

    # -- Alg. 3: push scheme ---------------------------------------------------
    def _push(
        self,
        proxy: BlockForest,
        geom,
        r: int,
        flow: dict[int, list[float]],
        levels: range,
        out: dict[int, int],
        w_r: list[float] | None = None,
        avg: list[float] | None = None,
        w_nb0: dict[int, list[float]] | None = None,
    ) -> None:
        local = proxy.local_blocks(r)
        local_bids = set(local)
        marked: set[int] = set()
        for li in range(len(levels)):
            f = {j: fl[li] for j, fl in flow.items()}
            outflow = sum(v for v in f.values() if v > 0)
            if avg is not None:
                # budget: the exact excess over the global average (paper).
                # Churn/swamping control is the receiver-side strict-descent
                # handshake, so no granularity band is needed here.
                outflow = min(outflow, max(0.0, w_r[li] - avg[li]))
            while outflow > _EPS and any(v > _EPS for v in f.values()):
                j = max(f, key=lambda k: f[k])
                if f[j] <= _EPS:
                    break
                # blocks that can be moved to j: correct level, unmarked,
                # weight within the accumulated outflow. Connection strength
                # (strong to j, weak to i) only *ranks* the candidates — the
                # flows are "clues", not hard constraints (paper §2.4.2).
                # sender-side survivability: a block heavier than half the
                # pairwise load gap would be denied by the receiver handshake
                # anyway — filter it here so the round is not wasted on it.
                gap_cap = None
                if avg is not None and w_nb0 is not None and j in w_nb0:
                    gap_cap = (w_r[li] - w_nb0[j][li]) / 2.0
                best = None
                best_score = None
                for bid, blk in local.items():
                    if bid in marked or (self.per_level and blk.level != li):
                        continue
                    if blk.weight > outflow + _EPS:
                        continue
                    if gap_cap is not None and blk.weight > gap_cap + _EPS:
                        continue
                    s = _connection_strengths(geom, blk, local_bids, marked)
                    score = s.get(j, 0.0) - s.get(-1, 0.0)
                    if best_score is None or score > best_score:
                        best, best_score = bid, score
                if best is None:
                    f[j] = 0.0
                    continue
                blk = local[best]
                marked.add(best)
                out[best] = j
                f[j] -= blk.weight
                outflow -= blk.weight

    # -- Alg. 4: pull scheme -----------------------------------------------------
    def _pull(
        self,
        proxy: BlockForest,
        comm: Comm,
        geom,
        flows: list[dict[int, list[float]]],
        nbrs: list[list[int]],
        levels: range,
        assignments: list[dict[int, int]],
        w: list[list[float]] | None = None,
        avg: list[float] | None = None,
    ) -> None:
        R = proxy.nranks
        # line 6: send (block id, weight) lists to all neighbor processes
        for r in range(R):
            items = [(b.bid, b.weight, b.level) for b in proxy.local_blocks(r).values()]
            for j in nbrs[r]:
                comm.send(r, j, "blist", (r, items),
                          nbytes=len(items) * (BYTES_BLOCK_ID + BYTES_WEIGHT))
        inbox = comm.exchange()
        remote: list[dict[int, list[tuple[int, float, int]]]] = [dict() for _ in range(R)]
        for dst, msgs in inbox.items():
            for _tag, (src, items) in msgs:
                remote[dst][src] = items

        # lines 7-18: bookmark remote blocks to fetch
        requests: list[dict[int, list[int]]] = [dict() for _ in range(R)]
        for r in range(R):
            local = proxy.local_blocks(r)
            local_bids = set(local)
            # adjacency of remote candidate blocks to me, with strengths
            adj_strength: dict[int, float] = {}
            for blk in local.values():
                for nb, owner in blk.neighbors.items():
                    if owner != r:
                        adj_strength[nb] = adj_strength.get(nb, 0.0) + _STRENGTH[
                            geom.adjacency_kind(blk.bid, nb)
                        ]
            bookmarked: set[int] = set()
            for li in range(len(levels)):
                f = {j: fl[li] for j, fl in flows[r].items()}
                inflow = -sum(v for v in f.values() if v < 0)
                if avg is not None:
                    # cap at the exact deficit below the global average
                    inflow = min(inflow, max(0.0, avg[li] - w[r][li]))
                while inflow > _EPS and any(v < -_EPS for v in f.values()):
                    j = min(f, key=lambda k: f[k])
                    if f[j] >= -_EPS:
                        break
                    best = None
                    best_score = None
                    best_w = 0.0
                    for bid, wgt, lvl in remote[r].get(j, ()):
                        if bid in bookmarked or (self.per_level and lvl != li):
                            continue
                        if wgt > inflow + _EPS:
                            continue
                        score = adj_strength.get(bid, 0.0)
                        if best_score is None or score > best_score:
                            best, best_score, best_w = bid, score, wgt
                    if best is None:
                        f[j] = 0.0
                        continue
                    bookmarked.add(best)
                    requests[r].setdefault(j, []).append(best)
                    f[j] += best_w
                    inflow -= best_w

        # line 19: send requests (annotated with the requester's loads so the
        # owner can grant on strict pairwise improvement — same quiescence
        # guarantee as the push handshake)
        for r in range(R):
            for j, bids in requests[r].items():
                comm.send(r, j, "req", (r, bids, list(w[r]) if w else None),
                          nbytes=len(bids) * BYTES_BLOCK_ID)
        inbox = comm.exchange()
        # lines 20-26: grant requests; ties go to the requester with the
        # largest outflow f_ij from the owner's perspective
        for dst, msgs in inbox.items():
            wanted: dict[int, list[int]] = {}
            w_req: dict[int, list[float] | None] = {}
            for _tag, (src, bids, w_src) in msgs:
                w_req[src] = list(w_src) if w_src is not None else None
                for bid in bids:
                    wanted.setdefault(bid, []).append(src)
            local = proxy.local_blocks(dst)
            w_own = list(w[dst]) if w else None
            for bid, srcs in wanted.items():
                if bid not in local or bid in assignments[dst]:
                    continue
                lvl_idx = local[bid].level if self.per_level else 0
                pick = max(srcs, key=lambda s: flows[dst].get(s, [0.0] * (lvl_idx + 1))[lvl_idx])
                wgt = local[bid].weight
                if w_own is not None and w_req.get(pick) is not None:
                    if w_req[pick][lvl_idx] + wgt > w_own[lvl_idx] - wgt + _EPS:
                        continue  # would not strictly improve: deny
                    w_own[lvl_idx] -= wgt
                    w_req[pick][lvl_idx] += wgt
                assignments[dst][bid] = pick
