"""Dynamic load balancing callbacks (paper §2.4).

A balancer is a callable invoked by the pipeline once per *main iteration*:

    assignments, again = balancer(proxy, comm, iteration)

``assignments[rank]`` maps local proxy bids to target ranks; the framework
then migrates the proxy blocks (:func:`repro.core.proxy.migrate_proxy_blocks`)
and re-invokes the balancer while ``again`` is True — enabling iterative,
diffusion-based schemes (paper Fig. 4).
"""

from .base import Balancer
from .sfc import SFCBalancer
from .diffusion import DiffusionBalancer

__all__ = ["Balancer", "SFCBalancer", "DiffusionBalancer"]
