"""SFC-based dynamic load balancing (paper §2.4.1).

Blocks are globally ordered along a space filling curve (Morton or Hilbert),
the ordered list is split into ``nranks`` contiguous pieces of (approximately)
equal weight, and piece *r* is assigned to rank *r*. For the LBM, blocks must
be balanced **per level** (paper §3.2), which requires one list per level.

The construction of the curve requires a *global* synchronization, realized
as an allgather (paper: "usually best realized with an allgather operation").
The amount of data each rank must then hold follows Table 1:

    per-level? weighted?   bytes allgathered per block (or per rank)
    no         no          1 byte per rank        (block counts only)
    no         yes         1-4  bytes per block   (weights, order preserved)
    yes        no          4-8  bytes per block   (block IDs)
    yes        yes         5-12 bytes per block   (IDs + weights)

This Θ(N) growth in per-rank memory and communication is the scalability
bottleneck measured in §5.1.2/§5.1.4 — reproduced by
``benchmarks/metadata_sync.py`` via the Comm accounting.
"""

from __future__ import annotations

from dataclasses import dataclass

from ..comm import BYTES_BLOCK_ID, BYTES_COUNT, BYTES_WEIGHT, Comm
from ..forest import BlockForest

__all__ = ["SFCBalancer"]


def _split_targets(items: list[tuple], weights: list[float], nranks: int) -> list[int]:
    """Assign sorted items to nranks contiguous chunks of ~equal weight via
    the prefix-midpoint rule (unit weights -> perfect ceil/floor split)."""
    total = sum(weights)
    if total <= 0:
        return [0] * len(items)
    targets = []
    prefix = 0.0
    for w in weights:
        mid = prefix + w / 2.0
        targets.append(min(nranks - 1, int(mid * nranks / total)))
        prefix += w
    return targets


@dataclass
class SFCBalancer:
    """Single-shot global balancer along a Morton or Hilbert curve."""

    order: str = "morton"  # "morton" | "hilbert"
    per_level: bool = True
    weighted: bool = False

    def __call__(
        self, proxy: BlockForest, comm: Comm, iteration: int
    ) -> tuple[list[dict[int, int]], bool]:
        geom = proxy.geom
        R = proxy.nranks
        key = geom.morton_key if self.order == "morton" else geom.hilbert_key

        if not self.per_level:
            # cheap path (Fig. 5, 1.1/1.2): blocks stay in curve order across
            # refinement, so synchronizing per-rank counts (and weights if
            # needed) suffices. Per-rank contribution: local blocks in order.
            contribs = []
            for r in range(R):
                blocks = sorted(proxy.local_blocks(r).values(), key=lambda b: key(b.bid))
                contribs.append([(b.bid, b.weight if self.weighted else 1.0) for b in blocks])
            nbytes_each = (
                BYTES_COUNT
                if not self.weighted
                else BYTES_WEIGHT * max(len(c) for c in contribs)
            )
            gathered = comm.allgather(contribs, nbytes_each=nbytes_each)
            flat: list[tuple[int, float]] = [x for c in gathered for x in c]
            weights = [w for _, w in flat]
            targets = _split_targets(flat, weights, R)
            target_of = {bid: t for (bid, _), t in zip(flat, targets)}
            assignments = [
                {bid: target_of[bid] for bid in proxy.local_blocks(r)} for r in range(R)
            ]
            return assignments, False

        # per-level path: allgather all block IDs (+ weights), reconstruct and
        # split every level's list locally on every rank (Fig. 5, 2.1/2.2).
        contribs = []
        for r in range(R):
            contribs.append(
                [
                    (b.bid, b.weight if self.weighted else 1.0)
                    for b in proxy.local_blocks(r).values()
                ]
            )
        per_block = BYTES_BLOCK_ID + (BYTES_WEIGHT if self.weighted else 0)
        nbytes_each = per_block * max((len(c) for c in contribs), default=0)
        gathered = comm.allgather(contribs, nbytes_each=nbytes_each)
        flat = [x for c in gathered for x in c]
        by_level: dict[int, list[tuple[int, float]]] = {}
        for bid, w in flat:
            by_level.setdefault(geom.level_of(bid), []).append((bid, w))
        target_of = {}
        for lvl, items in by_level.items():
            items.sort(key=lambda bw: key(bw[0]))
            targets = _split_targets(items, [w for _, w in items], R)
            for (bid, _), t in zip(items, targets):
                target_of[bid] = t
        assignments = [
            {bid: target_of[bid] for bid in proxy.local_blocks(r)} for r in range(R)
        ]
        return assignments, False
