"""The four-step AMR pipeline driver (paper Alg. 1, §2.1).

    1. mark blocks for refinement/coarsening + enforce 2:1   (refine.py)
    2. create the lightweight proxy data structure           (proxy.py)
    3. dynamically load balance the proxy                    (balancing/)
    4. migrate + refine/coarsen the actual simulation data   (migration.py)

The pipeline can be forced to run without any marks ("block weights must be
reevaluated and blocks must be redistributed"), supports multiple AMR cycles
per invocation, and records per-stage communication statistics so benchmarks
can attribute cost to stages exactly like the paper's Figures 8-13.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Callable

from ..telemetry import get_tracer
from .balancing.base import Balancer
from .comm import Comm
from .forest import Block, BlockForest
from .migration import BlockDataRegistry, migrate_data
from .proxy import ProxyWeightFn, build_proxy, migrate_proxy_blocks
from .refine import MarkCallback, mark_and_balance_targets

_TR = get_tracer()

__all__ = ["AMRPipeline", "CycleReport", "BlockWeightFn", "recompute_weights"]

# per-block weight callback evaluated on *actual* blocks (with their data):
# the paper's "block weights must be reevaluated" hook. Unlike ProxyWeightFn
# it sees the block's simulation payloads, so data-dependent load models
# (fluid-cell counts §3.2, particle counts) are expressible directly.
BlockWeightFn = Callable[[Block], float]


def recompute_weights(forest: BlockForest, weight_fn: BlockWeightFn) -> int:
    """Reevaluate every block's weight from its current data (process-local,
    no communication). Returns the number of blocks whose weight changed.

    The pipeline calls this automatically when ``block_weight_fn`` is set:
    once before each cycle (so the proxy is balanced against fresh loads) and
    once after data migration (so refined/coarsened/migrated blocks carry
    weights derived from their *actual* post-cycle data instead of whatever
    the proxy estimated — without this, new blocks keep their construction
    weight until the next reevaluation)."""
    changed = 0
    for b in forest.all_blocks():
        w = float(weight_fn(b))
        if w != b.weight:
            b.weight = w
            changed += 1
    return changed


@dataclass
class StageStats:
    seconds: float = 0.0
    p2p_bytes: int = 0
    p2p_messages: int = 0
    rounds: int = 0
    exchange_rounds: int = 0
    collective_bytes_per_rank: int = 0

    @staticmethod
    def delta(before: dict, after: dict, seconds: float) -> "StageStats":
        return StageStats(
            seconds=seconds,
            p2p_bytes=after["p2p_bytes"] - before["p2p_bytes"],
            p2p_messages=after["p2p_messages"] - before["p2p_messages"],
            rounds=after["rounds"] - before["rounds"],
            exchange_rounds=after.get("exchange_rounds", 0)
            - before.get("exchange_rounds", 0),
            collective_bytes_per_rank=after["collective_bytes_per_rank"]
            - before["collective_bytes_per_rank"],
        )

    def add(self, other: "StageStats") -> None:
        """Accumulate another stage observation (data-plane stages span many
        substeps; the driver folds each exchange's delta into one entry)."""
        self.seconds += other.seconds
        self.p2p_bytes += other.p2p_bytes
        self.p2p_messages += other.p2p_messages
        self.rounds += other.rounds
        self.exchange_rounds += other.exchange_rounds
        self.collective_bytes_per_rank += other.collective_bytes_per_rank


@dataclass
class CycleReport:
    executed: bool = False
    levels_changed: bool = False
    main_iterations: int = 0
    proxy_blocks_moved: int = 0
    stages: dict[str, StageStats] = field(default_factory=dict)

    def total_seconds(self) -> float:
        return sum(s.seconds for s in self.stages.values())


@dataclass
class AMRPipeline:
    balancer: Balancer
    registry: BlockDataRegistry
    weight_fn: ProxyWeightFn | None = None
    # data-dependent load model, reevaluated on the actual forest before each
    # balancing cycle and again after migration (see recompute_weights)
    block_weight_fn: BlockWeightFn | None = None

    def run_cycle(
        self,
        forest: BlockForest,
        comm: Comm,
        mark_fn: MarkCallback | None,
        *,
        force_rebalance: bool = False,
        max_cycles: int = 1,
    ) -> tuple[BlockForest, CycleReport]:
        """Run up to ``max_cycles`` AMR cycles (Alg. 1). Returns the new
        actual forest (the input forest is consumed) and a report."""
        report = CycleReport()
        current = forest
        for _cycle in range(max_cycles):
            # ---- step 0: reevaluate data-dependent block weights ------------
            # (later cycles are already covered by the post-migration call)
            if self.block_weight_fn is not None and _cycle == 0:
                recompute_weights(current, self.block_weight_fn)

            # ---- step 1: block-level refinement (+ 2:1) ---------------------
            s0 = comm.stats.summary()
            with _TR.stage("refine", cat="amr", cycle=_cycle) as sp:
                changed, ghost = mark_and_balance_targets(current, comm, mark_fn)
            report.stages["refine"] = StageStats.delta(
                s0, comm.stats.summary(), sp.seconds
            )
            report.levels_changed |= changed
            if not changed and not force_rebalance:
                # early exit: no marks and no forced weight reevaluation
                return current, report
            report.executed = True

            # ---- step 2: proxy data structure --------------------------------
            s0 = comm.stats.summary()
            with _TR.stage("proxy", cat="amr", cycle=_cycle) as sp:
                proxy = build_proxy(current, comm, ghost, self.weight_fn)
            report.stages["proxy"] = StageStats.delta(
                s0, comm.stats.summary(), sp.seconds
            )

            # ---- step 3: dynamic load balancing (iterative) -------------------
            s0 = comm.stats.summary()
            with _TR.stage("balance", cat="amr", cycle=_cycle) as sp:
                iteration = 0
                while True:
                    assignments, again = self.balancer(proxy, comm, iteration)
                    report.proxy_blocks_moved += migrate_proxy_blocks(
                        proxy, current, comm, assignments
                    )
                    iteration += 1
                    if not again:
                        break
                sp.set(iterations=iteration)
            report.main_iterations += iteration
            report.stages["balance"] = StageStats.delta(
                s0, comm.stats.summary(), sp.seconds
            )

            # ---- step 4: data migration + refine/coarsen ----------------------
            s0 = comm.stats.summary()
            with _TR.stage("migrate", cat="amr", cycle=_cycle) as sp:
                current = migrate_data(current, proxy, comm, self.registry)
            report.stages["migrate"] = StageStats.delta(
                s0, comm.stats.summary(), sp.seconds
            )
            # proxy is destroyed here (temporary structure, paper Fig. 6)
            del proxy
            # new blocks now hold their actual data: re-derive their weights
            # from the callback (split/merge proxy weights were estimates)
            if self.block_weight_fn is not None:
                recompute_weights(current, self.block_weight_fn)
            force_rebalance = False
            mark_fn = mark_fn if max_cycles > 1 else None
        return current, report
