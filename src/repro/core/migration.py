"""Data migration, refinement, and coarsening in one step (paper §2.5).

The framework never interprets block data. Each block-data item is registered
with **six callbacks** (three serialize/deserialize pairs): move, split, and
merge. During migration the framework invokes the right pair per block:

* **move**  — serialize on the source, deserialize on the target, unmodified;
* **split** — the source serializes one payload per octant *without*
  refining; interpolation to the fine grid happens on the *target* during
  deserialization (so no 8x memory reserve is ever needed on the source —
  the paper's memory argument in §2.5);
* **merge** — the source *coarsens before serializing*; the target only
  assembles the eight coarse octant payloads.

Refinement and coarsening always go through serialize/deserialize, even when
source and target rank coincide (paper §2.5), which keeps the code paths
identical and extensible to arbitrary data.
"""

from __future__ import annotations

import copy as _copy
import pickle
from dataclasses import dataclass
from typing import Any, Callable

import numpy as np

from .blockid import child_id, children_ids, octant_of, parent_id
from .comm import BYTES_BLOCK_ID, Comm
from .forest import Block, BlockForest

__all__ = ["BlockDataItem", "BlockDataRegistry", "migrate_data"]


def payload_nbytes(obj: Any) -> int:
    """Exact serialized size of a payload in the fabric's byte accounting.

    Containers are sized recursively — including *ragged* structures such as
    dict-of-ndarray particle sets, where every array has its own length — and
    dict keys are counted (a real wire format ships them). Numpy scalars are
    their itemsize, python scalars the fixed-width convention below. Only
    genuinely opaque objects fall back to their pickled size; nothing falls
    through to a flat guess, so migration byte counts for arbitrary §2.5
    payloads (the Table-1 quantities) are exact."""
    if obj is None:
        return 0
    if isinstance(obj, np.ndarray):
        return obj.nbytes
    if isinstance(obj, np.generic):  # numpy scalar: its in-memory width
        return obj.dtype.itemsize
    if isinstance(obj, bool):
        return 1
    if isinstance(obj, (int, float, complex)):
        return 16 if isinstance(obj, complex) else 8
    if isinstance(obj, str):
        return len(obj.encode())
    if isinstance(obj, (bytes, bytearray)):
        return len(obj)
    if isinstance(obj, (list, tuple, set, frozenset)):
        return sum(payload_nbytes(o) for o in obj)
    if isinstance(obj, dict):
        return sum(payload_nbytes(k) + payload_nbytes(v) for k, v in obj.items())
    try:
        return len(pickle.dumps(obj))
    except Exception:
        return 64


def _snapshot_copy(v: Any) -> Any:
    """Owned copy of a payload value: arrays directly, containers recursively
    (the §2.5 contract allows arbitrary nested data)."""
    if isinstance(v, np.ndarray):
        return np.array(v)
    if isinstance(v, (dict, list, tuple)):
        return _copy.deepcopy(v)
    return v  # scalars/str/bytes are immutable; opaque objects stay opaque


@dataclass
class BlockDataItem:
    """The six serialization callbacks for one named block-data item."""

    serialize_move: Callable[[Any, Block], Any]
    deserialize_move: Callable[[Any, Block], Any]
    # split: (data, old block, octant) -> payload; payload -> child data
    serialize_split: Callable[[Any, Block, int], Any]
    deserialize_split: Callable[[Any, Block], Any]
    # merge: (data, old block) -> coarsened octant payload;
    #        ({octant: payload}, new block) -> merged data
    serialize_merge: Callable[[Any, Block], Any]
    deserialize_merge: Callable[[dict[int, Any], Block], Any]


class BlockDataRegistry:
    def __init__(self) -> None:
        self.items: dict[str, BlockDataItem] = {}

    def register(self, name: str, item: BlockDataItem) -> None:
        self.items[name] = item

    # -- whole-block snapshot codec (checkpoint §4.1, resilience §4.2) ---------
    # Both subsystems need exactly move semantics: serialize on the owner,
    # deserialize wherever the block lands. Deriving them here keeps every
    # registry — including the typed FieldRegistry, which overrides
    # decode_block with shape/dtype validation — the single source of truth.
    #
    # Move callbacks commonly pass arrays by reference (right for migration,
    # where the source forest is discarded). A long-lived in-memory snapshot
    # must instead own its arrays — in-place stepping would silently mutate
    # it — so ``copy=True`` copies every ndarray payload. Payloads that are
    # immediately serialized (disk checkpoint) skip the copy.
    def encode_block(self, blk: Block, *, copy: bool = True) -> dict[str, Any]:
        payload = {
            name: item.serialize_move(blk.data.get(name), blk)
            for name, item in self.items.items()
        }
        if copy:
            payload = {n: _snapshot_copy(v) for n, v in payload.items()}
        return payload

    def decode_block(
        self, payload: dict[str, Any], blk: Block, *, copy: bool = False
    ) -> dict[str, Any]:
        data = {
            name: item.deserialize_move(payload.get(name), blk)
            for name, item in self.items.items()
        }
        if copy:  # restore paths: the snapshot must survive the restored run
            data = {n: _snapshot_copy(v) for n, v in data.items()}
        return data

    @staticmethod
    def trivial(name: str = "payload") -> "BlockDataRegistry":
        """Registry for opaque payloads (no refinement semantics) — useful
        for meshless data and tests."""
        reg = BlockDataRegistry()
        ident2 = lambda d, b: d
        reg.register(
            name,
            BlockDataItem(
                serialize_move=ident2,
                deserialize_move=ident2,
                serialize_split=lambda d, b, o: d,
                deserialize_split=ident2,
                serialize_merge=ident2,
                deserialize_merge=lambda parts, b: parts,
            ),
        )
        return reg


def migrate_data(
    actual: BlockForest,
    proxy: BlockForest,
    comm: Comm,
    registry: BlockDataRegistry,
) -> BlockForest:
    """Adapt the actual forest to the balanced proxy: refine, coarsen, and
    migrate all simulation data in one single step (paper §2.5, Fig. 6).

    Returns the new actual forest (topology copied from the proxy, data
    produced by the registered callbacks). The proxy is left untouched and
    is destroyed by the caller (pipeline)."""
    R = actual.nranks
    geom = actual.geom
    new_forest = BlockForest(geom, R)

    # new topology from the proxy (adjacency & weights are authoritative there)
    for r in range(R):
        for pb in proxy.local_blocks(r).values():
            nb = Block(
                bid=pb.bid,
                level=pb.level,
                owner=r,
                neighbors=dict(pb.neighbors),
                weight=pb.weight,
            )
            new_forest.insert(nb)

    # serialize + route payloads according to the bilateral links
    # message payloads: (new_bid, kind, octant, {item: payload})
    local_deliveries: list[list[tuple[int, str, int, dict[str, Any]]]] = [
        [] for _ in range(R)
    ]
    for r in range(R):
        for bid, blk in actual.local_blocks(r).items():
            t = blk.target_level
            if t == blk.level:
                tgt = blk.target_ranks[0]
                if tgt == r:
                    # plain keep: rebind data locally, no serialization
                    new_forest.local_blocks(r)[bid].data = blk.data
                    continue
                payloads = {
                    n: it.serialize_move(blk.data.get(n), blk)
                    for n, it in registry.items.items()
                }
                comm.send(r, tgt, "mig", (bid, "move", 0, payloads),
                          nbytes=BYTES_BLOCK_ID + payload_nbytes(payloads))
            elif t == blk.level + 1:
                for o in range(8):
                    tgt = blk.target_ranks[o]
                    payloads = {
                        n: it.serialize_split(blk.data.get(n), blk, o)
                        for n, it in registry.items.items()
                    }
                    msg = (child_id(bid, o), "split", o, payloads)
                    if tgt == r:
                        local_deliveries[r].append(msg)
                    else:
                        comm.send(r, tgt, "mig", msg,
                                  nbytes=BYTES_BLOCK_ID + payload_nbytes(payloads))
            else:  # merge: coarsen on the sender, assemble on the target
                tgt = blk.target_ranks[0]
                payloads = {
                    n: it.serialize_merge(blk.data.get(n), blk)
                    for n, it in registry.items.items()
                }
                msg = (parent_id(bid), "merge", octant_of(bid), payloads)
                if tgt == r:
                    local_deliveries[r].append(msg)
                else:
                    comm.send(r, tgt, "mig", msg,
                              nbytes=BYTES_BLOCK_ID + 1 + payload_nbytes(payloads))

    inbox = comm.exchange()
    arrivals: list[list[tuple[int, str, int, dict[str, Any]]]] = [[] for _ in range(R)]
    for dst, msgs in inbox.items():
        for _tag, msg in msgs:
            arrivals[dst].append(msg)
    for r in range(R):
        arrivals[r].extend(local_deliveries[r])

    merge_parts: list[dict[int, dict[int, dict[str, Any]]]] = [dict() for _ in range(R)]
    for r in range(R):
        blocks = new_forest.local_blocks(r)
        for new_bid, kind, octant, payloads in arrivals[r]:
            assert new_bid in blocks, (
                f"rank {r} received data for {new_bid:#x} it does not own"
            )
            nb = blocks[new_bid]
            if kind == "move":
                nb.data = {
                    n: registry.items[n].deserialize_move(p, nb)
                    for n, p in payloads.items()
                }
            elif kind == "split":
                nb.data = {
                    n: registry.items[n].deserialize_split(p, nb)
                    for n, p in payloads.items()
                }
            else:  # merge: collect all 8 octants, then assemble
                merge_parts[r].setdefault(new_bid, {})[octant] = payloads
    for r in range(R):
        blocks = new_forest.local_blocks(r)
        for new_bid, parts in merge_parts[r].items():
            assert len(parts) == 8, f"merge {new_bid:#x}: got {sorted(parts)} octants"
            nb = blocks[new_bid]
            nb.data = {
                n: registry.items[n].deserialize_merge(
                    {o: p[n] for o, p in parts.items()}, nb
                )
                for n in registry.items
            }
    return new_forest
