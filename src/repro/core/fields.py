"""Typed block-field API: one declaration drives the whole framework.

The paper's blocks "support the storage of arbitrary data" via user-registered
serialization callbacks (§2.5). The raw mechanism — six callbacks per item
(:class:`~repro.core.migration.BlockDataItem`) — is maximally general but
forces every physics module to hand-write the same volumetric split/merge
boilerplate, and gives the framework no type information to build fast data
paths from. This module layers a *typed* field API on top:

* :class:`FieldSpec` — one declaration per physics field: name, dtype,
  per-cell component shape, ghost width, and a declarative refine/coarsen
  policy (``copy | inject | interpolate`` x ``copy | restrict | max`` or
  custom functions);
* :class:`FieldRegistry` — a :class:`BlockDataRegistry` subclass that
  **derives** the six migration callbacks, checkpoint encode/decode, and
  resilience snapshot/restore from the declarations. Untyped
  ``BlockDataRegistry`` (e.g. :meth:`BlockDataRegistry.trivial`) keeps
  working everywhere as the compatibility shim for meshless/opaque data;
* :class:`LevelArena` — persistent per-level struct-of-arrays storage: one
  contiguous ``(B, *field_shape)`` buffer per (level, field) with a
  bid -> slot index maintained across migration/refine/coarsen. Every
  ``Block.data[name]`` entry is a zero-copy view into its arena buffer, so
  ghost exchange and diagnostics keep their per-block interface while the
  stepping loop hands whole buffers to the kernels — no per-substep
  restacking.

Registering a new physics field is one line::

    reg = FieldRegistry(cells=(16, 16, 16))
    reg.add(FieldSpec("temperature", dtype=np.float32,
                      refine="interpolate", coarsen="restrict"))

and migration, checkpoint/restart, buddy resilience, halo exchange, and the
arena data plane all pick it up with no further code.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Iterable

import numpy as np

from ..telemetry import get_tracer
from .forest import Block, BlockForest
from .migration import BlockDataItem, BlockDataRegistry

_TR = get_tracer()

__all__ = [
    "FieldSpec",
    "FieldRegistry",
    "LevelArena",
    "RankArenas",
    "DeviceResidency",
    "octant_slices",
    "coarsen2",
    "refine2",
]


# -- volumetric resampling primitives (paper §3.3, [54]/[16]) -----------------


def _interior_slice(g: int) -> slice:
    return slice(g, -g) if g else slice(None)  # slice(0, -0) would be empty


def octant_slices(o: int, n: tuple[int, int, int], g: int) -> tuple[slice, slice, slice]:
    """Interior slices of octant ``o`` of a ghosted (nx+2g, ny+2g, nz+2g) array."""
    ox, oy, oz = o & 1, (o >> 1) & 1, (o >> 2) & 1
    nx, ny, nz = n
    return (
        slice(g + ox * nx // 2, g + (ox + 1) * nx // 2),
        slice(g + oy * ny // 2, g + (oy + 1) * ny // 2),
        slice(g + oz * nz // 2, g + (oz + 1) * nz // 2),
    )


def _group2(a: np.ndarray) -> np.ndarray:
    """View the last three axes as 2x2x2 groups: (..., x/2, 2, y/2, 2, z/2, 2)."""
    s = a.shape
    return a.reshape(*s[:-3], s[-3] // 2, 2, s[-2] // 2, 2, s[-1] // 2, 2)


def coarsen2(a: np.ndarray) -> np.ndarray:
    """Average 2x2x2 groups over the last three axes (volumetric restrict)."""
    return _group2(a).mean(axis=(-5, -3, -1))


def refine2(a: np.ndarray) -> np.ndarray:
    """Replicate each cell into 2x2x2 over the last three axes (volumetric split)."""
    for ax in (-3, -2, -1):
        a = np.repeat(a, 2, axis=ax)
    return a


def _coarsen_max(a: np.ndarray) -> np.ndarray:
    """2x2x2 max over the last three axes (categorical merge: 'prefer walls')."""
    return _group2(a).max(axis=(-5, -3, -1))


_REFINE_FNS: dict[str, Callable[[np.ndarray], np.ndarray]] = {
    # both replicate cell values onto the 2x finer grid; they differ in intent
    # (and are allowed to diverge, e.g. to trilinear interpolation):
    "interpolate": refine2,  # continuous data; conservative w.r.t. cell averages
    "inject": refine2,  # categorical data (piecewise-constant injection)
}
_COARSEN_FNS: dict[str, Callable[[np.ndarray], np.ndarray]] = {
    "restrict": coarsen2,  # mean over the 2x2x2 octet (mass-conservative)
    "max": _coarsen_max,  # categorical reduce
}


@dataclass(frozen=True)
class FieldSpec:
    """Declaration of one typed per-block mesh field.

    The stored array has shape ``(*shape, nx+2g, ny+2g, nz+2g)``: ``shape``
    leading per-cell component axes (e.g. ``(Q,)`` for PDFs, ``()`` for a
    scalar), then the three ghosted spatial axes.

    ``refine`` governs the split data path (coarse parent -> 8 fine children)
    and ``coarsen`` the merge path (8 fine children -> coarse parent):

    * ``refine="interpolate" | "inject"`` — the *unmodified* coarse octant is
      serialized on the sender; the prolongation onto the finer grid happens
      on the receiver during deserialization (the paper's §2.5/§3.3 memory
      argument: no 8x reserve on the source). A custom callable maps the
      coarse octant interior to the full fine interior.
    * ``coarsen="restrict" | "max"`` — restriction happens on the *sender*
      before serialization; the receiver only assembles the eight coarse
      octant payloads. A custom callable maps the fine interior to one
      coarse octant payload.
    * ``refine="copy"`` / ``coarsen="copy"`` — opaque pass-through: every
      child receives the full parent array; a merged parent takes octant 0's
      array (for per-block metadata that has no mesh semantics).
    """

    name: str
    dtype: Any = np.float32
    shape: tuple[int, ...] = ()
    ghost: int = 1
    refine: str | Callable[[np.ndarray], np.ndarray] = "interpolate"
    coarsen: str | Callable[[np.ndarray], np.ndarray] = "restrict"

    def block_shape(self, cells: tuple[int, int, int]) -> tuple[int, ...]:
        g2 = 2 * self.ghost
        return (*self.shape, cells[0] + g2, cells[1] + g2, cells[2] + g2)

    def _refine_fn(self) -> Callable[[np.ndarray], np.ndarray] | None:
        if self.refine == "copy":
            return None
        if callable(self.refine):
            return self.refine
        return _REFINE_FNS[self.refine]

    def _coarsen_fn(self) -> Callable[[np.ndarray], np.ndarray] | None:
        if self.coarsen == "copy":
            return None
        if callable(self.coarsen):
            return self.coarsen
        return _COARSEN_FNS[self.coarsen]


def _derive_item(spec: FieldSpec, cells: tuple[int, int, int]) -> BlockDataItem:
    """Derive the six §2.5 serialization callbacks from one declaration."""
    g = spec.ghost
    full = spec.block_shape(cells)
    refine_fn = spec._refine_fn()
    coarsen_fn = spec._coarsen_fn()
    interior = (Ellipsis,) + (_interior_slice(g),) * 3

    def ser_move(d: Any, _blk: Block) -> Any:
        return d

    def des_move(p: Any, _blk: Block) -> Any:
        return p

    def ser_split(d: np.ndarray, _blk: Block, o: int) -> np.ndarray:
        if refine_fn is None:  # copy policy: full array to every child
            return d
        sx, sy, sz = octant_slices(o, cells, g)
        return np.ascontiguousarray(d[..., sx, sy, sz])  # unmodified coarse data

    def des_split(p: np.ndarray, _blk: Block) -> np.ndarray:
        if refine_fn is None:
            return np.array(p) if isinstance(p, np.ndarray) else p
        out = np.zeros(full, dtype=spec.dtype)
        out[interior] = refine_fn(p)  # prolong on the receiver (§3.3)
        return out

    def ser_merge(d: np.ndarray, _blk: Block) -> np.ndarray:
        if coarsen_fn is None:
            return d
        return coarsen_fn(d[interior]).astype(spec.dtype)  # restrict on the sender

    def des_merge(parts: dict[int, np.ndarray], _blk: Block) -> np.ndarray:
        if coarsen_fn is None:
            p = parts[0]
            return np.array(p) if isinstance(p, np.ndarray) else p
        out = np.zeros(full, dtype=spec.dtype)
        for o, payload in parts.items():
            sx, sy, sz = octant_slices(o, cells, g)
            out[..., sx, sy, sz] = payload
        return out

    return BlockDataItem(
        serialize_move=ser_move,
        deserialize_move=des_move,
        serialize_split=ser_split,
        deserialize_split=des_split,
        serialize_merge=ser_merge,
        deserialize_merge=des_merge,
    )


class FieldRegistry(BlockDataRegistry):
    """Typed registry: :class:`FieldSpec` declarations with derived callbacks.

    A drop-in :class:`BlockDataRegistry` — migration, checkpoint, resilience,
    and the AMR pipeline consume it unchanged through ``items`` /
    ``encode_block`` / ``decode_block`` — plus the typed surface
    (``fields``, ``alloc``, ``block_shape``) that the arena data plane and
    halo exchange build on.
    """

    def __init__(
        self, cells: tuple[int, int, int], fields: Iterable[FieldSpec] = ()
    ) -> None:
        super().__init__()
        self.cells = tuple(int(c) for c in cells)
        for n in self.cells:
            assert n % 2 == 0, "cells per block must be even (octant split)"
        self.fields: dict[str, FieldSpec] = {}
        for spec in fields:
            self.add(spec)

    def add(self, spec: FieldSpec) -> FieldSpec:
        """Register one field; all framework callbacks are derived here."""
        assert spec.name not in self.fields, f"field {spec.name!r} already registered"
        self.fields[spec.name] = spec
        self.register(spec.name, _derive_item(spec, self.cells))
        return spec

    def block_shape(self, name: str) -> tuple[int, ...]:
        return self.fields[name].block_shape(self.cells)

    def alloc(self, name: str) -> np.ndarray:
        """A zeroed per-block array for field ``name`` (ghosted)."""
        spec = self.fields[name]
        return np.zeros(spec.block_shape(self.cells), dtype=spec.dtype)

    def interior(self, name: str, arr: np.ndarray) -> np.ndarray:
        s = _interior_slice(self.fields[name].ghost)
        return arr[..., s, s, s]

    # -- checkpoint / resilience hook (typed: validates on decode) -------------
    # encode_block's snapshot copy semantics come from the base registry.
    def decode_block(
        self, payload: dict[str, Any], blk: Block, *, copy: bool = False
    ) -> dict[str, Any]:
        data = super().decode_block(payload, blk, copy=copy)
        for name, spec in self.fields.items():
            arr = data.get(name)
            if arr is None:
                continue
            arr = np.asarray(arr)
            want = spec.block_shape(self.cells)
            if arr.shape != want:  # external input — must survive python -O
                raise ValueError(
                    f"field {name!r}: payload shape {arr.shape} != declared {want}"
                )
            data[name] = arr.astype(spec.dtype, copy=False)
        return data


class LevelArena:
    """Persistent per-level struct-of-arrays storage for all mesh fields.

    For every refinement level in use, the arena owns one contiguous
    ``(B, *field_shape)`` buffer per registered field, where ``B`` is the
    number of blocks on that level (across all simulated ranks — the data
    plane is host-side, like the stepping loop it feeds). ``Block.data[name]``
    is rebound to the block's zero-copy slice of the buffer, so all per-block
    code (ghost exchange, criteria, diagnostics, migration serializers) keeps
    working while kernels consume whole levels without restacking.

    :meth:`adopt` is the single maintenance point: call it after any forest
    topology change (AMR cycle, restart, resilience restore). It keeps the
    bid -> slot index consistent with the forest and reuses buffers when a
    level's block set is unchanged.

    With ``rank`` given, the arena is *rank-sharded*: it packs only blocks
    owned by that simulated rank, so its memory is O(local blocks) — the
    paper's per-rank bound — and a set of such arenas (:class:`RankArenas`)
    partitions the forest's data plane by owner.
    """

    def __init__(self, registry: FieldRegistry, rank: int | None = None) -> None:
        self.registry = registry
        self.rank = rank  # None: whole forest; int: only blocks owned by rank
        self._bufs: dict[int, dict[str, np.ndarray]] = {}  # level -> field -> SoA
        self._slots: dict[int, dict[int, int]] = {}  # level -> bid -> slot
        self.version = 0  # bumped on every adopt (cache invalidation hook)
        self._residency: "DeviceResidency | None" = None

    def _owned(self, forest: BlockForest) -> Iterable[Block]:
        if self.rank is None:
            return forest.all_blocks()
        return forest.local_blocks(self.rank).values()

    # -- data-plane access ------------------------------------------------------
    def levels(self) -> list[int]:
        return sorted(self._bufs)

    def buffer(self, level: int, name: str) -> np.ndarray | None:
        """The (B, *field_shape) SoA buffer for one level, or None."""
        return self._bufs.get(level, {}).get(name)

    def slots(self, level: int) -> dict[int, int]:
        """bid -> slot index for one level (slot order is ascending bid)."""
        return self._slots.get(level, {})

    def slot_of(self, level: int, bid: int) -> int:
        return self._slots[level][bid]

    def num_blocks(self, level: int) -> int:
        return len(self._slots.get(level, {}))

    # -- maintenance ------------------------------------------------------------
    def adopt(self, forest: BlockForest) -> None:
        """(Re)pack block fields into per-level buffers and rebind views.

        Blocks whose arrays already live in the right slot are left in place
        (no copy); freshly materialized arrays (from migration deserialize,
        checkpoint load, or block init) are copied into their slot once.
        """
        if self._residency is not None:
            # device-side results must be flushed before the storage they
            # mirror is repacked — otherwise computed steps would vanish
            self._residency.check_no_pending()
        by_level: dict[int, list[Block]] = {}
        for b in self._owned(forest):
            by_level.setdefault(b.level, []).append(b)
        new_bufs: dict[int, dict[str, np.ndarray]] = {}
        new_slots: dict[int, dict[int, int]] = {}
        for level, blocks in by_level.items():
            blocks.sort(key=lambda b: b.bid)
            slots = {b.bid: i for i, b in enumerate(blocks)}
            reuse = self._slots.get(level) == slots
            bufs = dict(self._bufs.get(level, {})) if reuse else {}
            for name, spec in self.registry.fields.items():
                shape = (len(blocks), *spec.block_shape(self.registry.cells))
                buf = bufs.get(name)
                if buf is None or buf.shape != shape:
                    buf = np.zeros(shape, dtype=spec.dtype)
                for i, b in enumerate(blocks):
                    src = b.data.get(name)
                    view = buf[i]
                    if src is not None and src.base is not buf:
                        view[...] = src
                    b.data[name] = view
                bufs[name] = buf
            new_bufs[level] = bufs
            new_slots[level] = slots
        self._bufs = new_bufs
        self._slots = new_slots
        self.version += 1

    # -- device residency -------------------------------------------------------
    def device(self) -> "DeviceResidency":
        """The arena's device-residency layer (created on first use).

        Fused stepping keeps whole level buffers resident on the accelerator
        as ``jax.Array``s; host views are only rematerialized (via
        :meth:`DeviceResidency.flush`) when migration, checkpointing, or
        diagnostics actually need them. All host<->device traffic is counted,
        so tests can assert the steady-state substep loop performs zero
        transfers.
        """
        if self._residency is None:
            self._residency = DeviceResidency(self)
        return self._residency

    # -- invariants (tests / verification) --------------------------------------
    def check_consistent(self, forest: BlockForest) -> None:
        """Slot index and views agree with the (rank-local) forest topology."""
        by_level: dict[int, set[int]] = {}
        for b in self._owned(forest):
            by_level.setdefault(b.level, set()).add(b.bid)
        assert set(self._slots) == set(by_level), (
            f"arena levels {sorted(self._slots)} != forest levels {sorted(by_level)}"
        )
        for level, bids in by_level.items():
            slots = self._slots[level]
            assert set(slots) == bids, f"L{level}: slot index out of sync"
            assert sorted(slots.values()) == list(range(len(bids))), (
                f"L{level}: slots not a dense permutation"
            )
        for b in self._owned(forest):
            slot = self._slots[b.level][b.bid]
            for name in self.registry.fields:
                buf = self._bufs[b.level][name]
                view = b.data[name]
                assert view.base is buf and view.shape == buf.shape[1:], (
                    f"block {b.bid:#x} field {name!r} is not an arena view"
                )
                expect = buf[slot]
                assert (
                    view.__array_interface__["data"][0]
                    == expect.__array_interface__["data"][0]
                ), f"block {b.bid:#x} field {name!r} bound to the wrong slot"


class DeviceResidency:
    """Device-resident mirror of a :class:`LevelArena`, version-tracked both
    ways.

    Each (level, field) buffer can live in one of three states:

    * **host-only** — no device copy exists; :meth:`fetch` uploads one
      (counted as an h2d transfer);
    * **synced** — a device copy exists and matches the host buffer;
      :meth:`fetch` returns it with no transfer;
    * **device-newer** — :meth:`store` installed a device-side update (the
      output of a jitted step); the host view is stale until :meth:`flush`
      downloads it back into the arena buffer *in place*, so every
      ``Block.data`` view stays bound.

    Invalidation across topology changes is by mechanism: an arena
    ``adopt()`` bumps ``arena.version``, which drops all device state on the
    next access (the buffers it mirrored no longer exist), and refuses to run
    at all while device-newer results are un-flushed (see
    :meth:`check_no_pending`). Host-side writes *between* adoptions are a
    manual contract — numpy views cannot announce mutation — so code that
    edits host buffers while a synced device copy exists (e.g. the driver's
    mask refresh) must call :meth:`drop` for the touched field or the edit
    never reaches the device; :meth:`drop` asserts if it would discard a
    pending device-side update.
    """

    def __init__(self, arena: LevelArena) -> None:
        self.arena = arena
        self._dev: dict[tuple[int, str], Any] = {}  # (level, field) -> jax.Array
        self._dev_newer: set[tuple[int, str]] = set()
        self._arena_version = arena.version
        self.h2d_transfers = 0
        self.h2d_bytes = 0
        self.d2h_transfers = 0
        self.d2h_bytes = 0

    @property
    def transfers(self) -> int:
        """Total host<->device transfers performed (both directions)."""
        return self.h2d_transfers + self.d2h_transfers

    def _sync_version(self) -> None:
        if self._arena_version != self.arena.version:
            # storage was rebound under us: every device copy mirrors a buffer
            # that no longer backs the forest — drop them all (adopt already
            # asserted nothing device-newer was pending; backstop here for
            # version bumps that bypass adopt)
            self.check_no_pending()
            self._dev.clear()
            self._arena_version = self.arena.version

    def fetch(self, level: int, name: str):
        """The device-resident buffer for (level, field).

        Args:
            level: refinement level whose arena buffer to mirror.
            name: registered field name.

        Returns:
            The ``jax.Array`` mirror of ``arena.buffer(level, name)``. If no
            device copy exists (first access, or everything was dropped by a
            version bump) the host buffer is uploaded and the transfer
            counted; otherwise the cached array — possibly a device-newer
            one installed by :meth:`store` — is returned with no transfer.

        The arena version is synchronized first: if ``arena.version`` moved
        since the last access (an ``adopt`` happened), all device state is
        dropped before the lookup, so a fetch can never return a mirror of
        storage that no longer backs the forest.
        """
        import jax.numpy as jnp

        self._sync_version()
        key = (level, name)
        arr = self._dev.get(key)
        if arr is None:
            host = self.arena.buffer(level, name)
            assert host is not None, f"no arena buffer for L{level} {name!r}"
            arr = jnp.asarray(host)
            self._dev[key] = arr
            self.h2d_transfers += 1
            self.h2d_bytes += host.nbytes
            if _TR.enabled:
                _TR.instant(
                    "h2d", cat="residency", rank=self.arena.rank or 0,
                    level=level, field=name, bytes=host.nbytes,
                )
        return arr

    def store(self, level: int, name: str, value) -> None:
        """Install a device-side update; the host view becomes stale.

        Args:
            level: refinement level the update belongs to.
            name: registered field name.
            value: the new device array (typically a jitted step's output);
                its shape must match the arena buffer exactly.

        The (level, field) pair is marked *device-newer*: subsequent
        :meth:`fetch` calls return ``value`` without transfers, host readers
        must :meth:`flush` first, and an arena ``adopt()`` while the mark is
        set fails loudly (:meth:`check_no_pending`) instead of silently
        discarding computed steps.
        """
        self._sync_version()
        key = (level, name)
        host = self.arena.buffer(level, name)
        assert host is not None and value.shape == host.shape, (
            f"store shape {getattr(value, 'shape', None)} != arena "
            f"{None if host is None else host.shape} for L{level} {name!r}"
        )
        self._dev[key] = value
        self._dev_newer.add(key)

    def drop(self, name: str | None = None, level: int | None = None) -> None:
        """Forget device copies (after a host-side write made them stale).

        Args:
            name: restrict to one field (``None`` = every field).
            level: restrict to one level (``None`` = every level).

        Host-side writes between adoptions are a manual contract — numpy
        views cannot announce mutation — so code that edits host buffers
        while a synced device copy exists (e.g. the driver's mask refresh)
        must call this for the touched field, or the edit never reaches the
        device. Dropping a *device-newer* entry asserts: that would discard
        a computed result — ``flush()`` first.
        """
        self._sync_version()
        for key in [
            k
            for k in self._dev
            if (name is None or k[1] == name) and (level is None or k[0] == level)
        ]:
            assert key not in self._dev_newer, (
                f"host write raced a pending device update for {key}: flush() "
                "before mutating host buffers the device owns"
            )
            del self._dev[key]

    def check_no_pending(self) -> None:
        """Assert no un-flushed device-newer state exists (called by
        ``LevelArena.adopt`` so a missing flush fails loudly instead of
        silently discarding computed steps)."""
        assert not self._dev_newer, (
            f"device-newer state pending for {sorted(self._dev_newer)}: "
            "flush() before rebinding/adopting the arena"
        )

    def flush(self) -> None:
        """Materialize host views: download every device-newer buffer into
        its arena storage in place (block views stay bound).

        Downloads are counted (``d2h_transfers`` / ``d2h_bytes``) and the
        device-newer marks cleared; the device copies are kept and remain
        *synced*, so a later :meth:`fetch` performs no re-upload. Idempotent:
        a second flush with nothing pending transfers nothing — the
        conformance suite relies on this to pin "transfers only when state
        actually moved".
        """
        self._sync_version()
        for key in sorted(self._dev_newer):
            level, name = key
            host = self.arena.buffer(level, name)
            np.copyto(host, np.asarray(self._dev[key]))
            self.d2h_transfers += 1
            self.d2h_bytes += host.nbytes
            if _TR.enabled:
                _TR.instant(
                    "d2h", cat="residency", rank=self.arena.rank or 0,
                    level=level, field=name, bytes=host.nbytes,
                )
        self._dev_newer.clear()


class RankArenas:
    """The rank-sharded data plane: one :class:`LevelArena` per simulated rank.

    Each rank's arena holds only the blocks that rank owns, so every per-rank
    buffer is bounded by the local block count — stepping a rank touches no
    other rank's memory, which is what makes the sharded stepping mode an
    end-to-end distributed data plane (cross-rank ghost data must travel as
    messages, never as direct reads).

    :meth:`adopt` rebuilds every rank's arena from the forest's current
    ownership; it is the single maintenance point after migration, refine,
    coarsen, or restore (the sharded analogue of global restacking). The
    shared ``version`` counter invalidates downstream caches (device masks,
    halo exchange plans, compiled per-rank programs) exactly like
    :class:`LevelArena.version` does — callers pass it as the O(1)
    ``cache_token`` to the plan caches and key compiled-program caches on
    it, so no cache can survive a storage rebind.

    Device residency is per rank: ``per_rank[r].device()`` returns rank r's
    own :class:`DeviceResidency` (created on first use), which is what lets
    the ``fused_sharded`` stepping mode keep every rank's state resident on
    its (simulated) accelerator and count per-rank transfers independently.
    """

    def __init__(self, registry: FieldRegistry, nranks: int) -> None:
        self.registry = registry
        self.nranks = nranks
        self.per_rank = [LevelArena(registry, rank=r) for r in range(nranks)]
        self.version = 0

    def adopt(self, forest: BlockForest) -> None:
        """Rebuild every rank's arena from the forest's current ownership
        and bump the shared version counter.

        Args:
            forest: the post-cycle forest; its ``nranks`` must match.

        Each per-rank adopt refuses to run while that rank holds un-flushed
        device-newer state (see :meth:`DeviceResidency.check_no_pending`),
        so a missing ``materialize_host()`` before an AMR event fails loudly
        on the exact rank that would have lost steps."""
        assert forest.nranks == self.nranks, (forest.nranks, self.nranks)
        for arena in self.per_rank:
            arena.adopt(forest)
        self.version += 1

    def buffer(self, rank: int, level: int, name: str) -> np.ndarray | None:
        """Rank ``rank``'s (B_local, *field_shape) SoA buffer, or None."""
        return self.per_rank[rank].buffer(level, name)

    def num_blocks(self, rank: int, level: int) -> int:
        return self.per_rank[rank].num_blocks(level)

    def levels(self) -> list[int]:
        return sorted({l for a in self.per_rank for l in a.levels()})

    def held_bytes_per_rank(self) -> list[int]:
        """Data-plane bytes resident per rank (the Table-1 quantity for the
        data plane: must stay O(local blocks), independent of nranks)."""
        return [
            sum(buf.nbytes for fields in a._bufs.values() for buf in fields.values())
            for a in self.per_rank
        ]

    def check_consistent(self, forest: BlockForest) -> None:
        for arena in self.per_rank:
            arena.check_consistent(forest)
