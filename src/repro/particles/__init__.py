"""Lagrangian particle subsystem: meshless block data on the AMR forest.

The paper's block concept "supports the storage of arbitrary data" so the
framework serves "mesh based and meshless methods" — this package is that
claim made executable. Passive tracers live as per-block variable-length
struct-of-arrays sets, ride the §2.5 migration/checkpoint/resilience
machinery unchanged (:mod:`~repro.particles.storage`), advect through the
block-local LBM velocity field with a jitted RK2 kernel
(:mod:`~repro.particles.advect`), hop blocks/ranks through batched p2p
messages over the Comm fabric (:mod:`~repro.particles.redistribute`), and
feed a ``cells + alpha * N`` load model into the dynamic balancers
(:mod:`~repro.particles.balance`) — the mesh+particle imbalance regime of
Nanda et al. 2025 / AMReX (Zhang et al. 2020).

Driver integration: pass ``LidDrivenCavityConfig(particles=ParticlesConfig(...))``
— all four stepping modes are supported (see the README's support matrix).
"""

from .storage import (
    PARTICLE_FIELDS,
    ParticlesConfig,
    all_particles,
    block_box,
    concat_particles,
    empty_particles,
    find_leaf,
    num_particles,
    particles_nbytes,
    register_particles,
    seed_particles,
    sort_by_id,
    take,
    total_particles,
)
from .advect import advect_block_batch
from .balance import particle_block_weight, particle_proxy_weight
from .redistribute import apply_domain_boundary, redistribute_particles

__all__ = [
    "PARTICLE_FIELDS",
    "ParticlesConfig",
    "all_particles",
    "block_box",
    "concat_particles",
    "empty_particles",
    "find_leaf",
    "num_particles",
    "particles_nbytes",
    "register_particles",
    "seed_particles",
    "sort_by_id",
    "take",
    "total_particles",
    "advect_block_batch",
    "particle_block_weight",
    "particle_proxy_weight",
    "apply_domain_boundary",
    "redistribute_particles",
]
