"""Meshless per-block particle storage on the block forest (paper §2.5).

The paper's blocks "support the storage of arbitrary data", so the framework
serves "mesh based and meshless methods" — this module exercises that claim
with Lagrangian passive tracers. Every block stores one variable-length
struct-of-arrays particle set::

    Block.data["particles"] = {
        "pos": (N, 3) float64   world-coordinate positions,
        "vel": (N, 3) float64   world-coordinate velocities (diagnostic),
        "id":  (N,)   int64     globally unique, immutable particle ids,
    }

ordered ascending by id (every mutation re-establishes the ordering, so the
arrays are bit-identical for any rank count or stepping mode).

:func:`register_particles` plugs the set into the §2.5 serialization
machinery as one :class:`~repro.core.migration.BlockDataItem`, so **data
migration, checkpoint/restart, and buddy resilience come for free**:

* **move** — the whole set travels unmodified;
* **split** — each particle is routed to the child octant that owns its
  position (mid-plane comparisons partition the set exactly: every particle
  lands in exactly one octant, so refinement conserves the particle count
  even for positions marginally outside the parent's box);
* **merge** — the eight children's sets are concatenated on the target (the
  sender ships its set unmodified; there is no volumetric restriction for
  meshless data) and re-sorted by id.

Unlike mesh fields, particle sets are *ragged*: payload byte accounting goes
through :func:`repro.core.migration.payload_nbytes`, which sizes
dict-of-ndarray payloads exactly — the Table-1 migration-volume numbers stay
truthful with particles in flight. Particle sets are deliberately **not**
arena-backed (``FieldRegistry.fields`` drives the arenas; opaque items
registered through the base ``register()`` stay per-block host data).
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Iterable

import numpy as np

from ..core.blockid import ForestGeometry, parent_id
from ..core.forest import Block, BlockForest
from ..core.migration import BlockDataItem, BlockDataRegistry

__all__ = [
    "PARTICLE_FIELDS",
    "ParticlesConfig",
    "empty_particles",
    "num_particles",
    "take",
    "concat_particles",
    "sort_by_id",
    "particles_nbytes",
    "block_box",
    "octant_index",
    "find_leaf",
    "register_particles",
    "seed_particles",
    "total_particles",
    "all_particles",
]

# canonical SoA layout: name -> (dtype, trailing shape)
PARTICLE_FIELDS: tuple[tuple[str, Any, tuple[int, ...]], ...] = (
    ("pos", np.float64, (3,)),
    ("vel", np.float64, (3,)),
    ("id", np.int64, ()),
)


@dataclass(frozen=True)
class ParticlesConfig:
    """Driver-facing configuration of the Lagrangian tracer layer.

    ``alpha`` feeds the load model ``weight(block) = cells + alpha * N`` (see
    :mod:`repro.particles.balance`); ``boundary`` selects the domain behavior
    of escaping particles (``"reflect"`` matches the cavity's solid walls,
    ``"periodic"`` wraps); ``region`` optionally restricts seeding to a world
    AABB ``(lo, hi)`` so tracers can be clustered (heterogeneous load)."""

    per_block: int = 8
    seed: int = 0
    alpha: float = 0.05
    boundary: str = "reflect"  # | "periodic"
    region: tuple[tuple[float, float, float], tuple[float, float, float]] | None = None


def empty_particles() -> dict[str, np.ndarray]:
    return {
        name: np.empty((0, *shape), dtype=dtype)
        for name, dtype, shape in PARTICLE_FIELDS
    }


def num_particles(p: dict[str, np.ndarray] | None) -> int:
    return 0 if p is None else int(p["id"].shape[0])


def take(p: dict[str, np.ndarray], sel) -> dict[str, np.ndarray]:
    """Subset by boolean mask or index array (copies, order-preserving)."""
    return {k: v[sel] for k, v in p.items()}


def concat_particles(parts: Iterable[dict[str, np.ndarray] | None]) -> dict[str, np.ndarray]:
    parts = [p for p in parts if p is not None]
    if not parts:
        return empty_particles()
    return {
        name: np.concatenate([np.asarray(p[name], dtype=dtype) for p in parts])
        for name, dtype, _shape in PARTICLE_FIELDS
    }


def sort_by_id(p: dict[str, np.ndarray]) -> dict[str, np.ndarray]:
    """Canonical ordering: ascending id. Every mutation path re-sorts, so a
    block's arrays are identical regardless of message arrival order — the
    cross-rank/cross-mode conformance suite compares them at 1e-10."""
    order = np.argsort(p["id"], kind="stable")
    return take(p, order)


def _validated(p: Any) -> dict[str, np.ndarray]:
    """Canonicalize an external payload (checkpoint/resilience restore) to
    the declared dtypes/shapes; raises on structural mismatch."""
    if p is None:
        return empty_particles()
    out: dict[str, np.ndarray] = {}
    n = None
    for name, dtype, shape in PARTICLE_FIELDS:
        if name not in p:  # external input — must survive python -O
            raise ValueError(f"particle payload missing {name!r}")
        arr = np.asarray(p[name], dtype=dtype)
        if arr.shape[1:] != shape:
            raise ValueError(f"particle {name!r}: shape {arr.shape} != (N, {shape})")
        if n is None:
            n = arr.shape[0]
        elif arr.shape[0] != n:
            raise ValueError(f"particle {name!r}: ragged length {arr.shape[0]} != {n}")
        out[name] = arr
    return out


def particles_nbytes(p: dict[str, np.ndarray] | None) -> int:
    return 0 if p is None else sum(v.nbytes for v in p.values())


# -- geometry helpers -------------------------------------------------------------


def block_box(geom: ForestGeometry, bid: int) -> tuple[np.ndarray, np.ndarray]:
    """Block AABB in world units (one root block = unit cube), half-open."""
    box = np.asarray(geom.aabb(bid), dtype=np.float64)
    scale = 1.0 / (1 << geom.max_level)
    return box[:3] * scale, box[3:] * scale


def octant_index(geom: ForestGeometry, bid: int, pos: np.ndarray) -> np.ndarray:
    """Child octant owning each position: mid-plane comparisons (>= -> upper
    half), so the eight masks partition ANY position set exactly."""
    lo, hi = block_box(geom, bid)
    mid = 0.5 * (lo + hi)
    up = pos >= mid  # (N, 3) bool
    return (
        up[:, 0].astype(np.int64)
        | (up[:, 1].astype(np.int64) << 1)
        | (up[:, 2].astype(np.int64) << 2)
    )


def find_leaf(geom: ForestGeometry, leaves: dict[int, Any], pos) -> int | None:
    """The leaf block containing a world position, or None outside the
    domain. O(max_level) id arithmetic — used by the periodic-wrap routing
    fallback and by tests as the containment oracle."""
    full = 1 << geom.max_level
    fx, fy, fz = (int(np.floor(float(c) * full)) for c in pos)
    rx, ry, rz = fx // full, fy // full, fz // full
    gx, gy, gz = geom.root_grid
    if not (0 <= rx < gx and 0 <= ry < gy and 0 <= rz < gz):
        return None
    bid = geom.id_from_coords(
        geom.max_level, fx - rx * full, fy - ry * full, fz - rz * full,
        geom.root_index(rx, ry, rz),
    )
    while bid.bit_length() > geom.root_bits:
        if bid in leaves:
            return bid
        bid = parent_id(bid)
    return None


# -- §2.5 registration -------------------------------------------------------------


def register_particles(
    registry: BlockDataRegistry,
    geom: ForestGeometry,
    name: str = "particles",
) -> str:
    """Register the particle set as one block-data item: the six migration
    callbacks (and through them checkpoint encode/decode and resilience
    snapshot/restore) are derived here. Works on any registry — typed
    :class:`~repro.core.fields.FieldRegistry` included, where the set stays
    out of the arenas (it has no per-cell mesh layout to pack)."""

    def ser_move(d: Any, _blk: Block) -> dict[str, np.ndarray]:
        return d if d is not None else empty_particles()

    def des_move(p: Any, _blk: Block) -> dict[str, np.ndarray]:
        return _validated(p)

    def ser_split(d: Any, blk: Block, o: int) -> dict[str, np.ndarray]:
        if num_particles(d) == 0:
            return empty_particles()
        return take(d, octant_index(geom, blk.bid, d["pos"]) == o)

    def des_split(p: Any, _blk: Block) -> dict[str, np.ndarray]:
        return _validated(p)

    def ser_merge(d: Any, _blk: Block) -> dict[str, np.ndarray]:
        # meshless merge: the fine set travels unmodified (no restriction)
        return d if d is not None else empty_particles()

    def des_merge(parts: dict[int, Any], _blk: Block) -> dict[str, np.ndarray]:
        return sort_by_id(concat_particles(parts[o] for o in sorted(parts)))

    registry.register(
        name,
        BlockDataItem(
            serialize_move=ser_move,
            deserialize_move=des_move,
            serialize_split=ser_split,
            deserialize_split=des_split,
            serialize_merge=ser_merge,
            deserialize_merge=des_merge,
        ),
    )
    return name


# -- seeding & whole-forest queries -------------------------------------------------


def seed_particles(
    forest: BlockForest,
    geom: ForestGeometry,
    *,
    per_block: int,
    seed: int = 0,
    region: tuple | None = None,
    name: str = "particles",
) -> int:
    """Seed ``per_block`` tracers uniformly into every block (optionally only
    where the block intersects the world AABB ``region``, drawn inside the
    intersection — the clustering hook for heterogeneous-load scenarios).

    Ids are assigned along ascending bid and the per-block RNG streams are
    keyed by ``(seed, bid)``, so seeding is identical for any rank count.
    Returns the total number of particles seeded."""
    total = 0
    for blk in sorted(forest.all_blocks(), key=lambda b: b.bid):
        lo, hi = block_box(geom, blk.bid)
        if region is not None:
            lo = np.maximum(lo, np.asarray(region[0], dtype=np.float64))
            hi = np.minimum(hi, np.asarray(region[1], dtype=np.float64))
        n = per_block if np.all(hi > lo) else 0
        if n:
            rng = np.random.default_rng([seed, blk.bid])
            pos = lo + rng.random((n, 3)) * (hi - lo)
            ids = np.arange(total, total + n, dtype=np.int64)
            blk.data[name] = {
                "pos": pos,
                "vel": np.zeros((n, 3), dtype=np.float64),
                "id": ids,
            }
        else:
            blk.data[name] = empty_particles()
        total += n
    return total


def total_particles(forest: BlockForest, name: str = "particles") -> int:
    return sum(num_particles(b.data.get(name)) for b in forest.all_blocks())


def all_particles(forest: BlockForest, name: str = "particles") -> dict[str, np.ndarray]:
    """Whole-forest particle state sorted by id (verification/diagnostics)."""
    return sort_by_id(
        concat_particles(b.data.get(name) for b in forest.all_blocks())
    )
