"""Cross-block / cross-rank particle redistribution over the Comm fabric.

After advection some particles sit outside their block's AABB. Redistribution
(run once per coarse step) applies the domain boundary condition, then routes
every escaped particle to the leaf block containing its new position:

* **intra-rank** moves are direct host-side deliveries;
* **cross-rank** moves travel as point-to-point messages over the same
  :class:`~repro.core.comm.Comm` fabric the sharded halo exchange uses — all
  particles from rank *i* to rank *j* are batched into **one message per
  neighboring rank pair** per step, with exact byte accounting
  (:func:`~repro.core.migration.payload_nbytes` sizes the ragged SoA payloads
  honestly), delivered in a single exchange round.

Because one coarse step moves a tracer by at most ``max|u| / n`` world units
(far less than a block side), the containing leaf is always *adjacent* to the
source block, so routing needs only the block's own neighbor list — the
paper's next-neighbor communication property holds for particle traffic too.
The one exception is a periodic wrap across the domain, where the target sits
on the far side: those few particles are routed through a global leaf lookup
(``find_leaf``); a production mesh with periodic topology would instead carry
periodic adjacency and stay next-neighbor.

Domain boundaries:

* ``"reflect"`` — mirror the position at the wall and flip the velocity
  component (matches the cavity's solid walls and lid);
* ``"periodic"`` — wrap positions modulo the domain extent.

Both then clamp positions into the half-open domain box so every particle is
contained in exactly one leaf.
"""

from __future__ import annotations

import numpy as np

from ..core.blockid import ForestGeometry
from ..core.comm import BYTES_BLOCK_ID, Comm
from ..core.forest import BlockForest
from ..core.migration import payload_nbytes

from .storage import (
    block_box,
    concat_particles,
    empty_particles,
    find_leaf,
    num_particles,
    sort_by_id,
    take,
)

__all__ = ["apply_domain_boundary", "redistribute_particles"]


def apply_domain_boundary(
    pos: np.ndarray,
    vel: np.ndarray,
    hi_dom: np.ndarray,
    boundary: str,
) -> tuple[np.ndarray, np.ndarray]:
    """Map positions back into the half-open domain box [0, hi_dom).

    One reflection per side suffices: a coarse step moves a tracer far less
    than the domain extent. Returned arrays are fresh copies."""
    pos = np.array(pos)
    vel = np.array(vel)
    if boundary == "periodic":
        pos = np.mod(pos, hi_dom)
    elif boundary == "reflect":
        for d in range(3):
            below = pos[:, d] < 0.0
            pos[below, d] = -pos[below, d]
            vel[below, d] = -vel[below, d]
            above = pos[:, d] > hi_dom[d]
            pos[above, d] = 2.0 * hi_dom[d] - pos[above, d]
            vel[above, d] = -vel[above, d]
    else:
        raise ValueError(f"unknown boundary {boundary!r}")
    # half-open containment: a position exactly on the upper face belongs to
    # no leaf — nudge it to the last representable interior coordinate
    np.minimum(pos, np.nextafter(hi_dom, 0.0), out=pos)
    np.maximum(pos, 0.0, out=pos)
    return pos, vel


def redistribute_particles(
    forest: BlockForest,
    geom: ForestGeometry,
    comm: Comm,
    *,
    boundary: str = "reflect",
    name: str = "particles",
) -> tuple[int, int]:
    """Route escaped particles to their containing leaf block/rank.

    Returns ``(moved, cross_rank_bytes)``: the number of particles that
    changed blocks and the p2p payload bytes that crossed rank boundaries
    (zero when every move was intra-rank — then no exchange round is spent,
    mirroring the sharded halo's no-traffic fast path)."""
    R = forest.nranks
    hi_dom = np.asarray(geom.root_grid, dtype=np.float64)
    deliveries: list[list[tuple[int, dict[str, np.ndarray]]]] = [[] for _ in range(R)]
    sends: dict[tuple[int, int], list[tuple[int, dict[str, np.ndarray]]]] = {}
    leaves: dict[int, int] | None = None  # bid -> owner, built lazily (periodic)
    moved = 0
    for r in range(R):
        local = forest.local_blocks(r)
        for bid in sorted(local):
            blk = local[bid]
            p = blk.data.get(name)
            if num_particles(p) == 0:
                continue
            lo, hi = block_box(geom, bid)
            # hot-path skip: everything still in-box needs no boundary
            # handling (the domain boundary is unreachable from inside the
            # block box) and no rewrite — interior blocks cost nothing
            if bool(np.all((p["pos"] >= lo) & (p["pos"] < hi))):
                continue
            pos, vel = apply_domain_boundary(p["pos"], p["vel"], hi_dom, boundary)
            inside = np.all((pos >= lo) & (pos < hi), axis=1)
            updated = {"pos": pos, "vel": vel, "id": p["id"]}
            if bool(inside.all()):
                blk.data[name] = updated
                continue
            # assign each leaver to the adjacent leaf containing it
            target = np.full(pos.shape[0], -1, dtype=np.int64)
            owner_of: dict[int, int] = {}
            unresolved = ~inside
            for nbid in sorted(blk.neighbors):
                if not unresolved.any():
                    break
                nlo, nhi = block_box(geom, nbid)
                m = unresolved & np.all((pos >= nlo) & (pos < nhi), axis=1)
                if m.any():
                    target[m] = nbid
                    owner_of[nbid] = blk.neighbors[nbid]
                    unresolved &= ~m
            if unresolved.any():
                # periodic wrap: the containing leaf is across the domain —
                # not a neighbor. Route via the global leaf map (simulated
                # fabric; real periodic meshes carry periodic adjacency).
                if boundary == "periodic":
                    if leaves is None:
                        leaves = {b.bid: b.owner for b in forest.all_blocks()}
                    for i in np.flatnonzero(unresolved):
                        t = find_leaf(geom, leaves, pos[i])
                        assert t is not None, f"particle {p['id'][i]} left the domain"
                        target[i] = t
                        owner_of[t] = leaves[t]
                    unresolved[:] = False
                else:
                    ids = p["id"][unresolved]
                    raise AssertionError(
                        f"particles {ids[:8].tolist()} of block {bid:#x} moved "
                        "beyond the neighbor shell in one step (CFL violated?)"
                    )
            blk.data[name] = take(updated, inside)
            for nbid in np.unique(target[target >= 0]):
                nbid = int(nbid)
                m = target == nbid
                payload = take(updated, m)
                moved += int(m.sum())
                dst = owner_of[nbid]
                if dst == r:
                    deliveries[r].append((nbid, payload))
                else:
                    sends.setdefault((r, dst), []).append((nbid, payload))
    cross_bytes = 0
    if sends:
        for (src, dst), items in sorted(sends.items()):
            nbytes = sum(BYTES_BLOCK_ID + payload_nbytes(pl) for _b, pl in items)
            cross_bytes += nbytes
            comm.send(src, dst, "part", items, nbytes=nbytes)
        inbox = comm.exchange()
        for dst, msgs in inbox.items():
            for _tag, items in msgs:
                deliveries[dst].extend(items)
    for r in range(R):
        local = forest.local_blocks(r)
        for bid, payload in deliveries[r]:
            blk = local[bid]
            blk.data[name] = sort_by_id(
                concat_particles([blk.data.get(name) or empty_particles(), payload])
            )
    return moved, cross_bytes
