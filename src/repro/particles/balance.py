"""Particle-aware load model for dynamic balancing (cf. Nanda et al. 2025).

With mesh-only LBM every block costs the same (paper §3.2) and the balancers
only ever see ``weight = 1.0``. Tracers break that: a block's work is its
cell count plus a per-particle advection/redistribution cost, so the load
model becomes::

    weight(block) = nx*ny*nz + alpha * num_particles(block)

Two hooks plug this into the AMR pipeline:

* :func:`particle_block_weight` — a
  :data:`~repro.core.pipeline.BlockWeightFn` evaluated on actual blocks;
  the pipeline reevaluates it before every balancing cycle and again after
  migration, so refined/coarsened/migrated blocks always carry weights
  derived from their actual particle content;
* :func:`particle_proxy_weight` — a :data:`~repro.core.proxy.ProxyWeightFn`
  for the in-cycle estimates: keeps are exact, split children count the
  particles in their octant exactly (mid-plane partition of the parent's
  set), merges estimate the octet as 8x the designated sibling's count (the
  other seven live on other ranks; the post-migration reevaluation replaces
  the estimate with the exact merged count).
"""

from __future__ import annotations

import math

from ..core.blockid import octant_of
from ..core.forest import Block
from ..core.pipeline import BlockWeightFn
from ..core.proxy import ProxyWeightFn

from .storage import num_particles, octant_index

__all__ = ["particle_block_weight", "particle_proxy_weight"]


def particle_block_weight(
    cells: tuple[int, int, int],
    alpha: float,
    name: str = "particles",
) -> BlockWeightFn:
    ncells = float(math.prod(cells))

    def weight(blk: Block) -> float:
        return ncells + alpha * num_particles(blk.data.get(name))

    return weight


def particle_proxy_weight(
    geom,
    cells: tuple[int, int, int],
    alpha: float,
    name: str = "particles",
) -> ProxyWeightFn:
    ncells = float(math.prod(cells))

    def weight(old: Block, kind: str, new_bid: int) -> float:
        p = old.data.get(name)
        n = num_particles(p)
        if kind == "split" and n:
            o = octant_of(new_bid)
            n = int((octant_index(geom, old.bid, p["pos"]) == o).sum())
        elif kind == "merge":
            n = 8 * n  # estimate: only the designated sibling is visible
        return ncells + alpha * n

    return weight
