"""Jitted Lagrangian advection: trilinear velocity interpolation + RK2.

One kernel call advects all particles of one (level, block-batch) group: it
gathers the PDF stack's eight surrounding cells per particle, forms the
macroscopic velocity per corner, trilinearly blends, takes an RK2 midpoint
sample, and returns the end-of-step lattice velocity per particle. Positions
are integrated on the host in float64.

**Cross-batch determinism.** The sharded path batches per rank while the
host modes batch a whole level, so the same particle must produce bitwise
identical results under different batch shapes. All reductions are therefore
written as *fixed-order chained adds* (the Q-sum over 19 populations and the
8-corner trilinear blend are unrolled) — XLA does not reassociate explicit
float adds, the same property the compiled ghost plan relies on for its
host==device bitwise guarantee. Everything per-particle is elementwise or a
gather, so batch shape cannot influence a particle's arithmetic.

**Units.** World space: one root block = unit cube. A level-l block spans
``2**-l`` per axis with ``n`` cells, and substeps ``2**l`` times per coarse
step, so a lattice velocity ``u`` (cells/substep) is a world displacement of
``u * 2**l * h_l = u / n`` per coarse step — *level-independent*. In the
kernel's own (ghosted cell-index) coordinates the midpoint offset is
``0.5 * dt * 2**l * u`` cells. With one ghost layer, cell centers span
``[-g+0.5, n+g-0.5]``, so trilinear interpolation is defined everywhere in
the block and midpoint excursions are clamped to that hull.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from ..core.forest import Block

from .storage import block_box, num_particles

__all__ = ["advect_block_batch", "gather_batch", "scatter_batch"]


def _next_pow2(n: int) -> int:
    return 1 << max(3, (n - 1).bit_length())


@functools.lru_cache(maxsize=None)
def _kernel(Q: int, c_bytes: bytes):
    """Build the jitted advection kernel for one lattice (closed-over c)."""
    c = np.frombuffer(c_bytes, dtype=np.float32).reshape(Q, 3)

    def sample(pdf, mask, slot, xi):
        """Fluid-masked macroscopic velocity at positions ``xi`` (ghosted
        cell-center coordinates), trilinear over the 8 surrounding cells."""
        dims = pdf.shape[-3:]
        i0 = [
            jnp.clip(jnp.floor(xi[:, d]).astype(jnp.int32), 0, dims[d] - 2)
            for d in range(3)
        ]
        t = [jnp.clip(xi[:, d] - i0[d].astype(xi.dtype), 0.0, 1.0) for d in range(3)]
        out = None
        for dx in (0, 1):
            for dy in (0, 1):
                for dz in (0, 1):
                    ix, iy, iz = i0[0] + dx, i0[1] + dy, i0[2] + dz
                    f = pdf[slot, :, ix, iy, iz]  # (N, Q) corner populations
                    # fixed-order chained Q-sums (no reassociation)
                    rho = f[:, 0]
                    for q in range(1, Q):
                        rho = rho + f[:, q]
                    u = []
                    for d in range(3):
                        m = f[:, 0] * c[0, d]
                        for q in range(1, Q):
                            m = m + f[:, q] * c[q, d]
                        u.append(m / jnp.maximum(rho, 1e-12))
                    fluid = (mask[slot, ix, iy, iz] == 0).astype(xi.dtype)
                    w = (
                        (t[0] if dx else 1.0 - t[0])
                        * (t[1] if dy else 1.0 - t[1])
                        * (t[2] if dz else 1.0 - t[2])
                    ) * fluid
                    term = jnp.stack([w * u[d] for d in range(3)], axis=1)
                    out = term if out is None else out + term  # canonical order
        return out  # (N, 3) lattice velocity

    @jax.jit
    def advect(pdf, mask, xi, slot, step_cells, dt):
        """RK2 midpoint: returns the end-of-step lattice velocity (N, 3)."""
        u1 = sample(pdf, mask, slot, xi)
        xi_mid = xi + (0.5 * dt) * step_cells * u1
        return sample(pdf, mask, slot, xi_mid)

    return advect


def gather_batch(
    blocks: list[Block],
    slots: dict[int, int],
    name: str = "particles",
) -> tuple[np.ndarray, np.ndarray, list[tuple[Block, int]]]:
    """Concatenate the particle positions of a block batch (ascending bid)
    into one (N, 3) array with a per-particle buffer-slot index. Returns
    ``(pos, slot, layout)`` where ``layout`` records per-block counts for
    :func:`scatter_batch`."""
    blocks = sorted(blocks, key=lambda b: b.bid)
    pos_parts, slot_parts, layout = [], [], []
    for b in blocks:
        p = b.data.get(name)
        n = num_particles(p)
        layout.append((b, n))
        if n:
            pos_parts.append(p["pos"])
            slot_parts.append(np.full(n, slots[b.bid], dtype=np.int32))
    if not pos_parts:
        return np.empty((0, 3)), np.empty((0,), np.int32), layout
    return np.concatenate(pos_parts), np.concatenate(slot_parts), layout


def scatter_batch(
    layout: list[tuple[Block, int]],
    pos: np.ndarray,
    vel: np.ndarray,
    name: str = "particles",
) -> None:
    """Write advected positions/velocities back per block (same order that
    :func:`gather_batch` concatenated them in)."""
    off = 0
    for b, n in layout:
        if n:
            p = b.data[name]
            b.data[name] = {"pos": pos[off : off + n], "vel": vel[off : off + n], "id": p["id"]}
            off += n


def advect_block_batch(
    pdf: np.ndarray,
    mask: np.ndarray,
    lattice,
    geom,
    blocks: list[Block],
    slots: dict[int, int],
    *,
    level: int,
    cells: tuple[int, int, int],
    ghost: int,
    dt: float = 1.0,
    name: str = "particles",
) -> int:
    """Advect all particles of a block batch against its (B, Q, X, Y, Z) PDF
    stack (numpy or device-resident jax array) for one coarse step.

    ``slots`` maps bid -> stack slot (arena slot index, or position in an
    ad-hoc restack). Positions integrate on the host in float64 from the
    kernel's float32 velocities; the particle's stored ``vel`` is the
    end-of-step world velocity. Returns the number of particles advected."""
    pos, slot, layout = gather_batch(blocks, slots, name)
    n = pos.shape[0]
    if n == 0:
        return 0
    ncells = np.asarray(cells, dtype=np.float64)
    lo_of = np.zeros((max(slots[b.bid] for b, _n in layout) + 1, 3))
    for b, _cnt in layout:
        lo_of[slots[b.bid]] = block_box(geom, b.bid)[0]
    h = (2.0 ** -level) / ncells  # world cell size per axis on this level
    # ghosted cell-center coordinates (f64 on host, f32 into the kernel):
    xi64 = (pos - lo_of[slot]) / h - 0.5 + ghost
    # pad to a pow2 length so jit specializations stay bounded
    npad = _next_pow2(n)
    xi = np.full((npad, 3), float(ghost), dtype=np.float32)
    xi[:n] = xi64.astype(np.float32)
    slot_pad = np.zeros(npad, dtype=np.int32)
    slot_pad[:n] = slot
    c32 = np.ascontiguousarray(lattice.c, dtype=np.float32)
    kern = _kernel(lattice.Q, c32.tobytes())
    u = kern(
        jnp.asarray(pdf),
        jnp.asarray(mask),
        jnp.asarray(xi),
        jnp.asarray(slot_pad),
        jnp.float32(2.0**level),
        jnp.float32(dt),
    )
    u = np.asarray(u[:n]).astype(np.float64)
    vel_world = u / ncells  # per coarse time unit, level-independent
    scatter_batch(layout, pos + dt * vel_world, vel_world, name)
    return n
