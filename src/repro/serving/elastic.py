"""Elastic ranks: resize a live simulation onto a different rank count.

The resize is the checkpoint/restart protocol run in memory (paper §4.1,
`core/checkpoint.py`): materialize host views, move-serialize every block
through the registry codec (:func:`~repro.core.checkpoint.snapshot_payloads`),
rebuild the forest onto the new rank count with the standard Morton
contiguous partition (:func:`~repro.core.checkpoint.rebuild_forest`), rebuild
the engine's per-rank storage (`RankArenas` re-adopt), and optionally run one
forced balance cycle with the simulation's own configured balancer so
ownership reflects the new pool. Pass ``checkpoint_dir`` to route the
snapshot through the on-disk files instead — the durable variant for
shrinking after a real capacity loss.

Bitwise contract: the codec round-trips every registered field — pdf
*including ghost layers* and the mask — unchanged, and the sharded data
planes are rank-count invariant (the same per-block kernel math and the same
exchange values regardless of which rank owns a block), so a resized run
continues bitwise-identically to a fixed-rank reference.

The control-plane half — deciding *when* and *how much* to resize — is the
straggler/shrink planning ported from the seed ``train/elastic.py`` sketch:
EWMA step-time monitoring per rank, capacity-weighted bucket reassignment,
and a shrink plan for surviving hosts. It is self-contained here (greedy LPT
assignment by default, any ``assign(weights, nranks)`` callable accepted,
e.g. ``repro.train.data.diffusion_assign_buckets``).
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from pathlib import Path
from typing import TYPE_CHECKING, Callable

import numpy as np

from ..core.checkpoint import (
    load_checkpoint,
    rebuild_forest,
    save_checkpoint,
    snapshot_payloads,
)
from ..telemetry import get_tracer

_TR = get_tracer()

if TYPE_CHECKING:  # pragma: no cover - typing only
    from ..lbm.driver import AMRLBM

__all__ = [
    "ElasticPlan",
    "ResizeReport",
    "StragglerMonitor",
    "greedy_assign_buckets",
    "plan_shrink",
    "resize_ranks",
]


# ---------------------------------------------------------------------------
# data-plane resize
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ResizeReport:
    """What a :func:`resize_ranks` call did."""

    old_nranks: int
    new_nranks: int
    nblocks: int
    via_disk: bool
    rebalanced: bool
    seconds: float


def resize_ranks(
    sim: "AMRLBM",
    new_nranks: int,
    *,
    rebalance: bool = True,
    checkpoint_dir: str | Path | None = None,
) -> ResizeReport:
    """Restore a live simulation onto ``new_nranks`` ranks mid-run.

    Composes the existing subsystems end to end: registry-codec snapshot →
    Morton redistribution onto the new rank count → fresh comm fabric and
    stepping engine → optional forced balance cycle with the simulation's
    configured balancer. Works for every stepping mode (the snapshot goes
    through materialized host views); physics continues bitwise-identically.

    With ``checkpoint_dir`` the snapshot round-trips through the on-disk
    checkpoint files (topology.json + per-rank payload pickles) instead of
    staying in memory — same protocol, durable variant.
    """
    from ..lbm.engines import make_engine  # local: avoid serving<->lbm cycle

    old_nranks = sim.cfg.nranks
    with _TR.stage("resize", cat="serving", old=old_nranks,
                   new=new_nranks) as sp:
        sim.materialize_host()  # codec reads host views
        if checkpoint_dir is not None:
            save_checkpoint(sim.forest, sim.registry, checkpoint_dir)
            forest = load_checkpoint(checkpoint_dir, sim.registry, new_nranks)
        else:
            entries = [
                {"bid": b.bid, "level": b.level, "weight": b.weight}
                for b in sim.forest.all_blocks()
            ]
            payloads = snapshot_payloads(sim.forest, sim.registry)
            forest = rebuild_forest(
                sim.geom, entries, payloads, sim.registry, new_nranks
            )
        sim.cfg = dataclasses.replace(sim.cfg, nranks=new_nranks)
        # preserve the fabric type (device_sharded runs on a DeviceComm)
        sim.comm = type(sim.comm)(new_nranks)
        sim.forest = forest
        # fresh engine: per-rank storage is sized by cfg.nranks at
        # construction, so rebuilding it is the rebind (mask travels through
        # the codec — no refresh needed, and the restored pdf ghosts stay
        # exactly as serialized)
        sim.engine = make_engine(sim)
        sim.engine.adopt(sim.forest)
        sim.engine.sync_caches()
        rebalanced = False
        if rebalance and new_nranks > 1:
            sim.forest, report = sim.pipeline.run_cycle(
                sim.forest, sim.comm, None, force_rebalance=True
            )
            if report.executed:
                rebalanced = True
                sim.engine.adopt(sim.forest)
                sim.engine.sync_caches()
    return ResizeReport(
        old_nranks=old_nranks,
        new_nranks=new_nranks,
        nblocks=len(list(sim.forest.all_blocks())),
        via_disk=checkpoint_dir is not None,
        rebalanced=rebalanced,
        seconds=sp.seconds,
    )


# ---------------------------------------------------------------------------
# control plane: straggler monitoring + shrink planning
# (ported from the seed train/elastic.py sketch; self-contained assignment)
# ---------------------------------------------------------------------------


def greedy_assign_buckets(
    bucket_weights: list[float], nranks: int
) -> tuple[list[int], int]:
    """LPT greedy: heaviest bucket to the least-loaded rank. Same contract as
    ``repro.train.data.diffusion_assign_buckets`` (assignment, iterations) so
    the two are interchangeable as ``assign`` callables."""
    n = len(bucket_weights)
    if n == 0:
        return [], 0
    order = sorted(range(n), key=lambda i: -bucket_weights[i])
    loads = np.zeros(max(1, nranks))
    assign = [0] * n
    for i in order:
        r = int(np.argmin(loads))
        assign[i] = r
        loads[r] += bucket_weights[i]
    return assign, 1


@dataclass
class StragglerMonitor:
    """EWMA step times per host; emits capacity weights for the balancer.

    Slow hosts are mitigated with the *same* machinery that balances AMR
    blocks: their measured throughput scales their share of the weighted
    buckets, realized by splitting each host into round(capacity*K) virtual
    ranks and running a standard bucket assignment over them.
    """

    n_hosts: int
    alpha: float = 0.2
    ewma: np.ndarray = field(default=None)  # type: ignore[assignment]

    def __post_init__(self):
        if self.ewma is None:
            self.ewma = np.zeros(self.n_hosts)

    def observe(self, step_times: np.ndarray) -> None:
        t = np.asarray(step_times, dtype=np.float64)
        self.ewma = np.where(
            self.ewma == 0, t, self.alpha * t + (1 - self.alpha) * self.ewma
        )

    def capacities(self) -> np.ndarray:
        """Relative per-host throughput (1.0 = median host)."""
        med = np.median(self.ewma[self.ewma > 0]) if (self.ewma > 0).any() else 1.0
        caps = np.where(self.ewma > 0, med / np.maximum(self.ewma, 1e-9), 1.0)
        return np.clip(caps, 0.1, 2.0)

    def rebalance_buckets(
        self,
        bucket_weights: list[float],
        *,
        assign: Callable[[list[float], int], tuple[list[int], int]] | None = None,
    ) -> tuple[list[int], int]:
        """Assign buckets ~proportionally to measured capacity: slow hosts
        present as fewer virtual ranks, so the assignment hands them less."""
        K = 4
        assign = assign or greedy_assign_buckets
        caps = self.capacities()
        virt_of_host = [max(1, int(round(c * K))) for c in caps]
        n_virt = sum(virt_of_host)
        assign_v, iters = assign(bucket_weights, n_virt)
        host_of_virt = []
        for h, nv in enumerate(virt_of_host):
            host_of_virt.extend([h] * nv)
        return [host_of_virt[v] for v in assign_v], iters


@dataclass(frozen=True)
class ElasticPlan:
    new_hosts: list[int]  # surviving host ids
    mesh_shape: tuple[int, ...]  # new (data, model) shape
    resume_step: int
    bucket_assignment: list[int]


def plan_shrink(
    *,
    alive_hosts: list[int],
    chips_per_host: int,
    model_parallel: int,
    last_checkpoint_step: int,
    bucket_tokens: list[float],
    assign: Callable[[list[float], int], tuple[list[int], int]] | None = None,
) -> ElasticPlan:
    """Plan resumption after losing hosts: keep the model axis intact (TP
    groups must not straddle dead hosts) and shrink the data axis; data
    buckets are rebalanced over the survivors."""
    assign = assign or greedy_assign_buckets
    total_chips = len(alive_hosts) * chips_per_host
    assert total_chips % model_parallel == 0, (
        f"{total_chips} chips cannot keep model_parallel={model_parallel}"
    )
    data = total_chips // model_parallel
    assignment, _ = assign(bucket_tokens, len(alive_hosts))
    return ElasticPlan(
        new_hosts=sorted(alive_hosts),
        mesh_shape=(data, model_parallel),
        resume_step=last_checkpoint_step,
        bucket_assignment=assignment,
    )
