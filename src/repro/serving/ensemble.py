"""Batched ensemble execution: many independent runs, one compiled program.

The fused superstep (PR 4/5) is keyed only by forest topology and activity
pattern — nothing in the compiled program depends on *which* simulation is
running beyond its relaxation rate and wall velocity. An :class:`Ensemble`
exploits that: it takes N member simulations that share one forest topology,
stacks their per-level arena buffers into ``(M, B, Q, X, Y, Z)`` device
arrays, and advances all of them with a single
:func:`~repro.kernels.lbm_collide.ops.make_ensemble_superstep` program whose
per-member physics parameters (tau, lid velocity) enter as batched operands.
One compile per (topology, activity-pattern) key serves every member — the
classic inference-serving amortization.

Bitwise contract: the batched program runs the identical op sequence as each
member's solo fused run (coefficients are pre-rounded to the field dtype on
the host by ``collision_coeffs`` either way), so member ``i``'s physical
(interior-cell) state matches an independent single run with the same
parameters bitwise. The ghost ring is excluded from the contract: post-step
ghost values are dead (the next substep's fill overwrites them before any
read) and XLA:CPU rounds them context-dependently under the member ``vmap``.

Divergence: members own their control planes (criterion, AMR pipeline), so
refinement decisions may diverge. :meth:`Ensemble.adapt` materializes the
batch back into the member arenas, runs each member's own AMR cycle, and
regroups by the new topology keys — a diverging member simply splits into
its own (possibly singleton) ensemble, and every group keeps sharing the
same :class:`EnsembleProgramCache`.
"""

from __future__ import annotations

from typing import TYPE_CHECKING, Callable

import jax
import jax.numpy as jnp
import numpy as np

from ..core.pipeline import StageStats
from ..telemetry import get_tracer
from ..kernels.lbm_collide.ops import make_ensemble_superstep, resolve_donate
from ..kernels.lbm_collide.ref import collision_coeffs
from ..lbm.halo import compile_ghost_plan
from ..lbm.lattice import omega_for_level

if TYPE_CHECKING:  # pragma: no cover - typing only
    from ..core.forest import BlockForest
    from ..lbm.driver import AMRLBM, LidDrivenCavityConfig

__all__ = [
    "Ensemble",
    "EnsembleProgramCache",
    "ensemble_compat_key",
    "is_batchable",
    "topology_key",
]

_TR = get_tracer()


def topology_key(forest: "BlockForest") -> tuple[tuple[int, int], ...]:
    """Canonical (bid, level) signature of a forest's block structure.

    Ownership is deliberately excluded: the single-arena ghost plans and the
    slot layout depend only on which blocks exist, so two members balanced
    onto different owners still share one compiled program.
    """
    return tuple(sorted((b.bid, b.level) for b in forest.all_blocks()))


def ensemble_compat_key(cfg: "LidDrivenCavityConfig") -> tuple:
    """Members are batchable together iff this key matches.

    Everything that shapes the compiled program or the masks is included;
    the per-member physics (``omega``, ``u_lid``) and control-plane knobs
    (refinement thresholds, balancer, nranks) are deliberately excluded —
    the former batch as operands, the latter only steer AMR decisions and
    are handled by divergence splits.
    """
    return (
        tuple(cfg.root_grid),
        tuple(cfg.cells_per_block),
        cfg.ghost,
        cfg.max_level,
        cfg.collision,
        cfg.kernel_backend,
        id(cfg.obstacle_fn) if cfg.obstacle_fn is not None else None,
    )


def is_batchable(cfg: "LidDrivenCavityConfig") -> bool:
    """Can a job with this config join an ensemble batch?

    Requires a host-arena data plane (``arena``/``fused`` members expose the
    single global :class:`LevelArena` the batch stacks), the ``ref`` kernel
    (the batched program is built from the pure-jnp coefficient kernel, so
    solo references must run the same math), and no Lagrangian particles
    (tracer advection is per-member host work that would serialize the batch
    anyway). A job that resolves to donated pdf buffers on XLA:CPU is also
    excluded: the batched program never donates, and CPU codegen under
    aliasing drifts by one ulp, so such a job's solo fused run would not
    match its batched slice bitwise (on accelerators donation is
    value-preserving and stays batchable).
    """
    donation_drifts = (
        resolve_donate(getattr(cfg, "donate_pdfs", None))
        and jax.default_backend() == "cpu"
    )
    return (
        cfg.stepping_mode in ("arena", "fused")
        and cfg.kernel_backend == "ref"
        and cfg.particles is None
        and not donation_drifts
    )


class EnsembleProgramCache:
    """Compiled ensemble supersteps keyed by (compat, topology, levels).

    Shared across every ensemble of a service so a divergence split (or a
    later job with a previously-seen topology) reuses existing programs.
    ``hits``/``misses`` feed the serving counters; the acceptance bar is one
    miss per distinct (topology, activity-pattern) key, total, per batch.
    """

    def __init__(self) -> None:
        self._programs: dict[tuple, Callable] = {}
        self.hits = 0
        self.misses = 0

    def __len__(self) -> int:
        return len(self._programs)

    def get_or_build(self, key: tuple, build: Callable[[], Callable]) -> Callable:
        fn = self._programs.get(key)
        if fn is not None:
            self.hits += 1
            return fn
        self.misses += 1
        fn = self._programs[key] = build()
        return fn

    def hit_rate(self) -> float:
        total = self.hits + self.misses
        return self.hits / total if total else 0.0


class Ensemble:
    """A batch of member simulations sharing one forest topology.

    The members keep their full control planes (forest, AMR pipeline,
    criterion, diagnostics); the ensemble owns only the batched data plane —
    a device-resident ``(M, B, Q, X, Y, Z)`` pdf stack per level, refreshed
    lazily against the member arena versions and flushed back by
    :meth:`materialize` (mirroring :class:`~repro.core.fields.DeviceResidency`
    semantics, one batch axis up).
    """

    def __init__(
        self,
        members: list["AMRLBM"],
        *,
        programs: EnsembleProgramCache | None = None,
    ) -> None:
        assert members, "an ensemble needs at least one member"
        self.members = list(members)
        self.programs = programs if programs is not None else EnsembleProgramCache()
        m0 = self.members[0]
        self.compat = ensemble_compat_key(m0.cfg)
        topo0 = topology_key(m0.forest)
        for m in self.members:
            assert is_batchable(m.cfg), (
                f"job config not batchable (mode={m.cfg.stepping_mode!r}, "
                f"backend={m.cfg.kernel_backend!r}, particles={m.cfg.particles})"
            )
            assert ensemble_compat_key(m.cfg) == self.compat, "incompatible member"
            assert topology_key(m.forest) == topo0, "members must share a topology"
        self.stats = StageStats()
        self.h2d_bytes = 0
        self.d2h_bytes = 0
        # batched device state: level -> (M, B, Q, X, Y, Z)
        self._dev: dict[int, jax.Array] = {}
        self._dev_levels: tuple[int, ...] | None = None
        self._dev_versions: tuple[int, ...] | None = None
        self._dev_newer = False
        # per-(levels) stacked member coefficients (members are fixed for the
        # ensemble's lifetime, so only the level set can vary the coeffs)
        self._coeffs: dict[tuple[int, ...], dict] = {}

    # -- introspection ---------------------------------------------------------
    @property
    def size(self) -> int:
        return len(self.members)

    def topology(self) -> tuple[tuple[int, int], ...]:
        return topology_key(self.members[0].forest)

    # -- compiled program ------------------------------------------------------
    def _program(self) -> tuple[Callable, tuple[int, ...]]:
        m0 = self.members[0]
        arena = m0.engine.arena
        levels = tuple(sorted(m0.forest.levels_in_use()))
        key = (self.compat, self.topology(), levels)

        def build() -> Callable:
            with _TR.span("build:ensemble_superstep", cat="compile",
                          members=len(self.members)):
                return self._build_program(levels)

        return self.programs.get_or_build(key, build), levels

    def _build_program(self, levels: tuple[int, ...]) -> Callable:
        m0 = self.members[0]
        arena = m0.engine.arena
        lmax = levels[-1]
        slots = {l: arena.slots(l) for l in levels}
        plans = {
            p: compile_ghost_plan(
                m0.forest,
                m0.fields,
                slots,
                fields=("pdf",),
                levels={l for l in levels if l >= lmax - p},
            )
            for p in range(lmax + 1)
        }
        masks = {l: arena.buffer(l, "mask") for l in levels}
        for m in self.members[1:]:  # shared-mask precondition
            for l in levels:
                assert np.array_equal(
                    m.engine.arena.buffer(l, "mask"), masks[l]
                ), "ensemble members must share cell-type masks"
        return make_ensemble_superstep(
            levels=levels,
            plans=plans,
            masks=masks,
            lattice=m0.spec.lattice,
            collision=m0.cfg.collision,
        )

    def _member_coeffs(self, levels: tuple[int, ...]) -> dict:
        """level -> stacked per-member collision coefficients (leading M)."""
        cached = self._coeffs.get(levels)
        if cached is not None:
            return cached
        dtype = self.members[0].engine.arena.buffer(levels[0], "pdf").dtype.type
        out: dict[int, dict] = {}
        for l in levels:
            per = [
                collision_coeffs(
                    omega_for_level(m.cfg.omega, l),
                    lattice=m.spec.lattice,
                    u_wall=m.cfg.u_lid,
                    collision=m.cfg.collision,
                    dtype=dtype,
                )
                for m in self.members
            ]
            out[l] = {
                k: jnp.asarray(np.stack([c[k] for c in per])) for k in per[0]
            }
        self._coeffs[levels] = out
        return out

    # -- batched residency -----------------------------------------------------
    def _fetch(self, levels: tuple[int, ...]) -> None:
        """Upload the member pdf stacks unless the device copy is current."""
        versions = tuple(m.engine.arena.version for m in self.members)
        if self._dev_levels == levels and self._dev_versions == versions:
            return
        assert not self._dev_newer, (
            "member arenas rebound while the batched device state was newer; "
            "materialize() before adapting members externally"
        )
        self._dev = {}
        for l in levels:
            stack = np.stack(
                [m.engine.arena.buffer(l, "pdf") for m in self.members]
            )
            self._dev[l] = jnp.asarray(stack)
            self.h2d_bytes += stack.nbytes
        self._dev_levels = levels
        self._dev_versions = versions

    def materialize(self) -> None:
        """Flush device-newer batched state back into the member arenas so
        every member's ``Block.data`` views are current (diagnostics, AMR,
        checkpointing all read host views)."""
        if not self._dev_newer:
            return
        versions = tuple(m.engine.arena.version for m in self.members)
        assert versions == self._dev_versions, (
            "member arenas rebound under unmaterialized device state"
        )
        for l in self._dev_levels:
            # repro: host-ok(explicit materialize contract, accounted in d2h_bytes)
            host = np.asarray(self._dev[l])
            self.d2h_bytes += host.nbytes
            for i, m in enumerate(self.members):
                np.copyto(m.engine.arena.buffer(l, "pdf"), host[i])
        self._dev_newer = False

    # -- stepping --------------------------------------------------------------
    def advance(self, coarse_steps: int) -> None:
        """Advance every member by ``coarse_steps`` with one program call per
        coarse step for the whole batch."""
        if coarse_steps <= 0:
            return
        fn, levels = self._program()
        with _TR.stage("ensemble.advance", cat="serving",
                       members=len(self.members),
                       coarse_steps=coarse_steps) as sp:
            self._fetch(levels)
            coeffs = self._member_coeffs(levels)
            pdfs = tuple(self._dev[l] for l in levels)
            for _ in range(coarse_steps):
                pdfs = fn(pdfs, coeffs)
            # repro: host-ok(timing fence: advance latency is the serving metric)
            jax.block_until_ready(pdfs)
            for l, arr in zip(levels, pdfs):
                self._dev[l] = arr
        self._dev_newer = True
        nsub = 1 << levels[-1]
        self.stats.add(
            StageStats(
                seconds=sp.seconds,
                exchange_rounds=coarse_steps * nsub,
            )
        )
        for m in self.members:
            m.coarse_step += coarse_steps

    # -- AMR / divergence ------------------------------------------------------
    def adapt(self, force_rebalance: bool = False) -> list["Ensemble"]:
        """Run each member's own AMR cycle, then regroup by topology.

        Returns the list of ensembles to continue with: ``[self]`` when every
        member still shares one topology (the common case — device state is
        reused when no member's storage rebound), or fresh ensembles per
        topology group after a divergence split. All groups keep sharing
        ``self.programs``, so a split costs at most one new program per new
        (topology, activity-pattern) key.
        """
        self.materialize()
        for m in self.members:
            m.adapt(force_rebalance=force_rebalance)
        groups: dict[tuple, list["AMRLBM"]] = {}
        for m in self.members:
            groups.setdefault(topology_key(m.forest), []).append(m)
        if len(groups) == 1:
            return [self]
        return [
            Ensemble(g, programs=self.programs) for g in groups.values()
        ]
