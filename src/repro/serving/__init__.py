"""Serving layer: many concurrent simulations on one shared pool.

Three pieces (see ARCHITECTURE.md "Serving layer"):

* :mod:`~repro.serving.ensemble` — batched ensemble execution: members
  sharing one forest topology advance under a single compiled superstep
  ``vmap``-ped over a leading member axis, with per-member physics as
  batched operands and divergence splits at AMR events.
* :mod:`~repro.serving.service` — the job driver: submit/poll/stream API,
  compatibility grouping, round-robin chunk scheduling, streamed
  diagnostics + registry-codec checkpoints, serving counters.
* :mod:`~repro.serving.elastic` — elastic ranks: mid-run rank-count resize
  via the in-memory checkpoint protocol, plus the straggler/shrink control
  plane ported from the seed training sketch.
"""

from .elastic import (
    ElasticPlan,
    ResizeReport,
    StragglerMonitor,
    greedy_assign_buckets,
    plan_shrink,
    resize_ranks,
)
from .ensemble import (
    Ensemble,
    EnsembleProgramCache,
    ensemble_compat_key,
    is_batchable,
    topology_key,
)
from .service import Job, JobSpec, SimulationService

__all__ = [
    "ElasticPlan",
    "Ensemble",
    "EnsembleProgramCache",
    "Job",
    "JobSpec",
    "ResizeReport",
    "SimulationService",
    "StragglerMonitor",
    "ensemble_compat_key",
    "greedy_assign_buckets",
    "is_batchable",
    "plan_shrink",
    "resize_ranks",
    "topology_key",
]
