"""Job driver: a submit/poll/stream service over batched ensembles.

:class:`SimulationService` is the bridge from "one big run" to "many
concurrent runs on a shared pool": callers submit scenario configs as
:class:`JobSpec`\\ s; the service groups compatible jobs into
:class:`~repro.serving.ensemble.Ensemble` batches (same compat key, same
forest topology, same AMR cadence), advances all groups round-robin in
``amr_interval``-sized chunks, runs each member's own AMR cycle at the
cadence boundaries (divergence splits regroup automatically), and streams
per-member diagnostics and registry-codec checkpoints back out.

Execution is cooperative and deterministic: :meth:`SimulationService.run`
(or iterating :meth:`stream`) drives rounds on the caller's thread — there
is no background concurrency, matching the repo's simulated-rank style.

Counters: ``data_stats["serving"]`` holds the data-plane wall time
(``stage``), per-job latency/throughput counters (``jobs``), and the shared
compile-cache statistics (``compile``) — the serving analogue of the
driver's per-stage ``data_stats``. :meth:`summary` flattens the same
numbers for the benchmark harness.
"""

from __future__ import annotations

from dataclasses import dataclass, field as dc_field
from pathlib import Path
from typing import TYPE_CHECKING, Iterator

from ..core.checkpoint import save_checkpoint
from ..core.pipeline import StageStats
from ..telemetry import get_tracer
from .elastic import ResizeReport, resize_ranks
from .ensemble import (
    Ensemble,
    EnsembleProgramCache,
    ensemble_compat_key,
    is_batchable,
    topology_key,
)

if TYPE_CHECKING:  # pragma: no cover - typing only
    from ..lbm.driver import AMRLBM, LidDrivenCavityConfig

__all__ = ["JobSpec", "Job", "SimulationService"]

_TR = get_tracer()


@dataclass(frozen=True)
class JobSpec:
    """One serving request: a scenario config plus run/streaming cadence."""

    config: "LidDrivenCavityConfig"
    coarse_steps: int
    amr_interval: int = 4
    checkpoint_every: int = 0  # coarse steps between streamed checkpoints (0 = off)
    collect_diagnostics: bool = True
    name: str = ""


@dataclass
class Job:
    """Live state of a submitted job (owned by the service)."""

    job_id: int
    spec: JobSpec
    sim: "AMRLBM"
    status: str = "pending"  # pending | running | done
    submitted_at: float = 0.0
    started_at: float | None = None
    finished_at: float | None = None
    events: list[dict] = dc_field(default_factory=list)
    checkpoints: list[str] = dc_field(default_factory=list)

    @property
    def step(self) -> int:
        return self.sim.coarse_step

    @property
    def remaining(self) -> int:
        return max(0, self.spec.coarse_steps - self.sim.coarse_step)


@dataclass
class _Group:
    """A scheduling unit: one ensemble batch or one solo job."""

    jobs: list[Job]
    ensemble: Ensemble | None  # None -> solo execution via the job's own engine


class SimulationService:
    """Group, batch, and round-robin many independent simulations.

    ``batching=False`` turns the grouping off (every job runs solo through
    its own stepping engine) — the sequential baseline the serving benchmark
    compares against.
    """

    def __init__(
        self,
        *,
        batching: bool = True,
        checkpoint_root: str | Path | None = None,
    ) -> None:
        self.batching = batching
        self.checkpoint_root = (
            Path(checkpoint_root) if checkpoint_root is not None else None
        )
        self.programs = EnsembleProgramCache()
        self.jobs: dict[int, Job] = {}
        self._next_id = 0
        self._pending: list[Job] = []
        self._groups: list[_Group] = []
        self.counters = {
            "jobs_submitted": 0,
            "jobs_completed": 0,
            "rounds": 0,
            "batched_steps": 0,  # member-coarse-steps advanced in ensembles
            "solo_steps": 0,
            "ensembles_formed": 0,  # groups formed with >= 2 members
            "divergence_splits": 0,  # extra groups created by AMR divergence
        }
        self.data_stats: dict[str, dict] = {
            "serving": {"stage": StageStats(), "jobs": {}, "compile": {}}
        }

    # -- submit / poll / stream ------------------------------------------------
    def submit(self, spec: JobSpec) -> int:
        """Accept a scenario config; returns the job id (grouping is lazy —
        compatible jobs submitted before the next round batch together)."""
        from ..lbm.driver import AMRLBM  # deferred: serving is importable alone

        job = Job(
            job_id=self._next_id,
            spec=spec,
            sim=AMRLBM(spec.config),
            submitted_at=_TR.clock(),
        )
        self._next_id += 1
        self.jobs[job.job_id] = job
        self._pending.append(job)
        self.counters["jobs_submitted"] += 1
        _TR.instant("job.submit", cat="serving", job=job.job_id)
        self._refresh_job_stats(job)
        return job.job_id

    def poll(self, job_id: int) -> dict:
        """Current status + latency/throughput counters for one job."""
        job = self.jobs[job_id]
        self._refresh_job_stats(job)
        return dict(self.data_stats["serving"]["jobs"][job_id])

    def stream(self, job_id: int) -> Iterator[dict]:
        """Yield a job's event records (diagnostics, checkpoints, resizes,
        completion) in order, driving service rounds from the consumer's
        loop until the job completes."""
        job = self.jobs[job_id]
        cursor = 0
        while True:
            while cursor < len(job.events):
                yield job.events[cursor]
                cursor += 1
            if job.status == "done":
                return
            progressed = self.run_round()
            if not progressed and cursor >= len(job.events):
                return  # nothing left to run and nothing new to drain

    def resize(self, job_id: int, new_nranks: int, **kw) -> ResizeReport:
        """Elastically resize a *solo* job's rank pool mid-run (batched
        members share one data plane — split or finish them first)."""
        job = self.jobs[job_id]
        for g in self._groups:
            if job in g.jobs:
                assert g.ensemble is None, "cannot resize a batched member"
        report = resize_ranks(job.sim, new_nranks, **kw)
        job.events.append(
            {
                "type": "resize",
                "step": job.step,
                "old_nranks": report.old_nranks,
                "new_nranks": report.new_nranks,
                "rebalanced": report.rebalanced,
            }
        )
        return report

    # -- scheduling ------------------------------------------------------------
    def _form_groups(self) -> None:
        """Drain pending jobs into scheduling groups: batchable jobs with the
        same (compat, topology, cadence) key share one ensemble."""
        if not self._pending:
            return
        batches: dict[tuple, list[Job]] = {}
        for job in self._pending:
            if self.batching and is_batchable(job.spec.config):
                key = (
                    ensemble_compat_key(job.spec.config),
                    topology_key(job.sim.forest),
                    job.spec.amr_interval,
                    job.step,  # lockstep cadence within a group
                )
                batches.setdefault(key, []).append(job)
            else:
                self._groups.append(_Group(jobs=[job], ensemble=None))
        for jobs in batches.values():
            ens = Ensemble([j.sim for j in jobs], programs=self.programs)
            self._groups.append(_Group(jobs=jobs, ensemble=ens))
            if len(jobs) >= 2:
                self.counters["ensembles_formed"] += 1
                _TR.instant(
                    "ensemble.form", cat="serving", members=len(jobs)
                )
        self._pending = []

    def run_round(self) -> bool:
        """Advance every active group by one ``amr_interval`` chunk (or to
        its members' finish line, whichever is nearer). Returns whether any
        work remains."""
        self._form_groups()
        if not self._groups:
            return False
        with _TR.stage("serving.round", cat="serving",
                       groups=len(self._groups)) as sp:
            next_groups: list[_Group] = []
            for g in self._groups:
                next_groups.extend(self._run_group_chunk(g))
        self._groups = next_groups
        self.counters["rounds"] += 1
        serving = self.data_stats["serving"]
        serving["stage"].add(StageStats(seconds=sp.seconds))
        serving["compile"] = {
            "hits": self.programs.hits,
            "misses": self.programs.misses,
            "hit_rate": self.programs.hit_rate(),
            "programs": len(self.programs),
        }
        return bool(self._groups or self._pending)

    def run(self) -> None:
        """Drive rounds until every submitted job completes."""
        while self.run_round():
            pass

    # -- internals -------------------------------------------------------------
    def _run_group_chunk(self, g: _Group) -> list[_Group]:
        now = _TR.clock()
        for j in g.jobs:
            if j.started_at is None:
                j.started_at = now
                j.status = "running"
        interval = g.jobs[0].spec.amr_interval
        chunk = min([interval] + [j.remaining for j in g.jobs])
        assert chunk >= 1, "finished jobs must leave their group"
        job_of_sim = {id(j.sim): j for j in g.jobs}

        if g.ensemble is not None:
            g.ensemble.advance(chunk)
            self.counters["batched_steps"] += chunk * len(g.jobs)
            at_boundary = g.jobs[0].step % interval == 0
            if at_boundary:
                parts = g.ensemble.adapt()  # materializes, may split
                if len(parts) > 1:
                    self.counters["divergence_splits"] += len(parts) - 1
                    _TR.instant(
                        "ensemble.split", cat="serving", parts=len(parts)
                    )
            else:
                g.ensemble.materialize()  # diagnostics/checkpoints read host
                parts = [g.ensemble]
            self.data_stats["serving"]["stage"].add(
                StageStats(exchange_rounds=g.ensemble.stats.exchange_rounds)
            )
            g.ensemble.stats = StageStats()  # consumed into the service stage
        else:
            job = g.jobs[0]
            job.sim.advance(chunk)
            self.counters["solo_steps"] += chunk
            if job.step % interval == 0:
                job.sim.adapt()
            parts = [None]

        for j in g.jobs:
            self._emit_events(j)
        finished = {id(j.sim) for j in g.jobs if j.remaining == 0}
        for j in g.jobs:
            if id(j.sim) in finished:
                self._finish(j)

        out: list[_Group] = []
        for part in parts:
            members = g.jobs if part is None else [
                job_of_sim[id(m)] for m in part.members
            ]
            alive = [j for j in members if id(j.sim) not in finished]
            if not alive:
                continue
            if part is None:
                out.append(_Group(jobs=alive, ensemble=None))
            elif len(alive) == len(part.members):
                out.append(_Group(jobs=alive, ensemble=part))
            else:  # membership shrank: rebatch survivors on the shared cache
                out.append(
                    _Group(
                        jobs=alive,
                        ensemble=Ensemble(
                            [j.sim for j in alive], programs=self.programs
                        ),
                    )
                )
        return out

    def _emit_events(self, job: Job) -> None:
        if job.spec.collect_diagnostics:
            job.events.append(
                {
                    "type": "diagnostics",
                    "step": job.step,
                    "mass": job.sim.total_mass(),
                    "max_velocity": job.sim.max_velocity(),
                    "amr_cycles": job.sim.amr_cycles,
                }
            )
        every = job.spec.checkpoint_every
        if every and self.checkpoint_root is not None and job.step % every == 0:
            path = self.checkpoint_root / f"job_{job.job_id:04d}" / (
                f"step_{job.step:06d}"
            )
            job.sim.materialize_host()
            save_checkpoint(job.sim.forest, job.sim.registry, path)
            job.checkpoints.append(str(path))
            job.events.append(
                {"type": "checkpoint", "step": job.step, "path": str(path)}
            )
        self._refresh_job_stats(job)

    def _finish(self, job: Job) -> None:
        job.status = "done"
        job.finished_at = _TR.clock()
        self.counters["jobs_completed"] += 1
        job.events.append({"type": "done", "step": job.step})
        _TR.instant("job.done", cat="serving", job=job.job_id, step=job.step)
        self._refresh_job_stats(job)

    def _refresh_job_stats(self, job: Job) -> None:
        now = job.finished_at if job.finished_at is not None else _TR.clock()
        run_s = (now - job.started_at) if job.started_at is not None else 0.0
        self.data_stats["serving"]["jobs"][job.job_id] = {
            "status": job.status,
            "step": job.step,
            "coarse_steps": job.spec.coarse_steps,
            "latency_s": now - job.submitted_at,
            "run_s": run_s,
            "steps_per_s": (job.step / run_s) if run_s > 0 else 0.0,
            "checkpoints": len(job.checkpoints),
        }

    def summary(self) -> dict:
        """Flat counter view for benchmarks and logs."""
        serving = self.data_stats["serving"]
        wall = serving["stage"].seconds
        done = self.counters["jobs_completed"]
        return {
            **self.counters,
            "wall_s": wall,
            "jobs_per_s": (done / wall) if wall > 0 else 0.0,
            "compile_hits": self.programs.hits,
            "compile_misses": self.programs.misses,
            "compile_cache_hit_rate": self.programs.hit_rate(),
            "programs": len(self.programs),
            "jobs": {k: dict(v) for k, v in serving["jobs"].items()},
        }
