"""Stepping engines: one class per ``stepping_mode``, one shared contract.

:class:`~repro.lbm.driver.AMRLBM` owns the control plane (forest, AMR
pipeline, criterion, ``Comm``, particles, diagnostics); a :class:`StepEngine`
owns the data plane for one stepping mode — storage (none / ``LevelArena`` /
``RankArenas``), the kernel steppers, cached exchange plans, device masks and
compiled programs, and the per-mode advance loop. The engines replace the
five-way ``if/elif`` dispatch that had accumulated in the driver over PRs
1–4: every mode now implements the same small surface and inherits the
invalidation / residency / statistics hooks instead of duplicating them.

Engine surface (see ARCHITECTURE.md for the mode matrix):

* :meth:`StepEngine.advance` — run whole coarse steps (substep cycle
  included); attributes wall time and traffic to ``sim.data_stats``.
* :meth:`StepEngine.exchange_ghosts` — host-visible ghost refresh, used by
  the advance loop of the host modes and by mode-independent consumers
  (post-AMR refresh, pre-advection refresh for particles).
* :meth:`StepEngine.adopt` — rebind storage after a forest topology change.
* :meth:`StepEngine.sync_caches` / :meth:`StepEngine.masks_refreshed` —
  invalidation by mechanism: caches are keyed to the storage version (every
  ``adopt`` bumps it), so no call site can replay a stale plan, mask, or
  compiled program.
* :meth:`StepEngine.materialize_host` — flush device-newer state so every
  ``Block.data`` view is current (no-op for host-resident modes).
* :meth:`StepEngine.particle_batches` — the advection batch source for the
  Lagrangian tracer layer (host modes batch a level, sharded modes batch per
  rank so a rank's tracers read only the rank's own memory).

Mode notes: ``restack`` is the seed baseline (re-stack every substep);
``arena`` steps persistent per-level SoA buffers in place; ``fused``
compiles the whole coarse step into one device program over a
:class:`~repro.core.fields.DeviceResidency`; ``sharded`` runs the rank-
partitioned data plane with host-side p2p halo messages; ``fused_sharded``
composes the last two — per-rank device residency, compiled rank-halo plans
(:func:`~repro.lbm.halo.compile_rank_halo_plan`), and per-rank jitted
substep programs, with host contact only at AMR events.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import TYPE_CHECKING, Callable

import jax
import jax.numpy as jnp
import numpy as np

from ..core import LevelArena, RankArenas
from ..core.pipeline import StageStats
from ..telemetry import get_tracer
from ..kernels.lbm_collide.ops import (
    boundary_slot_sets,
    make_arena_stream_collide,
    make_device_superstep,
    make_fused_superstep,
    make_halo_stream_collide,
    make_rank_absorb,
    make_rank_absorb_split,
    make_rank_emit,
    make_stream_collide,
)
from .grid import CellType
from .halo import (
    compile_ghost_plan,
    compile_rank_halo_plan,
    fill_ghost_layers,
    fill_ghost_layers_sharded,
    padded_block_counts,
    schedule_ppermute_rounds,
    verify_padded_plan,
)
from .lattice import omega_for_level

if TYPE_CHECKING:  # pragma: no cover - typing only
    from ..core.forest import Block, BlockForest
    from .driver import AMRLBM

__all__ = ["StepEngine", "ENGINES", "make_engine"]

ENGINES: dict[str, type["StepEngine"]] = {}

_TR = get_tracer()


def make_engine(sim: "AMRLBM") -> "StepEngine":
    mode = sim.cfg.stepping_mode
    assert mode in ENGINES, (mode, sorted(ENGINES))
    return ENGINES[mode](sim)


def _register(cls: type["StepEngine"]) -> type["StepEngine"]:
    ENGINES[cls.mode] = cls
    return cls


class StepEngine:
    """Shared state and hooks; subclasses fill in storage + the step loop."""

    mode: str = ""

    def __init__(self, sim: "AMRLBM") -> None:
        self.sim = sim
        self.cfg = sim.cfg
        self.arena: LevelArena | None = None
        self.arenas: RankArenas | None = None
        self._steppers: dict[int, Callable] = {}
        self._fused_steppers: dict[int, Callable] = {}
        # device mask cache; keys: level (arena) or (level, ranks) (sharded)
        self._mask_dev: dict = {}
        # ghost-exchange plans keyed by active level set; valid between arena
        # adoptions (restack rebinds arrays per substep, so no caching there)
        self._halo_plans: dict | None = {}
        self._cache_version = -1  # last storage version the caches were built for

    # -- kernel steppers -------------------------------------------------------
    stepper_factory = staticmethod(make_arena_stream_collide)

    def _stepper_kwargs(self, level: int) -> dict:
        cfg = self.cfg
        return dict(
            omega=omega_for_level(cfg.omega, level),
            lattice=self.sim.spec.lattice,
            u_wall=cfg.u_lid,
            collision=cfg.collision,
            backend=cfg.kernel_backend,
            # None resolves at program-build time: interpret iff the active
            # backend is CPU (a real TPU/GPU lowers the kernel natively)
            interpret=getattr(cfg, "kernel_interpret", None),
        )

    def _halo_stepper_factory(self, masks_host: dict[int, np.ndarray]):
        """``(level, dst_slot, dst_cell) -> step(f, vals)`` builder for the
        halo-in-tile superstep paths; ``masks_host`` are host mask stacks
        (copied — the factory's premask constants must not alias mutable
        arena storage)."""

        def factory(level: int, dst_slot: np.ndarray, dst_cell: np.ndarray):
            return make_halo_stream_collide(
                dst_slot, dst_cell, mask=masks_host[level], **self._stepper_kwargs(level)
            )

        return factory

    def _stepper(self, level: int) -> Callable:
        if level not in self._steppers:
            self._steppers[level] = self.stepper_factory(**self._stepper_kwargs(level))
        return self._steppers[level]

    def _fused_stepper(self, level: int) -> Callable:
        """Pure ``step(f, mask) -> f`` for compiled programs (traced inline
        by the device-resident engines; cached separately from the in-place
        arena steppers)."""
        if level not in self._fused_steppers:
            self._fused_steppers[level] = make_stream_collide(
                **self._stepper_kwargs(level)
            )
        return self._fused_steppers[level]

    # -- storage / invalidation ------------------------------------------------
    def storage_version(self) -> int:
        if self.arena is not None:
            return self.arena.version
        if self.arenas is not None:
            return self.arenas.version
        return -1

    def adopt(self, forest: "BlockForest") -> None:
        """Rebind storage after a topology change (AMR event, restore)."""
        if self.arena is not None:
            self.arena.adopt(forest)
        if self.arenas is not None:
            self.arenas.adopt(forest)

    def sync_caches(self) -> None:
        """Drop device masks and ghost plans if the arena(s) rebound storage
        since they were built — invalidation by mechanism, not by call-site
        discipline (any future adopt site is covered automatically)."""
        version = self.storage_version()
        if self._halo_plans is not None and self._cache_version != version:
            self._mask_dev.clear()
            self._halo_plans.clear()
            self._cache_version = version

    def masks_refreshed(self) -> None:
        """Host-side mask write happened: device mask copies are stale."""
        self._mask_dev.clear()

    def materialize_host(self) -> None:
        """Flush device-newer buffers so ``Block.data`` views are current
        (no-op in the host-resident modes)."""

    # -- ghost exchange --------------------------------------------------------
    def exchange_ghosts(self, active: set[int] | None = None) -> None:
        """Refresh pdf ghost layers for the active levels, attributing the
        wall time (and, for the sharded engines, the p2p traffic the exchange
        put on the fabric) to the "halo" data-plane stage."""
        self.sync_caches()  # an external adopt() must not replay stale plans
        # arena storage is versioned (adopt bumps it on every topology /
        # storage change), so the plan-cache guard is an O(1) token compare
        # instead of the default O(blocks) binding scan
        token = self.storage_version() if self._halo_plans is not None else None
        with _TR.stage("halo", cat="stage") as sp:
            fill_ghost_layers(
                self.sim.forest,
                self.sim.fields,
                fields=("pdf",),
                levels=active,
                plan_cache=self._halo_plans,
                cache_token=token,
            )
        self.sim.data_stats["halo"].add(StageStats(seconds=sp.seconds))

    # -- stepping --------------------------------------------------------------
    def advance(self, coarse_steps: int) -> None:
        """Host substep loop: per-level activity sets, ghost exchange, then
        stream+collide finest-first (device engines override wholesale)."""
        sim = self.sim
        levels = sim.forest.levels_in_use()
        lmax = max(levels)
        for _ in range(coarse_steps):
            for s in range(2**lmax):
                active = {l for l in levels if s % (2 ** (lmax - l)) == 0}
                self.exchange_ghosts(active)
                with _TR.stage("step", cat="stage") as sp:
                    for l in sorted(active, reverse=True):
                        self.step_level(l)
                sim.data_stats["step"].add(StageStats(seconds=sp.seconds))

    def step_level(self, level: int) -> None:  # pragma: no cover - abstract
        raise NotImplementedError

    # -- Lagrangian tracers ----------------------------------------------------
    def particle_batches(
        self, level: int
    ) -> list[tuple[np.ndarray, np.ndarray, dict[int, int], list["Block"]]]:
        """(pdf stack, mask stack, bid->slot, blocks) advection groups for one
        level (host views must be current — the driver materializes first)."""
        arena = self.arena
        pdf = arena.buffer(level, "pdf")
        if pdf is None or pdf.shape[0] == 0:
            return []
        blocks = [b for b in self.sim.forest.all_blocks() if b.level == level]
        return [(pdf, arena.buffer(level, "mask"), arena.slots(level), blocks)]


@_register
class RestackEngine(StepEngine):
    """The seed data plane: stack every block of a level into a fresh array
    each substep and copy the results back out — the benchmark baseline."""

    mode = "restack"
    stepper_factory = staticmethod(make_stream_collide)

    def __init__(self, sim: "AMRLBM") -> None:
        super().__init__(sim)
        self._halo_plans = None  # arrays rebind every substep: nothing to cache

    def step_level(self, level: int) -> None:
        blocks = [b for b in self.sim.forest.all_blocks() if b.level == level]
        if not blocks:
            return
        f = jnp.asarray(np.stack([b.data["pdf"] for b in blocks]))
        m = jnp.asarray(np.stack([b.data["mask"] for b in blocks]))
        f = self._stepper(level)(f, m)
        # repro: host-ok(restack-mode copy-out contract: results return to host block storage)
        out = np.array(f)
        for i, b in enumerate(blocks):
            b.data["pdf"] = out[i]

    def particle_batches(self, level: int):
        blocks = sorted(
            (b for b in self.sim.forest.all_blocks() if b.level == level),
            key=lambda b: b.bid,
        )
        if not blocks:
            return []
        pdf = np.stack([b.data["pdf"] for b in blocks])
        mask = np.stack([b.data["mask"] for b in blocks])
        return [(pdf, mask, {b.bid: i for i, b in enumerate(blocks)}, blocks)]


@_register
class ArenaEngine(StepEngine):
    """Persistent per-level SoA buffers stepped in place (host-resident)."""

    mode = "arena"

    def __init__(self, sim: "AMRLBM") -> None:
        super().__init__(sim)
        self.arena = LevelArena(sim.fields)

    def _level_mask(self, level: int) -> jax.Array:
        """Device-resident (B, X, Y, Z) mask stack, cached across substeps."""
        self.sync_caches()
        m = self._mask_dev.get(level)
        if m is None:
            m = jnp.asarray(self.arena.buffer(level, "mask"))
            self._mask_dev[level] = m
        return m

    def step_level(self, level: int) -> None:
        buf = self.arena.buffer(level, "pdf")
        if buf is None or buf.shape[0] == 0:
            return
        # in-place: reads and writes the persistent level buffer directly
        self._stepper(level)(buf, self._level_mask(level))


@_register
class FusedEngine(ArenaEngine):
    """Device-resident single-arena mode: the whole ``2^lmax`` substep cycle
    is one jitted program over the arena's :class:`DeviceResidency`."""

    mode = "fused"

    def __init__(self, sim: "AMRLBM") -> None:
        super().__init__(sim)
        # fused superstep program cache: (arena version, level tuple) -> fn
        self._fused_fn = None
        self._fused_key: tuple | None = None

    def masks_refreshed(self) -> None:
        super().masks_refreshed()
        # host-side write: device mask copies (and the fused program that
        # baked them in) are stale
        self.arena.device().drop(name="mask")
        self._fused_fn = None
        self._fused_key = None

    def materialize_host(self) -> None:
        self.arena.device().flush()

    def _fused_program(self) -> tuple[Callable, tuple[int, ...]]:
        """Get-or-build the jitted superstep for the current forest: compiled
        ghost plans for every activity pattern + per-level steppers + device
        masks, cached until the next AMR event (arena version) or mask
        refresh."""
        forest = self.sim.forest
        levels = tuple(sorted(forest.levels_in_use()))
        key = (self.arena.version, levels)
        if self._fused_fn is not None and self._fused_key == key:
            return self._fused_fn, levels
        with _TR.span("build:fused_superstep", cat="compile",
                      version=self.arena.version):
            lmax = levels[-1]
            slots = {l: self.arena.slots(l) for l in levels}
            plans = {
                p: compile_ghost_plan(
                    forest,
                    self.sim.fields,
                    slots,
                    fields=("pdf",),
                    levels={l for l in levels if l >= lmax - p},
                )
                for p in range(lmax + 1)
            }
            res = self.arena.device()
            # repro: host-ok(mask copy at program build, once per arena version)
            masks_host = {l: np.array(self.arena.buffer(l, "mask")) for l in levels}
            self._fused_fn = make_fused_superstep(
                levels=levels,
                plans=plans,
                steppers={l: self._fused_stepper(l) for l in levels},
                masks={l: res.fetch(l, "mask") for l in levels},
                donate=getattr(self.cfg, "donate_pdfs", None),
                halo_stepper_factory=self._halo_stepper_factory(masks_host),
            )
        self._fused_key = key
        return self._fused_fn, levels

    def advance(self, coarse_steps: int) -> None:
        """Run whole coarse steps on device: one program call each, zero host
        transfers in steady state (uploads only after AMR events / mask
        refreshes; downloads only when diagnostics or the control plane
        materialize host views). The superstep donates its pdf tuple, so
        each call consumes the previous arrays (ping-pong in place) — the
        fresh outputs are stored back into the residency immediately."""
        fn, levels = self._fused_program()
        res = self.arena.device()
        pdfs = tuple(res.fetch(l, "pdf") for l in levels)
        nsub = 1 << levels[-1]
        with _TR.stage("fused", cat="stage", coarse_steps=coarse_steps) as sp:
            for _ in range(coarse_steps):
                pdfs = fn(pdfs)
            # repro: host-ok(timing fence: StageStats seconds must not hide queued device work)
            jax.block_until_ready(pdfs)
            for l, arr in zip(levels, pdfs):
                res.store(l, "pdf", arr)
        self.sim.data_stats["fused"].add(
            StageStats(seconds=sp.seconds, exchange_rounds=coarse_steps * nsub)
        )


@_register
class ShardedEngine(StepEngine):
    """The rank-partitioned host data plane: per-rank arenas, in-place
    intra-rank halo copies, cross-rank faces as batched p2p messages."""

    mode = "sharded"

    def __init__(self, sim: "AMRLBM") -> None:
        super().__init__(sim)
        self.arenas = RankArenas(sim.fields, sim.cfg.nranks)

    def _group_mask(self, level: int, ranks: tuple[int, ...]) -> jax.Array:
        """Device mask for a batched group of rank buffers."""
        self.sync_caches()
        key = (level, ranks)
        m = self._mask_dev.get(key)
        if m is None:
            parts = [self.arenas.buffer(r, level, "mask") for r in ranks]
            m = jnp.asarray(parts[0] if len(parts) == 1 else np.concatenate(parts))
            self._mask_dev[key] = m
        return m

    def exchange_ghosts(self, active: set[int] | None = None) -> None:
        self.sync_caches()
        token = self.storage_version()
        comm = self.sim.comm
        s0 = comm.stats.summary()
        with _TR.stage("halo", cat="stage") as sp:
            fill_ghost_layers_sharded(
                self.sim.forest,
                self.sim.fields,
                comm,
                fields=("pdf",),
                levels=active,
                plan_cache=self._halo_plans,
                cache_token=token,
            )
        self.sim.data_stats["halo"].add(
            StageStats.delta(s0, comm.stats.summary(), sp.seconds)
        )

    def step_level(self, level: int) -> None:
        """One kernel call per rank per level, batched where shapes agree:
        ranks whose level buffers hold the same block count share one call
        (their stacked shapes are identical, so one jit specialization and
        one device round-trip cover the whole group)."""
        per_rank = [
            (r, buf)
            for r in range(self.cfg.nranks)
            if (buf := self.arenas.buffer(r, level, "pdf")) is not None
            and buf.shape[0] > 0
        ]
        by_count: dict[int, list[tuple[int, np.ndarray]]] = {}
        for r, buf in per_rank:
            by_count.setdefault(buf.shape[0], []).append((r, buf))
        stepper = self._stepper(level)
        for nblocks, group in sorted(by_count.items()):
            ranks = tuple(r for r, _ in group)
            mask = self._group_mask(level, ranks)
            if len(group) == 1:
                stepper(group[0][1], mask)  # in-place on the rank's buffer
                continue
            cat = np.concatenate([buf for _, buf in group])
            stepper(cat, mask)
            for i, (_r, buf) in enumerate(group):
                np.copyto(buf, cat[i * nblocks : (i + 1) * nblocks])

    def particle_batches(self, level: int):
        """Per-rank batches over that rank's own buffers, so a rank's tracers
        read only the rank's own memory."""
        out = []
        for r in range(self.cfg.nranks):
            arena = self.arenas.per_rank[r]
            pdf = arena.buffer(level, "pdf")
            if pdf is None or pdf.shape[0] == 0:
                continue
            blocks = [
                b
                for b in self.sim.forest.local_blocks(r).values()
                if b.level == level
            ]
            out.append(
                (pdf, arena.buffer(level, "mask"), arena.slots(level), blocks)
            )
        return out


@dataclass
class _RankPrograms:
    """Compiled per-rank substep programs for one (storage version, level
    set): emit/absorb jitted closures per (activity pattern, rank) plus the
    message routing tables the advance loop feeds the ``Comm`` fabric from."""

    levels: tuple[int, ...]
    nsub: int
    pattern: list[int]
    ranks: tuple[int, ...]
    rank_levels: dict[int, tuple[int, ...]]
    emits: dict[int, dict[int, Callable]] = field(default_factory=dict)
    absorbs: dict[int, dict[int, Callable]] = field(default_factory=dict)
    # interior/boundary split pair (exclusive with absorbs[p][r]): interior
    # steps while the host routes payloads, boundary consumes the messages
    interiors: dict[int, dict[int, Callable]] = field(default_factory=dict)
    boundaries: dict[int, dict[int, Callable]] = field(default_factory=dict)
    sends: dict[int, dict[int, list]] = field(default_factory=dict)
    recvs: dict[int, dict[int, list]] = field(default_factory=dict)
    has_messages: dict[int, bool] = field(default_factory=dict)


@_register
class FusedShardedEngine(ShardedEngine):
    """Device-resident rank-sharded mode: each rank's substep runs as jitted
    programs over its own :class:`DeviceResidency`, and cross-rank halo
    patches travel as device-built per-rank-pair message buffers through
    ``Comm`` — one p2p message per neighboring pair per exchange, zero
    host<->device transfers per substep (host contact only at AMR events).
    """

    mode = "fused_sharded"

    def __init__(self, sim: "AMRLBM") -> None:
        super().__init__(sim)
        self._programs_cache: _RankPrograms | None = None
        self._programs_key: tuple | None = None

    def masks_refreshed(self) -> None:
        super().masks_refreshed()
        for arena in self.arenas.per_rank:
            if arena._residency is not None:
                arena.device().drop(name="mask")
        self._programs_cache = None
        self._programs_key = None

    def materialize_host(self) -> None:
        for arena in self.arenas.per_rank:
            if arena._residency is not None:
                arena.device().flush()

    def _programs(self) -> _RankPrograms:
        forest = self.sim.forest
        levels = tuple(sorted(forest.levels_in_use()))
        key = (self.arenas.version, levels)
        if self._programs_cache is not None and self._programs_key == key:
            return self._programs_cache
        with _TR.span("build:rank_programs", cat="compile",
                      version=self.arenas.version):
            self._programs_cache = self._build_programs(forest, levels)
        self._programs_key = key
        return self._programs_cache

    def _build_programs(self, forest: "BlockForest",
                        levels: tuple[int, ...]) -> _RankPrograms:
        lmax = levels[-1]
        nsub = 1 << lmax
        per_rank = self.arenas.per_rank
        ranks = tuple(r for r in range(self.cfg.nranks) if per_rank[r].levels())
        rank_levels = {r: tuple(per_rank[r].levels()) for r in ranks}
        rank_slots = {
            r: {l: per_rank[r].slots(l) for l in rank_levels[r]} for r in ranks
        }
        # pattern of substep s = trailing zeros of s (s=0 activates everything)
        pattern = [
            lmax if s == 0 else min((s & -s).bit_length() - 1, lmax)
            for s in range(nsub)
        ]
        progs = _RankPrograms(
            levels=levels,
            nsub=nsub,
            pattern=pattern,
            ranks=ranks,
            rank_levels=rank_levels,
        )
        for p in range(lmax + 1):
            active = {l for l in levels if l >= lmax - p}
            plan = compile_rank_halo_plan(
                forest, self.sim.fields, rank_slots, fields=("pdf",), levels=active
            )
            progs.has_messages[p] = bool(plan.messages)
            progs.emits[p] = {}
            progs.absorbs[p] = {}
            progs.interiors[p] = {}
            progs.boundaries[p] = {}
            progs.sends[p] = {}
            progs.recvs[p] = {}
            for r in ranks:
                idx = {l: i for i, l in enumerate(rank_levels[r])}
                res = per_rank[r].device()
                sends = [m for m in plan.messages if m.src_rank == r]
                recvs = [m for m in plan.messages if m.dst_rank == r]
                progs.sends[p][r] = sends
                progs.recvs[p][r] = recvs
                emit = make_rank_emit(sends, idx)
                if emit is not None:
                    progs.emits[p][r] = emit
                local = plan.local.get(r)
                rank_active = active & set(rank_levels[r])
                if not recvs and not rank_active and not (local and local.ops):
                    # the rank is idle in this pattern (e.g. it owns only
                    # coarse blocks and a fine-only substep is running):
                    # don't compile — and don't dispatch — an identity program
                    continue
                steppers = {l: self._fused_stepper(l) for l in rank_levels[r]}
                masks_dev = {l: res.fetch(l, "mask") for l in rank_levels[r]}
                masks_host = {
                    # repro: host-ok(mask copy at program build, once per arena version)
                    l: np.array(per_rank[r].buffer(l, "mask"))
                    for l in rank_levels[r]
                }
                bnd = boundary_slot_sets(
                    recvs, {l: masks_host[l] for l in rank_active}
                )
                n_interior = sum(
                    masks_host[l].shape[0] - len(bnd.get(l, ()))
                    for l in rank_active
                )
                # the split is an accelerator optimization: XLA:CPU compiles
                # the sub-stack stencil with context-dependent rounding (one
                # ulp off the unsplit program), so the CPU default keeps the
                # bitwise-conformant unsplit absorb (override: overlap_split)
                split = getattr(self.cfg, "overlap_split", None)
                if split is None:
                    split = jax.default_backend() != "cpu"
                if split and recvs and n_interior > 0:
                    # boundary blocks wait for inbound payloads; interior
                    # blocks don't — split so the host-side message routing
                    # overlaps the interior stepping dispatched before it
                    progs.interiors[p][r], progs.boundaries[p][r] = (
                        make_rank_absorb_split(
                            recvs,
                            local,
                            idx,
                            steppers=steppers,
                            masks=masks_dev,
                            active_levels=rank_active,
                            donate=getattr(self.cfg, "donate_pdfs", None),
                        )
                    )
                else:
                    progs.absorbs[p][r] = make_rank_absorb(
                        recvs,
                        local,
                        idx,
                        steppers=steppers,
                        masks=masks_dev,
                        active_levels=rank_active,
                        donate=getattr(self.cfg, "donate_pdfs", None),
                        halo_stepper_factory=self._halo_stepper_factory(masks_host),
                    )
        return progs

    def advance(self, coarse_steps: int) -> None:
        """Run whole coarse steps with per-rank device programs: the only
        per-substep host involvement is routing device-resident message
        buffers through ``Comm`` (the fabric sees exactly the same p2p shape
        as the host-sharded mode, with identical byte accounting).

        Dispatch order per substep implements the latency-hiding split:
        every rank's ``emit`` (payload build) and ``interior`` program is
        dispatched *before* the host touches the fabric, so the Python-side
        send/exchange/routing runs while the device is still chewing on
        payload gathers and interior stepping (JAX dispatch is async); only
        the ``boundary``/``absorb`` programs — which consume inbound
        payloads — wait for routing. Emits read the pre-step buffers the
        interior programs then consume by donation; the runtime sequences
        the donated write after the pending reads."""
        progs = self._programs()
        comm = self.sim.comm
        res = {r: self.arenas.per_rank[r].device() for r in progs.ranks}
        pdfs = {
            r: tuple(res[r].fetch(l, "pdf") for l in progs.rank_levels[r])
            for r in progs.ranks
        }
        s0 = comm.stats.summary()
        with _TR.stage("fused", cat="stage", coarse_steps=coarse_steps) as st:
            for _ in range(coarse_steps):
                for s in range(progs.nsub):
                    p = progs.pattern[s]
                    # the route span's `overlapped` flag marks whether this
                    # pattern dispatched interior programs before routing —
                    # the quantity trace_report's overlap efficiency reads
                    overlapped = bool(progs.interiors[p])
                    payloads = []
                    for r in progs.ranks:
                        emit = progs.emits[p].get(r)
                        if emit is not None:
                            with _TR.span("emit", cat="substep", rank=r,
                                          substep=s, pattern=p):
                                payloads.append((r, emit(pdfs[r])))
                    for r in progs.ranks:
                        interior = progs.interiors[p].get(r)
                        if interior is not None:
                            with _TR.span("interior", cat="substep", rank=r,
                                          substep=s, pattern=p):
                                pdfs[r] = interior(pdfs[r])
                    with _TR.span("route", cat="substep", substep=s,
                                  pattern=p, overlapped=overlapped) as rt:
                        nbytes = 0
                        for r, arrs in payloads:
                            for m, arr in zip(progs.sends[p][r], arrs):
                                comm.send(
                                    m.src_rank, m.dst_rank, "halo",
                                    (m.key, arr), nbytes=m.nbytes,
                                )
                                nbytes += m.nbytes
                        by_key = {}
                        if progs.has_messages[p]:
                            for _dst, msgs in comm.exchange().items():
                                for _tag, (mkey, arr) in msgs:
                                    by_key[mkey] = arr
                        rt.set(bytes=nbytes)
                    for r in progs.ranks:
                        boundary = progs.boundaries[p].get(r)
                        if boundary is not None:
                            with _TR.span("absorb", cat="substep", rank=r,
                                          substep=s, pattern=p, split=True):
                                msgs = tuple(
                                    by_key[m.key] for m in progs.recvs[p][r]
                                )
                                pdfs[r] = boundary(pdfs[r], msgs)
                            continue
                        absorb = progs.absorbs[p].get(r)
                        if absorb is None:  # rank is idle in this pattern
                            continue
                        with _TR.span("absorb", cat="substep", rank=r,
                                      substep=s, pattern=p, split=False):
                            msgs = tuple(by_key[m.key] for m in progs.recvs[p][r])
                            pdfs[r] = absorb(pdfs[r], msgs)
            # repro: host-ok(timing fence: StageStats seconds must not hide queued device work)
            jax.block_until_ready([pdfs[r] for r in progs.ranks])
            for r in progs.ranks:
                for l, arr in zip(progs.rank_levels[r], pdfs[r]):
                    res[r].store(l, "pdf", arr)
        stage = StageStats.delta(s0, comm.stats.summary(), st.seconds)
        # report in-program exchange rounds with the same meaning as the
        # fused engine (one logical ghost-exchange round per substep) rather
        # than the Comm superstep count the delta carries — the latter is 0
        # at one rank even though every substep exchanged intra-rank ghosts
        stage.exchange_rounds = coarse_steps * progs.nsub
        self.sim.data_stats["fused"].add(stage)


@dataclass
class _DevicePrograms:
    """One compiled SPMD superstep for a (storage version, level set): the
    shard_map'ed program plus the per-pattern message tables the advance loop
    feeds :meth:`~repro.core.comm.DeviceComm.ppermute` accounting from."""

    levels: tuple[int, ...]
    counts: dict[int, int]
    nsub: int
    pattern: list[int]
    fn: Callable
    messages: dict[int, tuple]
    rounds: dict[int, int]
    pad_bytes: dict[int, int]


@_register
class DeviceShardedEngine(ShardedEngine):
    """Real multi-device rank sharding: one XLA device per rank.

    Where ``fused_sharded`` *simulates* the distributed data plane (per-rank
    programs on one device, payloads routed through the host ``Comm``), this
    mode places each rank's block stacks on its own device via ``shard_map``
    over a 1-D mesh and moves halo payloads with ``jax.lax.ppermute`` inside
    the compiled program — no host involvement per substep at all, not even
    routing. Host devices are provisioned with
    ``XLA_FLAGS=--xla_force_host_platform_device_count=N`` (see
    ``launch/env_preset.sh``); on a real TPU/GPU pod the same program maps
    onto the physical interconnect unchanged.

    Equal-blocks-per-rank padding makes the program SPMD: every level's stack
    is padded to the max per-rank block count with all-WALL masks and
    weight-vector PDFs — an exact fixed point of the stream+collide kernel
    (all-WALL streaming bounces the symmetric weights onto themselves and the
    final fluid blend returns the input), so padded slots are provably dead:
    never read by any halo plan (``verify_padded_plan``), unchanged by every
    step. The ``Comm`` fabric must be a :class:`~repro.core.comm.DeviceComm`
    (the driver wires this) so the in-program ppermute traffic lands in the
    same Table-1 counters as every other mode.
    """

    mode = "device_sharded"

    def __init__(self, sim: "AMRLBM") -> None:
        super().__init__(sim)
        n = self.cfg.nranks
        ndev = jax.device_count()
        if ndev < n:
            raise RuntimeError(
                f"device_sharded needs one XLA device per rank: nranks={n} but "
                f"jax.device_count()={ndev}. Provision host devices with "
                "XLA_FLAGS=--xla_force_host_platform_device_count="
                f"{n} before the first jax import (launch/env_preset.sh does "
                "this), or lower cfg.nranks."
            )
        if not hasattr(sim.comm, "ppermute"):
            raise TypeError(
                "device_sharded requires a DeviceComm fabric so in-program "
                f"ppermute traffic is accounted; got {type(sim.comm).__name__}"
            )
        self.mesh = jax.sharding.Mesh(
            np.array(jax.devices()[:n]),  # repro: host-ok(device handles, not array data)
            ("ranks",),
        )
        self._dev_programs: _DevicePrograms | None = None
        self._dev_programs_key: tuple | None = None
        self._dev_levels: tuple[int, ...] | None = None
        self._dev_pdfs: tuple | None = None
        self._dev_masks: tuple | None = None
        self._dev_version = -1
        self._host_stale = False  # device pdfs newer than the host arenas

    # -- storage / invalidation ------------------------------------------------
    def adopt(self, forest: "BlockForest") -> None:
        assert not self._host_stale, (
            "materialize_host() before adopt: device-resident steps would be "
            "lost rebinding the arenas"
        )
        super().adopt(forest)

    def masks_refreshed(self) -> None:
        super().masks_refreshed()
        self._dev_masks = None

    def materialize_host(self) -> None:
        if not self._host_stale:
            return
        assert self._dev_pdfs is not None and self._dev_levels is not None
        with _TR.span("device:materialize_host", cat="transfer"):
            for i, l in enumerate(self._dev_levels):
                # repro: host-ok(AMR-event download: device-newer pdfs flush to the arenas)
                host = np.asarray(self._dev_pdfs[i])  # (R, Bmax, ...)
                for r in range(self.cfg.nranks):
                    buf = self.arenas.buffer(r, l, "pdf")
                    if buf is not None and buf.shape[0]:
                        np.copyto(buf, host[r, : buf.shape[0]])
        self._host_stale = False

    def exchange_ghosts(self, active: set[int] | None = None) -> None:
        # host-visible ghost refresh (post-AMR, pre-advection): flush device
        # steps first, then run the host-fabric exchange. The device copy's
        # interiors stay current (the exchange only writes ghost cells) and
        # its ghosts are re-exchanged in-program at the next substep 0, so
        # the device state is deliberately NOT invalidated here — same
        # contract as fused_sharded's residency.
        self.materialize_host()
        super().exchange_ghosts(active)

    # -- compiled programs -----------------------------------------------------
    def _programs(self) -> _DevicePrograms:
        forest = self.sim.forest
        levels = tuple(sorted(forest.levels_in_use()))
        key = (self.arenas.version, levels)
        if self._dev_programs is not None and self._dev_programs_key == key:
            return self._dev_programs
        with _TR.span("build:device_programs", cat="compile",
                      version=self.arenas.version):
            self._dev_programs = self._build_programs(forest, levels)
        self._dev_programs_key = key
        return self._dev_programs

    def _build_programs(self, forest: "BlockForest",
                        levels: tuple[int, ...]) -> _DevicePrograms:
        lmax = levels[-1]
        nsub = 1 << lmax
        nranks = self.cfg.nranks
        per_rank = self.arenas.per_rank
        rank_slots = {
            r: {l: per_rank[r].slots(l) for l in per_rank[r].levels()}
            for r in range(nranks)
        }
        counts = padded_block_counts(rank_slots, nranks)
        pattern = [
            lmax if s == 0 else min((s & -s).bit_length() - 1, lmax)
            for s in range(nsub)
        ]
        fs = self.sim.fields.fields["pdf"]
        lead = int(np.prod(fs.shape, dtype=np.int64)) if fs.shape else 1
        itemsize = np.dtype(fs.dtype).itemsize
        plans: dict[int, object] = {}
        schedules: dict[int, tuple] = {}
        messages: dict[int, tuple] = {}
        rounds_n: dict[int, int] = {}
        pad_bytes: dict[int, int] = {}
        for p in range(lmax + 1):
            active = {l for l in levels if l >= lmax - p}
            plan = compile_rank_halo_plan(
                forest, self.sim.fields, rank_slots, fields=("pdf",),
                levels=active,
            )
            bad = verify_padded_plan(plan, rank_slots)
            assert not bad, bad  # no plan index may ever touch a padded slot
            sched = schedule_ppermute_rounds(plan.messages)
            plans[p] = plan
            schedules[p] = sched
            messages[p] = plan.messages
            rounds_n[p] = len(sched)
            pad_bytes[p] = (
                sum(rnd.pad_cells() for rnd in sched) * lead * itemsize
            )
        fn = make_device_superstep(
            mesh=self.mesh,
            levels=levels,
            plans=plans,
            schedules=schedules,
            steppers={l: self._fused_stepper(l) for l in levels},
            donate=getattr(self.cfg, "donate_pdfs", None),
        )
        return _DevicePrograms(
            levels=levels,
            counts=counts,
            nsub=nsub,
            pattern=pattern,
            fn=fn,
            messages=messages,
            rounds=rounds_n,
            pad_bytes=pad_bytes,
        )

    # -- device residency ------------------------------------------------------
    def _ensure_device(self, progs: _DevicePrograms) -> None:
        """Upload the padded global stacks (once per storage version)."""
        version = self.arenas.version
        if self._dev_version != version or self._dev_levels != progs.levels:
            assert not self._host_stale  # adopt() already enforces the flush
            self._dev_pdfs = None
            self._dev_masks = None
        if self._dev_pdfs is not None and self._dev_masks is not None:
            return
        nranks = self.cfg.nranks
        sharding = jax.sharding.NamedSharding(
            self.mesh, jax.sharding.PartitionSpec("ranks")
        )
        lattice = self.sim.spec.lattice
        with _TR.span("device:upload", cat="transfer", version=version):
            if self._dev_pdfs is None:
                stacks = []
                for l in progs.levels:
                    bufs = [self.arenas.buffer(r, l, "pdf") for r in range(nranks)]
                    shape = next(b.shape[1:] for b in bufs if b is not None)
                    dtype = next(b.dtype for b in bufs if b is not None)
                    g = np.empty((nranks, progs.counts[l]) + shape, dtype)
                    # pad slots hold the weight vector — the all-WALL fixed
                    # point of the kernel (see the class docstring)
                    g[:] = np.asarray(  # repro: host-ok(lattice weights are a host constant)
                        lattice.w, dtype=dtype
                    ).reshape((lattice.Q,) + (1,) * 3)
                    for r, b in enumerate(bufs):
                        if b is not None and b.shape[0]:
                            g[r, : b.shape[0]] = b
                    stacks.append(jax.device_put(g, sharding))
                self._dev_pdfs = tuple(stacks)
            if self._dev_masks is None:
                stacks = []
                for l in progs.levels:
                    bufs = [self.arenas.buffer(r, l, "mask") for r in range(nranks)]
                    shape = next(b.shape[1:] for b in bufs if b is not None)
                    dtype = next(b.dtype for b in bufs if b is not None)
                    g = np.full(
                        (nranks, progs.counts[l]) + shape, CellType.WALL, dtype
                    )
                    for r, b in enumerate(bufs):
                        if b is not None and b.shape[0]:
                            g[r, : b.shape[0]] = b
                    stacks.append(jax.device_put(g, sharding))
                self._dev_masks = tuple(stacks)
        self._dev_version = version
        self._dev_levels = progs.levels

    def device_held_bytes_per_rank(self) -> int:
        """Per-device bytes of padded stepping state (equal on every rank by
        construction — the Table-1 boundedness quantity for this fabric)."""
        progs = self._programs()
        self._ensure_device(progs)
        n = self.cfg.nranks
        return sum(int(a.nbytes) // n for a in self._dev_pdfs + self._dev_masks)

    # -- stepping --------------------------------------------------------------
    def advance(self, coarse_steps: int) -> None:
        """Run whole coarse steps as one SPMD program per step: upload once
        per storage version, then every substep's emit/permute/absorb/step
        happens on-device; the host only attributes the known (compile-time)
        ppermute traffic into the ``DeviceComm`` counters."""
        progs = self._programs()
        self._ensure_device(progs)
        comm = self.sim.comm
        s0 = comm.stats.summary()
        with _TR.stage("fused", cat="stage", coarse_steps=coarse_steps) as st:
            pdfs = self._dev_pdfs
            for _ in range(coarse_steps):
                with _TR.span("device_superstep", cat="substep",
                              nsub=progs.nsub):
                    pdfs = progs.fn(pdfs, self._dev_masks)
                for s in range(progs.nsub):
                    p = progs.pattern[s]
                    if progs.messages[p]:
                        # repro: collective-ok(accounting mirror of the in-program ppermute rounds — p2p bytes, not a collective)
                        comm.ppermute(
                            progs.messages[p],
                            rounds=progs.rounds[p],
                            pad_bytes=progs.pad_bytes[p],
                        )
            # repro: host-ok(timing fence: StageStats seconds must not hide queued device work)
            jax.block_until_ready(pdfs)
            self._dev_pdfs = pdfs
        self._host_stale = True
        stage = StageStats.delta(s0, comm.stats.summary(), st.seconds)
        # same convention as the other fused engines: one logical ghost
        # exchange per substep, even where the fabric saw no cross-rank bytes
        stage.exchange_rounds = coarse_steps * progs.nsub
        self.sim.data_stats["fused"].add(stage)
