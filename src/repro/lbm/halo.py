"""Ghost-layer exchange between blocks (data plane).

For every block/neighbor pair the region to fill is the intersection of the
block's ghost-extended box with the neighbor's box, computed exactly in
integer fine units (the octree geometry guarantees all box corners are
multiples of the coarser cell size when the per-block cell count is even).

Level transitions use the volumetric scheme of [54]/[16] (paper §3.3):

* fine -> coarse ghost ("coalescence"): average 2x2x2 fine cells;
* coarse -> fine ghost ("explosion"): replicate the covering coarse cell.

Two execution models share the same region geometry:

* **host-plane** (:func:`fill_ghost_layers`): neighbor data is read directly
  regardless of ownership — the seed behavior, kept as the reference;
* **rank-sharded** (:func:`fill_ghost_layers_sharded`): intra-rank faces are
  in-place copies, cross-rank faces travel as point-to-point messages over
  the :class:`~repro.core.comm.Comm` fabric — the standard nonuniform-LBM
  communication of [57]. Resampling happens on the *sender* (restrict before
  send, explode before send), so each message carries exactly the ghost
  region it fills, all patches for one rank pair are batched into a single
  message per exchange, and only process-graph neighbors ever communicate.
"""

from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from ..core.blockid import ForestGeometry
from ..core.comm import Comm
from ..core.fields import FieldRegistry
from ..core.forest import Block, BlockForest
from .grid import LBMBlockSpec

__all__ = [
    "fill_ghost_layers",
    "fill_ghost_layers_sharded",
    "ghost_regions",
    "build_ghost_plan",
    "run_ghost_plan",
    "RankHaloPlan",
    "build_rank_halo_plan",
    "run_rank_halo_plan",
]


def _boxes(geom: ForestGeometry, bid: int) -> tuple[np.ndarray, np.ndarray]:
    box = geom.aabb(bid)
    return np.asarray(box[:3], dtype=np.int64), np.asarray(box[3:], dtype=np.int64)


def ghost_regions(
    geom: ForestGeometry,
    spec: LBMBlockSpec,
    blk: Block,
    nbid: int,
    nlevel: int,
):
    """Compute (target slices, source spec) for filling blk's ghosts from
    neighbor ``nbid``. Returns None if the ghost-extended boxes do not
    overlap (cannot happen for true neighbors)."""
    g = spec.ghost
    ncells = np.asarray(spec.cells, dtype=np.int64)
    b0, b1 = _boxes(geom, blk.bid)
    n0, n1 = _boxes(geom, nbid)
    cb = (b1 - b0) // ncells  # own cell size per axis (fine units)
    cn = (n1 - n0) // ncells  # neighbor cell size
    lo = np.maximum(b0 - g * cb, n0)
    hi = np.minimum(b1 + g * cb, n1)
    if np.any(hi <= lo):
        return None
    assert np.all((lo - b0) % cb == 0) and np.all((hi - lo) % cb == 0), (
        "cell alignment violated — use even cells-per-block and a max_level "
        "at least levels+log2(cells)"
    )
    t_lo = (lo - b0) // cb + g  # target array start (ghosted indices)
    w = (hi - lo) // cb  # target width in own cells
    target = tuple(slice(int(t_lo[d]), int(t_lo[d] + w[d])) for d in range(3))

    if nlevel == blk.level:
        s_lo = (lo - n0) // cn + g
        source = ("same", tuple(slice(int(s_lo[d]), int(s_lo[d] + w[d])) for d in range(3)))
    elif nlevel == blk.level + 1:  # neighbor finer: coalesce 2x2x2
        s_lo = (lo - n0) // cn + g
        source = (
            "fine",
            tuple(slice(int(s_lo[d]), int(s_lo[d] + 2 * w[d])) for d in range(3)),
        )
    else:  # neighbor coarser: explode (replicate covering coarse cell)
        idx = tuple(
            ((lo[d] + np.arange(int(w[d])) * cb[d] - n0[d]) // cn[d] + g).astype(np.int64)
            for d in range(3)
        )
        source = ("coarse", idx)
    return target, source


def _extract(arr: np.ndarray, kind: str, src) -> np.ndarray:
    """Extract + resample the source region (arr may have a leading Q axis)."""
    if kind == "same":
        return arr[..., src[0], src[1], src[2]]
    if kind == "fine":
        a = arr[..., src[0], src[1], src[2]]
        s = a.shape
        a = a.reshape(*s[:-3], s[-3] // 2, 2, s[-2] // 2, 2, s[-1] // 2, 2)
        return a.mean(axis=(-5, -3, -1)).astype(arr.dtype)
    # coarse: fancy-index with per-axis replication maps
    ix, iy, iz = src
    return arr[..., ix[:, None, None], iy[None, :, None], iz[None, None, :]]


def _field_groups(
    spec: LBMBlockSpec | FieldRegistry, fields: tuple[str, ...]
) -> list[tuple[LBMBlockSpec, tuple[str, ...]]]:
    """Group exchanged fields by ghost width (one region geometry per group)."""
    if isinstance(spec, FieldRegistry):
        by_ghost: dict[int, list[str]] = {}
        for name in fields:
            by_ghost.setdefault(spec.fields[name].ghost, []).append(name)
        return [
            (LBMBlockSpec(cells=spec.cells, ghost=g), tuple(names))
            for g, names in by_ghost.items()
        ]
    return [(spec, tuple(fields))]


def build_ghost_plan(
    forest: BlockForest,
    spec: LBMBlockSpec | FieldRegistry,
    *,
    fields: tuple[str, ...] = ("pdf",),
    levels: set[int] | None = None,
) -> list[tuple]:
    """Precompute the ghost-exchange copy plan: one (target view, kind,
    source) entry per block/neighbor/field, with all geometry math and slice
    construction done once.

    The plan holds zero-copy views into the blocks' storage, so it stays
    valid exactly as long as the forest topology AND the backing arrays are
    unchanged — i.e. between arena adoptions. This is the payoff of
    persistent :class:`~repro.core.fields.LevelArena` storage: the seed's
    per-substep restacking invalidated every array each step, making a
    persistent plan impossible.
    """
    groups = _field_groups(spec, fields)
    geom = forest.geom
    by_id: dict[int, Block] = {b.bid: b for b in forest.all_blocks()}
    plan: list[tuple] = []
    for blk in by_id.values():
        if levels is not None and blk.level not in levels:
            continue
        for nbid in blk.neighbors:
            nb = by_id[nbid]
            for sp, names in groups:
                reg = ghost_regions(geom, sp, blk, nbid, nb.level)
                if reg is None:
                    continue
                target, (kind, src) = reg
                for name in names:
                    tgt = blk.data[name][..., target[0], target[1], target[2]]
                    if kind == "same":  # fast path: a plain view-to-view copy
                        plan.append(
                            (tgt, kind, nb.data[name][..., src[0], src[1], src[2]])
                        )
                    else:
                        plan.append((tgt, kind, (nb.data[name], src)))
    return plan


def run_ghost_plan(plan: list[tuple]) -> None:
    """Execute a precomputed exchange plan (pure array copies/resampling)."""
    for tgt, kind, payload in plan:
        if kind == "same":
            tgt[...] = payload
        else:  # fine / coarse: resample through the shared extractor
            arr, src = payload
            tgt[...] = _extract(arr, kind, src)


def fill_ghost_layers(
    forest: BlockForest,
    spec: LBMBlockSpec | FieldRegistry,
    *,
    fields: tuple[str, ...] = ("pdf",),
    levels: set[int] | None = None,
    plan_cache: dict | None = None,
) -> None:
    """Refresh ghost layers of all blocks (optionally only given levels).

    ``spec`` is either an :class:`LBMBlockSpec` (one ghost width for all
    ``fields``) or a :class:`FieldRegistry`, in which case each field uses
    the ghost width of its own declaration. Writes happen in place, so when
    blocks are arena-backed the level buffers are updated directly.

    With ``plan_cache`` (a dict owned by the caller, who must clear it on
    every topology/storage change) the exchange plan is built once per
    distinct level set and replayed on subsequent calls.
    """
    run_ghost_plan(
        _cached_plan(
            plan_cache,
            levels,
            fields,
            lambda: build_ghost_plan(forest, spec, fields=fields, levels=levels),
        )
    )


def _cached_plan(plan_cache: dict | None, levels: set[int] | None, fields, build):
    """Get-or-build an exchange plan keyed by (level set, fields)."""
    if plan_cache is None:
        return build()
    key = (None if levels is None else frozenset(levels), tuple(fields))
    plan = plan_cache.get(key)
    if plan is None:
        plan = plan_cache[key] = build()
    return plan


# -- rank-sharded exchange (cross-rank ghosts as p2p messages) ------------------


@dataclass
class RankHaloPlan:
    """Precomputed sharded exchange: in-place intra-rank copies plus one
    batched point-to-point message per communicating rank pair.

    ``sends[(src, dst)]`` and ``recvs[(src, dst)]`` are index-aligned: entry
    ``i`` of the send list produces the patch that entry ``i`` of the receive
    list writes into. Senders only read arrays owned by ``src``; receivers
    only write arrays owned by ``dst`` — rank-locality by construction.
    """

    local: list[tuple] = field(default_factory=list)  # run_ghost_plan entries
    sends: dict[tuple[int, int], list[tuple]] = field(default_factory=dict)
    recvs: dict[tuple[int, int], list[np.ndarray]] = field(default_factory=dict)
    nbytes: dict[tuple[int, int], int] = field(default_factory=dict)

    def rank_pairs(self) -> set[tuple[int, int]]:
        return set(self.sends)

    def cross_rank_bytes(self) -> int:
        return sum(self.nbytes.values())


def build_rank_halo_plan(
    forest: BlockForest,
    spec: LBMBlockSpec | FieldRegistry,
    *,
    fields: tuple[str, ...] = ("pdf",),
    levels: set[int] | None = None,
) -> RankHaloPlan:
    """Split the ghost-exchange plan by ownership: same-owner pairs become
    in-place copies, cross-owner pairs become (sender extract, receiver
    write) entries batched per rank pair. Like :func:`build_ghost_plan` the
    plan holds zero-copy views, so it stays valid between arena adoptions."""
    groups = _field_groups(spec, fields)
    geom = forest.geom
    by_id: dict[int, Block] = {b.bid: b for b in forest.all_blocks()}
    plan = RankHaloPlan()
    for blk in by_id.values():
        if levels is not None and blk.level not in levels:
            continue
        for nbid in blk.neighbors:
            nb = by_id[nbid]
            for sp, names in groups:
                reg = ghost_regions(geom, sp, blk, nbid, nb.level)
                if reg is None:
                    continue
                target, (kind, src) = reg
                for name in names:
                    tgt = blk.data[name][..., target[0], target[1], target[2]]
                    if nb.owner == blk.owner:
                        if kind == "same":
                            plan.local.append(
                                (tgt, kind, nb.data[name][..., src[0], src[1], src[2]])
                            )
                        else:
                            plan.local.append((tgt, kind, (nb.data[name], src)))
                    else:
                        # data flows owner(neighbor) -> owner(block); §2 next-
                        # neighbor property: communicating ranks must be
                        # process-graph neighbors (pinned by the conformance
                        # suite via rank_pairs()).
                        pair = (nb.owner, blk.owner)
                        plan.sends.setdefault(pair, []).append(
                            (nb.data[name], kind, src)
                        )
                        plan.recvs.setdefault(pair, []).append(tgt)
                        plan.nbytes[pair] = plan.nbytes.get(pair, 0) + tgt.nbytes
    return plan


def run_rank_halo_plan(plan: RankHaloPlan, comm: Comm) -> None:
    """Execute a sharded exchange: local copies in place, then one p2p
    message per rank pair (sender-side resampling) and one delivery round."""
    run_ghost_plan(plan.local)
    if not plan.sends:
        return  # nothing crosses a rank boundary: no communication round
    for (src_rank, dst_rank), entries in plan.sends.items():
        patches = [
            np.ascontiguousarray(_extract(arr, kind, src))
            for arr, kind, src in entries
        ]
        comm.send(
            src_rank,
            dst_rank,
            "halo",
            ((src_rank, dst_rank), patches),
            nbytes=plan.nbytes[(src_rank, dst_rank)],
        )
    inbox = comm.exchange()
    for _dst, msgs in inbox.items():
        for _tag, (pair, patches) in msgs:
            targets = plan.recvs[pair]
            assert len(patches) == len(targets), pair
            for tgt, patch in zip(targets, patches):
                tgt[...] = patch


def fill_ghost_layers_sharded(
    forest: BlockForest,
    spec: LBMBlockSpec | FieldRegistry,
    comm: Comm,
    *,
    fields: tuple[str, ...] = ("pdf",),
    levels: set[int] | None = None,
    plan_cache: dict | None = None,
) -> RankHaloPlan:
    """Sharded counterpart of :func:`fill_ghost_layers`: refresh ghost layers
    with intra-rank in-place copies and cross-rank p2p messages through
    ``comm``. Returns the plan used (for traffic introspection). The caller
    owns ``plan_cache`` and must clear it on every topology/storage change."""
    plan = _cached_plan(
        plan_cache,
        levels,
        fields,
        lambda: build_rank_halo_plan(forest, spec, fields=fields, levels=levels),
    )
    run_rank_halo_plan(plan, comm)
    return plan
