"""Ghost-layer exchange between blocks (data plane).

For every block/neighbor pair the region to fill is the intersection of the
block's ghost-extended box with the neighbor's box, computed exactly in
integer fine units (the octree geometry guarantees all box corners are
multiples of the coarser cell size when the per-block cell count is even).

Level transitions use the volumetric scheme of [54]/[16] (paper §3.3):

* fine -> coarse ghost ("coalescence"): average 2x2x2 fine cells;
* coarse -> fine ghost ("explosion"): replicate the covering coarse cell.

On a distributed machine this is the standard nonuniform-LBM communication
of [57]; in this host-plane implementation neighbor data is read directly —
the AMR *algorithms* themselves never do this, only the stepping data path.
"""

from __future__ import annotations

import numpy as np

from ..core.blockid import ForestGeometry
from ..core.fields import FieldRegistry
from ..core.forest import Block, BlockForest
from .grid import LBMBlockSpec

__all__ = ["fill_ghost_layers", "ghost_regions", "build_ghost_plan", "run_ghost_plan"]


def _boxes(geom: ForestGeometry, bid: int) -> tuple[np.ndarray, np.ndarray]:
    box = geom.aabb(bid)
    return np.asarray(box[:3], dtype=np.int64), np.asarray(box[3:], dtype=np.int64)


def ghost_regions(
    geom: ForestGeometry,
    spec: LBMBlockSpec,
    blk: Block,
    nbid: int,
    nlevel: int,
):
    """Compute (target slices, source spec) for filling blk's ghosts from
    neighbor ``nbid``. Returns None if the ghost-extended boxes do not
    overlap (cannot happen for true neighbors)."""
    g = spec.ghost
    ncells = np.asarray(spec.cells, dtype=np.int64)
    b0, b1 = _boxes(geom, blk.bid)
    n0, n1 = _boxes(geom, nbid)
    cb = (b1 - b0) // ncells  # own cell size per axis (fine units)
    cn = (n1 - n0) // ncells  # neighbor cell size
    lo = np.maximum(b0 - g * cb, n0)
    hi = np.minimum(b1 + g * cb, n1)
    if np.any(hi <= lo):
        return None
    assert np.all((lo - b0) % cb == 0) and np.all((hi - lo) % cb == 0), (
        "cell alignment violated — use even cells-per-block and a max_level "
        "at least levels+log2(cells)"
    )
    t_lo = (lo - b0) // cb + g  # target array start (ghosted indices)
    w = (hi - lo) // cb  # target width in own cells
    target = tuple(slice(int(t_lo[d]), int(t_lo[d] + w[d])) for d in range(3))

    if nlevel == blk.level:
        s_lo = (lo - n0) // cn + g
        source = ("same", tuple(slice(int(s_lo[d]), int(s_lo[d] + w[d])) for d in range(3)))
    elif nlevel == blk.level + 1:  # neighbor finer: coalesce 2x2x2
        s_lo = (lo - n0) // cn + g
        source = (
            "fine",
            tuple(slice(int(s_lo[d]), int(s_lo[d] + 2 * w[d])) for d in range(3)),
        )
    else:  # neighbor coarser: explode (replicate covering coarse cell)
        idx = tuple(
            ((lo[d] + np.arange(int(w[d])) * cb[d] - n0[d]) // cn[d] + g).astype(np.int64)
            for d in range(3)
        )
        source = ("coarse", idx)
    return target, source


def _extract(arr: np.ndarray, kind: str, src) -> np.ndarray:
    """Extract + resample the source region (arr may have a leading Q axis)."""
    if kind == "same":
        return arr[..., src[0], src[1], src[2]]
    if kind == "fine":
        a = arr[..., src[0], src[1], src[2]]
        s = a.shape
        a = a.reshape(*s[:-3], s[-3] // 2, 2, s[-2] // 2, 2, s[-1] // 2, 2)
        return a.mean(axis=(-5, -3, -1)).astype(arr.dtype)
    # coarse: fancy-index with per-axis replication maps
    ix, iy, iz = src
    return arr[..., ix[:, None, None], iy[None, :, None], iz[None, None, :]]


def build_ghost_plan(
    forest: BlockForest,
    spec: LBMBlockSpec | FieldRegistry,
    *,
    fields: tuple[str, ...] = ("pdf",),
    levels: set[int] | None = None,
) -> list[tuple]:
    """Precompute the ghost-exchange copy plan: one (target view, kind,
    source) entry per block/neighbor/field, with all geometry math and slice
    construction done once.

    The plan holds zero-copy views into the blocks' storage, so it stays
    valid exactly as long as the forest topology AND the backing arrays are
    unchanged — i.e. between arena adoptions. This is the payoff of
    persistent :class:`~repro.core.fields.LevelArena` storage: the seed's
    per-substep restacking invalidated every array each step, making a
    persistent plan impossible.
    """
    if isinstance(spec, FieldRegistry):
        by_ghost: dict[int, list[str]] = {}
        for name in fields:
            by_ghost.setdefault(spec.fields[name].ghost, []).append(name)
        groups = [
            (LBMBlockSpec(cells=spec.cells, ghost=g), tuple(names))
            for g, names in by_ghost.items()
        ]
    else:
        groups = [(spec, tuple(fields))]
    geom = forest.geom
    by_id: dict[int, Block] = {b.bid: b for b in forest.all_blocks()}
    plan: list[tuple] = []
    for blk in by_id.values():
        if levels is not None and blk.level not in levels:
            continue
        for nbid in blk.neighbors:
            nb = by_id[nbid]
            for sp, names in groups:
                reg = ghost_regions(geom, sp, blk, nbid, nb.level)
                if reg is None:
                    continue
                target, (kind, src) = reg
                for name in names:
                    tgt = blk.data[name][..., target[0], target[1], target[2]]
                    if kind == "same":  # fast path: a plain view-to-view copy
                        plan.append(
                            (tgt, kind, nb.data[name][..., src[0], src[1], src[2]])
                        )
                    else:
                        plan.append((tgt, kind, (nb.data[name], src)))
    return plan


def run_ghost_plan(plan: list[tuple]) -> None:
    """Execute a precomputed exchange plan (pure array copies/resampling)."""
    for tgt, kind, payload in plan:
        if kind == "same":
            tgt[...] = payload
        else:  # fine / coarse: resample through the shared extractor
            arr, src = payload
            tgt[...] = _extract(arr, kind, src)


def fill_ghost_layers(
    forest: BlockForest,
    spec: LBMBlockSpec | FieldRegistry,
    *,
    fields: tuple[str, ...] = ("pdf",),
    levels: set[int] | None = None,
    plan_cache: dict | None = None,
) -> None:
    """Refresh ghost layers of all blocks (optionally only given levels).

    ``spec`` is either an :class:`LBMBlockSpec` (one ghost width for all
    ``fields``) or a :class:`FieldRegistry`, in which case each field uses
    the ghost width of its own declaration. Writes happen in place, so when
    blocks are arena-backed the level buffers are updated directly.

    With ``plan_cache`` (a dict owned by the caller, who must clear it on
    every topology/storage change) the exchange plan is built once per
    distinct level set and replayed on subsequent calls.
    """
    if plan_cache is None:
        run_ghost_plan(build_ghost_plan(forest, spec, fields=fields, levels=levels))
        return
    key = (None if levels is None else frozenset(levels), tuple(fields))
    plan = plan_cache.get(key)
    if plan is None:
        plan = plan_cache[key] = build_ghost_plan(
            forest, spec, fields=fields, levels=levels
        )
    run_ghost_plan(plan)
