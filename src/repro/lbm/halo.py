"""Ghost-layer exchange between blocks (data plane).

For every block/neighbor pair the region to fill is the intersection of the
block's ghost-extended box with the neighbor's box, computed exactly in
integer fine units (the octree geometry guarantees all box corners are
multiples of the coarser cell size when the per-block cell count is even).

Level transitions use the volumetric scheme of [54]/[16] (paper §3.3):

* fine -> coarse ghost ("coalescence"): average 2x2x2 fine cells;
* coarse -> fine ghost ("explosion"): replicate the covering coarse cell.

Two execution models share the same region geometry:

* **host-plane** (:func:`fill_ghost_layers`): neighbor data is read directly
  regardless of ownership — the seed behavior, kept as the reference;
* **rank-sharded** (:func:`fill_ghost_layers_sharded`): intra-rank faces are
  in-place copies, cross-rank faces travel as point-to-point messages over
  the :class:`~repro.core.comm.Comm` fabric — the standard nonuniform-LBM
  communication of [57]. Resampling happens on the *sender* (restrict before
  send, explode before send), so each message carries exactly the ghost
  region it fills, all patches for one rank pair are batched into a single
  message per exchange, and only process-graph neighbors ever communicate.
"""

from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from ..core.blockid import ForestGeometry
from ..core.comm import Comm
from ..core.fields import FieldRegistry
from ..core.forest import Block, BlockForest
from ..telemetry import get_tracer
from .grid import LBMBlockSpec

_TR = get_tracer()


def _traced_plan(name: str):
    """Record plan build/compile work as a ``halo.plan`` span (these run at
    adoption and AMR events, never per substep — the span makes replanning
    cost visible next to the compile events it usually precedes)."""

    def deco(fn):
        def wrapper(*args, **kwargs):
            if not _TR.enabled:
                return fn(*args, **kwargs)
            with _TR.span(name, cat="halo.plan"):
                return fn(*args, **kwargs)

        wrapper.__name__ = fn.__name__
        wrapper.__doc__ = fn.__doc__
        wrapper.__wrapped__ = fn
        return wrapper

    return deco

__all__ = [
    "fill_ghost_layers",
    "fill_ghost_layers_sharded",
    "ghost_regions",
    "build_ghost_plan",
    "run_ghost_plan",
    "RankHaloPlan",
    "build_rank_halo_plan",
    "run_rank_halo_plan",
    "CompiledGhostOp",
    "CompiledGhostPlan",
    "compile_ghost_plan",
    "HaloFillSegment",
    "LevelHaloFill",
    "lower_halo_fill",
    "CompiledRankMessage",
    "CompiledRankHaloPlan",
    "compile_rank_halo_plan",
]

# 2x2x2 coalescence offsets in the canonical (lexicographic) order; the host
# extractor and the compiled plan both sum in exactly this sequence so their
# float32 results are bitwise identical.
_OCTET_OFFSETS: tuple[tuple[int, int, int], ...] = tuple(
    (dx, dy, dz) for dx in (0, 1) for dy in (0, 1) for dz in (0, 1)
)


def _boxes(geom: ForestGeometry, bid: int) -> tuple[np.ndarray, np.ndarray]:
    box = geom.aabb(bid)
    return np.asarray(box[:3], dtype=np.int64), np.asarray(box[3:], dtype=np.int64)


def ghost_regions(
    geom: ForestGeometry,
    spec: LBMBlockSpec,
    blk: Block,
    nbid: int,
    nlevel: int,
):
    """Compute (target slices, source spec) for filling blk's ghosts from
    neighbor ``nbid``. Returns None if the ghost-extended boxes do not
    overlap (cannot happen for true neighbors)."""
    g = spec.ghost
    ncells = np.asarray(spec.cells, dtype=np.int64)
    b0, b1 = _boxes(geom, blk.bid)
    n0, n1 = _boxes(geom, nbid)
    # Work in sub-cell units (fine units x cells-per-block, per axis): every
    # block corner and cell corner lands on an integer coordinate for ANY
    # even cells-per-block, not just powers of two — the old formulation
    # divided the pow2 block side by the cell count, which is inexact unless
    # the cell count is itself a power of two.
    b0, b1 = b0 * ncells, b1 * ncells
    n0, n1 = n0 * ncells, n1 * ncells
    cb = (b1 - b0) // ncells  # own cell size per axis (exact: side * ncells / ncells)
    cn = (n1 - n0) // ncells  # neighbor cell size
    lo = np.maximum(b0 - g * cb, n0)
    hi = np.minimum(b1 + g * cb, n1)
    if np.any(hi <= lo):
        return None
    assert np.all((lo - b0) % cb == 0) and np.all((hi - lo) % cb == 0), (
        "cell alignment violated — cells per block must be even (octant "
        "split + halo alignment across a 2:1 level transition)"
    )
    t_lo = (lo - b0) // cb + g  # target array start (ghosted indices)
    w = (hi - lo) // cb  # target width in own cells
    target = tuple(slice(int(t_lo[d]), int(t_lo[d] + w[d])) for d in range(3))

    if nlevel == blk.level:
        s_lo = (lo - n0) // cn + g
        source = ("same", tuple(slice(int(s_lo[d]), int(s_lo[d] + w[d])) for d in range(3)))
    elif nlevel == blk.level + 1:  # neighbor finer: coalesce 2x2x2
        s_lo = (lo - n0) // cn + g
        source = (
            "fine",
            tuple(slice(int(s_lo[d]), int(s_lo[d] + 2 * w[d])) for d in range(3)),
        )
    else:  # neighbor coarser: explode (replicate covering coarse cell)
        idx = tuple(
            ((lo[d] + np.arange(int(w[d])) * cb[d] - n0[d]) // cn[d] + g).astype(np.int64)
            for d in range(3)
        )
        source = ("coarse", idx)
    return target, source


def _extract(arr: np.ndarray, kind: str, src) -> np.ndarray:
    """Extract + resample the source region (arr may have a leading Q axis)."""
    if kind == "same":
        return arr[..., src[0], src[1], src[2]]
    if kind == "fine":
        # 2x2x2 coalescence as a fixed-order sequential sum so the host path
        # and the compiled device path (compile_ghost_plan) round identically
        # in float32 — the fused conformance suite compares them at 1e-10.
        a = arr[..., src[0], src[1], src[2]]
        acc = None
        for dx, dy, dz in _OCTET_OFFSETS:
            part = a[..., dx::2, dy::2, dz::2]
            acc = part.copy() if acc is None else acc + part
        if np.issubdtype(arr.dtype, np.floating):
            return (acc * arr.dtype.type(0.125)).astype(arr.dtype)
        return (acc / 8).astype(arr.dtype)
    # coarse: fancy-index with per-axis replication maps
    ix, iy, iz = src
    return arr[..., ix[:, None, None], iy[None, :, None], iz[None, None, :]]


def _field_groups(
    spec: LBMBlockSpec | FieldRegistry, fields: tuple[str, ...]
) -> list[tuple[LBMBlockSpec, tuple[str, ...]]]:
    """Group exchanged fields by ghost width (one region geometry per group)."""
    if isinstance(spec, FieldRegistry):
        by_ghost: dict[int, list[str]] = {}
        for name in fields:
            by_ghost.setdefault(spec.fields[name].ghost, []).append(name)
        return [
            (LBMBlockSpec(cells=spec.cells, ghost=g), tuple(names))
            for g, names in by_ghost.items()
        ]
    return [(spec, tuple(fields))]


@_traced_plan("build_ghost_plan")
def build_ghost_plan(
    forest: BlockForest,
    spec: LBMBlockSpec | FieldRegistry,
    *,
    fields: tuple[str, ...] = ("pdf",),
    levels: set[int] | None = None,
) -> list[tuple]:
    """Precompute the ghost-exchange copy plan: one (target view, kind,
    source) entry per block/neighbor/field, with all geometry math and slice
    construction done once.

    Args:
        forest: the block forest whose ghost layers the plan refreshes.
        spec: an :class:`~repro.lbm.grid.LBMBlockSpec` (one ghost width for
            all ``fields``) or a :class:`~repro.core.fields.FieldRegistry`
            (each field uses the ghost width of its own declaration).
        fields: names of the per-block arrays to exchange.
        levels: restrict exchange *targets* to these refinement levels
            (``None`` = all). Sources are never restricted — a level-l
            block's ghosts may be sourced from level l-1/l/l+1 neighbors.

    Returns:
        A list of ``(target view, kind, source)`` entries consumed by
        :func:`run_ghost_plan`; ``kind`` is ``"same"`` (plain copy),
        ``"fine"`` (2x2x2 coalescence) or ``"coarse"`` (replicating
        explosion).

    The plan holds zero-copy views into the blocks' storage, so it stays
    valid exactly as long as the forest topology AND the backing arrays are
    unchanged — i.e. between arena adoptions; callers that cache plans must
    guard them with the validity token described in
    :func:`fill_ghost_layers`. This is the payoff of persistent
    :class:`~repro.core.fields.LevelArena` storage: the seed's per-substep
    restacking invalidated every array each step, making a persistent plan
    impossible.
    """
    groups = _field_groups(spec, fields)
    geom = forest.geom
    by_id: dict[int, Block] = {b.bid: b for b in forest.all_blocks()}
    plan: list[tuple] = []
    for blk in by_id.values():
        if levels is not None and blk.level not in levels:
            continue
        for nbid in blk.neighbors:
            nb = by_id[nbid]
            for sp, names in groups:
                reg = ghost_regions(geom, sp, blk, nbid, nb.level)
                if reg is None:
                    continue
                target, (kind, src) = reg
                for name in names:
                    tgt = blk.data[name][..., target[0], target[1], target[2]]
                    if kind == "same":  # fast path: a plain view-to-view copy
                        plan.append(
                            (tgt, kind, nb.data[name][..., src[0], src[1], src[2]])
                        )
                    else:
                        plan.append((tgt, kind, (nb.data[name], src)))
    return plan


def run_ghost_plan(plan: list[tuple]) -> None:
    """Execute a precomputed exchange plan (pure array copies/resampling)."""
    for tgt, kind, payload in plan:
        if kind == "same":
            tgt[...] = payload
        else:  # fine / coarse: resample through the shared extractor
            arr, src = payload
            tgt[...] = _extract(arr, kind, src)


def fill_ghost_layers(
    forest: BlockForest,
    spec: LBMBlockSpec | FieldRegistry,
    *,
    fields: tuple[str, ...] = ("pdf",),
    levels: set[int] | None = None,
    plan_cache: dict | None = None,
    cache_token=None,
) -> None:
    """Refresh ghost layers of all blocks (optionally only given levels).

    ``spec`` is either an :class:`LBMBlockSpec` (one ghost width for all
    ``fields``) or a :class:`FieldRegistry`, in which case each field uses
    the ghost width of its own declaration. Writes happen in place, so when
    blocks are arena-backed the level buffers are updated directly.

    With ``plan_cache`` (a dict owned by the caller) the exchange plan is
    built once per distinct level set and replayed on subsequent calls. Each
    cached plan carries a validity token and is rebuilt automatically when
    the token no longer matches, so a cache surviving a refine/coarsen/
    migration or arena rebind can never replay a stale plan. By default the
    token is the binding signature — leaf topology plus the identity of
    every participating storage array, an O(blocks) scan per call; callers
    that already version their storage (e.g. the driver via the arena
    version counter, which bumps on every adopt) can pass that counter as
    ``cache_token`` to make the guard O(1)."""
    run_ghost_plan(
        _cached_plan(
            plan_cache,
            levels,
            fields,
            _token_fn(forest, fields, cache_token),
            lambda: build_ghost_plan(forest, spec, fields=fields, levels=levels),
        )
    )


def _binding_token(forest: BlockForest, fields) -> list[tuple]:
    """Everything a cached exchange plan's validity depends on: the leaf
    topology (bid, level) plus the *identity* of each participating data
    array (plans hold zero-copy views into exactly these arrays). Ghost
    sources may live on any level, so the token always covers all blocks
    regardless of the plan's level filter."""
    return [
        (b.bid, b.level, tuple(b.data.get(name) for name in fields))
        for b in sorted(forest.all_blocks(), key=lambda b: b.bid)
    ]


def _token_fn(forest: BlockForest, fields, cache_token):
    """Validity-token thunk for the plan cache: a caller-supplied storage
    version when given (O(1) compare), the full binding signature otherwise."""
    if cache_token is not None:
        return lambda: ("version", cache_token)
    return lambda: _binding_token(forest, fields)


def _token_matches(cached, current) -> bool:
    if not (isinstance(cached, list) and isinstance(current, list)):
        return cached == current  # version tokens (or mixed kinds: mismatch)
    if len(cached) != len(current):
        return False
    for (bid_a, lvl_a, arrs_a), (bid_b, lvl_b, arrs_b) in zip(cached, current):
        if bid_a != bid_b or lvl_a != lvl_b or len(arrs_a) != len(arrs_b):
            return False
        # identity, not equality: a plan is bound to these exact arrays
        if any(x is not y for x, y in zip(arrs_a, arrs_b)):
            return False
    return True


def _cached_plan(plan_cache: dict | None, levels: set[int] | None, fields, token_fn, build):
    """Get-or-build an exchange plan keyed by (level set, fields), guarded by
    the binding token (stale entries are rebuilt, never replayed). The token
    is a thunk so uncached calls pay nothing for it."""
    if plan_cache is None:
        return build()
    token = token_fn()
    key = (None if levels is None else frozenset(levels), tuple(fields))
    entry = plan_cache.get(key)
    if entry is not None and _token_matches(entry[1], token):
        return entry[0]
    plan = build()
    plan_cache[key] = (plan, token)
    return plan


# -- compiled (device-executable) exchange plans --------------------------------


@dataclass(frozen=True)
class CompiledGhostOp:
    """One batched gather/scatter of a compiled exchange plan.

    Flat, concatenated index arrays for one (field, dst level, src level,
    resampling kind) combination: entry ``i`` fills cell ``dst_cell[i]`` of
    block-slot ``dst_slot[i]`` in the destination level's SoA buffer from
    source cell(s) ``src_cell[i]`` of slot(s) ``src_slot[i]`` in the source
    level's buffer. Cell ids are flat C-order indices into the ghosted
    spatial box of one block.

    * kind ``"same"`` / ``"coarse"``: src arrays are ``(N,)`` — a plain
      (possibly replicating) gather;
    * kind ``"fine"``: src arrays are ``(N, 8)`` — the 2x2x2 octet to
      coalesce, in the canonical offset order so a fixed-sequence sum
      reproduces the host extractor bit for bit.
    """

    field: str
    dst_level: int
    src_level: int
    kind: str  # "same" | "fine" | "coarse"
    dst_slot: np.ndarray
    dst_cell: np.ndarray
    src_slot: np.ndarray
    src_cell: np.ndarray

    @property
    def num_cells(self) -> int:
        return int(self.dst_cell.size)


@dataclass(frozen=True)
class CompiledGhostPlan:
    """A ghost exchange lowered to pure index arithmetic: no array views, no
    host copies — just gather/scatter maps over per-level SoA buffers,
    executable as ``jnp`` ops inside a jitted program (see
    ``repro.kernels.lbm_collide.ops.make_fused_superstep``). Valid as long
    as the forest topology and the arena slot assignment are unchanged."""

    fields: tuple[str, ...]
    levels: frozenset[int] | None
    ops: tuple[CompiledGhostOp, ...]

    @property
    def num_cells(self) -> int:
        return sum(op.num_cells for op in self.ops)


def _flat_cells(dims: tuple[int, int, int], ax: np.ndarray, ay: np.ndarray, az: np.ndarray) -> np.ndarray:
    """(len(ax), len(ay), len(az)) flat C-order cell ids from per-axis indices."""
    return (
        ax[:, None, None] * dims[1] + ay[None, :, None]
    ) * dims[2] + az[None, None, :]


def _srange(s: slice) -> np.ndarray:
    return np.arange(s.start, s.stop, dtype=np.int64)


def _lower_region_cells(
    sp: LBMBlockSpec, target, kind: str, src
) -> tuple[np.ndarray, np.ndarray]:
    """Lower one :func:`ghost_regions` result to flat C-order cell ids.

    Returns ``(tgt_cell, src_cell)``: ``tgt_cell`` is ``(N,)`` destination
    cell ids; ``src_cell`` is ``(N,)`` for ``"same"``/``"coarse"`` gathers or
    ``(N, 8)`` for ``"fine"`` coalescence, with the trailing octet axis in
    the canonical ``_OCTET_OFFSETS`` order so a fixed-sequence device sum is
    bitwise identical to the host extractor."""
    dims = tuple(c + 2 * sp.ghost for c in sp.cells)
    tgt_cell = _flat_cells(
        dims, _srange(target[0]), _srange(target[1]), _srange(target[2])
    ).ravel()
    if kind == "same":
        src_cell = _flat_cells(
            dims, _srange(src[0]), _srange(src[1]), _srange(src[2])
        ).ravel()
    elif kind == "fine":
        w = tuple(t.stop - t.start for t in target)
        off = np.arange(2, dtype=np.int64)
        fx = (src[0].start + 2 * np.arange(w[0], dtype=np.int64)[:, None] + off
              ).reshape(w[0], 1, 1, 2, 1, 1)
        fy = (src[1].start + 2 * np.arange(w[1], dtype=np.int64)[:, None] + off
              ).reshape(1, w[1], 1, 1, 2, 1)
        fz = (src[2].start + 2 * np.arange(w[2], dtype=np.int64)[:, None] + off
              ).reshape(1, 1, w[2], 1, 1, 2)
        # trailing (2,2,2) axes flatten to octet index dx*4+dy*2+dz
        # == the canonical _OCTET_OFFSETS order
        src_cell = ((fx * dims[1] + fy) * dims[2] + fz).reshape(-1, 8)
    else:  # coarse: per-axis replication maps (already ghosted ids)
        src_cell = _flat_cells(dims, src[0], src[1], src[2]).ravel()
    return tgt_cell, src_cell


@_traced_plan("compile_ghost_plan")
def compile_ghost_plan(
    forest: BlockForest,
    spec: LBMBlockSpec | FieldRegistry,
    slots: dict[int, dict[int, int]],
    *,
    fields: tuple[str, ...] = ("pdf",),
    levels: set[int] | None = None,
) -> CompiledGhostPlan:
    """Lower :func:`build_ghost_plan`'s region lists into flat gather/scatter
    index arrays addressed by (arena slot, flat ghosted-cell id).

    Args:
        forest: the block forest to compile the exchange for.
        spec: :class:`~repro.lbm.grid.LBMBlockSpec` or
            :class:`~repro.core.fields.FieldRegistry` (per-field ghost
            widths), as in :func:`build_ghost_plan`.
        slots: level -> bid -> slot (``LevelArena.slots``); must cover *all*
            blocks of the forest — targets are restricted to ``levels`` but
            ghost sources can live on any neighboring level.
        fields: names of the fields to exchange (one op group per field).
        levels: restrict exchange targets to these levels (``None`` = all).

    Returns:
        A :class:`CompiledGhostPlan` whose ops are batched per (field, dst
        level, src level, kind), so the whole exchange of a level set
        executes as a handful of vectorized ops regardless of block count.

    The compiled plan contains index arrays only (no array views); it stays
    valid as long as the forest topology and the slot assignment are
    unchanged, i.e. until the next arena ``adopt()`` — callers key their
    program caches on ``arena.version`` for exactly this reason.
    """
    groups = _field_groups(spec, fields)
    geom = forest.geom
    by_id: dict[int, Block] = {b.bid: b for b in forest.all_blocks()}
    acc: dict[tuple, list[tuple]] = {}
    for blk in by_id.values():
        if levels is not None and blk.level not in levels:
            continue
        t_slot = slots[blk.level][blk.bid]
        for nbid in blk.neighbors:
            nb = by_id[nbid]
            s_slot = slots[nb.level][nbid]
            for sp, names in groups:
                reg = ghost_regions(geom, sp, blk, nbid, nb.level)
                if reg is None:
                    continue
                target, (kind, src) = reg
                tgt_cell, src_cell = _lower_region_cells(sp, target, kind, src)
                n = tgt_cell.size
                dst_slot = np.full(n, t_slot, dtype=np.int32)
                src_slot = np.full(src_cell.shape, s_slot, dtype=np.int32)
                for name in names:
                    acc.setdefault((name, blk.level, nb.level, kind), []).append(
                        (dst_slot, tgt_cell, src_slot, src_cell)
                    )
    ops = tuple(
        CompiledGhostOp(
            field=name,
            dst_level=dl,
            src_level=sl,
            kind=kind,
            dst_slot=np.concatenate([e[0] for e in entries]),
            dst_cell=np.concatenate([e[1] for e in entries]).astype(np.int32),
            src_slot=np.concatenate([e[2] for e in entries]),
            src_cell=np.concatenate([e[3] for e in entries]).astype(np.int32),
        )
        for (name, dl, sl, kind), entries in sorted(acc.items())
    )
    return CompiledGhostPlan(
        fields=tuple(fields),
        levels=None if levels is None else frozenset(levels),
        ops=ops,
    )


@dataclass(frozen=True)
class HaloFillSegment:
    """One value-source segment of a merged per-level halo fill: gather
    ``src_cell`` (``(N,)`` or ``(N, 8)`` for fine coalescence, canonical
    octet order) from slots ``src_slot`` of ``src_level``'s buffer."""

    src_level: int
    kind: str  # "same" | "fine" | "coarse"
    src_slot: np.ndarray
    src_cell: np.ndarray


@dataclass(frozen=True)
class LevelHaloFill:
    """Halo-in-tile index map: *every* ghost fill targeting one destination
    level, merged into a single scatter.

    ``dst_slot``/``dst_cell`` are the concatenation of the plan's per-(src
    level, kind) op targets in op order; ``segments`` name the value sources
    in the same order, so ``concat(gather(seg) for seg in segments)`` lines
    up with the destination arrays row for row. Because every ghost cell is
    filled from exactly one source region, the merged scatter has no
    duplicate targets and is bitwise equal to the sequential per-op schedule
    — but it materializes the destination buffer once per level instead of
    once per op, and its index arrays can be handed straight to a halo-aware
    kernel (the stencil reads the ghost ring in-tile instead of waiting for
    a separately materialized exchanged buffer)."""

    field: str
    dst_level: int
    dst_slot: np.ndarray  # (N,)
    dst_cell: np.ndarray  # (N,)
    segments: tuple[HaloFillSegment, ...]

    @property
    def num_cells(self) -> int:
        return int(self.dst_cell.size)


def lower_halo_fill(plan: CompiledGhostPlan) -> dict[int, LevelHaloFill]:
    """Merge a single-field :class:`CompiledGhostPlan` into one
    :class:`LevelHaloFill` per destination level.

    All gather segments read *interior* cells of their source blocks (ghost
    regions are clipped to the neighbor's own box), and all scatter targets
    are ghost cells, so the upfront gather-everything-then-scatter-per-level
    schedule this enables is bitwise identical to interleaving the plan's
    ops one by one."""
    assert len({op.field for op in plan.ops}) <= 1, (
        "lower_halo_fill merges one field's ops; compile one plan per field"
    )
    by_level: dict[int, list[CompiledGhostOp]] = {}
    for op in plan.ops:  # plan op order is the deterministic sorted-acc order
        by_level.setdefault(op.dst_level, []).append(op)
    return {
        dl: LevelHaloFill(
            field=ops[0].field,
            dst_level=dl,
            dst_slot=np.concatenate([op.dst_slot for op in ops]),
            dst_cell=np.concatenate([op.dst_cell for op in ops]),
            segments=tuple(
                HaloFillSegment(
                    src_level=op.src_level,
                    kind=op.kind,
                    src_slot=op.src_slot,
                    src_cell=op.src_cell,
                )
                for op in ops
            ),
        )
        for dl, ops in sorted(by_level.items())
    }


# -- rank-sharded exchange (cross-rank ghosts as p2p messages) ------------------


@dataclass
class RankHaloPlan:
    """Precomputed sharded exchange: in-place intra-rank copies plus one
    batched point-to-point message per communicating rank pair.

    ``sends[(src, dst)]`` and ``recvs[(src, dst)]`` are index-aligned: entry
    ``i`` of the send list produces the patch that entry ``i`` of the receive
    list writes into. Senders only read arrays owned by ``src``; receivers
    only write arrays owned by ``dst`` — rank-locality by construction.
    """

    local: list[tuple] = field(default_factory=list)  # run_ghost_plan entries
    sends: dict[tuple[int, int], list[tuple]] = field(default_factory=dict)
    recvs: dict[tuple[int, int], list[np.ndarray]] = field(default_factory=dict)
    nbytes: dict[tuple[int, int], int] = field(default_factory=dict)

    def rank_pairs(self) -> set[tuple[int, int]]:
        return set(self.sends)

    def cross_rank_bytes(self) -> int:
        return sum(self.nbytes.values())


@_traced_plan("build_rank_halo_plan")
def build_rank_halo_plan(
    forest: BlockForest,
    spec: LBMBlockSpec | FieldRegistry,
    *,
    fields: tuple[str, ...] = ("pdf",),
    levels: set[int] | None = None,
) -> RankHaloPlan:
    """Split the ghost-exchange plan by ownership: same-owner pairs become
    in-place copies, cross-owner pairs become (sender extract, receiver
    write) entries batched per rank pair.

    Args:
        forest: the block forest (``Block.owner`` decides intra vs cross).
        spec: :class:`~repro.lbm.grid.LBMBlockSpec` or
            :class:`~repro.core.fields.FieldRegistry`, as in
            :func:`build_ghost_plan`.
        fields: names of the per-block arrays to exchange.
        levels: restrict exchange targets to these levels (``None`` = all).

    Returns:
        A :class:`RankHaloPlan`; execute it with :func:`run_rank_halo_plan`.

    Like :func:`build_ghost_plan` the plan holds zero-copy views, so it
    stays valid between arena adoptions only; cached plans are guarded by
    the same validity token (see :func:`fill_ghost_layers`) and rebuilt
    automatically when the forest topology or storage binding changed."""
    groups = _field_groups(spec, fields)
    geom = forest.geom
    by_id: dict[int, Block] = {b.bid: b for b in forest.all_blocks()}
    plan = RankHaloPlan()
    for blk in by_id.values():
        if levels is not None and blk.level not in levels:
            continue
        for nbid in blk.neighbors:
            nb = by_id[nbid]
            for sp, names in groups:
                reg = ghost_regions(geom, sp, blk, nbid, nb.level)
                if reg is None:
                    continue
                target, (kind, src) = reg
                for name in names:
                    tgt = blk.data[name][..., target[0], target[1], target[2]]
                    if nb.owner == blk.owner:
                        if kind == "same":
                            plan.local.append(
                                (tgt, kind, nb.data[name][..., src[0], src[1], src[2]])
                            )
                        else:
                            plan.local.append((tgt, kind, (nb.data[name], src)))
                    else:
                        # data flows owner(neighbor) -> owner(block); §2 next-
                        # neighbor property: communicating ranks must be
                        # process-graph neighbors (pinned by the conformance
                        # suite via rank_pairs()).
                        pair = (nb.owner, blk.owner)
                        plan.sends.setdefault(pair, []).append(
                            (nb.data[name], kind, src)
                        )
                        plan.recvs.setdefault(pair, []).append(tgt)
                        plan.nbytes[pair] = plan.nbytes.get(pair, 0) + tgt.nbytes
    return plan


def run_rank_halo_plan(plan: RankHaloPlan, comm: Comm) -> None:
    """Execute a sharded exchange: local copies in place, then one p2p
    message per rank pair (sender-side resampling) and one delivery round."""
    run_ghost_plan(plan.local)
    if not plan.sends:
        return  # nothing crosses a rank boundary: no communication round
    for (src_rank, dst_rank), entries in plan.sends.items():
        patches = [
            np.ascontiguousarray(_extract(arr, kind, src))
            for arr, kind, src in entries
        ]
        comm.send(
            src_rank,
            dst_rank,
            "halo",
            ((src_rank, dst_rank), patches),
            nbytes=plan.nbytes[(src_rank, dst_rank)],
        )
    inbox = comm.exchange()
    for _dst, msgs in inbox.items():
        for _tag, (pair, patches) in msgs:
            targets = plan.recvs[pair]
            assert len(patches) == len(targets), pair
            for tgt, patch in zip(targets, patches):
                tgt[...] = patch


def fill_ghost_layers_sharded(
    forest: BlockForest,
    spec: LBMBlockSpec | FieldRegistry,
    comm: Comm,
    *,
    fields: tuple[str, ...] = ("pdf",),
    levels: set[int] | None = None,
    plan_cache: dict | None = None,
    cache_token=None,
) -> RankHaloPlan:
    """Sharded counterpart of :func:`fill_ghost_layers`: refresh ghost layers
    with intra-rank in-place copies and cross-rank p2p messages through
    ``comm``. Returns the plan used (for traffic introspection). The caller
    owns ``plan_cache``; stale entries are detected (and rebuilt) through the
    same validity token as :func:`fill_ghost_layers`."""
    plan = _cached_plan(
        plan_cache,
        levels,
        fields,
        _token_fn(forest, fields, cache_token),
        lambda: build_rank_halo_plan(forest, spec, fields=fields, levels=levels),
    )
    run_rank_halo_plan(plan, comm)
    return plan


# -- compiled rank-sharded exchange (device-built p2p messages) ------------------


@dataclass(frozen=True)
class CompiledRankMessage:
    """One rank pair's batched halo message, lowered to device index arrays.

    The message payload for the pair is a single ``(num_cells, C)`` array per
    field (``C`` = product of the field's leading component axes, e.g. Q for
    PDFs), built *on the sender's device* by concatenating the ``gather``
    segments in order — resampling (fine->coarse coalescence, coarse->fine
    replication) happens sender-side exactly as in :class:`RankHaloPlan`,
    with the canonical fixed-order octet sum so device == host bitwise. The
    receiver writes the payload into its own buffers by walking the
    ``scatter`` segments over the same consecutive cell ranges, so sender and
    receiver lowering agree by construction (both sides are emitted by the
    same loop in :func:`compile_rank_halo_plan`).

    ``gather`` entries are ``(src_level, kind, src_slot, src_cell)`` — slots
    index the *sender's* rank-local per-level buffers; ``src_cell`` is
    ``(N,)`` or ``(N, 8)`` as in :class:`CompiledGhostOp`. ``scatter``
    entries are ``(dst_level, dst_slot, dst_cell, ncells)`` — slots index the
    *receiver's* rank-local buffers. ``nbytes`` is the payload size the
    ``Comm`` fabric accounts for the pair (identical to the host-plan patch
    bytes, so Table-1 numbers are mode-independent).
    """

    src_rank: int
    dst_rank: int
    field: str
    nbytes: int
    num_cells: int
    gather: tuple[tuple[int, str, np.ndarray, np.ndarray], ...]
    scatter: tuple[tuple[int, np.ndarray, np.ndarray, int], ...]

    @property
    def key(self) -> tuple[int, int, str]:
        """Routing key carried alongside the payload on the fabric."""
        return (self.src_rank, self.dst_rank, self.field)


@dataclass(frozen=True)
class CompiledRankHaloPlan:
    """A sharded ghost exchange lowered to pure index arithmetic per rank.

    The device analogue of :class:`RankHaloPlan`: ``local[r]`` is rank r's
    intra-rank exchange as a :class:`CompiledGhostPlan` over its *rank-local*
    arena slots (executable inside r's jitted program), and ``messages``
    holds one :class:`CompiledRankMessage` per (communicating rank pair,
    field) — so the ``Comm`` fabric still sees exactly one p2p message per
    neighboring rank pair per exchange, only now the payload is a
    device-built buffer instead of a list of host patches. Valid as long as
    the forest topology and every rank's slot assignment are unchanged
    (callers key caches on ``RankArenas.version``).
    """

    fields: tuple[str, ...]
    levels: frozenset[int] | None
    local: dict[int, CompiledGhostPlan]
    messages: tuple[CompiledRankMessage, ...]

    def rank_pairs(self) -> set[tuple[int, int]]:
        return {(m.src_rank, m.dst_rank) for m in self.messages}

    def cross_rank_bytes(self) -> int:
        return sum(m.nbytes for m in self.messages)


@_traced_plan("compile_rank_halo_plan")
def compile_rank_halo_plan(
    forest: BlockForest,
    spec: LBMBlockSpec | FieldRegistry,
    rank_slots: dict[int, dict[int, dict[int, int]]],
    *,
    fields: tuple[str, ...] = ("pdf",),
    levels: set[int] | None = None,
) -> CompiledRankHaloPlan:
    """Lower :func:`build_rank_halo_plan`'s ownership-split exchange into
    flat gather/scatter index arrays addressed by *rank-local* arena slots.

    Args:
        forest: the block forest (``Block.owner`` decides intra vs cross).
        spec: :class:`~repro.lbm.grid.LBMBlockSpec` or
            :class:`~repro.core.fields.FieldRegistry`, as in
            :func:`build_ghost_plan`.
        rank_slots: rank -> level -> bid -> slot, i.e.
            ``{r: {l: arenas.per_rank[r].slots(l)}}`` for a
            :class:`~repro.core.fields.RankArenas` — every block must appear
            in its owner's slot map (sources are never level-restricted).
        fields: names of the fields to exchange.
        levels: restrict exchange targets to these levels (``None`` = all).

    Returns:
        A :class:`CompiledRankHaloPlan`. Intra-rank copies become per-rank
        :class:`CompiledGhostOp` batches; cross-rank patches become
        per-rank-pair :class:`CompiledRankMessage` specs whose payloads are
        gathered on the sender's device and scattered on the receiver's.

    This is the same treatment :func:`compile_ghost_plan` gave the
    single-arena region lists, applied to the sharded plan: the host-side
    numpy patch resampling of :func:`run_rank_halo_plan` disappears, and the
    only per-substep host involvement left is routing the (device-resident)
    message buffers through the ``Comm`` fabric.
    """
    groups = _field_groups(spec, fields)
    geom = forest.geom
    by_id: dict[int, Block] = {b.bid: b for b in forest.all_blocks()}
    local_acc: dict[int, dict[tuple, list[tuple]]] = {}
    # (src_rank, dst_rank, field) -> (src_level, kind) -> aligned seg lists
    msg_acc: dict[tuple, dict[tuple, list[tuple]]] = {}
    lead: dict[str, int] = {}
    itemsize: dict[str, int] = {}
    if isinstance(spec, FieldRegistry):
        for name in fields:
            fs = spec.fields[name]
            lead[name] = int(np.prod(fs.shape, dtype=np.int64)) if fs.shape else 1
            itemsize[name] = np.dtype(fs.dtype).itemsize
    else:
        for name in fields:
            lead[name] = spec.lattice.Q if name == "pdf" else 1
            itemsize[name] = np.dtype(spec.dtype).itemsize
    for blk in by_id.values():
        if levels is not None and blk.level not in levels:
            continue
        t_slot = rank_slots[blk.owner][blk.level][blk.bid]
        for nbid in blk.neighbors:
            nb = by_id[nbid]
            s_slot = rank_slots[nb.owner][nb.level][nbid]
            for sp, names in groups:
                reg = ghost_regions(geom, sp, blk, nbid, nb.level)
                if reg is None:
                    continue
                target, (kind, src) = reg
                tgt_cell, src_cell = _lower_region_cells(sp, target, kind, src)
                n = tgt_cell.size
                dst_slot = np.full(n, t_slot, dtype=np.int32)
                src_slot = np.full(src_cell.shape, s_slot, dtype=np.int32)
                for name in names:
                    if nb.owner == blk.owner:
                        local_acc.setdefault(blk.owner, {}).setdefault(
                            (name, blk.level, nb.level, kind), []
                        ).append((dst_slot, tgt_cell, src_slot, src_cell))
                    else:
                        # data flows owner(neighbor) -> owner(block); one
                        # aligned append per side keeps sender gather order
                        # == receiver scatter order by construction
                        msg_acc.setdefault(
                            (nb.owner, blk.owner, name), {}
                        ).setdefault((nb.level, kind), []).append(
                            (src_slot, src_cell, blk.level, dst_slot, tgt_cell)
                        )
    local = {
        rank: CompiledGhostPlan(
            fields=tuple(fields),
            levels=None if levels is None else frozenset(levels),
            ops=tuple(
                CompiledGhostOp(
                    field=name,
                    dst_level=dl,
                    src_level=sl,
                    kind=kind,
                    dst_slot=np.concatenate([e[0] for e in entries]),
                    dst_cell=np.concatenate([e[1] for e in entries]).astype(np.int32),
                    src_slot=np.concatenate([e[2] for e in entries]),
                    src_cell=np.concatenate([e[3] for e in entries]).astype(np.int32),
                )
                for (name, dl, sl, kind), entries in sorted(acc.items())
            ),
        )
        for rank, acc in local_acc.items()
    }
    messages = []
    for (src_rank, dst_rank, name), seg_map in sorted(msg_acc.items()):
        gather, scatter, total = [], [], 0
        for (src_level, kind), entries in sorted(seg_map.items()):
            g_slot = np.concatenate([e[0] for e in entries])
            g_cell = np.concatenate([e[1] for e in entries]).astype(np.int32)
            gather.append((src_level, kind, g_slot, g_cell))
            # within a (src_level, kind) segment all dst levels agree (the
            # kind fixes the level offset), so one scatter segment suffices
            dst_levels = {e[2] for e in entries}
            assert len(dst_levels) == 1, (src_rank, dst_rank, name, dst_levels)
            d_slot = np.concatenate([e[3] for e in entries])
            d_cell = np.concatenate([e[4] for e in entries]).astype(np.int32)
            scatter.append((dst_levels.pop(), d_slot, d_cell, int(d_cell.size)))
            total += int(d_cell.size)
        messages.append(
            CompiledRankMessage(
                src_rank=src_rank,
                dst_rank=dst_rank,
                field=name,
                nbytes=total * lead[name] * itemsize[name],
                num_cells=total,
                gather=tuple(gather),
                scatter=tuple(scatter),
            )
        )
    return CompiledRankHaloPlan(
        fields=tuple(fields),
        levels=None if levels is None else frozenset(levels),
        local=local,
        messages=tuple(messages),
    )


# ---------------------------------------------------------------------------
# Device-fabric lowering: ppermute rounds + equal-blocks-per-rank padding
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class PpermuteRound:
    """One ``jax.lax.ppermute`` call covering a set of rank-pair messages.

    ``ppermute`` is a partial permutation: each device sends at most one
    payload and receives at most one per call. A halo exchange generally has
    several messages per rank (one per neighboring pair and field), so the
    message set is decomposed into rounds where every source and every
    destination appears at most once. ``perm`` is the ``(src, dst)`` list in
    the exact form ``ppermute`` takes; ``messages`` is aligned with it, and
    ``num_cells`` is the padded per-payload row count for the round (every
    participant ships the same shape — the SPMD program is identical on all
    ranks, shorter messages are zero-padded and the pad rows are dropped by
    the receiver's scatter, which only reads ``message.num_cells`` rows).
    """

    perm: tuple[tuple[int, int], ...]
    messages: tuple[CompiledRankMessage, ...]
    num_cells: int

    def pad_cells(self) -> int:
        """Zero rows shipped beyond the logical payloads (wire overhead)."""
        return sum(self.num_cells - m.num_cells for m in self.messages)


def schedule_ppermute_rounds(
    messages: tuple[CompiledRankMessage, ...],
) -> tuple[PpermuteRound, ...]:
    """Greedily decompose rank-pair messages into partial permutations.

    Messages are scanned in the deterministic plan order (sorted by
    ``(src_rank, dst_rank, field)`` — :func:`compile_rank_halo_plan` emits
    them that way) and each is placed in the first round where its source is
    not yet sending and its destination not yet receiving, so the schedule is
    a pure function of the plan. For the face-neighbor traffic of an SFC
    partition this yields O(max rank degree) rounds, independent of the rank
    count — the per-process boundedness column of Table 1 carried over to the
    collective schedule.
    """
    rounds: list[tuple[list[tuple[int, int]], list[CompiledRankMessage]]] = []
    for m in messages:
        for perm, ms in rounds:
            if all(s != m.src_rank for s, _ in perm) and all(
                d != m.dst_rank for _, d in perm
            ):
                perm.append((m.src_rank, m.dst_rank))
                ms.append(m)
                break
        else:
            rounds.append(([(m.src_rank, m.dst_rank)], [m]))
    return tuple(
        PpermuteRound(
            perm=tuple(perm),
            messages=tuple(ms),
            num_cells=max(m.num_cells for m in ms),
        )
        for perm, ms in rounds
    )


def padded_block_counts(
    rank_slots: dict[int, dict[int, dict[int, int]]], nranks: int
) -> dict[int, int]:
    """Per-level block-stack height shared by every rank (max over ranks).

    The device fabric runs one SPMD program, so each level's block stack must
    have the same shape on every rank: ranks owning fewer blocks pad with
    masked slots (all-WALL mask, weight-vector PDFs — an exact fixed point of
    the kernel, see ``DeviceShardedEngine``). Rank-local slot ids stay valid
    in the padded ``(nranks, count, ...)`` layout unchanged, because arenas
    assign slots densely from zero.
    """
    counts: dict[int, int] = {}
    for r in range(nranks):
        for lvl, slots in rank_slots.get(r, {}).items():
            counts[lvl] = max(counts.get(lvl, 0), len(slots))
    return counts


def verify_padded_plan(
    plan: CompiledRankHaloPlan,
    rank_slots: dict[int, dict[int, dict[int, int]]],
) -> list[str]:
    """Prove the lowered plan never reads or writes a padded slot.

    Every gather/scatter slot index must address a *real* block of the
    owning rank (slot < that rank's block count on the level); the padded
    slots above are only ever touched by the kernel's masked no-op step.
    Returns human-readable violations (empty == safe), in the style of
    ``repro.analysis.plan_verify``.
    """
    problems: list[str] = []

    def nblocks(rank: int, level: int) -> int:
        return len(rank_slots.get(rank, {}).get(level, {}))

    for rank, local in plan.local.items():
        for op in local.ops:
            if op.dst_slot.size and int(op.dst_slot.max()) >= nblocks(rank, op.dst_level):
                problems.append(
                    f"local[{rank}] {op.field}: dst_slot {int(op.dst_slot.max())} "
                    f"exceeds {nblocks(rank, op.dst_level)} blocks at level {op.dst_level}"
                )
            if op.src_slot.size and int(op.src_slot.max()) >= nblocks(rank, op.src_level):
                problems.append(
                    f"local[{rank}] {op.field}: src_slot {int(op.src_slot.max())} "
                    f"exceeds {nblocks(rank, op.src_level)} blocks at level {op.src_level}"
                )
    for m in plan.messages:
        for src_level, _kind, src_slot, _src_cell in m.gather:
            if src_slot.size and int(src_slot.max()) >= nblocks(m.src_rank, src_level):
                problems.append(
                    f"message {m.key}: gather slot {int(src_slot.max())} exceeds "
                    f"{nblocks(m.src_rank, src_level)} blocks at level {src_level}"
                )
        for dst_level, dst_slot, _dst_cell, _n in m.scatter:
            if dst_slot.size and int(dst_slot.max()) >= nblocks(m.dst_rank, dst_level):
                problems.append(
                    f"message {m.key}: scatter slot {int(dst_slot.max())} exceeds "
                    f"{nblocks(m.dst_rank, dst_level)} blocks at level {dst_level}"
                )
    return problems
