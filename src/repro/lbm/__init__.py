"""Lattice Boltzmann substrate (paper §3, §5).

D3Q19/D3Q27 lattices, BGK and TRT collision operators, halfway bounce-back
(no-slip) and velocity bounce-back (moving lid) boundaries, per-block uniform
grids with ghost layers, the volumetric coarse<->fine PDF conversion used
during dynamic refinement (paper §3.3, [54]/[16]), the velocity-gradient
refinement criterion (§3.1), and the AMR-coupled simulation driver.
"""

from .lattice import D3Q19, D3Q27, Lattice
from .grid import CellType, LBMBlockSpec, make_lbm_fields, make_lbm_registry

__all__ = [
    "D3Q19",
    "D3Q27",
    "Lattice",
    "CellType",
    "LBMBlockSpec",
    "make_lbm_fields",
    "make_lbm_registry",
    "AMRLBM",
    "LidDrivenCavityConfig",
]


def __getattr__(name):  # lazy: avoids kernels<->lbm circular import
    if name in ("AMRLBM", "LidDrivenCavityConfig"):
        from .driver import AMRLBM, LidDrivenCavityConfig

        return {"AMRLBM": AMRLBM, "LidDrivenCavityConfig": LidDrivenCavityConfig}[name]
    raise AttributeError(name)
