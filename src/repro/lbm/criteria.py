"""Refinement criteria for the LBM (paper §3.1).

The velocity-gradient criterion used by the paper's example application
(§3.1/§5.2): per cell, sum the absolute values of all nine components of the
dimensionless velocity gradient (characteristic length 1, so only
subtractions are needed). A block is marked for refinement if the sum
exceeds an upper limit in *any* cell, and for potential coarsening if it
stays below a lower limit in *all* cells.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Mapping

import numpy as np

from ..core.forest import Block
from .grid import CellType, LBMBlockSpec
from .lattice import Lattice

__all__ = ["VelocityGradientCriterion", "macroscopic"]


def macroscopic(pdf: np.ndarray, lattice: Lattice) -> tuple[np.ndarray, np.ndarray]:
    """(rho, u) from a (Q, X, Y, Z) PDF array (numpy)."""
    c = lattice.c.astype(pdf.dtype)
    rho = pdf.sum(axis=0)
    u = np.einsum("qxyz,qd->dxyz", pdf, c) / np.maximum(rho, 1e-12)[None]
    return rho, u


@dataclass
class VelocityGradientCriterion:
    """Callable usable as the AMR pipeline's mark callback."""

    spec: LBMBlockSpec
    upper: float
    lower: float
    max_level: int
    min_level: int = 0

    def cell_indicator(self, blk: Block) -> np.ndarray:
        pdf = blk.data["pdf"]
        mask = blk.data["mask"]
        _rho, u = macroscopic(pdf, self.spec.lattice)
        u = u * (mask == CellType.FLUID)[None]
        s = np.zeros(u.shape[1:], dtype=np.float64)
        for d in range(3):  # velocity component
            for ax in (1, 2, 3):  # gradient direction
                grad = np.abs(np.diff(u[d], axis=ax - 1, append=np.take(u[d], [-1], axis=ax - 1)))
                s += grad
        return self.spec.interior(s)

    def __call__(self, _rank: int, blocks: Mapping[int, Block]) -> dict[int, int]:
        out: dict[int, int] = {}
        for bid, blk in blocks.items():
            s = self.cell_indicator(blk)
            if s.max(initial=0.0) > self.upper and blk.level < self.max_level:
                out[bid] = blk.level + 1
            elif s.max(initial=0.0) < self.lower and blk.level > self.min_level:
                out[bid] = blk.level - 1
        return out
