"""AMR-coupled LBM simulation driver (paper §3, §5).

Couples the data plane (per-block grids, fused stream+collide kernel, halo
exchange) with the control plane (the four-step AMR pipeline):

* per-level time stepping: a level-l block advances 2^l times per coarsest
  step with the level-scaled relaxation rate (acoustic scaling), the program
  flow the paper's data structures support (§2: "methods that require more
  time steps on finer levels");
* every ``amr_interval`` coarse steps the refinement criterion is evaluated
  and one AMR cycle (mark -> proxy -> balance -> migrate) is executed;
* cell types are re-derived from the analytic domain geometry after every
  repartitioning, which restores the §3.3 overlap-consistency invariant
  (octets of fine cells agree with the overlapping coarse cell) exactly.

Stepping modes (``LidDrivenCavityConfig.stepping_mode``): one
:class:`~repro.lbm.engines.StepEngine` per mode —
``"restack"`` (seed baseline), ``"arena"`` (default, persistent host
buffers), ``"fused"`` (single device program per coarse step),
``"sharded"`` (rank-partitioned host data plane with p2p halo messages),
and ``"fused_sharded"`` (per-rank device programs + device-built p2p
messages). See the README's *Choosing a stepping mode* decision table for
workload/rank-count guidance and ARCHITECTURE.md for the engine mode
matrix; :mod:`repro.lbm.engines` documents the engine contract itself.

Data-plane traffic is attributed in :attr:`AMRLBM.data_stats`: host modes
fill ``"halo"`` / ``"step"``; the device-resident modes cannot split their
in-program exchange from their stepping, so they report wall time plus
exchange rounds (and, for ``fused_sharded``, the cross-rank p2p traffic)
under ``"fused"`` (host<->device transfer counts live on the arenas'
:class:`~repro.core.fields.DeviceResidency`).

With ``particles=ParticlesConfig(...)`` a Lagrangian tracer layer rides the
forest (see :mod:`repro.particles` and the README support matrix): once per
coarse step the tracers advect through the block-local velocity field (RK2,
trilinear) and redistribute to their new block/rank over the ``Comm`` fabric
(attributed under ``data_stats["particles"]``). All five stepping modes are
supported — the advection batch source is an engine hook
(:meth:`~repro.lbm.engines.StepEngine.particle_batches`): restack/arena
advect per level over host stacks, the sharded engines run one batch per
rank over that rank's own buffers, and the device-resident engines
materialize host views once per coarse step (tracer advection is a host
consumer, like diagnostics). The particle load model (``cells + alpha * N``)
feeds the balancer through the pipeline's weight hooks.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Callable

import numpy as np

from ..core import (
    AMRPipeline,
    Comm,
    DeviceComm,
    DiffusionBalancer,
    ForestGeometry,
    SFCBalancer,
    make_uniform_forest,
    recompute_weights,
)
from ..core.forest import Block, BlockForest
from ..core.pipeline import StageStats
from ..telemetry import get_tracer
from ..particles import (
    ParticlesConfig,
    advect_block_batch,
    particle_block_weight,
    particle_proxy_weight,
    redistribute_particles,
    register_particles,
    seed_particles,
)
from ..particles import total_particles as _forest_total_particles
from .criteria import VelocityGradientCriterion, macroscopic
from .engines import ENGINES, make_engine
from .grid import CellType, LBMBlockSpec, block_world_box, make_lbm_fields
from .lattice import D3Q19

__all__ = ["LidDrivenCavityConfig", "AMRLBM"]

_TR = get_tracer()


@dataclass
class LidDrivenCavityConfig:
    root_grid: tuple[int, int, int] = (2, 2, 2)
    cells_per_block: tuple[int, int, int] = (8, 8, 8)
    ghost: int = 1
    nranks: int = 4
    omega: float = 1.6
    u_lid: tuple[float, float, float] = (0.05, 0.0, 0.0)
    collision: str = "trt"
    max_level: int = 2
    refine_upper: float = 0.06
    refine_lower: float = 0.015
    balancer: str = "diffusion-pushpull"  # | "diffusion-push" | "morton" | "hilbert"
    kernel_backend: str = "pallas"
    # Pallas interpret override: None resolves once at program-build time to
    # "interpret iff jax.default_backend() == 'cpu'" (see
    # repro.kernels.lbm_collide.resolve_interpret); set a bool to force it
    kernel_interpret: bool | None = None
    # pdf buffer donation for the compiled superstep programs: None resolves
    # at program-build time to "donate iff the backend is not CPU" (XLA:CPU
    # codegen under aliasing drifts by one ulp, breaking the bitwise
    # conformance contract; see repro.kernels.lbm_collide.resolve_donate)
    donate_pdfs: bool | None = None
    # interior/boundary split of the fused_sharded substep (overlaps host
    # message routing with interior stepping): None resolves like donation —
    # split iff the backend is not CPU, because XLA:CPU compiles the
    # sub-stack stencil with context-dependent rounding (one ulp off the
    # unsplit program, breaking the bitwise conformance contract)
    overlap_split: bool | None = None
    # one StepEngine per mode; see README "Choosing a stepping mode"
    stepping_mode: str = "arena"  # | "fused" | "sharded" | "fused_sharded" | "device_sharded" | "restack"
    obstacle_fn: Callable[[np.ndarray], np.ndarray] | None = None  # (N,3)->bool
    # optional Lagrangian tracer layer (repro.particles); None disables it
    particles: ParticlesConfig | None = None


def _make_balancer(name: str):
    if name == "morton":
        return SFCBalancer(order="morton", per_level=True)
    if name == "hilbert":
        return SFCBalancer(order="hilbert", per_level=True)
    if name == "diffusion-push":
        return DiffusionBalancer(mode="push", flow_iterations=15, max_main_iterations=20)
    if name == "diffusion-pushpull":
        return DiffusionBalancer(mode="pushpull", flow_iterations=5, max_main_iterations=20)
    raise ValueError(name)


class AMRLBM:
    def __init__(self, cfg: LidDrivenCavityConfig):
        self.cfg = cfg
        assert cfg.stepping_mode in ENGINES, (
            cfg.stepping_mode,
            sorted(ENGINES),
        )
        for n in cfg.cells_per_block:
            # the real invariant (shared with FieldRegistry and ghost_regions):
            # even cells keep octant splits and 2:1 halo regions cell-aligned;
            # powers of two are NOT required
            assert n > 0 and n % 2 == 0, (
                "cells per block must be even (octant split + halo alignment)"
            )
        self.spec = LBMBlockSpec(
            cells=cfg.cells_per_block, ghost=cfg.ghost, lattice=D3Q19
        )
        self.geom = ForestGeometry(root_grid=cfg.root_grid, max_level=12)
        self.fields = make_lbm_fields(self.spec)
        self.registry = self.fields  # typed registry drives all subsystems
        # device_sharded moves halo payloads as in-program ppermute; the
        # DeviceComm fabric attributes those bytes into the same counters
        comm_cls = DeviceComm if cfg.stepping_mode == "device_sharded" else Comm
        self.comm = comm_cls(cfg.nranks)
        # Lagrangian tracers: the particle set registers as one more §2.5
        # block-data item (migration/checkpoint/resilience come for free) and
        # installs the cells + alpha*N load model into the pipeline, so the
        # balancers finally see a genuinely heterogeneous load.
        self._block_weight_fn = None
        if cfg.particles is not None:
            register_particles(self.fields, self.geom)
            self._block_weight_fn = particle_block_weight(
                cfg.cells_per_block, cfg.particles.alpha
            )
        self.pipeline = AMRPipeline(
            balancer=_make_balancer(cfg.balancer),
            registry=self.registry,
            weight_fn=(
                particle_proxy_weight(
                    self.geom, cfg.cells_per_block, cfg.particles.alpha
                )
                if cfg.particles is not None
                else None
            ),
            block_weight_fn=self._block_weight_fn,
        )
        self.criterion = VelocityGradientCriterion(
            spec=self.spec,
            upper=cfg.refine_upper,
            lower=cfg.refine_lower,
            max_level=cfg.max_level,
        )
        self.forest: BlockForest = make_uniform_forest(self.geom, cfg.nranks, level=0)
        # data-plane stage attribution (sharded halo bytes/rounds live here,
        # mirroring the control plane's CycleReport.stages); the device-
        # resident engines report their single-program wall time + exchange
        # rounds under "fused" (halo and step are indistinguishable on device)
        self.data_stats: dict[str, StageStats] = {
            "halo": StageStats(),
            "step": StageStats(),
            "fused": StageStats(),
            "particles": StageStats(),
        }
        # cumulative tracer counters (benchmarks/diagnostics)
        self.particles_advected = 0
        self.particles_moved = 0
        # the data plane: storage, steppers, plan/mask/program caches, and
        # the per-mode advance loop all live on the engine
        self.engine = make_engine(self)
        for blk in self.forest.all_blocks():
            self._init_block(blk)
        if cfg.particles is not None:
            seed_particles(
                self.forest,
                self.geom,
                per_block=cfg.particles.per_block,
                seed=cfg.particles.seed,
                region=cfg.particles.region,
            )
            recompute_weights(self.forest, self._block_weight_fn)
        self.engine.adopt(self.forest)
        self.refresh_masks()
        self.coarse_step = 0
        self.amr_cycles = 0

    # -- engine-owned storage (stable public aliases) ---------------------------
    @property
    def arena(self):
        """The single global :class:`LevelArena` (arena/fused engines)."""
        return self.engine.arena

    @property
    def arenas(self):
        """The per-rank :class:`RankArenas` (sharded engines)."""
        return self.engine.arenas

    @property
    def _halo_plans(self):
        return self.engine._halo_plans

    # -- block initialization & masks ----------------------------------------
    def _init_block(self, blk: Block) -> None:
        import jax.numpy as jnp

        from ..kernels.lbm_collide.ref import equilibrium

        rho = jnp.ones(self.spec.mask_shape, dtype=jnp.float32)
        u = jnp.zeros((3, *self.spec.mask_shape), dtype=jnp.float32)
        blk.data["pdf"] = np.array(equilibrium(rho, u, self.spec.lattice))  # copy: must stay writable
        blk.data["mask"] = self.fields.alloc("mask")

    def _cell_centers(self, blk: Block) -> np.ndarray:
        """World coordinates of all (ghosted) cell centers, shape (X,Y,Z,3)."""
        lo, hi = block_world_box(self.geom, blk.bid)
        n = np.asarray(self.spec.cells, dtype=np.float64)
        h = (hi - lo) / n
        g = self.spec.ghost
        axes = [
            lo[d] + (np.arange(-g, n[d] + g) + 0.5) * h[d] for d in range(3)
        ]
        return np.stack(np.meshgrid(*axes, indexing="ij"), axis=-1)

    def refresh_masks(self) -> None:
        """Re-derive cell types from the analytic geometry (domain walls, the
        moving lid at the top z face, optional obstacles). Writes in place so
        arena views stay bound; the engine's device mask state is invalidated."""
        top = float(self.geom.root_grid[2])
        for blk in self.forest.all_blocks():
            xyz = self._cell_centers(blk)
            mask = np.zeros(xyz.shape[:-1], dtype=np.int32)
            outside = (
                (xyz[..., 0] < 0.0)
                | (xyz[..., 0] > self.geom.root_grid[0])
                | (xyz[..., 1] < 0.0)
                | (xyz[..., 1] > self.geom.root_grid[1])
                | (xyz[..., 2] < 0.0)
            )
            mask[outside] = CellType.WALL
            mask[xyz[..., 2] > top] = CellType.LID
            if self.cfg.obstacle_fn is not None:
                obst = self.cfg.obstacle_fn(xyz.reshape(-1, 3)).reshape(mask.shape)
                mask[obst & (mask == 0)] = CellType.WALL
            blk.data["mask"][...] = mask
        self.engine.masks_refreshed()

    def materialize_host(self) -> None:
        """Flush device-newer buffers into the host arena(s) (device-resident
        engines) so every ``Block.data`` view is current. Diagnostics and
        :meth:`adapt` call this automatically; external consumers of
        per-block host data — ``save_checkpoint``, the resilience manager,
        visualization — must call it before reading when stepping in a
        device-resident mode (no-op in the host-resident modes)."""
        self.engine.materialize_host()

    # -- Lagrangian tracers -----------------------------------------------------
    def _step_particles(self) -> None:
        """Advect tracers through the end-of-step velocity field and route
        escapees to their new block/rank (batched p2p, one message per rank
        pair). Runs once per coarse step in every stepping mode."""
        self.materialize_host()  # device modes: host pdf views must be current
        # Ghost layers must be a deterministic function of the (mode-
        # identical) interiors so interpolation reads the same values in
        # every mode. The next substep's exchange overwrites them again —
        # and the device-resident programs re-exchange all levels at substep
        # 0 before any device read — so this host-side write needs no
        # residency drop.
        self.engine.exchange_ghosts()
        s0 = self.comm.stats.summary()
        with _TR.stage("particles", cat="stage") as sp:
            advected = 0
            for level in self.forest.levels_in_use():
                for pdf, mask, slots, blocks in self.engine.particle_batches(level):
                    advected += advect_block_batch(
                        pdf,
                        mask,
                        self.spec.lattice,
                        self.geom,
                        blocks,
                        slots,
                        level=level,
                        cells=self.spec.cells,
                        ghost=self.spec.ghost,
                    )
            moved, _cross_bytes = redistribute_particles(
                self.forest,
                self.geom,
                self.comm,
                boundary=self.cfg.particles.boundary,
            )
        self.particles_advected += advected
        self.particles_moved += moved
        self.data_stats["particles"].add(
            StageStats.delta(s0, self.comm.stats.summary(), sp.seconds)
        )

    def advance(self, coarse_steps: int = 1) -> None:
        """Advance by coarse time steps with per-level substepping."""
        self.engine.sync_caches()
        if self.cfg.particles is None:
            self.engine.advance(coarse_steps)
            self.coarse_step += coarse_steps
            return
        for _ in range(coarse_steps):
            self.engine.advance(1)
            self.coarse_step += 1
            self._step_particles()

    # -- AMR ------------------------------------------------------------------
    def adapt(self, force_rebalance: bool = False):
        """Evaluate the refinement criterion and run one AMR cycle."""
        self.materialize_host()  # criterion + migration read host views
        self.forest, report = self.pipeline.run_cycle(
            self.forest, self.comm, self.criterion, force_rebalance=force_rebalance
        )
        if report.executed:
            self.amr_cycles += 1
            _TR.instant(
                "amr.event", cat="amr", cycle=self.amr_cycles,
                blocks=self.forest.num_blocks(),
            )
            self.engine.adopt(self.forest)  # repack/rebuild storage, rebind views
            self.engine.sync_caches()
            self.refresh_masks()
            self.engine.exchange_ghosts()
        return report

    def run(self, coarse_steps: int, amr_interval: int = 4) -> None:
        for i in range(coarse_steps):
            self.advance(1)
            if (i + 1) % amr_interval == 0:
                self.adapt()

    # -- diagnostics -----------------------------------------------------------
    def _interior(self, arr: np.ndarray) -> np.ndarray:
        """Interior (non-ghost) slice of a per-block array (ghost-0 safe)."""
        return self.spec.interior(arr)

    def total_mass(self) -> float:
        self.materialize_host()
        total = 0.0
        for b in self.forest.all_blocks():
            interior = self._interior(b.data["pdf"])
            fluid = self._interior(b.data["mask"]) == CellType.FLUID
            # level-l cells have volume 8^-l of a root-cell unit
            total += float((interior.sum(axis=0) * fluid).sum()) * (8.0 ** -b.level)
        return total

    def max_velocity(self) -> float:
        self.materialize_host()
        vmax = 0.0
        for b in self.forest.all_blocks():
            _rho, u = macroscopic(b.data["pdf"], self.spec.lattice)
            fluid = b.data["mask"] == CellType.FLUID
            speed = np.sqrt((u**2).sum(axis=0)) * fluid
            vmax = max(vmax, float(self._interior(speed).max(initial=0.0)))
        return vmax

    def total_particles(self) -> int:
        """Tracer population across the whole forest (conservation probe)."""
        return _forest_total_particles(self.forest)

    def num_fluid_cells(self) -> int:
        return int(
            sum(
                (self._interior(b.data["mask"]) == CellType.FLUID).sum()
                for b in self.forest.all_blocks()
            )
        )
