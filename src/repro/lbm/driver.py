"""AMR-coupled LBM simulation driver (paper §3, §5).

Couples the data plane (per-block grids, fused stream+collide kernel, halo
exchange) with the control plane (the four-step AMR pipeline):

* per-level time stepping: a level-l block advances 2^l times per coarsest
  step with the level-scaled relaxation rate (acoustic scaling), the program
  flow the paper's data structures support (§2: "methods that require more
  time steps on finer levels");
* every ``amr_interval`` coarse steps the refinement criterion is evaluated
  and one AMR cycle (mark -> proxy -> balance -> migrate) is executed;
* cell types are re-derived from the analytic domain geometry after every
  repartitioning, which restores the §3.3 overlap-consistency invariant
  (octets of fine cells agree with the overlapping coarse cell) exactly.

Stepping modes (``LidDrivenCavityConfig.stepping_mode``):

==============  ================================================================
mode            data plane per coarse step
==============  ================================================================
``"fused"``     device-resident: the whole ``2^lmax`` substep cycle — per-level
                activity masks, compiled ghost exchange, stream+collide — is
                one jitted program over persistent device buffers
                (:meth:`~repro.core.fields.LevelArena.device`). Zero host
                transfers between AMR events; host views are rematerialized
                on demand for diagnostics/migration/checkpointing.
``"arena"``     persistent per-level :class:`~repro.core.fields.LevelArena`
(default)       host buffers; every ``Block.data`` entry is a zero-copy view,
                ghost exchange writes in place (numpy), and the kernel's
                arena entry point steps a whole level per call — but each
                substep still round-trips host<->device once per level.
``"sharded"``   the rank-sharded data plane: one
                :class:`~repro.core.fields.RankArenas` arena per simulated
                rank holding only locally-owned blocks; intra-rank ghost
                faces copy in place, cross-rank faces travel as batched p2p
                messages over :class:`~repro.core.Comm` (sender-side
                resampling); one kernel call per rank per level, batched
                across ranks with equal block counts.
``"restack"``   the seed behavior (stack all blocks of a level into a fresh
                array every substep, copy results back out per block) — the
                benchmark baseline.
==============  ================================================================

Data-plane traffic is attributed in :attr:`AMRLBM.data_stats`: host modes
fill ``"halo"`` / ``"step"``; the fused path cannot split its in-program
exchange from its stepping, so it reports wall time plus in-program exchange
rounds under ``"fused"`` (host<->device transfer counts live on the arena's
:class:`~repro.core.fields.DeviceResidency`).

With ``particles=ParticlesConfig(...)`` a Lagrangian tracer layer rides the
forest (see :mod:`repro.particles` and the README support matrix): once per
coarse step the tracers advect through the block-local velocity field (RK2,
trilinear) and redistribute to their new block/rank over the ``Comm`` fabric
(attributed under ``data_stats["particles"]``). All four stepping modes are
supported — restack/arena advect per level over host stacks, sharded runs
one batch per rank over that rank's own buffers, and fused materializes host
views once per coarse step (tracer advection is a host consumer, like
diagnostics). The particle load model (``cells + alpha * N``) feeds the
balancer through the pipeline's weight hooks.
"""

from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from ..core import (
    AMRPipeline,
    Comm,
    DiffusionBalancer,
    ForestGeometry,
    LevelArena,
    RankArenas,
    SFCBalancer,
    make_uniform_forest,
    recompute_weights,
)
from ..core.forest import Block, BlockForest
from ..core.pipeline import StageStats
from ..particles import (
    ParticlesConfig,
    advect_block_batch,
    particle_block_weight,
    particle_proxy_weight,
    redistribute_particles,
    register_particles,
    seed_particles,
)
from ..particles import total_particles as _forest_total_particles
from ..kernels.lbm_collide.ops import (
    make_arena_stream_collide,
    make_fused_superstep,
    make_stream_collide,
)
from ..kernels.lbm_collide.ref import equilibrium
from .criteria import VelocityGradientCriterion, macroscopic
from .grid import CellType, LBMBlockSpec, block_world_box, make_lbm_fields
from .halo import compile_ghost_plan, fill_ghost_layers, fill_ghost_layers_sharded
from .lattice import D3Q19, omega_for_level

__all__ = ["LidDrivenCavityConfig", "AMRLBM"]


@dataclass
class LidDrivenCavityConfig:
    root_grid: tuple[int, int, int] = (2, 2, 2)
    cells_per_block: tuple[int, int, int] = (8, 8, 8)
    ghost: int = 1
    nranks: int = 4
    omega: float = 1.6
    u_lid: tuple[float, float, float] = (0.05, 0.0, 0.0)
    collision: str = "trt"
    max_level: int = 2
    refine_upper: float = 0.06
    refine_lower: float = 0.015
    balancer: str = "diffusion-pushpull"  # | "diffusion-push" | "morton" | "hilbert"
    kernel_backend: str = "pallas"
    stepping_mode: str = "arena"  # | "fused" (device) | "sharded" (per-rank) | "restack" (seed)
    obstacle_fn: Callable[[np.ndarray], np.ndarray] | None = None  # (N,3)->bool
    # optional Lagrangian tracer layer (repro.particles); None disables it
    particles: ParticlesConfig | None = None


def _make_balancer(name: str):
    if name == "morton":
        return SFCBalancer(order="morton", per_level=True)
    if name == "hilbert":
        return SFCBalancer(order="hilbert", per_level=True)
    if name == "diffusion-push":
        return DiffusionBalancer(mode="push", flow_iterations=15, max_main_iterations=20)
    if name == "diffusion-pushpull":
        return DiffusionBalancer(mode="pushpull", flow_iterations=5, max_main_iterations=20)
    raise ValueError(name)


class AMRLBM:
    def __init__(self, cfg: LidDrivenCavityConfig):
        self.cfg = cfg
        assert cfg.stepping_mode in ("arena", "fused", "sharded", "restack"), (
            cfg.stepping_mode
        )
        for n in cfg.cells_per_block:
            # the real invariant (shared with FieldRegistry and ghost_regions):
            # even cells keep octant splits and 2:1 halo regions cell-aligned;
            # powers of two are NOT required
            assert n > 0 and n % 2 == 0, (
                "cells per block must be even (octant split + halo alignment)"
            )
        self.spec = LBMBlockSpec(
            cells=cfg.cells_per_block, ghost=cfg.ghost, lattice=D3Q19
        )
        self.geom = ForestGeometry(root_grid=cfg.root_grid, max_level=12)
        self.fields = make_lbm_fields(self.spec)
        self.registry = self.fields  # typed registry drives all subsystems
        # restack mode never reads SoA buffers — don't pay for keeping them
        self.arena: LevelArena | None = (
            LevelArena(self.fields)
            if cfg.stepping_mode in ("arena", "fused")
            else None
        )
        # sharded mode: one rank-local arena set per simulated rank
        self.arenas: RankArenas | None = (
            RankArenas(self.fields, cfg.nranks)
            if cfg.stepping_mode == "sharded"
            else None
        )
        self.comm = Comm(cfg.nranks)
        # Lagrangian tracers: the particle set registers as one more §2.5
        # block-data item (migration/checkpoint/resilience come for free) and
        # installs the cells + alpha*N load model into the pipeline, so the
        # balancers finally see a genuinely heterogeneous load.
        self._block_weight_fn = None
        if cfg.particles is not None:
            register_particles(self.fields, self.geom)
            self._block_weight_fn = particle_block_weight(
                cfg.cells_per_block, cfg.particles.alpha
            )
        self.pipeline = AMRPipeline(
            balancer=_make_balancer(cfg.balancer),
            registry=self.registry,
            weight_fn=(
                particle_proxy_weight(
                    self.geom, cfg.cells_per_block, cfg.particles.alpha
                )
                if cfg.particles is not None
                else None
            ),
            block_weight_fn=self._block_weight_fn,
        )
        self.criterion = VelocityGradientCriterion(
            spec=self.spec,
            upper=cfg.refine_upper,
            lower=cfg.refine_lower,
            max_level=cfg.max_level,
        )
        self.forest: BlockForest = make_uniform_forest(self.geom, cfg.nranks, level=0)
        self._steppers: dict[int, Callable] = {}
        # device mask cache; keys: level (arena) or (level, ranks) (sharded)
        self._mask_dev: dict = {}
        # ghost-exchange plans keyed by active level set; valid between arena
        # adoptions (restack mode rebinds arrays per substep, so no caching)
        self._halo_plans: dict | None = (
            {} if (self.arena is not None or self.arenas is not None) else None
        )
        self._cache_version = -1  # last arena.version the caches were built for
        # fused superstep program cache: (arena version, level tuple) -> fn
        self._fused_fn = None
        self._fused_key: tuple | None = None
        self._fused_steppers: dict[int, Callable] = {}
        # data-plane stage attribution (sharded halo bytes/rounds live here,
        # mirroring the control plane's CycleReport.stages); the fused path
        # reports its single-program wall time + in-program exchange rounds
        # under "fused" (halo and step are indistinguishable on device)
        self.data_stats: dict[str, StageStats] = {
            "halo": StageStats(),
            "step": StageStats(),
            "fused": StageStats(),
            "particles": StageStats(),
        }
        # cumulative tracer counters (benchmarks/diagnostics)
        self.particles_advected = 0
        self.particles_moved = 0
        for blk in self.forest.all_blocks():
            self._init_block(blk)
        if cfg.particles is not None:
            seed_particles(
                self.forest,
                self.geom,
                per_block=cfg.particles.per_block,
                seed=cfg.particles.seed,
                region=cfg.particles.region,
            )
            recompute_weights(self.forest, self._block_weight_fn)
        if self.arena is not None:
            self.arena.adopt(self.forest)
        if self.arenas is not None:
            self.arenas.adopt(self.forest)
        self.refresh_masks()
        self.coarse_step = 0
        self.amr_cycles = 0

    # -- block initialization & masks ----------------------------------------
    def _init_block(self, blk: Block) -> None:
        rho = jnp.ones(self.spec.mask_shape, dtype=jnp.float32)
        u = jnp.zeros((3, *self.spec.mask_shape), dtype=jnp.float32)
        blk.data["pdf"] = np.array(equilibrium(rho, u, self.spec.lattice))  # copy: must stay writable
        blk.data["mask"] = self.fields.alloc("mask")

    def _cell_centers(self, blk: Block) -> np.ndarray:
        """World coordinates of all (ghosted) cell centers, shape (X,Y,Z,3)."""
        lo, hi = block_world_box(self.geom, blk.bid)
        n = np.asarray(self.spec.cells, dtype=np.float64)
        h = (hi - lo) / n
        g = self.spec.ghost
        axes = [
            lo[d] + (np.arange(-g, n[d] + g) + 0.5) * h[d] for d in range(3)
        ]
        return np.stack(np.meshgrid(*axes, indexing="ij"), axis=-1)

    def refresh_masks(self) -> None:
        """Re-derive cell types from the analytic geometry (domain walls, the
        moving lid at the top z face, optional obstacles). Writes in place so
        arena views stay bound; the device mask cache is invalidated."""
        top = float(self.geom.root_grid[2])
        for blk in self.forest.all_blocks():
            xyz = self._cell_centers(blk)
            mask = np.zeros(xyz.shape[:-1], dtype=np.int32)
            outside = (
                (xyz[..., 0] < 0.0)
                | (xyz[..., 0] > self.geom.root_grid[0])
                | (xyz[..., 1] < 0.0)
                | (xyz[..., 1] > self.geom.root_grid[1])
                | (xyz[..., 2] < 0.0)
            )
            mask[outside] = CellType.WALL
            mask[xyz[..., 2] > top] = CellType.LID
            if self.cfg.obstacle_fn is not None:
                obst = self.cfg.obstacle_fn(xyz.reshape(-1, 3)).reshape(mask.shape)
                mask[obst & (mask == 0)] = CellType.WALL
            blk.data["mask"][...] = mask
        self._mask_dev.clear()
        if self.arena is not None:
            # host-side write: device mask copies (and the fused program that
            # baked them in) are stale
            self.arena.device().drop(name="mask")
            self._fused_fn = None
            self._fused_key = None

    # -- stepping ---------------------------------------------------------------
    def _stepper_kwargs(self, level: int) -> dict:
        return dict(
            omega=omega_for_level(self.cfg.omega, level),
            lattice=self.spec.lattice,
            u_wall=self.cfg.u_lid,
            collision=self.cfg.collision,
            backend=self.cfg.kernel_backend,
            interpret=True,
        )

    def _stepper(self, level: int) -> Callable:
        if level not in self._steppers:
            make = (
                make_stream_collide
                if self.cfg.stepping_mode == "restack"
                else make_arena_stream_collide
            )
            self._steppers[level] = make(**self._stepper_kwargs(level))
        return self._steppers[level]

    def _fused_stepper(self, level: int) -> Callable:
        """Pure ``step(f, mask) -> f`` for the fused program (traced inline)."""
        if level not in self._fused_steppers:
            self._fused_steppers[level] = make_stream_collide(
                **self._stepper_kwargs(level)
            )
        return self._fused_steppers[level]

    def _storage_version(self) -> int:
        if self.arena is not None:
            return self.arena.version
        if self.arenas is not None:
            return self.arenas.version
        return -1

    def _sync_caches(self) -> None:
        """Drop device masks and ghost plans if the arena(s) rebound storage
        since they were built — invalidation by mechanism, not by call-site
        discipline (any future adopt site is covered automatically)."""
        version = self._storage_version()
        if self._halo_plans is not None and self._cache_version != version:
            self._mask_dev.clear()
            self._halo_plans.clear()
            self._cache_version = version

    def _level_mask(self, level: int) -> jax.Array:
        """Device-resident (B, X, Y, Z) mask stack, cached across substeps."""
        self._sync_caches()
        m = self._mask_dev.get(level)
        if m is None:
            m = jnp.asarray(self.arena.buffer(level, "mask"))
            self._mask_dev[level] = m
        return m

    def _group_mask(self, level: int, ranks: tuple[int, ...]) -> jax.Array:
        """Device mask for a batched group of rank buffers (sharded mode)."""
        self._sync_caches()
        key = (level, ranks)
        m = self._mask_dev.get(key)
        if m is None:
            parts = [self.arenas.buffer(r, level, "mask") for r in ranks]
            m = jnp.asarray(parts[0] if len(parts) == 1 else np.concatenate(parts))
            self._mask_dev[key] = m
        return m

    def _step_level_sharded(self, level: int) -> None:
        """One kernel call per rank per level, batched where shapes agree:
        ranks whose level buffers hold the same block count share one call
        (their stacked shapes are identical, so one jit specialization and
        one device round-trip cover the whole group)."""
        per_rank = [
            (r, buf)
            for r in range(self.cfg.nranks)
            if (buf := self.arenas.buffer(r, level, "pdf")) is not None
            and buf.shape[0] > 0
        ]
        by_count: dict[int, list[tuple[int, np.ndarray]]] = {}
        for r, buf in per_rank:
            by_count.setdefault(buf.shape[0], []).append((r, buf))
        stepper = self._stepper(level)
        for nblocks, group in sorted(by_count.items()):
            ranks = tuple(r for r, _ in group)
            mask = self._group_mask(level, ranks)
            if len(group) == 1:
                stepper(group[0][1], mask)  # in-place on the rank's buffer
                continue
            cat = np.concatenate([buf for _, buf in group])
            stepper(cat, mask)
            for i, (_r, buf) in enumerate(group):
                np.copyto(buf, cat[i * nblocks : (i + 1) * nblocks])

    def _step_level(self, level: int) -> None:
        if self.cfg.stepping_mode == "restack":
            blocks = [b for b in self.forest.all_blocks() if b.level == level]
            if not blocks:
                return
            f = jnp.asarray(np.stack([b.data["pdf"] for b in blocks]))
            m = jnp.asarray(np.stack([b.data["mask"] for b in blocks]))
            f = self._stepper(level)(f, m)
            out = np.array(f)  # copy out of the (read-only) jax buffer
            for i, b in enumerate(blocks):
                b.data["pdf"] = out[i]
            return
        if self.cfg.stepping_mode == "sharded":
            self._step_level_sharded(level)
            return
        buf = self.arena.buffer(level, "pdf")
        if buf is None or buf.shape[0] == 0:
            return
        # in-place: reads and writes the persistent level buffer directly
        self._stepper(level)(buf, self._level_mask(level))

    def _exchange_ghosts(self, active: set[int] | None = None) -> None:
        """Refresh pdf ghost layers for the active levels, attributing the
        wall time (and, in sharded mode, the p2p bytes/messages/rounds the
        exchange put on the fabric) to the "halo" data-plane stage."""
        self._sync_caches()  # an external adopt() must not replay stale plans
        # arena storage is versioned (adopt bumps it on every topology /
        # storage change), so the plan-cache guard is an O(1) token compare
        # instead of the default O(blocks) binding scan
        token = self._storage_version() if self._halo_plans is not None else None
        t0 = time.perf_counter()
        if self.cfg.stepping_mode == "sharded":
            s0 = self.comm.stats.summary()
            fill_ghost_layers_sharded(
                self.forest,
                self.fields,
                self.comm,
                fields=("pdf",),
                levels=active,
                plan_cache=self._halo_plans,
                cache_token=token,
            )
            self.data_stats["halo"].add(
                StageStats.delta(
                    s0, self.comm.stats.summary(), time.perf_counter() - t0
                )
            )
            return
        fill_ghost_layers(
            self.forest,
            self.fields,
            fields=("pdf",),
            levels=active,
            plan_cache=self._halo_plans,
            cache_token=token,
        )
        self.data_stats["halo"].add(StageStats(seconds=time.perf_counter() - t0))

    # -- fused (device-resident) stepping ---------------------------------------
    def _fused_program(self) -> tuple[Callable, tuple[int, ...]]:
        """Get-or-build the jitted superstep for the current forest: compiled
        ghost plans for every activity pattern + per-level steppers + device
        masks, cached until the next AMR event (arena version) or mask
        refresh."""
        levels = tuple(sorted(self.forest.levels_in_use()))
        key = (self.arena.version, levels)
        if self._fused_fn is not None and self._fused_key == key:
            return self._fused_fn, levels
        lmax = levels[-1]
        slots = {l: self.arena.slots(l) for l in levels}
        plans = {
            p: compile_ghost_plan(
                self.forest,
                self.fields,
                slots,
                fields=("pdf",),
                levels={l for l in levels if l >= lmax - p},
            )
            for p in range(lmax + 1)
        }
        res = self.arena.device()
        self._fused_fn = make_fused_superstep(
            levels=levels,
            plans=plans,
            steppers={l: self._fused_stepper(l) for l in levels},
            masks={l: res.fetch(l, "mask") for l in levels},
        )
        self._fused_key = key
        return self._fused_fn, levels

    def _advance_fused(self, coarse_steps: int) -> None:
        """Run whole coarse steps on device: one program call each, zero host
        transfers in steady state (uploads only after AMR events / mask
        refreshes; downloads only when diagnostics or the control plane
        materialize host views)."""
        fn, levels = self._fused_program()
        res = self.arena.device()
        pdfs = tuple(res.fetch(l, "pdf") for l in levels)
        nsub = 1 << levels[-1]
        t0 = time.perf_counter()
        for _ in range(coarse_steps):
            pdfs = fn(pdfs)
        jax.block_until_ready(pdfs)
        for l, arr in zip(levels, pdfs):
            res.store(l, "pdf", arr)
        self.data_stats["fused"].add(
            StageStats(
                seconds=time.perf_counter() - t0,
                exchange_rounds=coarse_steps * nsub,
            )
        )
        self.coarse_step += coarse_steps

    def materialize_host(self) -> None:
        """Flush device-newer buffers into the host arena (fused mode) so
        every ``Block.data`` view is current. Diagnostics and :meth:`adapt`
        call this automatically; external consumers of per-block host data —
        ``save_checkpoint``, the resilience manager, visualization — must
        call it before reading when stepping in fused mode (no-op in the
        host-resident modes)."""
        if self.arena is not None:
            self.arena.device().flush()


    # -- Lagrangian tracers -----------------------------------------------------
    def _particle_batches(
        self, level: int
    ) -> list[tuple[np.ndarray, np.ndarray, dict[int, int], list[Block]]]:
        """(pdf stack, mask stack, bid->slot, blocks) advection groups for one
        level. Host modes batch the whole level (arena slots, or an ad-hoc
        restack); sharded batches per rank over that rank's own buffers, so a
        rank's tracers read only the rank's own memory."""
        if self.cfg.stepping_mode == "sharded":
            out = []
            for r in range(self.cfg.nranks):
                arena = self.arenas.per_rank[r]
                pdf = arena.buffer(level, "pdf")
                if pdf is None or pdf.shape[0] == 0:
                    continue
                blocks = [
                    b
                    for b in self.forest.local_blocks(r).values()
                    if b.level == level
                ]
                out.append(
                    (pdf, arena.buffer(level, "mask"), arena.slots(level), blocks)
                )
            return out
        if self.cfg.stepping_mode == "restack":
            blocks = sorted(
                (b for b in self.forest.all_blocks() if b.level == level),
                key=lambda b: b.bid,
            )
            if not blocks:
                return []
            pdf = np.stack([b.data["pdf"] for b in blocks])
            mask = np.stack([b.data["mask"] for b in blocks])
            return [(pdf, mask, {b.bid: i for i, b in enumerate(blocks)}, blocks)]
        # arena / fused: persistent level buffers (host views are current
        # after materialize_host)
        pdf = self.arena.buffer(level, "pdf")
        if pdf is None or pdf.shape[0] == 0:
            return []
        blocks = [b for b in self.forest.all_blocks() if b.level == level]
        return [
            (pdf, self.arena.buffer(level, "mask"), self.arena.slots(level), blocks)
        ]

    def _step_particles(self) -> None:
        """Advect tracers through the end-of-step velocity field and route
        escapees to their new block/rank (batched p2p, one message per rank
        pair). Runs once per coarse step in every stepping mode."""
        self.materialize_host()  # fused: host pdf views must be current
        # Ghost layers must be a deterministic function of the (mode-
        # identical) interiors so interpolation reads the same values in
        # every mode. The next substep's exchange overwrites them again —
        # and the fused program re-exchanges in-program before any device
        # read — so this host-side write needs no residency drop.
        self._exchange_ghosts()
        t0 = time.perf_counter()
        s0 = self.comm.stats.summary()
        advected = 0
        for level in self.forest.levels_in_use():
            for pdf, mask, slots, blocks in self._particle_batches(level):
                advected += advect_block_batch(
                    pdf,
                    mask,
                    self.spec.lattice,
                    self.geom,
                    blocks,
                    slots,
                    level=level,
                    cells=self.spec.cells,
                    ghost=self.spec.ghost,
                )
        moved, _cross_bytes = redistribute_particles(
            self.forest,
            self.geom,
            self.comm,
            boundary=self.cfg.particles.boundary,
        )
        self.particles_advected += advected
        self.particles_moved += moved
        self.data_stats["particles"].add(
            StageStats.delta(
                s0, self.comm.stats.summary(), time.perf_counter() - t0
            )
        )

    def advance(self, coarse_steps: int = 1) -> None:
        """Advance by coarse time steps with per-level substepping."""
        self._sync_caches()
        if self.cfg.stepping_mode == "fused":
            if self.cfg.particles is None:
                self._advance_fused(coarse_steps)
                return
            for _ in range(coarse_steps):
                self._advance_fused(1)
                self._step_particles()
            return
        levels = self.forest.levels_in_use()
        lmax = max(levels)
        for _ in range(coarse_steps):
            for s in range(2**lmax):
                active = {l for l in levels if s % (2 ** (lmax - l)) == 0}
                self._exchange_ghosts(active)
                t0 = time.perf_counter()
                for l in sorted(active, reverse=True):
                    self._step_level(l)
                self.data_stats["step"].add(
                    StageStats(seconds=time.perf_counter() - t0)
                )
            self.coarse_step += 1
            if self.cfg.particles is not None:
                self._step_particles()

    # -- AMR ------------------------------------------------------------------
    def adapt(self, force_rebalance: bool = False):
        """Evaluate the refinement criterion and run one AMR cycle."""
        self.materialize_host()  # criterion + migration read host views
        self.forest, report = self.pipeline.run_cycle(
            self.forest, self.comm, self.criterion, force_rebalance=force_rebalance
        )
        if report.executed:
            self.amr_cycles += 1
            if self.arena is not None:
                self.arena.adopt(self.forest)  # repack SoA buffers, rebind views
            if self.arenas is not None:
                self.arenas.adopt(self.forest)  # rebuild rank-local arenas
            self._sync_caches()
            self.refresh_masks()
            self._exchange_ghosts()
        return report

    def run(self, coarse_steps: int, amr_interval: int = 4) -> None:
        for i in range(coarse_steps):
            self.advance(1)
            if (i + 1) % amr_interval == 0:
                self.adapt()

    # -- diagnostics -----------------------------------------------------------
    def _interior(self, arr: np.ndarray) -> np.ndarray:
        """Interior (non-ghost) slice of a per-block array (ghost-0 safe)."""
        return self.spec.interior(arr)

    def total_mass(self) -> float:
        self.materialize_host()
        total = 0.0
        for b in self.forest.all_blocks():
            interior = self._interior(b.data["pdf"])
            fluid = self._interior(b.data["mask"]) == CellType.FLUID
            # level-l cells have volume 8^-l of a root-cell unit
            total += float((interior.sum(axis=0) * fluid).sum()) * (8.0 ** -b.level)
        return total

    def max_velocity(self) -> float:
        self.materialize_host()
        vmax = 0.0
        for b in self.forest.all_blocks():
            _rho, u = macroscopic(b.data["pdf"], self.spec.lattice)
            fluid = b.data["mask"] == CellType.FLUID
            speed = np.sqrt((u**2).sum(axis=0)) * fluid
            vmax = max(vmax, float(self._interior(speed).max(initial=0.0)))
        return vmax

    def total_particles(self) -> int:
        """Tracer population across the whole forest (conservation probe)."""
        return _forest_total_particles(self.forest)

    def num_fluid_cells(self) -> int:
        return int(
            sum(
                (self._interior(b.data["mask"]) == CellType.FLUID).sum()
                for b in self.forest.all_blocks()
            )
        )
