"""LBM velocity sets: D3Q19 (paper §5.1.1) and D3Q27 (paper §5.2)."""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np

__all__ = ["Lattice", "D3Q19", "D3Q27"]


@dataclass(frozen=True)
class Lattice:
    name: str
    c: np.ndarray  # (Q, 3) int8 lattice velocities
    w: np.ndarray  # (Q,) float64 weights
    opposite: np.ndarray  # (Q,) int — index of -c_q

    @property
    def Q(self) -> int:
        return len(self.w)

    cs2: float = 1.0 / 3.0


def _make(name: str, vels: list[tuple[int, int, int]], weights: list[float]) -> Lattice:
    c = np.array(vels, dtype=np.int8)
    w = np.array(weights, dtype=np.float64)
    assert abs(w.sum() - 1.0) < 1e-12, w.sum()
    opp = np.array(
        [next(i for i, v in enumerate(vels) if v == (-x, -y, -z)) for x, y, z in vels],
        dtype=np.int32,
    )
    return Lattice(name=name, c=c, w=w, opposite=opp)


_D3Q19_VELS = [
    (0, 0, 0),
    (1, 0, 0), (-1, 0, 0), (0, 1, 0), (0, -1, 0), (0, 0, 1), (0, 0, -1),
    (1, 1, 0), (-1, -1, 0), (1, -1, 0), (-1, 1, 0),
    (1, 0, 1), (-1, 0, -1), (1, 0, -1), (-1, 0, 1),
    (0, 1, 1), (0, -1, -1), (0, 1, -1), (0, -1, 1),
]
_D3Q19_W = [1 / 3] + [1 / 18] * 6 + [1 / 36] * 12

_D3Q27_VELS = _D3Q19_VELS + [
    (1, 1, 1), (-1, -1, -1), (1, 1, -1), (-1, -1, 1),
    (1, -1, 1), (-1, 1, -1), (1, -1, -1), (-1, 1, 1),
]
_D3Q27_W = [8 / 27] + [2 / 27] * 6 + [1 / 54] * 12 + [1 / 216] * 8

D3Q19 = _make("D3Q19", _D3Q19_VELS, _D3Q19_W)
D3Q27 = _make("D3Q27", _D3Q27_VELS, _D3Q27_W)


def omega_for_level(omega_coarse: float, level: int) -> float:
    """Relaxation rate on refined grids (acoustic scaling, dx,dt halve per
    level): tau_l - 1/2 = 2^l (tau_0 - 1/2)."""
    tau0 = 1.0 / omega_coarse
    tau_l = 0.5 + (2.0**level) * (tau0 - 0.5)
    return 1.0 / tau_l
