"""Per-block uniform grids declared through the typed field API (paper §3.3).

Every block stores a grid of the same size (paper Fig. 1), independent of its
level: ``(Q, nx+2, ny+2, nz+2)`` PDFs plus an ``(nx+2, ny+2, nz+2)`` cell-type
mask, with one ghost layer. Instead of hand-writing the six migration
callbacks per field (the seed's ``make_lbm_registry`` sextuples), each field
is one :class:`~repro.core.fields.FieldSpec` declaration; the
:class:`~repro.core.fields.FieldRegistry` derives migration, checkpoint, and
resilience behavior from it:

* ``pdf``  — ``refine="interpolate"``, ``coarsen="restrict"``: the volumetric
  copy/average pair of [54]/[16]. Split serializes the *unmodified* coarse
  octant and prolongs on the receiver (§3.3: "Only during deserialization,
  this data is distributed to and interpolated on the newly allocated, finer
  grids"); merge restricts (2x2x2 average) on the sender. Split followed by
  merge is the identity on cell averages — mass-conservative.
* ``mask`` — ``refine="inject"``, ``coarsen="max"``: every octet of fine
  cells takes the type of the coarse cell (§3.3 overlap consistency);
  merging prefers walls.
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from ..core.blockid import ForestGeometry
from ..core.fields import FieldRegistry, FieldSpec
from .lattice import D3Q19, Lattice

__all__ = [
    "CellType",
    "LBMBlockSpec",
    "make_lbm_fields",
    "make_lbm_registry",
    "block_world_box",
]


class CellType:
    FLUID = 0
    WALL = 1
    LID = 2


@dataclass(frozen=True)
class LBMBlockSpec:
    cells: tuple[int, int, int] = (16, 16, 16)
    ghost: int = 1
    lattice: Lattice = D3Q19
    dtype: type = np.float32

    @property
    def pdf_shape(self) -> tuple[int, int, int, int]:
        nx, ny, nz = self.cells
        g = 2 * self.ghost
        return (self.lattice.Q, nx + g, ny + g, nz + g)

    @property
    def mask_shape(self) -> tuple[int, int, int]:
        nx, ny, nz = self.cells
        g = 2 * self.ghost
        return (nx + g, ny + g, nz + g)

    def interior(self, arr: np.ndarray) -> np.ndarray:
        g = self.ghost
        # explicit bounds: arr[g:-g] with g == 0 would be silently empty
        sl = tuple(slice(g, n - g) for n in arr.shape[-3:])
        return arr[(Ellipsis, *sl)]


def block_world_box(geom: ForestGeometry, bid: int) -> tuple[np.ndarray, np.ndarray]:
    """Block AABB in world units (one root block = unit cube)."""
    box = np.asarray(geom.aabb(bid), dtype=np.float64)
    scale = 1.0 / (1 << geom.max_level)
    return box[:3] * scale, box[3:] * scale


def make_lbm_fields(spec: LBMBlockSpec) -> FieldRegistry:
    """The whole LBM data declaration: two typed fields, nothing hand-rolled."""
    return FieldRegistry(
        cells=spec.cells,
        fields=(
            FieldSpec(
                "pdf",
                dtype=spec.dtype,
                shape=(spec.lattice.Q,),
                ghost=spec.ghost,
                refine="interpolate",
                coarsen="restrict",
            ),
            FieldSpec(
                "mask",
                dtype=np.int32,
                ghost=spec.ghost,
                refine="inject",
                coarsen="max",
            ),
        ),
    )


def make_lbm_registry(spec: LBMBlockSpec) -> FieldRegistry:
    """Backward-compatible name; the six callbacks are now derived."""
    return make_lbm_fields(spec)
