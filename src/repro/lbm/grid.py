"""Per-block uniform grids and their migration serializers (paper §3.3).

Every block stores a grid of the same size (paper Fig. 1), independent of its
level: ``(Q, nx+2, ny+2, nz+2)`` PDFs plus an ``(nx+2, ny+2, nz+2)`` cell-type
mask, with one ghost layer. The six serialization callbacks implement the
paper's refinement data path exactly:

* **split**: the *unmodified* coarse octant is serialized and sent; the
  distribution onto the newly allocated finer grid happens on the receiving
  side during deserialization (volumetric copy, [54]/[16]) — §3.3: "Only
  during deserialization, this data is distributed to and interpolated on
  the newly allocated, finer grids";
* **merge**: coarsening (2x2x2 averaging) happens on the *sending* side
  before serialization; the receiver only assembles the eight coarse octant
  payloads — §3.3.

The volumetric copy/average pair is mass-conservative: split followed by
merge is the identity on cell averages.
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from ..core.blockid import ForestGeometry
from ..core.forest import Block
from ..core.migration import BlockDataItem, BlockDataRegistry
from .lattice import D3Q19, Lattice

__all__ = ["CellType", "LBMBlockSpec", "make_lbm_registry", "block_world_box"]


class CellType:
    FLUID = 0
    WALL = 1
    LID = 2


@dataclass(frozen=True)
class LBMBlockSpec:
    cells: tuple[int, int, int] = (16, 16, 16)
    ghost: int = 1
    lattice: Lattice = D3Q19
    dtype: type = np.float32

    @property
    def pdf_shape(self) -> tuple[int, int, int, int]:
        nx, ny, nz = self.cells
        g = 2 * self.ghost
        return (self.lattice.Q, nx + g, ny + g, nz + g)

    @property
    def mask_shape(self) -> tuple[int, int, int]:
        nx, ny, nz = self.cells
        g = 2 * self.ghost
        return (nx + g, ny + g, nz + g)

    def interior(self, arr: np.ndarray) -> np.ndarray:
        g = self.ghost
        return arr[..., g:-g, g:-g, g:-g]


def block_world_box(geom: ForestGeometry, bid: int) -> tuple[np.ndarray, np.ndarray]:
    """Block AABB in world units (one root block = unit cube)."""
    box = np.asarray(geom.aabb(bid), dtype=np.float64)
    scale = 1.0 / (1 << geom.max_level)
    return box[:3] * scale, box[3:] * scale


def _octant_slices(o: int, n: tuple[int, int, int], g: int) -> tuple[slice, slice, slice]:
    """Interior slices of octant ``o`` of a ghosted (nx+2g, ...) array."""
    ox, oy, oz = o & 1, (o >> 1) & 1, (o >> 2) & 1
    nx, ny, nz = n
    return (
        slice(g + ox * nx // 2, g + (ox + 1) * nx // 2),
        slice(g + oy * ny // 2, g + (oy + 1) * ny // 2),
        slice(g + oz * nz // 2, g + (oz + 1) * nz // 2),
    )


def _coarsen2(a: np.ndarray) -> np.ndarray:
    """Average 2x2x2 groups over the last three axes (volumetric merge)."""
    s = a.shape
    x, y, z = s[-3] // 2, s[-2] // 2, s[-1] // 2
    a = a.reshape(*s[:-3], x, 2, y, 2, z, 2)
    return a.mean(axis=(-5, -3, -1))


def _refine2(a: np.ndarray) -> np.ndarray:
    """Replicate each cell into 2x2x2 (volumetric split)."""
    for ax in (-3, -2, -1):
        a = np.repeat(a, 2, axis=ax)
    return a


def make_lbm_registry(spec: LBMBlockSpec) -> BlockDataRegistry:
    nx, ny, nz = spec.cells
    g = spec.ghost
    assert nx % 2 == ny % 2 == nz % 2 == 0, "cells per block must be even"

    def pdf_ser_move(data: np.ndarray, _blk: Block) -> np.ndarray:
        return data

    def pdf_des_move(payload: np.ndarray, _blk: Block) -> np.ndarray:
        return payload

    def pdf_ser_split(data: np.ndarray, _blk: Block, o: int) -> np.ndarray:
        sx, sy, sz = _octant_slices(o, spec.cells, g)
        return np.ascontiguousarray(data[:, sx, sy, sz])  # unmodified coarse data

    def pdf_des_split(payload: np.ndarray, _blk: Block) -> np.ndarray:
        out = np.zeros(spec.pdf_shape, dtype=spec.dtype)
        out[:, g:-g, g:-g, g:-g] = _refine2(payload)  # interpolate on receiver
        return out

    def pdf_ser_merge(data: np.ndarray, _blk: Block) -> np.ndarray:
        return _coarsen2(data[:, g:-g, g:-g, g:-g]).astype(spec.dtype)  # coarsen on sender

    def pdf_des_merge(parts: dict[int, np.ndarray], _blk: Block) -> np.ndarray:
        out = np.zeros(spec.pdf_shape, dtype=spec.dtype)
        for o, payload in parts.items():
            sx, sy, sz = _octant_slices(o, spec.cells, g)
            out[:, sx, sy, sz] = payload
        return out

    def mask_ser_split(data: np.ndarray, _blk: Block, o: int) -> np.ndarray:
        sx, sy, sz = _octant_slices(o, spec.cells, g)
        return np.ascontiguousarray(data[sx, sy, sz])

    def mask_des_split(payload: np.ndarray, _blk: Block) -> np.ndarray:
        out = np.zeros(spec.mask_shape, dtype=np.int32)
        # every octet of fine cells takes the type of the coarse cell (§3.3)
        out[g:-g, g:-g, g:-g] = _refine2(payload)
        return out

    def mask_ser_merge(data: np.ndarray, _blk: Block) -> np.ndarray:
        interior = data[g:-g, g:-g, g:-g]
        x, y, z = interior.shape
        grouped = interior.reshape(x // 2, 2, y // 2, 2, z // 2, 2)
        return grouped.max(axis=(1, 3, 5)).astype(np.int32)  # prefer walls

    def mask_des_merge(parts: dict[int, np.ndarray], _blk: Block) -> np.ndarray:
        out = np.zeros(spec.mask_shape, dtype=np.int32)
        for o, payload in parts.items():
            sx, sy, sz = _octant_slices(o, spec.cells, g)
            out[sx, sy, sz] = payload
        return out

    reg = BlockDataRegistry()
    reg.register(
        "pdf",
        BlockDataItem(
            serialize_move=pdf_ser_move,
            deserialize_move=pdf_des_move,
            serialize_split=pdf_ser_split,
            deserialize_split=pdf_des_split,
            serialize_merge=pdf_ser_merge,
            deserialize_merge=pdf_des_merge,
        ),
    )
    reg.register(
        "mask",
        BlockDataItem(
            serialize_move=lambda d, b: d,
            deserialize_move=lambda p, b: p,
            serialize_split=mask_ser_split,
            deserialize_split=mask_des_split,
            serialize_merge=mask_ser_merge,
            deserialize_merge=mask_des_merge,
        ),
    )
    return reg
