"""Training substrate: optimizer, train/serve steps, data pipeline,
checkpointing, elasticity — plus the paper-technique integration points
(diffusion-balanced data buckets, MoE expert placement)."""

from .optimizer import AdamWConfig, adamw_init, adamw_update
from .train_step import make_train_step
from .data import SyntheticTokenPipeline, diffusion_assign_buckets

__all__ = [
    "AdamWConfig",
    "adamw_init",
    "adamw_update",
    "make_train_step",
    "SyntheticTokenPipeline",
    "diffusion_assign_buckets",
]
