"""Deprecated: elasticity control moved to :mod:`repro.serving.elastic`.

The seed sketch that lived here (straggler EWMAs -> capacity-weighted bucket
reassignment, shrink planning) matured into the serving subsystem, where it
sits next to the data-plane resize (:func:`repro.serving.elastic.resize_ranks`)
it steers. This module re-exports the moved names so old imports keep
working, with a :class:`DeprecationWarning`; new code should import from
``repro.serving.elastic``.

One behavioral note: the moved ``StragglerMonitor.rebalance_buckets`` /
``plan_shrink`` default to the self-contained greedy-LPT assignment; pass
``assign=repro.train.data.diffusion_assign_buckets`` to restore the old
diffusion-balancer coupling.
"""

from __future__ import annotations

import warnings

from ..serving.elastic import (  # noqa: F401  (re-exports)
    ElasticPlan,
    StragglerMonitor,
    greedy_assign_buckets,
    plan_shrink,
)

__all__ = ["StragglerMonitor", "ElasticPlan", "plan_shrink", "greedy_assign_buckets"]

warnings.warn(
    "repro.train.elastic moved to repro.serving.elastic; this shim will be removed",
    DeprecationWarning,
    stacklevel=2,
)
