"""Elastic training control: straggler mitigation + shrink/grow re-meshing.

The control logic mirrors the paper's resilience design (§4.2) at the
LM-plane level:

* **straggler mitigation** — per-host step-time EWMAs feed the *same*
  diffusion balancer that balances AMR blocks: data buckets (blocks,
  weight = tokens) are reassigned away from slow hosts by scaling their
  per-rank capacity with the inverse measured throughput;
* **elastic re-mesh** — on device loss the runner decides the new mesh
  shape (dropping whole hosts), reload point (last checkpoint), and a
  rebalanced bucket assignment; the training driver then re-lowers the
  step function for the new mesh (cheap: scan-based HLO) and resumes.

Deterministic and host-side, so it is fully unit-testable without hardware.
"""

from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from .data import diffusion_assign_buckets

__all__ = ["StragglerMonitor", "ElasticPlan", "plan_shrink"]


@dataclass
class StragglerMonitor:
    """EWMA step times per host; emits capacity weights for the balancer."""

    n_hosts: int
    alpha: float = 0.2
    ewma: np.ndarray = field(default=None)  # type: ignore[assignment]

    def __post_init__(self):
        if self.ewma is None:
            self.ewma = np.zeros(self.n_hosts)

    def observe(self, step_times: np.ndarray) -> None:
        t = np.asarray(step_times, dtype=np.float64)
        self.ewma = np.where(
            self.ewma == 0, t, self.alpha * t + (1 - self.alpha) * self.ewma
        )

    def capacities(self) -> np.ndarray:
        """Relative per-host throughput (1.0 = median host)."""
        med = np.median(self.ewma[self.ewma > 0]) if (self.ewma > 0).any() else 1.0
        caps = np.where(self.ewma > 0, med / np.maximum(self.ewma, 1e-9), 1.0)
        return np.clip(caps, 0.1, 2.0)

    def rebalance_buckets(self, bucket_tokens: list[float]) -> tuple[list[int], int]:
        """Assign buckets ~proportionally to measured capacity: bucket weights
        are scaled by the *inverse* capacity of their candidate rank through
        virtual duplication — slow hosts present as ranks with fewer slots.
        Realized by splitting each host into round(cap*K) virtual ranks and
        running the standard diffusion assignment over them."""
        K = 4
        caps = self.capacities()
        virt_of_host = [max(1, int(round(c * K))) for c in caps]
        n_virt = sum(virt_of_host)
        assign_v, iters = diffusion_assign_buckets(bucket_tokens, n_virt)
        # map virtual ranks back to hosts
        host_of_virt = []
        for h, nv in enumerate(virt_of_host):
            host_of_virt.extend([h] * nv)
        return [host_of_virt[v] for v in assign_v], iters


@dataclass(frozen=True)
class ElasticPlan:
    new_hosts: list[int]  # surviving host ids
    mesh_shape: tuple[int, ...]  # new (data, model) shape
    resume_step: int
    bucket_assignment: list[int]


def plan_shrink(
    *,
    alive_hosts: list[int],
    chips_per_host: int,
    model_parallel: int,
    last_checkpoint_step: int,
    bucket_tokens: list[float],
) -> ElasticPlan:
    """Plan resumption after losing hosts: keep the model axis intact (TP
    groups must not straddle dead hosts) and shrink the data axis; data
    buckets are diffusion-rebalanced over the survivors."""
    total_chips = len(alive_hosts) * chips_per_host
    assert total_chips % model_parallel == 0, (
        f"{total_chips} chips cannot keep model_parallel={model_parallel}"
    )
    data = total_chips // model_parallel
    assignment, _ = diffusion_assign_buckets(bucket_tokens, len(alive_hosts))
    return ElasticPlan(
        new_hosts=sorted(alive_hosts),
        mesh_shape=(data, model_parallel),
        resume_step=last_checkpoint_step,
        bucket_assignment=assignment,
    )
