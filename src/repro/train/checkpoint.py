"""LM-plane step checkpointing (params + optimizer + data-pipeline state).

Mirrors the AMR plane's §4.1 design: everything needed to resume — including
on a different device count — is serialized. Leaves are stored as one .npz
keyed by flattened tree paths, so restore is layout-independent: the restored
arrays are re-sharded by whatever in_shardings the new mesh uses.
"""

from __future__ import annotations

import json
from pathlib import Path
from typing import Any

import jax
import numpy as np

__all__ = ["save_train_state", "load_train_state"]


def _flatten(tree: Any) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(
            str(getattr(p, "key", getattr(p, "idx", p))) for p in path
        )
        flat[key] = np.asarray(leaf)
    return flat


def save_train_state(
    path: str | Path,
    *,
    params: Any,
    opt_state: Any,
    step: int,
    meta: dict | None = None,
) -> None:
    path = Path(path)
    path.mkdir(parents=True, exist_ok=True)
    np.savez(path / "params.npz", **_flatten(params))
    np.savez(path / "opt_state.npz", **_flatten(opt_state))
    (path / "meta.json").write_text(json.dumps({"step": step, **(meta or {})}))


def load_train_state(path: str | Path, params_like: Any, opt_like: Any):
    """Restore into the given tree structures (from eval_shape or init)."""
    path = Path(path)
    p_flat = np.load(path / "params.npz")
    o_flat = np.load(path / "opt_state.npz")

    def rebuild(like, flat):
        leaves = []
        for p, leaf in jax.tree_util.tree_flatten_with_path(like)[0]:
            key = "/".join(str(getattr(x, "key", getattr(x, "idx", x))) for x in p)
            arr = flat[key]
            assert arr.shape == tuple(leaf.shape), (key, arr.shape, leaf.shape)
            leaves.append(arr.astype(leaf.dtype))
        return jax.tree_util.tree_unflatten(
            jax.tree_util.tree_structure(like), leaves
        )

    meta = json.loads((path / "meta.json").read_text())
    return rebuild(params_like, p_flat), rebuild(opt_like, o_flat), meta
