"""AdamW with fp32 master weights (mixed precision / ZeRO-1 ready).

The optimizer state (master params + both moments) carries its own sharding
specs from :mod:`repro.sharding.specs`: moments and masters are sharded like
the parameters *plus* an extra data-axis sharding on the largest replicated
axis (ZeRO-1), so optimizer memory per device shrinks with the data-parallel
degree. Compute params may be bf16; updates happen in fp32 on the master.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any

import jax
import jax.numpy as jnp

__all__ = ["AdamWConfig", "adamw_init", "adamw_update", "global_norm"]


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100


def global_norm(tree: Any) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree)]
    return jnp.sqrt(sum(leaves))


def _schedule(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    warm = jnp.minimum(1.0, (step + 1) / max(1, cfg.warmup_steps))
    return cfg.lr * warm


def adamw_init(params: Any) -> dict:
    f32 = lambda x: x.astype(jnp.float32)
    return {
        "step": jnp.zeros((), jnp.int32),
        "master": jax.tree.map(f32, params),
        "m": jax.tree.map(lambda x: jnp.zeros(x.shape, jnp.float32), params),
        "v": jax.tree.map(lambda x: jnp.zeros(x.shape, jnp.float32), params),
    }


def adamw_update(
    grads: Any, opt_state: dict, params: Any, cfg: AdamWConfig
) -> tuple[Any, dict, dict]:
    """Returns (new params in the original dtype, new opt state, stats)."""
    step = opt_state["step"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9))
    lr = _schedule(cfg, opt_state["step"])
    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(g, m, v, master, p):
        g = g.astype(jnp.float32) * scale
        m_new = b1 * m + (1 - b1) * g
        v_new = b2 * v + (1 - b2) * jnp.square(g)
        mhat = m_new / bc1
        vhat = v_new / bc2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * master
        master_new = master - lr * delta
        return m_new, v_new, master_new, master_new.astype(p.dtype)

    flat_g, treedef = jax.tree.flatten(grads)
    flat_m = treedef.flatten_up_to(opt_state["m"])
    flat_v = treedef.flatten_up_to(opt_state["v"])
    flat_ma = treedef.flatten_up_to(opt_state["master"])
    flat_p = treedef.flatten_up_to(params)
    out = [upd(*args) for args in zip(flat_g, flat_m, flat_v, flat_ma, flat_p)]
    new_state = {
        "step": step,
        "m": jax.tree.unflatten(treedef, [o[0] for o in out]),
        "v": jax.tree.unflatten(treedef, [o[1] for o in out]),
        "master": jax.tree.unflatten(treedef, [o[2] for o in out]),
    }
    new_params = jax.tree.unflatten(treedef, [o[3] for o in out])
    return new_params, new_state, {"grad_norm": gnorm, "lr": lr}
