"""Diffusion-based MoE expert placement (the paper's technique on MoE archs).

Experts are blocks; the router's per-expert token counts are the block
weights; expert-parallel device groups are the ranks. Between training steps
the :class:`repro.core.DiffusionBalancer` recomputes the expert -> device
placement exactly like it rebalances AMR blocks: the *proxy* here is the
placement table (topology only, a few bytes per expert), and only once the
proxy is balanced are the actual expert weights migrated (one all-to-all of
the reassigned experts' parameters) — the same two-phase structure as the
paper's §2.3-§2.5.

For architectures whose expert count does not divide the model axis
(mixtral: 8e on 16-way TP), the placement is over virtual EP groups and the
balancer degenerates to the identity — documented in DESIGN.md.
"""

from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from .data import diffusion_assign_buckets

__all__ = ["ExpertPlacement"]


@dataclass
class ExpertPlacement:
    n_experts: int
    n_groups: int  # expert-parallel device groups
    # expert -> group assignment (current placement)
    assignment: list[int] = field(default_factory=list)
    history: list[dict] = field(default_factory=list)

    def __post_init__(self):
        if not self.assignment:
            per = self.n_experts // self.n_groups
            self.assignment = [min(e // max(per, 1), self.n_groups - 1) for e in range(self.n_experts)]

    def group_loads(self, expert_loads: np.ndarray) -> np.ndarray:
        loads = np.zeros(self.n_groups)
        for e, g in enumerate(self.assignment):
            loads[g] += float(expert_loads[e])
        return loads

    def rebalance(self, expert_loads: np.ndarray) -> tuple[list[int], int]:
        """One diffusion rebalance from measured router loads. Returns the
        list of migrated experts and the number of diffusion iterations."""
        before = self.group_loads(expert_loads)
        new_assign, iters = diffusion_assign_buckets(
            [float(w) for w in expert_loads], self.n_groups
        )
        moved = [e for e in range(self.n_experts) if new_assign[e] != self.assignment[e]]
        after_loads = np.zeros(self.n_groups)
        for e, g in enumerate(new_assign):
            after_loads[g] += float(expert_loads[e])
        self.history.append(
            {
                "max_before": float(before.max()),
                "max_after": float(after_loads.max()),
                "avg": float(expert_loads.sum() / self.n_groups),
                "moved": len(moved),
                "iters": iters,
            }
        )
        self.assignment = new_assign
        return moved, iters

    def permutation(self) -> np.ndarray:
        """Expert order such that each group's experts are contiguous — apply
        to stacked expert weights (gather) after rebalancing so the sharded
        expert dimension maps groups to devices."""
        order = sorted(range(self.n_experts), key=lambda e: (self.assignment[e], e))
        return np.asarray(order, dtype=np.int32)
