"""train_step / serve_step factories (jit-ready, donate-friendly)."""

from __future__ import annotations

import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp

from ..models.zoo import Model
from .optimizer import AdamWConfig, adamw_update

__all__ = ["make_train_step", "make_serve_step"]


def make_train_step(
    model: Model,
    opt_cfg: AdamWConfig,
    *,
    microbatches: int = 1,
) -> Callable:
    """Build ``train_step(params, opt_state, batch) -> (params, opt, metrics)``.

    With ``microbatches > 1`` the global batch is split on the leading axis
    and gradients are accumulated in fp32 through a scan — bounding peak
    activation memory to one microbatch regardless of the global batch.
    """

    grad_fn = jax.value_and_grad(lambda p, b: model.loss(p, b), has_aux=True)

    def train_step(params, opt_state, batch):
        if microbatches == 1:
            (loss, metrics), grads = grad_fn(params, batch)
        else:

            def split(x):
                B = x.shape[0]
                return x.reshape(microbatches, B // microbatches, *x.shape[1:])

            mb = jax.tree.map(split, batch)

            def acc_step(carry, mb_i):
                g_acc, l_acc = carry
                (loss, _m), g = grad_fn(params, mb_i)
                g_acc = jax.tree.map(
                    lambda a, b: a + b.astype(jnp.float32) / microbatches, g_acc, g
                )
                return (g_acc, l_acc + loss / microbatches), None

            g0 = jax.tree.map(lambda x: jnp.zeros(x.shape, jnp.float32), params)
            (grads, loss), _ = jax.lax.scan(acc_step, (g0, 0.0), mb)
            metrics = {}
        params, opt_state, stats = adamw_update(grads, opt_state, params, opt_cfg)
        out = {"loss": loss, **stats}
        return params, opt_state, out

    return train_step


def make_serve_step(model: Model, *, greedy: bool = True) -> Callable:
    """``serve_step(params, token, cache, extras) -> (next_token, cache)``."""

    def serve_step(params, token, cache, extras=None):
        logits, cache = model.decode(params, token, cache, extras)
        nxt = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
        return nxt, cache

    return serve_step
