"""Token data pipeline with diffusion-balanced document buckets.

This is the paper-technique integration point for *dense* architectures
(DESIGN.md §4): variable-length document buckets are modeled as blocks of a
1-D block forest (weight = token count) and assigned to data-parallel ranks
with the same :class:`repro.core.DiffusionBalancer` that balances the AMR
mesh — inexpensive, local, iterative. As documents grow/shrink between
epochs the assignment is *re*-balanced incrementally instead of reshuffled
globally (the SFC balancer is available as the global baseline, mirroring
the paper's §2.4.1-vs-§2.4.2 comparison).
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from ..core import (
    Comm,
    DiffusionBalancer,
    ForestGeometry,
    SFCBalancer,
    make_uniform_forest,
)

__all__ = ["diffusion_assign_buckets", "SyntheticTokenPipeline"]


def diffusion_assign_buckets(
    bucket_weights: list[float],
    nranks: int,
    *,
    mode: str = "pushpull",
    max_iterations: int = 30,
) -> tuple[list[int], int]:
    """Assign weighted buckets to ranks with the paper's diffusion scheme.

    The buckets become level-0 blocks of a (N,1,1) root-grid forest (a 1-D
    chain graph); the balancer runs exactly as for the AMR mesh. Returns
    (bucket -> rank assignment, main iterations used)."""
    n = len(bucket_weights)
    if n == 0:
        return [], 0
    # a roughly-cubic root grid gives each bucket up to 26 graph neighbors —
    # the denser process graph makes the diffusion converge in a handful of
    # iterations (a 1-D chain needs O(N) hops for the same imbalance)
    def _grid3(n: int) -> tuple[int, int, int]:
        best = (n, 1, 1)
        for a in range(1, int(n ** (1 / 3)) + 2):
            if n % a:
                continue
            m = n // a
            for b in range(a, int(m**0.5) + 1):
                if m % b == 0:
                    best = (m // b, b, a)
        return best

    geom = ForestGeometry(root_grid=_grid3(n), max_level=2)
    forest = make_uniform_forest(geom, nranks, level=0)
    order = sorted(b.bid for b in forest.all_blocks())
    idx_of = {bid: i for i, bid in enumerate(order)}
    for b in forest.all_blocks():
        b.weight = float(bucket_weights[idx_of[b.bid]])
    comm = Comm(nranks)
    balancer = DiffusionBalancer(
        mode=mode, flow_iterations=5, max_main_iterations=max_iterations, per_level=True
    )
    from ..core.forest import BlockForest
    from ..core.proxy import migrate_proxy_blocks  # late import to avoid cycle

    # the bucket forest acts as the proxy; a shallow twin (blocks pinned to
    # their initial ranks) absorbs the bilateral link updates, mirroring the
    # actual/proxy split of the AMR pipeline.
    anchor = BlockForest(geom, nranks)
    for blk in forest.all_blocks():
        blk.source_ranks = [blk.owner]
        blk.target_ranks = [blk.owner]
        blk.data["kind"] = "keep"
        twin = blk.clone_shallow()
        twin.target_ranks = [blk.owner]
        anchor.insert(twin)
    iteration = 0
    while True:
        assignments, again = balancer(forest, comm, iteration)
        migrate_proxy_blocks(forest, anchor, comm, assignments)
        iteration += 1
        if not again:
            break
    out = [0] * n
    for r in range(nranks):
        for bid in forest.local_blocks(r):
            out[idx_of[bid]] = r
    return out, iteration


@dataclass
class SyntheticTokenPipeline:
    """Deterministic synthetic corpus: documents with power-law lengths,
    packed into fixed-length rows per rank after diffusion balancing."""

    vocab: int
    seq_len: int
    global_batch: int
    nranks: int = 1
    seed: int = 0
    n_buckets: int = 64

    def __post_init__(self):
        rng = np.random.default_rng(self.seed)
        # power-law document buckets (token counts)
        raw = rng.pareto(1.5, size=self.n_buckets) + 1.0
        self.bucket_tokens = (raw / raw.sum() * self.global_batch * self.seq_len).astype(
            np.int64
        )
        self.assignment, self.balance_iters = diffusion_assign_buckets(
            [float(t) for t in self.bucket_tokens], self.nranks
        )

    def rank_load(self) -> list[int]:
        load = [0] * self.nranks
        for b, r in enumerate(self.assignment):
            load[r] += int(self.bucket_tokens[b])
        return load

    def batches(self, steps: int):
        rng = np.random.default_rng(self.seed + 1)
        B, S = self.global_batch, self.seq_len
        for _ in range(steps):
            tokens = rng.integers(0, self.vocab, size=(B, S + 1), dtype=np.int64)
            yield {
                "tokens": tokens[:, :-1].astype(np.int32),
                "labels": tokens[:, 1:].astype(np.int32),
            }

    def structured_batches(self, steps: int):
        """Batches with a learnable structure (for loss-decreases tests):
        token t+1 = (token t + 1) mod vocab with noise."""
        rng = np.random.default_rng(self.seed + 2)
        B, S = self.global_batch, self.seq_len
        for _ in range(steps):
            start = rng.integers(0, self.vocab, size=(B, 1), dtype=np.int64)
            seq = (start + np.arange(S + 1)[None, :]) % self.vocab
            yield {
                "tokens": seq[:, :-1].astype(np.int32),
                "labels": seq[:, 1:].astype(np.int32),
            }
