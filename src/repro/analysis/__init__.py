"""Static analysis over the repo's own source and built exchange plans.

Five checkers prove the data-plane contracts the runtime conformance tests
can only spot-check (see ARCHITECTURE.md "Static analysis"):

* ``host`` — no implicit device->host syncs in hot-path modules
  (:func:`~repro.analysis.checkers.check_host_transfer`);
* ``donation`` — no use-after-donate reads of consumed buffers
  (:func:`~repro.analysis.checkers.check_donation`);
* ``collective`` — stepping-path import closure is collective-free
  (:func:`~repro.analysis.checkers.check_collective`);
* ``protocol`` — compiled halo plans match pairwise, stay in bounds, and
  cover the ghost ring exactly (:mod:`repro.analysis.protocol`);
* ``retrace`` — static unstable-compile-cache patterns plus the runtime
  :class:`~repro.analysis.retrace.RetraceSentinel` budget hook.

Drive them via ``tools/repro_lint.py`` or the functions re-exported here.
"""

from .checkers import CHECKERS, run
from .config import DEFAULTS, LintConfig, load_config
from .findings import (
    Annotations,
    Finding,
    apply_baseline,
    line_hash,
    load_baseline,
    render,
    scan_annotations,
    write_baseline,
)
from .protocol import (
    build_sweep_topology,
    rank_slot_map,
    sweep_topologies,
    verify_compiled_rank_plan,
    verify_ghost_plan,
)
from .retrace import RetraceSentinel, budget_findings

__all__ = [
    "CHECKERS",
    "run",
    "DEFAULTS",
    "LintConfig",
    "load_config",
    "Annotations",
    "Finding",
    "apply_baseline",
    "line_hash",
    "load_baseline",
    "render",
    "scan_annotations",
    "write_baseline",
    "build_sweep_topology",
    "rank_slot_map",
    "sweep_topologies",
    "verify_compiled_rank_plan",
    "verify_ghost_plan",
    "RetraceSentinel",
    "budget_findings",
]
