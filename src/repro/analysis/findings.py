"""Finding model, inline-annotation allowlist, and the hash-guarded baseline.

Every checker in :mod:`repro.analysis.checkers` (and the plan-level verifier
in :mod:`repro.analysis.protocol`) reports through one shared shape — a
:class:`Finding` with a checker id, severity, ``file:line`` anchor, message
and fix hint — so the CLI, the baseline machinery and CI render them all the
same way.

Two suppression mechanisms exist, with different jobs:

* **inline annotations** document *sanctioned* behavior at the source line
  itself. The grammar is ``# repro: <checker>-ok(<reason>)`` — e.g.
  ``# repro: host-ok(restack copy-out is the mode's contract)`` — where
  ``<checker>`` is the checker's short name and the reason is mandatory (an
  empty reason is itself reported). An annotation on a ``def`` line covers
  the whole function body (for build-time helpers whose every line is
  sanctioned); otherwise it covers its own line or, as a standalone comment
  line, the line directly below.
* the **baseline** (:func:`load_baseline` / :func:`write_baseline`) grand-
  fathers *pre-existing* findings so a new checker can land without blocking
  CI on day one. Every baseline entry carries a content hash of the flagged
  line; if the line changes (or disappears) the entry goes stale and the
  lint FAILS LOUDLY instead of silently masking whatever new code now lives
  there — the annotation-drift hazard of classic lint baselines.
"""

from __future__ import annotations

import hashlib
import json
import re
from dataclasses import asdict, dataclass, field
from pathlib import Path

__all__ = [
    "Finding",
    "Annotations",
    "line_hash",
    "scan_annotations",
    "load_baseline",
    "write_baseline",
    "apply_baseline",
    "render",
]

# annotation grammar: "# repro: <checker>-ok(<reason>)"; several annotations
# may share one comment ("# repro: host-ok(timing) donation-ok(rebound)")
_ANNOT_RE = re.compile(r"#\s*repro:\s*((?:[a-z][a-z0-9_-]*-ok\([^()]*\)\s*)+)")
_ONE_RE = re.compile(r"([a-z][a-z0-9_-]*)-ok\(([^()]*)\)")


@dataclass(frozen=True)
class Finding:
    """One checker hit, anchored to a source line (or a plan object)."""

    checker: str  # short checker id: "host", "donation", "collective", ...
    severity: str  # "error" | "warning"
    path: str  # repo-relative file path ("<plan>" for protocol findings)
    line: int  # 1-based; 0 for non-source findings
    message: str
    fix_hint: str = ""
    line_hash: str = ""  # content hash of the flagged line (baseline key)

    def anchor(self) -> str:
        return f"{self.path}:{self.line}"

    def baseline_key(self) -> tuple[str, str, str]:
        """Baseline identity: checker + file + line *content* (not number),
        so pure line-shift edits don't stale the baseline but any edit to
        the flagged line itself does."""
        return (self.checker, self.path, self.line_hash)


def line_hash(text: str) -> str:
    """Content hash of one source line, whitespace-normalized."""
    return hashlib.sha256(" ".join(text.split()).encode()).hexdigest()[:12]


@dataclass
class Annotations:
    """Allowlist extracted from one file's comments.

    ``lines`` maps a covered line number to its ``{checker: reason}``
    annotations; ``empty`` records annotations with a missing reason (these
    are surfaced as findings — a sanction without documentation is exactly
    the drift the annotation grammar exists to prevent).
    """

    lines: dict[int, dict[str, str]] = field(default_factory=dict)
    empty: list[tuple[int, str]] = field(default_factory=list)

    def allows(self, lineno: int, checker: str) -> bool:
        return checker in self.lines.get(lineno, ())


def scan_annotations(source: str, func_ranges: list[tuple[int, int]] | None = None) -> Annotations:
    """Extract ``# repro: <checker>-ok(reason)`` annotations from source.

    ``func_ranges`` are ``(def_line, end_line)`` spans; an annotation sitting
    on a ``def`` line is expanded to cover the whole function body.
    """
    ann = Annotations()
    raw: dict[int, dict[str, str]] = {}
    lines = source.splitlines()
    for i, text in enumerate(lines, start=1):
        m = _ANNOT_RE.search(text)
        if not m:
            continue
        entries = {}
        for checker, reason in _ONE_RE.findall(m.group(1)):
            reason = reason.strip()
            if not reason:
                ann.empty.append((i, checker))
                continue
            entries[checker] = reason
        if not entries:
            continue
        raw[i] = entries
        code = text[: m.start()].strip()
        if not code:
            # standalone comment line: covers the next line
            raw.setdefault(i + 1, {}).update(entries)
    # def-line annotations cover the whole function
    for start, end in func_ranges or ():
        cover = raw.get(start)
        if cover:
            for ln in range(start, end + 1):
                raw.setdefault(ln, {}).update(cover)
    ann.lines = raw
    return ann


# -- baseline --------------------------------------------------------------------


def load_baseline(path: Path) -> list[dict]:
    if not path.exists():
        return []
    data = json.loads(path.read_text())
    assert isinstance(data, dict) and "findings" in data, (
        f"{path}: baseline must be an object with a 'findings' list"
    )
    return list(data["findings"])


def write_baseline(path: Path, findings: list[Finding]) -> None:
    entries = [
        {k: v for k, v in asdict(f).items() if k in
         ("checker", "path", "line", "line_hash", "message")}
        for f in sorted(findings, key=lambda f: (f.path, f.line, f.checker))
    ]
    path.write_text(
        json.dumps(
            {
                "comment": (
                    "repro_lint baseline: grandfathered findings. Entries are "
                    "matched by (checker, path, line content hash) — editing a "
                    "baselined line invalidates its entry and the lint fails "
                    "loudly until the entry is removed or the finding fixed. "
                    "Regenerate with: python tools/repro_lint.py --all "
                    "--update-baseline"
                ),
                "findings": entries,
            },
            indent=2,
        )
        + "\n"
    )


def apply_baseline(
    findings: list[Finding], baseline: list[dict], repo_root: Path
) -> tuple[list[Finding], list[Finding], list[str]]:
    """Split findings into (new, suppressed) and detect stale entries.

    A baseline entry suppresses at most one finding with a matching
    (checker, path, line_hash). Entries that match no current finding are
    *stale* in one of two ways, both reported: the flagged line no longer
    exists anywhere in the file (fixed — remove the entry), or the line text
    changed (the hash matches nothing — the entry may now be masking a
    different violation, so it must be re-audited). Either way the lint
    fails until the baseline is regenerated, never silently.
    """
    budget: dict[tuple, int] = {}
    for e in baseline:
        key = (e["checker"], e["path"], e["line_hash"])
        budget[key] = budget.get(key, 0) + 1
    new: list[Finding] = []
    suppressed: list[Finding] = []
    for f in findings:
        key = f.baseline_key()
        if budget.get(key, 0) > 0:
            budget[key] -= 1
            suppressed.append(f)
        else:
            new.append(f)
    stale: list[str] = []
    for e in baseline:
        key = (e["checker"], e["path"], e["line_hash"])
        if budget.get(key, 0) <= 0:
            continue  # fully consumed by current findings
        src = repo_root / e["path"]
        hashes = (
            {line_hash(l) for l in src.read_text().splitlines()}
            if src.exists()
            else set()
        )
        if e["line_hash"] in hashes:
            # line still exists but the checker no longer flags it: fixed
            stale.append(
                f"{e['path']}: baseline entry for [{e['checker']}] no longer "
                f"fires (line {e.get('line', '?')}) — remove it"
            )
        else:
            stale.append(
                f"{e['path']}: STALE baseline entry [{e['checker']}] — the "
                f"flagged line (hash {e['line_hash']}) was edited or removed; "
                "re-audit and regenerate the baseline"
            )
    return new, suppressed, stale


def render(f: Finding) -> str:
    hint = f"  [fix: {f.fix_hint}]" if f.fix_hint else ""
    return f"{f.anchor()}: {f.severity}: [{f.checker}] {f.message}{hint}"
