"""Shared AST machinery for the source-level checkers.

Three things live here because every checker needs them:

* :class:`Module` / :class:`ModuleCache` — parse each file once (AST, raw
  lines, annotation allowlist, dotted module name) no matter how many
  checkers scan it;
* traced-scope detection (:func:`traced_defs`) — which function bodies
  execute under a JAX trace. A function is traced if it is decorated with
  ``jit``/``vmap``/``pallas_call`` (directly or through ``partial``), if its
  name is passed as the first argument to one of those wrappers anywhere in
  the module (the repo's factory idiom: ``def superstep(...)`` ... ``return
  jax.jit(superstep, donate_argnums=0)``), or if it is lexically nested in a
  traced function;
* the repo-local import graph (:func:`repo_imports`, :func:`reachable`) for
  the collective-free reachability check, with ``if TYPE_CHECKING:`` blocks
  skipped — typing-only imports don't execute and must not create edges.
"""

from __future__ import annotations

import ast
from dataclasses import dataclass
from pathlib import Path

from .findings import Annotations, Finding, line_hash, scan_annotations

__all__ = [
    "Module",
    "ModuleCache",
    "attach_parents",
    "traced_defs",
    "repo_imports",
    "reachable",
    "root_name",
    "expr_key",
    "call_name",
    "src_finding",
]

_FUNC_DEFS = (ast.FunctionDef, ast.AsyncFunctionDef)
# wrappers whose wrapped function executes under a trace
_TRACE_WRAPPERS = {"jit", "pjit", "vmap", "pallas_call"}


@dataclass
class Module:
    path: Path
    rel: str  # repo-relative posix path
    source: str
    lines: list[str]
    tree: ast.Module
    func_ranges: list[tuple[int, int]]
    annotations: Annotations
    imports_jax: bool
    name: str  # dotted module name ("repro.lbm.halo"), "" outside src/
    is_pkg: bool


class ModuleCache:
    """Parse-once cache keyed by absolute path."""

    def __init__(self, repo_root: Path):
        self.repo_root = repo_root
        self._mods: dict[Path, Module | None] = {}

    def get(self, path: Path) -> Module | None:
        path = path.resolve()
        if path not in self._mods:
            self._mods[path] = self._parse(path)
        return self._mods[path]

    def _parse(self, path: Path) -> Module | None:
        try:
            source = path.read_text()
            tree = ast.parse(source)
        except (OSError, SyntaxError):
            return None
        attach_parents(tree)
        func_ranges = [
            (n.lineno, n.end_lineno or n.lineno)
            for n in ast.walk(tree)
            if isinstance(n, _FUNC_DEFS)
        ]
        rel = path.relative_to(self.repo_root).as_posix()
        return Module(
            path=path,
            rel=rel,
            source=source,
            lines=source.splitlines(),
            tree=tree,
            func_ranges=func_ranges,
            annotations=scan_annotations(source, func_ranges),
            imports_jax=_imports_jax(tree),
            name=_dotted_name(rel),
            is_pkg=path.name == "__init__.py",
        )

    def files(self, roots: list[str], exclude: tuple[str, ...] = ("fixtures",)) -> list[Path]:
        """Expand configured path roots (files or directories) to .py files."""
        out: set[Path] = set()
        for root in roots:
            p = (self.repo_root / root).resolve()
            if p.is_file():
                out.add(p)
            elif p.is_dir():
                for f in p.rglob("*.py"):
                    rel_parts = f.relative_to(self.repo_root).parts
                    if not any(part in exclude for part in rel_parts):
                        out.add(f)
        return sorted(out)

    def src_modules(self) -> dict[str, Module]:
        """Dotted-name map of every module under src/ (the import graph)."""
        out: dict[str, Module] = {}
        for f in self.files(["src"]):
            mod = self.get(f)
            if mod is not None and mod.name:
                out[mod.name] = mod
        return out


def _dotted_name(rel: str) -> str:
    parts = rel.split("/")
    if parts[0] != "src" or not parts[-1].endswith(".py"):
        return ""
    parts = parts[1:]
    if parts[-1] == "__init__.py":
        parts = parts[:-1]
    else:
        parts[-1] = parts[-1][:-3]
    return ".".join(parts)


def _imports_jax(tree: ast.Module) -> bool:
    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            if any(a.name == "jax" or a.name.startswith("jax.") for a in node.names):
                return True
        elif isinstance(node, ast.ImportFrom):
            if node.module and (node.module == "jax" or node.module.startswith("jax.")):
                return True
    return False


def attach_parents(tree: ast.AST) -> None:
    for node in ast.walk(tree):
        for child in ast.iter_child_nodes(node):
            child.parent = node  # type: ignore[attr-defined]


def ancestors(node: ast.AST):
    cur = getattr(node, "parent", None)
    while cur is not None:
        yield cur
        cur = getattr(cur, "parent", None)


def enclosing_def(node: ast.AST) -> ast.AST | None:
    for a in ancestors(node):
        if isinstance(a, _FUNC_DEFS):
            return a
    return None


def _last_name(expr: ast.expr) -> str:
    if isinstance(expr, ast.Name):
        return expr.id
    if isinstance(expr, ast.Attribute):
        return expr.attr
    return ""


def call_name(call: ast.Call) -> str:
    """Last path component of a call's callee (``jax.jit`` -> ``jit``)."""
    return _last_name(call.func)


def _is_trace_wrapper(expr: ast.expr) -> bool:
    return _last_name(expr) in _TRACE_WRAPPERS


def _decorator_traces(dec: ast.expr) -> bool:
    if _is_trace_wrapper(dec):
        return True
    if isinstance(dec, ast.Call):
        # @partial(jax.jit, ...) — the wrapper hides in the partial's args
        if _is_trace_wrapper(dec.func):
            return True
        return any(_is_trace_wrapper(a) for a in dec.args)
    return False


def traced_defs(tree: ast.Module) -> set[ast.AST]:
    """Function defs whose bodies execute under a JAX trace (see module doc)."""
    wrapped_names: set[str] = set()
    for node in ast.walk(tree):
        if (
            isinstance(node, ast.Call)
            and _is_trace_wrapper(node.func)
            and node.args
            and isinstance(node.args[0], ast.Name)
        ):
            wrapped_names.add(node.args[0].id)
    traced: set[ast.AST] = set()
    defs = [n for n in ast.walk(tree) if isinstance(n, _FUNC_DEFS)]
    for d in defs:
        if d.name in wrapped_names or any(_decorator_traces(dec) for dec in d.decorator_list):
            traced.add(d)
    # lexical nesting: a def inside a traced def is traced too
    for d in defs:
        if d not in traced and any(a in traced for a in ancestors(d)):
            traced.add(d)
    return traced


def root_name(expr: ast.expr) -> str:
    """Leftmost Name of an attribute/subscript chain (``a.b[c].d`` -> ``a``)."""
    while isinstance(expr, (ast.Attribute, ast.Subscript)):
        expr = expr.value
    return expr.id if isinstance(expr, ast.Name) else ""


def expr_key(expr: ast.expr) -> str:
    """Stable textual key for the access paths the donation checker tracks
    (names, attribute chains, constant-or-name subscripts). Returns "" for
    expressions too dynamic to track."""
    if isinstance(expr, ast.Name):
        return expr.id
    if isinstance(expr, ast.Attribute):
        base = expr_key(expr.value)
        return f"{base}.{expr.attr}" if base else ""
    if isinstance(expr, ast.Subscript):
        base = expr_key(expr.value)
        if not base:
            return ""
        sl = expr.slice
        if isinstance(sl, ast.Constant):
            return f"{base}[{sl.value!r}]"
        if isinstance(sl, ast.Name):
            return f"{base}[{sl.id}]"
        return f"{base}[?]"
    return ""


# -- repo-local import graph -------------------------------------------------------


def _is_type_checking_if(node: ast.stmt) -> bool:
    return isinstance(node, ast.If) and _last_name(node.test) == "TYPE_CHECKING"


def _iter_stmts(body: list[ast.stmt]):
    """All statements, skipping ``if TYPE_CHECKING:`` bodies (typing-only
    imports never execute — they must not create reachability edges)."""
    for stmt in body:
        if _is_type_checking_if(stmt):
            yield from _iter_stmts(stmt.orelse)
            continue
        yield stmt
        for attr in ("body", "orelse", "finalbody", "handlers"):
            sub = getattr(stmt, attr, None)
            if not sub:
                continue
            if attr == "handlers":
                for h in sub:
                    yield from _iter_stmts(h.body)
            else:
                yield from _iter_stmts(sub)


def repo_imports(mod: Module, known: set[str]) -> set[str]:
    """Dotted names of repo modules ``mod`` imports (resolved against
    ``known``, the full src/ module map — ``from . import x`` may name either
    a submodule or an attribute, so both candidates are tried)."""
    parts = mod.name.split(".") if mod.name else []
    pkg = parts if mod.is_pkg else parts[:-1]
    out: set[str] = set()

    def add(cand: str) -> None:
        # resolve to the longest known prefix (importing repro.core.comm
        # also executes repro.core/__init__)
        bits = cand.split(".")
        for i in range(len(bits), 0, -1):
            name = ".".join(bits[:i])
            if name in known:
                out.add(name)
                return

    for stmt in _iter_stmts(mod.tree.body):
        if isinstance(stmt, ast.Import):
            for a in stmt.names:
                add(a.name)
        elif isinstance(stmt, ast.ImportFrom):
            if stmt.level:
                base = pkg[: len(pkg) - (stmt.level - 1)]
                base_name = ".".join(base + (stmt.module.split(".") if stmt.module else []))
            else:
                base_name = stmt.module or ""
            if not base_name:
                continue
            add(base_name)
            for a in stmt.names:
                add(f"{base_name}.{a.name}")
    return out


def reachable(
    roots: list[str], modules: dict[str, Module], exclude: set[str]
) -> dict[str, str]:
    """BFS the import graph from ``roots``; returns module -> predecessor
    ("" for roots). ``exclude`` names are never entered (control-plane
    modules sanctioned to use collectives)."""
    seen: dict[str, str] = {}
    frontier = [r for r in roots if r in modules and r not in exclude]
    for r in frontier:
        seen[r] = ""
    while frontier:
        nxt: list[str] = []
        for name in frontier:
            for dep in sorted(repo_imports(modules[name], set(modules))):
                if dep in seen or dep in exclude:
                    continue
                seen[dep] = name
                nxt.append(dep)
        frontier = nxt
    return seen


def import_chain(name: str, seen: dict[str, str]) -> str:
    chain = [name]
    while seen.get(chain[-1]):
        chain.append(seen[chain[-1]])
    return " <- ".join(chain)


def src_finding(
    mod: Module,
    checker: str,
    lineno: int,
    message: str,
    fix_hint: str = "",
    severity: str = "error",
) -> Finding:
    text = mod.lines[lineno - 1] if 0 < lineno <= len(mod.lines) else ""
    return Finding(
        checker=checker,
        severity=severity,
        path=mod.rel,
        line=lineno,
        message=message,
        fix_hint=fix_hint,
        line_hash=line_hash(text),
    )
