"""The source-level checkers: host-transfer, donation-safety, collective-free,
retrace-static.

Each checker is a function ``(cfg, cache) -> list[Finding]`` registered in
:data:`CHECKERS`; :func:`run` drives any subset and folds in the
annotation-hygiene findings (an ``-ok()`` with an empty reason is itself an
error — an undocumented sanction is exactly the drift the annotation grammar
exists to prevent). The paper invariant each checker guards is spelled out in
ARCHITECTURE.md; the mechanics live here:

* **host** — implicit device->host syncs in the designated hot-path modules
  (config ``[tool.repro_lint.host_transfer]``). ``.item()``/``.tolist()``/
  ``block_until_ready`` are flagged anywhere in a hot module;
  ``np.asarray``/``np.array`` only in modules that import jax (halo.py is
  numpy-only — there the same call is a host-side copy, not a sync) and only
  when the argument isn't an obvious host value; ``float()``/``int()``/
  ``bool()`` and ``for``-iteration only inside traced scopes and only on the
  traced function's own parameters (host closures like lattice constants stay
  legal).
* **donation** — intra-function linear dataflow: a buffer passed to a program
  built by one of the configured donating factories is dead afterwards; any
  later read (including through a local alias or an attribute store) is a
  use-after-donate. Rebinding revives: ``pdfs = fn(pdfs)`` is the sanctioned
  idiom.
* **collective** — no collective-class call (``psum``/``all_gather``/...) in
  any module reachable from the stepping roots through the repo import graph
  (control-plane modules excluded by config). The static twin of the Table-1
  runtime assertions: stepping is p2p-only.
* **retrace** — static unstable-compile-cache patterns: jit programs built
  inside loops, jit of a lambda at function scope, traced closures over
  mutated mutable locals, float-defaulted static args.
"""

from __future__ import annotations

import ast
from pathlib import Path

from .astutil import (
    Module,
    ModuleCache,
    ancestors,
    call_name,
    enclosing_def,
    expr_key,
    import_chain,
    reachable,
    root_name,
    src_finding,
    traced_defs,
    _FUNC_DEFS,
    _last_name,
)
from .config import LintConfig
from .findings import Finding, line_hash

__all__ = ["CHECKERS", "run", "annotation_findings"]

_SYNC_METHODS = {"item", "tolist", "block_until_ready"}
_HOST_CASTS = {"float", "int", "bool"}
_NP_COPY = {"asarray", "array"}
# callees whose result is trivially a host value: casting it is not a sync
_HOST_PRODUCERS = {
    "list", "tuple", "dict", "sorted", "range", "len", "zip", "enumerate",
    "sum", "min", "max", "str", "repr",
}
_MUTATORS = {
    "append", "extend", "insert", "add", "update", "pop", "popitem",
    "remove", "discard", "clear", "setdefault",
}


def _allowed(mod: Module, node: ast.AST, checker: str) -> bool:
    return mod.annotations.allows(getattr(node, "lineno", 0), checker)


def _is_host_value(expr: ast.expr) -> bool:
    """Expressions that cannot be device arrays: literals, displays,
    comprehensions, and calls to plain host builtins."""
    if isinstance(
        expr,
        (
            ast.Constant, ast.List, ast.Tuple, ast.Dict, ast.Set,
            ast.ListComp, ast.SetComp, ast.DictComp, ast.GeneratorExp,
            ast.JoinedStr,
        ),
    ):
        return True
    if isinstance(expr, ast.Call) and call_name(expr) in _HOST_PRODUCERS:
        return True
    return False


def _np_base(expr: ast.expr) -> bool:
    return isinstance(expr, ast.Attribute) and _last_name(expr.value) in ("np", "numpy", "onp")


def check_host_transfer(cfg: LintConfig, cache: ModuleCache) -> list[Finding]:
    sec = cfg.section("host_transfer")
    out: list[Finding] = []
    for path in cache.files(sec["paths"]):
        mod = cache.get(path)
        if mod is None:
            continue
        traced = traced_defs(mod.tree)
        traced_params: dict[ast.AST, set[str]] = {
            d: {a.arg for a in (*d.args.posonlyargs, *d.args.args, *d.args.kwonlyargs)}
            for d in traced
        }

        def in_traced_on_param(node: ast.AST, value: ast.expr) -> bool:
            d = enclosing_def(node)
            while d is not None and d not in traced_params:
                d = enclosing_def(d)
            return d is not None and root_name(value) in traced_params[d]

        for node in ast.walk(mod.tree):
            if isinstance(node, ast.Call):
                name = call_name(node)
                if isinstance(node.func, ast.Attribute) and name in _SYNC_METHODS:
                    if not _allowed(mod, node, "host"):
                        out.append(src_finding(
                            mod, "host", node.lineno,
                            f".{name}() forces a device->host sync",
                            "keep the value on device, or annotate the "
                            "sanctioned sync with '# repro: host-ok(reason)'",
                        ))
                elif name == "block_until_ready" and not _allowed(mod, node, "host"):
                    out.append(src_finding(
                        mod, "host", node.lineno,
                        "block_until_ready() stalls the device pipeline",
                        "only benchmarks may fence; annotate with "
                        "'# repro: host-ok(reason)' if this fence is the contract",
                    ))
                elif (
                    name in _NP_COPY
                    and _np_base(node.func)
                    and mod.imports_jax
                    and node.args
                    and not _is_host_value(node.args[0])
                    and not _allowed(mod, node, "host")
                ):
                    out.append(src_finding(
                        mod, "host", node.lineno,
                        f"np.{name}() on a possibly device-resident value is "
                        "an implicit device->host transfer",
                        "use jnp on device, or annotate the sanctioned "
                        "materialization with '# repro: host-ok(reason)'",
                    ))
                elif (
                    name in _HOST_CASTS
                    and isinstance(node.func, ast.Name)
                    and node.args
                    and in_traced_on_param(node, node.args[0])
                    and not _allowed(mod, node, "host")
                ):
                    out.append(src_finding(
                        mod, "host", node.lineno,
                        f"{name}() on a traced value forces a concretization "
                        "(device->host sync or tracer error)",
                        "keep the computation in jnp ops",
                    ))
            elif isinstance(node, ast.For):
                if in_traced_on_param(node, node.iter) and not _allowed(mod, node, "host"):
                    out.append(src_finding(
                        mod, "host", node.lineno,
                        "Python iteration over a traced array unrolls on host "
                        "(one sync per element)",
                        "vectorize with jnp ops or lax primitives",
                    ))
    return out


# -- donation safety ---------------------------------------------------------------


class _DonationScan:
    """Linear intra-function dataflow over one def body.

    State: ``donors`` — access paths bound to donating programs; ``dead`` —
    access paths whose buffer was consumed (value: donation line); ``groups``
    — alias sets (``a = b`` makes a and b die together). Statements are
    visited in source order (branches sequentially — the checker
    over-approximates; annotations cover the rare intentional case).
    """

    def __init__(self, mod: Module, factories: set[str]):
        self.mod = mod
        self.factories = factories
        self.donors: set[str] = set()
        self.dead: dict[str, int] = {}
        self.groups: dict[str, set[str]] = {}
        self.findings: list[Finding] = []

    def _group(self, key: str) -> set[str]:
        return self.groups.setdefault(key, {key})

    def _alias(self, target: str, source: str) -> None:
        g = self._group(source)
        g.add(target)
        self.groups[target] = g

    def _kill(self, key: str, lineno: int) -> None:
        for member in self._group(key):
            self.dead.setdefault(member, lineno)

    def _revive(self, key: str) -> None:
        self.dead.pop(key, None)
        for k in [k for k in self.dead if k.startswith(key + "[") or k.startswith(key + ".")]:
            self.dead.pop(k)
        g = self.groups.pop(key, None)
        if g is not None:
            g.discard(key)
        self.donors.discard(key)

    def _check_reads(self, node: ast.AST, skip: set[ast.AST]) -> None:
        for sub in ast.walk(node):
            if sub in skip or not isinstance(sub, (ast.Name, ast.Attribute, ast.Subscript)):
                continue
            if isinstance(getattr(sub, "ctx", None), (ast.Store, ast.Del)):
                continue
            # only the outermost tracked expression counts as the read
            parent = getattr(sub, "parent", None)
            if isinstance(parent, (ast.Attribute, ast.Subscript)) and expr_key(parent):
                continue
            key = expr_key(sub)
            if not key:
                continue
            hit = next(
                (d for d in self.dead
                 if key == d or key.startswith(d + "[") or key.startswith(d + ".")),
                None,
            )
            if hit is not None and not _allowed(self.mod, sub, "donation"):
                self.findings.append(src_finding(
                    self.mod, "donation", sub.lineno,
                    f"read of '{key}' after its buffer was donated on line "
                    f"{self.dead[hit]} (use-after-donate: the array is "
                    "consumed by the donating program)",
                    "rebind the result over the operand "
                    "('pdfs = fn(pdfs)') or copy before donating",
                ))

    def _donations(self, node: ast.AST) -> set[ast.AST]:
        """Mark first-arg donations for calls of donor programs; returns the
        consumed arg nodes (their read happens at donation, not after)."""
        consumed: set[ast.AST] = set()
        for sub in ast.walk(node):
            if not isinstance(sub, ast.Call) or not sub.args:
                continue
            fkey = expr_key(sub.func)
            if fkey not in self.donors:
                continue
            arg = sub.args[0]
            key = expr_key(arg)
            if key:
                consumed.add(arg)
                self._kill(key, sub.lineno)
        return consumed

    def _seed_donors(self, value: ast.expr, targets: list[ast.expr]) -> None:
        calls = [value] if isinstance(value, ast.Call) else []
        if not calls or call_name(calls[0]) not in self.factories:
            # jax.jit(..., donate_argnums=...) builds a donor directly
            if not (
                isinstance(value, ast.Call)
                and call_name(value) in ("jit", "pjit")
                and any(k.arg in ("donate_argnums", "donate_argnames") for k in value.keywords)
            ):
                return
        names: list[ast.expr] = []
        for t in targets:
            names.extend(t.elts if isinstance(t, (ast.Tuple, ast.List)) else [t])
        for t in names:
            key = expr_key(t)
            if key:
                self.donors.add(key)

    def stmt(self, node: ast.stmt) -> None:
        if isinstance(node, _FUNC_DEFS):
            return  # nested defs get their own scan
        if isinstance(node, (ast.With, ast.AsyncWith)):
            # a with-block is straight-line code (no back edge), so the
            # whole-subtree pre-scan below would be pure over-approximation:
            # it kills on a donation anywhere in the body before the body's
            # own rebinds can revive. Check only the context managers here,
            # then visit the body in source order like any other suite.
            for item in node.items:
                consumed = self._donations(item.context_expr)
                self._check_reads(item.context_expr, skip=consumed)
                if item.optional_vars is not None:
                    key = expr_key(item.optional_vars)
                    if key:
                        self._revive(key)
            for sub in node.body:
                self.stmt(sub)
            return
        targets: list[ast.expr] = []
        value: ast.expr | None = None
        if isinstance(node, ast.Assign):
            targets, value = node.targets, node.value
        elif isinstance(node, ast.AnnAssign) and node.value is not None:
            targets, value = [node.target], node.value
        elif isinstance(node, ast.AugAssign):
            # target is read-modify-write: the read is checked, no revive
            self._check_reads(node, skip=set())
            self._donations(node)
            return

        # reads first (RHS evaluates before the store), skipping the args a
        # donation itself consumes — 'pdfs = fn(pdfs)' reads a live buffer
        consumed = self._donations(node)
        check_root = value if value is not None else node
        self._check_reads(check_root, skip=consumed)
        if value is not None:
            # rebinds revive the old binding first, then the new value may
            # seed a donor or alias the source
            src_key = expr_key(value)
            flat: list[ast.expr] = []
            for t in targets:
                flat.extend(t.elts if isinstance(t, (ast.Tuple, ast.List)) else [t])
            for t in flat:
                tkey = expr_key(t)
                if not tkey:
                    continue
                self._revive(tkey)
                if src_key and len(flat) == 1:
                    self._alias(tkey, src_key)
            self._seed_donors(value, targets)
        # recurse into compound bodies in source order
        for attr in ("body", "orelse", "finalbody"):
            for sub in getattr(node, attr, ()) or ():
                self.stmt(sub)
        for h in getattr(node, "handlers", ()) or ():
            for sub in h.body:
                self.stmt(sub)


def check_donation(cfg: LintConfig, cache: ModuleCache) -> list[Finding]:
    sec = cfg.section("donation")
    factories = set(sec["factories"])
    out: list[Finding] = []
    for path in cache.files(sec["paths"]):
        mod = cache.get(path)
        if mod is None:
            continue
        for d in ast.walk(mod.tree):
            if not isinstance(d, _FUNC_DEFS):
                continue
            scan = _DonationScan(mod, factories)
            for stmt in d.body:
                scan.stmt(stmt)
            out.extend(scan.findings)
    return out


# -- collective-free stepping ------------------------------------------------------


def check_collective(cfg: LintConfig, cache: ModuleCache) -> list[Finding]:
    sec = cfg.section("collective")
    collectives = set(sec["collectives"])
    modules = cache.src_modules()
    seen = reachable(list(sec["stepping_modules"]), modules, set(sec["exclude"]))
    out: list[Finding] = []
    for name in sorted(seen):
        mod = modules[name]
        for node in ast.walk(mod.tree):
            if not isinstance(node, ast.Call) or call_name(node) not in collectives:
                continue
            # a collective implementing itself in the fabric (Comm.allreduce's
            # body) is the provider, not a stepping-path caller
            encl = enclosing_def(node)
            if encl is not None and encl.name in collectives:
                continue
            if _allowed(mod, node, "collective"):
                continue
            out.append(src_finding(
                mod, "collective", node.lineno,
                f"collective '{call_name(node)}' reachable from the stepping "
                f"path (import chain: {import_chain(name, seen)}) — stepping "
                "must be p2p-only (paper §2, Table 1)",
                "move the collective to a control-plane module (AMR cycle), "
                "or annotate with '# repro: collective-ok(reason)'",
            ))
    return out


# -- retrace static scan -----------------------------------------------------------


def _jit_like(node: ast.Call) -> bool:
    return call_name(node) in ("jit", "pjit")


def check_retrace(cfg: LintConfig, cache: ModuleCache) -> list[Finding]:
    sec = cfg.section("retrace")
    out: list[Finding] = []
    for path in cache.files(sec["paths"]):
        mod = cache.get(path)
        if mod is None:
            continue
        traced = traced_defs(mod.tree)
        for node in ast.walk(mod.tree):
            if isinstance(node, ast.Call) and _jit_like(node):
                if _allowed(mod, node, "retrace"):
                    continue
                in_loop = any(isinstance(a, (ast.For, ast.While)) for a in ancestors(node))
                if in_loop:
                    out.append(src_finding(
                        mod, "retrace", node.lineno,
                        "jit program constructed inside a loop: every "
                        "iteration builds a fresh cache entry (retrace + "
                        "compile per iteration)",
                        "hoist the jit() out of the loop or cache the "
                        "program keyed on its static config",
                    ))
                if node.args and isinstance(node.args[0], ast.Lambda) and enclosing_def(node):
                    out.append(src_finding(
                        mod, "retrace", node.lineno,
                        "jit of a lambda at function scope: a new function "
                        "object per call defeats the jit cache",
                        "define the function once at module or factory scope",
                    ))
                out.extend(_float_static_args(mod, node))
        out.extend(_mutable_closures(mod, traced))
    return out


def _float_static_args(mod: Module, node: ast.Call) -> list[Finding]:
    """jit(fn, static_argnums=...) where fn's param at a static position has a
    float default: float statics hash by value, so every perturbation (sweep,
    annealing schedule) recompiles."""
    static: list[int] = []
    for kw in node.keywords:
        if kw.arg == "static_argnums":
            vals = kw.value.elts if isinstance(kw.value, ast.Tuple) else [kw.value]
            static = [v.value for v in vals if isinstance(v, ast.Constant) and isinstance(v.value, int)]
    if not static or not node.args or not isinstance(node.args[0], ast.Name):
        return []
    fn_def = next(
        (d for d in ast.walk(mod.tree)
         if isinstance(d, _FUNC_DEFS) and d.name == node.args[0].id),
        None,
    )
    if fn_def is None:
        return []
    args = [*fn_def.args.posonlyargs, *fn_def.args.args]
    defaults = fn_def.args.defaults
    default_of = dict(zip([a.arg for a in args[len(args) - len(defaults):]], defaults))
    out = []
    for i in static:
        if i >= len(args):
            continue
        dflt = default_of.get(args[i].arg)
        if isinstance(dflt, ast.Constant) and isinstance(dflt.value, float):
            if not _allowed(mod, node, "retrace"):
                out.append(src_finding(
                    mod, "retrace", node.lineno,
                    f"static arg '{args[i].arg}' (position {i}) defaults to a "
                    "float: float statics recompile on every distinct value",
                    "pass it as a traced operand, or quantize it into the "
                    "program's static config",
                ))
    return out


def _mutable_closures(mod: Module, traced: set[ast.AST]) -> list[Finding]:
    """Traced inner defs closing over a mutable local of the factory that the
    factory (or the traced body) also mutates: the closure cell changes under
    the jit cache's feet — either silently stale (captured at trace time) or
    a retrace source when used as a static."""
    out: list[Finding] = []
    for inner in traced:
        outer = enclosing_def(inner)
        if outer is None:
            continue
        inner_locals = {a.arg for a in (*inner.args.posonlyargs, *inner.args.args, *inner.args.kwonlyargs)}
        for n in ast.walk(inner):
            if isinstance(n, ast.Name) and isinstance(n.ctx, ast.Store):
                inner_locals.add(n.id)
        mutable_locals: set[str] = set()
        for stmt in ast.walk(outer):
            if isinstance(stmt, ast.Assign) and isinstance(
                stmt.value, (ast.List, ast.Dict, ast.Set, ast.ListComp, ast.DictComp, ast.SetComp)
            ):
                for t in stmt.targets:
                    if isinstance(t, ast.Name):
                        mutable_locals.add(t.id)
        if not mutable_locals:
            continue
        mutated = {
            root_name(n.func.value)
            for n in ast.walk(outer)
            if isinstance(n, ast.Call)
            and isinstance(n.func, ast.Attribute)
            and n.func.attr in _MUTATORS
        } | {
            root_name(n.targets[0] if isinstance(n, ast.Assign) else n.target)
            for n in ast.walk(outer)
            if isinstance(n, (ast.Assign, ast.AugAssign))
            and isinstance((n.targets[0] if isinstance(n, ast.Assign) else n.target), ast.Subscript)
        }
        for n in ast.walk(inner):
            if (
                isinstance(n, ast.Name)
                and isinstance(n.ctx, ast.Load)
                and n.id in mutable_locals
                and n.id in mutated
                and n.id not in inner_locals
                and not _allowed(mod, n, "retrace")
            ):
                out.append(src_finding(
                    mod, "retrace", inner.lineno,
                    f"traced function '{inner.name}' closes over mutable "
                    f"local '{n.id}' that the factory mutates: the traced "
                    "program captures a snapshot, later mutations are "
                    "silently ignored (or force retraces)",
                    "freeze the value (tuple) before tracing, or pass it "
                    "as an operand",
                ))
                break
    return out


# -- runner ------------------------------------------------------------------------


def annotation_findings(cfg: LintConfig, cache: ModuleCache) -> list[Finding]:
    """Empty-reason annotations across every scanned file."""
    paths: set[Path] = set()
    for sec_name in ("host_transfer", "donation", "retrace"):
        paths.update(cache.files(cfg.section(sec_name)["paths"]))
    out: list[Finding] = []
    for path in sorted(paths):
        mod = cache.get(path)
        if mod is None:
            continue
        for lineno, checker in mod.annotations.empty:
            out.append(src_finding(
                mod, "annotation", lineno,
                f"'{checker}-ok()' has an empty reason — every sanctioned "
                "finding must document why it is sanctioned",
                f"write '# repro: {checker}-ok(<why this is safe>)'",
            ))
    return out


CHECKERS = {
    "host": check_host_transfer,
    "donation": check_donation,
    "collective": check_collective,
    "retrace": check_retrace,
}


def run(cfg: LintConfig, names: list[str] | None = None, cache: ModuleCache | None = None) -> list[Finding]:
    cache = cache or ModuleCache(cfg.repo_root)
    names = names or list(CHECKERS)
    out: list[Finding] = []
    for name in names:
        out.extend(CHECKERS[name](cfg, cache))
    out.extend(annotation_findings(cfg, cache))
    return sorted(out, key=lambda f: (f.path, f.line, f.checker))
